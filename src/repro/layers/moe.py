"""Mixture-of-Experts FFN: top-k routing, capacity-bounded local dispatch.

Distribution (docs/DESIGN.md §5): expert weights are laid out (E, D, F) with
D sharded over "data" (ZeRO-3) and F over "model" (tensor parallel); the
expert dim is *not* device-sharded (8 experts don't divide a 16-way axis, and
keeping dispatch local to each data shard avoids the all-to-all entirely —
tokens never leave their data shard). Inside ``shard_map``:

    all-gather(W, "data")  —  ZeRO-3 weight gather, per layer
    local top-k dispatch    —  capacity C = ceil(T_loc·k·cf/E), overflow drops
    expert einsums          —  (E,C,D)x(E,D,F_loc): MXU-dense grouped GEMM
    psum(out, "model")      —  tensor-parallel reduction (post-combine, so the
                               reduced tensor is (T_loc, D), not (E,C,D))

The MoE layer is therefore collective-light: one weight all-gather + one
activation psum per layer; no token all-to-all.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.params import Builder


def init_moe(cfg, key):
    b = Builder(key, dtype=jnp.dtype(cfg.dtype))
    d, e = cfg.d_model, cfg.n_experts
    f = cfg.moe_d_ff or cfg.d_ff
    b.dense("wr", (d, e), (None, None), fan_in=d, dtype=jnp.float32)
    b.dense("w1", (e, d, f), ("experts", "embed_fsdp", "mlp"), fan_in=d)
    b.dense("w3", (e, d, f), ("experts", "embed_fsdp", "mlp"), fan_in=d)
    b.dense("w2", (e, f, d), ("experts", "mlp", "embed_fsdp"), fan_in=f)
    return b.build()


def _dispatch_combine(cfg, p, x, *, data_axes: Tuple[str, ...], model_axis,
                      capacity_factor: float):
    """Per-shard MoE body. x: (b_loc, s, d) local tokens, full D."""
    dist = model_axis is not None
    bsz, s, d = x.shape
    t = bsz * s
    e, k = cfg.n_experts, cfg.top_k
    cap = max(int(math.ceil(t * k * capacity_factor / e)), 1)

    if dist:
        # ZeRO-3 weight gather: expert weights shard D over "data" only.
        w1 = jax.lax.all_gather(p["w1"], "data", axis=1, tiled=True)
        w3 = jax.lax.all_gather(p["w3"], "data", axis=1, tiled=True)
        w2 = jax.lax.all_gather(p["w2"], "data", axis=2, tiled=True)
    else:
        w1, w3, w2 = p["w1"], p["w3"], p["w2"]

    xf = x.reshape(t, d)
    logits = (xf.astype(jnp.float32) @ p["wr"]).astype(jnp.float32)   # (t, e)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                               # (t, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # position within each expert's capacity buffer (sort-free cumsum ranking)
    oh = jax.nn.one_hot(idx, e, dtype=jnp.int32).reshape(t * k, e)
    pos = jnp.sum((jnp.cumsum(oh, axis=0) - oh) * oh, axis=-1)        # (t*k,)
    eid = idx.reshape(t * k)
    keep = pos < cap
    slot = jnp.where(keep, eid * cap + pos, e * cap)                  # OOB row drops

    xrep = jnp.broadcast_to(xf[:, None, :], (t, k, d)).reshape(t * k, d)
    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], xrep, 0.0), mode="drop")
    xe = buf[: e * cap].reshape(e, cap, d)

    h = jnp.einsum("ecd,edf->ecf", xe, w1.astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, w3.astype(x.dtype))
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, w2.astype(x.dtype))

    yflat = jnp.concatenate([ye.reshape(e * cap, d),
                             jnp.zeros((1, d), x.dtype)], axis=0)
    contrib = yflat[slot] * (gate.reshape(t * k, 1) * keep[:, None]).astype(x.dtype)
    out = contrib.reshape(t, k, d).sum(axis=1)
    if dist:
        out = jax.lax.psum(out, model_axis)                           # TP reduce

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    frac = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    pmean = jnp.mean(probs, axis=0)
    if data_axes:
        frac = jax.lax.pmean(frac, data_axes)
        pmean = jax.lax.pmean(pmean, data_axes)
    aux = e * jnp.sum(frac * pmean)
    return out.reshape(bsz, s, d), aux


def moe_ffn(cfg, p, x, mesh=None, *, capacity_factor: float = 1.25):
    """Returns (out, aux_loss). If ``mesh`` is None, runs unsharded (tests)."""
    if mesh is None:
        return _dispatch_combine(cfg, p, x, data_axes=(), model_axis=None,
                                 capacity_factor=capacity_factor)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    # batch=1 decode cells can't shard tokens over data: replicate instead
    shard_batch = bool(data_axes) and x.shape[0] % n_data == 0
    bspec = (data_axes if len(data_axes) > 1 else data_axes[0]) if shard_batch else None
    body = partial(_dispatch_combine, cfg,
                   data_axes=data_axes if shard_batch else (),
                   model_axis="model", capacity_factor=capacity_factor)
    p_specs = {
        "wr": P(None, None),
        "w1": P(None, "data", "model"),
        "w3": P(None, "data", "model"),
        "w2": P(None, "model", "data"),
    }
    fn = jax.shard_map(
        lambda pp, xx: body(pp, xx),
        mesh=mesh,
        in_specs=(p_specs, P(bspec, None, None)),
        out_specs=(P(bspec, None, None), P()),
        check_vma=False,
    )
    return fn(p, x)
