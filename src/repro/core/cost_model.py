"""Learned cost model and plan selection (paper Eq. 5 + §3.6).

    C = α·log N + β·(d·h) + γ·p·log(N/p)

α, β, γ are calibrated by least squares against measured query latencies
(the benchmark harness emits (features, latency) pairs). ``select_plan``
greedily picks the cheapest plan satisfying the recall constraint — the
paper's "greedy plan selection with optimality bounds".
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class CostModel:
    alpha: float = 1.0
    beta: float = 0.01
    gamma: float = 0.1

    def cost(self, n: int, d: int, h: int, p: int) -> float:
        """Eq. 5. n=corpus size, d=dim, h=hops, p=partitions probed."""
        p = max(p, 1)
        return (self.alpha * math.log(max(n, 2))
                + self.beta * (d * h)
                + self.gamma * p * math.log(max(n / p, 2)))

    def features(self, n, d, h, p) -> np.ndarray:
        p = max(p, 1)
        return np.array([math.log(max(n, 2)), d * h, p * math.log(max(n / p, 2))])

    def fit(self, samples: Sequence[Tuple[int, int, int, int]],
            latencies: Sequence[float]) -> "CostModel":
        """Least-squares calibration of (α, β, γ) on measured latencies."""
        X = np.stack([self.features(*s) for s in samples])
        y = np.asarray(latencies, np.float64)
        coef, *_ = np.linalg.lstsq(X, y, rcond=None)
        self.alpha, self.beta, self.gamma = (float(c) for c in coef)
        return self

    def r2(self, samples, latencies) -> float:
        X = np.stack([self.features(*s) for s in samples])
        y = np.asarray(latencies, np.float64)
        pred = X @ np.array([self.alpha, self.beta, self.gamma])
        ss_res = float(np.sum((y - pred) ** 2))
        ss_tot = float(np.sum((y - y.mean()) ** 2)) + 1e-12
        return 1.0 - ss_res / ss_tot


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    name: str
    n_probe: int
    n_hops: int
    use_nsw_refine: bool = False
    use_rerank: bool = False
    expected_recall: float = 0.9


DEFAULT_PLANS: Tuple[QueryPlan, ...] = (
    QueryPlan("vector_fast", n_probe=2, n_hops=0, expected_recall=0.80),
    QueryPlan("vector_std", n_probe=8, n_hops=0, expected_recall=0.95),
    QueryPlan("hybrid_1hop", n_probe=4, n_hops=1, expected_recall=0.93),
    QueryPlan("hybrid_2hop", n_probe=8, n_hops=2, expected_recall=0.97),
    QueryPlan("hybrid_deep", n_probe=16, n_hops=3, use_rerank=True,
              expected_recall=0.99),
)


def select_plan(model: CostModel, *, n: int, d: int, min_recall: float,
                plans: Sequence[QueryPlan] = DEFAULT_PLANS) -> QueryPlan:
    """Greedy: cheapest plan whose expected recall clears the floor."""
    feasible = [p for p in plans if p.expected_recall >= min_recall]
    if not feasible:
        feasible = [max(plans, key=lambda p: p.expected_recall)]
    return min(feasible, key=lambda p: model.cost(n, d, p.n_hops, p.n_probe))


# ---------------------------------------------------------------------------
# attribute-filtered search planning (pre-filter pushdown vs oversample)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FilteredScanPlan:
    """How to serve "top-k WHERE pred": push the predicate into the scan's
    validity mask ("prefilter") or run the unfiltered scan with an inflated
    k and post-filter ("oversample")."""
    mode: str                 # "prefilter" | "oversample"
    k_scan: int               # top-k width handed to the underlying scan
    selectivity: float


def estimate_selectivity(node_pass) -> float:
    """Fraction of rows a predicate admits — one mean over the (N,) mask the
    predicate compiler already produced (exact, not a sketch: attributes are
    resident on device and the mask is reused by every scan stage)."""
    return float(np.mean(np.asarray(node_pass)))


def plan_filtered_scan(selectivity: float, k: int, *, n_rows: int,
                       oversample: float = 3.0,
                       prefilter_max_sel: float = 0.5) -> FilteredScanPlan:
    """Selectivity-aware choice (the NHQ observation, inverted per regime):

    - Low selectivity (few rows pass): post-filtering is hopeless — the
      unfiltered top-k' must be ~k/sel wide before k survivors show up, so
      its top-k sort cost (and exactness risk) blows up as 1/sel. Pushdown
      scans the same rows but spends every top-k slot on qualifying rows.
    - Selectivity near 1: almost everything passes; a small constant
      oversample (k' = oversample·k/sel) already contains the filtered top-k
      with high probability, and skips the per-row mask gather the pushdown
      folds into the scan's valid lane.

    The crossover is where the oversampled width stops being "small":
    k/sel·oversample ≳ the pushdown's masked width ⇒ prefilter below
    ``prefilter_max_sel``, oversample above. k_scan for oversampling is the
    *initial* width — exactness-sensitive callers double it until k
    survivors are found (see HMGIIndex.search)."""
    sel = float(min(max(selectivity, 0.0), 1.0))
    if sel <= 0.0:
        return FilteredScanPlan("prefilter", k, 0.0)
    if sel <= prefilter_max_sel:
        return FilteredScanPlan("prefilter", k, sel)
    k_scan = min(n_rows, max(k + 1, int(math.ceil(k * oversample / sel))))
    return FilteredScanPlan("oversample", k_scan, sel)


# ---------------------------------------------------------------------------
# device layout planning (single-device vs row-sharded stable scan)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeviceLayoutPlan:
    """Where a modality's stable scan runs: "single" (one device holds the
    whole slab) or "sharded" (row-sharded over the mesh's db axes, per-shard
    probes + cross-shard top-k merge — see ivf.shard_index)."""
    layout: str               # "single" | "sharded"
    n_shards: int             # 1 for "single"


def plan_device_layout(n_rows: int, dim: int, *, n_shards: int,
                       budget_bytes: int, bytes_per_elem: int = 1,
                       force: Optional[str] = None) -> DeviceLayoutPlan:
    """Shard the stable scan when one device's slab share would exceed the
    per-device budget (n_rows·dim quantized bytes — the HBM-residency the
    probe path actually touches), single-device otherwise. Sharding below
    that is pure overhead: the probe scan is already one device's flops, and
    the cross-shard all-gather+merge adds a collective per query.

    force: "single"/"sharded" overrides the decision (cfg.shard_layout);
    forcing "sharded" on a 1-shard mesh still degenerates to "single"."""
    if force not in (None, "auto", "single", "sharded"):
        raise ValueError(f"unknown layout {force!r}")
    if n_shards <= 1 or force == "single":
        return DeviceLayoutPlan("single", 1)
    if force == "sharded":
        return DeviceLayoutPlan("sharded", n_shards)
    slab_bytes = n_rows * dim * bytes_per_elem
    if budget_bytes > 0 and slab_bytes > budget_bytes:
        return DeviceLayoutPlan("sharded", n_shards)
    return DeviceLayoutPlan("single", 1)


# ---------------------------------------------------------------------------
# query-engine stage planning (repro/query/planner.py consumes these)
# ---------------------------------------------------------------------------

def plan_seed_width(k: int, downstream: bool) -> int:
    """Scan width for a vector-seed stage: the bare top-k when the seeds are
    the answer; oversampled (fusion/re-score headroom, the facade's historic
    2k ∨ k+8 rule) when later stages re-rank or combine them."""
    return max(2 * k, k + 8) if downstream else k


@dataclasses.dataclass(frozen=True)
class FusionPlan:
    """Shape of a traversal-fusion stage: candidate-sparse (fuse over the
    seeds ∪ frontier union, O(Q·C) memory) vs dense (fuse over all N nodes).

    Sparse wins whenever the frontier is a strict subset of the corpus — its
    peak memory is corpus-size independent and its exactness argument holds
    (frontier = k_fuse + C_in). When ``frontier`` reaches ``n_nodes`` the
    candidate union already spans every node, so the sparse bookkeeping
    (dup masks, concat lanes) buys nothing over one dense scatter."""
    repr: str                 # "sparse" | "dense"
    k_fuse: int               # fused candidates kept (stage output width)
    frontier: int             # traversal nodes admitted to the candidate set


def plan_fusion(n_nodes: int, k: int, c_in: int) -> FusionPlan:
    """c_in = incoming candidate-set width (the seed stage's scan width)."""
    k_fuse = max(k, min(4 * k, n_nodes))
    frontier = int(min(n_nodes, k_fuse + c_in))
    return FusionPlan("dense" if frontier >= n_nodes else "sparse",
                      k_fuse, frontier)


# ---------------------------------------------------------------------------
# adaptive index maintenance planning (repro/maintenance consumes this)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MaintenanceAction:
    """One bounded-work maintenance step the executor can apply.

    kind ∈ {"compact_chunk", "split_hot", "merge_cold", "recluster"};
    ``rows`` is the estimated work (slab/delta rows touched — the budget
    currency), ``benefit`` the estimated per-query saving in scanned-row
    units (see ``plan_maintenance`` for the per-action model)."""
    kind: str
    partition: int = -1
    rows: int = 0
    benefit: float = 0.0

    def describe(self) -> str:
        p = "" if self.partition < 0 else f" p={self.partition}"
        return (f"{self.kind}[{self.rows} rows{p} "
                f"benefit={self.benefit:.1f}]")


@dataclasses.dataclass(frozen=True)
class MaintenanceSummary:
    """Per-partition statistics snapshot ``plan_maintenance`` decides from
    (assembled by maintenance/stats.py from its write-time accumulators)."""
    live: np.ndarray          # (K,) live (visible) rows per partition
    free: np.ndarray          # (K,) empty slots per partition
    heat: np.ndarray          # (K,) probe hits since the last plan
    dead: np.ndarray          # (K,) tombstoned/superseded stable rows
    drift: np.ndarray         # (K,) mean assigned-distance growth vs build
                              #      (0 = no drift, 0.5 = +50%)
    parked: np.ndarray        # (K,) bool — merged-away partitions
    delta_live: int           # live rows in the delta store
    delta_used: int           # append watermark (slots consumed)
    delta_capacity: int
    cap: int                  # per-partition slot capacity


def plan_maintenance(summary: MaintenanceSummary, *, budget_rows: int,
                     chunk: int, need_rows: int = 0,
                     delta_pressure: float = 0.5,
                     heat_imbalance: float = 4.0,
                     split_min_fill: float = 0.75,
                     merge_max_fill: float = 0.10,
                     drift_threshold: float = 0.35
                     ) -> List[MaintenanceAction]:
    """Cost-driven maintenance policy: choose the bounded-work actions worth
    their cost, greedily by benefit/row under ``budget_rows``.

    Per-action benefit model (scanned-row units per future query — the same
    currency Eq. 5's γ term prices):

    - **compact_chunk** — every query scans the whole delta, so draining
      ``r`` slots saves ``r`` scanned rows per query. Triggered when the
      delta's append watermark passes ``delta_pressure`` of capacity, or
      unconditionally when the caller must free ``need_rows`` slots for a
      pending insert (never drop a write).
    - **merge_cold** — a partition whose live fill sank below
      ``merge_max_fill`` (deletes/updates hollowed it out) still costs a
      full ``cap``-row scan whenever probed; folding its survivors into the
      nearest sibling retires that scan and frees the slot for a future
      split. Benefit: its probe share × cap + the dead rows removed.
    - **split_hot** — the probe-heat tracker shows one partition absorbing
      ≥ ``heat_imbalance``× the mean probe traffic while ≥ ``split_min_fill``
      full: its crowded slab degrades recall-per-probe and its overflow
      pressures the delta. Splitting halves the hot slab's crowding for its
      (dominant) probe share. Requires a parked partition or a viable merge
      to free one — the planner emits that merge first.
    - **recluster** — a partition whose incoming rows land ``drift_threshold``
      further from the centroid than the build-time baseline routes future
      probes badly; re-centering (no row moves) restores routing for its
      probe share.

    Returns actions in execution order; empty list = no-op. Estimates only —
    the executor re-validates feasibility (e.g. sibling capacity) at apply
    time."""
    K = len(summary.live)
    total_heat = float(summary.heat.sum()) or 1.0
    heat_frac = summary.heat / total_heat
    candidates: List[MaintenanceAction] = []

    # --- delta drain ------------------------------------------------------
    # forced chunks free exactly the slots a pending insert needs (every
    # drain step also reclaims stale/dead watermark slack via the rebuild);
    # draining the whole delta on a forced call would reinstate the very
    # full-compaction stall this subsystem removes. Pressure-driven chunks
    # beyond that compete under the budget like any other action.
    force = max(0, int(need_rows))
    n_forced = -(-force // max(chunk, 1))
    fill = summary.delta_used / max(summary.delta_capacity, 1)
    for _ in range(n_forced):
        candidates.append(MaintenanceAction("compact_chunk", -1, chunk,
                                            benefit=float(chunk)))
    if fill >= delta_pressure:
        if summary.delta_live == 0 and summary.delta_used and not n_forced:
            # pure dead weight (e.g. everything inserted was deleted): one
            # chunk reclaims the whole watermark via the drain's rebuild
            candidates.append(MaintenanceAction(
                "compact_chunk", -1, 1, benefit=float(summary.delta_used)))
        drain = summary.delta_live - n_forced * chunk
        while drain > 0:
            r = min(chunk, drain)
            candidates.append(MaintenanceAction("compact_chunk", -1, r,
                                                benefit=float(r)))
            drain -= r

    # --- merge-cold -------------------------------------------------------
    live_parts = ~summary.parked
    n_live_parts = int(live_parts.sum())
    mergeable = []
    for p in range(K):
        if summary.parked[p] or n_live_parts <= 1:
            continue
        fill_p = summary.live[p] / max(summary.cap, 1)
        if summary.live[p] == 0 or fill_p <= merge_max_fill:
            b = heat_frac[p] * summary.cap + float(summary.dead[p])
            mergeable.append(MaintenanceAction(
                "merge_cold", p, rows=max(int(summary.live[p]), 1),
                benefit=float(b)))
    mergeable.sort(key=lambda a: a.benefit / a.rows, reverse=True)
    candidates.extend(mergeable)

    # --- split-hot --------------------------------------------------------
    if n_live_parts > 1 and total_heat > 1.0:
        mean_heat = total_heat / max(n_live_parts, 1)
        # a parked partition's accumulated (pre-merge) hits must not win
        # the argmax and suppress splits of genuinely hot live partitions
        hot = int(np.argmax(np.where(summary.parked, -1, summary.heat)))
        if (summary.heat[hot] > heat_imbalance * mean_heat
                and summary.live[hot] >= split_min_fill * summary.cap):
            rows = int(summary.live[hot])
            b = heat_frac[hot] * rows / 2.0
            free_slot = bool(summary.parked.any())
            if not free_slot and not any(a.kind == "merge_cold"
                                         for a in candidates):
                # a split needs an empty partition: free the best merge
                # candidate first even if it didn't clear its own threshold
                others = [p for p in range(K)
                          if p != hot and not summary.parked[p]]
                cold = min(others, key=lambda p: summary.live[p])
                candidates.append(MaintenanceAction(
                    "merge_cold", cold,
                    rows=max(int(summary.live[cold]), 1),
                    benefit=float(b) / 2))
            candidates.append(MaintenanceAction("split_hot", hot, rows,
                                                benefit=float(b)))

    # --- recluster --------------------------------------------------------
    for p in range(K):
        if summary.parked[p] or summary.live[p] == 0:
            continue
        if summary.drift[p] >= drift_threshold:
            candidates.append(MaintenanceAction(
                "recluster", p, rows=max(int(summary.live[p]), 1),
                benefit=float(heat_frac[p] * summary.drift[p]
                              * summary.live[p])))

    # --- greedy selection under the row budget ----------------------------
    # the n_forced need_rows chunks (emitted first) are mandatory — a
    # dropped write is not a cost decision; everything else competes on
    # benefit/row, and at least one triggered action always runs (budget
    # floors, never zeroes)
    mandatory = candidates[:n_forced]
    optional = candidates[n_forced:]
    optional.sort(key=lambda a: a.benefit / max(a.rows, 1), reverse=True)
    chosen: List[MaintenanceAction] = list(mandatory)
    spent = sum(a.rows for a in chosen)
    for a in optional:
        if chosen and spent + a.rows > budget_rows:
            continue
        chosen.append(a)
        spent += a.rows
    # execution order: drain first (frees delta slots), then merges (free a
    # partition), then splits (consume one), then reclusters. The executor
    # re-validates feasibility (sibling capacity, parked-slot availability)
    # at apply time, so a budget-dropped enabling merge degrades a split to
    # a no-op rather than a fault.
    rank = {"compact_chunk": 0, "merge_cold": 1, "split_hot": 2,
            "recluster": 3}
    chosen.sort(key=lambda a: rank[a.kind])
    return chosen
