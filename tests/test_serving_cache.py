"""Hot-result cache contract: a hit is bit-identical to the compute it
replaced; every result-affecting index mutation (insert, delete, applied
maintenance, compaction) bumps the version stamp and forces a miss whose
fresh result matches the brute-force ``query_ref`` oracle; a no-op
maintenance pass must NOT bump (the MaintenanceDriver ticks constantly —
flushing the cache on every idle tick would make it useless); eviction is
LRU-ordered; signature collisions (same fp16 key, different fp32 bytes)
miss instead of serving a nearby query's results.
"""
import numpy as np
import pytest

from repro import obs
from repro.configs import get_config
from repro.core import HMGIIndex
from repro.query import Q
from repro.query.planner import compile_plan
from repro.serving.cache import HotResultCache, query_signature
from repro.serving.retrieval import RetrievalPlan, RetrievalService

from query_ref import assert_matches, reference_execute

N = 220
D = 16
K = 6


def _unit(v):
    return v / np.linalg.norm(v, axis=-1, keepdims=True)


@pytest.fixture()
def setup():
    rng = np.random.default_rng(3)
    vt = _unit(rng.normal(size=(N, D)).astype(np.float32))
    cfg = get_config("hmgi").replace(
        n_partitions=6, n_probe=6, top_k=K, kmeans_iters=5,
        delta_capacity=128, delta_rescore_margin=64)
    idx = HMGIIndex(cfg, seed=0)
    idx.ingest({"text": (np.arange(N, dtype=np.int32), vt)}, n_nodes=N)
    queries = _unit(vt[10:26] + 0.05 * rng.normal(size=(16, D))
                    .astype(np.float32)).astype(np.float32)
    cache = HotResultCache(capacity=32)
    svc = RetrievalService(idx, batching=False, cache=cache)
    plan = RetrievalPlan(modality="text", k=K)
    return idx, svc, cache, plan, queries, rng


def _counter(name):
    return obs.counter(name).value


class TestHitPath:
    def test_hit_is_bit_identical(self, setup):
        idx, svc, cache, plan, queries, _ = setup
        first = svc.search(plan, queries[0])
        h0 = _counter("serving.cache.hit")
        second = svc.search(plan, queries[0])
        assert _counter("serving.cache.hit") == h0 + 1
        assert second[0].tobytes() == first[0].tobytes()
        assert second[1].tobytes() == first[1].tobytes()

    def test_signature_collision_misses(self, setup):
        """Two fp32 queries that round to the same fp16 signature must
        NOT share an entry — the exact-byte check turns the collision
        into a miss and leaves the resident owner in place."""
        idx, svc, cache, plan, queries, _ = setup
        q1 = np.ones((1, D), np.float32)
        q2 = q1 + np.float32(1e-4)       # fp16 resolution near 1.0 ~ 1e-3
        assert query_signature(q1) == query_signature(q2)
        assert q1.tobytes() != q2.tobytes()
        r1 = svc.search(plan, q1)
        version = idx.version
        c0 = _counter("serving.cache.collision")
        # a raw lookup with the colliding query misses without disturbing
        # the resident owner
        assert cache.lookup(plan, q2, version) is None
        assert _counter("serving.cache.collision") == c0 + 1
        hit = cache.lookup(plan, q1, version)
        assert hit is not None and hit[1].tobytes() == r1[1].tobytes()
        # through the service, the colliding miss recomputes and its store
        # takes over the shared key (last writer wins); q1 then collides
        # against q2's entry — still never served the wrong bytes
        r2 = svc.search(plan, q2)
        assert r2[1].tobytes() != b"" and r2 is not None
        assert cache.lookup(plan, q2, version) is not None
        assert cache.lookup(plan, q1, version) is None
        # three collisions total: the raw q2 probe, the service's q2
        # lookup before it recomputed, and the final q1 probe
        assert _counter("serving.cache.collision") == c0 + 3


class TestVersionInvalidation:
    def _assert_miss_then_oracle(self, idx, svc, plan, q, v_before):
        assert idx.version > v_before, "mutation did not bump the version"
        i0 = _counter("serving.cache.invalidated")
        fresh = svc.search(plan, q)
        assert _counter("serving.cache.invalidated") == i0 + 1
        phys = compile_plan(idx, Q.vector("text", q.reshape(1, -1)).topk(K))
        assert_matches(fresh, reference_execute(idx, phys))

    def test_insert_invalidates(self, setup):
        idx, svc, cache, plan, queries, rng = setup
        svc.search(plan, queries[0])
        v0 = idx.version
        idx.insert("text", np.arange(N, N + 3, dtype=np.int32),
                   _unit(rng.normal(size=(3, D)).astype(np.float32)))
        self._assert_miss_then_oracle(idx, svc, plan, queries[0], v0)

    def test_delete_invalidates(self, setup):
        idx, svc, cache, plan, queries, _ = setup
        svc.search(plan, queries[1])
        v0 = idx.version
        idx.delete("text", np.array([10, 11], dtype=np.int32))
        self._assert_miss_then_oracle(idx, svc, plan, queries[1], v0)

    def test_applied_maintenance_invalidates(self, setup):
        idx, svc, cache, plan, queries, rng = setup
        idx.insert("text", np.arange(0, 48, dtype=np.int32),
                   _unit(rng.normal(size=(48, D)).astype(np.float32)))
        svc.search(plan, queries[2])
        v0 = idx.version
        # need_rows forces the planner to apply drain work this pass (the
        # insert path's never-drop-a-write hook) — an *applied* trail must
        # bump, unlike the idle pass below
        idx.maintain("text", need_rows=32)
        self._assert_miss_then_oracle(idx, svc, plan, queries[2], v0)

    def test_compaction_invalidates(self, setup):
        idx, svc, cache, plan, queries, rng = setup
        idx.insert("text", np.arange(0, 8, dtype=np.int32),
                   _unit(rng.normal(size=(8, D)).astype(np.float32)))
        svc.search(plan, queries[3])
        v0 = idx.version
        idx.compact("text")
        self._assert_miss_then_oracle(idx, svc, plan, queries[3], v0)

    def test_noop_maintenance_does_not_invalidate(self, setup):
        """Run maintenance until it stops changing the index, then one
        more pass: the version must hold and a cached entry must still
        hit — the idle MaintenanceDriver tick must not flush the cache."""
        idx, svc, cache, plan, queries, _ = setup
        for _ in range(8):
            v = idx.version
            idx.maintain("text")
            if idx.version == v:
                break
        svc.search(plan, queries[4])
        v0 = idx.version
        idx.maintain("text")
        assert idx.version == v0, "no-op maintain bumped the version"
        h0 = _counter("serving.cache.hit")
        svc.search(plan, queries[4])
        assert _counter("serving.cache.hit") == h0 + 1


class TestLRU:
    def test_eviction_is_lru_ordered(self):
        cache = HotResultCache(capacity=3)
        qs = [np.full((1, 4), float(i), np.float32) for i in range(4)]
        out = (np.zeros((1, 2), np.float32), np.zeros((1, 2), np.int64))
        for i in range(3):
            cache.store("p", qs[i], 0, *out)
        # touch q0 so q1 becomes the LRU victim
        assert cache.lookup("p", qs[0], 0) is not None
        cache.store("p", qs[3], 0, *out)
        assert len(cache) == 3
        assert cache.lookup("p", qs[1], 0) is None      # evicted
        assert cache.lookup("p", qs[0], 0) is not None  # survived the touch
        keys = cache.keys()
        assert keys[0] == ("p", query_signature(qs[2]))  # oldest first

    def test_capacity_must_be_positive(self):
        with pytest.raises(ValueError):
            HotResultCache(capacity=0)

    def test_clear(self):
        cache = HotResultCache(capacity=2)
        q = np.ones((1, 4), np.float32)
        cache.store("p", q, 0, np.zeros((1, 2), np.float32),
                    np.zeros((1, 2), np.int64))
        assert len(cache) == 1
        cache.clear()
        assert len(cache) == 0
        assert cache.lookup("p", q, 0) is None
