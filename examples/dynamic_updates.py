"""Streaming ingestion under MVCC: inserts/updates/deletes with live queries,
automatic compaction, and workload-aware repartitioning.

    PYTHONPATH=src python examples/dynamic_updates.py
"""
import numpy as np

from repro.configs import get_config
from repro.core import HMGIIndex
from repro.data.synthetic import make_corpus

corpus = make_corpus(n_nodes=1000, modality_dims={"text": 48}, seed=0)
cfg = get_config("hmgi").replace(n_partitions=16, n_probe=4, top_k=5,
                                 delta_capacity=128, compact_threshold=0.5)
index = HMGIIndex(cfg, seed=0)
index.ingest({"text": (corpus.node_ids["text"], corpus.vectors["text"])},
             n_nodes=corpus.n_nodes, edges=(corpus.src, corpus.dst))

rng = np.random.default_rng(0)
n_compactions = 0
for step in range(8):
    # streaming batch: 40 inserts (some are updates of existing ids)
    ids = rng.integers(0, corpus.n_nodes, 40).astype(np.int32)
    vecs = rng.normal(size=(40, 48)).astype(np.float32)
    before = int(index.modalities["text"].delta.count)
    index.insert("text", ids, vecs)
    after = int(index.modalities["text"].delta.count)
    compacted = after < before
    n_compactions += compacted
    # live query against the newest version of a just-written id
    _, found = index.search(vecs[:1], "text", k=1)
    fresh = int(found[0, 0]) == int(ids[0])
    print(f"step {step}: delta={after:4d} compacted={compacted} "
          f"fresh-read={'OK' if fresh else 'STALE!'}")

# skewed workload triggers online repartitioning
m = index.modalities["text"]
m.workload.hits[:] = 0
m.workload.hits[3] = 50_000
if index.maybe_repartition("text"):
    print("workload skew detected -> hot partition split (no downtime)")
print(f"compactions: {n_compactions}; "
      f"final delta size: {int(index.modalities['text'].delta.count)}")
