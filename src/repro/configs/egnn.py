"""egnn [gnn] — E(n)-equivariant message passing (scalar distances).  [arXiv:2102.09844]"""
from repro.configs.base import GNNConfig
from repro.configs.gnn_shapes import gnn_shapes

CONFIG = GNNConfig(
    arch_id="egnn",
    source="arXiv:2102.09844; paper",
    model="egnn",
    n_layers=4,
    d_hidden=64,
)

SHAPES = gnn_shapes()
