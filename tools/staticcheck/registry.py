"""Declarative registry behind both analysis layers.

Pure-data sections (imported by the AST layer, no jax needed):

- ``HOT_PATH_MODULES`` — modules whose traced functions must stay free of
  host-sync ops (HMG001)
- ``STATIC_INT_PARAMS`` — jitted entry points and the (static) shape-like
  parameters whose call sites HMG002 audits, with positional indexes so
  positional spellings are caught too
- ``SANCTIONED_SHAPE_HELPERS`` — the blessed padding/rounding spellings a
  data-dependent shape must route through
- ``MVCC_ENTRY_POINTS`` — scan entry points that must thread visibility
  kwargs (HMG003), with the kwargs that satisfy the rule and whether the
  callee's default is provably None (enables the --fix kwarg insertion)

Trace-level sections (functions — importing them pulls in jax + the repo):

- ``trace_entries()`` — hot jitted entry points with canonical shapes,
  traced to jaxprs for HMG101/HMG102
- ``budget_entries()`` / ``entry_cache_sizes()`` — the compile-count
  accounting surface for HMG103 and the benchmarks' ``n_compiles`` column
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

# --------------------------------------------------------------------- HMG001
# repo-relative path fragments; a file is hot iff one of these is a suffix
# of its posix path
HOT_PATH_MODULES = (
    "src/repro/core/ivf.py",
    "src/repro/core/delta.py",
    "src/repro/core/fusion.py",
    "src/repro/core/traversal.py",
    "src/repro/query/executor.py",
)
HOT_PATH_DIRS = ("src/repro/kernels/", "src/repro/obs/")

# --------------------------------------------------------------------- HMG002
# callee name -> {param name: positional index or None (kw-only)}.
# Positional indexes count every positional slot, 0-based.
STATIC_INT_PARAMS: Dict[str, Dict[str, Optional[int]]] = {
    "search": {"n_probe": None, "k": None, "query_block": None,
               "ef": None, "max_steps": None},
    "search_sharded": {"n_probe": None, "k": None, "query_block": None},
    "search_with_delta": {"n_probe": None, "k": None,
                          "rescore_margin": None},
    "search_with_delta_sharded": {"n_probe": None, "k": None,
                                  "rescore_margin": None},
    "search_raw": {"n_probe": 4, "k": 5},
    "_scan_delta": {"k": None, "margin": None},
    "scan_topk_quantized": {"k": None, "chunk": None, "block_n": None},
    "scan_topk_quantized_batched": {"k": None, "chunk": None,
                                    "block_n": None},
    "brute_force": {"k": None},
    "multi_hop_batch": {"n_hops": None, "top_m": None},
    "frontier_expand": {"n_hops": None, "top_m": None},
    "fuse_topk_sparse": {"k": 3},
    "fuse_topk": {"k": 3},
    "_fuse_candidates": {"k_fuse": None, "frontier": None},
}

# a data-dependent int expression is sanctioned when it routes through one
# of these helpers (repro/common/shapes.py) or the inline bit_length idiom
SANCTIONED_SHAPE_HELPERS = ("pow2_round", "pad_to_chunk", "bit_length")

# calls that *produce* data-dependent Python ints (the hazard markers)
HAZARD_CALLS = ("int", "len")

# --------------------------------------------------------------------- HMG003
# callee name -> (receivers or None for any, satisfying kwargs).
# The call must spell at least one of the kwargs explicitly (None counts:
# an explicit node_pass=None documents a conscious opt-out).
MVCC_ENTRY_POINTS: Dict[str, Tuple[Optional[Tuple[str, ...]],
                                   Tuple[str, ...]]] = {
    "search": (("ivf", "ivf_mod"), ("node_pass",)),
    "search_sharded": (None, ("node_pass",)),
    "search_with_delta": (None, ("node_pass", "mvcc_filter")),
    "search_with_delta_sharded": (None, ("node_pass", "mvcc_filter")),
    "_scan_delta": (None, ("node_pass",)),
}
# kwargs whose callee default is None in this repo — --fix may insert
# `<kwarg>=None` (provably behaviour-preserving)
MVCC_DEFAULT_NONE_KWARG = "node_pass"

# --------------------------------------------------------------------- HMG004
PERSISTENCE_DIRS = ("src/repro/persistence/", "src/repro/checkpoint/")
FSYNC_CALLS = ("fsync", "fsync_file", "fsync_dir", "_sync", "sync")
RENAME_CALLS = ("rename", "replace")      # as os.<name> attributes


# ------------------------------------------------------------- HMG201-HMG204
# Guarded-by registry: the shared mutable attributes of the repo's
# concurrent classes and the lock that guards each set. HMG201 enforces the
# discipline lexically (every read/write outside __init__ must sit inside a
# ``with <recv>.<lock>`` block or a ``*_locked`` method); the dynamic
# lockset checker (tools/racecheck.py) enforces it at runtime, importing
# the classes via ``module``. docs/DESIGN.md §9 renders this table.
@dataclasses.dataclass(frozen=True)
class GuardSpec:
    """One concurrent class and its guarded-by contract.

    ``attrs`` accessed via ``self.<attr>`` inside methods of ``cls`` — or
    via any receiver named in ``receivers`` anywhere in ``files`` — must be
    lexically inside ``with <recv>.<lock>`` (double-checked fast-path reads
    carry a reasoned pragma). ``module`` lets racecheck import the class
    for dynamic instrumentation."""
    cls: str
    module: str
    lock: str
    attrs: Tuple[str, ...]
    files: Tuple[str, ...]
    receivers: Tuple[str, ...] = ()       # non-self receivers to audit


GUARDED_BY: Tuple[GuardSpec, ...] = (
    GuardSpec("Histogram", "repro.obs.metrics", "_lock",
              ("bucket_counts", "count", "total", "vmax", "_window",
               "_wpos"),
              ("src/repro/obs/metrics.py",)),
    GuardSpec("MetricsRegistry", "repro.obs.metrics", "_lock",
              ("_counters", "_gauges", "_histograms"),
              ("src/repro/obs/metrics.py",)),
    GuardSpec("CheckpointManager", "repro.checkpoint.checkpoint", "_lock",
              ("_pending", "_error"),
              ("src/repro/checkpoint/checkpoint.py",)),
    GuardSpec("WorkloadStats", "repro.core.partitioner", "_lock",
              ("hits",),
              ("src/repro/core/partitioner.py", "src/repro/core/index.py",
               "src/repro/query/executor.py")),
    GuardSpec("Prefetcher", "repro.data.pipeline", "_lock",
              ("step", "q", "_stop", "_thread"),
              ("src/repro/data/pipeline.py",)),
    # ModalityIndex's lazily-built caches are owned by HMGIIndex's
    # _cache_lock (the facade builds/invalidates them; readers go through
    # the double-checked helpers) — accesses appear as ``m.<attr>``.
    GuardSpec("ModalityIndex", "repro.core.index", "_cache_lock",
              ("ivf_sharded", "id_rows"),
              ("src/repro/core/index.py", "src/repro/query/executor.py"),
              receivers=("m",)),
    # serving layer (PR 10): the hot-result cache's LRU dict, the admission
    # controller's token buckets, and the micro-batcher's combining-funnel
    # state are each guarded by their own leaf lock
    GuardSpec("HotResultCache", "repro.serving.cache", "_lock",
              ("_entries", "_stores"),
              ("src/repro/serving/cache.py",)),
    GuardSpec("AdmissionController", "repro.serving.scheduler", "_lock",
              ("_buckets",),
              ("src/repro/serving/scheduler.py",)),
    GuardSpec("MicroBatcher", "repro.serving.retrieval", "_lock",
              ("_pending", "_leader"),
              ("src/repro/serving/retrieval.py",)),
)

# Methods whose callers are required (and checked) to hold a lock: the
# ``*_locked`` suffix is the repo convention for "the caller already holds
# it". This maps each such method to the lock its body is considered to
# hold (HMG201 treats the body as guarded; HMG203 uses it for edges; call
# sites outside a ``with``-lock are HMG201 violations).
GUARDED_METHODS: Dict[str, str] = {
    "CheckpointManager._drain_pending_locked": "CheckpointManager._lock",
    "HMGIIndex._insert_locked": "HMGIIndex._write_lock",
    "HMGIIndex._maintain_locked": "HMGIIndex._write_lock",
    "HMGIIndex._ingest_locked": "HMGIIndex._write_lock",
    "HMGIIndex._compact_locked": "HMGIIndex._write_lock",
    "HMGIIndex._state_tree_locked": "HMGIIndex._write_lock",
    "HMGIIndex._restore_state_locked": "HMGIIndex._write_lock",
    "MicroBatcher._take_batch_locked": "MicroBatcher._lock",
}

# HMG202: calls that block (filesystem sync, host sync on device work,
# timed waits, thread/future joins) — none may run while one of the
# audited fine-grained locks is held, or every other thread touching that
# structure stalls behind the I/O. The coarse writer lock
# (HMGIIndex._write_lock) is deliberately NOT audited: it serialises
# mutations, and device work under it is the single-writer design.
BLOCKING_CALLS = ("fsync", "fsync_file", "fsync_dir", "sleep",
                  "block_until_ready", "join", "result", "wait",
                  "device_get")
HMG202_LOCK_ATTRS = ("_lock", "_cache_lock")

# HMG203: calls that acquire a known lock internally — lexical ``with``
# nesting alone would miss ``obs.counter(...).inc()`` under another lock.
# callee name -> lock node it acquires.
LOCK_ACQUIRING_CALLS: Dict[str, str] = {
    "counter": "MetricsRegistry._lock",
    "gauge": "MetricsRegistry._lock",
    "histogram": "MetricsRegistry._lock",
    "observe": "Histogram._lock",
    "observe_ms": "Histogram._lock",
    "inc": "Counter._lock",
    "record": "WorkloadStats._lock",
    "hits_snapshot": "WorkloadStats._lock",
    "load_hits": "WorkloadStats._lock",
    "_ensure_sharded": "HMGIIndex._cache_lock",
    "_modality_id_rows": "HMGIIndex._cache_lock",
    "try_admit": "AdmissionController._lock",
}

# HMG204: markers that a class runs background threads ("publication"
# starts at the first of these) and the constructors that create them.
THREAD_SPAWN_CALLS = ("Thread", "ThreadPoolExecutor", "Timer")
THREAD_START_CALLS = ("start", "submit")


# ===========================================================================
# trace-level registry (jax-importing; everything below is lazy)
# ===========================================================================

@dataclasses.dataclass
class TraceEntry:
    """One hot jitted entry point traced at canonical shapes.

    ``build`` returns (fn, args, kwargs) ready for ``jax.make_jaxpr``.
    ``max_upcast_elems`` — HMG101 threshold: an int8->f32
    ``convert_element_type`` of more elements than this (outside
    ``pallas_call``) is a slab-scale dequant, not the bounded rescore.
    None disables HMG101 for the entry (fp32-native paths)."""
    name: str
    build: Callable[[], Tuple[Callable, tuple, dict]]
    max_upcast_elems: Optional[int] = None


# canonical shapes — shared with tests/query_ref.py-style suites: small
# enough to trace in seconds, large enough that slab-scale and rescore-scale
# converts are an order of magnitude apart
_Q, _D, _K_PARTS, _N, _TOPK = 4, 32, 8, 512, 8


def _canonical_index():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import ivf as ivf_mod
    rng = np.random.default_rng(0)
    v = rng.normal(size=(_N, _D)).astype(np.float32)
    idx, _ = ivf_mod.build(jax.random.PRNGKey(0), jnp.asarray(v),
                           jnp.arange(_N), n_partitions=_K_PARTS, bits=8)
    q = jnp.asarray(rng.normal(size=(_Q, _D)).astype(np.float32))
    return idx, q


def _canonical_delta():
    import jax.numpy as jnp
    import numpy as np
    from repro.core import delta as delta_mod
    rng = np.random.default_rng(1)
    d = delta_mod.init(128, _D, _N)
    d = delta_mod.insert(d, jnp.asarray(
        rng.normal(size=(96, _D)).astype(np.float32)),
        jnp.arange(96, dtype=jnp.int32))
    q = jnp.asarray(rng.normal(size=(_Q, _D)).astype(np.float32))
    return d, q


def trace_entries() -> List[TraceEntry]:
    import functools

    def ivf_search_kernel():
        from repro.core import ivf as ivf_mod
        idx, q = _canonical_index()
        fn = functools.partial(ivf_mod.search, n_probe=4, k=_TOPK,
                               impl="kernel")
        return fn, (idx, q), {}

    def delta_scan():
        from repro.core import delta as delta_mod
        d, q = _canonical_delta()
        fn = functools.partial(delta_mod._scan_delta, k=_TOPK)
        return fn, (d, q), {}

    def delta_search():
        from repro.core import delta as delta_mod
        idx, q = _canonical_index()
        d, _ = _canonical_delta()
        fn = functools.partial(delta_mod.search_with_delta, n_probe=4,
                               k=_TOPK)
        return fn, (idx, d, q), {}

    def kernel_batched():
        import jax.numpy as jnp
        import numpy as np
        from repro.kernels.ivf_topk.ops import scan_topk_quantized_batched
        rng = np.random.default_rng(2)
        m = 1024
        fn = functools.partial(scan_topk_quantized_batched, k=_TOPK,
                               chunk=16, block_n=512)
        args = (jnp.asarray(rng.normal(size=(_Q, _D)).astype(np.float32)),
                jnp.asarray(rng.integers(-128, 127, size=(_Q, m, _D)
                                         ).astype(np.int8)),
                jnp.zeros((_Q, m), jnp.float32),
                jnp.ones((_Q, m), jnp.float32),
                jnp.ones((_Q, m), bool))
        return fn, args, {}

    def traverse():
        import jax.numpy as jnp
        import numpy as np
        from repro.core import traversal as trav_mod
        from repro.core.graph_store import from_edges
        rng = np.random.default_rng(3)
        e = 2048
        g = from_edges(_N, jnp.asarray(rng.integers(0, _N, e), jnp.int32),
                       jnp.asarray(rng.integers(0, _N, e), jnp.int32))
        ids = jnp.asarray(rng.integers(0, _N, size=(_Q, _TOPK)), jnp.int32)
        sc = jnp.asarray(rng.random(size=(_Q, _TOPK)).astype(np.float32))
        fn = functools.partial(trav_mod.multi_hop_batch, n_hops=2)
        return fn, (g, ids, sc), {}

    # HMG101 threshold: 2x the provable rescore gather (Q · k·chunk · d).
    # The smallest slab-scale dequant at canonical shapes is ≥ Q·M·d with
    # M = n_probe·cap ≈ 4·129, comfortably above it.
    rescore_budget = 2 * _Q * _TOPK * 16 * _D
    return [
        TraceEntry("ivf.search[int8-kernel]", ivf_search_kernel,
                   max_upcast_elems=rescore_budget),
        TraceEntry("delta._scan_delta", delta_scan,
                   max_upcast_elems=rescore_budget),
        TraceEntry("delta.search_with_delta[int8-kernel]", delta_search,
                   max_upcast_elems=rescore_budget),
        TraceEntry("ivf_topk.scan_topk_quantized_batched", kernel_batched,
                   max_upcast_elems=rescore_budget),
        TraceEntry("traversal.multi_hop_batch", traverse,
                   max_upcast_elems=None),
    ]


# --------------------------------------------------------------------- HMG103
# (entry name, module path, attribute) — every attribute is a jitted
# function exposing _cache_size(); distinct compiled signatures per entry
# are what budgets.json bounds.
BUDGET_ENTRIES: Sequence[Tuple[str, str, str]] = (
    ("ivf.search", "repro.core.ivf", "search"),
    ("ivf.brute_force", "repro.core.ivf", "brute_force"),
    ("delta.insert", "repro.core.delta", "insert"),
    ("delta.supersede", "repro.core.delta", "supersede"),
    ("delta.delete", "repro.core.delta", "delete"),
    ("delta._scan_delta", "repro.core.delta", "_scan_delta"),
    ("kernels.scan_topk_quantized",
     "repro.kernels.ivf_topk.ops", "scan_topk_quantized"),
    ("kernels.scan_topk_quantized_batched",
     "repro.kernels.ivf_topk.ops", "scan_topk_quantized_batched"),
    ("index._fuse_candidates", "repro.core.index", "_fuse_candidates"),
    ("executor._fuse_dense", "repro.query.executor", "_fuse_dense"),
    ("executor._rescore", "repro.query.executor", "_rescore"),
    ("partitioner.assign_with_distance",
     "repro.core.partitioner", "assign_with_distance"),
    ("nsw.search", "repro.core.nsw", "search"),
)


def budget_functions() -> Dict[str, object]:
    """entry name -> live jitted function object."""
    import importlib
    out = {}
    for name, mod, attr in BUDGET_ENTRIES:
        out[name] = getattr(importlib.import_module(mod), attr)
    return out


def entry_cache_sizes() -> Dict[str, int]:
    """Distinct compiled signatures currently cached per budget entry."""
    sizes = {}
    for name, fn in budget_functions().items():
        try:
            sizes[name] = int(fn._cache_size())
        except AttributeError:          # not a pjit function on this jax
            sizes[name] = -1
    return sizes


def total_cache_size() -> int:
    """Sum of compiled signatures across all budget entries (the
    benchmarks' ``n_compiles`` accounting surface)."""
    return sum(max(v, 0) for v in entry_cache_sizes().values())
