"""Public jit'd wrapper for the one-hot-matmul segment sum."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.segment_reduce.segment_reduce import segment_sum_pallas


@functools.lru_cache(maxsize=None)
def _interpret_mode() -> bool:
    """Probed once, lazily (first kernel call): Mosaic needs a TPU; every
    other backend interprets. Deferred past import so app-level JAX setup
    (jax.distributed.initialize, platform selection) runs first."""
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("n_segments", "block_n", "block_e",
                                             "interpret"))
def segment_sum_mm(messages, seg_ids, n_segments: int, *, block_n: int = 512,
                   block_e: int = 1024, interpret: bool | None = None):
    """messages (E, d) -> (n_segments, d); ids < 0 or >= n_segments drop."""
    interp = _interpret_mode() if interpret is None else interpret
    e, d = messages.shape
    block_n = min(block_n, max(128, n_segments))
    block_e = min(block_e, max(128, e))
    pad_e = (-e) % block_e
    pad_n = (-n_segments) % block_n
    seg = jnp.where(jnp.logical_and(seg_ids >= 0, seg_ids < n_segments),
                    seg_ids, n_segments + pad_n)  # out of padded range -> drops
    if pad_e:
        messages = jnp.pad(messages, ((0, pad_e), (0, 0)))
        seg = jnp.pad(seg, (0, pad_e), constant_values=n_segments + pad_n)
    out = segment_sum_pallas(messages, seg.astype(jnp.int32),
                             n_segments + pad_n, block_n=block_n,
                             block_e=block_e, interpret=interp)
    return out[:n_segments]
