"""Pure-jnp oracle for the ivf_topk kernel."""
from __future__ import annotations

import jax.numpy as jnp


def scan_topk_ref(queries, data_i8, vmin, scale, *, chunk: int = 128):
    """Dequantize fully, exact scores, per-chunk (max, argmax)."""
    q = queries.astype(jnp.float32)
    e = (data_i8.astype(jnp.float32) + 128.0) * scale[:, None] + vmin[:, None]
    scores = q @ e.T                                         # (Q, N)
    qn, n = scores.shape
    nchunks = n // chunk
    sc = scores.reshape(qn, nchunks, chunk)
    smax = jnp.max(sc, axis=-1)
    sarg = jnp.argmax(sc, axis=-1).astype(jnp.int32) + \
        (jnp.arange(nchunks, dtype=jnp.int32) * chunk)[None, :]
    return smax, sarg


def scan_topk_ref_batched(queries, data_i8, vmin, scale, *, chunk: int = 16):
    """Per-query-slab oracle: dequantize fully, exact scores, per-chunk
    (max, argmax). queries (Q, d); data_i8 (Q, M, d); vmin/scale (Q, M)."""
    q = queries.astype(jnp.float32)
    e = ((data_i8.astype(jnp.float32) + 128.0) * scale[..., None]
         + vmin[..., None])                                  # (Q, M, d)
    scores = jnp.einsum("qd,qmd->qm", q, e)                  # (Q, M)
    qn, m = scores.shape
    nchunks = m // chunk
    sc = scores.reshape(qn, nchunks, chunk)
    smax = jnp.max(sc, axis=-1)
    sarg = jnp.argmax(sc, axis=-1).astype(jnp.int32) + \
        (jnp.arange(nchunks, dtype=jnp.int32) * chunk)[None, :]
    return smax, sarg


def pad_topk(vals, ids, k: int):
    """Pads (Q, kk ≤ k) descending top-k lists to width k with (-inf, -1) —
    the one sentinel convention every scan/merge path shares."""
    kk = vals.shape[-1]
    if kk < k:
        vals = jnp.pad(vals, ((0, 0), (0, k - kk)), constant_values=-jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, k - kk)), constant_values=-1)
    return vals, ids


def topk_from_chunks(chunk_max, chunk_arg, k: int):
    """Exact top-k over the chunk survivors (second stage, tiny).

    Clamps k to the available chunk count and pads (-inf, -1)."""
    import jax
    kk = min(k, chunk_max.shape[-1])
    vals, pos = jax.lax.top_k(chunk_max, kk)
    ids = jnp.take_along_axis(chunk_arg, pos, axis=-1)
    return pad_topk(vals, ids, k)
