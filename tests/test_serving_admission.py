"""Per-tenant admission control: token-bucket semantics under an injected
clock (refill, burst cap, zero-quota and single-slot edge cases), fairness
under an over-subscribed open loop (every tenant makes its quota-rate
progress — no starvation — and the shed load lands in the per-tenant obs
counters), and the ContinuousBatcher integration: bounded-queue rejection
at the door and the per-tenant queue-wait histogram.
"""
import numpy as np
import pytest

from repro import obs
from repro.serving.scheduler import (AdmissionController, ContinuousBatcher,
                                     Request, TenantQuota)


def _count(name):
    return obs.counter(name).value


class TestTokenBucket:
    def test_burst_then_refill(self):
        adm = AdmissionController({"t": TenantQuota(rate=1.0, burst=2.0)})
        assert adm.try_admit("t", now=0.0)
        assert adm.try_admit("t", now=0.0)          # burst of 2 spent
        assert not adm.try_admit("t", now=0.0)
        assert not adm.try_admit("t", now=0.5)      # only 0.5 refilled
        assert adm.try_admit("t", now=1.6)          # 0.5 + 1.1 >= 1
        assert not adm.try_admit("t", now=1.7)

    def test_refill_caps_at_burst(self):
        adm = AdmissionController({"t": TenantQuota(rate=100.0, burst=2.0)})
        assert adm.try_admit("t", now=0.0)
        # a long idle gap refills to burst, not rate x elapsed
        for now in (100.0, 100.0):
            assert adm.try_admit("t", now=now)
        assert not adm.try_admit("t", now=100.0)

    def test_zero_quota_always_rejected(self):
        obs.reset()
        adm = AdmissionController({"z": TenantQuota(rate=0.0, burst=0.0)})
        for now in (0.0, 10.0, 1e6):
            assert not adm.try_admit("z", now=now)
        assert _count("serving.tenant.z.rejected") == 3
        assert _count("serving.admission.rejected") == 3

    def test_single_slot_admits_exactly_once(self):
        adm = AdmissionController({"s": TenantQuota(rate=0.0, burst=1.0)})
        got = [adm.try_admit("s", now=float(i)) for i in range(5)]
        assert got == [True, False, False, False, False]

    def test_unknown_tenant_without_default_is_admitted(self):
        obs.reset()
        adm = AdmissionController({"t": TenantQuota(rate=0.0, burst=1.0)})
        for _ in range(4):
            assert adm.try_admit("anon", now=0.0)
        assert _count("serving.tenant.anon.admitted") == 4

    def test_unknown_tenant_with_default_gets_own_bucket(self):
        adm = AdmissionController(
            {}, default_quota=TenantQuota(rate=0.0, burst=1.0))
        assert adm.try_admit("a", now=0.0)
        assert not adm.try_admit("a", now=1.0)
        # b's bucket is independent of a's spend
        assert adm.try_admit("b", now=1.0)


class TestFairness:
    def test_oversubscribed_open_loop_no_starvation(self):
        """Two equal-quota tenants each offering 2x their rate, plus a
        zero-quota tenant: each quota'd tenant makes quota-rate progress
        (neither is starved by the other's pressure), the zero-quota
        tenant never gets through, and the shed load is visible in the
        per-tenant obs counters."""
        obs.reset()
        adm = AdmissionController({"a": TenantQuota(rate=10.0, burst=1.0),
                                   "b": TenantQuota(rate=10.0, burst=1.0),
                                   "z": TenantQuota(rate=0.0, burst=0.0)})
        admitted = {"a": 0, "b": 0, "z": 0}
        # open loop: every 0.05 s each tenant offers one request (20 QPS
        # offered against a 10 QPS quota) for 2 simulated seconds
        for step in range(40):
            now = step * 0.05
            for t in ("a", "b", "z"):
                if adm.try_admit(t, now=now):
                    admitted[t] += 1
        assert admitted["z"] == 0
        # ~ rate x duration = 20 each (fp refill rounding can shave a
        # few); equal quotas must make near-equal progress
        for t in ("a", "b"):
            assert 15 <= admitted[t] <= 22, admitted
        assert abs(admitted["a"] - admitted["b"]) <= 1
        for t in ("a", "b"):
            assert _count(f"serving.tenant.{t}.rejected") >= 18
        assert _count("serving.admission.admitted") == (
            admitted["a"] + admitted["b"])


class TestBatcherIntegration:
    def _req(self, rid, tenant="default"):
        return Request(rid, np.array([1, 2, 3], np.int32),
                       max_new_tokens=2, tenant=tenant)

    def test_bounded_queue_rejects_at_the_door(self):
        obs.reset()
        b = ContinuousBatcher(1, max_queue=2)
        assert b.submit(self._req(0, "acme"))
        assert b.submit(self._req(1, "acme"))
        r = self._req(2, "acme")
        assert not b.submit(r)
        assert r.done and r.generated == []
        assert _count("serving.rejected_queue_full") == 1
        assert _count("serving.tenant.acme.rejected") == 1
        assert 2 not in b.requests       # shed, not queued

    def test_admission_reject_at_submit(self):
        obs.reset()
        adm = AdmissionController({"z": TenantQuota(rate=0.0, burst=0.0)})
        b = ContinuousBatcher(2, admission=adm)
        r = self._req(0, "z")
        assert not b.submit(r)
        assert r.done
        assert _count("serving.rejected") == 1
        assert _count("serving.tenant.z.rejected") == 1
        ok = self._req(1, "vip")         # no quota registered: admitted
        assert b.submit(ok)
        assert 1 in b.requests

    def test_queue_wait_histogram_per_tenant(self):
        obs.reset()
        b = ContinuousBatcher(2)
        b.submit(self._req(0, "acme"))
        b.submit(self._req(1, "umbrella"))
        b.admit()
        for t in ("acme", "umbrella"):
            h = obs.registry().histogram(f"serving.tenant.{t}.queue_wait")
            assert h.count == 1
        assert obs.registry().histogram("serving.queue_wait").count == 2
