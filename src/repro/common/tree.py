"""Pytree utilities shared across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def count_params(tree) -> int:
    """Total number of scalar parameters in a pytree of arrays."""
    leaves = jax.tree.leaves(tree)
    return int(sum(np.prod(l.shape) for l in leaves if hasattr(l, "shape")))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of (concrete or abstract) arrays."""
    total = 0
    for l in jax.tree.leaves(tree):
        if hasattr(l, "shape") and hasattr(l, "dtype"):
            total += int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
    return total


def tree_finite(tree) -> jax.Array:
    """Scalar bool: every float leaf is finite (used by smoke tests / fault guard)."""
    leaves = [l for l in jax.tree.leaves(tree) if jnp.issubdtype(jnp.asarray(l).dtype, jnp.floating)]
    if not leaves:
        return jnp.asarray(True)
    oks = [jnp.all(jnp.isfinite(l)) for l in leaves]
    out = oks[0]
    for o in oks[1:]:
        out = jnp.logical_and(out, o)
    return out


def global_norm(tree) -> jax.Array:
    """L2 norm over all leaves (gradient clipping)."""
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree))
    return jnp.sqrt(sq)
