"""HMGIIndex — the unified facade (paper Fig. 1): modality-aware partitioned
vector indexes + knowledge-graph store + MVCC delta + hybrid fusion engine +
learned optimisation, behind one ingest/search/update API.

Host-side orchestration (builds, compaction scheduling, plan selection) wraps
jitted device kernels (assignment, IVF scan, traversal, fusion). Ids are
global graph-node ids across all modalities, so vector hits seed traversals
directly.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import HMGIConfig
from repro.core import delta as delta_mod
from repro.core import ivf as ivf_mod
from repro.core import nsw as nsw_mod
from repro.core import community as comm_mod
from repro.core import rerank as rerank_mod
from repro.core.cost_model import (CostModel, DeviceLayoutPlan,
                                   plan_device_layout, select_plan)
from repro.core.fusion import FusionWeights, fuse_topk_sparse
from repro.core import graph_store as graph_mod
from repro.core.graph_store import (GraphStore, NodeAttributes,
                                    from_edges as graph_from_edges)
from repro.core.cost_model import plan_maintenance
from repro.core.partitioner import WorkloadStats, assign_with_distance
from repro.core.quantization import AdaptiveQuantPolicy
from repro.maintenance import MaintenanceReport, PartitionStats

# NOTE: repro.query (the declarative engine this facade compiles onto) is
# imported lazily inside methods — repro.query.planner/executor import core
# submodules at module scope, so a top-level import here would cycle.
# repro.maintenance.executor is imported lazily for the same hygiene.


@functools.partial(jax.jit, static_argnames=("k_fuse", "frontier"))
def _fuse_candidates(vs, vi, graph_scores, wv, wg, *, k_fuse: int,
                     frontier: int, node_pass=None):
    """Candidate-sparse fusion stage (Eq. 3): fuse over the union of the
    ANNS seeds ``vi`` and the ``frontier`` strongest traversal nodes instead
    of scattering into a dense (Q, n_nodes) similarity array.

    Exactness: a node outside the union that dense fusion would rank in its
    top-k_fuse has no vector term, so its fused score is monotone in its
    graph mass — but ≥ k_fuse non-seed nodes inside the frontier carry at
    least as much mass (frontier = k_fuse + k_seed ≥ k_fuse + #seeds), so it
    can never displace the union's top-k_fuse. The graph normaliser is the
    frontier's top-1 = the global max. Peak memory is O(Q·C), C = k_seed +
    frontier — independent of n_nodes.

    node_pass: optional (N,) bool predicate mask — excluded nodes are struck
    from both the seed and frontier candidate lanes (the traversal already
    routes no mass through them, but a zero-mass node could otherwise still
    fill a trailing top-k_fuse slot)."""
    # barrier: XLA:CPU otherwise re-materialises the frontier sort inside
    # every consumer fusion of its outputs (~40x fusion-stage slowdown)
    g_vals, g_ids = jax.lax.optimization_barrier(
        jax.lax.top_k(graph_scores, frontier))                    # (Q, F)
    n_nodes = graph_scores.shape[1]
    # drop repeated seed ids (NSW-refine merges can re-surface an IVF hit):
    # keep the first = highest-scored occurrence (the dense scatter's
    # duplicate-write order was unspecified; highest-score is the one
    # deterministic choice that never understates a seed)
    ks = vi.shape[1]
    earlier = jnp.tril(jnp.ones((ks, ks), bool), k=-1)
    seed_dup = jnp.any((vi[:, :, None] == vi[:, None, :]) & earlier[None],
                       axis=-1)                                   # (Q, ks)
    seed_valid = jnp.logical_and(vi >= 0, ~seed_dup)
    front_valid = jnp.ones(g_ids.shape, bool)
    if node_pass is not None:
        seed_valid = jnp.logical_and(seed_valid,
                                     graph_mod.mask_pass(node_pass, vi))
        front_valid = graph_mod.mask_pass(node_pass, g_ids)
    g_at_vi = jnp.take_along_axis(
        graph_scores, jnp.clip(vi, 0, n_nodes - 1).astype(jnp.int32), axis=1)
    # frontier entries already present as seeds fuse through the seed copy
    dup = jnp.any(g_ids[:, :, None] == jnp.where(seed_valid, vi, -2)[:, None, :],
                  axis=-1)                                        # (Q, F)
    cand_ids = jnp.concatenate([jnp.where(seed_valid, vi, -1), g_ids], axis=1)
    cand_sim = jnp.concatenate(
        [jnp.where(seed_valid, vs, -jnp.inf),
         jnp.full_like(g_vals, -jnp.inf)], axis=1)
    cand_graph = jnp.concatenate(
        [jnp.where(seed_valid, g_at_vi, 0.0),
         jnp.where(dup, 0.0, g_vals)], axis=1)
    cand_valid = jnp.concatenate(
        [seed_valid, jnp.logical_and(~dup, front_valid)], axis=1)
    w = FusionWeights(wv, wg)
    fvals, fpos = fuse_topk_sparse(cand_sim, cand_graph, w, k_fuse,
                                   graph_max=g_vals[:, :1], valid=cand_valid)
    fids = jnp.take_along_axis(cand_ids, fpos, axis=1)
    return fvals, fids


@dataclasses.dataclass
class ModalityIndex:
    ivf: ivf_mod.IVFIndex
    delta: delta_mod.DeltaStore
    vectors: jax.Array          # fp32 master copy (compaction + NSW refine)
    ids: jax.Array              # (N,) global node ids
    nsw: Optional[nsw_mod.NSWGraph] = None
    workload: Optional[WorkloadStats] = None
    # write-time per-partition maintenance statistics (heat lives in
    # ``workload``; this adds delta pressure, tombstone ratio, drift) —
    # consumed by cost_model.plan_maintenance via HMGIIndex.maintain
    stats: Optional[PartitionStats] = None
    # True once any delete/update touched this modality: gates the MVCC
    # visibility pushdown in the scan (never reset — conservative; False
    # guarantees no dead row can be visible, so scans skip the mask)
    has_dead: bool = False
    # (n_nodes,) global-id -> row cache for cross-modal re-scoring; rebuilt
    # lazily by the executor, invalidated when ``ids`` gains new entries
    id_rows: Optional[jax.Array] = None
    # row-sharded replica of ``ivf`` (ivf.shard_index layout, leaves placed
    # over the mesh's db axes); built lazily when the device-layout plan
    # says "sharded", dropped whenever the stable store is rebuilt
    ivf_sharded: Optional[ivf_mod.IVFIndex] = None


class HMGIIndex:
    """The Hybrid Multimodal Graph Index.

    Thread-safety contract (docs/DESIGN.md §9): searches are safe from any
    number of threads, concurrently with at most one mutating caller.
    ``_write_lock`` (reentrant) serialises every mutation — insert, delete,
    compact, maintain, repartition, ingest, restore — plus the state_tree
    snapshot, so writers and snapshotters see a consistent index.
    ``_cache_lock`` guards the two lazily-built read-path caches
    (``ModalityIndex.ivf_sharded`` and ``.id_rows``) with double-checked
    locking: searchers never touch ``_write_lock``, and the hot path is
    lock-free once a cache is published. Lock order is
    ``_write_lock -> _cache_lock -> leaf locks`` (obs, WorkloadStats) —
    enforced statically as HMG201-204 and dynamically by tools/racecheck.
    """

    def __init__(self, cfg: HMGIConfig, mesh=None, seed: int = 0):
        self.cfg = cfg
        self.mesh = mesh
        self.key = jax.random.PRNGKey(seed)
        self._write_lock = threading.RLock()   # serialises mutations
        self._cache_lock = threading.Lock()    # guards lazy read caches
        self.modalities: Dict[str, ModalityIndex] = {}
        self.graph: Optional[GraphStore] = None
        self.attributes: Optional[NodeAttributes] = None
        self.communities: Optional[np.ndarray] = None
        self.boosted_weights: Optional[jax.Array] = None
        self.sparse_docs: Optional[rerank_mod.SparseVectors] = None
        self.cost_model = CostModel(cfg.cost_alpha, cfg.cost_beta, cfg.cost_gamma)
        self.quant_policy = AdaptiveQuantPolicy(cfg.memory_budget_bytes)
        self.n_nodes = 0
        self._metrics: Dict[str, float] = {}
        # monotone mutation stamp: bumped by every change that can alter a
        # search result (insert/delete/compact/applied maintenance/
        # repartition/ingest/restore/attribute or sparse-doc swap). Serving
        # caches key results on it — a stale entry can never be served
        # because its stamp no longer matches. A *no-op* maintenance pass
        # does not bump (the MaintenanceDriver ticks constantly; ticking
        # must not flush hot caches).
        self._version = 0

    @property
    def version(self) -> int:
        """The mutation stamp (see ``__init__``). Read lock-free: a small
        int is published atomically under the GIL, and a reader that sees
        the pre-mutation value merely caches a result that the very next
        stamp check discards — the same conservative direction as missing."""
        return self._version

    def _bump_version(self) -> None:
        self._version += 1

    # ------------------------------------------------------------------ build
    def _split(self):
        self.key, k = jax.random.split(self.key)
        return k

    def ingest(self, embeddings: Dict[str, Tuple[np.ndarray, np.ndarray]],
               n_nodes: int, edges: Optional[Tuple] = None,
               build_nsw: bool = False,
               node_attrs: Optional[Dict[str, np.ndarray]] = None):
        """Builds the index over a multimodal corpus.

        embeddings: modality -> (node_ids (N_m,) int, vectors (N_m, d_m));
        vectors are L2-normalised here (all similarity is dot-product over
        unit vectors). edges: (src, dst[, edge_type[, edge_weight]]) arrays
        over global node ids. node_attrs: column name -> (n_nodes,) int
        values (the WHERE-clause side). Build overflow (rows beyond a
        partition's capacity) is routed to the delta store — grown if
        needed, never dropped — and per-partition maintenance statistics
        are baselined from the build's own assignment."""
        with self._write_lock:
            self._ingest_locked(embeddings, n_nodes, edges, build_nsw,
                                node_attrs)

    def _ingest_locked(self, embeddings, n_nodes, edges, build_nsw,
                       node_attrs):
        self.n_nodes = n_nodes
        for mod, (ids, vecs) in embeddings.items():
            vecs = jnp.asarray(vecs, jnp.float32)
            vecs = vecs / jnp.maximum(
                jnp.linalg.norm(vecs, axis=-1, keepdims=True), 1e-12)
            ids = jnp.asarray(ids, jnp.int32)
            bits = self.quant_policy.choose_bits(
                int(vecs.size * 4), default_bits=self.cfg.quant_bits)
            k = min(self.cfg.n_partitions, vecs.shape[0])
            index, overflow = ivf_mod.build(
                self._split(), vecs, ids, n_partitions=k, bits=bits,
                kmeans_iters=self.cfg.kmeans_iters)
            dstore = delta_mod.init(self.cfg.delta_capacity, vecs.shape[1],
                                    max_ids=max(n_nodes, 1))
            # overflow rows go to the delta store (capacity-bounded build) —
            # grown if needed: build overflow must never be dropped
            n_over = int(jnp.sum(overflow))
            if n_over:
                ov = jnp.where(overflow)[0]
                dstore = delta_mod.insert_grow(dstore, vecs[ov], ids[ov])
            m = ModalityIndex(ivf=index, delta=dstore, vectors=vecs, ids=ids,
                              workload=WorkloadStats(k),
                              stats=PartitionStats.from_build(
                                  vecs, ids, index, max_ids=max(n_nodes, 1)))
            if build_nsw or self.cfg.use_nsw_refine:
                m.nsw = nsw_mod.build(self._split(), vecs,
                                      degree=min(self.cfg.nsw_degree, vecs.shape[0] - 1))
            self.modalities[mod] = m
        if edges is not None:
            src, dst = edges[0], edges[1]
            et = edges[2] if len(edges) > 2 else None
            ew = edges[3] if len(edges) > 3 else None
            self.graph = graph_from_edges(n_nodes, src, dst, et, ew)
            self.communities = comm_mod.louvain_one_level(
                n_nodes, np.asarray(src), np.asarray(dst),
                np.ones(len(src)) if ew is None else np.asarray(ew))
            self.boosted_weights = comm_mod.community_edge_boost(
                self.graph, self.communities)
        if node_attrs is not None:
            self.set_attributes(node_attrs)
        self._bump_version()

    def set_attributes(self, node_attrs: Dict[str, np.ndarray]):
        """Attach/replace the relational attribute columns (global node id
        keyed; see graph_store.NodeAttributes). Swapping columns changes
        every filtered result, so it bumps the version stamp."""
        with self._write_lock:
            self.attributes = NodeAttributes.from_columns(self.n_nodes,
                                                          node_attrs)
            self._bump_version()

    def set_sparse_docs(self, docs: rerank_mod.SparseVectors):
        with self._write_lock:
            self.sparse_docs = docs
            self._bump_version()

    # ----------------------------------------------------------------- search
    def _norm_queries(self, queries) -> jax.Array:
        q = jnp.asarray(queries, jnp.float32)
        return q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)

    def _node_pass(self, where) -> Optional[jax.Array]:
        """Compiles a where clause against the attribute store -> (N,) bool."""
        if where is None:
            return None
        if self.attributes is None:
            raise ValueError("filtered search needs attributes: call "
                             "set_attributes() or ingest(node_attrs=...)")
        return self.attributes.node_pass(where)

    def device_layout(self, modality: str) -> DeviceLayoutPlan:
        """Where this modality's stable scan runs: row-sharded over the
        mesh's db axes when the quantized slab exceeds
        cfg.shard_device_budget_bytes (cfg.shard_layout forces either way),
        single-device otherwise. No mesh ⇒ always single."""
        from repro.sharding.rules import db_shards
        m = self.modalities[modality]
        force = None if self.cfg.shard_layout == "auto" else self.cfg.shard_layout
        return plan_device_layout(
            int(np.prod(m.ivf.data.shape[:2])), int(m.ivf.data.shape[-1]),
            n_shards=db_shards(self.mesh),
            budget_bytes=self.cfg.shard_device_budget_bytes,
            bytes_per_elem=int(m.ivf.data.dtype.itemsize), force=force)

    def _ensure_sharded(self, modality: str, n_shards: int) -> ivf_mod.IVFIndex:
        """The row-sharded stable replica (built lazily, leaves placed over
        the mesh's db axes; invalidated whenever the stable store changes).

        Double-checked: concurrent searchers must neither observe a
        half-built replica nor build it twice — the build happens once
        under ``_cache_lock`` and is published as a single reference
        assignment; the replica itself is immutable once published."""
        m = self.modalities[modality]
        # staticcheck: disable=HMG201 (double-checked fast path: a published replica is immutable and assigned atomically; a stale None just falls through to the locked build)
        sh = m.ivf_sharded
        if sh is not None and sh.ids.shape[0] == n_shards:
            return sh
        with self._cache_lock:
            sh = m.ivf_sharded
            if sh is None or sh.ids.shape[0] != n_shards:
                sh = ivf_mod.shard_index(m.ivf, n_shards)
                if self.mesh is not None:
                    sh = jax.tree_util.tree_map(
                        ivf_mod.shard_placement(self.mesh), sh)
                m.ivf_sharded = sh
            return sh

    def _modality_id_rows(self, modality: str) -> jax.Array:
        """The (n_nodes,) global-id -> row scatter map for cross-modal
        re-scoring, built lazily once per (modality, corpus-size) and
        shared by every search thread. Same double-checked publication
        protocol as ``_ensure_sharded``; invalidated (under
        ``_cache_lock``) when an insert adds new ids."""
        m = self.modalities[modality]
        # staticcheck: disable=HMG201 (double-checked fast path: a published rows array is immutable and assigned atomically; a stale None just falls through to the locked build)
        rows = m.id_rows
        if rows is not None and rows.shape[0] == self.n_nodes:
            return rows
        with self._cache_lock:
            rows = m.id_rows
            if rows is None or rows.shape[0] != self.n_nodes:
                from repro.query.executor import _modality_rows
                rows = _modality_rows(m.ids, self.n_nodes)
                m.id_rows = rows
            return rows

    def query(self, plan, *, trace: bool = False):
        """Runs a declarative plan (see ``repro.query.Q``): compiles it
        cost-wise against this index (predicate pushdown vs post-filter,
        probe widths, sparse vs dense fusion) and executes it as staged
        jitted primitives. Returns (scores (Q, k), ids (Q, k)); with
        ``trace=True``, (scores, ids, trace) where ``trace.render()`` is
        the per-stage span tree."""
        from repro.query.executor import execute
        from repro.query.planner import compile_plan
        obs.set_sync_spans(self.cfg.obs_sync_spans)
        with self._maybe_trace(trace) as t:
            out = execute(self, compile_plan(self, plan))
        return out + (t,) if trace else out

    @staticmethod
    def _maybe_trace(trace: bool):
        """``obs.trace()`` collector when tracing, else a null context —
        untraced queries skip span-tree assembly entirely (spans still
        feed the registry histograms)."""
        return obs.trace() if trace else contextlib.nullcontext()

    def explain(self, plan) -> str:
        """The compiled physical plan for ``plan``, as a one-line string
        (stage order, widths, filter mode, fusion representation)."""
        from repro.query.planner import compile_plan
        return compile_plan(self, plan).describe()

    def search(self, queries, modality: str, k: Optional[int] = None,
               n_probe: Optional[int] = None, where=None, impl: str = "auto",
               *, trace: bool = False, _node_pass=None):
        """Pure vector search (ANNS on stable index + delta), tombstone-aware.

        A thin wrapper over the query engine: builds the one-stage plan
        ``Q.vector(modality, queries).where(where).topk(k)`` and executes it.

        where: optional relational predicate — a (column, op, value) tuple or
        a list of them (AND), evaluated against the attribute store. The
        selectivity estimator picks the execution strategy per batch:
        *pushdown* (predicate folded into the scan validity masks, pre-top-k)
        when few rows qualify, *oversample-then-post-filter* when most do —
        the post-filter pass doubles its scan width until every query has k
        qualifying candidates (or the probed slabs are exhausted), so at full
        probe both strategies return the brute-force-with-predicate top-k.

        trace: when True, returns (scores, ids, trace) — ``trace.render()``
        prints the per-stage span tree (plan, seed-scan, traversal, ...)."""
        from repro.query.ast import Q
        from repro.query.executor import execute
        from repro.query.planner import compile_plan
        obs.set_sync_spans(self.cfg.obs_sync_spans)
        plan = Q.vector(modality, queries, n_probe=n_probe,
                        impl=impl).where(where)
        with self._maybe_trace(trace) as t:
            phys = compile_plan(self, plan, k=k or self.cfg.top_k,
                                node_pass=_node_pass)
            out = execute(self, phys)
        return out + (t,) if trace else out

    def hybrid_search(self, queries, modality: str, k: Optional[int] = None,
                      n_hops: Optional[int] = None,
                      n_probe: Optional[int] = None,
                      edge_type_mask=None,
                      where=None,
                      min_recall: Optional[float] = None,
                      use_rerank: bool = False,
                      q_terms=None, q_term_weights=None, *,
                      trace: bool = False):
        """The paper's hybrid query (Eq. 3): ANNS seeds -> h-hop traversal ->
        adaptive fusion -> (optional sparse-dense rerank). Returns (scores, ids).

        A thin wrapper over the query engine — it builds and executes
        ``Q.vector(...).where(where).traverse(n_hops, edge_types=...)``
        (fusion representation pinned to the candidate-sparse path), then
        applies the optional rerank lane to the untruncated candidate set.

        where: optional relational predicate (see ``search``). It is enforced
        at every stage: seed search (pushdown or planned oversampling),
        traversal (excluded nodes route no mass — ``frontier_expand``'s node
        mask), and fusion (excluded frontier nodes can't take candidate
        slots) — "nearest neighbors of q WHERE node.attr = v within h hops"
        as one query."""
        from repro.query.ast import Q
        from repro.query.executor import execute
        from repro.query.planner import compile_plan
        assert self.graph is not None, "hybrid_search needs a graph"
        obs.set_sync_spans(self.cfg.obs_sync_spans)
        cfg = self.cfg
        k = k or cfg.top_k
        if min_recall is not None:
            plan = select_plan(self.cost_model,
                               n=int(self.modalities[modality].ids.shape[0]),
                               d=int(self.modalities[modality].vectors.shape[1]),
                               min_recall=min_recall)
            n_probe = plan.n_probe
            n_hops = plan.n_hops
            use_rerank = use_rerank or plan.use_rerank
        n_hops = cfg.max_hops if n_hops is None else n_hops
        q = self._norm_queries(queries)

        with self._maybe_trace(trace) as t:
            plan = (Q.vector(modality, q, n_probe=n_probe)
                    .where(where)
                    .traverse(n_hops, edge_types=edge_type_mask))
            phys = compile_plan(self, plan, k=k, fusion_repr="sparse")
            fvals, fids = execute(self, phys, truncate=False)

            if (n_hops > 0 and use_rerank and self.sparse_docs is not None
                    and q_terms is not None):
                # optional sparse-dense rerank over the full fused set
                with obs.span("query.rescore") as span:
                    ss = rerank_mod.sparse_overlap_scores(
                        self.sparse_docs, q_terms, q_term_weights, fids)
                    fvals, fids = span.fence(
                        rerank_mod.rrf_rerank(fvals, ss, fids, k=k))
                out = (fvals, fids)
            else:
                out = (fvals[:, :k], fids[:, :k])
        return out + (t,) if trace else out

    # ----------------------------------------------------------------- update
    def _record_dead(self, m: ModalityIndex, ids_np: np.ndarray):
        """Maintenance stats: ids whose stable row just became invisible
        (tombstoned or superseded). Counts only freshly dead ids — an id
        already hidden must not inflate the partition's dead counter."""
        if m.stats is None or not ids_np.size:
            return
        tomb = np.asarray(m.delta.tombstones)
        sup = np.asarray(m.delta.superseded)
        c = np.clip(ids_np, 0, tomb.shape[0] - 1)
        m.stats.record_dead(ids_np[~(tomb[c] | sup[c])], m.ivf)

    def insert(self, modality: str, ids, vectors):
        """Insert-or-update a batch.

        ids: (B,) global node ids; vectors: (B, d_m) — L2-normalised here.
        Existing ids are superseded (MVCC update path): the stable row is
        hidden, the fp32 master row is rewritten in place, and the new
        version lands in the delta. When the delta lacks room (or crosses
        the compaction threshold), ``cfg.maint_auto`` routes the work
        through ``maintain`` — bounded incremental drains instead of a
        stop-the-world ``compact`` — growing the delta only if maintenance
        could not free enough slots. Writes are never dropped."""
        with obs.span("index.insert"), self._write_lock:
            self._insert_locked(modality, ids, vectors)

    def _insert_locked(self, modality: str, ids, vectors):
        m = self.modalities[modality]
        v = self._norm_queries(vectors)
        # free delta room BEFORE any visibility change: a forced drain here
        # still sees consistent MVCC state. Draining after supersede() would
        # move the id's *old* delta version into stable and clear its
        # superseded bit — then appending the new version would leave two
        # visible copies (the stale one served from stable).
        if delta_mod.free_slots(m.delta) < v.shape[0]:
            if self.cfg.maint_auto:
                self.maintain(modality,
                              need_rows=v.shape[0] - delta_mod.free_slots(m.delta))
            else:
                self.compact(modality)
        ids32 = jnp.asarray(ids, jnp.int32)
        ids_np = np.asarray(ids32)
        existing_np = np.asarray(m.ids)
        # vectorized id -> row lookup (no host loop over the corpus)
        order = np.argsort(existing_np, kind="stable")
        sorted_ids = existing_np[order]
        pos = np.searchsorted(sorted_ids, ids_np)
        pos_c = np.minimum(pos, max(existing_np.size - 1, 0))
        upd_mask = (sorted_ids[pos_c] == ids_np) if existing_np.size \
            else np.zeros(ids_np.shape, bool)
        if upd_mask.any():
            m.has_dead = True
            self._record_dead(m, ids_np[upd_mask])
            m.delta = delta_mod.supersede(m.delta, ids32[jnp.asarray(upd_mask)])
            rows = order[pos_c[upd_mask]]
            m.vectors = m.vectors.at[jnp.asarray(rows)].set(v[jnp.asarray(upd_mask)])
        if (~upd_mask).any():
            sel = jnp.asarray(~upd_mask)
            m.vectors = jnp.concatenate([m.vectors, v[sel]], axis=0)
            m.ids = jnp.concatenate([m.ids, ids32[sel]])
            with self._cache_lock:
                m.id_rows = None    # new ids -> the row cache is stale
        # never drop writes: insert_grow widens the store if the (already
        # drained, above) delta still lacks room for the batch
        m.delta = delta_mod.insert_grow(m.delta, v, ids32)
        if m.stats is not None:
            a, d2 = assign_with_distance(v, m.ivf.centroids)
            m.stats.record_writes(np.asarray(a), np.asarray(d2))
        if delta_mod.should_compact(m.delta, self.cfg.compact_threshold):
            if self.cfg.maint_auto:
                self.maintain(modality)
            else:
                self.compact(modality)
        self._bump_version()

    def delete(self, modality: str, ids):
        """Tombstones the ids in ``modality`` (O(B) mask writes; the rows
        vanish from every scan path immediately and are physically purged by
        maintenance/compaction). Auto-triggers a maintenance pass so
        hollowed-out partitions eventually merge away."""
        with obs.span("index.delete"), self._write_lock:
            m = self.modalities[modality]
            ids_np = np.asarray(jnp.asarray(ids, jnp.int32))
            self._record_dead(m, ids_np)
            m.has_dead = True
            m.delta = delta_mod.delete(m.delta, jnp.asarray(ids, jnp.int32))
            self._bump_version()
            if self.cfg.maint_auto:
                self.maintain(modality)

    def compact(self, modality: str):
        """Full compaction: merge the whole delta into the stable store in
        one synchronous rebuild (async-vacuum analogue; see core/delta.py).
        The adaptive path (``maintain`` / ``cfg.maint_auto``) drains the
        delta in bounded chunks instead — this remains the one-shot fallback
        and the reference the incremental drain must match."""
        with self._write_lock:
            self._compact_locked(modality)

    def _compact_locked(self, modality: str):
        m = self.modalities[modality]
        m.ivf, m.delta = delta_mod.compact(self._split(), m.ivf, m.delta,
                                           m.vectors, m.ids)
        with self._cache_lock:
            m.ivf_sharded = None  # stable rebuilt -> sharded replica stale
        if m.stats is not None:
            # the rebuild dropped every dead stable row and re-packed slots
            m.stats.dead[:] = 0
            m.stats.invalidate_slab()
        if m.nsw is not None:
            # compaction clears the superseded mask, which is what hid
            # updated rows from the NSW lane — refresh it over the latest
            # vectors or it would serve pre-update similarities again
            m.nsw = nsw_mod.build(
                self._split(), m.vectors,
                degree=min(self.cfg.nsw_degree, m.vectors.shape[0] - 1))
        self._bump_version()

    def maybe_repartition(self, modality: str):
        """Workload-aware online adjustment (paper §3.2), as bounded work.

        When the probe-heat tracker reports imbalance, the hottest
        partition is split in place by the maintenance executor: a local
        K=2 fit over that partition's stored rows, moved byte-identically
        between the hot slab and a freed partition (merging the coldest
        away first when none is parked). Only the hot partition's rows move
        — no full rebuild, and survivors that don't fit anywhere are routed
        to the delta, never dropped. Returns True if a split was applied."""
        from repro.maintenance import executor as maint_exec
        with self._write_lock:
            m = self.modalities[modality]
            if m.workload is None or not m.workload.should_repartition():
                return False
            # a parked partition's pre-merge hits must not win the argmax
            # (its heat is never reset on merge) and suppress the real hot
            # split
            hits = m.workload.hits_snapshot()
            if m.stats is not None:
                hits = np.where(m.stats.parked, -1, hits)
            hot = int(np.argmax(hits))
            res = maint_exec.split_hot(m, self.cfg, self._split(), m.stats,
                                       hot)
            with self._cache_lock:
                m.ivf_sharded = None  # slots moved -> sharded replica stale
            m.workload.reset()
            self._bump_version()
            return bool(res.get("moved", 0))

    def maintain(self, modality: Optional[str] = None,
                 budget: Optional[int] = None, *, need_rows: int = 0):
        """One adaptive-maintenance pass (docs/DESIGN.md §3.4): plan
        cost-worthy actions from the write-time partition statistics and
        apply them as bounded-work steps.

        budget: row budget for this pass (default ``cfg.maint_budget_rows``)
        — the planner picks the best benefit/row actions that fit.
        need_rows: caller must free at least this many delta slots (the
        insert path's never-drop-a-write hook); forces drain chunks ahead
        of the budget.

        Returns the ``MaintenanceReport`` for ``modality`` (or a dict of
        reports over all modalities when ``modality`` is None). The applied
        decision trail is also surfaced in ``metrics()['maintenance']``.

        Obs: the pass's wall time lands in the ``index.maintain`` histogram
        (write-path stall, since maintenance runs inline with mutations);
        each applied action bumps ``maintenance.actions.<kind>`` and its
        moved/drained/reclaimed rows accumulate in
        ``maintenance.rows_moved``."""
        with obs.span("index.maintain"), self._write_lock:
            return self._maintain_locked(modality, budget,
                                         need_rows=need_rows)

    def _maintain_locked(self, modality: Optional[str] = None,
                         budget: Optional[int] = None, *,
                         need_rows: int = 0):
        from repro.maintenance import executor as maint_exec
        cfg = self.cfg
        budget = cfg.maint_budget_rows if budget is None else int(budget)
        if budget <= 0 and need_rows <= 0:
            # an explicit zero budget is "no optional work", not "default"
            return ({m: MaintenanceReport(m) for m in self.modalities}
                    if modality is None else MaintenanceReport(modality))
        reports: Dict[str, MaintenanceReport] = {}
        for mod in ([modality] if modality else list(self.modalities)):
            m = self.modalities[mod]
            if m.stats is None:
                m.stats = PartitionStats.from_build(
                    m.vectors, m.ids, m.ivf,
                    max_ids=int(m.delta.tombstones.shape[0]))
            heat = None if m.workload is None else m.workload.hits_snapshot()
            actions = plan_maintenance(
                m.stats.summarize(m, heat),
                budget_rows=budget,
                chunk=cfg.maint_chunk, need_rows=need_rows,
                delta_pressure=cfg.maint_delta_pressure,
                heat_imbalance=cfg.maint_heat_imbalance,
                split_min_fill=cfg.maint_split_min_fill,
                merge_max_fill=cfg.maint_merge_max_fill,
                drift_threshold=cfg.maint_drift_threshold)
            report = MaintenanceReport(mod)
            cleared = 0
            skip_chunks = False
            for act in actions:
                if act.kind == "compact_chunk" and skip_chunks:
                    continue
                res = maint_exec.apply(m, cfg, self._split(), m.stats, act)
                report.actions.append((act, res))
                obs.counter(f"maintenance.actions.{act.kind}").inc()
                obs.counter("maintenance.rows_moved").inc(
                    res.get("drained", 0) + res.get("moved", 0)
                    + res.get("reclaimed", 0))
                cleared += res.get("cleared_superseded", 0)
                if act.kind == "compact_chunk" and not (
                        res.get("drained", 0) or res.get("reclaimed", 0)):
                    # every target partition is full (or the delta emptied):
                    # further chunks this pass would spin without progress
                    skip_chunks = True
                if res.get("ivf_changed", False):
                    with self._cache_lock:
                        m.ivf_sharded = None  # slots/centroids moved
                    if act.kind == "split_hot" and m.workload is not None:
                        m.workload.reset()
            if cleared and m.nsw is not None:
                # drained updates cleared superseded bits — exactly like a
                # full compaction, the NSW layer must refresh over the
                # latest master rows or it would serve pre-update scores
                m.nsw = nsw_mod.build(
                    self._split(), m.vectors,
                    degree=min(cfg.nsw_degree, m.vectors.shape[0] - 1))
            reports[mod] = report
        trail = "; ".join(r.describe() for r in reports.values()
                          if not r.is_noop)
        if trail:
            # the latest *applied* decision trail (a no-op pass leaves the
            # last real decision visible — that is the interesting one)
            self._metrics["maintenance"] = trail
            # only an *applied* pass can change results: a no-op plan must
            # not invalidate serving caches (the driver ticks constantly)
            self._bump_version()
        return reports[modality] if modality else reports

    # ------------------------------------------------------- durability state
    # The complete durable state, as a flat {key: array} dict + JSON-able
    # structural metadata. This is THE definition of "what must survive a
    # crash" — anything that influences a search result or a future
    # mutation's outcome is here (quantized slabs byte-identical, centroids
    # incl. parked sentinels, delta + staleness bits, graph CSR, attributes,
    # MVCC tombstone/superseded bits, partition stats, workload heat, PRNG
    # key). Derived caches (id_rows, ivf_sharded, _part_of) are excluded:
    # they rebuild lazily and deterministically from this state. Consumed by
    # repro.persistence.snapshot; keep the two restore paths in sync when
    # adding fields.

    def state_tree(self) -> Tuple[Dict[str, object], Dict[str, object]]:
        """Returns ``(tree, meta)``: every durable array keyed by a flat
        path, plus the structural metadata needed to rebuild the facade.
        Host-side numpy leaves (stats, heat) keep their exact dtypes —
        they must round-trip bit-identically, not through jnp's 32-bit
        coercion."""
        with self._write_lock:
            return self._state_tree_locked()

    def _state_tree_locked(self):
        tree: Dict[str, object] = {"key": self.key}
        meta: Dict[str, object] = {
            "n_nodes": int(self.n_nodes),
            "modalities": {},
            "graph": self.graph is not None,
            "communities": self.communities is not None,
            "boosted_weights": self.boosted_weights is not None,
            "attr_columns": None,
            "sparse_docs": self.sparse_docs is not None,
        }
        for mod, m in self.modalities.items():
            p = f"m/{mod}"
            for f in ("centroids", "data", "vmin", "scale", "ids", "counts"):
                tree[f"{p}/ivf/{f}"] = getattr(m.ivf, f)
            for f in delta_mod.DeltaStore._fields:
                tree[f"{p}/delta/{f}"] = getattr(m.delta, f)
            tree[f"{p}/vectors"] = m.vectors
            tree[f"{p}/ids"] = m.ids
            if m.nsw is not None:
                for f in ("vectors", "neighbors", "entry"):
                    tree[f"{p}/nsw/{f}"] = getattr(m.nsw, f)
            if m.workload is not None:
                tree[f"{p}/workload_hits"] = m.workload.hits_snapshot()
            if m.stats is not None:
                st = m.stats
                for f in ("baseline", "drift_sum", "drift_cnt", "dead",
                          "parked"):
                    tree[f"{p}/stats/{f}"] = np.asarray(getattr(st, f))
            meta["modalities"][mod] = {
                "bits": int(m.ivf.bits),
                "has_dead": bool(m.has_dead),
                "nsw": m.nsw is not None,
                "workload": m.workload is not None,
                "stats": m.stats is not None,
                "stats_max_ids": (int(m.stats.max_ids)
                                  if m.stats is not None else 0),
            }
        if self.graph is not None:
            for f in GraphStore._fields:
                tree[f"graph/{f}"] = getattr(self.graph, f)
        if self.communities is not None:
            tree["communities"] = np.asarray(self.communities)
        if self.boosted_weights is not None:
            tree["boosted_weights"] = self.boosted_weights
        if self.attributes is not None:
            tree["attributes/values"] = self.attributes.values
            cols = sorted(self.attributes.columns, key=self.attributes.columns.get)
            meta["attr_columns"] = cols
        if self.sparse_docs is not None:
            tree["sparse/term_ids"] = self.sparse_docs.term_ids
            tree["sparse/term_weights"] = self.sparse_docs.term_weights
        return tree, meta

    def restore_state(self, tree: Dict[str, object],
                      meta: Dict[str, object]) -> None:
        """Rebuilds this (freshly constructed) index from ``state_tree``
        output. Device arrays re-enter via jnp; host-side stat arrays stay
        numpy with their stored dtypes. The result is bit-identical to the
        snapshotted index for every search path."""
        with self._write_lock:
            self._restore_state_locked(tree, meta)

    def _restore_state_locked(self, tree, meta) -> None:
        self.n_nodes = int(meta["n_nodes"])
        self.key = jnp.asarray(np.asarray(tree["key"]))
        self.modalities = {}
        for mod, mm in meta["modalities"].items():
            p = f"m/{mod}"
            ivf = ivf_mod.IVFIndex(
                **{f: jnp.asarray(np.asarray(tree[f"{p}/ivf/{f}"]))
                   for f in ("centroids", "data", "vmin", "scale", "ids",
                             "counts")},
                bits=int(mm["bits"]))
            dstore = delta_mod.DeltaStore(
                **{f: jnp.asarray(np.asarray(tree[f"{p}/delta/{f}"]))
                   for f in delta_mod.DeltaStore._fields})
            m = ModalityIndex(
                ivf=ivf, delta=dstore,
                vectors=jnp.asarray(np.asarray(tree[f"{p}/vectors"])),
                ids=jnp.asarray(np.asarray(tree[f"{p}/ids"])),
                has_dead=bool(mm["has_dead"]))
            if mm["nsw"]:
                m.nsw = nsw_mod.NSWGraph(
                    vectors=jnp.asarray(np.asarray(tree[f"{p}/nsw/vectors"])),
                    neighbors=jnp.asarray(np.asarray(tree[f"{p}/nsw/neighbors"])),
                    entry=jnp.asarray(np.asarray(tree[f"{p}/nsw/entry"])))
            k = ivf.n_partitions
            if mm["workload"]:
                m.workload = WorkloadStats(k)
                m.workload.load_hits(np.asarray(tree[f"{p}/workload_hits"]))
            if mm["stats"]:
                st = PartitionStats(k, int(mm["stats_max_ids"]))
                for f in ("baseline", "drift_sum", "drift_cnt", "dead",
                          "parked"):
                    setattr(st, f, np.asarray(tree[f"{p}/stats/{f}"]).copy())
                m.stats = st
            self.modalities[mod] = m
        self.graph = (GraphStore(
            **{f: jnp.asarray(np.asarray(tree[f"graph/{f}"]))
               for f in GraphStore._fields})
            if meta["graph"] else None)
        self.communities = (np.asarray(tree["communities"]).copy()
                            if meta["communities"] else None)
        self.boosted_weights = (
            jnp.asarray(np.asarray(tree["boosted_weights"]))
            if meta["boosted_weights"] else None)
        if meta["attr_columns"] is not None:
            self.attributes = NodeAttributes(
                {n: i for i, n in enumerate(meta["attr_columns"])},
                jnp.asarray(np.asarray(tree["attributes/values"])))
        else:
            self.attributes = None
        if meta["sparse_docs"]:
            self.sparse_docs = rerank_mod.SparseVectors(
                term_ids=jnp.asarray(np.asarray(tree["sparse/term_ids"])),
                term_weights=jnp.asarray(np.asarray(tree["sparse/term_weights"])))
        else:
            self.sparse_docs = None
        self._bump_version()

    # ------------------------------------------------------------------ stats
    def metrics(self) -> Dict[str, object]:
        """Execution-side observability: filter selectivity/mode recorded by
        the last filtered seed scan, the latest maintenance decision trail
        under ``"maintenance"`` (one line per modality acted on), and the
        process-global obs registry snapshot under ``"obs"`` (counters,
        gauges, histogram summaries with exact p50/p90/p99 — see
        ``repro.obs``)."""
        out = dict(self._metrics)
        out["obs"] = obs.snapshot()
        return out

    def memory_usage(self) -> Dict[str, int]:
        """Bytes per component: one entry per modality's stable slab, one
        per delta store (fp32 master + int8 mirror + dequant terms), the
        graph, and a "total" sum."""
        out = {}
        for mod, m in self.modalities.items():
            out[mod] = m.ivf.nbytes
            out[f"{mod}_delta"] = int(m.delta.vectors.size * 4
                                      + m.delta.qdata.size
                                      + (m.delta.qvmin.size
                                         + m.delta.qscale.size) * 4)
        if self.graph is not None:
            out["graph"] = self.graph.nbytes
        out["total"] = sum(out.values())
        return out
