"""Sharded, atomic, async-capable checkpointing (fault-tolerance substrate).

Layout: ``<dir>/step_<N>/`` holds one ``.npy`` per pytree leaf (flattened
key paths) + a ``manifest.json`` (treedef, shapes, dtypes, per-leaf crc32,
step, config fingerprint). Writes go to ``step_<N>.tmp`` and are atomically
renamed — and every leaf file, the manifest, and the directories are
fsync'd *before* the rename, so a crashed writer never corrupts the latest
checkpoint under power loss, not just SIGKILL. On multi-host deployments
each host writes its own shard files (``shard_<k>``); here (single host)
arrays are gathered before write, which is also the path the dry-run
exercises.

Restore validates structure, per-leaf key/shape/dtype, and the recorded
crc32 of each leaf's bytes; any mismatch raises ``CheckpointError`` naming
the offending leaf instead of silently ``view()``-reinterpreting bytes.
``restore_checkpoint(dir, like=None)`` restores a flat ``{key: np.ndarray}``
dict straight from the manifest (host dtypes preserved exactly — the
persistence layer's snapshot path, where the shapes aren't known up front).

``CheckpointManager`` adds: retention (keep last k), async background
writes (thread pool) whose failures surface on the next ``save``/``wait``/
``restore_latest`` instead of vanishing in the pool, and
restore-latest-on-restart (the trainer's restart-from-step contract) which
skips and garbage-collects leftover ``step_<N>.tmp`` dirs from crashed
writers.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.persistence.faultpoints import crash_point

# numpy can't serialise ML dtypes natively: store as a same-width integer
# view and restore via the manifest's recorded dtype
_EXOTIC_VIEWS = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


class CheckpointError(RuntimeError):
    """A checkpoint failed validation. ``leaf`` names the offending leaf
    (or "" for manifest/structure-level failures), ``reason`` says why."""

    def __init__(self, path: str, leaf: str, reason: str):
        super().__init__(f"checkpoint {path}: "
                         + (f"leaf {leaf!r}: " if leaf else "") + reason)
        self.path = path
        self.leaf = leaf
        self.reason = reason


def _to_savable(arr: np.ndarray):
    name = str(arr.dtype)
    if name in _EXOTIC_VIEWS:
        return arr.view(_EXOTIC_VIEWS[name]), name
    return arr, name


def _from_saved(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC_VIEWS:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _leaf_crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Atomic checkpoint write. Returns the final path.

    Durability order: leaf files -> manifest -> fsync(every file) ->
    fsync(tmp dir) -> rename -> fsync(parent dir). A crash anywhere before
    the rename leaves only a ``.tmp`` dir (skipped + GC'd by restore); a
    crash after it leaves a complete, checksummed checkpoint."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    written = []
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        savable, dtype_name = _to_savable(arr)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), savable)
        written.append(os.path.join(tmp, fname))
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape),
             "dtype": dtype_name, "crc32": _leaf_crc(savable)})
        crash_point("snapshot.mid_write")
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    written.append(os.path.join(tmp, "manifest.json"))
    for path in written:
        fsync_file(path)
    fsync_dir(tmp)
    crash_point("snapshot.pre_rename")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    crash_point("snapshot.post_rename")
    fsync_dir(directory)
    return final


def _load_manifest(path: str) -> dict:
    mpath = os.path.join(path, "manifest.json")
    if not os.path.exists(mpath):
        raise CheckpointError(path, "", "missing manifest.json")
    try:
        with open(mpath) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointError(path, "", f"unreadable manifest: {e}") from e


def _load_leaf(path: str, rec: dict) -> np.ndarray:
    """One leaf, validated against its manifest record (shape + crc32)."""
    fpath = os.path.join(path, rec["file"])
    try:
        raw = np.load(fpath)
    except (OSError, ValueError) as e:
        raise CheckpointError(path, rec["key"], f"unreadable leaf: {e}") from e
    if "crc32" in rec and _leaf_crc(raw) != rec["crc32"]:
        raise CheckpointError(path, rec["key"], "crc32 mismatch (corrupt leaf)")
    arr = _from_saved(raw, rec["dtype"])
    if list(arr.shape) != list(rec["shape"]):
        raise CheckpointError(
            path, rec["key"],
            f"stored shape {list(arr.shape)} != manifest {rec['shape']}")
    return arr


def restore_checkpoint(directory: str, like: Any = None,
                       step: Optional[int] = None
                       ) -> Tuple[Any, int, dict]:
    """Restores a checkpoint. step=None -> latest. Returns
    (tree, step, extra).

    like provided: restores into its structure, with every leaf validated
    (key order, shape, dtype, stored crc32) — any mismatch raises
    ``CheckpointError`` naming the offending leaf. like=None: returns the
    flat ``{key: np.ndarray}`` dict as written (the tree must have been a
    flat dict) — host dtypes preserved exactly, no device transfer."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    manifest = _load_manifest(path)

    if like is None:
        out = {}
        for rec in manifest["leaves"]:
            out[rec["key"]] = _load_leaf(path, rec)
        return out, manifest["step"], manifest.get("extra", {})

    leaves, treedef = _flatten_with_paths(like)
    if len(leaves) != len(manifest["leaves"]):
        raise CheckpointError(
            path, "", f"pytree structure changed: {len(leaves)} leaves "
            f"expected, manifest has {len(manifest['leaves'])}")
    restored = []
    for (key, leaf), rec in zip(leaves, manifest["leaves"]):
        if key != rec["key"]:
            raise CheckpointError(
                path, rec["key"], f"leaf order mismatch: expected {key!r}")
        arr = _load_leaf(path, rec)
        want_shape = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise CheckpointError(
                path, key, f"shape {tuple(arr.shape)} != expected {want_shape}")
        want_dtype = getattr(leaf, "dtype", None)
        if want_dtype is not None and arr.dtype != want_dtype:
            raise CheckpointError(
                path, key, f"dtype {arr.dtype} != expected {want_dtype}")
        restored.append(jnp.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, restored)
    return tree, manifest["step"], manifest.get("extra", {})


def checkpoint_steps(directory: str):
    """All complete checkpoint steps under ``directory``, ascending."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = checkpoint_steps(directory)
    return steps[-1] if steps else None


class CheckpointManager:
    """Retention + async writes + restart contract."""

    def __init__(self, directory: str, keep: int = 3, async_writes: bool = True):
        self.directory = directory
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1) if async_writes else None
        self._pending = None
        self._error: Optional[BaseException] = None
        self._lock = threading.Lock()
        os.makedirs(directory, exist_ok=True)

    def _drain_pending_locked(self):
        """Joins the in-flight write; a stored failure surfaces here (and is
        cleared — one failed background write raises exactly once, on the
        next save/wait/restore_latest, instead of disappearing in the pool)."""
        if self._pending is not None:
            try:
                # staticcheck: disable=HMG202 (this drain IS the join point: save/wait/restore must not proceed past an in-flight write, and the single-slot pool means at most one writer blocks here)
                self._pending.result()
            except BaseException as e:  # noqa: BLE001 — surface, don't classify
                self._error = e
            self._pending = None
        if self._error is not None:
            err, self._error = self._error, None
            raise CheckpointError(
                self.directory, "",
                f"background checkpoint write failed: {err}") from err

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        # materialise on host *now* (snapshot semantics), write in background
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        snap = jax.tree_util.tree_unflatten(treedef, host)

        def work():
            save_checkpoint(self.directory, step, snap, extra)
            self._gc()

        if self._pool is None:
            work()
        else:
            with self._lock:
                self._drain_pending_locked()
                self._pending = self._pool.submit(work)

    def wait(self):
        with self._lock:
            self._drain_pending_locked()

    def restore_latest(self, like: Any = None):
        self.wait()
        self._gc_tmp()
        return restore_checkpoint(self.directory, like)

    def _gc_tmp(self):
        """Removes leftover ``step_<N>.tmp`` dirs (crashed writers). They are
        never a restore candidate — ``latest_step`` only matches completed
        dirs — but they hold disk and would shadow a same-step rewrite."""
        for name in os.listdir(self.directory):
            if re.fullmatch(r"step_\d+\.tmp", name):
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for m in
            (re.fullmatch(r"step_(\d+)", n) for n in os.listdir(self.directory))
            if m)
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
