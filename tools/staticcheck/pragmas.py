"""Suppression pragmas: ``# staticcheck: disable=HMG003 (reason)``.

A pragma suppresses the named rule(s) on its own line and — when it stands
alone on a line — on the next code line (so multi-line calls can carry the
pragma above the call). The parenthesised reason is mandatory: a disable
without one does not suppress anything and is itself reported (HMG000), so
the suppression inventory stays auditable. Unknown rule ids are HMG000 too
(a typo'd pragma must not silently disable nothing).
"""
from __future__ import annotations

import re
from typing import Dict, List, Set, Tuple

from tools.staticcheck import Violation

# canonical:  # staticcheck: disable=HMG001,HMG003 (reason text)
PRAGMA = re.compile(
    r"#\s*staticcheck\s*:\s*disable\s*=\s*"
    r"(?P<rules>[A-Za-z0-9_,\s]+?)"
    r"\s*(?:\((?P<reason>[^)]*)\))?\s*$")

KNOWN_RULES = {"HMG001", "HMG002", "HMG003", "HMG004",
               "HMG101", "HMG102", "HMG103",
               "HMG201", "HMG202", "HMG203", "HMG204"}


class PragmaIndex:
    """Per-file map: line number -> set of rule ids disabled there."""

    def __init__(self, disabled: Dict[int, Set[str]],
                 violations: List[Violation]):
        self._disabled = disabled
        self.violations = violations

    def is_disabled(self, rule: str, line: int) -> bool:
        return rule in self._disabled.get(line, ())


def _parse_line(text: str) -> Tuple[Set[str], str, bool]:
    """(rules, reason, found). ``found`` is True for any disable pragma,
    well-formed or not."""
    m = PRAGMA.search(text)
    if not m:
        return set(), "", False
    rules = {r.strip().upper() for r in m.group("rules").split(",")
             if r.strip()}
    return rules, (m.group("reason") or "").strip(), True


def scan_pragmas(path: str, source: str) -> PragmaIndex:
    disabled: Dict[int, Set[str]] = {}
    violations: List[Violation] = []
    lines = source.splitlines()
    for i, text in enumerate(lines, start=1):
        if "staticcheck" not in text:
            continue
        rules, reason, found = _parse_line(text)
        if not found:
            continue
        bad = rules - KNOWN_RULES
        if bad:
            violations.append(Violation(
                "HMG000", path, i,
                f"pragma names unknown rule id(s) {sorted(bad)} — it would "
                "silently disable nothing", fixable=False))
            rules &= KNOWN_RULES
        if not reason:
            violations.append(Violation(
                "HMG000", path, i,
                "disable pragma without a reason — spell it "
                "'# staticcheck: disable=RULE (why it is safe here)'",
                fixable=True))
            continue                      # a bare disable suppresses nothing
        eff = disabled.setdefault(i, set())
        eff |= rules
        # a pragma-only line also covers the next code line
        if text.strip().startswith("#"):
            for j in range(i + 1, len(lines) + 1):
                if j > len(lines):
                    break
                nxt = lines[j - 1].strip()
                if nxt and not nxt.startswith("#"):
                    disabled.setdefault(j, set()).update(rules)
                    break
    return PragmaIndex(disabled, violations)


def filter_suppressed(violations: List[Violation],
                      index: PragmaIndex) -> List[Violation]:
    return [v for v in violations
            if not index.is_disabled(v.rule, v.line)]
