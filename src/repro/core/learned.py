"""Learned index-parameter prediction (paper Eq. 4): a random-forest
regressor p̂ = f(x; θ) over workload features x = [μ_e, σ_e, ‖q‖, log N, p, …]
predicting the (n_probe, ef) that hits a recall target at minimum cost.

Built from scratch (numpy CART trees + bootstrap bagging) — no sklearn in
this environment, and the forest is part of the system per the scope rule.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0
    left: int = -1
    right: int = -1
    value: float = 0.0


class DecisionTreeRegressor:
    """CART with MSE splits, depth/min-samples bounded."""

    def __init__(self, max_depth: int = 6, min_samples_leaf: int = 4,
                 n_thresholds: int = 16):
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.n_thresholds = n_thresholds
        self.nodes: List[_Node] = []

    def fit(self, X: np.ndarray, y: np.ndarray) -> "DecisionTreeRegressor":
        self.nodes = []
        self._grow(np.asarray(X, np.float64), np.asarray(y, np.float64), 0)
        return self

    def _grow(self, X, y, depth) -> int:
        idx = len(self.nodes)
        self.nodes.append(_Node(value=float(y.mean()) if len(y) else 0.0))
        if (depth >= self.max_depth or len(y) < 2 * self.min_samples_leaf
                or np.allclose(y, y[0])):
            return idx
        best = None  # (sse, feat, thr)
        for f in range(X.shape[1]):
            col = X[:, f]
            qs = np.unique(np.quantile(col, np.linspace(0.05, 0.95, self.n_thresholds)))
            for thr in qs:
                m = col <= thr
                nl, nr = int(m.sum()), int((~m).sum())
                if nl < self.min_samples_leaf or nr < self.min_samples_leaf:
                    continue
                yl, yr = y[m], y[~m]
                sse = (yl.var() * nl) + (yr.var() * nr)
                if best is None or sse < best[0]:
                    best = (sse, f, float(thr))
        if best is None:
            return idx
        _, f, thr = best
        m = X[:, f] <= thr
        node = self.nodes[idx]
        node.feature, node.threshold = f, thr
        node.left = self._grow(X[m], y[m], depth + 1)
        node.right = self._grow(X[~m], y[~m], depth + 1)
        return idx

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, np.float64)
        out = np.empty(len(X))
        for i, row in enumerate(X):
            n = 0
            while self.nodes[n].feature >= 0:
                n = (self.nodes[n].left if row[self.nodes[n].feature]
                     <= self.nodes[n].threshold else self.nodes[n].right)
            out[i] = self.nodes[n].value
        return out


class RandomForestRegressor:
    def __init__(self, n_trees: int = 16, max_depth: int = 6,
                 min_samples_leaf: int = 4, seed: int = 0):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.seed = seed
        self.trees: List[DecisionTreeRegressor] = []

    def fit(self, X, y) -> "RandomForestRegressor":
        rng = np.random.default_rng(self.seed)
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        self.trees = []
        for _ in range(self.n_trees):
            boot = rng.integers(0, len(X), len(X))
            t = DecisionTreeRegressor(self.max_depth, self.min_samples_leaf)
            t.fit(X[boot], y[boot])
            self.trees.append(t)
        return self

    def predict(self, X) -> np.ndarray:
        return np.mean([t.predict(X) for t in self.trees], axis=0)


@dataclasses.dataclass
class ParamPredictor:
    """Eq. 4 wrapper: features -> predicted (n_probe, ef)."""
    probe_model: Optional[RandomForestRegressor] = None
    ef_model: Optional[RandomForestRegressor] = None

    @staticmethod
    def featurize(queries: np.ndarray, n: int, n_partitions: int) -> np.ndarray:
        q = np.asarray(queries, np.float64)
        mu = q.mean(axis=1)
        sd = q.std(axis=1)
        nrm = np.linalg.norm(q, axis=1)
        return np.stack([mu, sd, nrm,
                         np.full(len(q), np.log(max(n, 2))),
                         np.full(len(q), float(n_partitions))], axis=1)

    def fit(self, feats: np.ndarray, best_probe: np.ndarray,
            best_ef: np.ndarray) -> "ParamPredictor":
        self.probe_model = RandomForestRegressor(seed=1).fit(feats, best_probe)
        self.ef_model = RandomForestRegressor(seed=2).fit(feats, best_ef)
        return self

    def predict(self, feats: np.ndarray):
        p = np.clip(np.round(self.probe_model.predict(feats)), 1, None).astype(int)
        e = np.clip(np.round(self.ef_model.predict(feats)), 8, None).astype(int)
        return p, e
