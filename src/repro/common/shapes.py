"""Shape-padding helpers for static args of jitted entry points.

Every distinct Python-int value reaching a ``static_argnames`` parameter
compiles a new executable. Data-dependent widths (live-row counts, filter
cardinalities, drain sizes) must therefore be quantised before they touch a
jit boundary: ``pow2_round`` gives O(log n) distinct values over any range,
``pad_to_chunk`` gives one value per chunk multiple. staticcheck's HMG002
recognises both helpers (and the inline ``(x - 1).bit_length()`` idiom) as
sanctioned routes; raw ``int(...)``/``len(...)`` feeding a static arg is a
violation.
"""
from __future__ import annotations


def pow2_round(n: int, *, lo: int = 1, hi: int | None = None) -> int:
    """Smallest power of two >= n, clamped to [lo, hi].

    The PR 2 ``k_scan`` discipline: a scan width that doubles instead of
    tracking the exact candidate count takes at most log2(hi) distinct
    values, so the executor's adaptive widening reuses compiled
    executables instead of respecialising per batch."""
    n = max(int(n), 1)
    v = 1 << (n - 1).bit_length()
    v = max(v, lo)
    if hi is not None:
        v = min(v, hi)
    return v


def pad_to_chunk(n: int, chunk: int) -> int:
    """Smallest multiple of ``chunk`` >= n (n=0 stays 0).

    The PR 5 drain discipline: transfer widths padded to a fixed chunk
    compile once per chunk count, not once per occupancy."""
    chunk = int(chunk)
    if chunk <= 0:
        raise ValueError(f"chunk must be positive, got {chunk}")
    n = int(n)
    return ((n + chunk - 1) // chunk) * chunk
