"""Real spherical harmonics via stable associated-Legendre recurrence.

Layout: flat (l, m) with index l² + (m + l), m ∈ [-l, l]; real convention
  Y_{l,-|m|} ∝ P_l^{|m|}(cosθ)·sin(|m|φ),  Y_{l,+|m|} ∝ P_l^{|m|}(cosθ)·cos(|m|φ)
orthonormalised over the sphere (∫ Y² dΩ = 1). Differentiable away from the
poles/origin; inputs are unit-safe (r=0 maps to ẑ).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def sh_dim(l_max: int) -> int:
    return (l_max + 1) ** 2


def sh_index(l: int, m: int) -> int:
    return l * l + (m + l)


def real_sph_harm(vectors: jax.Array, l_max: int) -> jax.Array:
    """vectors (..., 3) -> (..., (l_max+1)^2) orthonormal real SH."""
    x, y, z = vectors[..., 0], vectors[..., 1], vectors[..., 2]
    r = jnp.sqrt(x * x + y * y + z * z)
    safe = r > 1e-12
    rs = jnp.where(safe, r, 1.0)
    ct = jnp.where(safe, z / rs, 1.0)                      # cosθ
    rho = jnp.sqrt(jnp.maximum(x * x + y * y, 1e-24))      # sinθ·r
    st = jnp.where(safe, rho / rs, 0.0)                    # sinθ ≥ 0
    cphi = jnp.where(rho > 1e-12, x / rho, 1.0)
    sphi = jnp.where(rho > 1e-12, y / rho, 0.0)

    # associated Legendre P_l^m(ct) with Condon–Shortley, m >= 0, recurrence:
    #   P_m^m = (-1)^m (2m-1)!! st^m
    #   P_{m+1}^m = ct (2m+1) P_m^m
    #   P_l^m = ((2l-1) ct P_{l-1}^m - (l+m-1) P_{l-2}^m) / (l - m)
    P = {}
    pmm = jnp.ones_like(ct)
    for m in range(l_max + 1):
        if m > 0:
            pmm = pmm * (-(2 * m - 1)) * st
        P[(m, m)] = pmm
        if m + 1 <= l_max:
            P[(m + 1, m)] = ct * (2 * m + 1) * pmm
        for l in range(m + 2, l_max + 1):
            P[(l, m)] = ((2 * l - 1) * ct * P[(l - 1, m)]
                         - (l + m - 1) * P[(l - 2, m)]) / (l - m)

    # cos(mφ), sin(mφ) by recurrence
    cos_m = [jnp.ones_like(cphi), cphi]
    sin_m = [jnp.zeros_like(sphi), sphi]
    for m in range(2, l_max + 1):
        cos_m.append(2 * cphi * cos_m[-1] - cos_m[-2])
        sin_m.append(2 * cphi * sin_m[-1] - sin_m[-2])

    out = []
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            am = abs(m)
            # orthonormal normalisation; (-1)^m cancels Condon–Shortley so the
            # real SH are the standard (positive) tesseral harmonics
            norm = math.sqrt((2 * l + 1) / (4 * math.pi)
                             * math.factorial(l - am) / math.factorial(l + am))
            if m != 0:
                norm *= math.sqrt(2.0)
            sign = (-1.0) ** am
            base = sign * norm * P[(l, am)]
            if m < 0:
                out.append(base * sin_m[am])
            elif m == 0:
                out.append(base)
            else:
                out.append(base * cos_m[am])
    return jnp.stack(out, axis=-1)


# ---------------------------------------------------------------------------
# Real-basis Wigner D via the Ivanic–Ruedenberg recurrence
# ---------------------------------------------------------------------------

def _p_func(i, l, a, b, D1, Dl1):
    """Ivanic–Ruedenberg helper P_i(l; a, b) (vectorised over leading dims)."""
    # D1 indexed by [i+1, j+1] for i,j in {-1,0,1}; Dl1 is D^{l-1}
    def d1(i_, j_):
        return D1[..., i_ + 1, j_ + 1]

    def dl(a_, b_):
        return Dl1[..., a_ + (l - 1), b_ + (l - 1)]

    if b == l:
        return d1(i, 1) * dl(a, l - 1) - d1(i, -1) * dl(a, -(l - 1))
    if b == -l:
        return d1(i, 1) * dl(a, -(l - 1)) + d1(i, -1) * dl(a, l - 1)
    return d1(i, 0) * dl(a, b)


def _uvw(l, m, n):
    """Ivanic–Ruedenberg (1996, with 1998 errata) u, v, w coefficients."""
    d = 1.0 if m == 0 else 0.0
    denom = (l + n) * (l - n) if abs(n) < l else (2 * l) * (2 * l - 1)
    u = math.sqrt((l + m) * (l - m) / denom)
    v = 0.5 * math.sqrt((1 + d) * (l + abs(m) - 1) * (l + abs(m)) / denom) * (1 - 2 * d)
    w = -0.5 * math.sqrt((l - abs(m) - 1) * (l - abs(m)) / denom) * (1 - d)
    return u, v, w


def _u_func(l, m, n, D1, Dl1):
    return _p_func(0, l, m, n, D1, Dl1)


def _v_func(l, m, n, D1, Dl1):
    if m == 0:
        return _p_func(1, l, 1, n, D1, Dl1) + _p_func(-1, l, -1, n, D1, Dl1)
    if m > 0:
        d1 = 1.0 if m == 1 else 0.0
        return (_p_func(1, l, m - 1, n, D1, Dl1) * math.sqrt(1 + d1)
                - _p_func(-1, l, -m + 1, n, D1, Dl1) * (1 - d1))
    d1 = 1.0 if m == -1 else 0.0
    return (_p_func(1, l, m + 1, n, D1, Dl1) * (1 - d1)
            + _p_func(-1, l, -m - 1, n, D1, Dl1) * math.sqrt(1 + d1))


def _w_func(l, m, n, D1, Dl1):
    if m == 0:
        raise AssertionError("w term vanishes for m == 0")
    if m > 0:
        return (_p_func(1, l, m + 1, n, D1, Dl1)
                + _p_func(-1, l, -m - 1, n, D1, Dl1))
    return (_p_func(1, l, m - 1, n, D1, Dl1)
            - _p_func(-1, l, -m + 1, n, D1, Dl1))


def wigner_d_from_rotation(R: jax.Array, l_max: int):
    """Real-basis Wigner-D blocks for rotation matrices R (..., 3, 3).

    Returns list [D^0 (...,1,1), D^1 (...,3,3), ..., D^{l_max}]. Equivariance:
    real_sph_harm(v @ R.T)_l == D^l @ real_sph_harm(v)_l.
    """
    batch = R.shape[:-2]
    D0 = jnp.ones(batch + (1, 1), R.dtype)
    # real-SH order (m = -1, 0, 1) ~ (y, z, x): D^1 = permuted R
    perm = [1, 2, 0]
    D1 = R[..., perm, :][..., :, perm]
    Ds = [D0, D1]
    for l in range(2, l_max + 1):
        Dl1 = Ds[-1]
        size = 2 * l + 1
        rows = []
        for m in range(-l, l + 1):
            row = []
            for n in range(-l, l + 1):
                u, v, w = _uvw(l, m, n)
                term = jnp.zeros(batch, R.dtype)
                if abs(u) > 1e-14:
                    term = term + u * _u_func(l, m, n, D1, Dl1)
                if abs(v) > 1e-14:
                    term = term + v * _v_func(l, m, n, D1, Dl1)
                if abs(w) > 1e-14:
                    term = term + w * _w_func(l, m, n, D1, Dl1)
                row.append(term)
            rows.append(jnp.stack(row, axis=-1))
        Ds.append(jnp.stack(rows, axis=-2))
    if l_max == 0:
        return [D0]
    return Ds[: l_max + 1]


def rotation_to_align_z(vec: jax.Array) -> jax.Array:
    """R (..., 3, 3) with R @ v̂ = ẑ (eSCN edge-frame alignment)."""
    v = vec / jnp.maximum(jnp.linalg.norm(vec, axis=-1, keepdims=True), 1e-12)
    x, y, z = v[..., 0], v[..., 1], v[..., 2]
    # axis = v × ẑ, angle = arccos(z); Rodrigues. Degenerate v ≈ ±ẑ handled.
    ax = jnp.stack([y, -x, jnp.zeros_like(x)], axis=-1)
    s = jnp.linalg.norm(ax, axis=-1)
    c = z
    safe = s > 1e-8
    axn = ax / jnp.maximum(s, 1e-12)[..., None]
    K = jnp.zeros(v.shape[:-1] + (3, 3), v.dtype)
    a1, a2, a3 = axn[..., 0], axn[..., 1], axn[..., 2]
    K = K.at[..., 0, 1].set(-a3).at[..., 0, 2].set(a2)
    K = K.at[..., 1, 0].set(a3).at[..., 1, 2].set(-a1)
    K = K.at[..., 2, 0].set(-a2).at[..., 2, 1].set(a1)
    eye = jnp.broadcast_to(jnp.eye(3, dtype=v.dtype), K.shape)
    R = eye + s[..., None, None] * K + (1 - c)[..., None, None] * (K @ K)
    flip = jnp.broadcast_to(jnp.diag(jnp.asarray([1.0, -1.0, -1.0], v.dtype)), K.shape)
    R = jnp.where(safe[..., None, None], R, jnp.where(c[..., None, None] > 0, eye, flip))
    return R
