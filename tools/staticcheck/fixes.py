"""``--fix``: mechanical rewrites for the rules where the fix is provable.

Two fixers, both conservative:

- pragma normalisation — rewrites spelling variants of a *well-formed*
  disable (odd spacing, lowercase rule ids) to the canonical
  ``# staticcheck: disable=HMG003 (reason)`` form. A pragma with no reason
  is NOT given one: inventing a justification would defeat the audit, so
  bare disables stay violations.
- HMG003 kwarg insertion — appends ``node_pass=None`` to a flagged scan
  call. The callee's default for that kwarg is ``None`` everywhere in this
  repo (registry: MVCC_DEFAULT_NONE_KWARG), so the rewrite is
  behaviour-preserving; it converts an implicit opt-out into an explicit,
  greppable one.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Tuple

from tools.staticcheck import Violation
from tools.staticcheck.pragmas import KNOWN_RULES, PRAGMA
from tools.staticcheck.registry import (MVCC_DEFAULT_NONE_KWARG,
                                        MVCC_ENTRY_POINTS)


def normalize_pragmas(source: str) -> Tuple[str, int]:
    """Canonicalise well-formed pragmas in ``source``; returns (new source,
    number of lines rewritten)."""
    lines = source.splitlines(keepends=True)
    n_fixed = 0
    for i, text in enumerate(lines):
        if "staticcheck" not in text:
            continue
        m = PRAGMA.search(text)
        if not m:
            continue
        reason = (m.group("reason") or "").strip()
        if not reason:
            continue                     # never invent a reason
        rules = sorted({r.strip().upper() for r in
                        m.group("rules").split(",") if r.strip()})
        if not set(rules) <= KNOWN_RULES:
            continue                     # unknown ids need a human
        eol = "\n" if text.endswith("\n") else ""
        canonical = (f"# staticcheck: disable={','.join(rules)} "
                     f"({reason})")
        new = text[:m.start()].rstrip("\n") + canonical + eol
        if new != text:
            lines[i] = new
            n_fixed += 1
    return "".join(lines), n_fixed


def insert_mvcc_kwargs(source: str,
                       violations: List[Violation]) -> Tuple[str, int]:
    """Append ``node_pass=None`` to each HMG003-flagged call, located via
    ast (so multi-line calls rewrite at their true closing paren)."""
    lines_flagged = {v.line for v in violations
                     if v.rule == "HMG003" and v.fixable}
    if not lines_flagged:
        return source, 0
    try:
        tree = ast.parse(source)
    except SyntaxError:
        return source, 0

    # (end_lineno, end_col) insertion points, applied bottom-up so earlier
    # offsets stay valid
    points: List[Tuple[int, int, bool]] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or node.lineno not in \
                lines_flagged:
            continue
        name = None
        if isinstance(node.func, ast.Name):
            name = node.func.id
        elif isinstance(node.func, ast.Attribute):
            name = node.func.attr
        if name not in MVCC_ENTRY_POINTS:
            continue
        has_args = bool(node.args or node.keywords)
        points.append((node.end_lineno, node.end_col_offset, has_args))

    lines = source.splitlines(keepends=True)
    for end_line, end_col, has_args in sorted(points, reverse=True):
        text = lines[end_line - 1]
        insert_at = end_col - 1          # just before the closing paren
        kw = f"{MVCC_DEFAULT_NONE_KWARG}=None"
        frag = f", {kw}" if has_args else kw
        lines[end_line - 1] = text[:insert_at] + frag + text[insert_at:]
    return "".join(lines), len(points)


def apply_fixes(path: str, source: str,
                violations: List[Violation]) -> Tuple[str, Dict[str, int]]:
    counts: Dict[str, int] = {}
    source, n = normalize_pragmas(source)
    if n:
        counts["pragma-normalized"] = n
    source, n = insert_mvcc_kwargs(
        source, [v for v in violations if v.path == path])
    if n:
        counts["node_pass-inserted"] = n
    return source, counts
