"""Knowledge-graph store: CSR adjacency + typed/weighted edges + node payloads.

This is HMGI's relational side (the paper's Neo4j role): entities are nodes,
relationships are typed weighted edges, and each node carries the id of its
embedding in the vector side of the index. Traversal operators live in
``core/traversal.py`` and run as fixed-hop masked frontier pushes over these
arrays (docs/DESIGN.md §2.3).

``NodeAttributes`` is the relational *predicate* side: a small fixed set of
int/categorical columns per global node id, held column-major on device, so
"WHERE node.category == X" compiles to one gather + compare and pushes down
into the vector scans (core/ivf.py, core/delta.py) and the traversal mask
(core/traversal.py) — the NHQ/TigerVector structured+unstructured query
class, served pre-top-k instead of by post-filtering.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np


class GraphStore(NamedTuple):
    indptr: jax.Array       # (N+1,) int32 CSR row pointers (by src)
    indices: jax.Array      # (E,) int32 dst node per edge
    src: jax.Array          # (E,) int32 src node per edge (COO twin for segment ops)
    edge_type: jax.Array    # (E,) int32
    edge_weight: jax.Array  # (E,) fp32
    node_modality: jax.Array  # (N,) int32 — modality id of each node's embedding

    @property
    def n_nodes(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def n_edges(self) -> int:
        return self.indices.shape[0]

    @property
    def nbytes(self) -> int:
        return sum(int(a.size) * a.dtype.itemsize for a in self)


def from_edges(n_nodes: int, src: np.ndarray, dst: np.ndarray,
               edge_type: Optional[np.ndarray] = None,
               edge_weight: Optional[np.ndarray] = None,
               node_modality: Optional[np.ndarray] = None,
               make_undirected: bool = False) -> GraphStore:
    """Host-side construction: sorts edges by src into CSR."""
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    et = np.zeros_like(src) if edge_type is None else np.asarray(edge_type, np.int32)
    ew = np.ones(len(src), np.float32) if edge_weight is None else np.asarray(edge_weight, np.float32)
    if make_undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        et = np.concatenate([et, et])
        ew = np.concatenate([ew, ew])
    order = np.argsort(src, kind="stable")
    src, dst, et, ew = src[order], dst[order], et[order], ew[order]
    counts = np.bincount(src, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, np.int32)
    np.cumsum(counts, out=indptr[1:])
    nm = (np.zeros(n_nodes, np.int32) if node_modality is None
          else np.asarray(node_modality, np.int32))
    return GraphStore(
        indptr=jnp.asarray(indptr), indices=jnp.asarray(dst), src=jnp.asarray(src),
        edge_type=jnp.asarray(et), edge_weight=jnp.asarray(ew),
        node_modality=jnp.asarray(nm),
    )


def degree(g: GraphStore) -> jax.Array:
    return g.indptr[1:] - g.indptr[:-1]


def edge_type_lut(edge_types: Iterable[int]) -> jax.Array:
    """Compiles a Cypher-style ``[:REL_A|:REL_B]`` filter — an iterable of
    edge-type ids — into a (T,) fp32 mask (indexed by edge type; excluded
    types carry zero weight, so they route no mass). T = max requested
    id + 1; the traversal treats types beyond the mask as excluded, so the
    graph's full type domain never needs to be known (no device reduction
    at plan time)."""
    raw = np.asarray(list(edge_types))
    if raw.size and not np.issubdtype(raw.dtype, np.integer):
        # a float-valued sequence is almost certainly a *mask* spelled as a
        # list — reinterpreting it as type ids would silently invert the
        # filter; masks must be passed as arrays (np/jnp)
        raise ValueError("edge_types must be integer type ids; pass a "
                         "(T,) mask as an array, not a list")
    types = np.unique(raw.astype(np.int64))
    if types.size == 0:
        raise ValueError("empty edge-type set")
    if types.min() < 0:
        raise ValueError("edge-type ids must be non-negative")
    lut = np.zeros(int(types.max()) + 1, np.float32)
    lut[types] = 1.0
    return jnp.asarray(lut)


# ---------------------------------------------------------------------------
# Node attributes + predicates (the relational WHERE clause)
# ---------------------------------------------------------------------------

# where-clause ops. "in" takes an iterable of ints (categorical value set,
# compiled to a boolean lookup table over the column's domain).
_OPS = ("==", "!=", "<", "<=", ">", ">=", "in")

# one predicate: (column, op, value) e.g. ("category", "==", 3),
# ("price", "<=", 100), ("tag", "in", {1, 5, 7}). A sequence of predicates
# is a conjunction (AND).
Predicate = Tuple[str, str, Union[int, Iterable[int]]]


@dataclasses.dataclass(frozen=True)
class CompiledPredicate:
    """Jit-friendly predicate form: static (col, op) + device value/value-set.

    ``value`` is a scalar int32 for comparison ops; ``valueset`` is a bool
    lookup table over [0, domain) for "in" (out-of-range values fail)."""
    col: int
    op: str
    value: Optional[jax.Array] = None
    valueset: Optional[jax.Array] = None


class NodeAttributes:
    """Columnar int/categorical attributes keyed by global node id.

    values: (C, N) int32 on device; ``columns`` maps name -> row. Missing
    nodes (ids a modality doesn't cover) read whatever default the column was
    built with (0 unless specified)."""

    def __init__(self, columns: Dict[str, int], values: jax.Array):
        self.columns = dict(columns)
        self.values = values

    @classmethod
    def from_columns(cls, n_nodes: int,
                     cols: Dict[str, np.ndarray]) -> "NodeAttributes":
        names = list(cols)
        mat = np.zeros((len(names), n_nodes), np.int32)
        for i, name in enumerate(names):
            v = np.asarray(cols[name], np.int32)
            if v.shape != (n_nodes,):
                raise ValueError(
                    f"column {name!r}: shape {v.shape} != ({n_nodes},)")
            mat[i] = v
        return cls({n: i for i, n in enumerate(names)}, jnp.asarray(mat))

    @property
    def n_nodes(self) -> int:
        return self.values.shape[1]

    def column(self, name: str) -> jax.Array:
        return self.values[self.columns[name]]

    def compile_where(self, where) -> Tuple[CompiledPredicate, ...]:
        """Normalises a where clause (one predicate tuple or a sequence of
        them, AND-combined) into compiled form."""
        if where is None:
            return ()
        if isinstance(where, tuple) and len(where) == 3 \
                and isinstance(where[0], str):
            where = [where]
        out = []
        for col, op, value in where:
            if op not in _OPS:
                raise ValueError(f"unknown predicate op {op!r} (one of {_OPS})")
            ci = self.columns[col]
            if op == "in":
                vals = np.asarray(sorted(set(int(v) for v in value)), np.int64)
                if vals.size == 0:
                    raise ValueError(f"empty value set for column {col!r}")
                if vals.min() < 0:
                    raise ValueError("'in' value sets must be non-negative")
                lut = np.zeros(int(vals.max()) + 1, bool)
                lut[vals] = True
                out.append(CompiledPredicate(ci, op, valueset=jnp.asarray(lut)))
            else:
                out.append(CompiledPredicate(
                    ci, op, value=jnp.asarray(int(value), jnp.int32)))
        return tuple(out)

    def node_pass(self, where) -> Optional[jax.Array]:
        """Evaluates a where clause to an (N,) bool mask (None = no filter).
        One compare (or LUT gather) per predicate — O(C·N) int ops, done once
        per query batch and shared by every scan/traversal stage."""
        preds = self.compile_where(where)
        if not preds:
            return None
        return eval_predicates(self.values, preds)


def mask_pass(node_pass: jax.Array, ids: jax.Array) -> jax.Array:
    """Gathers a (max_id+1,) predicate mask at (possibly -1-padded) id
    arrays: True iff the id is valid AND passes. The one shared spelling of
    the clip-gather idiom every scan/merge/fusion stage uses."""
    ok = node_pass[jnp.clip(ids, 0, node_pass.shape[0] - 1)]
    return jnp.logical_and(ids >= 0, ok)


def eval_predicates(values: jax.Array,
                    preds: Sequence[CompiledPredicate]) -> jax.Array:
    """(C, N) attribute matrix × compiled conjunction -> (N,) bool. Pure jnp
    (safe inside jit: col/op are static, value/valueset are arrays)."""
    mask = jnp.ones(values.shape[1], bool)
    for p in preds:
        col = values[p.col]
        if p.op == "in":
            dom = p.valueset.shape[0]
            hit = p.valueset[jnp.clip(col, 0, dom - 1)]
            mask &= jnp.logical_and(hit, jnp.logical_and(col >= 0, col < dom))
        elif p.op == "==":
            mask &= col == p.value
        elif p.op == "!=":
            mask &= col != p.value
        elif p.op == "<":
            mask &= col < p.value
        elif p.op == "<=":
            mask &= col <= p.value
        elif p.op == ">":
            mask &= col > p.value
        else:
            mask &= col >= p.value
    return mask
