"""End-to-end serving driver (the paper's target application): a small LM
encoder + HMGI retrieval + continuous-batched RAG generation, plus the
declarative query-builder API for relationship-heavy retrieval.

    PYTHONPATH=src python examples/multimodal_rag.py
"""
import time

import numpy as np
import jax

from repro.configs import get_config, smoke_config
from repro.core import HMGIIndex
from repro.data.synthetic import make_corpus
from repro.models import lm
from repro.query import Q
from repro.serving.engine import EngineConfig, RAGEngine

# 1. knowledge corpus + index: text and image entities in one graph, typed
#    edges (we treat type 1 as :authored), a `year` attribute column
corpus = make_corpus(n_nodes=1500, modality_dims={"text": 48, "image": 32},
                     seed=0)
AUTHORED = 1
rng0 = np.random.default_rng(0)
year = rng0.integers(2010, 2026, corpus.n_nodes).astype(np.int32)
cfg = get_config("hmgi").replace(n_partitions=16, n_probe=4, top_k=4,
                                 kmeans_iters=8)
index = HMGIIndex(cfg, seed=0)
index.ingest({m: (corpus.node_ids[m], corpus.vectors[m])
              for m in corpus.vectors},
             n_nodes=corpus.n_nodes,
             edges=(corpus.src, corpus.dst, corpus.edge_type),
             node_attrs={"year": year})
print(f"index built: {index.memory_usage()['total']/2**20:.2f} MiB")

# 1b. declarative hybrid query: "find entities (e.g. images) related via
#     :authored edges to text matches WHERE year > 2020". The predicate is
#     chain-global — it constrains the seed scan (pushdown or oversampling,
#     the planner decides from its selectivity), the traversal routing
#     (excluded nodes forward no mass) and the surfaced candidates.
qtext = corpus.vectors["text"][:4]
plan = (Q.vector("text", qtext)
          .where(("year", ">", 2020))
          .traverse(2, edge_types=(AUTHORED,))
          .topk(8))
print("plan:", index.explain(plan))
scores, ids = index.query(plan)
is_image = np.isin(np.asarray(ids), corpus.node_ids["image"])
print(f"hits: {int((np.asarray(ids) >= 0).sum())} "
      f"({int(is_image.sum())} image entities reached via :authored)")

# 1c. plans compose: re-score text matches in the image embedding space,
#     or intersect two seed scans (set ops over candidate sets)
qimg = corpus.vectors["image"][:4]
rescored = (Q.vector("text", qtext).traverse(1)
              .cross_modal("image", qimg, weight=0.4).topk(4))
both = Q.intersect(Q.vector("text", qtext).topk(32),
                   Q.vector("text", qtext + 0.05).topk(32)).topk(4)
for p in (rescored, both):
    print("plan:", index.explain(p))
    index.query(p)

# 2. a small LM (reduced phi4-family config) as the generator
lm_cfg = smoke_config("phi4-mini-3.8b")
params, _ = lm.init_lm(lm_cfg, jax.random.PRNGKey(0))
engine = RAGEngine(lm_cfg, params, index,
                   EngineConfig(n_slots=8, max_seq=96, retrieve_k=4, hops=1))

# 3. batched requests: retrieve entity context per query, then generate with
#    continuous batching (slots refill as requests finish)
rng = np.random.default_rng(2)
n_requests = 12
query_vecs = corpus.vectors["text"][rng.integers(0, 700, n_requests)]
retrieved = engine.retrieve(query_vecs)          # hybrid vector+graph
t0 = time.perf_counter()
for rid in range(n_requests):
    prompt = rng.integers(0, lm_cfg.vocab_size, 12)
    engine.submit(rid, prompt, retrieved_ids=retrieved[rid],
                  max_new_tokens=8 + (rid % 3) * 4)   # mixed lengths
outputs = engine.run_to_completion()
dt = time.perf_counter() - t0

done = sum(1 for v in outputs.values() if v)
toks = sum(len(v) for v in outputs.values())
print(f"served {done}/{n_requests} requests, {toks} tokens in {dt:.2f}s "
      f"({toks/dt:.1f} tok/s); engine stats: {engine.stats}")
assert done == n_requests
