"""Named crash points at every durability boundary (fault injection).

Durability claims are only as good as the crashes they survive. Every place
where the persistence layer transitions between "not yet durable" and
"durable" — around a log append, between snapshot leaf writes, around the
atomic rename, between replayed records — calls ``crash_point(name)``. In
production the call is a no-op (one dict lookup). Under test, a point is
*armed* and the process dies there mid-operation, exactly like ``kill -9``:

    HMGI_FAULTPOINT=wal.post_append      python child.py   # die on 1st hit
    HMGI_FAULTPOINT=wal.post_append:3    python child.py   # die on 3rd hit

or programmatically: ``faultpoints.arm("snapshot.pre_rename", hits=2)``.
The default crash mode is ``os._exit(137)`` — no atexit handlers, no
buffered-write flushing, nothing the real SIGKILL wouldn't do. Unit tests
that want to observe the failure in-process can arm with ``mode="raise"``,
which raises ``FaultInjected`` instead.

``POINTS`` is the static registry the sweep tests iterate: *every* entry
must be survivable — killing the process there and running ``recover()``
must yield search results bit-identical to an uninterrupted run of the
durable op prefix (tools/crash_harness.py asserts this for each one).
``crash_point`` refuses names outside the registry, so a new durability
boundary cannot be added without also entering the sweep.
"""
from __future__ import annotations

import os
from typing import Dict, Optional

# the sweep surface: one name per durability boundary. Keep in sync with
# docs/DESIGN.md §7.4 (each point's durable-prefix contract is stated there).
POINTS = (
    "wal.pre_append",       # before the record's bytes reach the segment
    "wal.post_append",      # after write (+fsync when the batch synced)
    "wal.pre_rotate",       # before a new segment file is created
    "wal.pre_gc",           # after a snapshot, before old segments unlink
    "wal.post_gc",          # after old segments unlink + dir fsync
    "snapshot.mid_write",   # between leaf files inside the .tmp dir
    "snapshot.pre_rename",  # .tmp complete + fsync'd, not yet visible
    "snapshot.post_rename", # renamed, parent dir not yet fsync'd
    "recover.mid_replay",   # between replayed op records
)

_ENV = "HMGI_FAULTPOINT"


class FaultInjected(RuntimeError):
    """Raised instead of killing the process when armed with mode="raise"."""

    def __init__(self, name: str):
        super().__init__(f"fault injected at {name}")
        self.point = name


class _Armed:
    def __init__(self, name: str, hits: int, mode: str):
        self.name = name
        self.remaining = hits
        self.mode = mode


_armed: Optional[_Armed] = None
_env_parsed = False
hit_counts: Dict[str, int] = {}


def arm(name: str, hits: int = 1, mode: str = "exit") -> None:
    """Arms ``name``: the ``hits``-th call to ``crash_point(name)`` crashes
    (mode="exit": ``os._exit(137)``; mode="raise": ``FaultInjected``)."""
    global _armed, _env_parsed
    if name not in POINTS:
        raise ValueError(f"unknown fault point {name!r} (register in POINTS)")
    if mode not in ("exit", "raise"):
        raise ValueError(f"unknown fault mode {mode!r}")
    _armed = _Armed(name, int(hits), mode)
    _env_parsed = True          # programmatic arming overrides the env


def disarm() -> None:
    global _armed, _env_parsed
    _armed = None
    _env_parsed = True
    hit_counts.clear()


def _parse_env() -> None:
    global _env_parsed, _armed
    _env_parsed = True
    spec = os.environ.get(_ENV, "")
    if not spec:
        return
    name, _, hits = spec.partition(":")
    arm(name.strip(), int(hits) if hits else 1, mode="exit")


def crash_point(name: str) -> None:
    """A durability boundary. No-op unless this point is armed."""
    if name not in POINTS:
        raise ValueError(f"unregistered fault point {name!r} — add to POINTS")
    if not _env_parsed:
        _parse_env()
    hit_counts[name] = hit_counts.get(name, 0) + 1
    a = _armed
    if a is None or a.name != name:
        return
    a.remaining -= 1
    if a.remaining > 0:
        return
    if a.mode == "raise":
        disarm()
        raise FaultInjected(name)
    os._exit(137)               # the real thing: no flush, no cleanup
