"""MVCC delta store (paper §3.5): insertions/updates/deletions land in a
fixed-capacity buffer; queries hybridise ANNS-on-stable with a scan-on-delta;
asynchronous compaction merges the delta into the IVF partitions without a
full rebuild.

Versioning: every write bumps ``version``. Visibility rules per read:
  stable row visible  iff  not tombstoned and not superseded
  delta  row visible  iff  not tombstoned
``superseded`` marks ids whose latest version lives in the delta (an update =
supersede(old) + insert(new)); compaction folds the latest versions back into
the stable index and clears the mask. Readers are wait-free: search takes a
consistent (stable, delta) snapshot pair.

Scan path: rows are quantized to int8 at insert time (mirroring the stable
slab layout), so the delta scan runs through the same fused Pallas kernel as
the IVF probe path — int8 HBM traffic, affine dequant folded into the matmul.
The top (k + margin) quantized survivors are then rescored exactly against
the fp32 master rows (a tiny gather), so results stay brute-force-exact
whenever the margin covers the quantization noise — and always when the
delta holds ≤ k + margin rows.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ivf as ivf_mod
from repro.core.ivf import IVFIndex
from repro.core.quantization import quantize
from repro.kernels.ivf_topk.ops import scan_topk_quantized
from repro.kernels.ivf_topk.ref import pad_topk

# default extra quantized survivors rescored in fp32 before the final top-k
# (HMGIConfig.delta_rescore_margin overrides per index)
_RESCORE_MARGIN = 16


class DeltaStore(NamedTuple):
    vectors: jax.Array      # (cap, d) fp32 — master rows (compaction, rescore)
    qdata: jax.Array        # (cap, d) int8 — kernel-scan mirror (centered)
    qvmin: jax.Array        # (cap,) fp32 — per-row affine dequant terms
    qscale: jax.Array       # (cap,) fp32
    ids: jax.Array          # (cap,) int32, -1 empty
    count: jax.Array        # () int32
    version: jax.Array      # () int32 — MVCC write counter
    tombstones: jax.Array   # (max_ids,) bool — user deletes
    superseded: jax.Array   # (max_ids,) bool — stale stable rows (updates)


def init(capacity: int, dim: int, max_ids: int) -> DeltaStore:
    return DeltaStore(
        vectors=jnp.zeros((capacity, dim), jnp.float32),
        qdata=jnp.zeros((capacity, dim), jnp.int8),
        qvmin=jnp.zeros((capacity,), jnp.float32),
        qscale=jnp.ones((capacity,), jnp.float32),
        ids=jnp.full((capacity,), -1, jnp.int32),
        count=jnp.zeros((), jnp.int32),
        version=jnp.zeros((), jnp.int32),
        tombstones=jnp.zeros((max_ids,), bool),
        superseded=jnp.zeros((max_ids,), bool),
    )


def _clip_ids(delta: DeltaStore, ids):
    return jnp.clip(ids, 0, delta.tombstones.shape[0] - 1)


@jax.jit
def insert(delta: DeltaStore, vecs: jax.Array, new_ids: jax.Array) -> DeltaStore:
    """Appends a batch (drops silently if full — caller checks ``should_compact``
    first). Rows are quantized here so reads never touch fp32 for the scan.
    Clears tombstones for re-inserted ids."""
    cap = delta.vectors.shape[0]
    n = vecs.shape[0]
    base = delta.count
    slots = jnp.clip(base + jnp.arange(n), 0, cap - 1)
    fits = (base + jnp.arange(n)) < cap
    v32 = vecs.astype(jnp.float32)
    qv = quantize(v32, 8)
    vectors = delta.vectors.at[slots].set(
        jnp.where(fits[:, None], v32, delta.vectors[slots]))
    qdata = delta.qdata.at[slots].set(
        jnp.where(fits[:, None], qv.data, delta.qdata[slots]))
    qvmin = delta.qvmin.at[slots].set(
        jnp.where(fits, qv.vmin[:, 0], delta.qvmin[slots]))
    qscale = delta.qscale.at[slots].set(
        jnp.where(fits, qv.scale[:, 0], delta.qscale[slots]))
    ids = delta.ids.at[slots].set(jnp.where(fits, new_ids.astype(jnp.int32),
                                            delta.ids[slots]))
    ts = delta.tombstones.at[_clip_ids(delta, new_ids)].set(False)
    return DeltaStore(vectors, qdata, qvmin, qscale, ids,
                      base + jnp.sum(fits.astype(jnp.int32)),
                      delta.version + 1, ts, delta.superseded)


@jax.jit
def supersede(delta: DeltaStore, old_ids: jax.Array) -> DeltaStore:
    """Marks stable rows stale (the update path: supersede + insert)."""
    sp = delta.superseded.at[_clip_ids(delta, old_ids)].set(True)
    return delta._replace(superseded=sp, version=delta.version + 1)


@jax.jit
def delete(delta: DeltaStore, dead_ids: jax.Array) -> DeltaStore:
    ts = delta.tombstones.at[_clip_ids(delta, dead_ids)].set(True)
    return delta._replace(tombstones=ts, version=delta.version + 1)


@functools.partial(jax.jit, static_argnames=("k", "margin"))
def _scan_delta(delta: DeltaStore, queries: jax.Array, *, k: int,
                margin: int = _RESCORE_MARGIN):
    """Kernel scan over the quantized delta rows + exact fp32 rescore of the
    top (k + margin) survivors. chunk=1 makes the survivor ordering exact
    over quantized scores (the delta is small; its scan output is tiny).
    Results match brute force exactly whenever the delta holds ≤ k + margin
    live rows, and up to int8 ordering error at the survivor boundary
    otherwise — raise ``margin`` (cfg.delta_rescore_margin) toward
    delta_capacity to trade scan output size for exactness."""
    cap = delta.ids.shape[0]
    valid = jnp.logical_and(delta.ids >= 0,
                            ~delta.tombstones[_clip_ids(delta, delta.ids)])
    k_scan = min(cap, k + margin)
    qvals, qrows = scan_topk_quantized(
        queries, delta.qdata, delta.qvmin, delta.qscale, valid, k=k_scan,
        chunk=1, block_n=128)
    rows = jnp.clip(qrows, 0, cap - 1)
    vecs = delta.vectors[rows]                                # (Q, k_scan, d)
    exact = jnp.einsum("qd,qrd->qr", queries.astype(jnp.float32),
                       vecs)
    exact = jnp.where(jnp.logical_and(qrows >= 0, jnp.isfinite(qvals)),
                      exact, -jnp.inf)
    kk = min(k, exact.shape[1])
    vals, pos = jax.lax.top_k(exact, kk)
    di = jnp.take_along_axis(delta.ids[rows], pos, axis=1)
    di = jnp.where(jnp.isfinite(vals), di, -1)
    return pad_topk(vals, di, k)


def search_with_delta(index: IVFIndex, delta: DeltaStore, queries: jax.Array, *,
                      n_probe: int, k: int,
                      rescore_margin: int = _RESCORE_MARGIN
                      ) -> Tuple[jax.Array, jax.Array]:
    """Stable-ANNS ∪ delta-kernel-scan, visibility-filtered, dedup-merged."""
    sv, si = ivf_mod.search(index, queries, n_probe=n_probe, k=k)
    dead = jnp.logical_or(delta.tombstones, delta.superseded)
    sv = jnp.where(dead[_clip_ids(delta, si)] | (si < 0), -jnp.inf, sv)
    dv, di = _scan_delta(delta, queries, k=k, margin=rescore_margin)
    # delta may hold multiple versions of an id (insert-after-insert): dedup
    mv, mi = ivf_mod.dedup_merge_topk(sv, si, dv, di, k)
    # -inf slots are "no result": don't leak a masked (e.g. tombstoned) id
    return mv, jnp.where(jnp.isfinite(mv), mi, -1)


def should_compact(delta: DeltaStore, threshold: float = 0.5) -> bool:
    return int(delta.count) >= int(threshold * delta.vectors.shape[0])


def compact(key, index: IVFIndex, delta: DeltaStore,
            all_vectors: jax.Array, all_ids: jax.Array) -> Tuple[IVFIndex, DeltaStore]:
    """Asynchronous-vacuum analogue: merge live delta rows into the stable
    index by re-running the (cheap) assignment against *existing* centroids —
    no K-means refit, no full rebuild (paper: "incremental merges into
    snapshots"). Centroid drift is handled by the workload-aware repartitioner.

    all_vectors/all_ids: the full live corpus with one latest row per id
    (facade-provided); returns (new_index, fresh_delta)."""
    live = ~delta.tombstones[_clip_ids(delta, all_ids)]
    vecs = jnp.where(live[:, None], all_vectors, 0.0)
    ids = jnp.where(live, all_ids, -1)
    new_index, overflow = ivf_mod.build(key, vecs, ids,
                                        n_partitions=index.n_partitions,
                                        capacity=index.capacity, bits=index.bits,
                                        centroids=index.centroids)
    fresh = init(delta.vectors.shape[0], delta.vectors.shape[1],
                 delta.tombstones.shape[0])
    fresh = fresh._replace(version=delta.version + 1, tombstones=delta.tombstones)
    # rows that didn't fit their partition stay queryable via the fresh delta
    over = jnp.logical_and(overflow, live)
    n_over = int(jnp.sum(over))
    if n_over:
        sel = jnp.where(over)[0][: fresh.vectors.shape[0]]
        fresh = insert(fresh, all_vectors[sel], all_ids[sel])
    return new_index, fresh
