"""HMGI default system config (the paper's own architecture, §3)."""
from repro.configs.base import HMGIConfig, ShapeSpec

CONFIG = HMGIConfig(
    arch_id="hmgi",
    source="this paper",
    dim=384,
    modalities=("text", "image", "audio", "video"),
    modality_dims={"text": 384, "image": 512, "video": 768, "audio": 1280},
    n_partitions=64,
    n_probe=8,
    top_k=10,
    quant_bits=8,
    nsw_degree=16,
    nsw_ef=64,
    delta_capacity=4096,
    w_vector=0.6,
    w_graph=0.4,
    max_hops=2,
)

# serving shapes for the index itself (benchmarks + distributed dry-run)
SHAPES = [
    ShapeSpec("serve_1m", "index_search", {"n_vectors": 1_048_576, "batch": 256, "dim": 384}),
    ShapeSpec("serve_16m", "index_search", {"n_vectors": 16_777_216, "batch": 1024, "dim": 384}),
]
