# The paper's primary contribution: the Hybrid Multimodal Graph Index.
from repro.core.index import HMGIIndex, ModalityIndex
from repro.core.graph_store import NodeAttributes
