"""Cross-request retrieval micro-batching for the serving path.

The serving engine used to issue one ``(1, k)`` jitted retrieval per
request while decode was already continuously batched — at 64 concurrent
streams that leaves ~6x of the fixed-shape batch amortisation on the
table (one probe assignment, one scan launch, one top-k per *request*
instead of per *batch*). This module closes that gap:

- ``MicroBatcher`` — a leader/follower combining funnel: requests arriving
  within a small window (plus everything that queued up while the previous
  batch was in flight) are stacked into one ``(Q, k)`` call through
  ``repro.query.executor.search_bucketed``, Q padded to a pow2 bucket so
  the compile-budget (HMG102/HMG103) stays O(log max_batch). Requests are
  grouped by plan fingerprint — a mixed-plan batch falls back to one
  bucketed call per group — and exact-duplicate queries inside a group are
  computed once and fanned out (dedup is exact-byte: serving a *nearby*
  query's results would be wrong).
- ``RetrievalService`` — admission (per-tenant token bucket, shared
  ``scheduler.AdmissionController``) -> hot-result cache lookup
  (``cache.HotResultCache``, version-stamped) -> micro-batch -> cache
  store. ``batching=False`` keeps the same bucketed entry (identical
  bytes) without the cross-request funnel — the bench's baseline mode.

Bit-exactness contract: ``search_bucketed`` pads every batch to a pow2
bucket >= 2, and for those shapes XLA:CPU computes each row independently
of its co-batched neighbours — so a request's result is byte-identical
whether it rode solo, deduped, or in a full bucket. The racecheck
interleaver exercises the cache + admission state; the MicroBatcher's
condition-variable handoff is real-thread-tested (a ``Condition.wait``
cannot run under the token-passing interleaver).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.query.executor import search_bucketed
from repro.serving.cache import HotResultCache
from repro.serving.scheduler import AdmissionController


@dataclasses.dataclass(frozen=True)
class RetrievalPlan:
    """The plan fingerprint: everything that selects a compiled plan for a
    retrieval, *except* the query values. Hashable — it keys micro-batch
    groups and cache entries. ``where`` must be the frozen spelling
    (``freeze_where``)."""
    modality: str
    k: int
    n_hops: int = 0
    n_probe: Optional[int] = None
    where: Optional[tuple] = None
    impl: str = "auto"


def freeze_where(where) -> Optional[tuple]:
    """Hashable spelling of a predicate: one (col, op, value) clause stays
    a tuple, a conjunction list becomes a tuple of clause tuples."""
    if where is None:
        return None
    if isinstance(where[0], (list, tuple)):
        return tuple(tuple(c) for c in where)
    return tuple(where)


def _thaw_where(frozen):
    if frozen is None:
        return None
    if isinstance(frozen[0], tuple):
        return [list(c) for c in frozen]
    return frozen


def run_plan(index, plan: RetrievalPlan,
             q: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """One bucketed retrieval for ``plan`` over the (Q, d) batch ``q``."""
    return search_bucketed(index, q, plan.modality, k=plan.k,
                           n_probe=plan.n_probe,
                           where=_thaw_where(plan.where),
                           n_hops=plan.n_hops, impl=plan.impl)


class _Pending:
    """One in-flight request riding a micro-batch."""
    __slots__ = ("plan", "q", "scores", "ids", "error", "ready")

    def __init__(self, plan: RetrievalPlan, q: np.ndarray):
        self.plan = plan
        self.q = q
        self.scores = None
        self.ids = None
        self.error: Optional[BaseException] = None
        self.ready = False


class MicroBatcher:
    """Leader/follower combining funnel over ``search_bucketed``.

    The first request to find no leader becomes one: it waits ``window_s``
    for followers to pile on, takes the whole pending list (releasing
    leadership first, so arrivals during execution elect the next leader
    and batches pipeline), executes one bucketed call per plan group, and
    wakes everyone. Followers park on the condition variable until their
    entry is marked ready. With ``window_s == 0`` batches still form under
    load — everything that arrived while the previous batch was in flight
    rides the next one."""

    def __init__(self, index, *, window_s: float = 0.001,
                 max_batch: int = 64, floor: int = 2):
        self.index = index
        self.window_s = float(window_s)
        self.max_batch = int(max_batch)
        self.floor = int(floor)
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._pending: List[_Pending] = []
        self._leader = False

    # ------------------------------------------------------------ internals
    def _execute(self, batch: List[_Pending]) -> None:
        """Run one taken batch: group by plan, dedup exact query bytes
        within each group, one bucketed call per group. Called with the
        lock NOT held (device work must never run under it)."""
        groups: Dict[RetrievalPlan, List[_Pending]] = {}
        for p in batch:
            groups.setdefault(p.plan, []).append(p)
        if len(groups) > 1:
            obs.counter("serving.batch.mixed_plan").inc()
        for plan, members in groups.items():
            uniq: Dict[bytes, int] = {}
            rows: List[np.ndarray] = []
            slot: List[int] = []
            for p in members:
                key = p.q.tobytes()
                at = uniq.get(key)
                if at is None:
                    at = uniq[key] = len(rows)
                    rows.append(p.q)
                else:
                    obs.counter("serving.batch.dedup_hits").inc()
                slot.append(at)
            sv, si = run_plan(self.index, plan, np.concatenate(rows))
            obs.histogram("serving.batch_q",
                          obs.COUNT_BUCKETS).observe(len(members))
            obs.counter("serving.batch.calls").inc()
            obs.counter("serving.batch.queries").inc(len(members))
            for p, at in zip(members, slot):
                p.scores, p.ids = sv[at:at + 1], si[at:at + 1]

    def _take_batch_locked(self) -> List[_Pending]:
        """Claim up to ``max_batch`` pending entries and release
        leadership (caller holds the lock)."""
        batch = self._pending[:self.max_batch]
        self._pending = self._pending[len(batch):]
        self._leader = False
        if self._pending:
            # leftovers need a new leader; wake a parked follower to claim
            self._cv.notify_all()
        return batch

    # ------------------------------------------------------------------ API
    def search(self, plan: RetrievalPlan,
               q: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Retrieve one (1, d) query through the funnel. Blocks until the
        batch it rode completes; returns (scores (1, k), ids (1, k))."""
        mine = _Pending(plan, np.ascontiguousarray(q, np.float32).reshape(1, -1))
        with self._lock:
            self._pending.append(mine)
            lead = not self._leader
            if lead:
                self._leader = True
        if lead:
            if self.window_s > 0.0:
                time.sleep(self.window_s)      # collect followers
            while True:
                with self._lock:
                    batch = self._take_batch_locked()
                try:
                    self._execute(batch)
                except BaseException as e:     # propagate to every rider
                    for p in batch:
                        p.error = e
                with self._lock:
                    for p in batch:
                        p.ready = True
                    self._cv.notify_all()
                    if mine.ready:
                        break
                    # our entry rode past max_batch: lead the next round
                    if not self._leader:
                        self._leader = True
                        continue
                # another thread took over leadership; park as a follower
                self._wait_ready(mine)
                break
        else:
            self._wait_ready(mine)
        if mine.error is not None:
            raise mine.error
        return mine.scores, mine.ids

    def _wait_ready(self, mine: _Pending) -> None:
        with self._lock:
            while not mine.ready:
                # a parked follower may be elected leader for leftovers
                # (the previous leader overflowed max_batch and quit)
                if self._pending and not self._leader:
                    self._leader = True
                    batch = self._take_batch_locked()
                    try:
                        self._execute_unlocked(batch)
                    finally:
                        for p in batch:
                            p.ready = True
                        self._cv.notify_all()
                    continue
                # staticcheck: disable=HMG202 (Condition.wait releases _lock while blocking; parked followers stall nobody)
                self._cv.wait(timeout=0.1)

    def _execute_unlocked(self, batch: List[_Pending]) -> None:
        """Drop the lock around device work, reacquire after (only called
        from ``_wait_ready``, which holds it)."""
        self._lock.release()
        try:
            self._execute(batch)
        except BaseException as e:
            for p in batch:
                p.error = e
        finally:
            self._lock.acquire()


class RetrievalService:
    """The serving retrieval path: admission -> cache -> micro-batch.

    ``search`` returns ``None`` when admission rejects (the caller sheds
    the request); otherwise (scores (1, k), ids (1, k)) — byte-identical
    to the same request retrieved alone, whatever it co-batched with.
    ``search_many`` is the caller-already-batched entry (the RAG engine's
    per-tick retrieval): one bucketed call for the cache-missing rows."""

    def __init__(self, index, *, batching: bool = True,
                 window_s: float = 0.001, max_batch: int = 64,
                 cache: Optional[HotResultCache] = None,
                 admission: Optional[AdmissionController] = None,
                 floor: int = 2):
        self.index = index
        self.batching = bool(batching)
        self.cache = cache
        self.admission = admission
        self.floor = int(floor)
        self._batcher = MicroBatcher(index, window_s=window_s,
                                     max_batch=max_batch, floor=floor)

    def _admit(self, tenant: str) -> bool:
        if self.admission is not None and not self.admission.try_admit(tenant):
            obs.counter("serving.rejected").inc()
            return False
        return True

    def search(self, plan: RetrievalPlan, q: np.ndarray,
               tenant: str = "default"
               ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        if not self._admit(tenant):
            return None
        q = np.ascontiguousarray(q, np.float32).reshape(1, -1)
        # the version is read BEFORE computing: if a mutation lands
        # mid-flight the stored stamp is already stale and the entry never
        # hits — a result can be cached under at most the state it saw
        version = self.index.version
        if self.cache is not None:
            hit = self.cache.lookup(plan, q, version)
            if hit is not None:
                return hit
        if self.batching:
            scores, ids = self._batcher.search(plan, q)
        else:
            scores, ids = run_plan(self.index, plan, q)
        if self.cache is not None:
            self.cache.store(plan, q, version, scores, ids)
        return scores, ids

    def search_many(self, plan: RetrievalPlan, queries: np.ndarray,
                    tenant: str = "default"
                    ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Caller-batched retrieval: cache per row, one bucketed call for
        the misses. Admission charges one token per row."""
        q = np.ascontiguousarray(queries, np.float32)
        if q.ndim == 1:
            q = q[None]
        for _ in range(q.shape[0]):
            if not self._admit(tenant):
                return None
        version = self.index.version
        out: List[Optional[Tuple[np.ndarray, np.ndarray]]] = [None] * q.shape[0]
        misses: List[int] = []
        for i in range(q.shape[0]):
            row = q[i:i + 1]
            hit = (self.cache.lookup(plan, row, version)
                   if self.cache is not None else None)
            if hit is not None:
                out[i] = hit
            else:
                misses.append(i)
        if misses:
            sv, si = run_plan(self.index, plan, q[misses])
            obs.histogram("serving.batch_q",
                          obs.COUNT_BUCKETS).observe(len(misses))
            for j, i in enumerate(misses):
                got = (sv[j:j + 1], si[j:j + 1])
                out[i] = got
                if self.cache is not None:
                    self.cache.store(plan, q[i:i + 1], version, *got)
        return (np.concatenate([o[0] for o in out]),
                np.concatenate([o[1] for o in out]))
