"""Sharded execution path: layout invariants of ``ivf.shard_index``, the
cross-shard merge's equivalence to the single-device scan, and the facade's
transparent routing through the sharded path (stable + delta, tombstones,
predicates) against both the single-layout facade and the brute-force
oracle in ``tests/query_ref.py``.

Multi-device cases run when the process has >= 2 devices (the CI lane sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``); on one device the
real shard_map path still runs with S=1, and multi-shard *layout* semantics
are covered by a host-side shard-loop emulation that needs no mesh.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.configs import get_config
from repro.core import HMGIIndex
from repro.core import ivf as ivf_mod
from repro.core.cost_model import plan_device_layout
from repro.data.synthetic import make_corpus
from repro.sharding.rules import db_shards

from query_ref import assert_matches, reference_execute

N_DEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEV < 2, reason="needs >= 2 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _mesh(n):
    return Mesh(np.array(jax.devices()[:n]).reshape(n), ("data",))


def _corpus_index(rng, n=1200, d=32, k_parts=10):
    v = rng.normal(size=(n, d)).astype(np.float32)
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    idx, _ = ivf_mod.build(jax.random.PRNGKey(0), jnp.asarray(v),
                           jnp.arange(n), n_partitions=k_parts, bits=8)
    q = jnp.asarray(v[:12] + 0.02 * rng.normal(size=(12, d)).astype(np.float32))
    return v, idx, q


class TestShardLayout:
    def test_live_rows_and_partitions_preserved(self, rng):
        """Every live (id, partition, quantized bytes) triple survives the
        re-layout untouched — sharding moves rows, it never re-encodes."""
        _, idx, _ = _corpus_index(rng)
        s = 4
        sh = ivf_mod.shard_index(idx, s)
        k, cap = idx.ids.shape
        single = {}
        for p in range(k):
            for j in range(cap):
                i = int(idx.ids[p, j])
                if i >= 0:
                    single[i] = (p, idx.data[p, j].tobytes(),
                                 float(idx.vmin[p, j]), float(idx.scale[p, j]))
        sharded = {}
        for si in range(s):
            for p in range(k):
                for j in range(sh.ids.shape[2]):
                    i = int(sh.ids[si, p, j])
                    if i >= 0:
                        sharded[i] = (p, sh.data[si, p, j].tobytes(),
                                      float(sh.vmin[si, p, j]),
                                      float(sh.scale[si, p, j]))
        assert sharded == single
        np.testing.assert_array_equal(
            np.asarray(sh.counts).sum(axis=0), np.asarray(idx.counts))

    def test_round_robin_balance(self, rng):
        """Builds pack live rows into low slots, so dealing slots round-robin
        spreads each partition's rows within 1 of evenly across shards."""
        _, idx, _ = _corpus_index(rng)
        sh = ivf_mod.shard_index(idx, 4)
        per_shard = np.asarray(sh.counts)                     # (S, K)
        for p in range(idx.n_partitions):
            col = per_shard[:, p]
            assert col.max() - col.min() <= 1, (p, col)

    def test_centroids_replicated(self, rng):
        _, idx, _ = _corpus_index(rng)
        sh = ivf_mod.shard_index(idx, 3)
        for s in range(3):
            np.testing.assert_array_equal(np.asarray(sh.centroids[s]),
                                          np.asarray(idx.centroids))

    def test_rejects_bad_shard_count(self, rng):
        _, idx, _ = _corpus_index(rng, n=100, k_parts=4)
        with pytest.raises(ValueError):
            ivf_mod.shard_index(idx, 0)


class TestShardedScanEquivalence:
    """The merged sharded scan must carry the single-device scores exactly:
    same probes against the same centroids select the same candidate set,
    split S ways, in the same stored representation."""

    def _emulated(self, idx, sh, q, *, n_probe, k, impl, node_pass=None):
        """Host-side twin of search_sharded's shard_map body (no mesh)."""
        parts = []
        for s in range(sh.ids.shape[0]):
            loc = ivf_mod.IVFIndex(sh.centroids[s], sh.data[s], sh.vmin[s],
                                   sh.scale[s], sh.ids[s], sh.counts[s],
                                   sh.bits)
            parts.append(ivf_mod.search(loc, q, n_probe=n_probe, k=k,
                                        impl=impl, node_pass=node_pass))
        allv = jnp.concatenate([p[0] for p in parts], axis=1)
        alli = jnp.concatenate([p[1] for p in parts], axis=1)
        mv, pos = jax.lax.top_k(allv, k)
        mi = jnp.take_along_axis(alli, pos, axis=1)
        return mv, jnp.where(jnp.isfinite(mv), mi, -1)

    @pytest.mark.parametrize("impl", ["kernel", "einsum"])
    @pytest.mark.parametrize("n_shards", [2, 3, 8])
    def test_emulated_shards_match_single(self, rng, impl, n_shards):
        _, idx, q = _corpus_index(rng)
        sh = ivf_mod.shard_index(idx, n_shards)
        for n_probe in (3, idx.n_partitions):
            se, ie = ivf_mod.search(idx, q, n_probe=n_probe, k=10, impl=impl)
            sv, si = self._emulated(idx, sh, q, n_probe=n_probe, k=10,
                                    impl=impl)
            np.testing.assert_array_equal(np.asarray(sv), np.asarray(se))
            _assert_ids_consistent(sv, si, se, ie)

    def test_emulated_shards_respect_node_pass(self, rng):
        v, idx, q = _corpus_index(rng)
        npass = jnp.asarray(np.random.default_rng(5).random(len(v)) < 0.25)
        sh = ivf_mod.shard_index(idx, 4)
        se, ie = ivf_mod.search(idx, q, n_probe=idx.n_partitions, k=10,
                                node_pass=npass)
        sv, si = self._emulated(idx, sh, q, n_probe=idx.n_partitions, k=10,
                                impl="auto", node_pass=npass)
        np.testing.assert_array_equal(np.asarray(sv), np.asarray(se))
        _assert_ids_consistent(sv, si, se, ie)
        live = np.asarray(si)[np.isfinite(np.asarray(sv))]
        assert np.all(np.asarray(npass)[live])

    @pytest.mark.parametrize("impl", ["kernel", "einsum"])
    def test_shard_map_path_matches_single(self, rng, impl):
        """The real shard_map path, at however many devices we have."""
        _, idx, q = _corpus_index(rng)
        mesh = _mesh(N_DEV)
        sh = ivf_mod.shard_index(idx, N_DEV)
        for n_probe in (3, idx.n_partitions):
            se, ie = ivf_mod.search(idx, q, n_probe=n_probe, k=10, impl=impl)
            sv, si = ivf_mod.search_sharded(sh, q, mesh, n_probe=n_probe,
                                            k=10, impl=impl)
            np.testing.assert_array_equal(np.asarray(sv), np.asarray(se))
            _assert_ids_consistent(sv, si, se, ie)

    @multi_device
    def test_shard_map_masks_and_probes(self, rng):
        v, idx, q = _corpus_index(rng)
        from repro.core.partitioner import assign_topk
        mesh = _mesh(N_DEV)
        sh = ivf_mod.shard_index(idx, N_DEV)
        npass = jnp.asarray(np.random.default_rng(7).random(len(v)) < 0.3)
        probes, _ = assign_topk(q, idx.centroids, 5)
        se, ie = ivf_mod.search(idx, q, n_probe=5, k=10, probes=probes,
                                node_pass=npass)
        sv, si = ivf_mod.search_sharded(sh, q, mesh, n_probe=5, k=10,
                                        probes=probes, node_pass=npass)
        np.testing.assert_array_equal(np.asarray(sv), np.asarray(se))
        _assert_ids_consistent(sv, si, se, ie)

    def test_padding_semantics_tiny_corpus(self, rng):
        """k far beyond the live rows: sharded merge must pad (-inf, -1)
        exactly like the single scan — no shard's pad slot may leak."""
        _, idx, q = _corpus_index(rng, n=40, d=16, k_parts=4)
        sh = ivf_mod.shard_index(idx, 4)
        se, ie = ivf_mod.search(idx, q[:4], n_probe=4, k=64)
        sv, si = self._emulated(idx, sh, q[:4], n_probe=4, k=64, impl="auto")
        np.testing.assert_array_equal(np.asarray(sv), np.asarray(se))
        dead = ~np.isfinite(np.asarray(sv))
        assert np.all(np.asarray(si)[dead] == -1)


def _assert_ids_consistent(sv, si, se, ie):
    """Scores must be identical; ids must agree except where the score ties
    make the order legally ambiguous."""
    sv, si = np.asarray(sv), np.asarray(si)
    se, ie = np.asarray(se), np.asarray(ie)
    for qi in range(sv.shape[0]):
        ref = {}
        for s, i in zip(se[qi], ie[qi]):
            if np.isfinite(s):
                ref.setdefault(float(s), set()).add(int(i))
        for s, i in zip(sv[qi], si[qi]):
            if np.isfinite(s):
                assert int(i) in ref[float(s)], (qi, int(i), float(s))


# ---------------------------------------------------------------------------
# facade: the planner routes search/hybrid_search/query through the sharded
# path transparently, and results stay bit-identical to the single layout
# ---------------------------------------------------------------------------

def _build_facade(corpus, layout, mesh=None):
    cfg = get_config("hmgi").replace(n_partitions=8, n_probe=8, top_k=6,
                                     kmeans_iters=4, delta_capacity=128,
                                     shard_layout=layout)
    idx = HMGIIndex(cfg, mesh=mesh, seed=0)
    idx.ingest({m: (corpus.node_ids[m], corpus.vectors[m])
                for m in corpus.vectors}, n_nodes=corpus.n_nodes,
               edges=(corpus.src, corpus.dst, corpus.edge_type),
               node_attrs={"year": np.arange(corpus.n_nodes) % 7})
    rng = np.random.default_rng(3)
    ids = np.asarray(corpus.node_ids["text"])
    nv = rng.normal(size=(3, 32)).astype(np.float32)
    idx.insert("text", ids[:3], nv)                    # MVCC updates
    idx.delete("text", ids[10:13])                     # tombstones
    return idx


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(n_nodes=700, modality_dims={"text": 32, "image": 48},
                       seed=1)


@multi_device
class TestShardedFacade:
    @pytest.fixture(scope="class")
    def pair(self, corpus):
        return (_build_facade(corpus, "single"),
                _build_facade(corpus, "sharded", _mesh(N_DEV)))

    def test_planner_reports_sharded_layout(self, pair, corpus):
        from repro.query.ast import Q
        _, b = pair
        desc = b.explain(Q.vector("text", corpus.vectors["text"][:2]).topk(3))
        assert f"layout=sharded(x{N_DEV})" in desc

    def test_search_matches_single_layout(self, pair, corpus):
        a, b = pair
        q = corpus.vectors["text"][:10]
        for kw in (dict(), dict(where=("year", "<", 3)), dict(n_probe=2),
                   dict(impl="einsum")):
            sa, ia = a.search(q, "text", k=6, **kw)
            sb, ib = b.search(q, "text", k=6, **kw)
            np.testing.assert_array_equal(np.asarray(sb), np.asarray(sa))
            _assert_ids_consistent(sb, ib, sa, ia)

    def test_hybrid_matches_single_layout(self, pair, corpus):
        a, b = pair
        q = corpus.vectors["text"][:8]
        ha, hia = a.hybrid_search(q, "text", k=6, n_hops=2)
        hb, hib = b.hybrid_search(q, "text", k=6, n_hops=2)
        np.testing.assert_array_equal(np.asarray(hb), np.asarray(ha))
        _assert_ids_consistent(hb, hib, ha, hia)

    def test_query_plan_matches_oracle(self, pair, corpus):
        """Full-probe declarative chains through the sharded path must equal
        the brute-force numpy oracle (stable + delta, tombstones, Where)."""
        from repro.query.ast import Q
        from repro.query.planner import compile_plan
        _, b = pair
        q = corpus.vectors["text"][:6]
        for plan in (Q.vector("text", q, n_probe=8).topk(6),
                     Q.vector("text", q, n_probe=8)
                      .where(("year", "<", 5)).topk(6),
                     Q.vector("text", q, n_probe=8).traverse(1).topk(6)):
            phys = compile_plan(b, plan)
            assert_matches(b.query(plan), reference_execute(b, phys))

    def test_mutation_invalidates_sharded_replica(self, corpus):
        b = _build_facade(corpus, "sharded", _mesh(N_DEV))
        q = corpus.vectors["text"][:4]
        b.search(q, "text", k=4)                        # builds the replica
        assert b.modalities["text"].ivf_sharded is not None
        b.compact("text")
        assert b.modalities["text"].ivf_sharded is None
        a = _build_facade(corpus, "single")
        a.compact("text")
        sa, ia = a.search(q, "text", k=4)
        sb, ib = b.search(q, "text", k=4)
        np.testing.assert_array_equal(np.asarray(sb), np.asarray(sa))
        _assert_ids_consistent(sb, ib, sa, ia)

    def test_rag_engine_retrieves_through_sharded_path(self, pair, corpus):
        """RAGEngine.retrieve -> hybrid_search -> sharded seed scan."""
        from repro.serving.engine import EngineConfig, RAGEngine
        a, b = pair
        eng_b = RAGEngine.__new__(RAGEngine)   # retrieval only: no LM needed
        eng_b.index = b
        eng_b.cfg = EngineConfig(retrieve_k=4, hops=1)
        eng_b.stats = {"retrievals": 0}
        q = corpus.vectors["text"][:3]
        np.testing.assert_array_equal(
            RAGEngine.retrieve(eng_b, q),
            np.asarray(a.hybrid_search(q, "text", k=4, n_hops=1)[1]))


class TestDeviceLayoutPlanning:
    def test_crossover(self):
        small = plan_device_layout(10_000, 64, n_shards=8,
                                   budget_bytes=1 << 30)
        big = plan_device_layout(50_000_000, 128, n_shards=8,
                                 budget_bytes=1 << 30)
        assert small.layout == "single" and small.n_shards == 1
        assert big.layout == "sharded" and big.n_shards == 8

    def test_force_overrides(self):
        assert plan_device_layout(10, 8, n_shards=4, budget_bytes=1 << 30,
                                  force="sharded").layout == "sharded"
        assert plan_device_layout(10 ** 9, 128, n_shards=4, budget_bytes=1,
                                  force="single").layout == "single"
        with pytest.raises(ValueError):
            plan_device_layout(10, 8, n_shards=4, budget_bytes=0, force="bogus")

    def test_one_shard_degenerates_to_single(self):
        assert plan_device_layout(10 ** 9, 128, n_shards=1, budget_bytes=1,
                                  force="sharded").layout == "single"

    def test_facade_single_without_mesh(self, corpus):
        idx = _build_facade(corpus, "sharded", mesh=None)   # no mesh => single
        assert idx.device_layout("text").layout == "single"
        idx.search(corpus.vectors["text"][:2], "text", k=3)
        assert idx.modalities["text"].ivf_sharded is None

    def test_db_shards(self):
        assert db_shards(None) == 1
        assert db_shards(_mesh(N_DEV)) == N_DEV
