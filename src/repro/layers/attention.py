"""GQA/MHA attention with causal + sliding-window masking, blocked softmax,
prefill KV-cache production and single-token decode (flash-decode layout).

Blocking: training/prefill attention is computed per q-block (online softmax
free — each q-block sees the full K prefix, masked), bounding the live score
matrix to (B, H, q_block, S_kv). The q-block loop is a ``lax.scan`` whose
``unroll`` the dry-run sets to the full trip count so cost_analysis counts
every block (see docs/DESIGN.md §6 calibration note).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.params import Builder
from repro.layers.rope import apply_rope
from repro.sharding.rules import with_sharding


def init_gqa(cfg, key):
    b = Builder(key, dtype=jnp.dtype(cfg.dtype))
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    b.dense("wq", (d, hq, hd), ("embed_fsdp", "heads", "head_dim"), fan_in=d)
    b.dense("wk", (d, hkv, hd), ("embed_fsdp", "kv_heads", "head_dim"), fan_in=d)
    b.dense("wv", (d, hkv, hd), ("embed_fsdp", "kv_heads", "head_dim"), fan_in=d)
    b.dense("wo", (hq, hd, d), ("heads", "head_dim", "embed_fsdp"), fan_in=hq * hd)
    if cfg.qkv_bias:
        b.zeros("bq", (hq, hd), ("heads", "head_dim"))
        b.zeros("bk", (hkv, hd), ("kv_heads", "head_dim"))
        b.zeros("bv", (hkv, hd), ("kv_heads", "head_dim"))
    return b.build()


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


def _mask_bias(q_pos, k_pos, window: int, dtype):
    """(qb, kv) additive mask: causal plus optional sliding window."""
    ok = k_pos[None, :] <= q_pos[:, None]
    if window:
        ok = jnp.logical_and(ok, k_pos[None, :] > q_pos[:, None] - window)
    return jnp.where(ok, 0.0, -jnp.inf).astype(dtype)


def attend_full(q, k, v, q_positions, k_positions, *, window: int = 0,
                q_block: int = 0, unroll: bool = False, mesh=None):
    """Blocked masked attention.

    q: (B, Sq, Hq, hd); k/v: (B, Skv, Hkv, hd) — already roped.
    Returns (B, Sq, Hq, hd).
    """
    bsz, sq, hq, hd = q.shape
    hkv = k.shape[2]
    k = _repeat_kv(k, hq // hkv)
    v = _repeat_kv(v, hq // hkv)
    scale = 1.0 / math.sqrt(hd)

    qb = q_block if (q_block and q_block < sq) else sq
    n_blocks = max(sq // qb, 1)
    if sq % qb:
        qb, n_blocks = sq, 1

    def one_block(carry, idx):
        qi = jax.lax.dynamic_slice_in_dim(q, idx * qb, qb, axis=1)
        pi = jax.lax.dynamic_slice_in_dim(q_positions, idx * qb, qb, axis=0)
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, k) * scale
        s = s.astype(jnp.float32) + _mask_bias(pi, k_positions, window, jnp.float32)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v)
        return carry, o

    if n_blocks == 1:
        _, out = one_block(None, jnp.asarray(0))
        return out
    _, outs = jax.lax.scan(one_block, None, jnp.arange(n_blocks),
                           unroll=n_blocks if unroll else 1)
    # (n_blocks, B, qb, H, dv) -> (B, Sq, H, dv)   (dv may differ from hd: MLA)
    return jnp.moveaxis(outs, 0, 1).reshape(bsz, sq, hq, outs.shape[-1])


def attend_decode(q, k_cache, v_cache, valid_mask, mesh=None):
    """Single-token decode vs. a (B, S_cache, Hkv, hd) cache.

    GQA groups are handled with einsum batch dims — NO materialised KV repeat:
    a broadcast+reshape of the seq-sharded cache defeats GSPMD propagation and
    silently all-gathers the entire cache (§Perf iteration log). The cache's
    seq dim stays sharded over "model" (flash-decode split-K); the softmax
    psum over the sharded dim is inserted by GSPMD.
    """
    bsz, one, hq, hd = q.shape
    hkv = k_cache.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(bsz, one, hkv, g, hd)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k_cache) * scale  # (B,Hkv,G,1,S)
    s = s.astype(jnp.float32) + jnp.where(
        valid_mask[:, None, None, None, :], 0.0, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p, v_cache)         # (B,1,Hkv,G,hd)
    return out.reshape(bsz, one, hq, hd)


def gqa_forward(cfg, p, x, positions, *, mode: str, cache=None, cache_pos=None,
                mesh=None, q_block: int = 1024, unroll_blocks: bool = False):
    """One attention sublayer.

    mode "full":    returns (out, (k, v))            — train / prefill
    mode "decode":  returns (out, (k_cache, v_cache)) — x is (B, 1, D)
    """
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if mode == "full":
        q = with_sharding(q, ("batch", "seq_attn", "act_heads", None), mesh)
        out = attend_full(q, k, v, positions[0] if positions.ndim > 1 else positions,
                          positions[0] if positions.ndim > 1 else positions,
                          window=cfg.sliding_window, q_block=q_block,
                          unroll=unroll_blocks, mesh=mesh)
        new_cache = (k, v)
    elif mode == "decode":
        # cache: (B,S,Hkv,hd) x2, slot_pos (B,S); cache_pos (B,) per-sequence
        # positions — each row writes its own slot and masks its own history
        # (continuous batching: ragged prompts put rows at different lengths)
        k_cache, v_cache, slot_pos = cache
        bsz = x.shape[0]
        rows = jnp.arange(bsz)
        slot = cache_pos % k_cache.shape[1]                    # (B,) rolling for SWA
        k_cache = k_cache.at[rows, slot].set(k[:, 0])
        v_cache = v_cache.at[rows, slot].set(v[:, 0])
        slot_pos = slot_pos.at[rows, slot].set(
            cache_pos.astype(slot_pos.dtype))
        k_cache = with_sharding(k_cache, ("batch", "cache_seq", None, None), mesh)
        v_cache = with_sharding(v_cache, ("batch", "cache_seq", None, None), mesh)
        pos_now = cache_pos[:, None]                           # (B, 1)
        valid = jnp.logical_and(slot_pos >= 0, slot_pos <= pos_now)
        if cfg.sliding_window:
            valid = jnp.logical_and(valid, slot_pos > pos_now - cfg.sliding_window)
        out = attend_decode(q, k_cache, v_cache, valid, mesh=mesh)
        new_cache = (k_cache, v_cache, slot_pos)
    else:
        raise ValueError(mode)

    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))
    return out, new_cache
