"""GQA single-token flash-decode Pallas kernel (serving hot loop).

One query token per sequence attends over a long KV cache. The cache is
streamed through VMEM in (block_s) slices; an online-softmax accumulator
(m, l, acc) lives in VMEM scratch and persists across the sequence sweep —
the classic flash-decoding layout, with the GQA head-group handled by a
batched dot_general over the kv-head axis (no materialised KV repeat).

Grid = (B, S // block_s); the S axis is the accumulation axis (sequential on
TPU). Scratch is re-initialised at s==0 and the normalised output is written
at the final s block.

VMEM per step (block_s=512, Hkv=8, G=8, hd=128, fp32): K/V blocks 2·512·8·128
·4 = 4 MB, scores 8·8·512·4 = 128 KB, acc 8·8·128·4 = 256 KB — fits; the
dot_generals are (G×hd)·(hd×block_s) per kv head, MXU-shaped at hd=128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_s: int):
    # q_ref:    (1, Hkv, G, hd)
    # k_ref:    (1, block_s, Hkv, hd)
    # v_ref:    (1, block_s, Hkv, hd)
    # valid_ref:(1, block_s) bool/int8
    # o_ref:    (1, Hkv, G, hd)
    # scratch:  m/l (Hkv, G) fp32;  acc (Hkv, G, hd) fp32
    s = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                          # (Hkv, G, hd)
    k = k_ref[0].astype(jnp.float32)                          # (bs, Hkv, hd)
    v = v_ref[0].astype(jnp.float32)
    ok = valid_ref[0] != 0                                    # (bs,)

    # scores: (Hkv, G, bs) — batch over kv heads, contract hd
    kt = jnp.transpose(k, (1, 0, 2))                          # (Hkv, bs, hd)
    scores = jax.lax.dot_general(
        q, kt, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(ok[None, None, :], scores, -jnp.inf)

    m_prev = m_scr[...]                                       # (Hkv, G)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
    # guard: all -inf so far -> exp(0)=1 on nothing; use safe max
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(scores - m_safe[..., None])
    p = jnp.where(ok[None, None, :], p, 0.0)                  # (Hkv, G, bs)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)

    vt = jnp.transpose(v, (1, 0, 2))                          # (Hkv, bs, hd)
    pv = jax.lax.dot_general(
        p, vt, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)                   # (Hkv, G, hd)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[..., None] + pv
    m_scr[...] = m_new

    @pl.when(s == ns - 1)
    def _fini():
        denom = jnp.maximum(l_scr[...], 1e-20)[..., None]
        o_ref[...] = (acc_scr[...] / denom)[None].astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, valid, *, block_s: int = 512,
                            interpret: bool = False):
    """q (B, Hkv, G, hd); k/v (B, S, Hkv, hd); valid (B, S) -> (B, Hkv, G, hd)."""
    b, hkv, g, hd = q.shape
    s = k.shape[1]
    assert s % block_s == 0, (s, block_s)
    grid = (b, s // block_s)
    return pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / (hd ** 0.5), block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hkv, g, hd), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((1, block_s, hkv, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, block_s, hkv, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, block_s), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, hkv, g, hd), lambda i, j: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        scratch_shapes=[
            _vmem_scratch((hkv, g)),
            _vmem_scratch((hkv, g)),
            _vmem_scratch((hkv, g, hd)),
        ],
        interpret=interpret,
    )(q, k, v, valid.astype(jnp.int8))


def _vmem_scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
