"""HMGI-RAG serving engine: batched retrieval-augmented generation.

The end-to-end serving pipeline the paper targets (§1: "advanced RAG"):
  1. encode the query batch with the LM (mean-pooled hidden state),
  2. HMGI hybrid search (vector + graph fusion) retrieves entity context,
  3. retrieved entity tokens are prepended and the LM generates with
     continuous batching over a shared fixed-shape KV cache.

All device work is jitted fixed-shape: one prefill per admitted request
(spliced into that request's slot of the shared KV cache, including its
per-slot position row), then one batched decode step per engine tick. The
decode step takes a per-slot ``(n_slots,)`` position vector — with ragged
prompts the slots sit at different sequence lengths, and each row writes KV
at its own cache index and attends only to its own history, so a batched
tick produces exactly the tokens sequential per-request decoding would.
The scheduler fills freed slots every tick (iteration-level batching), and
a ``MaintenanceDriver`` (when an index is attached) runs one bounded
adaptive-maintenance step between decode steps — ingest-while-search pays a
small constant tax per tick instead of rare full-compaction stalls.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import HMGIIndex
from repro.models import lm
from repro.serving.cache import HotResultCache
from repro.serving.retrieval import RetrievalPlan, RetrievalService
from repro.serving.scheduler import (AdmissionController, ContinuousBatcher,
                                     MaintenanceDriver, Request)


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 8
    max_seq: int = 256
    retrieve_k: int = 4
    hops: int = 1
    # adaptive index maintenance between decode steps (0 = off): every
    # maintenance_interval-th tick runs index.maintain(budget=...) so
    # ingest-while-search pays bounded work per tick, never a full rebuild
    maintenance_interval: int = 4
    maintenance_budget_rows: int = 256
    # durability pacing (0 = off): every snapshot_interval-th tick writes a
    # versioned snapshot when the index is a DurableHMGIIndex, bounding
    # crash-recovery replay at ~one interval's worth of ops
    snapshot_interval: int = 0
    # retrieval path (repro.serving.retrieval.RetrievalService): micro-batch
    # retrievals through the pow2-bucketed (Q, k) entry, with an optional
    # version-invalidated hot-result cache (0 = no cache)
    retrieval_batching: bool = True
    retrieval_window_s: float = 0.001
    retrieval_max_batch: int = 64
    retrieval_cache_capacity: int = 256


class RAGEngine:
    def __init__(self, lm_cfg, lm_params, index: Optional[HMGIIndex],
                 cfg: EngineConfig = EngineConfig(), mesh=None,
                 admission: Optional[AdmissionController] = None):
        self.lm_cfg = lm_cfg
        self.params = lm_params
        self.index = index
        self.cfg = cfg
        self.mesh = mesh
        self.batcher = ContinuousBatcher(cfg.n_slots, admission=admission)
        self.retrieval = (RetrievalService(
            index, batching=cfg.retrieval_batching,
            window_s=cfg.retrieval_window_s,
            max_batch=cfg.retrieval_max_batch,
            cache=(HotResultCache(cfg.retrieval_cache_capacity)
                   if cfg.retrieval_cache_capacity > 0 else None),
            admission=admission) if index is not None else None)
        opts = lm.ExecOpts(q_block=0, remat=False)
        clen = lm.cache_len_for(lm_cfg, cfg.max_seq)
        self._cache, _ = lm.init_cache(lm_cfg, cfg.n_slots, clen)
        self._opts = opts
        self._decode = jax.jit(
            lambda p, c, t, pos: lm.decode_step(lm_cfg, p, c, t, pos, mesh, opts))
        self._encode = jax.jit(lambda p, toks: self._embed(p, toks))
        self._tokens = np.zeros((cfg.n_slots,), np.int32)
        self.maintenance = (
            MaintenanceDriver(index, cfg.maintenance_budget_rows,
                              cfg.maintenance_interval,
                              snapshot_interval=cfg.snapshot_interval)
            if index is not None and cfg.maintenance_interval > 0 else None)
        self.stats = {"ticks": 0, "tokens": 0, "retrievals": 0,
                      "maintenance_runs": 0}

    # -- query embedding (mean-pooled token embeddings) -----------------------
    def _embed(self, params, tokens):
        # cheap sentence embedding from the embedding table alone — no
        # transformer forward (a full prefill here would be pure wasted
        # compute: its logits were never used)
        emb = jnp.take(params["embed"], tokens, axis=0)
        return jnp.mean(emb, axis=1)

    def embed_queries(self, token_batch: np.ndarray) -> np.ndarray:
        return np.asarray(self._encode(self.params, jnp.asarray(token_batch)))

    # -- retrieval ------------------------------------------------------------
    def retrieve(self, query_vecs: np.ndarray, modality: str = "text",
                 tenant: str = "default"):
        """Hybrid retrieval through the serving path: pow2-bucketed batch
        call + per-row hot-result cache (invalidated by the index version
        stamp). Returns None when admission rejects the tenant."""
        if self.index is None:
            return None
        self.stats["retrievals"] += len(query_vecs)
        service = getattr(self, "retrieval", None)
        if service is None:
            # retrieval-only engines built without __init__ (tests, tools)
            # keep the direct facade path
            scores, ids = self.index.hybrid_search(
                query_vecs, modality, k=self.cfg.retrieve_k,
                n_hops=self.cfg.hops)
            return np.asarray(ids)
        plan = RetrievalPlan(modality=modality, k=self.cfg.retrieve_k,
                             n_hops=self.cfg.hops)
        got = service.search_many(plan, np.asarray(query_vecs),
                                  tenant=tenant)
        if got is None:
            return None
        _scores, ids = got
        return np.asarray(ids)

    # -- generation -----------------------------------------------------------
    def submit(self, rid: int, prompt: np.ndarray, retrieved_ids=None,
               max_new_tokens: int = 16):
        if retrieved_ids is not None:
            # entity ids map into reserved low vocab as context tokens.
            # hybrid_search pads short candidate sets with -1 ("no result"):
            # those must be dropped, not wrapped by the modulo into a real
            # vocab token and prepended as phantom context.
            rids = np.asarray(retrieved_ids).reshape(-1)
            rids = rids[rids >= 0]
            ctx = (rids % max(self.lm_cfg.vocab_size // 4, 1)).astype(np.int32)
            prompt = np.concatenate([ctx, prompt])
        self.batcher.submit(Request(rid, prompt.astype(np.int32),
                                    max_new_tokens))

    def _prefill_slot(self, slot: int, prompt: np.ndarray):
        toks = jnp.asarray(prompt)[None, :]
        opts = self._opts
        with obs.span("serving.prefill") as sp:
            logits, cache = lm.prefill(
                self.lm_cfg, self.params, toks, self.mesh, opts,
                margin=self._cache[0].shape[2] - len(prompt))
            sp.fence(logits)
        # splice this request's cache into the shared slot cache — all
        # leaves, including the (L, 1, clen) slot-position row: decode masks
        # each slot's attention by its own positions
        def splice(shared, one):
            return shared.at[:, slot].set(one[:, 0])
        self._cache = tuple(splice(s, o) for s, o in zip(self._cache, cache))
        # the prefill logits produce this request's first generated token
        # (fed to the first decode step at pos = len(prompt))
        first = int(jnp.argmax(logits[0]))
        self._tokens[slot] = first
        self.batcher.record_prefill_token(slot, first)

    def tick(self) -> List[int]:
        """One engine iteration: admit + prefill new, decode one token for all.

        Decode runs at a per-slot ``(n_slots,)`` position vector — slots hold
        ragged sequences, and a shared scalar position would make lagging
        slots write KV at the wrong cache index and attend beyond their own
        history. Inactive slots decode garbage into their own rows only;
        admission re-prefills the row before reuse."""
        with obs.span("serving.tick"):
            admitted = self.batcher.admit()
            for slot in admitted:
                req = self.batcher.requests[self.batcher.slots[slot].rid]
                self._prefill_slot(slot, req.prompt)
            if self.maintenance is not None:
                # between decode steps: one bounded maintenance step keeps
                # ingest-while-search from ever paying a full compaction
                # stall
                if self.maintenance.tick() is not None:
                    self.stats["maintenance_runs"] += 1
            occupancy = int(np.sum(self.batcher.active_mask()))
            if occupancy == 0:
                return []
            obs.histogram("serving.batch_occupancy",
                          obs.COUNT_BUCKETS).observe(occupancy)
            pos = np.array([s.pos for s in self.batcher.slots], np.int32)
            with obs.span("serving.decode_step") as sp:
                logits, self._cache = self._decode(
                    self.params, self._cache, jnp.asarray(self._tokens),
                    jnp.asarray(pos))
                # argmax forces the step's result to host, so the decode
                # span is honestly fenced without obs_sync_spans
                nxt = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
            self.batcher.record_tokens(nxt)
            self._tokens = nxt
            self.stats["ticks"] += 1
            self.stats["tokens"] += int(np.sum(self.batcher.active_mask()))
            return list(nxt)

    def run_to_completion(self, max_ticks: int = 1000) -> Dict[int, List[int]]:
        t = 0
        while self.batcher.any_active and t < max_ticks:
            self.tick()
            t += 1
        return {rid: r.generated for rid, r in self.batcher.requests.items()}
