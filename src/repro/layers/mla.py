"""Multi-head Latent Attention (DeepSeek-V2), with the decode-time
weight-absorption trick: the cache holds only (latent, roped-k) per token —
(kv_lora + qk_rope) floats/token/layer — and w_uk/w_uv are folded into the
query/output paths, so decode never materialises per-head K/V.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.common.params import Builder
from repro.layers.rope import apply_rope
from repro.layers.attention import attend_full
from repro.sharding.rules import with_sharding


def init_mla(cfg, key):
    b = Builder(key, dtype=jnp.dtype(cfg.dtype))
    d, h = cfg.d_model, cfg.n_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    b.dense("wq", (d, h, dn + dr), ("embed_fsdp", "heads", "head_dim"), fan_in=d)
    b.dense("w_dkv", (d, r), ("embed_fsdp", "kv_lora"), fan_in=d)
    b.dense("w_krope", (d, dr), ("embed_fsdp", "head_dim"), fan_in=d)
    b.dense("w_uk", (r, h, dn), ("kv_lora", "heads", "head_dim"), fan_in=r)
    b.dense("w_uv", (r, h, dv), ("kv_lora", "heads", "head_dim"), fan_in=r)
    b.dense("wo", (h, dv, d), ("heads", "head_dim", "embed_fsdp"), fan_in=h * dv)
    return b.build()


def mla_forward(cfg, p, x, positions, *, mode: str, cache=None, cache_pos=None,
                mesh=None, q_block: int = 1024, unroll_blocks: bool = False):
    dtype = x.dtype
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    scale_dim = dn + dr

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))       # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    latent = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(dtype))  # (B,S,r)
    k_rope = jnp.einsum("bsd,dk->bsk", x, p["w_krope"].astype(dtype))
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    if mode == "full":
        # materialised form (training / prefill)
        k_nope = jnp.einsum("bsr,rhk->bshk", latent, p["w_uk"].astype(dtype))
        v = jnp.einsum("bsr,rhk->bshk", latent, p["w_uv"].astype(dtype))
        h = cfg.n_heads
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (*k_rope.shape[:2], h, dr))],
            axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        # pad v to qk dim so attend_full's hd matches? no — attend_full takes hd from q;
        # v may have different last dim, which attend_full supports via einsum shapes.
        out = attend_full(q_full, k_full, v, positions, positions,
                          q_block=q_block, unroll=unroll_blocks, mesh=mesh)
        new_cache = (latent, k_rope)
    elif mode == "decode":
        # cache: (B,S,r), (B,S,dr), slot_pos (B,S); cache_pos (B,) —
        # per-sequence positions (see layers/attention.py decode branch)
        lat_cache, rope_cache, slot_pos = cache
        bsz = x.shape[0]
        rows = jnp.arange(bsz)
        slot = cache_pos % lat_cache.shape[1]                  # (B,)
        lat_cache = lat_cache.at[rows, slot].set(latent[:, 0])
        rope_cache = rope_cache.at[rows, slot].set(k_rope[:, 0])
        slot_pos = slot_pos.at[rows, slot].set(cache_pos.astype(slot_pos.dtype))
        lat_cache = with_sharding(lat_cache, ("batch", "cache_seq", None), mesh)
        rope_cache = with_sharding(rope_cache, ("batch", "cache_seq", None), mesh)
        # absorbed scores: q_nope W_uk · latent  +  q_rope · k_rope
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"].astype(dtype))
        s = (jnp.einsum("bshr,btr->bhst", q_lat, lat_cache)
             + jnp.einsum("bshk,btk->bhst", q_rope, rope_cache))
        s = s.astype(jnp.float32) / math.sqrt(scale_dim)
        valid = jnp.logical_and(slot_pos >= 0,
                                slot_pos <= cache_pos[:, None])  # (B, S)
        s = s + jnp.where(valid[:, None, None, :], 0.0, -jnp.inf)
        pr = jax.nn.softmax(s, axis=-1).astype(dtype)
        o_lat = jnp.einsum("bhst,btr->bshr", pr, lat_cache)    # (B,1,H,r)
        out = jnp.einsum("bshr,rhk->bshk", o_lat, p["w_uv"].astype(dtype))
        new_cache = (lat_cache, rope_cache, slot_pos)
    else:
        raise ValueError(mode)

    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))
    return out, new_cache
