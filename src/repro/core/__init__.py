# The paper's primary contribution: the Hybrid Multimodal Graph Index.
from repro.core.index import HMGIIndex, ModalityIndex
