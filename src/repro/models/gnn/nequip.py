"""NequIP — E(3)-equivariant interatomic potential (Batzner et al.,
arXiv:2101.03164): messages are Clebsch–Gordan tensor products of neighbour
features with edge spherical harmonics, radially gated by learned R(r)
weights — the irrep-tensor-product kernel regime.

Feature layout: per-l blocks with equal multiplicity C = cfg.d_hidden, flat
(N, C, Σ_l (2l+1)); block l occupies columns [l², (l+1)²).
"""
from __future__ import annotations

import math
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.params import Builder
from repro.equivariant.bessel import envelope
from repro.equivariant.cg import clebsch_gordan
from repro.equivariant.spherical import real_sph_harm, sh_dim
from repro.sparse import segment as seg


def _paths(l_max: int) -> List[Tuple[int, int, int]]:
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max) + 1):
                out.append((l1, l2, l3))
    return out


def _slice(l: int) -> slice:
    return slice(l * l, (l + 1) * (l + 1))


def init(cfg, key, d_feat_in: int, n_out: int):
    c, lm = cfg.d_hidden, cfg.l_max
    dim = sh_dim(lm)
    b = Builder(key, dtype=jnp.float32)
    b.dense("enc", (d_feat_in, c), (None, "hidden"), fan_in=d_feat_in)
    paths = _paths(lm)
    layers = []
    for _ in range(cfg.n_layers):
        lb = b.sub()
        # radial MLP -> per-path per-channel weights
        lb.dense("r_w0", (cfg.n_rbf, 32), (None, None), fan_in=cfg.n_rbf)
        lb.zeros("r_b0", (32,), (None,))
        lb.dense("r_w1", (32, len(paths) * c), (None, None), fan_in=32)
        # per-l self-interaction (channel mixing) + skip
        for l in range(lm + 1):
            lb.dense(f"self_l{l}", (c, c), (None, "hidden"), fan_in=c)
            lb.dense(f"skip_l{l}", (c, c), (None, "hidden"), fan_in=c)
        # gate scalars for l>0 blocks
        lb.dense("gate", (c, lm * c), (None, None), fan_in=c)
        layers.append(lb.build())
    b.params["layers"] = [p for p, _ in layers]
    b.axes["layers"] = [a for _, a in layers]
    b.dense("head", (c, n_out), (None, None), fan_in=c)
    return b.build()


def _rbf(dist, n_rbf: int, cutoff: float):
    mu = jnp.linspace(0.0, cutoff, n_rbf)
    beta = (n_rbf / cutoff) ** 2
    return jnp.exp(-beta * (dist[..., None] - mu) ** 2) * envelope(dist, cutoff)[..., None]


def apply(cfg, params, feats, positions, node_mask, ex):
    """Returns invariant node scalars (N, C) after cfg.n_layers interactions."""
    c, lm = cfg.d_hidden, cfg.l_max
    dim = sh_dim(lm)
    n = feats.shape[0]
    paths = _paths(lm)
    cg = {p: jnp.asarray(clebsch_gordan(p[0], p[1], p[2]), jnp.float32)
          for p in paths}

    h = jnp.zeros((n, c, dim))
    h = h.at[:, :, 0].set(feats @ params["enc"])            # scalar init

    for lp in params["layers"]:
        payload = jnp.concatenate([h.reshape(n, c * dim), positions], axis=-1)

        def msg_fn(srcs, dsts, lp=lp):
            e = srcs.shape[0]
            h_src = srcs[:, : c * dim].reshape(e, c, dim)
            x_src = srcs[:, c * dim:]
            x_dst = dsts[:, c * dim:]
            rel = x_dst - x_src
            dist = jnp.linalg.norm(rel, axis=-1)
            sh = real_sph_harm(rel, lm)                      # (E, dim)
            rbf = _rbf(dist, cfg.n_rbf, cfg.cutoff)          # (E, n_rbf)
            rw = jax.nn.silu(rbf @ lp["r_w0"] + lp["r_b0"]) @ lp["r_w1"]
            rw = rw.reshape(e, len(paths), c)
            out = jnp.zeros((e, c, dim))
            for pi, (l1, l2, l3) in enumerate(paths):
                t = jnp.einsum("mab,eca,eb->ecm", cg[(l1, l2, l3)],
                               h_src[:, :, _slice(l1)], sh[:, _slice(l2)])
                out = out.at[:, :, _slice(l3)].add(t * rw[:, pi, :, None])
            out = out / math.sqrt(len(paths))
            # zero-length edges (self-loops / padding) carry no direction:
            # masking them preserves exact equivariance
            live = (dist > 1e-6).astype(out.dtype)[:, None]
            ones = jnp.ones((e, 1), out.dtype)                 # degree counter
            return jnp.concatenate([out.reshape(e, c * dim), ones], axis=-1) * live

        agg_c = ex.push(payload, msg_fn, c * dim + 1)
        deg = jnp.maximum(agg_c[:, -1:], 1.0)                  # (N, 1)
        agg = (agg_c[:, :-1] / jnp.sqrt(deg)).reshape(n, c, dim)

        # self-interaction + gated nonlinearity, per l
        gates = jax.nn.sigmoid(h[:, :, 0] @ lp["gate"]).reshape(n, lm, c)
        new = jnp.zeros_like(h)
        for l in range(lm + 1):
            sl = _slice(l)
            mixed = jnp.einsum("ncm,cd->ndm", agg[:, :, sl], lp[f"self_l{l}"])
            skip = jnp.einsum("ncm,cd->ndm", h[:, :, sl], lp[f"skip_l{l}"])
            blk = mixed + skip
            if l == 0:
                blk = jax.nn.silu(blk)
            else:
                blk = blk * gates[:, l - 1][:, :, None]
            new = new.at[:, :, sl].set(blk)
        h = new * node_mask[:, None, None]
    return h[:, :, 0]                                        # invariant scalars


def node_logits(cfg, params, feats, positions, node_mask, ex):
    return apply(cfg, params, feats, positions, node_mask, ex) @ params["head"]
