"""Adaptive index maintenance (docs/DESIGN.md §3.4) — the paper's
"adaptive, low-overhead index updates" pillar.

Three layers, all host-side orchestration over jitted primitives:

- ``stats.PartitionStats`` — per-partition statistics tracked incrementally
  at write time (heat via the workload tracker, delta pressure, tombstone
  ratio, centroid drift vs. the build-time baseline);
- ``cost_model.plan_maintenance`` (in ``repro.core.cost_model``) — the
  cost-driven policy choosing among split-hot / merge-cold / recluster /
  incremental-compact / no-op, greedily by estimated query-time benefit per
  row of bounded work;
- ``executor`` — applies each action as in-place slot surgery (byte-identical
  row moves, fixed-size delta drains) instead of a stop-the-world rebuild.

The facade entry point is ``HMGIIndex.maintain(budget=...)``; ``insert`` /
``delete`` auto-trigger it (cfg.maint_auto), and the serving layer paces it
between decode steps (``serving.scheduler.MaintenanceDriver``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.core.cost_model import (MaintenanceAction, MaintenanceSummary,
                                   plan_maintenance)
from repro.maintenance.stats import PartitionStats

__all__ = ["MaintenanceAction", "MaintenanceSummary", "MaintenanceReport",
           "PartitionStats", "plan_maintenance"]


@dataclasses.dataclass
class MaintenanceReport:
    """What one ``HMGIIndex.maintain`` call planned and applied.

    ``actions`` pairs each planned ``MaintenanceAction`` with the executor's
    result dict (``note`` plus per-action counters). ``describe()`` renders
    the applied sequence in the same one-line style as
    ``PhysicalPlan.describe()`` — it is also what ``HMGIIndex`` surfaces in
    its metrics under ``"maintenance"``."""
    modality: str
    actions: List[Tuple[MaintenanceAction, Dict]] = \
        dataclasses.field(default_factory=list)

    @property
    def is_noop(self) -> bool:
        return not self.actions

    def describe(self) -> str:
        if not self.actions:
            return f"{self.modality}: noop"
        steps = " -> ".join(f"{a.kind}[{r.get('note', '')}]"
                            for a, r in self.actions)
        return f"{self.modality}: {steps}"
