"""Train a small LM text encoder for a few hundred steps with the
fault-tolerant trainer (checkpoint/restart + straggler monitoring), then
ingest its embeddings into HMGI.

    PYTHONPATH=src python examples/train_encoder.py [--steps 200]
"""
import argparse
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.core import HMGIIndex
from repro.data.pipeline import SyntheticLMStream
from repro.models import lm
from repro.train.optimizer import AdamWConfig, init_adamw
from repro.train.trainer import Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
args = ap.parse_args()

cfg = smoke_config("qwen2-72b").replace(d_model=128, n_layers=2, d_ff=256,
                                        vocab_size=2048)
params, _ = lm.init_lm(cfg, jax.random.PRNGKey(0))
opt = init_adamw(params)
opts = lm.ExecOpts(q_block=0, remat=False)
step_fn = jax.jit(lm.make_train_step(
    cfg, None, opts, AdamWConfig(lr=3e-3, warmup_steps=20,
                                 total_steps=args.steps)))
stream = SyntheticLMStream(cfg.vocab_size, batch=8, seq_len=32, seed=0)

with tempfile.TemporaryDirectory() as ckpt_dir:
    tc = TrainerConfig(total_steps=args.steps, checkpoint_every=50,
                       checkpoint_dir=ckpt_dir, log_every=25)
    trainer = Trainer(tc, step_fn, stream,
                      params, opt,
                      lambda b: {k: jnp.asarray(v) for k, v in b.items()})
    out = trainer.run()

first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps "
      f"({'improved' if last < first else 'NO IMPROVEMENT'})")

# use the trained embedding table as a text encoder for HMGI ingestion
docs = np.random.default_rng(1).integers(0, cfg.vocab_size, (500, 16))
emb = np.asarray(jnp.take(trainer.params["embed"], jnp.asarray(docs),
                          axis=0).mean(axis=1), np.float32)
index = HMGIIndex(get_config("hmgi").replace(n_partitions=8, n_probe=4), seed=0)
index.ingest({"text": (np.arange(500), emb)}, n_nodes=500,
             edges=(np.array([0, 1]), np.array([1, 2])))
_, ids = index.search(emb[:4], "text", k=1)
print(f"self-retrieval after ingest: "
      f"{(np.asarray(ids)[:, 0] == np.arange(4)).mean()*100:.0f}% top-1")
