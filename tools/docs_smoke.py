"""Docs smoke: extract every ```python fence from README.md and docs/*.md
and execute them, in document order, in one shared namespace seeded with
the identifiers the snippets assume (a built index, queries, attribute
columns, ...). API drift in a documented snippet then fails CI instead of
silently rotting.

    PYTHONPATH=src python tools/docs_smoke.py
"""
from __future__ import annotations

import pathlib
import re
import sys

import numpy as np

ROOT = pathlib.Path(__file__).resolve().parent.parent
FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _unit(v):
    return v / np.linalg.norm(v, axis=-1, keepdims=True)


def build_namespace():
    """The documented snippets' world: a built two-modality index with a
    typed graph and attribute columns, a fresh un-ingested index (the
    attribute section's ingest snippet builds it), queries, and a write
    batch for the maintenance section."""
    from repro.configs import get_config
    from repro.core import HMGIIndex

    rng = np.random.default_rng(0)
    n, dt, di = 300, 32, 24
    vt = _unit(rng.normal(size=(n, dt)).astype(np.float32))
    vi = _unit(rng.normal(size=(n, di)).astype(np.float32))
    ids = np.arange(n, dtype=np.int32)
    year = rng.integers(2000, 2030, n).astype(np.int32)
    cat = rng.integers(0, 6, n).astype(np.int32)
    price = rng.integers(1, 200, n).astype(np.int32)
    e = 1200
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    et = rng.integers(0, 3, len(src)).astype(np.int32)

    cfg = get_config("hmgi").replace(n_partitions=8, n_probe=8, top_k=10,
                                     kmeans_iters=4, delta_capacity=128)
    index = HMGIIndex(cfg, seed=0)
    index.ingest({"text": (ids, vt), "image": (ids, vi)}, n_nodes=n,
                 edges=(src, dst, et),
                 node_attrs={"year": year, "category": cat})

    q = (vt[:5] + 0.05 * rng.normal(size=(5, dt))).astype(np.float32)
    qi = (vi[:5] + 0.05 * rng.normal(size=(5, di))).astype(np.float32)
    return {
        "np": np, "index": index, "q": q, "qi": qi,
        "q1": q, "q2": (vt[5:10] + 0.05 * rng.normal(size=(5, dt))
                        ).astype(np.float32),
        "AUTHORED": 1,
        # the attribute section's snippet ingests this one itself
        "idx": HMGIIndex(cfg, seed=1),
        "embeddings": {"text": (ids, vt), "image": (ids, vi)},
        "n_nodes": n, "edges": (src, dst, et), "cat": cat, "price": price,
        # the maintenance section's write batch — large enough to cross the
        # delta-pressure threshold, so the snippet's auto-drain is real
        "wid": np.arange(200, 280, dtype=np.int32),
        "wvecs": rng.normal(size=(80, dt)).astype(np.float32),
    }


def main() -> int:
    docs = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
    ns = build_namespace()
    failures = 0
    for doc in docs:
        rel = doc.relative_to(ROOT)
        text = doc.read_text()
        for i, m in enumerate(FENCE.finditer(text)):
            snippet = m.group(1)
            # line of the fence body inside the md file; padding the
            # snippet with blank lines makes every traceback lineno a real
            # line number in the document
            fence_line = text.count("\n", 0, m.start(1)) + 1
            label = f"{rel}#fence{i}"
            padded = "\n" * (fence_line - 1) + snippet
            try:
                exec(compile(padded, str(rel), "exec"), ns)  # noqa: S102
                print(f"ok   {label} ({rel}:{fence_line})")
            except Exception as exc:                        # noqa: BLE001
                failures += 1
                line = fence_line
                tb = exc.__traceback__
                while tb is not None:
                    if tb.tb_frame.f_code.co_filename == str(rel):
                        line = tb.tb_lineno
                    tb = tb.tb_next
                if isinstance(exc, SyntaxError) and exc.filename == str(rel):
                    line = exc.lineno or fence_line
                print(f"FAIL {label} at {rel}:{line}: "
                      f"{type(exc).__name__}: {exc}")
    print(f"# docs-smoke: {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
