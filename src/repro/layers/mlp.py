"""SwiGLU feed-forward (LLaMA convention: w1=gate, w3=up, w2=down)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.params import Builder


def init_swiglu(cfg, key, d_ff: int | None = None):
    b = Builder(key, dtype=jnp.dtype(cfg.dtype))
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    b.dense("w1", (d, f), ("embed_fsdp", "mlp"), fan_in=d)
    b.dense("w3", (d, f), ("embed_fsdp", "mlp"), fan_in=d)
    b.dense("w2", (f, d), ("mlp", "embed_fsdp"), fan_in=f)
    return b.build()


def swiglu(p, x):
    dtype = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, p["w1"].astype(dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w3"].astype(dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["w2"].astype(dtype))
