"""Partitioned ANNS — the TPU-native realisation of the paper's search layer.

Two-level search (docs/DESIGN.md §2.1): centroid scoring (small matmul) selects
``n_probe`` partitions per query; probed partitions are scored over their
*quantized* rows; exact top-k over the probed candidates. Cost ∝
n_probe·N/K + K instead of N — the paper's sub-linear claim, with every FLOP
on the MXU.

Storage is fixed-shape: (K, cap, d) quantized buckets + (K, cap) ids with -1
sentinels, so search jits once per (K, cap, n_probe, k) and shards cleanly.

Slab layout & the fused kernel. ``IVFIndex.slab_view`` exposes the buckets as
one flattened (K·cap, d) int8 slab with per-row vmin/scale and -1 ids on
empty slots; partition ``p`` is the contiguous row block
[p·cap, (p+1)·cap). The probe path gathers each query's probed blocks
(int8 — never dequantized in HBM) and hands them to the fused Pallas kernel
(``kernels/ivf_topk``), which folds the affine dequant into the scan matmul
and reduces to per-chunk survivors; an exact rescore of the top-k chunks
recovers the exact top-k. ``impl`` selects the path: "kernel" (int8 indexes),
"einsum" (the legacy fp32 dequant-then-einsum, kept for 4/16-bit storage and
as the benchmark baseline), or "auto" (kernel whenever bits == 8). Off-TPU
the kernel runs under ``interpret=True``, probed once on the first kernel
call (see ``kernels/ivf_topk/ops._interpret_mode``).

Sharded execution path. ``shard_index`` re-lays the stable slab out as S
per-shard replicas with a leading shard dim: partition ``p``'s capacity slots
are dealt round-robin across shards (slot j -> shard j % S, local slot
j // S), the quantized rows move untouched (same int8 bytes, same per-row
vmin/scale), and the centroids are replicated. Every shard therefore holds
the same K partitions over a 1/S row slice, so a query's probe list —
scored against identical centroids — selects exactly the single-device
candidate set, split S ways. ``search_sharded`` runs the per-shard scan
(kernel or einsum, with the same validity ∧ predicate mask pushdown as
``search``) under ``shard_map`` over the ("pod","data") mesh axes, then
all-gathers the S local top-k lists and merges — bit-identical scores to the
single-device scan at any ``n_probe`` (k ≪ N ⇒ collective-light; ids may
permute only where scores tie exactly).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:                                       # newer jax spells it jax.shard_map
    _shard_map = jax.shard_map
except AttributeError:                     # 0.4.x: jax.experimental
    from jax.experimental.shard_map import shard_map as _shard_map
# the replication-check kwarg was renamed check_rep -> check_vma on a
# different version boundary than the alias promotion: probe the signature
import inspect as _inspect
_SHARD_MAP_KW = (
    {"check_vma": False}
    if "check_vma" in _inspect.signature(_shard_map).parameters
    else {"check_rep": False})

from repro.common.shapes import pad_to_chunk
from repro.core import partitioner
from repro.core.graph_store import mask_pass
from repro.core.quantization import QuantizedVectors, quantize
from repro.kernels.ivf_topk.ops import (_interpret_mode,
                                        scan_topk_quantized_batched)
from repro.kernels.ivf_topk.ref import pad_topk

# probe-path kernel tiling: chunk-of-16 survivors, 512-row blocks (see
# kernels/ivf_topk/ivf_topk.py for the VMEM accounting)
_CHUNK = 16
_BLOCK_N = 512


def _probe_block_n(m: int, qb: int, d: int) -> int:
    """Row-block size for the probe scan. On TPU the tile keeps the per-step
    data block — int8 plus its in-register fp32 cast, 5 bytes/element over
    (qb, bn, d) — near 8 MB of VMEM, so the (qb, P, cap, d) fp32 intermediate
    the einsum path writes to HBM never exists. Under the interpreter each
    grid step costs fixed overhead and padding to a block multiple is pure
    waste (P·cap is rarely block-aligned), so the whole per-query slab runs
    as one step, padded only to the chunk size."""
    if _interpret_mode():
        return pad_to_chunk(m, _CHUNK)
    budget = 8 * 1024 * 1024
    bn = budget // (5 * max(qb, 1) * max(d, 1))
    return max(_CHUNK, min(_BLOCK_N, (bn // _CHUNK) * _CHUNK))


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["centroids", "data", "vmin", "scale", "ids", "counts"],
    meta_fields=["bits"],
)
@dataclasses.dataclass
class IVFIndex:
    centroids: jax.Array     # (K, d) fp32
    data: jax.Array          # (K, cap, d) int8 | (K, cap, d//2) int4-packed | bf16
    vmin: jax.Array          # (K, cap) fp32
    scale: jax.Array         # (K, cap) fp32
    ids: jax.Array           # (K, cap) int32, -1 = empty slot
    counts: jax.Array        # (K,) int32
    bits: int = 8

    @property
    def n_partitions(self) -> int:
        return self.centroids.shape[0]

    @property
    def capacity(self) -> int:
        return self.ids.shape[1]

    @property
    def nbytes(self) -> int:
        return sum(int(a.size) * a.dtype.itemsize
                   for a in (self.centroids, self.data, self.vmin, self.scale, self.ids))

    def slab_view(self):
        """Flattened row-major view: (K·cap, d') data, (K·cap,) vmin/scale/ids.

        Partition p occupies the contiguous rows [p·cap, (p+1)·cap), so a
        probe list maps to row blocks the fused kernel consumes directly.
        Reshape-only — no copy, no dequantization."""
        k, cap = self.ids.shape
        return (self.data.reshape(k * cap, -1), self.vmin.reshape(-1),
                self.scale.reshape(-1), self.ids.reshape(-1))

    def _replace(self, **kw) -> "IVFIndex":
        return dataclasses.replace(self, **kw)


def build(key, vectors: jax.Array, ids: jax.Array, *, n_partitions: int,
          capacity: Optional[int] = None, bits: int = 8, kmeans_iters: int = 16,
          centroids: Optional[jax.Array] = None) -> Tuple[IVFIndex, jax.Array]:
    """Builds an IVF index. Returns (index, overflow_mask) — True rows did not
    fit their partition's capacity and belong in the delta store."""
    n, d = vectors.shape
    k = n_partitions
    cap = capacity or max(int(2 * n / k) + 1, 8)
    if centroids is None:
        st = partitioner.fit(key, vectors, k, kmeans_iters)
        centroids = st.centroids
    a = partitioner.assign(vectors, centroids)                    # (N,)

    onehot = jax.nn.one_hot(a, k, dtype=jnp.int32)                # (N, K)
    pos = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, axis=-1)
    keep = pos < cap
    slot = jnp.where(keep, a * cap + pos, k * cap)

    qv = quantize(vectors, bits)
    dstore = jnp.zeros((k * cap + 1,) + qv.data.shape[1:], qv.data.dtype)
    dstore = dstore.at[slot].set(jnp.where(keep[:, None], qv.data, 0))
    vmin = jnp.zeros((k * cap + 1,), jnp.float32).at[slot].set(qv.vmin[:, 0])
    scale = jnp.ones((k * cap + 1,), jnp.float32).at[slot].set(qv.scale[:, 0])
    id_store = jnp.full((k * cap + 1,), -1, jnp.int32)
    id_store = id_store.at[slot].set(jnp.where(keep, ids.astype(jnp.int32), -1))
    counts = jax.ops.segment_sum(keep.astype(jnp.int32), a, num_segments=k)

    idx = IVFIndex(
        centroids=centroids,
        data=dstore[:-1].reshape(k, cap, -1),
        vmin=vmin[:-1].reshape(k, cap),
        scale=scale[:-1].reshape(k, cap),
        ids=id_store[:-1].reshape(k, cap),
        counts=counts,
        bits=bits,
    )
    return idx, ~keep


# ---------------------------------------------------------------------------
# slot-level slab surgery (the maintenance executor's primitives)
# ---------------------------------------------------------------------------
# Maintenance actions (incremental compaction, merge-cold, split-hot — see
# repro/maintenance/executor.py) rewrite bounded sets of slab slots in place
# instead of rebuilding the (K, cap, d) store. Rows always move as their
# stored bytes: identical int8 data + per-row vmin/scale ⇒ identical
# dequantized scores, exactly like ``shard_index``'s re-layout. ``rows`` are
# flat slab indices (partition p's slots are [p·cap, (p+1)·cap), matching
# ``slab_view``). Host-side orchestration — dynamic shapes are fine here.

def set_slots(index: IVFIndex, rows, data, vmin, scale, ids) -> IVFIndex:
    """Writes quantized rows (byte-identical) into the given flat slab slots
    and refreshes the per-partition counts."""
    k, cap = index.ids.shape
    rows = jnp.asarray(rows, jnp.int32)
    flat_ids = index.ids.reshape(-1).at[rows].set(jnp.asarray(ids, jnp.int32))
    return index._replace(
        data=index.data.reshape(k * cap, -1).at[rows].set(data)
            .reshape(index.data.shape),
        vmin=index.vmin.reshape(-1).at[rows].set(vmin).reshape(k, cap),
        scale=index.scale.reshape(-1).at[rows].set(scale).reshape(k, cap),
        ids=flat_ids.reshape(k, cap),
        counts=jnp.sum(flat_ids.reshape(k, cap) >= 0, axis=1,
                       dtype=jnp.int32))


def clear_slots(index: IVFIndex, rows) -> IVFIndex:
    """Empties the given flat slab slots (-1 id, zero data, unit scale)."""
    rows = jnp.asarray(rows, jnp.int32)
    n = rows.shape[0]
    return set_slots(
        index, rows,
        jnp.zeros((n,) + index.data.shape[2:], index.data.dtype),
        jnp.zeros((n,), jnp.float32), jnp.ones((n,), jnp.float32),
        jnp.full((n,), -1, jnp.int32))


def gather_slots(index: IVFIndex, rows):
    """(data, vmin, scale, ids) of the given flat slab slots — the stored
    bytes, ready to be ``set_slots`` elsewhere byte-identically."""
    data, vmin, scale, ids = index.slab_view()
    rows = jnp.asarray(rows, jnp.int32)
    return data[rows], vmin[rows], scale[rows], ids[rows]


def _dequant_rows(index: IVFIndex, rows_data, rows_vmin, rows_scale):
    """rows_data: (..., d') quantized — returns (..., d) fp32."""
    if index.bits == 16:
        return rows_data.astype(jnp.float32)
    if index.bits == 8:
        q = rows_data.astype(jnp.float32) + 128.0
    else:  # 4-bit packed
        u = rows_data.astype(jnp.uint8)
        lo = (u & 0xF).astype(jnp.float32)
        hi = (u >> 4).astype(jnp.float32)
        q = jnp.stack([lo, hi], axis=-1).reshape(*u.shape[:-1], -1)
    return q * rows_scale[..., None] + rows_vmin[..., None]


def _resolve_impl(index: IVFIndex, impl: str) -> str:
    if impl == "auto":
        return "kernel" if index.bits == 8 else "einsum"
    if impl == "kernel" and index.bits != 8:
        raise ValueError(f"kernel probe path needs int8 storage, bits={index.bits}")
    return impl


@functools.partial(jax.jit, static_argnames=("n_probe", "k", "query_block", "impl"))
def search(index: IVFIndex, queries: jax.Array, *, n_probe: int, k: int,
           query_block: int = 64, impl: str = "auto",
           probes: Optional[jax.Array] = None,
           node_pass: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Returns (scores (Q, k), ids (Q, k)) — dot-product similarity, descending.

    impl="kernel" (default for int8) scans the probed slab blocks with the
    fused Pallas kernel: int8 rows all the way into the scoring matmul, no
    (qb, P, cap, d) fp32 dequant ever materialised in HBM. impl="einsum" is
    the legacy gather-dequant-einsum path (4/16-bit storage, baseline).

    probes: optional precomputed (Q, n_probe) partition assignment (the
    facade records workload stats from the same ``assign_topk`` — passing it
    here scores centroids once per query batch instead of twice).

    node_pass: optional (max_id+1,) bool predicate mask over global node
    ids — predicate *pushdown*: excluded rows are folded into the scan's
    validity mask (kernel bias / einsum -inf) before the top-k, so the k
    results all satisfy the predicate with no post-filter recall loss."""
    impl = _resolve_impl(index, impl)
    q = queries.astype(jnp.float32)
    nq = q.shape[0]
    n_probe = min(n_probe, index.n_partitions)
    if probes is None:
        probe, _ = partitioner.assign_topk(q, index.centroids, n_probe)  # (Q, P)
    else:
        probe = probes[:, :n_probe].astype(jnp.int32)
    cap = index.capacity

    qb = min(query_block, nq)
    pad = (-nq) % qb
    qp = jnp.pad(q, ((0, pad), (0, 0)))
    pp = jnp.pad(probe, ((0, pad), (0, 0)))
    nblocks = qp.shape[0] // qb
    slab_data, slab_vmin, slab_scale, slab_ids = index.slab_view()

    def _row_valid(bids):
        """Slot occupancy ∧ predicate pushdown (pre-top-k filtering)."""
        if node_pass is not None:
            return mask_pass(node_pass, bids)
        return bids >= 0

    def block_kernel(carry, i):
        qs = jax.lax.dynamic_slice_in_dim(qp, i * qb, qb, axis=0)      # (qb, d)
        ps = jax.lax.dynamic_slice_in_dim(pp, i * qb, qb, axis=0)      # (qb, P)
        # probed partitions = contiguous row blocks of the flat slab
        rows = (ps[:, :, None] * cap
                + jnp.arange(cap, dtype=jnp.int32)[None, None, :])
        rows = rows.reshape(qb, -1)                                     # (qb, M)
        bdata = slab_data[rows]                                         # int8!
        bmin = slab_vmin[rows]
        bscale = slab_scale[rows]
        bids = slab_ids[rows]                                           # (qb, M)
        vals, pos = scan_topk_quantized_batched(
            qs, bdata, bmin, bscale, _row_valid(bids), k=k,
            chunk=_CHUNK, block_n=_probe_block_n(rows.shape[1], qb,
                                                 qs.shape[1]))
        ids = jnp.where(pos >= 0,
                        jnp.take_along_axis(
                            bids, jnp.clip(pos, 0, rows.shape[1] - 1), axis=1),
                        -1)
        return carry, (vals, ids)

    def block_einsum(carry, i):
        qs = jax.lax.dynamic_slice_in_dim(qp, i * qb, qb, axis=0)      # (qb, d)
        ps = jax.lax.dynamic_slice_in_dim(pp, i * qb, qb, axis=0)      # (qb, P)
        bdata = index.data[ps]                                          # (qb,P,cap,d')
        bmin = index.vmin[ps]
        bscale = index.scale[ps]
        bids = index.ids[ps]                                            # (qb,P,cap)
        vecs = _dequant_rows(index, bdata, bmin, bscale)                # (qb,P,cap,d)
        scores = jnp.einsum("qd,qpcd->qpc", qs, vecs)
        scores = jnp.where(_row_valid(bids), scores, -jnp.inf)
        flat = scores.reshape(qb, -1)
        fids = bids.reshape(qb, -1)
        vals, pos = jax.lax.top_k(flat, min(k, flat.shape[1]))
        ids = jnp.where(jnp.isfinite(vals),
                        jnp.take_along_axis(fids, pos, axis=1), -1)
        return carry, pad_topk(vals, ids, k)

    block = block_kernel if impl == "kernel" else block_einsum
    _, (vals, ids) = jax.lax.scan(block, None, jnp.arange(nblocks))
    return vals.reshape(-1, k)[:nq], ids.reshape(-1, k)[:nq]


@functools.partial(jax.jit, static_argnames=("k",))
def brute_force(vectors: jax.Array, valid: jax.Array, ids: jax.Array,
                queries: jax.Array, *, k: int):
    """Monolithic-baseline / delta-store scoring: exact matmul + top-k."""
    scores = queries.astype(jnp.float32) @ vectors.astype(jnp.float32).T
    scores = jnp.where(valid[None, :], scores, -jnp.inf)
    vals, pos = jax.lax.top_k(scores, min(k, vectors.shape[0]))
    return vals, ids[pos]


def merge_topk(scores_a, ids_a, scores_b, ids_b, k: int):
    """Exact merge of two descending top-k lists (associative — distributed
    tournament merges use this pairwise). Assumes disjoint id sets."""
    s = jnp.concatenate([scores_a, scores_b], axis=-1)
    i = jnp.concatenate([ids_a, ids_b], axis=-1)
    vals, pos = jax.lax.top_k(s, k)
    return vals, jnp.take_along_axis(i, pos, axis=-1)


def dedup_merge_topk(scores_a, ids_a, scores_b, ids_b, k: int):
    """Merge of possibly-overlapping top-k lists: keeps one entry per id
    (progressive rounds re-probe earlier partitions)."""
    s = jnp.concatenate([scores_a, scores_b], axis=-1)
    i = jnp.concatenate([ids_a, ids_b], axis=-1)
    order = jnp.argsort(-s, axis=-1)
    s = jnp.take_along_axis(s, order, axis=-1)
    i = jnp.take_along_axis(i, order, axis=-1)
    # mask entries whose id appeared at any earlier (higher-score) position
    dup = (i[..., :, None] == i[..., None, :])
    earlier = jnp.tril(jnp.ones((s.shape[-1], s.shape[-1]), bool), k=-1)
    is_dup = jnp.any(jnp.logical_and(dup, earlier[None, :, :]), axis=-1)
    s = jnp.where(jnp.logical_or(is_dup, i < 0), -jnp.inf, s)
    vals, pos = jax.lax.top_k(s, k)
    return vals, jnp.take_along_axis(i, pos, axis=-1)


def shard_index(index: IVFIndex, n_shards: int) -> IVFIndex:
    """Re-lays the stable store out for ``n_shards``-way row-parallel search.

    Returns an ``IVFIndex`` whose every leaf carries a leading shard dim
    (S, ...): partition ``p``'s capacity slots are dealt round-robin (slot j
    -> shard j % S, local slot j // S — builds pack live rows into the low
    slots, so live rows spread evenly), the quantized rows are moved without
    re-quantization (identical int8 bytes + per-row vmin/scale ⇒ identical
    dequantized scores), and the centroids are replicated. A probe list
    computed against the (identical) centroids therefore selects exactly the
    single-device candidate set, split S ways — ``search_sharded`` over this
    layout is score-bit-identical to ``search`` at any ``n_probe``.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    k, cap = index.ids.shape
    cap_l = (cap + n_shards - 1) // n_shards
    pad = n_shards * cap_l - cap

    def deal(a, fill):
        if pad:
            widths = [(0, 0)] * a.ndim
            widths[1] = (0, pad)
            a = jnp.pad(a, widths, constant_values=fill)
        # (K, cap_l·S, ...) -> (K, cap_l, S, ...) -> (S, K, cap_l, ...):
        # local slot l of shard s is global slot l·S + s
        a = a.reshape((k, cap_l, n_shards) + a.shape[2:])
        return jnp.moveaxis(a, 2, 0)

    ids = deal(index.ids, -1)
    return IVFIndex(
        centroids=jnp.broadcast_to(index.centroids,
                                   (n_shards,) + index.centroids.shape),
        data=deal(index.data, 0),
        vmin=deal(index.vmin, 0.0),
        scale=deal(index.scale, 1.0),
        ids=ids,
        counts=jnp.sum((ids >= 0).astype(jnp.int32), axis=2),
        bits=index.bits,
    )


def shard_placement(mesh):
    """NamedSharding placing shard_index leaves: leading shard dim over the
    mesh's db axes (sharding/rules.py), everything else replicated."""
    from jax.sharding import NamedSharding
    from repro.sharding.rules import db_axes
    axes = db_axes(mesh)
    spec = axes if len(axes) > 1 else (axes[0] if axes else None)

    def place(a):
        return jax.device_put(
            a, NamedSharding(mesh, P(*((spec,) + (None,) * (a.ndim - 1)))))
    return place


def search_sharded(index: IVFIndex, queries: jax.Array, mesh, *, n_probe: int,
                   k: int, query_block: int = 64, impl: str = "auto",
                   probes: Optional[jax.Array] = None,
                   node_pass: Optional[jax.Array] = None):
    """Distributed search: index leaves carry a leading shard dim (S, ...)
    row-sharded over ("pod","data") (see ``shard_index``); queries (and the
    optional precomputed ``probes`` / ``node_pass`` predicate-or-visibility
    mask) replicated; per-shard local top-k, then all-gather(k) + merge.
    Local ids must already be globally unique (they are global node ids).
    The local scan is ``search`` itself — same kernel/einsum selection, same
    pre-top-k mask pushdown, same -inf/-1 padding semantics — so the merged
    result carries the single-device scores exactly."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    bits = index.bits

    have_probes = probes is not None
    have_pass = node_pass is not None

    def local(cent, data, vmin, scale, ids, counts, q, *rest):
        rest = iter(rest)
        pr = next(rest) if have_probes else None
        npass = next(rest) if have_pass else None
        loc = IVFIndex(cent[0], data[0], vmin[0], scale[0], ids[0], counts[0],
                       bits)
        vals, lids = search(loc, q, n_probe=n_probe, k=k,
                            query_block=query_block, impl=impl,
                            probes=pr, node_pass=npass)
        allv = jax.lax.all_gather(vals, data_axes, axis=0, tiled=False)   # (S,Q,k)
        alli = jax.lax.all_gather(lids, data_axes, axis=0, tiled=False)
        ns = allv.shape[0]
        allv = jnp.moveaxis(allv, 0, 1).reshape(q.shape[0], ns * k)
        alli = jnp.moveaxis(alli, 0, 1).reshape(q.shape[0], ns * k)
        mv, pos = jax.lax.top_k(allv, k)
        mi = jnp.take_along_axis(alli, pos, axis=1)
        # shards pad ragged tails with (-inf, -1): never let a pad slot of
        # one shard surface another's id through the merge
        return mv, jnp.where(jnp.isfinite(mv), mi, -1)

    shard_spec = P(data_axes if len(data_axes) > 1 else data_axes[0])
    # shard_map pytrees can't hold None leaves: absent optionals are dropped
    # from the arg list and re-inserted as None inside ``local``
    in_specs = [shard_spec] * 6 + [P(None, None)]
    args = [index.centroids, index.data, index.vmin, index.scale, index.ids,
            index.counts, queries]
    if have_probes:
        in_specs.append(P(None, None))
        args.append(probes)
    if have_pass:
        in_specs.append(P(None))
        args.append(node_pass)
    fn = _shard_map(
        local, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(None, None), P(None, None)),
        **_SHARD_MAP_KW,
    )
    return fn(*args)
