"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 64 routed top-6 + 2 shared.

[arXiv:2405.04434; hf]. The assignment block lists "MoE 64e top-6" and
"2 shared+160 routed"; 160 routed is the full V2 config — the lite model
(16B) has 64 routed experts, which matches the primary "64e top-6" spec,
so we use 64 routed + 2 shared (noted in docs/DESIGN.md §4).
"""
from repro.configs.base import LMConfig
from repro.configs.lm_shapes import lm_shapes

CONFIG = LMConfig(
    arch_id="deepseek-v2-lite-16b",
    source="arXiv:2405.04434; hf",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,           # per-expert hidden
    vocab_size=102400,
    attention="mla",
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    moe=True,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    dense_d_ff=10944,
)

# MLA latent KV cache (512+64 per token/layer) keeps the 500k decode cell's
# memory term tractable (~16 GB at batch 1 before sharding); decode is O(seq)
# per token. Run (justified in docs/DESIGN.md §4).
SHAPES = lm_shapes(long_ok=True, long_note="MLA compressed KV cache")
