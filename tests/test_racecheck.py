"""Concurrency invariants: HMG201-204 static fixtures, the dynamic
lockset/interleaving harness, and a tier-1 concurrent-search smoke.

Static fixtures go through the rule functions directly with a custom
GuardSpec registry (the ``guards=``/``methods=`` hooks exist for exactly
this), so the tests don't couple to the production registry's contents.
The dynamic tests drive ``tools/racecheck.py``'s fixture caches and the
canonical workload at a single seed; the CI racecheck job runs the full
sweep.
"""
import ast
import sys
import textwrap
import threading
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))            # make `tools` importable

from tools.staticcheck.concurrency import (      # noqa: E402
    check_hmg201, check_hmg202, check_hmg203, check_hmg204,
    collect_lock_edges)
from tools.staticcheck.pragmas import (          # noqa: E402
    KNOWN_RULES, filter_suppressed, scan_pragmas)
from tools.staticcheck.registry import GuardSpec  # noqa: E402
from tools import racecheck as rc                 # noqa: E402

CONC = "src/x/conc.py"
SPECS = (GuardSpec("Box", "x.conc", "_lock", ("items", "count"), ("x/conc.py",),
                   receivers=("b",)),)
METHODS = {"Box._refill_locked": "Box._lock"}


def parse(src):
    return ast.parse(textwrap.dedent(src))


def rules_of(vs):
    return [v.rule for v in vs]


# ------------------------------------------------------------------- HMG201
def test_hmg201_bad_unlocked_access():
    vs = check_hmg201(CONC, parse("""
        class Box:
            def __init__(self):
                self.items = []          # construction: exempt
            def add(self, x):
                self.items.append(x)     # read of guarded attr, no lock
            def size(self):
                return self.count        # same
    """), guards=SPECS, methods=METHODS)
    assert rules_of(vs) == ["HMG201", "HMG201"]
    assert vs[0].line == 6 and vs[1].line == 8


def test_hmg201_good_with_lock_and_locked_method():
    vs = check_hmg201(CONC, parse("""
        class Box:
            def add(self, x):
                with self._lock:
                    self.items.append(x)
            def _refill_locked(self):
                self.count = 0           # registered *_locked: lock held
            def refill(self):
                with self._lock:
                    self._refill_locked()
    """), guards=SPECS, methods=METHODS)
    assert vs == []


def test_hmg201_nested_def_does_not_inherit_lock():
    # the closure body runs later, possibly on another thread
    vs = check_hmg201(CONC, parse("""
        class Box:
            def add(self):
                with self._lock:
                    def work():
                        return self.items
                    return work
    """), guards=SPECS, methods=METHODS)
    assert rules_of(vs) == ["HMG201"]


def test_hmg201_named_receiver_audited_anywhere_in_file():
    vs = check_hmg201(CONC, parse("""
        def helper(b):
            return b.items               # 'b' is a registered receiver
        def ok(b):
            with b._lock:
                return b.items
    """), guards=SPECS, methods=METHODS)
    assert rules_of(vs) == ["HMG201"]
    assert vs[0].line == 3


def test_hmg201_unregistered_locked_method_flagged():
    vs = check_hmg201(CONC, parse("""
        class Box:
            def _drain_locked(self):
                pass
    """), guards=SPECS, methods=METHODS)
    assert rules_of(vs) == ["HMG201"]
    assert "GUARDED_METHODS" in vs[0].message


def test_hmg201_locked_call_site_requires_lock():
    vs = check_hmg201(CONC, parse("""
        class Box:
            def refill(self):
                self._refill_locked()    # caller does not hold the lock
    """), guards=SPECS, methods=METHODS)
    assert any("without holding" in v.message for v in vs)


def test_hmg201_pragma_with_reason_suppresses():
    src = textwrap.dedent("""
        class Box:
            def peek(self):
                # staticcheck: disable=HMG201 (double-checked fast path: published value is immutable)
                return self.items
    """)
    vs = check_hmg201(CONC, parse(src), guards=SPECS, methods=METHODS)
    pragmas = scan_pragmas(CONC, src)
    assert rules_of(vs) == ["HMG201"]
    assert filter_suppressed(vs, pragmas) == []
    assert pragmas.violations == []      # reasoned pragma is well-formed


def test_hmg20x_rules_are_known_to_pragma_scanner():
    assert {"HMG201", "HMG202", "HMG203", "HMG204"} <= set(KNOWN_RULES)


# ------------------------------------------------------------------- HMG202
def test_hmg202_bad_blocking_call_under_lock():
    vs = check_hmg202(CONC, parse("""
        import time
        class Box:
            def flush(self):
                with self._lock:
                    time.sleep(0.1)
            def drain(self):
                with self._cache_lock:
                    self.fut.result()
    """), methods=METHODS)
    assert rules_of(vs) == ["HMG202", "HMG202"]


def test_hmg202_good_wait_outside_and_deferred_def():
    vs = check_hmg202(CONC, parse("""
        import time
        class Box:
            def flush(self):
                with self._lock:
                    item = self.q
                time.sleep(0.1)          # blocking, but lock released
            def spawn(self):
                with self._lock:
                    def later():
                        time.sleep(1)    # deferred: runs without the lock
                    return later
    """), methods=METHODS)
    assert vs == []


def test_hmg202_locked_method_body_audited():
    vs = check_hmg202(CONC, parse("""
        class Box:
            def _refill_locked(self):
                self.fut.wait()
    """), methods=METHODS)
    assert rules_of(vs) == ["HMG202"]
    assert "Box._lock" in vs[0].message


# ------------------------------------------------------------------- HMG203
def test_hmg203_cycle_across_files_detected():
    a = parse("""
        class P:
            def f(self):
                with self._alock:
                    with self._block:
                        pass
    """)
    b = parse("""
        class P:
            def g(self):
                with self._block:
                    with self._alock:
                        pass
    """)
    vs = check_hmg203([("x/a.py", a), ("x/b.py", b)],
                      guards=SPECS, acquiring={}, methods={})
    assert rules_of(vs) == ["HMG203"]
    assert "cycle" in vs[0].message


def test_hmg203_consistent_order_is_clean():
    a = parse("""
        class P:
            def f(self):
                with self._alock:
                    with self._block:
                        pass
            def g(self):
                with self._alock:
                    with self._block:
                        pass
    """)
    assert check_hmg203([("x/a.py", a)], guards=SPECS, acquiring={},
                        methods={}) == []


def test_hmg203_acquiring_call_creates_edge():
    a = parse("""
        class P:
            def f(self):
                with self._alock:
                    stats.record(x)
    """)
    edges = collect_lock_edges("x/a.py", a, guards=SPECS,
                               acquiring={"record": "Stats._lock"},
                               methods={})
    assert edges == [("P._alock", "Stats._lock", 5)]


def test_hmg203_reentrant_same_lock_is_not_an_edge():
    a = parse("""
        class P:
            def f(self):
                with self._alock:
                    with self._alock:    # RLock reentry: no self-edge
                        pass
    """)
    assert collect_lock_edges("x/a.py", a, guards=SPECS, acquiring={},
                              methods={}) == []


# ------------------------------------------------------------------- HMG204
def test_hmg204_undeclared_mutation_after_thread_start():
    vs = check_hmg204(CONC, parse("""
        import threading
        class Box:
            def __init__(self):
                self.safe = 1            # before start: fine
                self.t = threading.Thread(target=self.run)
                self.t.start()
                self.late = 2            # after start, undeclared
            def poke(self):
                self.other = 3           # worker may be running
    """), guards=SPECS)
    assert rules_of(vs) == ["HMG204", "HMG204"]
    assert "late" in vs[0].message and "other" in vs[1].message


def test_hmg204_declared_attrs_and_threadless_class_ok():
    vs = check_hmg204(CONC, parse("""
        import threading
        class Box:
            def __init__(self):
                self.t = threading.Thread(target=self.run)
                self.t.start()
                self.count = 0           # declared in the registry
            def poke(self):
                self.items = []          # declared
        class Plain:
            def poke(self):
                self.anything = 1        # no threads: not audited
    """), guards=SPECS)
    assert vs == []


# ---------------------------------------------------------- dynamic: locksets
def test_racy_lazy_cache_is_caught():
    caught = 0
    for seed in range(6):
        r = rc.run_fixture(rc.RacyLazyCache, seed=seed)
        if r["builds"] > 1 or r["warnings"]:
            caught += 1
    assert caught > 0, "no schedule exposed the unguarded lazy build"


def test_guarded_lazy_cache_is_clean():
    for seed in range(6):
        r = rc.run_fixture(rc.GuardedLazyCache, seed=seed)
        assert r["builds"] == 1
        assert r["warnings"] == []


def test_lockset_warning_names_attribute_and_thread():
    r = rc.run_fixture(rc.RacyLazyCache, seed=0)
    assert any("RacyLazyCache" in w and "lockset empty" in w
               for w in r["warnings"])


def test_racy_result_cache_is_caught():
    """The pre-fix serving hot-result cache shape (lock elided): some
    schedule must expose the unguarded store."""
    caught = 0
    for seed in range(6):
        r = rc.run_fixture(rc.RacyResultCache, seed=seed)
        if r["builds"] > 1 or r["warnings"]:
            caught += 1
    assert caught > 0, "no schedule exposed the unguarded result cache"


def test_guarded_result_cache_is_clean():
    """The real HotResultCache (instrumented via the GUARDED_BY registry)
    under the same schedules: concurrent missers may both store
    (idempotent), but the lockset checker must stay quiet."""
    for seed in range(6):
        g = rc.run_fixture(rc.GuardedResultCacheFixture, seed=seed)
        assert g["warnings"] == []


def test_searcher_ops_cache_and_admission_paths():
    """The canonical workload's serving state, single-threaded: a repeat
    query hits the shared cache bit-identically, a writer mutation bumps
    the version and the recompute still matches, and the admission
    outcomes are the deterministic ones the workload asserts."""
    index, queries, writes = rc._build_index()
    cache, adm = rc._serving_state()
    r1 = rc._searcher_ops(index, queries[0], cache=cache, admission=adm)
    assert len(cache) == 1
    r2 = rc._searcher_ops(index, queries[0], cache=cache, admission=adm)
    for a, b in zip(r1, r2):
        assert np.array_equal(a, b)
    snaps = []
    rc._writer_ops(index, 0, writes, snaps)      # bumps index.version
    r3 = rc._searcher_ops(index, queries[0], cache=cache, admission=adm)
    for a, b in zip(r1, r3):                     # modality-a unaffected
        assert np.array_equal(a, b)


# ----------------------------------------------- dynamic: schedules & replay
def test_schedule_string_round_trip():
    s = rc.format_schedule(7, [0, 2, 1, 1, 0])
    assert s == "7:0.2.1.1.0"
    assert rc.parse_schedule(s) == (7, [0, 2, 1, 1, 0])
    assert rc.parse_schedule("3:") == (3, [])


def test_same_seed_same_schedule_same_result():
    a = rc.run_fixture(rc.RacyLazyCache, seed=4)
    b = rc.run_fixture(rc.RacyLazyCache, seed=4)
    assert a["schedule"] == b["schedule"]
    assert a["builds"] == b["builds"]
    assert a["warnings"] == b["warnings"]


def test_replaying_a_recorded_schedule_reproduces_it():
    rec = rc.run_fixture(rc.RacyLazyCache, seed=5)
    seed, choices = rc.parse_schedule(rec["schedule"])
    rep = rc.run_fixture(rc.RacyLazyCache, seed=seed, replay=choices)
    assert rep["schedule"] == rec["schedule"]
    assert rep["builds"] == rec["builds"]


def test_tracked_lock_maintains_held_set():
    lk = rc.TrackedLock(threading.RLock(), "t")
    assert rc.held_locks() == frozenset()
    with lk:
        with lk:                         # reentrant: counted
            assert rc.held_locks() == {lk}
        assert rc.held_locks() == {lk}
    assert rc.held_locks() == frozenset()


# ------------------------------------------------- dynamic: canonical workload
def test_canonical_workload_single_seed():
    r = rc.canonical_workload(seed=0, n_searchers=2, rounds=1)
    assert r["warnings"] == []
    assert r["mismatches"] == []
    assert r["ok"]
    assert r["schedule"].startswith("0:")


# -------------------------------------------- tier-1 concurrent-search smoke
def test_concurrent_search_matches_oracle():
    """8 real (uninstrumented) threads hammer modality-"a" searches — each
    through the shared hot-result cache, racing hits, misses, and
    version-stamp invalidations — and the lazily-built caches against a
    concurrent writer on "b"; every result must be bit-identical to the
    single-threaded oracle."""
    index, queries, writes = rc._build_index()
    oracle = [rc._searcher_ops(index, queries[i % queries.shape[0]])
              for i in range(8)]
    # invalidate the lazy caches so the concurrent phase races cold builds
    m = index.modalities["a"]
    with index._cache_lock:
        m.ivf_sharded = None
        m.id_rows = None
    cache, admission = rc._serving_state()
    errors = []
    barrier = threading.Barrier(9)

    def worker(i):
        try:
            barrier.wait()
            for _ in range(3):
                sv, si, rows = rc._searcher_ops(
                    index, queries[i % queries.shape[0]],
                    cache=cache, admission=admission)
                esv, esi, erows = oracle[i]
                assert np.array_equal(sv, esv)
                assert np.array_equal(si, esi)
                assert np.array_equal(rows, erows)
        except BaseException as e:       # pragma: no cover - failure path
            errors.append((i, e))

    def writer():
        try:
            barrier.wait()
            snaps = []
            for step in range(writes[0].shape[0]):
                rc._writer_ops(index, step, writes, snaps)
            for s in snaps[1:]:
                for k, v in snaps[0].items():
                    assert np.array_equal(s[k], v)
        except BaseException as e:       # pragma: no cover - failure path
            errors.append(("writer", e))

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    threads.append(threading.Thread(target=writer))
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
        assert not t.is_alive(), "concurrent smoke stalled"
    assert errors == [], errors[0]
