"""EquiformerV2 — SO(2)-eSCN equivariant graph attention (Liao et al.,
arXiv:2306.12059).

The eSCN trick: rotate each edge's source features into the edge-aligned
frame (Wigner-D from the Ivanic–Ruedenberg recurrence), where an SO(3)
tensor-product convolution reduces to independent SO(2) mixes per azimuthal
order m — O(L³) instead of O(L⁶) — truncated at ``m_max``. Attention weights
come from the invariant (l=0) channel; messages are rotated back and
softmax-aggregated per destination.

Feature layout: (N, (l_max+1)², C).
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.params import Builder
from repro.equivariant.spherical import (real_sph_harm, rotation_to_align_z,
                                         sh_dim, wigner_d_from_rotation)


def _m_orders(l_max: int, m_max: int):
    """(l, m) component bookkeeping for the SO(2) mix: for each m ∈ [0, m_max],
    the list of l's with l ≥ m. Components with |m| > m_max are truncated."""
    return {m: [l for l in range(l_max + 1) if l >= m] for m in range(m_max + 1)}


def _comp_index(l: int, m: int) -> int:
    return l * l + (m + l)


def init(cfg, key, d_feat_in: int, n_out: int):
    c, lm, mm, nh = cfg.d_hidden, cfg.l_max, cfg.m_max, cfg.n_heads
    dh = c // nh
    orders = _m_orders(lm, mm)
    b = Builder(key, dtype=jnp.float32)
    b.dense("enc", (d_feat_in, c), (None, "hidden"), fan_in=d_feat_in)
    layers = []
    for _ in range(cfg.n_layers):
        lb = b.sub()
        # SO(2) mixes: m=0 real mix; m>0 paired (cos/sin) complex-style mix
        for m, ls in orders.items():
            k = len(ls) * c
            if m == 0:
                lb.dense("so2_m0", (k, k), (None, None), fan_in=k)
            else:
                lb.dense(f"so2_m{m}_r", (k, k), (None, None), fan_in=k)
                lb.dense(f"so2_m{m}_i", (k, k), (None, None), fan_in=k)
        lb.dense("attn_q", (c, nh * dh), (None, None), fan_in=c)
        lb.dense("attn_k", (c, nh * dh), (None, None), fan_in=c)
        lb.dense("attn_alpha", (dh, 1), (None, None), fan_in=dh)
        lb.dense("ffn0", (c, 2 * c), (None, "hidden"), fan_in=c)
        lb.dense("ffn1", (2 * c, c), ("hidden", None), fan_in=2 * c)
        lb.ones("ln1", (c,), (None,))
        lb.ones("ln2", (c,), (None,))
        layers.append(lb.build())
    b.params["layers"] = [p for p, _ in layers]
    b.axes["layers"] = [a for _, a in layers]
    b.dense("head", (c, n_out), (None, None), fan_in=c)
    return b.build()


def _so2_conv(lp, f_rot, orders, lm, c):
    """f_rot: (E, dim, C) in the edge frame. Mix channels×l per m; truncate
    |m| > m_max (their components pass through zeroed — the eSCN truncation)."""
    e = f_rot.shape[0]
    out = jnp.zeros_like(f_rot)
    for m, ls in orders.items():
        if m == 0:
            rows = [_comp_index(l, 0) for l in ls]
            blk = f_rot[:, jnp.asarray(rows), :].reshape(e, -1)
            mixed = blk @ lp["so2_m0"]
            out = out.at[:, jnp.asarray(rows), :].set(mixed.reshape(e, len(ls), c))
        else:
            rp = [_comp_index(l, m) for l in ls]
            rm = [_comp_index(l, -m) for l in ls]
            fp = f_rot[:, jnp.asarray(rp), :].reshape(e, -1)
            fm = f_rot[:, jnp.asarray(rm), :].reshape(e, -1)
            wr, wi = lp[f"so2_m{m}_r"], lp[f"so2_m{m}_i"]
            op = fp @ wr - fm @ wi
            om = fp @ wi + fm @ wr
            out = out.at[:, jnp.asarray(rp), :].set(op.reshape(e, len(ls), c))
            out = out.at[:, jnp.asarray(rm), :].set(om.reshape(e, len(ls), c))
    return out


def _rotate(f, Ds, lm, inverse=False):
    """Apply block-diagonal Wigner-D: f (E, dim, C)."""
    out = []
    for l in range(lm + 1):
        blk = f[:, l * l:(l + 1) * (l + 1), :]
        D = Ds[l]
        if inverse:
            D = jnp.swapaxes(D, -1, -2)
        out.append(jnp.einsum("emn,enc->emc", D, blk))
    return jnp.concatenate(out, axis=1)


def apply(cfg, params, feats, positions, node_mask, ex):
    """Returns invariant node scalars (N, C)."""
    c, lm, mm, nh = cfg.d_hidden, cfg.l_max, cfg.m_max, cfg.n_heads
    dh = c // nh
    dim = sh_dim(lm)
    n = feats.shape[0]
    orders = _m_orders(lm, mm)

    h = jnp.zeros((n, dim, c))
    h = h.at[:, 0, :].set(feats @ params["enc"])

    for lp in params["layers"]:
        payload = jnp.concatenate([h.reshape(n, dim * c), positions], axis=-1)

        def edge_message(srcs, dsts, lp=lp):
            e = srcs.shape[0]
            f_src = srcs[:, : dim * c].reshape(e, dim, c)
            x_src = srcs[:, dim * c:]
            x_dst = dsts[:, dim * c:]
            rel = x_dst - x_src
            R = rotation_to_align_z(rel)
            Ds = wigner_d_from_rotation(jax.lax.stop_gradient(R), lm)
            f_rot = _rotate(f_src, Ds, lm)
            f_mix = _so2_conv(lp, f_rot, orders, lm, c)
            f_out = _rotate(f_mix, Ds, lm, inverse=True)
            # zero-length edges carry no frame: mask to preserve equivariance
            live = (jnp.linalg.norm(rel, axis=-1) > 1e-6).astype(f_out.dtype)
            return f_out * live[:, None, None], Ds

        def logit_fn(srcs, dsts, lp=lp):
            f_out, _ = edge_message(srcs, dsts)
            s_msg = f_out[:, 0, :]                            # invariant channel
            s_dst = dsts[:, : dim * c].reshape(-1, dim, c)[:, 0, :]
            q = (s_dst @ lp["attn_q"]).reshape(-1, nh, dh)
            k = (s_msg @ lp["attn_k"]).reshape(-1, nh, dh)
            a = jax.nn.leaky_relu(q + k, 0.2)
            return (a @ lp["attn_alpha"])[..., 0]             # (E, nh)

        def msg_fn(srcs, dsts, lp=lp):
            f_out, _ = edge_message(srcs, dsts)
            e = f_out.shape[0]
            return jnp.transpose(f_out.reshape(e, dim, nh, dh), (0, 2, 1, 3)
                                 ).reshape(e, nh, dim * dh)

        agg = ex.push_attn(payload, logit_fn, msg_fn, nh * dim * dh)
        agg = jnp.transpose(agg.reshape(n, nh, dim, dh), (0, 2, 1, 3)
                            ).reshape(n, dim, c)
        h = h + agg

        # equivariant layernorm (per-l RMS over m,c) + scalar FFN
        def eq_norm(f, scale):
            outs = []
            for l in range(lm + 1):
                blk = f[:, l * l:(l + 1) * (l + 1), :]
                rms = jnp.sqrt(jnp.mean(jnp.sum(blk * blk, axis=1), axis=-1) + 1e-6)
                outs.append(blk / rms[:, None, None])
            return jnp.concatenate(outs, axis=1) * scale[None, None, :]

        h = eq_norm(h, lp["ln1"])
        s = h[:, 0, :]
        s = s + (jax.nn.silu(s @ lp["ffn0"]) @ lp["ffn1"])
        h = h.at[:, 0, :].set(s)
        h = eq_norm(h, lp["ln2"]) * node_mask[:, None, None]
    return h[:, 0, :]


def node_logits(cfg, params, feats, positions, node_mask, ex):
    return apply(cfg, params, feats, positions, node_mask, ex) @ params["head"]
