"""End-to-end behaviour tests for the HMGI system (the paper's claims at
laptop scale): recall, hybrid fusion, dynamic updates, compaction,
workload-aware repartitioning, progressive execution, plan selection."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import HMGIIndex
from repro.core import ivf as ivf_mod
from repro.core.progressive import progressive_search
from repro.core.cost_model import CostModel, select_plan
from repro.data.synthetic import (ground_truth_topk, make_corpus, recall_at_k)


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(n_nodes=1200, modality_dims={"text": 48, "image": 64},
                       seed=0)


@pytest.fixture(scope="module")
def index(corpus):
    cfg = get_config("hmgi").replace(n_partitions=16, n_probe=4, top_k=10,
                                     delta_capacity=256, kmeans_iters=8)
    idx = HMGIIndex(cfg, seed=0)
    idx.ingest({m: (corpus.node_ids[m], corpus.vectors[m])
                for m in corpus.vectors}, n_nodes=corpus.n_nodes,
               edges=(corpus.src, corpus.dst, corpus.edge_type))
    return idx


def _queries(corpus, n=32, seed=7, noise=0.05):
    rng = np.random.default_rng(seed)
    sel = rng.integers(0, len(corpus.vectors["text"]), n)
    q = corpus.vectors["text"][sel] + noise * rng.normal(
        size=(n, corpus.vectors["text"].shape[1])).astype(np.float32)
    return q


class TestVectorSearch:
    def test_recall_at_probe(self, index, corpus):
        q = _queries(corpus)
        truth = ground_truth_topk(corpus.vectors["text"],
                                  corpus.node_ids["text"], q, 10)
        _, si = index.search(q, "text", k=10)
        assert recall_at_k(np.asarray(si), truth) > 0.8

    def test_recall_improves_with_probe(self, index, corpus):
        q = _queries(corpus)
        truth = ground_truth_topk(corpus.vectors["text"],
                                  corpus.node_ids["text"], q, 10)
        r_low = recall_at_k(np.asarray(index.search(q, "text", k=10, n_probe=1)[1]), truth)
        r_hi = recall_at_k(np.asarray(index.search(q, "text", k=10, n_probe=16)[1]), truth)
        assert r_hi >= r_low
        assert r_hi > 0.95

    def test_modality_isolation(self, index, corpus):
        """Modality-aware partitioning: text queries never return image ids."""
        q = _queries(corpus)
        _, si = index.search(q, "text", k=10)
        text_ids = set(int(i) for i in corpus.node_ids["text"])
        for row in np.asarray(si):
            for i in row:
                if i >= 0:
                    assert int(i) in text_ids


class TestHybrid:
    def test_hybrid_shapes_finite(self, index, corpus):
        q = _queries(corpus, 8)
        hv, hi = index.hybrid_search(q, "text", k=10, n_hops=2)
        assert hv.shape == (8, 10) and hi.shape == (8, 10)
        assert bool(jnp.all(jnp.isfinite(hv)))

    def test_hybrid_includes_vector_hits(self, index, corpus):
        q = _queries(corpus, 4)
        hv, hi = index.hybrid_search(q, "text", k=10, n_hops=2)
        _, vi = index.search(q, "text", k=10)
        overlap = np.mean([len(set(map(int, a)) & set(map(int, b))) / 10
                           for a, b in zip(np.asarray(hi), np.asarray(vi))])
        assert 0.0 < overlap <= 1.0

    def test_plan_selection(self):
        cm = CostModel()
        plan_fast = select_plan(cm, n=10 ** 6, d=384, min_recall=0.5)
        plan_deep = select_plan(cm, n=10 ** 6, d=384, min_recall=0.99)
        assert plan_fast.n_probe <= plan_deep.n_probe
        assert cm.cost(10 ** 6, 384, plan_fast.n_hops, plan_fast.n_probe) <= \
            cm.cost(10 ** 6, 384, plan_deep.n_hops, plan_deep.n_probe)


class TestDynamicUpdates:
    def test_insert_search_delete(self, corpus):
        cfg = get_config("hmgi").replace(n_partitions=8, n_probe=8, top_k=5,
                                         delta_capacity=128, kmeans_iters=4)
        idx = HMGIIndex(cfg, seed=0)
        idx.ingest({"text": (corpus.node_ids["text"], corpus.vectors["text"])},
                   n_nodes=corpus.n_nodes, edges=(corpus.src, corpus.dst))
        nv = np.zeros((4, 48), np.float32)
        nv[np.arange(4), np.arange(4)] = 1.0
        ids = np.arange(4, dtype=np.int32) + 1100
        idx.insert("text", ids, nv)
        _, si = idx.search(nv, "text", k=1)
        assert np.array_equal(np.asarray(si)[:, 0], ids)
        idx.delete("text", ids)
        _, si2 = idx.search(nv, "text", k=1)
        assert not np.any(np.isin(np.asarray(si2), ids))

    def test_update_supersedes_and_compacts(self, corpus):
        cfg = get_config("hmgi").replace(n_partitions=8, n_probe=8, top_k=3,
                                         delta_capacity=64, kmeans_iters=4)
        idx = HMGIIndex(cfg, seed=0)
        idx.ingest({"text": (corpus.node_ids["text"], corpus.vectors["text"])},
                   n_nodes=corpus.n_nodes, edges=(corpus.src, corpus.dst))
        tid = int(corpus.node_ids["text"][0])
        nv = np.zeros((1, 48), np.float32)
        nv[0, 0] = 1.0
        idx.insert("text", np.array([tid]), nv)
        _, si = idx.search(nv, "text", k=1)
        assert int(si[0, 0]) == tid
        idx.compact("text")
        sv, si2 = idx.search(nv, "text", k=1)
        assert int(si2[0, 0]) == tid
        assert float(sv[0, 0]) > 0.99   # latest version, not the stale one

    def test_repartition_trigger(self, index, corpus):
        m = index.modalities["text"]
        m.workload.hits[:] = 0
        m.workload.hits[0] = 10_000   # extreme skew
        assert m.workload.should_repartition()
        assert index.maybe_repartition("text")
        q = _queries(corpus)
        truth = ground_truth_topk(corpus.vectors["text"],
                                  corpus.node_ids["text"], q, 10)
        _, si = index.search(q, "text", k=10, n_probe=16)
        assert recall_at_k(np.asarray(si), truth) > 0.9


class TestProgressive:
    def test_monotone_improvement(self, corpus):
        v = corpus.vectors["text"]
        v = v / np.linalg.norm(v, axis=1, keepdims=True)
        idx, _ = ivf_mod.build(jax.random.PRNGKey(1), jnp.asarray(v),
                               jnp.arange(len(v)), n_partitions=16, bits=8)
        q = _queries(corpus, 16)
        truth = ground_truth_topk(v, np.arange(len(v)), q, 10)
        recalls = [recall_at_k(np.asarray(r.ids), truth)
                   for r in progressive_search(idx, jnp.asarray(q), k=10)]
        assert all(b >= a - 1e-9 for a, b in zip(recalls, recalls[1:]))
        assert recalls[-1] > 0.9
