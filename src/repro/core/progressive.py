"""Progressive (anytime) query execution (paper §3.4): deliver a coarse
result immediately and refine within a latency budget — n_probe doubles per
round; every round's result is exact over the partitions probed so far, so
quality is monotone (each round's candidate set is a superset).
"""
from __future__ import annotations

import time
from typing import Iterator, NamedTuple, Optional, Sequence

import jax

from repro import obs
from repro.core import ivf as ivf_mod
from repro.core.ivf import IVFIndex


class AnytimeResult(NamedTuple):
    scores: jax.Array
    ids: jax.Array
    n_probe: int
    round: int
    elapsed_s: float


def progressive_search(index: IVFIndex, queries: jax.Array, *, k: int,
                       probe_schedule: Sequence[int] = (1, 2, 4, 8, 16),
                       budget_s: Optional[float] = None,
                       node_pass: Optional[jax.Array] = None
                       ) -> Iterator[AnytimeResult]:
    """Yields monotonically improving results; stops at budget or schedule end.

    node_pass: optional (N,) visibility mask threaded into every round's
    scan — anytime refinement must honour the same MVCC/tombstone view as a
    one-shot search, or a round could resurface deleted rows.

    The budget is charged with *work* time: each round's scan+merge is
    measured individually (the ``progressive.round`` histogram) and the
    check compares the accumulated round time against ``budget_s``. Wall
    time since the first round would also bill whatever happens between
    rounds — a GC pause, or the consumer's own work while the generator is
    suspended at ``yield`` — and silently eat the final refinement round;
    time this generator does not spend refining must not cost refinement.
    ``elapsed_s`` reports the accumulated work time."""
    work_s = 0.0
    best = None
    for rnd, np_ in enumerate(probe_schedule):
        np_ = min(np_, index.n_partitions)
        t0 = time.perf_counter()
        sv, si = ivf_mod.search(index, queries, n_probe=np_, k=k,
                                node_pass=node_pass)
        if best is None:
            best = (sv, si)
        else:
            best = ivf_mod.dedup_merge_topk(best[0], best[1], sv, si, k)
        sv, si = best
        # the explicit sync stays *inside* the measured round: a round's
        # cost is its device work, not just its dispatch
        jax.block_until_ready(sv)
        dt = time.perf_counter() - t0
        work_s += dt
        obs.observe_ms("progressive.round", dt)
        obs.counter("progressive.rounds").inc()
        yield AnytimeResult(sv, si, np_, rnd, work_s)
        if budget_s is not None and work_s >= budget_s:
            return
        if np_ >= index.n_partitions:
            return
