"""Dynamic concurrency checker: Eraser-style locksets + deterministic
interleaving replay (the runtime half of the HMG2xx concurrency contract;
``tools/staticcheck/concurrency.py`` is the static half).

Two cooperating mechanisms, both driven by the same declarative registry
(``tools/staticcheck/registry.py`` GUARDED_BY):

**Lockset checking (Eraser).** ``instrument()`` patches the registered
classes so every access to a guarded attribute records ``(thread,
locks-held)``; locks named in the registry are wrapped in ``TrackedLock``
at construction. Per attribute, the checker runs the classic state
machine — virgin -> exclusive(first thread) -> shared — and maintains the
candidate lockset C(v) as the intersection of locks held at each *write*
once a second thread has touched the attribute. An empty C(v) at a shared
write is a warning: no single lock protects that attribute. Refining on
writes only (not reads) is deliberate — the repo's sanctioned
double-checked pattern publishes an immutable value under the lock and
reads it lock-free afterwards; racy *writes* are what corrupt.

**Deterministic interleaving (the Interleaver).** A cooperative
token-passing scheduler: participating threads run one at a time and hand
over only at *yield points* — lock acquire/release boundaries and guarded
attribute accesses (the same named-point spirit as PR 6's fault points).
A seeded RNG picks which parked thread runs next; the pick sequence IS
the schedule, printable as ``"<seed>:<i>.<i>..."`` and replayable
bit-for-bit with ``--schedule``. ``TrackedLock`` never blocks while
holding the token (it spins with ``acquire(blocking=False)`` and yields
between attempts), so a suspended lock holder cannot deadlock the
harness.

The canonical workload races N searcher threads (modality "a": searches,
plus direct ``_ensure_sharded`` / ``_modality_id_rows`` calls so the
lazy-cache builds race cold) against a writer thread confined to modality
"b" (insert/delete/maintain + ``state_tree`` snapshots). Confinement is
what makes bit-identity assertable: the searchers' results and the
snapshot's modality-"a" keys are invariant under every legal
interleaving, so any divergence from the single-threaded oracle is a real
race, reported with its repro string.

    PYTHONPATH=src python -m tools.racecheck --sweep           # >= 20 seeds
    PYTHONPATH=src python -m tools.racecheck --seed 7
    PYTHONPATH=src python -m tools.racecheck --schedule "7:0.2.1..."
"""
from __future__ import annotations

import argparse
import importlib
import random
import sys
import threading
from contextlib import contextmanager
from pathlib import Path
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))
_SRC = REPO_ROOT / "src"
if str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

from tools.staticcheck.registry import GUARDED_BY  # noqa: E402

_LOCK_TYPES = (type(threading.Lock()), type(threading.RLock()))


# ---------------------------------------------------------------------------
# held-lock tracking (per thread, counted for RLock reentrancy)
# ---------------------------------------------------------------------------

class _Held(threading.local):
    def __init__(self):
        self.locks: Dict["TrackedLock", int] = {}


_held = _Held()


def held_locks() -> FrozenSet["TrackedLock"]:
    return frozenset(l for l, c in _held.locks.items() if c > 0)


class TrackedLock:
    """Lock/RLock wrapper: maintains the per-thread held set and
    cooperates with an active Interleaver (spin-acquire + yield instead of
    blocking, yield points at acquire/release)."""

    _counter = 0

    def __init__(self, inner, name: str = ""):
        self._inner = inner
        TrackedLock._counter += 1
        self.name = name or f"lock#{TrackedLock._counter}"

    def _sched(self) -> Optional["Interleaver"]:
        return getattr(threading.current_thread(), "_rc_sched", None)

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        sched = self._sched()
        if sched is None:
            ok = (self._inner.acquire(blocking) if timeout < 0
                  else self._inner.acquire(blocking, timeout))
        else:
            # never block while holding the scheduler token: the holder
            # may be parked and could only run if we yield
            while not self._inner.acquire(blocking=False):
                sched.yield_point(f"wait:{self.name}")
            sched.yield_point(f"acq:{self.name}")
            ok = True
        if ok:
            _held.locks[self] = _held.locks.get(self, 0) + 1
        return ok

    def release(self) -> None:
        c = _held.locks.get(self, 0)
        if c <= 1:
            _held.locks.pop(self, None)
        else:
            _held.locks[self] = c - 1
        self._inner.release()
        sched = self._sched()
        if sched is not None:
            sched.yield_point(f"rel:{self.name}")

    def __enter__(self) -> "TrackedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"TrackedLock({self.name})"


# ---------------------------------------------------------------------------
# Eraser-style lockset checker
# ---------------------------------------------------------------------------

class LocksetChecker:
    """State machine per (object, attribute): virgin -> exclusive(owner)
    -> shared once a second thread touches it. C(v) = intersection of
    locks held at each shared *write*; empty C(v) at a write -> warning
    (no single lock protects the attribute)."""

    EXCLUSIVE, SHARED = 0, 1

    def __init__(self):
        self._mu = threading.Lock()          # plain: never yields inside
        self._state: Dict[Tuple[int, str], list] = {}
        self.warnings: List[str] = []
        self._warned: set = set()

    def access(self, obj, desc: str, attr: str, is_write: bool,
               thread_name: str, locks: FrozenSet[TrackedLock]) -> None:
        key = (id(obj), attr)
        with self._mu:
            st = self._state.get(key)
            if st is None:
                self._state[key] = [self.EXCLUSIVE, thread_name, None]
                return
            if st[0] == self.EXCLUSIVE:
                if st[1] == thread_name:
                    return                   # still single-threaded
                st[0] = self.SHARED
                st[2] = None                 # C(v) initialised at first
                                             # shared write below
            if not is_write:
                return                       # reads don't refine C(v)
            st[2] = locks if st[2] is None else (st[2] & locks)
            if not st[2] and key not in self._warned:
                self._warned.add(key)
                self.warnings.append(
                    f"lockset empty for {desc} (write by {thread_name!r} "
                    "with no lock in common with prior writers) — no "
                    "single lock protects this attribute")


# ---------------------------------------------------------------------------
# deterministic cooperative scheduler
# ---------------------------------------------------------------------------

class ScheduleStall(RuntimeError):
    """A thread failed to reach its next yield point (real deadlock or a
    blocking call outside TrackedLock). Carries the repro string."""


def format_schedule(seed: int, choices: Sequence[int]) -> str:
    return f"{seed}:" + ".".join(str(c) for c in choices)


def parse_schedule(s: str) -> Tuple[int, List[int]]:
    head, _, tail = s.partition(":")
    choices = [int(c) for c in tail.split(".") if c != ""]
    return int(head), choices


class Interleaver:
    """Token-passing scheduler. Threads spawned via ``spawn`` park until
    given the token; they hand it back at every yield point. The seeded
    pick sequence over the (registration-ordered) set of unfinished
    threads is recorded and replayable."""

    def __init__(self, seed: int = 0,
                 replay: Optional[Sequence[int]] = None,
                 timeout_s: float = 60.0):
        self.seed = int(seed)
        self._rng = random.Random(self.seed)
        self.choices: List[int] = []
        self._replay = list(replay) if replay is not None else None
        self._cv = threading.Condition()
        self._threads: List[threading.Thread] = []
        self._done: set = set()
        self._current: Optional[threading.Thread] = None
        self.timeout_s = timeout_s
        self.errors: List[Tuple[str, BaseException]] = []

    # ------------------------------------------------------------- lifecycle
    def spawn(self, fn, *args, name: str = "") -> threading.Thread:
        t = threading.Thread(target=self._trampoline, args=(fn, args),
                             name=name or f"rc-{len(self._threads)}",
                             daemon=True)
        t._rc_sched = self
        self._threads.append(t)
        t.start()                            # parks immediately
        return t

    def _trampoline(self, fn, args) -> None:
        me = threading.current_thread()
        with self._cv:
            while self._current is not me:
                if not self._cv.wait(timeout=self.timeout_s):
                    return                   # run() already gave up
        try:
            fn(*args)
        except BaseException as e:           # surfaced by run()
            self.errors.append((me.name, e))
        finally:
            with self._cv:
                self._done.add(me)
                self._hand_over()

    def run(self) -> str:
        """Release the first thread and wait for all to finish. Returns
        the schedule string; raises ScheduleStall (with repro string) on
        deadlock, or the first worker exception."""
        with self._cv:
            self._hand_over()
        for t in self._threads:
            t.join(timeout=self.timeout_s)
            if t.is_alive():
                raise ScheduleStall(
                    f"thread {t.name!r} stalled (deadlock or blocking "
                    "call outside TrackedLock); repro: --schedule "
                    f"'{self.schedule_string()}'")
        if self.errors:
            name, err = self.errors[0]
            raise RuntimeError(
                f"thread {name!r} failed under schedule "
                f"'{self.schedule_string()}'") from err
        return self.schedule_string()

    def schedule_string(self) -> str:
        return format_schedule(self.seed, self.choices)

    # ------------------------------------------------------------ scheduling
    def _hand_over(self) -> None:
        # caller holds _cv. All non-done threads are parked right now
        # (single-token invariant), so the candidate set is exact.
        cands = [t for t in self._threads if t not in self._done]
        if not cands:
            self._current = None
            self._cv.notify_all()
            return
        if self._replay:
            i = min(self._replay.pop(0), len(cands) - 1)
        elif self._replay is not None:       # replay exhausted: determin-
            i = 0                            # istic tail
        else:
            i = self._rng.randrange(len(cands))
        self.choices.append(i)
        self._current = cands[i]
        self._cv.notify_all()

    def yield_point(self, tag: str = "") -> None:
        me = threading.current_thread()
        if getattr(me, "_rc_sched", None) is not self:
            return
        with self._cv:
            self._hand_over()
            while self._current is not me:
                if not self._cv.wait(timeout=self.timeout_s):
                    raise ScheduleStall(
                        f"scheduler stalled at {tag!r}; repro: --schedule "
                        f"'{self.schedule_string()}'")


# ---------------------------------------------------------------------------
# instrumentation: patch registered classes
# ---------------------------------------------------------------------------

class _RCState:
    def __init__(self, checker: Optional[LocksetChecker]):
        self.checker = checker


_RC: Optional[_RCState] = None


def _on_access(obj, desc: str, attr: str, is_write: bool) -> None:
    st = _RC
    if st is None:
        return
    t = threading.current_thread()
    sched = getattr(t, "_rc_sched", None)
    if sched is None:
        return                               # only scheduled threads count
    sched.yield_point(f"{'w' if is_write else 'r'}:{desc}.{attr}")
    if st.checker is not None:
        st.checker.access(obj, desc, attr, is_write, t.name, held_locks())


def _wrap_class(cls, tracked: Tuple[str, ...], lock_attrs: Tuple[str, ...],
                patches: list) -> None:
    cname = cls.__name__
    if lock_attrs:
        orig_init = cls.__init__

        def __init__(self, *a, _orig=orig_init, _locks=lock_attrs,
                     _cname=cname, **kw):
            _orig(self, *a, **kw)
            for la in _locks:
                cur = getattr(self, la, None)
                if isinstance(cur, _LOCK_TYPES):
                    object.__setattr__(self, la,
                                       TrackedLock(cur, f"{_cname}.{la}"))

        patches.append((cls, "__init__", orig_init))
        cls.__init__ = __init__
    if tracked:
        tset = frozenset(tracked)
        orig_get = cls.__getattribute__
        orig_set = cls.__setattr__

        def __getattribute__(self, name, _orig=orig_get, _t=tset,
                             _cname=cname):
            val = _orig(self, name)
            if name in _t:
                _on_access(self, _cname, name, False)
            return val

        def __setattr__(self, name, value, _orig=orig_set, _t=tset,
                        _cname=cname):
            if name in _t:
                _on_access(self, _cname, name, True)
            _orig(self, name, value)

        patches.append((cls, "__getattribute__", orig_get))
        patches.append((cls, "__setattr__", orig_set))
        cls.__getattribute__ = __getattribute__
        cls.__setattr__ = __setattr__


# classes to lock-wrap beyond what GUARDED_BY names directly: HMGIIndex
# owns the two facade locks; obs Counter serialises inc() on its own lock.
_EXTRA_LOCK_WRAPS = (
    ("repro.core.index", "HMGIIndex", ("_write_lock", "_cache_lock")),
    ("repro.obs.metrics", "Counter", ("_lock",)),
)


@contextmanager
def instrument(checker: Optional[LocksetChecker] = None,
               extra: Sequence[Tuple[type, Tuple[str, ...],
                                     Tuple[str, ...]]] = ()):
    """Patch every GUARDED_BY class (and ``extra`` (cls, tracked_attrs,
    lock_attrs) triples — test fixtures) for the duration of the context:
    registry locks become TrackedLock at construction, guarded attribute
    accesses feed the lockset checker and the interleaving scheduler. The
    global obs registry is swapped for a fresh (wrapped-lock) instance so
    scheduled threads never block on a pre-instrumentation plain lock."""
    global _RC
    if _RC is not None:
        raise RuntimeError("instrument() does not nest")
    patches: list = []
    plan: Dict[type, Tuple[set, set]] = {}

    def add(cls, tracked=(), lock_attrs=()):
        tr, lk = plan.setdefault(cls, (set(), set()))
        tr.update(tracked)
        lk.update(lock_attrs)

    for spec in GUARDED_BY:
        mod = importlib.import_module(spec.module)
        cls = getattr(mod, spec.cls)
        add(cls, spec.attrs, (spec.lock,))
    for modname, clsname, lock_attrs in _EXTRA_LOCK_WRAPS:
        cls = getattr(importlib.import_module(modname), clsname)
        add(cls, (), lock_attrs)
    for cls, tracked, lock_attrs in extra:
        add(cls, tuple(tracked), tuple(lock_attrs))

    import repro.obs.metrics as metrics_mod
    for cls, (tracked, lock_attrs) in plan.items():
        _wrap_class(cls, tuple(sorted(tracked)), tuple(sorted(lock_attrs)),
                    patches)
    old_registry = metrics_mod._REGISTRY
    metrics_mod._REGISTRY = metrics_mod.MetricsRegistry()
    _RC = _RCState(checker)
    try:
        yield
    finally:
        _RC = None
        metrics_mod._REGISTRY = old_registry
        for cls, name, orig in reversed(patches):
            setattr(cls, name, orig)


# ---------------------------------------------------------------------------
# regression fixtures: the pre-fix lazy-cache race, and its fix
# ---------------------------------------------------------------------------

class RacyLazyCache:
    """The pre-PR9 ``_ensure_sharded`` / scatter-cache pattern: unguarded
    check-then-build. Two threads can both see None and both build —
    ``builds`` counts it, and the lockset checker flags the bare write."""

    def __init__(self):
        self._lock = threading.Lock()        # exists, but never taken
        self.cache = None
        self.builds = 0

    def get(self):
        if self.cache is None:
            self.builds += 1
            self.cache = ("built", self.builds)
        return self.cache


class GuardedLazyCache:
    """The fixed pattern: double-checked build under ``_lock``, immutable
    value published by a single reference assignment, lock-free reads
    after publication."""

    def __init__(self):
        self._lock = threading.Lock()
        self.cache = None
        self.builds = 0

    def get(self):
        c = self.cache
        if c is not None:
            return c
        with self._lock:
            if self.cache is None:
                self.builds += 1
                self.cache = ("built", self.builds)
            return self.cache


class RacyResultCache:
    """The hot-result cache with its lock elided: unguarded get-then-store
    on the entry dict plus a bare store counter. This is the pre-fix shape
    of ``repro.serving.cache.HotResultCache`` — kept as the regression the
    lockset checker must keep catching."""

    def __init__(self):
        self._lock = threading.Lock()        # exists, but never taken
        self.entries: dict = {}
        self.stores = 0

    def get(self):
        hit = self.entries.get("k")
        if hit is None:
            self.stores += 1
            hit = self.entries["k"] = ("scores", self.stores)
        return hit


class GuardedResultCacheFixture:
    """Drives the real serving ``HotResultCache`` (instrumented through the
    GUARDED_BY registry) through the same lookup-or-store shape its racy
    twin loses: concurrent missers may both compute and store — idempotent,
    same key, same bytes — but every dict access rides ``_lock``, so the
    lockset checker must stay quiet."""

    def __init__(self):
        import numpy as np
        from repro.serving.cache import HotResultCache
        self.cache = HotResultCache(capacity=4)
        self.q = np.ones((1, 4), np.float32)
        self.scores = np.zeros((1, 2), np.float32)
        self.ids = np.arange(2, dtype=np.int32)[None]

    def get(self):
        hit = self.cache.lookup("plan", self.q, 0)
        if hit is None:
            self.cache.store("plan", self.q, 0, self.scores, self.ids)
            hit = self.cache.lookup("plan", self.q, 0)
        return hit


_FIXTURE_SPECS = (
    (RacyLazyCache, ("cache", "builds"), ("_lock",)),
    (GuardedLazyCache, ("cache", "builds"), ("_lock",)),
    (RacyResultCache, ("entries", "stores"), ("_lock",)),
)


def run_fixture(cls, seed: int = 0, n_threads: int = 3,
                replay: Optional[Sequence[int]] = None) -> dict:
    """Race ``n_threads`` over one lazy cache under a seeded schedule.
    Returns {builds, warnings, schedule}."""
    checker = LocksetChecker()
    with instrument(checker, extra=_FIXTURE_SPECS):
        obj = cls()
        sched = Interleaver(seed, replay=replay)
        for i in range(n_threads):
            sched.spawn(obj.get, name=f"fix-{i}")
        schedule = sched.run()
    builds = getattr(obj, "builds", None)
    if builds is None:
        builds = getattr(obj, "stores", 0)
    return {"builds": builds, "warnings": list(checker.warnings),
            "schedule": schedule}


def fixture_selftest(seeds: Sequence[int]) -> Tuple[int, int]:
    """The 'pre-fix race demonstrably caught' gate: across ``seeds``, the
    racy cache must double-build (and draw a lockset warning) under at
    least one schedule, and the guarded cache must never do either.
    Returns (racy_catches, guarded_failures)."""
    catches = 0
    guarded_failures = 0
    for s in seeds:
        r = run_fixture(RacyLazyCache, seed=s)
        if r["builds"] > 1 or r["warnings"]:
            catches += 1
        g = run_fixture(GuardedLazyCache, seed=s)
        if g["builds"] != 1 or g["warnings"]:
            guarded_failures += 1
        r = run_fixture(RacyResultCache, seed=s)
        if r["builds"] > 1 or r["warnings"]:
            catches += 1
        g = run_fixture(GuardedResultCacheFixture, seed=s)
        # concurrent missers may legitimately both store (idempotent
        # same-key overwrite) — only a lockset warning fails the guarded
        # result cache
        if g["warnings"]:
            guarded_failures += 1
    return catches, guarded_failures


# ---------------------------------------------------------------------------
# canonical concurrent workload
# ---------------------------------------------------------------------------

# state_tree keys restricted to modality "a" for the bit-identity check:
# the writer never touches "a"'s stores, but "a"'s workload heat varies
# with searcher interleaving, so heat is excluded by construction.
_A_KEY_PREFIXES = ("m/a/ivf/", "m/a/delta/", "m/a/vectors", "m/a/ids")


def _a_keys(tree: dict) -> dict:
    import numpy as np
    return {k: np.asarray(v) for k, v in tree.items()
            if any(k.startswith(p) for p in _A_KEY_PREFIXES)}


def _build_index(seed_data: int = 0):
    import numpy as np
    from repro.configs.base import HMGIConfig
    from repro.core.index import HMGIIndex

    rng = np.random.default_rng(seed_data)
    n, d = 240, 16
    ids_a = np.arange(0, n // 2, dtype=np.int32)
    ids_b = np.arange(n // 2, n, dtype=np.int32)
    vec = rng.normal(size=(n, d)).astype(np.float32)
    cfg = HMGIConfig(n_partitions=6, kmeans_iters=4, n_probe=4, top_k=5,
                     delta_capacity=256, maint_auto=True,
                     maint_budget_rows=96, maint_chunk=32,
                     use_nsw_refine=False, obs_sync_spans=False)
    index = HMGIIndex(cfg, seed=seed_data)
    index.ingest({"a": (ids_a, vec[: n // 2]),
                  "b": (ids_b, vec[n // 2:])}, n_nodes=n)
    queries = rng.normal(size=(3, 2, d)).astype(np.float32)
    upd = rng.normal(size=(3, 8, d)).astype(np.float32)
    upd_ids = np.stack([rng.choice(ids_b, size=8, replace=False)
                        for _ in range(3)])
    del_ids = np.stack([rng.choice(ids_b, size=3, replace=False)
                        for _ in range(3)])
    return index, queries, (upd_ids, upd, del_ids)


def _serving_state():
    """The serving-layer shared state the searchers race over: one
    hot-result cache and one admission controller with a huge-burst tenant
    ("hot", deterministically always admitted) and a zero-quota tenant
    ("zero", deterministically always rejected) — outcomes that cannot
    depend on interleaving, so they assert cleanly under any schedule."""
    from repro.serving.cache import HotResultCache
    from repro.serving.scheduler import AdmissionController, TenantQuota
    return (HotResultCache(capacity=8),
            AdmissionController({"hot": TenantQuota(rate=0.0, burst=1e9),
                                 "zero": TenantQuota(rate=0.0, burst=0.0)}))


def _searcher_ops(index, q, k: int = 5, cache=None, admission=None):
    """One searcher round: a modality-"a" search plus direct hits on both
    lazily-built caches (the double-checked publication paths under test —
    the facade alone cannot reach the sharded layout without a mesh).

    With serving state attached the search goes lookup-or-store through
    the shared ``HotResultCache`` stamped with ``index.version`` — the
    writer's modality-"b" mutations bump the stamp, so searchers race
    hits, misses, and invalidations against it — and each round spends
    admission tokens with deterministic outcomes."""
    import numpy as np
    if admission is not None:
        assert admission.try_admit("hot", now=0.0), "hot tenant starved"
        assert not admission.try_admit("zero", now=0.0), \
            "zero-quota tenant admitted"
    if cache is not None:
        version = index.version
        hit = cache.lookup(("a", k), q, version)
        if hit is None:
            sv, si = index.search(q, "a", k=k)
            sv, si = np.asarray(sv), np.asarray(si)
            cache.store(("a", k), q, version, sv, si)
        else:
            sv, si = hit
    else:
        sv, si = index.search(q, "a", k=k)
    rows = index._modality_id_rows("a")
    index._ensure_sharded("a", 1)
    return np.asarray(sv), np.asarray(si), np.asarray(rows)


def _writer_ops(index, step: int, writes, snaps: list) -> None:
    upd_ids, upd, del_ids = writes
    index.insert("b", upd_ids[step], upd[step])
    index.delete("b", del_ids[step])
    index.maintain("b")
    tree, _meta = index.state_tree()
    snaps.append(_a_keys(tree))


def canonical_workload(seed: int = 0,
                       schedule: Optional[str] = None,
                       n_searchers: int = 3, rounds: int = 2,
                       timeout_s: float = 120.0) -> dict:
    """One seeded (or replayed) run of the canonical concurrent workload.

    Phase 1 (single-threaded oracle, instrumentation passive): build a
    twin index, run the full writer sequence and every searcher round,
    recording expected searcher results and the modality-"a" snapshot
    keys. This also warms every jit cache the concurrent phase needs.

    Phase 2 (scheduled): a fresh identical index; n_searchers searcher
    threads x rounds race one writer thread under the deterministic
    interleaver. Asserts searcher results and writer snapshots are
    bit-identical to the oracle and reports lockset warnings.
    """
    import numpy as np

    if schedule is not None:
        seed, replay = parse_schedule(schedule)
    else:
        replay = None

    checker = LocksetChecker()
    with instrument(checker):
        # ---- phase 1: oracle (main thread: no scheduling, no recording)
        index, queries, writes = _build_index()
        steps = writes[0].shape[0]
        cache, admission = _serving_state()
        expected = [_searcher_ops(index, queries[i % queries.shape[0]],
                                  cache=cache, admission=admission)
                    for i in range(n_searchers)]
        oracle_snap = None
        oracle_snaps: List[dict] = []
        for step in range(steps):
            _writer_ops(index, step, writes, oracle_snaps)
        oracle_snap = oracle_snaps[0]
        for s in oracle_snaps[1:]:
            for k0, v in oracle_snap.items():
                assert np.array_equal(s[k0], v), \
                    f"oracle modality-a state drifted at {k0} (workload " \
                    "bug: the writer must be confined to modality b)"

        # ---- phase 2: the same workload, interleaved
        index, queries, writes = _build_index()
        cache, admission = _serving_state()
        sched = Interleaver(seed, replay=replay, timeout_s=timeout_s)
        results: Dict[int, list] = {i: [] for i in range(n_searchers)}
        snaps: List[dict] = []

        def searcher(i: int) -> None:
            for _ in range(rounds):
                results[i].append(
                    _searcher_ops(index, queries[i % queries.shape[0]],
                                  cache=cache, admission=admission))

        def writer() -> None:
            for step in range(steps):
                _writer_ops(index, step, writes, snaps)

        for i in range(n_searchers):
            sched.spawn(searcher, i, name=f"searcher-{i}")
        sched.spawn(writer, name="writer")
        sched_str = sched.run()

    mismatches: List[str] = []
    for i in range(n_searchers):
        esv, esi, erows = expected[i]
        for r, (sv, si, rows) in enumerate(results[i]):
            if not np.array_equal(sv, esv):
                mismatches.append(f"searcher-{i} round {r}: scores diverge")
            if not np.array_equal(si, esi):
                mismatches.append(f"searcher-{i} round {r}: ids diverge")
            if not np.array_equal(rows, erows):
                mismatches.append(f"searcher-{i} round {r}: id_rows diverge")
    for step, snap in enumerate(snaps):
        for k0, v in oracle_snap.items():
            if not np.array_equal(snap[k0], v):
                mismatches.append(
                    f"writer snapshot step {step}: modality-a key {k0} "
                    "diverges")
    return {"seed": seed, "schedule": sched_str,
            "warnings": list(checker.warnings), "mismatches": mismatches,
            "ok": not checker.warnings and not mismatches}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.racecheck",
        description="Dynamic race checker: Eraser locksets + deterministic "
                    "interleaving replay over the canonical concurrent "
                    "workload.")
    ap.add_argument("--sweep", action="store_true",
                    help="run the fixture selftest plus the canonical "
                         "workload across --seeds seeded schedules")
    ap.add_argument("--seeds", type=int, default=20,
                    help="number of seeds for --sweep (default 20)")
    ap.add_argument("--seed", type=int, default=None,
                    help="run the canonical workload under one seed")
    ap.add_argument("--schedule", type=str, default=None,
                    help="replay a recorded schedule string "
                         "('<seed>:<i>.<i>...')")
    ap.add_argument("--searchers", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="per-thread stall timeout (seconds)")
    args = ap.parse_args(argv)

    failed = False
    if args.sweep:
        seeds = list(range(args.seeds))
        catches, bad = fixture_selftest(seeds[: min(8, len(seeds))])
        print(f"fixture selftest: racy lazy-cache caught under "
              f"{catches} of {min(8, len(seeds))} seeds; guarded version "
              f"clean ({bad} failures)")
        if catches == 0 or bad:
            print("FIXTURE SELFTEST FAILED", file=sys.stderr)
            failed = True
        for s in seeds:
            r = canonical_workload(s, n_searchers=args.searchers,
                                   rounds=args.rounds,
                                   timeout_s=args.timeout)
            status = "ok" if r["ok"] else "FAIL"
            print(f"seed {s:3d}: {status}  "
                  f"({len(r['schedule'].split('.'))} scheduling points)")
            if not r["ok"]:
                failed = True
                for w in r["warnings"]:
                    print(f"  warning: {w}", file=sys.stderr)
                for m0 in r["mismatches"]:
                    print(f"  mismatch: {m0}", file=sys.stderr)
                print(f"  repro: python -m tools.racecheck --schedule "
                      f"'{r['schedule']}'", file=sys.stderr)
        print("sweep: " + ("FAILED" if failed else
                           f"clean across {len(seeds)} seeds "
                           "(zero lockset warnings, bit-identical results)"))
    elif args.schedule is not None or args.seed is not None:
        r = canonical_workload(args.seed or 0, schedule=args.schedule,
                               n_searchers=args.searchers,
                               rounds=args.rounds, timeout_s=args.timeout)
        for w in r["warnings"]:
            print(f"warning: {w}", file=sys.stderr)
        for m0 in r["mismatches"]:
            print(f"mismatch: {m0}", file=sys.stderr)
        if r["ok"]:
            print(f"ok (schedule '{r['schedule'][:60]}"
                  f"{'...' if len(r['schedule']) > 60 else ''}')")
        else:
            print(f"FAILED; repro: python -m tools.racecheck --schedule "
                  f"'{r['schedule']}'", file=sys.stderr)
            failed = True
    else:
        ap.print_help()
        return 2
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
