"""Open-loop serving load benchmark: QPS and p50/p99 vs concurrency,
batched vs unbatched retrieval.

The ROADMAP's serving deliverable: drive the serving retrieval path
(``repro.serving.retrieval.RetrievalService``) with N concurrent client
streams issuing single-query searches at scheduled arrival times (open
loop — arrivals do not wait for completions, so queue wait is part of
latency, the way a latency SLO sees it), and report throughput and tail
latency **from the obs registry**: each request's latency is observed
into the ``serving.request_ms`` histogram and the reported p50/p99 are
that histogram's exact-quantile readout.

Two modes per level (``--batching both``, the default):

- ``off`` — every request runs its own pow2-bucketed ``(1, k)`` call
  (the pre-micro-batching serving path);
- ``on``  — requests arriving within the micro-batch window ride one
  ``(Q, k)`` call through ``MicroBatcher`` (``batch_q`` in the CSV is the
  mean realised batch size from the ``serving.batch_q`` histogram).

Both modes use the same bucketed entry (``search_bucketed``, floor 2), so
with ``--check`` every response in *both* modes is validated bit-exactly
against one precomputed solo-request reference table — the bench measures
correctness under load and under co-batching, not just latency. The
hot-result cache is disabled here: repeated queries would let cache hits
masquerade as batching throughput.

Arrival pacing: the single-stream unbatched service time is calibrated
first; each stream then offers ``utilization / (t_service * max_streams)``
QPS — the *same* interval for both modes, so the speedup line compares
like against like. The default utilization oversubscribes the unbatched
path (~3x calibrated capacity): the top level saturates, and each mode's
QPS reads out its actual capacity. JAX releases the GIL during device
execution, so thread-per-stream genuinely overlaps dispatch with device
work.

Also prints the instrumentation overhead check: single-stream query p50
with the obs layer enabled (tracing off — the always-on configuration)
vs fully disabled (``obs.set_enabled(False)``), interleaved A/B rounds to
cancel drift. The enabled p50 must stay within ~5% of the disabled one
for "cheap enough to leave always-on" to hold.

    PYTHONPATH=src python benchmarks/serving_load_bench.py \
        --streams 1,8,64 --duration 5 --check
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np
import jax

from repro import obs
from repro.serving.retrieval import RetrievalPlan, RetrievalService

try:
    from benchmarks.common import (build_hmgi, load_corpus, make_queries,
                                   primary_mod)
except ImportError:                     # script-style invocation
    from common import build_hmgi, load_corpus, make_queries, primary_mod

REQUEST_HIST = "serving.request_ms"
BATCH_HIST = "serving.batch_q"


def _one_query(index, q1, modality, k):
    sv, si = index.search(q1, modality, k=k)
    jax.block_until_ready(sv)
    return sv, si


def make_services(index, modality, k, window_s):
    """(plan, unbatched service, batched service). No cache in either —
    repeated queries would let cache hits masquerade as batching
    throughput."""
    plan = RetrievalPlan(modality=modality, k=k)
    off = RetrievalService(index, batching=False, cache=None)
    on = RetrievalService(index, batching=True, window_s=window_s,
                          max_batch=64, cache=None)
    return plan, off, on


def calibrate(service, plan, queries, warmup=8, trials=32) -> float:
    """Mean single-stream unbatched service seconds per request (after
    compile). Warmup also compiles the pow2 buckets the batched mode will
    hit, so neither mode pays compiles inside a measured level."""
    for i in range(warmup):
        service.search(plan, queries[i % len(queries)][None])
    t0 = time.perf_counter()
    for i in range(trials):
        service.search(plan, queries[i % len(queries)][None])
    return (time.perf_counter() - t0) / trials


def warm_buckets(index, plan, queries, max_batch=64):
    """Compile every pow2 (Q, k) bucket up to max_batch once, so the
    batched levels never pay a compile mid-measurement."""
    from repro.serving.retrieval import run_plan
    b = 2
    while b <= max_batch:
        run_plan(index, plan, np.stack([queries[i % len(queries)]
                                        for i in range(b)]))
        b *= 2


def overhead_check(index, queries, modality, k, rounds=6, per_round=24):
    """Interleaved A/B: p50 with obs enabled vs disabled, measured with
    identical host timers. Returns (enabled_p50_ms, disabled_p50_ms)."""
    lat = {True: [], False: []}
    try:
        for r in range(rounds):
            for enabled in (True, False) if r % 2 == 0 else (False, True):
                obs.set_enabled(enabled)
                for i in range(per_round):
                    q1 = queries[(r * per_round + i) % len(queries)][None]
                    t0 = time.perf_counter()
                    _one_query(index, q1, modality, k)
                    lat[enabled].append(time.perf_counter() - t0)
    finally:
        obs.set_enabled(True)
    return (float(np.percentile(lat[True], 50)) * 1e3,
            float(np.percentile(lat[False], 50)) * 1e3)


def run_level(service, plan, queries, mode, n_streams, duration_s,
              interval_s, check_ref=None) -> dict:
    """One (mode, concurrency) level: n_streams open-loop clients for
    duration_s. Latency is measured from each request's *scheduled*
    arrival time, so a request that waited on a busy device is charged
    its queue time.

    check_ref: optional per-query (scores, ids) precomputed solo-request
    reference — every stream then validates each response bit-exactly, so
    the bench measures correctness under load (and, in batched mode,
    under co-batching with whatever else arrived), not just latency."""
    obs.reset()
    barrier = threading.Barrier(n_streams + 1)
    errors = []

    def stream(sid: int):
        try:
            barrier.wait()
            start = time.perf_counter()
            n = 0
            while True:
                sched = start + n * interval_s
                if sched - start >= duration_s:
                    return
                now = time.perf_counter()
                if sched > now:
                    time.sleep(sched - now)
                qi = (sid + n) % len(queries)
                sv, si = service.search(plan, queries[qi][None])
                obs.observe_ms(REQUEST_HIST, time.perf_counter() - sched)
                if check_ref is not None:
                    rv, ri = check_ref[qi]
                    if not (np.array_equal(np.asarray(sv), rv)
                            and np.array_equal(np.asarray(si), ri)):
                        raise RuntimeError(
                            f"response for query {qi} diverged from the "
                            f"solo-request reference ({mode} mode)")
                n += 1
        except Exception as e:          # surface, don't hang the join
            errors.append((sid, e))

    threads = [threading.Thread(target=stream, args=(s,), daemon=True)
               for s in range(n_streams)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    elapsed = time.perf_counter() - t0
    if errors:
        # surface EVERY failed stream, not just the first — a race that
        # hits 3 of 64 streams reads very differently from one bad query
        detail = "; ".join(f"stream {sid}: {e!r}" for sid, e in errors)
        raise RuntimeError(
            f"{len(errors)} of {n_streams} stream(s) failed: {detail}"
        ) from errors[0][1]
    h = obs.registry().histogram(REQUEST_HIST)
    bh = obs.registry().histogram(BATCH_HIST)
    batch_q = (bh.total / bh.count) if bh.count else 1.0
    return {"mode": mode, "streams": n_streams, "requests": h.count,
            "qps": h.count / elapsed,
            "offered_qps": n_streams / interval_s,
            "p50_ms": h.percentile(50), "p99_ms": h.percentile(99),
            "batch_q": batch_q}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=str, default="1,8,64",
                    help="comma-separated concurrency levels")
    ap.add_argument("--duration", type=float, default=5.0,
                    help="seconds per (mode, concurrency) level")
    ap.add_argument("--dataset", type=str, default="dec-10k")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--utilization", type=float, default=3.0,
                    help="offered load at the largest level, as a multiple "
                         "of calibrated single-stream unbatched capacity "
                         "(>1 saturates: QPS reads out each mode's actual "
                         "capacity)")
    ap.add_argument("--batching", choices=("on", "off", "both"),
                    default="both",
                    help="retrieval mode(s) to run at each level")
    ap.add_argument("--window-ms", type=float, default=1.0,
                    help="micro-batch collection window (batched mode)")
    ap.add_argument("--check", action="store_true",
                    help="validate every response bit-exactly against a "
                         "precomputed solo-request reference")
    args = ap.parse_args()
    levels = [int(s) for s in args.streams.split(",")]
    modes = (["off", "on"] if args.batching == "both" else [args.batching])

    corpus = load_corpus(args.dataset)
    modality = primary_mod(args.dataset)
    index = build_hmgi(corpus)
    queries = make_queries(corpus, modality, n=256)

    plan, svc_off, svc_on = make_services(index, modality, args.k,
                                          args.window_ms * 1e-3)
    services = {"off": svc_off, "on": svc_on}

    t_service = calibrate(svc_off, plan, queries)
    print(f"# {args.dataset}: unbatched service time "
          f"{t_service*1e3:.3f} ms/req, capacity ~{1.0/t_service:.0f} QPS")
    if "on" in modes:
        warm_buckets(index, plan, queries)

    en_p50, dis_p50 = overhead_check(index, queries, modality, args.k)
    delta = (en_p50 - dis_p50) / dis_p50 * 100.0
    verdict = "within 5%" if delta <= 5.0 else "EXCEEDS 5%"
    print(f"# obs overhead: p50 {en_p50:.3f} ms enabled vs {dis_p50:.3f} ms "
          f"uninstrumented ({delta:+.1f}%, {verdict})")

    check_ref = None
    if args.check:
        # one reference table serves both modes: the bit-exactness
        # contract says a request's bytes do not depend on co-batching
        check_ref = [tuple(np.asarray(x)
                           for x in svc_off.search(plan, q[None]))
                     for q in queries]
        print(f"# check: {len(check_ref)} solo-request reference "
              "responses precomputed; every stream in every mode "
              "validates bit-exactly")

    # per-stream interval so the top level offers utilization × unbatched
    # capacity — the SAME interval for both modes
    interval_s = t_service * max(levels) / args.utilization
    print("mode,streams,requests,offered_qps,qps,p50_ms,p99_ms,batch_q")
    qps = {}
    for s in levels:
        for mode in modes:
            r = run_level(services[mode], plan, queries, mode, s,
                          args.duration, interval_s, check_ref=check_ref)
            qps[(mode, s)] = r["qps"]
            print(f"{r['mode']},{r['streams']},{r['requests']},"
                  f"{r['offered_qps']:.1f},{r['qps']:.1f},"
                  f"{r['p50_ms']:.3f},{r['p99_ms']:.3f},"
                  f"{r['batch_q']:.2f}")
        if len(modes) == 2:
            ratio = qps[("on", s)] / qps[("off", s)]
            print(f"# speedup @{s} streams: {ratio:.2f}x QPS "
                  "(batched vs unbatched)")
    if args.check:
        print("# check: PASS (all responses matched the reference)")


if __name__ == "__main__":
    main()
