"""Serving launcher: builds an HMGI index over a synthetic multimodal corpus
and serves batched hybrid queries, then an ingest-while-search phase
(streaming inserts/deletes interleaved with queries, adaptive maintenance
draining the delta in bounded steps between batches) and optional RAG
generation with maintenance paced between decode steps.

``python -m repro.launch.serve --n-nodes 2000 --queries 64 [--rag]``

Durability: ``--data-dir DIR`` makes the index durable (write-ahead op log +
periodic snapshots under DIR); ``--recover`` restarts from DIR's latest
valid snapshot plus log-tail replay instead of rebuilding — search results
are bit-identical to the pre-crash index.

Observability: all phase timings come from the ``repro.obs`` registry
(spans feed named histograms; see docs/ARCHITECTURE.md). ``--metrics-out
FILE`` dumps the full registry snapshot as JSON at exit.
"""
from __future__ import annotations

import argparse
import json

import numpy as np
import jax

from repro import obs
from repro.configs import get_config, smoke_config
from repro.core import HMGIIndex
from repro.data.synthetic import ground_truth_topk, make_corpus, recall_at_k


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-nodes", type=int, default=2000)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--hops", type=int, default=2)
    ap.add_argument("--rag", action="store_true")
    ap.add_argument("--ingest-steps", type=int, default=4,
                    help="ingest-while-search streaming steps (0 = skip)")
    ap.add_argument("--data-dir", type=str, default=None,
                    help="durable mode: op-log + snapshot under this dir")
    ap.add_argument("--recover", action="store_true",
                    help="recover from --data-dir instead of rebuilding")
    ap.add_argument("--metrics-out", type=str, default=None,
                    help="write the obs registry snapshot (JSON) here at exit")
    args = ap.parse_args()
    if args.recover and not args.data_dir:
        ap.error("--recover requires --data-dir")

    cfg = get_config("hmgi").replace(n_partitions=32, n_probe=8,
                                     kmeans_iters=8, top_k=args.k)
    corpus = make_corpus(n_nodes=args.n_nodes,
                         modality_dims={"text": 64, "image": 96})
    hist = lambda name: obs.histogram(name).summary()
    if args.recover:
        from repro.persistence import recover
        with obs.span("serve.recover"):
            index = recover(cfg, args.data_dir, seed=0)
        print(f"recover: {hist('serve.recover')['max']/1e3:.2f}s  "
              f"[{index.metrics()['recovery']}]")
    else:
        if args.data_dir:
            from repro.persistence import DurableHMGIIndex
            index = DurableHMGIIndex(cfg, args.data_dir, seed=0)
        else:
            index = HMGIIndex(cfg, seed=0)
        with obs.span("serve.ingest_build"):
            index.ingest({m: (corpus.node_ids[m], corpus.vectors[m])
                          for m in corpus.vectors}, n_nodes=corpus.n_nodes,
                         edges=(corpus.src, corpus.dst, corpus.edge_type))
        print(f"ingest+build: {hist('serve.ingest_build')['max']/1e3:.2f}s  "
              f"memory: {index.memory_usage()['total']/2**20:.1f} MiB")

    rng = np.random.default_rng(1)
    sel = rng.integers(0, len(corpus.vectors["text"]), args.queries)
    q = corpus.vectors["text"][sel] + 0.05 * rng.normal(
        size=(args.queries, 64)).astype(np.float32)

    with obs.span("serve.vector_batch") as sp:
        sv, si = index.search(q, "text", k=args.k)
        jax.block_until_ready(sv)
        sp.fence(sv)
    truth = ground_truth_topk(corpus.vectors["text"], corpus.node_ids["text"],
                              q, args.k)
    print(f"vector search: "
          f"{hist('serve.vector_batch')['max']/args.queries:.3f} ms/q  "
          f"recall@{args.k}={recall_at_k(np.asarray(si), truth):.3f}")

    with obs.span("serve.hybrid_batch") as sp:
        hv, hi = index.hybrid_search(q, "text", k=args.k, n_hops=args.hops)
        jax.block_until_ready(hv)
        sp.fence(hv)
    print(f"hybrid search ({args.hops} hops): "
          f"{hist('serve.hybrid_batch')['max']/args.queries:.3f} ms/q")

    # ingest-while-search: streaming writes interleaved with queries; the
    # adaptive maintenance hooks (insert/delete auto-trigger) drain the
    # delta in bounded steps instead of stop-the-world compactions. Worst
    # write stall = the max of the per-step "serve.ingest_step" histogram.
    if args.ingest_steps > 0:
        batch = max(args.n_nodes // 20, 8)
        for step in range(args.ingest_steps):
            wid = rng.integers(0, args.n_nodes, batch).astype(np.int32)
            wv = rng.normal(size=(batch, 64)).astype(np.float32)
            with obs.span("serve.ingest_step"):
                index.insert("text", wid, wv)
                index.delete("text", wid[:batch // 8])
            sv2, _ = index.search(q[:8], "text", k=args.k)
            jax.block_until_ready(sv2)
        m = index.modalities["text"]
        print(f"ingest-while-search: {args.ingest_steps} steps x {batch} "
              f"writes, worst write stall "
              f"{hist('serve.ingest_step')['max']:.1f} ms, "
              f"delta={int(m.delta.count)}  "
              f"maintenance: {index.metrics().get('maintenance', 'n/a')}")

    if args.data_dir:
        with obs.span("serve.snapshot"):
            path = index.snapshot()
        print(f"snapshot: {hist('serve.snapshot')['max']/1e3:.2f}s -> {path}  "
              f"(last_seq={index.last_seq})")

    if args.rag:
        from repro.models import lm
        from repro.serving.engine import EngineConfig, RAGEngine
        lcfg = smoke_config("phi4-mini-3.8b")
        params, _ = lm.init_lm(lcfg, jax.random.PRNGKey(0))
        eng = RAGEngine(lcfg, params, index,
                        EngineConfig(n_slots=4, max_seq=64, retrieve_k=4,
                                     snapshot_interval=32))
        rids = eng.retrieve(q[:4])
        for i in range(4):
            eng.submit(i, rng.integers(0, lcfg.vocab_size, 8), rids[i], 8)
        gen = eng.run_to_completion()
        print(f"RAG generated: { {k: len(v) for k, v in gen.items()} } "
              f"stats={eng.stats}")

    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(obs.snapshot(), f, indent=2)
        print(f"metrics -> {args.metrics_out}")


if __name__ == "__main__":
    main()
