"""Production meshes. A FUNCTION, not a module-level constant — importing
this module never touches jax device state (dry-run requirement)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def data_shards(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        n *= mesh.shape.get(a, 1)
    return n
