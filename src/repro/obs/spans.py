"""Trace spans: named, nestable wall-clock timers feeding the registry.

A span is a context manager that times its body with ``perf_counter`` and
records the duration (milliseconds) into the histogram of the same name:

    with span("query.seed_scan") as sp:
        sv, si = run_seed(index, p, node_pass)
        sp.fence((sv, si))

Spans are host-side only — they wrap *calls to* jitted functions, never
code inside a trace. Because JAX dispatch is async, a naive timer charges
device work to whichever later span happens to block first. ``sp.fence(x)``
fixes attribution: when ``cfg.obs_sync_spans`` is on (plumbed here via
``set_sync_spans``), the span's exit calls ``jax.block_until_ready`` on the
fenced value so device time lands in the span that launched it. With the
flag off (the default), ``fence`` stores nothing and exit does no sync —
spans add only two clock reads and a histogram insert, cheap enough to
leave always-on.

Nesting/parenting is per-thread (``threading.local``): a ``trace()``
context installs a collector that assembles completed spans into a
printable tree, returned to callers via the facades' ``trace=`` option.
Span exit always runs (context-manager protocol), so a raise inside the
body still closes the span and records its duration.
"""
from __future__ import annotations

import threading
import time
from typing import Any, List, Optional

from .metrics import registry

_SYNC_SPANS = False


def set_sync_spans(on: bool) -> None:
    """Enable ``block_until_ready`` fencing at span exit (honest device-time
    attribution, at the cost of serialising dispatch). Facades call this
    with ``cfg.obs_sync_spans`` on entry."""
    global _SYNC_SPANS
    _SYNC_SPANS = bool(on)


def sync_spans() -> bool:
    return _SYNC_SPANS


class SpanNode:
    """One completed span in a trace tree."""

    __slots__ = ("name", "duration_ms", "children", "error")

    def __init__(self, name: str):
        self.name = name
        self.duration_ms = float("nan")
        self.children: List["SpanNode"] = []
        self.error: Optional[str] = None

    def find(self, name: str) -> Optional["SpanNode"]:
        if self.name == name:
            return self
        for c in self.children:
            hit = c.find(name)
            if hit is not None:
                return hit
        return None

    def render(self, indent: int = 0) -> str:
        mark = f"  !{self.error}" if self.error else ""
        lines = [f"{'  ' * indent}{self.name:<{max(1, 28 - 2 * indent)}}"
                 f" {self.duration_ms:8.3f} ms{mark}"]
        for c in self.children:
            lines.append(c.render(indent + 1))
        return "\n".join(lines)

    def __repr__(self) -> str:
        return f"SpanNode({self.name}, {self.duration_ms:.3f} ms)"

    def __str__(self) -> str:
        return self.render()


class _ThreadState(threading.local):
    def __init__(self):
        self.stack: List[SpanNode] = []   # open spans, innermost last
        self.trace: Optional["Trace"] = None


_STATE = _ThreadState()


class Trace:
    """Collector for one traced request. ``root`` is the first top-level
    span completed while the trace was active (the facade's outermost
    span); ``render()`` prints the whole tree."""

    def __init__(self):
        self.roots: List[SpanNode] = []

    @property
    def root(self) -> Optional[SpanNode]:
        return self.roots[0] if self.roots else None

    def find(self, name: str) -> Optional[SpanNode]:
        for r in self.roots:
            hit = r.find(name)
            if hit is not None:
                return hit
        return None

    def render(self) -> str:
        return "\n".join(r.render() for r in self.roots)

    def __str__(self) -> str:
        return self.render()


class trace:
    """Context manager installing a per-thread span collector:

        with trace() as t:
            index.search(q, "text")
        print(t.render())

    Only one trace per thread at a time; nested ``trace()`` reuses the
    outer collector.
    """

    def __init__(self):
        self._owner = False
        self.trace: Optional[Trace] = None

    def __enter__(self) -> Trace:
        if _STATE.trace is None:
            _STATE.trace = Trace()
            self._owner = True
        self.trace = _STATE.trace
        return self.trace

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._owner:
            _STATE.trace = None


class span:
    """Timed, nestable span. Records duration_ms into the histogram named
    ``name``; attaches to the enclosing span's trace node when a trace is
    active. Exception-safe: exit runs and records even when the body
    raises (the node is marked with the exception type)."""

    __slots__ = ("name", "_t0", "_node", "_fenced")

    def __init__(self, name: str):
        self.name = name
        self._t0 = 0.0
        self._node: Optional[SpanNode] = None
        self._fenced: Any = None

    def fence(self, value: Any) -> Any:
        """Mark ``value`` (arrays/pytrees) to be ``block_until_ready``-ed at
        span exit when sync-spans is on; returns it unchanged so call
        sites can fence in-line. No-op (stores nothing) when off."""
        if _SYNC_SPANS:
            self._fenced = value
        return value

    def __enter__(self) -> "span":
        node = SpanNode(self.name)
        st = _STATE
        if st.trace is not None:
            if st.stack:
                st.stack[-1].children.append(node)
            else:
                st.trace.roots.append(node)
        st.stack.append(node)
        self._node = node
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._fenced is not None:
            import jax
            jax.block_until_ready(self._fenced)
            self._fenced = None
        dt_ms = (time.perf_counter() - self._t0) * 1e3
        node = self._node
        node.duration_ms = dt_ms
        if exc_type is not None:
            node.error = exc_type.__name__
        st = _STATE
        if st.stack and st.stack[-1] is node:
            st.stack.pop()
        registry().histogram(self.name).observe(dt_ms)


Span = span  # CamelCase alias


def observe_ms(name: str, dt_s: float) -> None:
    """Record an already-measured duration (seconds) into histogram
    ``name`` — for call sites that time across yields (generators) where
    a context manager can't bracket the work."""
    registry().histogram(name).observe(dt_s * 1e3)
