"""Durability cost benchmark: snapshot latency, WAL append overhead,
recovery time vs replay length.

The claims under test (docs/DESIGN.md §7):

- snapshots are cheap enough to pace from the serving loop (a full-state
  write is one host gather + sequential .npy writes, no device sync stalls)
- the write-ahead log costs <5% p50 on the ingest path (one CRC-framed
  append + fsync per facade call, amortised over the batch it covers)
- recovery time is snapshot restore + linear replay: bounded by how often
  the serving loop snapshots, not by index size

Rows:
  persistence/snapshot_write_{n}    us per full-state snapshot, corpus n
  persistence/snapshot_restore_{n}  us per restore (recover, empty tail)
  persistence/insert_{plain,durable}  p50 per-insert-batch wall us on a
                                    write stream; derived: WAL overhead %
  persistence/recover_tail_{r}      us to recover with r ops of log tail
                                    replayed on top of the base snapshot
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import timeit
from repro.configs import get_config
from repro.core import HMGIIndex
from repro.data.synthetic import make_corpus
from repro.persistence import DurableHMGIIndex, recover

DIM = 64
BATCH = 64


def _cfg():
    return get_config("hmgi").replace(
        n_partitions=32, n_probe=8, top_k=10, kmeans_iters=8,
        delta_capacity=2048, maint_auto=False)


def _ingest(idx, n):
    corpus = make_corpus(n_nodes=n, modality_dims={"text": DIM}, seed=0)
    idx.ingest({"text": (corpus.node_ids["text"], corpus.vectors["text"])},
               n_nodes=n + 64 * BATCH, edges=(corpus.src, corpus.dst))


def _write_stream(idx, steps, rng, base):
    """Applies ``steps`` insert batches; returns per-batch wall seconds."""
    stalls = []
    for s in range(steps):
        ids = (base + s * BATCH + np.arange(BATCH)).astype(np.int32)
        vecs = rng.standard_normal((BATCH, DIM)).astype(np.float32)
        t0 = time.perf_counter()
        idx.insert("text", ids, vecs)
        stalls.append(time.perf_counter() - t0)
    return stalls


def run(report) -> None:
    cfg = _cfg()

    # -- snapshot write / restore latency vs corpus size ---------------------
    for n in (1000, 4000):
        work = tempfile.mkdtemp(prefix="hmgi_pbench_")
        try:
            idx = DurableHMGIIndex(cfg, work, seed=0)
            _ingest(idx, n)
            # each trial must actually write: bump last_seq with a no-op-ish
            # tiny insert so snapshot() isn't skipped as unchanged
            rng = np.random.default_rng(1)

            def snap(i=[0]):
                i[0] += 1
                idx.insert("text", np.asarray([n + i[0]], np.int32),
                           rng.standard_normal((1, DIM)).astype(np.float32))
                return idx.snapshot()

            dt = timeit(snap, trials=3, warmup=1)
            report(f"persistence/snapshot_write_{n}", dt * 1e6)
            idx.close()
            dt = timeit(lambda: recover(cfg, work, seed=0).close(),
                        trials=3, warmup=1)
            report(f"persistence/snapshot_restore_{n}", dt * 1e6)
        finally:
            shutil.rmtree(work, ignore_errors=True)

    # -- WAL append overhead on the ingest path ------------------------------
    n, steps = 2000, 24
    # untimed warm-up stream on a throwaway index: the insert path retraces
    # as the delta fills, and both measured streams walk the same fill
    # sequence — without this, whichever stream runs first pays every
    # compile and the comparison measures XLA caching, not the WAL
    warm = HMGIIndex(cfg, seed=0)
    _ingest(warm, n)
    _write_stream(warm, steps, np.random.default_rng(2), n)
    plain = HMGIIndex(cfg, seed=0)
    _ingest(plain, n)
    s_plain = _write_stream(plain, steps, np.random.default_rng(2), n)
    p50_plain = float(np.median(s_plain))
    report("persistence/insert_plain", p50_plain * 1e6)
    # sync_every=1: every append fsyncs before returning (durable at return;
    # the fsync dominates the overhead). sync_every=16: group commit — the
    # p50 append only buffers, and this is where the <5% ingest-overhead
    # target holds (a crash loses at most 15 trailing ops, which were never
    # acknowledged as durable)
    for sync_every, tag in ((1, "durable"), (16, "durable_grouped")):
        work = tempfile.mkdtemp(prefix="hmgi_pbench_")
        try:
            dcfg = cfg.replace(wal_sync_every=sync_every)
            durable = DurableHMGIIndex(dcfg, work, seed=0)
            _ingest(durable, n)
            s_dur = _write_stream(durable, steps, np.random.default_rng(2), n)
            durable.close()
        finally:
            shutil.rmtree(work, ignore_errors=True)
        p50 = float(np.median(s_dur))
        overhead = (p50 - p50_plain) / p50_plain * 100.0
        report(f"persistence/insert_{tag}", p50 * 1e6,
               f"wal_overhead_pct={overhead:.2f}")

    # -- recovery time vs replayed-op count ----------------------------------
    for tail in (0, 16, 64):
        work = tempfile.mkdtemp(prefix="hmgi_pbench_")
        try:
            idx = DurableHMGIIndex(cfg, work, seed=0)
            _ingest(idx, n)
            idx.snapshot()
            _write_stream(idx, tail, np.random.default_rng(3), n)
            idx.close()
            dt = timeit(lambda: recover(cfg, work, seed=0).close(),
                        trials=3, warmup=1)
            report(f"persistence/recover_tail_{tail}", dt * 1e6)
        finally:
            shutil.rmtree(work, ignore_errors=True)
