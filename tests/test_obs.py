"""Observability layer: registry math, span semantics, exporters, wiring.

Covers the obs package contract: histogram bucket/quantile math against a
numpy oracle, nested span parenting and exception safety, registry reset
isolation, the zero-sync guarantee when ``obs_sync_spans`` is off, the
Prometheus exposition round-trip, and the integration points (facade
``trace=``, ``metrics()["obs"]``, WAL histograms, staticcheck cleanliness
of the instrumented tree).
"""
import json
import subprocess
import sys
import os

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import obs
from repro.obs import metrics as obs_metrics


@pytest.fixture(autouse=True)
def _clean_registry():
    """Every test starts from an empty global registry with sync off."""
    obs.reset()
    obs.set_enabled(True)
    obs.set_sync_spans(False)
    yield
    obs.reset()
    obs.set_enabled(True)
    obs.set_sync_spans(False)


# ---------------------------------------------------------------- histograms
def test_histogram_buckets_match_manual_count(rng):
    h = obs.histogram("t.lat")
    xs = rng.gamma(2.0, 5.0, size=500)          # ms-ish latencies
    for x in xs:
        h.observe(x)
    cum = h.cumulative_buckets()
    for le, got in cum:
        assert got == int(np.sum(xs <= le)), f"bucket le={le}"
    assert cum[-1][1] == len(xs)                 # +inf holds everything
    assert h.count == len(xs)
    assert h.total == pytest.approx(float(np.sum(xs)))
    assert h.vmax == pytest.approx(float(np.max(xs)))


def test_histogram_quantiles_match_numpy_oracle(rng):
    h = obs.histogram("t.q")
    xs = rng.normal(50.0, 10.0, size=1000)
    for x in xs:
        h.observe(x)
    for p in (50, 90, 99, 0, 100, 37.5):
        assert h.percentile(p) == pytest.approx(float(np.percentile(xs, p)))


def test_histogram_window_keeps_newest(rng):
    """Past the ring window, quantiles are over the newest `window`
    observations — old samples age out."""
    h = obs.histogram("t.w")
    n = obs_metrics.DEFAULT_WINDOW
    for _ in range(n):
        h.observe(1.0)
    for _ in range(n):
        h.observe(100.0)
    assert h.percentile(50) == pytest.approx(100.0)   # old 1.0s aged out
    assert h.count == 2 * n                           # totals never age
    assert h.cumulative_buckets()[-1][1] == 2 * n


def test_histogram_empty_and_bad_buckets():
    h = obs.histogram("t.e")
    assert np.isnan(h.percentile(50))
    assert np.isnan(h.summary()["p99"])
    with pytest.raises(ValueError):
        obs_metrics.Histogram("bad", buckets=(5.0, 1.0, float("inf")))
    with pytest.raises(ValueError):
        obs_metrics.Histogram("bad", buckets=(1.0, 5.0))   # no +inf


def test_counter_gauge_and_disable():
    obs.counter("t.c").inc()
    obs.counter("t.c").inc(3)
    obs.gauge("t.g").set(7)
    assert obs.registry().counter("t.c").value == 4
    assert obs.registry().gauge("t.g").value == 7
    obs.set_enabled(False)
    obs.counter("t.c").inc(100)
    obs.gauge("t.g").set(0)
    obs.histogram("t.h").observe(1.0)
    obs.set_enabled(True)
    snap = obs.snapshot()
    assert snap["counters"]["t.c"] == 4          # disabled writes dropped
    assert snap["gauges"]["t.g"] == 7
    assert snap["histograms"]["t.h"]["count"] == 0


def test_registry_reset_between_tests_part1():
    obs.counter("leak.check").inc()


def test_registry_reset_between_tests_part2():
    # runs after part1; the autouse fixture must have wiped its counter
    assert "leak.check" not in obs.snapshot()["counters"]


# --------------------------------------------------------------------- spans
def test_span_records_duration_histogram():
    with obs.span("t.span"):
        pass
    h = obs.registry().histogram("t.span")
    assert h.count == 1
    assert h.vmax >= 0.0


def test_nested_span_parenting():
    with obs.trace() as t:
        with obs.span("outer"):
            with obs.span("inner.a"):
                pass
            with obs.span("inner.b"):
                with obs.span("leaf"):
                    pass
    root = t.root
    assert root.name == "outer"
    assert [c.name for c in root.children] == ["inner.a", "inner.b"]
    assert [c.name for c in root.children[1].children] == ["leaf"]
    assert t.find("leaf") is not None
    # every node carries a recorded duration
    assert all(np.isfinite(n.duration_ms)
               for n in [root, *root.children, root.children[1].children[0]])
    # the render is one line per span, indented by depth
    lines = t.render().splitlines()
    assert len(lines) == 4 and lines[0].startswith("outer")
    assert lines[1].startswith("  inner.a")


def test_span_closed_and_recorded_on_raise():
    with pytest.raises(RuntimeError):
        with obs.trace() as t:
            with obs.span("boom"):
                raise RuntimeError("x")
    h = obs.registry().histogram("boom")
    assert h.count == 1                          # duration still recorded
    node = t.find("boom")
    assert node.error == "RuntimeError"
    # the per-thread stack unwound: a fresh span is a root again
    with obs.trace() as t2:
        with obs.span("after"):
            pass
    assert t2.root.name == "after"


def test_spans_without_trace_still_feed_registry():
    with obs.span("untraced"):
        pass
    assert obs.registry().histogram("untraced").count == 1


def test_fence_noop_when_sync_off(monkeypatch):
    """obs_sync_spans off: span exit must never call block_until_ready —
    the zero-overhead contract for always-on instrumentation."""
    import jax
    calls = []
    monkeypatch.setattr(jax, "block_until_ready",
                        lambda x: calls.append(1) or x)
    with obs.span("t.f") as sp:
        assert sp.fence("value") == "value"      # passthrough either way
    assert calls == []
    obs.set_sync_spans(True)
    with obs.span("t.f") as sp:
        sp.fence("value")
    assert calls == [1]


# ----------------------------------------------------------------- exporters
def test_prometheus_roundtrip(rng):
    obs.counter("q.count").inc(5)
    obs.gauge("q.depth").set(3)
    h = obs.histogram("q.lat")
    xs = rng.gamma(2.0, 5.0, size=200)
    for x in xs:
        h.observe(x)
    text = obs.render_prometheus()
    back = obs.parse_prometheus(text)
    assert back["counters"]["hmgi_q_count"] == 5
    assert back["gauges"]["hmgi_q_depth"] == 3
    hb = back["histograms"]["hmgi_q_lat"]
    assert hb["count"] == 200
    assert hb["sum"] == pytest.approx(float(np.sum(xs)), rel=1e-6)
    assert hb["buckets"] == h.cumulative_buckets()
    # exposition shape: cumulative, ends at +Inf == count
    les = [le for le, _ in hb["buckets"]]
    assert les == sorted(les) and les[-1] == float("inf")
    assert hb["buckets"][-1][1] == hb["count"]


def test_snapshot_is_json_serialisable():
    obs.counter("j.c").inc()
    obs.histogram("j.h").observe(1.5)
    out = json.loads(json.dumps(obs.snapshot()))
    assert out["histograms"]["j.h"]["count"] == 1


# ------------------------------------------------------------- facade wiring
@pytest.fixture(scope="module")
def small_index():
    from repro.configs import get_config
    from repro.core import HMGIIndex
    rng = np.random.default_rng(7)
    cfg = get_config("hmgi").replace(
        modalities=("text",), n_partitions=4, n_probe=4, kmeans_iters=4,
        top_k=5, delta_capacity=64)
    idx = HMGIIndex(cfg, seed=0)
    vecs = rng.normal(size=(128, cfg.dim)).astype(np.float32)
    edges = (np.arange(128), (np.arange(128) + 1) % 128)
    idx.ingest({"text": (np.arange(128), vecs)}, n_nodes=128, edges=edges)
    return idx, vecs


def test_search_trace_option(small_index):
    idx, vecs = small_index
    q = vecs[:2]
    sv, si = idx.search(q, "text", k=5)           # default: 2-tuple compat
    sv2, si2, t = idx.search(q, "text", k=5, trace=True)
    np.testing.assert_array_equal(np.asarray(si), np.asarray(si2))
    names = {n.name for n in t.roots}
    assert names == {"query.plan", "query.execute"}
    assert t.find("query.seed_scan") is not None
    assert "query.execute" in t.render()


def test_hybrid_search_trace_spans(small_index):
    idx, vecs = small_index
    _, _, t = idx.hybrid_search(vecs[:2], "text", k=5, n_hops=1, trace=True)
    for name in ("query.plan", "query.execute", "query.seed_scan",
                 "query.traversal", "query.fusion"):
        assert t.find(name) is not None, name


def test_metrics_obs_section_and_registry_population(small_index):
    idx, vecs = small_index
    idx.search(vecs[:2], "text", k=5)
    m = idx.metrics()
    hs = m["obs"]["histograms"]
    assert hs["query.execute"]["count"] >= 1
    assert np.isfinite(hs["query.execute"]["p50"])
    assert "query.seed_scan" in hs


def test_progressive_rounds_counter(small_index):
    from repro.core.progressive import progressive_search
    idx, vecs = small_index
    m = idx.modalities["text"]
    results = list(progressive_search(m.ivf, vecs[:2], k=5,
                                      probe_schedule=(1, 2, 4)))
    assert len(results) == 3
    assert obs.registry().counter("progressive.rounds").value == 3
    assert obs.registry().histogram("progressive.round").count == 3
    # elapsed is accumulated *work* time: monotone across rounds
    els = [r.elapsed_s for r in results]
    assert els == sorted(els) and els[0] > 0


def test_wal_histograms_populate(tmp_path):
    from repro.persistence.oplog import OpLog
    log = OpLog(str(tmp_path), sync_every=2)
    for i in range(4):
        log.append("op", {"i": i}, {"a": np.arange(3, dtype=np.int32)})
    log.close()
    reg = obs.registry()
    assert reg.histogram("wal.append").count == 4
    assert reg.histogram("wal.fsync").count == 2      # group commit of 2
    assert reg.histogram("wal.sync_batch").percentile(50) == 2.0


def test_staticcheck_all_stays_clean():
    """The instrumented tree (obs/ is in the HMG001 hot-path set) passes
    the full lint+trace+budget gate."""
    repo = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    r = subprocess.run([sys.executable, "-m", "tools.staticcheck", "--all"],
                       cwd=repo, env=env, capture_output=True, text=True,
                       timeout=600)
    assert r.returncode == 0, f"staticcheck --all failed:\n{r.stdout}\n{r.stderr}"
