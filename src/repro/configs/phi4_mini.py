"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA kv=8.  [arXiv:2412.08905; hf]"""
from repro.configs.base import LMConfig
from repro.configs.lm_shapes import lm_shapes

CONFIG = LMConfig(
    arch_id="phi4-mini-3.8b",
    source="arXiv:2412.08905; hf",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    tie_embeddings=True,
    rope_theta=10_000.0,
    # 24 heads don't divide a 16-way "model" axis: phi4 uses context-parallel
    # attention + TP mlp instead of head-sharding (docs/DESIGN.md §5)
    sharding_overrides={"heads": None, "kv_heads": None, "seq_attn": "model"},
)

SHAPES = lm_shapes(long_ok=False)
