"""Sparse–dense reranking (paper §3.4: "+20% recall uplift via sparse matrix
fusion").

Dense candidates from the IVF/NSW search are re-scored with a sparse lexical
signal: hashed-term vectors (a CSR-free fixed-width representation — each doc
keeps its ``nnz`` strongest hashed terms) combined with the dense score by
reciprocal-rank fusion (robust to score-scale mismatch, per Exp4Fuse).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class SparseVectors(NamedTuple):
    term_ids: jax.Array      # (N, nnz) int32, -1 padded — hashed term ids
    term_weights: jax.Array  # (N, nnz) fp32


def sparse_overlap_scores(docs: SparseVectors, q_terms: jax.Array,
                          q_weights: jax.Array, cand_ids: jax.Array) -> jax.Array:
    """Sparse dot-product between a query's hashed terms and candidate docs.

    q_terms: (T,) int32; cand_ids: (Q, k) rows into docs. Returns (Q, k)."""
    d_ids = docs.term_ids[jnp.clip(cand_ids, 0, docs.term_ids.shape[0] - 1)]
    d_w = docs.term_weights[jnp.clip(cand_ids, 0, docs.term_ids.shape[0] - 1)]
    # (Q, k, nnz, T) match matrix — nnz and T are small (≤32)
    match = (d_ids[..., :, None] == q_terms[None, None, None, :])
    match = jnp.logical_and(match, d_ids[..., :, None] >= 0)
    contrib = d_w[..., :, None] * q_weights[None, None, None, :]
    s = jnp.sum(jnp.where(match, contrib, 0.0), axis=(-1, -2))
    return jnp.where(cand_ids >= 0, s, -jnp.inf)


def rrf_rerank(dense_scores: jax.Array, sparse_scores: jax.Array,
               cand_ids: jax.Array, *, k: int, c: float = 60.0,
               w_dense: float = 1.0, w_sparse: float = 1.0
               ) -> Tuple[jax.Array, jax.Array]:
    """Reciprocal-rank fusion of the two orderings; returns (scores, ids)."""
    def ranks(s):
        order = jnp.argsort(-s, axis=-1)
        rk = jnp.argsort(order, axis=-1).astype(jnp.float32)
        return rk
    rd = ranks(dense_scores)
    rs = ranks(sparse_scores)
    fused = w_dense / (c + rd) + w_sparse / (c + rs)
    fused = jnp.where(cand_ids >= 0, fused, -jnp.inf)
    vals, pos = jax.lax.top_k(fused, min(k, fused.shape[-1]))
    return vals, jnp.take_along_axis(cand_ids, pos, axis=-1)


def hash_terms(tokens: jax.Array, n_buckets: int) -> jax.Array:
    """Cheap multiplicative hash of token ids into term buckets."""
    return ((tokens.astype(jnp.uint32) * jnp.uint32(2654435761)) >>
            jnp.uint32(16)).astype(jnp.int32) % n_buckets
