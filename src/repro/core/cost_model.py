"""Learned cost model and plan selection (paper Eq. 5 + §3.6).

    C = α·log N + β·(d·h) + γ·p·log(N/p)

α, β, γ are calibrated by least squares against measured query latencies
(the benchmark harness emits (features, latency) pairs). ``select_plan``
greedily picks the cheapest plan satisfying the recall constraint — the
paper's "greedy plan selection with optimality bounds".
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class CostModel:
    alpha: float = 1.0
    beta: float = 0.01
    gamma: float = 0.1

    def cost(self, n: int, d: int, h: int, p: int) -> float:
        """Eq. 5. n=corpus size, d=dim, h=hops, p=partitions probed."""
        p = max(p, 1)
        return (self.alpha * math.log(max(n, 2))
                + self.beta * (d * h)
                + self.gamma * p * math.log(max(n / p, 2)))

    def features(self, n, d, h, p) -> np.ndarray:
        p = max(p, 1)
        return np.array([math.log(max(n, 2)), d * h, p * math.log(max(n / p, 2))])

    def fit(self, samples: Sequence[Tuple[int, int, int, int]],
            latencies: Sequence[float]) -> "CostModel":
        """Least-squares calibration of (α, β, γ) on measured latencies."""
        X = np.stack([self.features(*s) for s in samples])
        y = np.asarray(latencies, np.float64)
        coef, *_ = np.linalg.lstsq(X, y, rcond=None)
        self.alpha, self.beta, self.gamma = (float(c) for c in coef)
        return self

    def r2(self, samples, latencies) -> float:
        X = np.stack([self.features(*s) for s in samples])
        y = np.asarray(latencies, np.float64)
        pred = X @ np.array([self.alpha, self.beta, self.gamma])
        ss_res = float(np.sum((y - pred) ** 2))
        ss_tot = float(np.sum((y - y.mean()) ** 2)) + 1e-12
        return 1.0 - ss_res / ss_tot


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    name: str
    n_probe: int
    n_hops: int
    use_nsw_refine: bool = False
    use_rerank: bool = False
    expected_recall: float = 0.9


DEFAULT_PLANS: Tuple[QueryPlan, ...] = (
    QueryPlan("vector_fast", n_probe=2, n_hops=0, expected_recall=0.80),
    QueryPlan("vector_std", n_probe=8, n_hops=0, expected_recall=0.95),
    QueryPlan("hybrid_1hop", n_probe=4, n_hops=1, expected_recall=0.93),
    QueryPlan("hybrid_2hop", n_probe=8, n_hops=2, expected_recall=0.97),
    QueryPlan("hybrid_deep", n_probe=16, n_hops=3, use_rerank=True,
              expected_recall=0.99),
)


def select_plan(model: CostModel, *, n: int, d: int, min_recall: float,
                plans: Sequence[QueryPlan] = DEFAULT_PLANS) -> QueryPlan:
    """Greedy: cheapest plan whose expected recall clears the floor."""
    feasible = [p for p in plans if p.expected_recall >= min_recall]
    if not feasible:
        feasible = [max(plans, key=lambda p: p.expected_recall)]
    return min(feasible, key=lambda p: model.cost(n, d, p.n_hops, p.n_probe))
