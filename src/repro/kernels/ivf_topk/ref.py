"""Pure-jnp oracle for the ivf_topk kernel."""
from __future__ import annotations

import jax.numpy as jnp


def scan_topk_ref(queries, data_i8, vmin, scale, *, chunk: int = 128):
    """Dequantize fully, exact scores, per-chunk (max, argmax)."""
    q = queries.astype(jnp.float32)
    e = (data_i8.astype(jnp.float32) + 128.0) * scale[:, None] + vmin[:, None]
    scores = q @ e.T                                         # (Q, N)
    qn, n = scores.shape
    nchunks = n // chunk
    sc = scores.reshape(qn, nchunks, chunk)
    smax = jnp.max(sc, axis=-1)
    sarg = jnp.argmax(sc, axis=-1).astype(jnp.int32) + \
        (jnp.arange(nchunks, dtype=jnp.int32) * chunk)[None, :]
    return smax, sarg


def topk_from_chunks(chunk_max, chunk_arg, k: int):
    """Exact top-k over the chunk survivors (second stage, tiny).

    Clamps k to the available chunk count and pads (-inf, -1)."""
    import jax
    kk = min(k, chunk_max.shape[-1])
    vals, pos = jax.lax.top_k(chunk_max, kk)
    ids = jnp.take_along_axis(chunk_arg, pos, axis=-1)
    if kk < k:
        pad = k - kk
        vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
    return vals, ids
