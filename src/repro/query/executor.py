"""Staged executor for compiled query plans.

Each physical stage maps onto the existing jitted primitives — the IVF
probe (`ivf.search` via `delta.search_with_delta`), typed masked traversal
(`traversal.multi_hop_batch`), candidate-sparse fusion
(`index._fuse_candidates` / `fusion.fuse_topk_sparse`) — and threads one
fixed-shape (Q, C) candidate-set state ``(scores, ids)`` between stages:
scores descending, −inf on empty slots, ids −1 there. Stage widths are
static per compiled plan, so chains jit once per plan shape.

This module is also the one execution path behind the facade:
``HMGIIndex.search`` and ``hybrid_search`` compile the equivalent plan and
run it here (``run_seed`` is the former ``search`` body verbatim — probe
assignment, workload recording, predicate pushdown vs the widening
oversample loop)."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import delta as delta_mod
from repro.core import graph_store as graph_mod
from repro.core import ivf as ivf_mod
from repro.core import nsw as nsw_mod
from repro.core import traversal as trav_mod
from repro.core.fusion import (FusionWeights, adaptive_weights,
                               fuse_topk_sparse, scatter_sim)
from repro.core.index import _fuse_candidates
from repro.core.partitioner import assign_topk
from repro.common.shapes import pow2_round
from repro.kernels.ivf_topk.ref import pad_topk
from repro.query.planner import (PhysicalPlan, PRescore, PSeed, PSetOp,
                                 PTraverse)

State = Tuple[jax.Array, jax.Array]      # (scores (Q, C), ids (Q, C))


def _topk_state(sv: jax.Array, si: jax.Array, k: int) -> State:
    """The one spelling of the candidate-state sort/truncate contract:
    top-k scores descending, ids gathered along, −1 wherever the score is
    −inf (empty slots must never leak a masked id)."""
    vals, pos = jax.lax.top_k(sv, k)
    ids = jnp.take_along_axis(si, pos, axis=1)
    return vals, jnp.where(jnp.isfinite(vals), ids, -1)


# ------------------------------------------------------------------ seed scan
def search_raw(index, m, q: jax.Array, probes, n_probe: int, k: int,
               node_pass=None, impl: str = "auto", sharded=None) -> State:
    """One stable+delta scan round (centroids pre-scored in ``probes``),
    with the optional NSW refine lane (MVCC-visibility- and
    predicate-masked). ``sharded`` (an ivf.shard_index replica) routes the
    stable scan through the row-sharded path — same masks, same probes,
    same merged results, the flops spread over the mesh's db axes."""
    if sharded is not None:
        scores, ids = delta_mod.search_with_delta_sharded(
            sharded, m.delta, q, index.mesh, n_probe=n_probe, k=k,
            rescore_margin=index.cfg.delta_rescore_margin, probes=probes,
            node_pass=node_pass, impl=impl, mvcc_filter=m.has_dead)
    else:
        scores, ids = delta_mod.search_with_delta(
            m.ivf, m.delta, q, n_probe=n_probe, k=k,
            rescore_margin=index.cfg.delta_rescore_margin, probes=probes,
            node_pass=node_pass, impl=impl, mvcc_filter=m.has_dead)
    if index.cfg.use_nsw_refine and m.nsw is not None:
        ns, ni = nsw_mod.search(m.nsw, q, ef=index.cfg.nsw_ef, k=k)
        ni = jnp.where(ni >= 0, m.ids[jnp.clip(ni, 0, m.ids.shape[0] - 1)], -1)
        # the NSW layer indexes ingest-time rows: apply the same MVCC
        # visibility rules as the stable scan (deletes and superseded
        # versions must not resurface through the refine lane) plus the
        # predicate mask
        dead = jnp.logical_or(m.delta.tombstones, m.delta.superseded)
        ok = jnp.logical_and(
            ni >= 0, ~dead[jnp.clip(ni, 0, dead.shape[0] - 1)])
        if node_pass is not None:
            ok = jnp.logical_and(ok, graph_mod.mask_pass(node_pass, ni))
        ns = jnp.where(ok, ns, -jnp.inf)
        ni = jnp.where(ok, ni, -1)
        scores, ids = ivf_mod.dedup_merge_topk(scores, ids, ns, ni, k)
        ids = jnp.where(jnp.isfinite(scores), ids, -1)
    return scores, ids


def run_seed(index, s: PSeed, node_pass) -> State:
    """ANNS seed stage. Unfiltered, or per the compiled filter plan:
    *pushdown* folds the predicate into the scan validity masks pre-top-k;
    *oversample* scans unfiltered at k_scan and widens (doubling, pow2
    jit-stable) until every query has k qualifying survivors — exact at
    full probe either way (the unfiltered top-k_scan is descending, so once
    k rows pass they are the filtered top-k over everything probed)."""
    m = index.modalities[s.modality]
    q = s.query
    n_probe = min(s.n_probe, m.ivf.n_partitions)
    k = s.k
    # the planner's device-layout choice: resolve the row-sharded replica
    # once per seed stage (built lazily, cached until the stable changes)
    sharded = (index._ensure_sharded(s.modality, s.layout.n_shards)
               if s.layout.layout == "sharded" else None)
    # centroids are scored once per batch: the same assignment feeds the
    # workload tracker and (as precomputed probes) every shard's IVF scan
    probes, _ = assign_topk(q, m.ivf.centroids, n_probe)
    if m.workload is not None:
        m.workload.record(np.asarray(probes))
    if node_pass is None:
        return search_raw(index, m, q, probes, n_probe, k, impl=s.impl,
                          sharded=sharded)
    index._metrics["filter_selectivity"] = s.filter_plan.selectivity
    index._metrics["filter_mode"] = s.filter_plan.mode
    if s.filter_plan.mode == "prefilter":
        return search_raw(index, m, q, probes, n_probe, k,
                          node_pass=node_pass, impl=s.impl, sharded=sharded)
    k_max = min(int(m.ids.shape[0]),
                n_probe * m.ivf.capacity + m.delta.ids.shape[0])
    # pow2-round: k_scan is a static jit arg, so raw selectivity-derived
    # widths would recompile the scan pipeline per distinct batch
    k_scan = min(max(k, pow2_round(s.filter_plan.k_scan)), k_max)
    while True:
        sv, si = search_raw(index, m, q, probes, n_probe, k_scan, impl=s.impl,
                            sharded=sharded)
        ok = graph_mod.mask_pass(node_pass, si)
        sv = jnp.where(ok, sv, -jnp.inf)
        if k_scan >= k_max:
            break
        if int(jnp.min(jnp.sum(ok, axis=1))) >= k:
            break
        k_scan = min(2 * k_scan, k_max)
    vals, ids = _topk_state(sv, si, min(k, sv.shape[1]))
    return pad_topk(vals, ids, k)


# ------------------------------------------------------------- traverse+fuse
def run_traverse(index, t: PTraverse, sv: jax.Array, si: jax.Array,
                 node_pass) -> State:
    """h-hop traversal seeded by the current candidate set, fused back into
    the scores (Eq. 3) via the compiled representation: candidate-sparse
    (seeds ∪ frontier) or dense (all N). hops=0 passes the set through."""
    if t.n_hops == 0:
        return sv, si
    cfg = index.cfg
    g = index.graph
    if index.boosted_weights is not None:
        g = g._replace(edge_weight=index.boosted_weights)
    with obs.span("query.traversal") as sp:
        graph_scores = sp.fence(trav_mod.multi_hop_batch(
            g, si, sv, n_hops=t.n_hops, edge_type_mask=t.edge_type_mask,
            node_mask=node_pass, damping=t.damping))                # (Q, N)
    with obs.span("query.fusion") as sp:
        w = (adaptive_weights(sv, base_wv=cfg.w_vector, base_wg=cfg.w_graph)
             if cfg.adaptive_weights else
             FusionWeights(jnp.full((sv.shape[0],), cfg.w_vector),
                           jnp.full((sv.shape[0],), cfg.w_graph)))
        if t.repr == "sparse":
            out = _fuse_candidates(sv, si, graph_scores, w.w_vector,
                                   w.w_graph, k_fuse=t.k_fuse,
                                   frontier=t.frontier, node_pass=node_pass)
        else:
            out = _fuse_dense(sv, si, graph_scores, w.w_vector, w.w_graph,
                              k_fuse=t.k_fuse, node_pass=node_pass)
        return sp.fence(out)


@functools.partial(jax.jit, static_argnames=("k_fuse",))
def _fuse_dense(sv, si, graph_scores, wv, wg, *, k_fuse: int, node_pass=None):
    """Dense fusion representation: one scatter of the candidate sims over
    all N nodes (positions are ids), then Eq. 3 + top-k_fuse. Chosen by the
    planner when the sparse frontier would cover the corpus anyway."""
    sim_full = scatter_sim(graph_scores.shape[1], si, sv)
    valid = (None if node_pass is None else
             jnp.broadcast_to(node_pass[None, :], graph_scores.shape))
    vals, pos = fuse_topk_sparse(sim_full, graph_scores,
                                 FusionWeights(wv, wg), k_fuse, valid=valid)
    return vals, jnp.where(jnp.isfinite(vals), pos, -1)


# --------------------------------------------------------------- cross-modal
def run_rescore(index, r: PRescore, sv: jax.Array, si: jax.Array) -> State:
    m = index.modalities[r.modality]
    # the id->row map only changes when the modality gains new ids — cache
    # it (an O(n_nodes) scatter per query would dwarf the re-score einsum).
    # The build is double-checked under the index's cache lock: concurrent
    # search threads share one published map instead of racing the build.
    rows = index._modality_id_rows(r.modality)
    return _rescore(r.query, m.vectors, rows, m.delta.tombstones,
                    sv, si, jnp.float32(r.weight))


@functools.partial(jax.jit, static_argnames=("n_nodes",))
def _modality_rows(ids: jax.Array, n_nodes: int) -> jax.Array:
    """(n_nodes,) global-id -> row map for one modality (-1 = no embedding)."""
    rows = jnp.full((n_nodes,), -1, jnp.int32)
    return rows.at[jnp.clip(ids, 0, n_nodes - 1)].set(
        jnp.arange(ids.shape[0], dtype=jnp.int32))


@jax.jit
def _rescore(q2, vectors, rows, tombstones, sv, si, weight):
    """new = (1-w)·current + w·sim2 over the fp32 master rows of the second
    modality (latest versions — updates rewrite them in place); candidates
    without an embedding there — never ingested, or deleted (tombstoned
    ids must not contribute their dead vector) — read sim2 = 0.
    Width-preserving, re-sorted descending."""
    rr = rows[jnp.clip(si, 0, rows.shape[0] - 1)]
    present = jnp.logical_and(si >= 0, rr >= 0)
    present = jnp.logical_and(
        present, ~tombstones[jnp.clip(si, 0, tombstones.shape[0] - 1)])
    vecs = vectors[jnp.clip(rr, 0, vectors.shape[0] - 1)]       # (Q, C, d2)
    sim2 = jnp.einsum("qd,qcd->qc", q2, vecs)
    sim2 = jnp.where(present, sim2, 0.0)
    new = jnp.where(jnp.isfinite(sv),
                    (1.0 - weight) * sv + weight * sim2, -jnp.inf)
    return _topk_state(new, si, new.shape[1])


# ------------------------------------------------------------------- set ops
def run_setop(index, op: PSetOp) -> State:
    la, li = execute(index, op.left)
    ra, ri = execute(index, op.right)
    return (_union if op.kind == "union" else _intersect)(la, li, ra, ri)


@jax.jit
def _union(sa, ia, sb, ib):
    """ids from either side; duplicate ids keep their higher score."""
    vals, ids = ivf_mod.dedup_merge_topk(sa, ia, sb, ib,
                                         sa.shape[1] + sb.shape[1])
    return vals, jnp.where(jnp.isfinite(vals), ids, -1)


@jax.jit
def _intersect(sa, ia, sb, ib):
    """ids live on both sides; score = mean of the two sides' scores."""
    match = jnp.logical_and(ia[:, :, None] == ib[:, None, :],
                            ia[:, :, None] >= 0)
    match = jnp.logical_and(match, jnp.isfinite(sb)[:, None, :])
    sb_at = jnp.max(jnp.where(match, sb[:, None, :], -jnp.inf), axis=-1)
    both = jnp.logical_and(jnp.isfinite(sa), jnp.isfinite(sb_at))
    s = jnp.where(both, 0.5 * (sa + sb_at), -jnp.inf)
    return _topk_state(s, ia, s.shape[1])


@jax.jit
def _post_filter(sv, si, node_pass):
    """Outer Where over a set-op source: branches fixed their candidate
    sets already, so the merged set is post-filtered (and later stages
    still carry the mask)."""
    ok = graph_mod.mask_pass(node_pass, si)
    return _topk_state(jnp.where(ok, sv, -jnp.inf), si, sv.shape[1])


# ------------------------------------------------------- serving micro-batch
def search_bucketed(index, queries, modality: str, *, k: int,
                    n_probe: Optional[int] = None, where=None,
                    n_hops: int = 0, impl: str = "auto",
                    floor: int = 2) -> Tuple[np.ndarray, np.ndarray]:
    """The cross-request retrieval entry: one ``(B, k)`` jitted call over
    the pow2 bucket ``B = pow2_round(Q, lo=floor)``, rows sliced back to Q.

    Padding replicates row 0 — every per-row computation in the pipeline
    (probe assignment, scan, top-k, traversal, fusion, rescore) is
    row-separable at fixed shape, so pad-row *content* cannot influence a
    real row's result, and bucketing keeps the set of compiled shapes
    O(log max_batch) (HMG102/HMG103 budgets stay flat).

    The floor of 2 is load-bearing for bit-exactness: XLA:CPU specialises
    the Q=1 contraction differently from Q>=2 (last-bit float divergence in
    the fp32 rescore), while every B>=2 bucket computes rows identically.
    With the floor, a request retrieved solo and the same request
    co-batched with 63 others return byte-identical results — the oracle
    contract tests/test_serving_batch.py pins.

    Shared probe work is amortised structurally: ``run_seed`` scores the
    centroids once per batch (one ``assign_topk`` feeds every co-batched
    query's IVF scan), so Q requests pay one probe-assignment pass."""
    q = np.asarray(queries, np.float32)
    if q.ndim == 1:
        q = q[None]
    n_q = q.shape[0]
    bucket = pow2_round(n_q, lo=max(int(floor), 1))
    if bucket != n_q:
        q = np.concatenate(
            [q, np.broadcast_to(q[:1], (bucket - n_q,) + q.shape[1:])])
    if n_hops > 0:
        sv, si = index.hybrid_search(q, modality, k=k, n_hops=n_hops,
                                     n_probe=n_probe, where=where)
    else:
        sv, si = index.search(q, modality, k=k, n_probe=n_probe,
                              where=where, impl=impl)
    return np.asarray(sv)[:n_q], np.asarray(si)[:n_q]


# ----------------------------------------------------------------- execution
def run_topk(sv: jax.Array, si: jax.Array, k: int) -> State:
    """Terminal truncation to k (padded with (−inf, −1) past the width)."""
    vals, ids = _topk_state(sv, si, min(k, sv.shape[1]))
    return pad_topk(vals, ids, k)


def execute(index, phys: PhysicalPlan, *, truncate: bool = True) -> State:
    """Runs a compiled plan. truncate=False returns the last stage's full
    candidate set (the facade's rerank lane re-scores it before cutting)."""
    with obs.span("query.execute") as root:
        if isinstance(phys.source, PSetOp):
            with obs.span("query.setop") as sp:
                sv, si = sp.fence(run_setop(index, phys.source))
                if phys.node_pass is not None:
                    sv, si = sp.fence(
                        _post_filter(sv, si, phys.node_pass))
        else:
            with obs.span("query.seed_scan") as sp:
                sv, si = sp.fence(
                    run_seed(index, phys.source, phys.node_pass))
        for st in phys.stages:
            if isinstance(st, PTraverse):
                sv, si = run_traverse(index, st, sv, si, phys.node_pass)
            else:
                with obs.span("query.cross_modal") as sp:
                    sv, si = sp.fence(run_rescore(index, st, sv, si))
        if truncate:
            sv, si = run_topk(sv, si, phys.k)
        return root.fence((sv, si))
