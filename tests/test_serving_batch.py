"""Serving micro-batch oracle: a ``(Q, k)`` bucketed retrieval must be
bit-identical to Q sequential ``(1, k)`` retrievals through the same
serving entry, for every bucket size, stable+delta, with and without
``where=`` — and the cross-request ``MicroBatcher`` must preserve that
contract under real concurrency, including mixed-plan batches (which fall
back to one bucketed call per plan group) and exact-duplicate dedup.

The bucketed entry (``search_bucketed``) pads every batch to a pow2
bucket >= 2: XLA:CPU specialises the Q=1 contraction differently from
Q>=2 (last-bit fp divergence), but for every Q>=2 each row's result is
composition-independent — so the floor-2 pad makes solo and co-batched
requests byte-identical. One case is also pinned to the brute-force
``query_ref`` oracle so the whole stack stays semantically grounded, not
just self-consistent.
"""
import threading

import numpy as np
import pytest

from repro import obs
from repro.configs import get_config
from repro.core import HMGIIndex
from repro.query import Q
from repro.query.executor import search_bucketed
from repro.query.planner import compile_plan
from repro.serving.retrieval import (MicroBatcher, RetrievalPlan,
                                     RetrievalService, freeze_where,
                                     run_plan)

from query_ref import assert_matches, reference_execute

N = 260
D = 24
K = 8


def _unit(v):
    return v / np.linalg.norm(v, axis=-1, keepdims=True)


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(7)
    vt = _unit(rng.normal(size=(N, D)).astype(np.float32))
    year = rng.integers(2000, 2030, N).astype(np.int32)
    e = 1500
    src = rng.integers(0, N, e).astype(np.int32)
    dst = rng.integers(0, N, e).astype(np.int32)
    keep = src != dst
    # full probe so the query_ref pin is exact; delta rows on top of the
    # stable build so every sweep covers the stable+delta merge path
    cfg = get_config("hmgi").replace(
        n_partitions=8, n_probe=8, top_k=K, kmeans_iters=6,
        delta_capacity=128, delta_rescore_margin=64)
    idx = HMGIIndex(cfg, seed=0)
    ids = np.arange(N, dtype=np.int32)
    idx.ingest({"text": (ids, vt)}, n_nodes=N,
               edges=(src[keep], dst[keep]), node_attrs={"year": year})
    upd = _unit(rng.normal(size=(6, D)).astype(np.float32))
    idx.insert("text", np.arange(6, dtype=np.int32), upd)
    queries = _unit(vt[40:104] + 0.05 * rng.normal(size=(64, D))
                    .astype(np.float32)).astype(np.float32)
    return idx, queries


def _solo(idx, plan, queries):
    """Q sequential (1, k) retrievals through the serving entry."""
    rows = [run_plan(idx, plan, queries[i:i + 1])
            for i in range(queries.shape[0])]
    return (np.concatenate([r[0] for r in rows]),
            np.concatenate([r[1] for r in rows]))


class TestBucketOracle:
    @pytest.mark.parametrize("nq", [1, 2, 3, 4, 7, 8, 16, 32, 33, 64])
    def test_batched_matches_sequential(self, setup, nq):
        idx, queries = setup
        plan = RetrievalPlan(modality="text", k=K)
        bv, bi = run_plan(idx, plan, queries[:nq])
        sv, si = _solo(idx, plan, queries[:nq])
        assert bv.tobytes() == sv.tobytes()
        assert bi.tobytes() == si.tobytes()

    @pytest.mark.parametrize("thresh", [2004, 2027])
    @pytest.mark.parametrize("nq", [1, 3, 8])
    def test_where_both_planner_modes(self, setup, nq, thresh):
        """Low threshold = pushdown, high = oversample — the bucket
        contract must hold in both planner filter modes."""
        idx, queries = setup
        plan = RetrievalPlan(modality="text", k=K,
                             where=freeze_where(("year", "<", thresh)))
        bv, bi = run_plan(idx, plan, queries[:nq])
        sv, si = _solo(idx, plan, queries[:nq])
        assert bv.tobytes() == sv.tobytes()
        assert bi.tobytes() == si.tobytes()

    @pytest.mark.parametrize("nq", [1, 5])
    def test_hybrid_hops(self, setup, nq):
        idx, queries = setup
        plan = RetrievalPlan(modality="text", k=K, n_hops=2)
        bv, bi = run_plan(idx, plan, queries[:nq])
        sv, si = _solo(idx, plan, queries[:nq])
        assert bv.tobytes() == sv.tobytes()
        assert bi.tobytes() == si.tobytes()

    def test_bucketed_matches_query_ref_oracle(self, setup):
        """Semantic grounding: the padded batch is not just internally
        consistent — at full probe it reproduces the brute-force
        reference over the 3-query (pad to 4) bucket."""
        idx, queries = setup
        q3 = queries[:3]
        sv, si = search_bucketed(idx, q3, "text", k=K)
        phys = compile_plan(idx, Q.vector("text", q3).topk(K))
        assert_matches((sv, si), reference_execute(idx, phys))

    def test_mutation_keeps_contract(self, setup):
        """Insert + delete between sweeps: the solo/batched identity is a
        property of the entry, not of one frozen index state."""
        idx, queries = setup
        rng = np.random.default_rng(13)
        plan = RetrievalPlan(modality="text", k=K)
        idx.insert("text", np.arange(10, 13, dtype=np.int32),
                   _unit(rng.normal(size=(3, D)).astype(np.float32)))
        idx.delete("text", np.array([40, 41], dtype=np.int32))
        for nq in (1, 4, 7):
            bv, bi = run_plan(idx, plan, queries[:nq])
            sv, si = _solo(idx, plan, queries[:nq])
            assert bv.tobytes() == sv.tobytes()
            assert bi.tobytes() == si.tobytes()


class TestMicroBatcher:
    def test_concurrent_riders_bit_identical(self, setup):
        """8 threads arriving inside one window must ride >= one shared
        batch and each get exactly its solo-request bytes."""
        idx, queries = setup
        obs.reset()
        plan = RetrievalPlan(modality="text", k=K)
        solo_v, solo_i = _solo(idx, plan, queries[:8])
        mb = MicroBatcher(idx, window_s=0.05, max_batch=64)
        results = [None] * 8
        barrier = threading.Barrier(8)

        def worker(i):
            barrier.wait()
            results[i] = mb.search(plan, queries[i:i + 1])

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
            assert not t.is_alive(), "micro-batch rider stalled"
        for i in range(8):
            assert results[i][0].tobytes() == solo_v[i:i + 1].tobytes()
            assert results[i][1].tobytes() == solo_i[i:i + 1].tobytes()
        h = obs.histogram("serving.batch_q", obs.COUNT_BUCKETS)
        assert h.count >= 1
        assert h.total / h.count > 1.0, "no cross-request batch formed"

    def test_mixed_plan_batch_falls_back_per_group(self, setup):
        """Two plans in one window: each group runs its own bucketed call
        and every rider still gets its own plan's solo bytes."""
        idx, queries = setup
        obs.reset()
        plans = [RetrievalPlan(modality="text", k=K),
                 RetrievalPlan(modality="text", k=K,
                               where=freeze_where(("year", "<", 2027)))]
        solo = [run_plan(idx, p, queries[i:i + 1])
                for i, p in enumerate(plans * 4)]
        mb = MicroBatcher(idx, window_s=0.05, max_batch=64)
        results = [None] * 8
        barrier = threading.Barrier(8)

        def worker(i):
            barrier.wait()
            results[i] = mb.search(plans[i % 2], queries[i:i + 1])

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
            assert not t.is_alive(), "mixed-plan rider stalled"
        for i in range(8):
            assert results[i][0].tobytes() == solo[i][0].tobytes()
            assert results[i][1].tobytes() == solo[i][1].tobytes()
        assert obs.counter("serving.batch.mixed_plan").value >= 1

    def test_exact_duplicate_queries_deduped(self, setup):
        """The same query bytes submitted by many threads compute once per
        batch; every rider still gets the full solo bytes."""
        idx, queries = setup
        obs.reset()
        plan = RetrievalPlan(modality="text", k=K)
        sv, si = run_plan(idx, plan, queries[:1])
        mb = MicroBatcher(idx, window_s=0.05, max_batch=64)
        results = [None] * 6
        barrier = threading.Barrier(6)

        def worker(i):
            barrier.wait()
            results[i] = mb.search(plan, queries[:1])

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
            assert not t.is_alive(), "dedup rider stalled"
        for i in range(6):
            assert results[i][0].tobytes() == sv.tobytes()
            assert results[i][1].tobytes() == si.tobytes()
        assert obs.counter("serving.batch.dedup_hits").value >= 1


class TestRetrievalService:
    def test_batched_and_unbatched_modes_identical(self, setup):
        idx, queries = setup
        plan = RetrievalPlan(modality="text", k=K)
        on = RetrievalService(idx, batching=True, window_s=0.0)
        off = RetrievalService(idx, batching=False)
        a = on.search(plan, queries[0])
        b = off.search(plan, queries[0])
        assert a[0].tobytes() == b[0].tobytes()
        assert a[1].tobytes() == b[1].tobytes()

    def test_search_many_matches_solo(self, setup):
        idx, queries = setup
        plan = RetrievalPlan(modality="text", k=K)
        svc = RetrievalService(idx, batching=False)
        got = svc.search_many(plan, queries[:5])
        assert got is not None
        sv, si = _solo(idx, plan, queries[:5])
        assert got[0].tobytes() == sv.tobytes()
        assert got[1].tobytes() == si.tobytes()
