"""Adaptive maintenance benchmark: ingest stall + post-maintenance latency.

The claim under test (docs/DESIGN.md §3.4): draining the delta in bounded
chunks interleaved with serving cuts the *worst-case* ingest stall vs. the
synchronous full compaction — while ending in an equivalently fast
searchable state.

Rows:
  maintenance/ingest_worst_{full,adaptive}  worst per-insert wall time over a
                                            write stream (us); the full path
                                            pays a whole-index rebuild on the
                                            batch that crosses the threshold
  maintenance/ingest_mean_{full,adaptive}   mean per-insert wall time (us)
  maintenance/query_post_{full,adaptive}    ms/query after the stream is fully
                                            drained on each path — must match
  maintenance/stall_speedup                 worst_full / worst_adaptive
"""
from __future__ import annotations

import time

import numpy as np
import jax

from benchmarks.common import make_queries, timeit
from repro.configs import get_config
from repro.core import HMGIIndex
from repro.data.synthetic import make_corpus

N_NODES = 4000
DIM = 64
STEPS = 12
BATCH = 96


def _build(mode: str):
    corpus = make_corpus(n_nodes=N_NODES, modality_dims={"text": DIM}, seed=0)
    cfg = get_config("hmgi").replace(
        n_partitions=32, n_probe=8, top_k=10, kmeans_iters=8,
        delta_capacity=1024, maint_auto=(mode == "adaptive"),
        maint_chunk=128, maint_budget_rows=256)
    idx = HMGIIndex(cfg, seed=0)
    idx.ingest({"text": (corpus.node_ids["text"], corpus.vectors["text"])},
               n_nodes=corpus.n_nodes + STEPS * BATCH,
               edges=(corpus.src, corpus.dst))
    return idx, corpus


def _block(idx):
    m = idx.modalities["text"]
    jax.block_until_ready((m.ivf.data, m.delta.vectors))


def _stream(idx, rng):
    """Streaming writes: new ids, updates of existing ids, a few deletes.
    Returns per-insert wall times (the stall distribution)."""
    stalls = []
    for step in range(STEPS):
        new_ids = (N_NODES + step * BATCH
                   + np.arange(BATCH // 2)).astype(np.int32)
        upd_ids = rng.integers(0, N_NODES, BATCH // 2).astype(np.int32)
        ids = np.concatenate([new_ids, upd_ids])
        vecs = rng.normal(size=(BATCH, DIM)).astype(np.float32)
        t0 = time.perf_counter()
        idx.insert("text", ids, vecs)
        _block(idx)
        stalls.append(time.perf_counter() - t0)
        idx.delete("text", rng.integers(0, N_NODES, 4).astype(np.int32))
    return np.array(stalls)


def run(report):
    results = {}
    for mode in ("full", "adaptive"):
        idx, corpus = _build(mode)
        q = make_queries(corpus, "text", n=64)
        # warm the jit caches outside the timed stream (both paths pay
        # their compile once; the stall comparison is steady-state)
        warm = np.random.default_rng(99)
        idx.insert("text", np.arange(2, dtype=np.int32) + N_NODES + 50_000,
                   warm.normal(size=(2, DIM)).astype(np.float32))
        idx.search(q[:8], "text", k=10)
        if mode == "full":
            idx.compact("text")
        else:
            idx.maintain("text", need_rows=2)

        rng = np.random.default_rng(7)
        stalls = _stream(idx, rng)
        report(f"maintenance/ingest_worst_{mode}", float(stalls.max() * 1e6),
               f"steps={STEPS}x{BATCH}")
        report(f"maintenance/ingest_mean_{mode}", float(stalls.mean() * 1e6))

        # finish draining on each path, then measure steady-state queries
        if mode == "full":
            idx.compact("text")
        else:
            while int(idx.modalities["text"].delta.count):
                r = idx.maintain("text", need_rows=256)
                if r.is_noop or all(
                        not (res.get("drained", 0) or res.get("reclaimed", 0))
                        for _, res in r.actions):
                    break
        t = timeit(lambda: idx.search(q, "text", k=10), trials=5)
        report(f"maintenance/query_post_{mode}", t * 1e6 / len(q),
               f"delta={int(idx.modalities['text'].delta.count)}")
        results[mode] = (float(stalls.max()), t)

    speedup = results["full"][0] / max(results["adaptive"][0], 1e-9)
    q_ratio = results["adaptive"][1] / max(results["full"][1], 1e-9)
    report("maintenance/stall_speedup", speedup,
           f"post-maintenance query ratio {q_ratio:.2f}x")


if __name__ == "__main__":
    def _p(name, us, derived=""):
        print(f"{name},{us:.3f},{derived}")
    run(_p)
