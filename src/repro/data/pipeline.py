"""Host data pipeline: deterministic sharded batches with background
prefetch and restart-safe skipping.

Determinism contract (fault tolerance): batch ``i`` is a pure function of
(seed, i), so a restarted trainer resumes mid-epoch by fast-forwarding the
step counter — no data-state checkpointing needed.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, Iterator, Optional

import numpy as np


class SyntheticLMStream:
    """Deterministic synthetic LM token stream (per-step fresh RNG)."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int, seed: int = 0):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq_len
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.integers(0, self.vocab, (self.batch, self.seq + 1), dtype=np.int64)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class SyntheticRecsysStream:
    def __init__(self, n_fields: int, vocab: int, batch: int, seed: int = 0):
        self.f, self.v, self.b, self.seed = n_fields, vocab, batch, seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        ids = rng.integers(0, self.v, (self.b, self.f), dtype=np.int64)
        # click labelled by a planted sparse rule so accuracy can move
        y = ((ids[:, 0] + ids[:, 1]) % 7 < 3).astype(np.int32)
        return {"ids": ids.astype(np.int32), "labels": y}


def _drain(q: Optional["queue.Queue"]) -> None:
    if q is None:
        return
    try:
        while True:
            q.get_nowait()
    except queue.Empty:
        pass


class Prefetcher:
    """Background-thread prefetch of ``stream.batch_at(step)``, yielding
    ``(step, batch)`` tuples in step order.

    Concurrency contract (guarded-by ``_lock``: ``q``/``step``/``_stop``/
    ``_thread`` — HMG201/HMG204): the worker receives its queue, stop
    event and start step as *arguments* and never reads them off ``self``,
    so restarts can swap them without publication races. ``close()`` stops
    the worker *before* the final drain: set the stop event, then
    drain-while-joining under a bounded deadline (the worker may be blocked
    mid-``put`` — draining unblocks it; a put landing after the last drain
    cannot happen because the join completes first). ``start()`` after
    ``close()`` resumes from the next unconsumed step — the restart path
    the determinism contract (batch ``i`` is a pure function of (seed, i))
    exists for.
    """

    JOIN_TIMEOUT_S = 5.0

    def __init__(self, stream, start_step: int = 0, depth: int = 2):
        self.stream = stream
        self.depth = depth
        self._lock = threading.Lock()
        self.q: Optional["queue.Queue"] = None
        self.step = start_step
        self._stop: Optional[threading.Event] = None
        self._thread: Optional[threading.Thread] = None
        self.start()

    def start(self) -> None:
        """(Re)start the worker from the next unconsumed step. Idempotent
        while a worker is alive."""
        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                return
            q: "queue.Queue" = queue.Queue(maxsize=self.depth)
            stop = threading.Event()
            t = threading.Thread(target=self._work, args=(stop, q, self.step),
                                 daemon=True)
            self.q = q
            self._stop = stop
            self._thread = t
            t.start()

    def _work(self, stop: threading.Event, q: "queue.Queue", s: int) -> None:
        while not stop.is_set():
            try:
                q.put((s, self.stream.batch_at(s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        with self._lock:
            q = self.q
        if q is None:
            raise StopIteration          # closed and not restarted
        item = q.get()                   # blocks OUTSIDE the lock (HMG202)
        with self._lock:
            self.step = item[0] + 1      # restart point: next unconsumed
        return item

    def close(self) -> None:
        """Stop the worker, join it (bounded), and leave the queue empty.
        Safe to call repeatedly; ``start()`` afterwards resumes."""
        with self._lock:
            thread, stop, q = self._thread, self._stop, self.q
            self._thread = None
        if stop is not None:
            stop.set()
        if thread is not None:
            deadline = time.monotonic() + self.JOIN_TIMEOUT_S
            while thread.is_alive() and time.monotonic() < deadline:
                _drain(q)                # unblock a worker stuck in put()
                thread.join(timeout=0.1)
            if thread.is_alive():
                raise RuntimeError(
                    "Prefetcher worker failed to stop within "
                    f"{self.JOIN_TIMEOUT_S}s")
        # worker has exited: nothing can enqueue after this drain
        _drain(q)
        with self._lock:
            if self.q is q:
                self.q = None
