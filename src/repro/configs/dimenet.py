"""dimenet [gnn] — directional message passing, triplet angular basis.  [arXiv:2003.03123]"""
from repro.configs.base import GNNConfig
from repro.configs.gnn_shapes import gnn_shapes

CONFIG = GNNConfig(
    arch_id="dimenet",
    source="arXiv:2003.03123; unverified",
    model="dimenet",
    n_layers=6,            # n_blocks
    d_hidden=128,
    n_bilinear=8,
    n_spherical=7,
    n_radial=6,
)

SHAPES = gnn_shapes()
