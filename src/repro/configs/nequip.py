"""nequip [gnn] — E(3)-equivariant tensor-product interatomic potential.  [arXiv:2101.03164]"""
from repro.configs.base import GNNConfig
from repro.configs.gnn_shapes import gnn_shapes

CONFIG = GNNConfig(
    arch_id="nequip",
    source="arXiv:2101.03164; paper",
    model="nequip",
    n_layers=5,
    d_hidden=32,
    l_max=2,
    n_rbf=8,
    cutoff=5.0,
)

SHAPES = gnn_shapes()
