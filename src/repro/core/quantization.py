"""Flash quantization (paper Eq. 2) with the adaptive bit-width policy.

    q = floor(levels * (e - min(e)) / (max(e) - min(e)))      per-vector affine

Supports 8-bit (int8 storage), 4-bit (two nibbles packed per int8) and 16-bit
(bf16 passthrough). ``AdaptiveQuantPolicy`` lowers the bit width when index
memory crosses the configured budget (paper: ">80% triggers 8-bit"), which is
the paper's 50%-memory-saving mechanism; on TPU it also halves/quarters HBM
traffic of the IVF scan (see kernels/ivf_topk).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["data", "vmin", "scale"], meta_fields=["bits", "dim"],
)
@dataclasses.dataclass
class QuantizedVectors:
    data: jax.Array      # int8: (N, d) for 8-bit, (N, ceil(d/2)) packed for 4-bit; bf16 for 16
    vmin: jax.Array      # (N, 1) fp32
    scale: jax.Array     # (N, 1) fp32: (max-min)/levels
    bits: int = 8
    dim: int = 0         # original d (4-bit packing pads odd dims)

    @property
    def nbytes(self) -> int:
        return (self.data.size * self.data.dtype.itemsize
                + self.vmin.size * 4 + self.scale.size * 4)


def quantize(e: jax.Array, bits: int = 8) -> QuantizedVectors:
    """Per-vector affine quantization (Eq. 2 generalised to 4/8/16 bits)."""
    d = e.shape[-1]
    if bits == 16:
        return QuantizedVectors(e.astype(jnp.bfloat16),
                                jnp.zeros((e.shape[0], 1), jnp.float32),
                                jnp.ones((e.shape[0], 1), jnp.float32), 16, d)
    ef = e.astype(jnp.float32)
    vmin = jnp.min(ef, axis=-1, keepdims=True)
    vmax = jnp.max(ef, axis=-1, keepdims=True)
    levels = (1 << bits) - 1
    scale = jnp.maximum(vmax - vmin, 1e-12) / levels
    q = jnp.clip(jnp.floor((ef - vmin) / scale), 0, levels)
    if bits == 8:
        data = (q - 128).astype(jnp.int8)                     # store centered
    elif bits == 4:
        if d % 2:
            q = jnp.pad(q, ((0, 0), (0, 1)))                  # pad odd dims
        qi = q.astype(jnp.uint8)
        lo, hi = qi[:, 0::2], qi[:, 1::2]
        data = (lo | (hi << 4)).astype(jnp.int8)
    else:
        raise ValueError(f"bits={bits}")
    return QuantizedVectors(data, vmin, scale, bits, d)


def dequantize(qv: QuantizedVectors) -> jax.Array:
    if qv.bits == 16:
        return qv.data.astype(jnp.float32)
    if qv.bits == 8:
        q = qv.data.astype(jnp.float32) + 128.0
    elif qv.bits == 4:
        u = qv.data.astype(jnp.uint8)
        lo = (u & 0xF).astype(jnp.float32)
        hi = (u >> 4).astype(jnp.float32)
        q = jnp.stack([lo, hi], axis=-1).reshape(u.shape[0], -1)
        if qv.dim and q.shape[-1] != qv.dim:
            q = q[:, : qv.dim]                                # drop pad column
    else:
        raise ValueError(qv.bits)
    return q * qv.scale + qv.vmin


def quantized_scores(queries: jax.Array, qv: QuantizedVectors) -> jax.Array:
    """Dot-product scores without materialising dequantized vectors:

        q · e  =  scale_e * (q · qint)  +  min_e * sum(q)

    (the identity the fused Pallas kernel exploits; here in jnp for the oracle
    and the GSPMD path). queries: (Q, d) -> (Q, N).
    """
    if qv.bits == 16:
        return queries.astype(jnp.float32) @ qv.data.astype(jnp.float32).T
    if qv.bits == 8:
        qint = qv.data.astype(jnp.float32).T + 128.0          # (d, N)
        dots = queries.astype(jnp.float32) @ qint              # (Q, N)
    else:  # 4-bit: unpack then dot (packed GEMM is the kernel's job)
        e = dequantize(qv)
        return queries.astype(jnp.float32) @ e.T
    qsum = jnp.sum(queries.astype(jnp.float32), axis=-1, keepdims=True)   # (Q,1)
    return dots * qv.scale[:, 0][None, :] + qsum * qv.vmin[:, 0][None, :]


class AdaptiveQuantPolicy:
    """Memory-pressure driven bit selection (paper §3.3 "adaptive quantization")."""

    def __init__(self, budget_bytes: int = 0, high_water: float = 0.8,
                 low_water: float = 0.5):
        self.budget = budget_bytes
        self.high = high_water
        self.low = low_water

    def choose_bits(self, current_bytes: int, default_bits: int = 16) -> int:
        if not self.budget:
            return default_bits
        frac = current_bytes / self.budget
        if frac >= self.high:
            return 4 if default_bits <= 8 or frac >= 1.0 else 8
        if frac >= self.low:
            return 8
        return default_bits
