"""Version-invalidated LRU hot-result cache for the serving retrieval path.

Entries are keyed on ``(plan fingerprint, quantized query signature)`` and
stamped with the index version (``HMGIIndex.version``) they were computed
at. A lookup hits only when all three agree:

- the plan fingerprint (modality, k, hops, probes, predicate, impl) — two
  different plans never share an entry;
- the stored *exact* fp32 query bytes — the signature is a float16
  quantisation, so two nearby queries can collide on a key; serving one
  the other's results would be wrong by construction, hence the entry
  keeps the exact bytes and a byte mismatch is a miss (the resident
  entry stays: the colliding key owner keeps its slot until evicted);
- the index version — every mutation that can change a result (insert,
  delete, compaction, *applied* maintenance, repartition, attribute swap)
  bumps the stamp, so a stale entry is structurally unservable. Version
  mismatches evict the entry on sight (it can never hit again).

Concurrency: one lock (``_lock``) guards the LRU dict and the counters —
declared in tools/staticcheck/registry.py GUARDED_BY and exercised by the
tools/racecheck interleaver. Stored arrays are immutable by convention
(the cache hands back the same numpy objects it was given).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from repro import obs


def query_signature(q: np.ndarray) -> bytes:
    """Quantized signature of one query batch: float16-rounded bytes.

    Deliberately lossy — nearby fp32 queries may share a signature, which
    is what makes the key small and the hit rate tolerant of transport
    jitter. Correctness never rests on it: the entry's exact-byte check
    does (see module docstring)."""
    return np.ascontiguousarray(q, np.float16).tobytes()


class HotResultCache:
    """LRU (scores, ids) cache over ``(plan fingerprint, query signature,
    index version)`` with exact-byte verification on hit."""

    def __init__(self, capacity: int = 256):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        # (plan, signature) -> (exact query bytes, version, scores, ids)
        self._entries: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._stores = 0

    def lookup(self, plan, q: np.ndarray,
               version: int) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """The cached (scores, ids) for ``plan`` over ``q`` at ``version``,
        or None. A version mismatch evicts the entry (it can never hit
        again); an exact-byte mismatch leaves it (signature collision —
        the resident owner may still hit)."""
        q = np.ascontiguousarray(q, np.float32)
        key = (plan, query_signature(q))
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                obs.counter("serving.cache.miss").inc()
                return None
            qbytes, ver, scores, ids = entry
            if ver != version:
                del self._entries[key]
                obs.counter("serving.cache.invalidated").inc()
                obs.counter("serving.cache.miss").inc()
                return None
            if qbytes != q.tobytes():
                obs.counter("serving.cache.collision").inc()
                obs.counter("serving.cache.miss").inc()
                return None
            self._entries.move_to_end(key)
            obs.counter("serving.cache.hit").inc()
            return scores, ids

    def store(self, plan, q: np.ndarray, version: int,
              scores: np.ndarray, ids: np.ndarray) -> None:
        """Insert (LRU-evicting past capacity). ``version`` must be the
        index version read *before* the result was computed: if a mutation
        landed mid-flight the stamp is already stale and the entry simply
        never hits — conservative, never wrong."""
        q = np.ascontiguousarray(q, np.float32)
        key = (plan, query_signature(q))
        entry = (q.tobytes(), int(version),
                 np.asarray(scores), np.asarray(ids))
        with self._lock:
            self._entries[key] = entry
            self._entries.move_to_end(key)
            self._stores += 1
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                obs.counter("serving.cache.evicted").inc()
            obs.gauge("serving.cache.size").set(len(self._entries))

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            obs.gauge("serving.cache.size").set(0)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def keys(self) -> list:
        """Current keys in LRU order (oldest first) — test introspection."""
        with self._lock:
            return list(self._entries)
