"""Segment primitives (the JAX message-passing substrate — docs/DESIGN.md: BCOO-free,
``segment_sum``-based; this IS part of the system, not a gap).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum(data, segment_ids, num_segments: int):
    ok = jnp.logical_and(segment_ids >= 0, segment_ids < num_segments)
    data = jnp.where(ok.reshape(ok.shape + (1,) * (data.ndim - 1)), data, 0)
    seg = jnp.where(ok, segment_ids, 0)
    return jax.ops.segment_sum(data, seg, num_segments=num_segments)


def segment_mean(data, segment_ids, num_segments: int):
    s = segment_sum(data, segment_ids, num_segments)
    ones = jnp.ones(segment_ids.shape, data.dtype)
    c = segment_sum(ones, segment_ids, num_segments)
    return s / jnp.maximum(c.reshape(c.shape + (1,) * (data.ndim - 1)), 1.0)


def segment_max(data, segment_ids, num_segments: int):
    ok = jnp.logical_and(segment_ids >= 0, segment_ids < num_segments)
    data = jnp.where(ok.reshape(ok.shape + (1,) * (data.ndim - 1)), data, -jnp.inf)
    seg = jnp.where(ok, segment_ids, 0)
    return jax.ops.segment_max(data, seg, num_segments=num_segments)


def segment_softmax(logits, segment_ids, num_segments: int):
    """Per-segment softmax over edge logits (GAT-style attention weights)."""
    m = segment_max(logits, segment_ids, num_segments)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    shifted = logits - m[jnp.clip(segment_ids, 0, num_segments - 1)]
    e = jnp.exp(shifted)
    ok = jnp.logical_and(segment_ids >= 0, segment_ids < num_segments)
    e = jnp.where(ok.reshape(ok.shape + (1,) * (e.ndim - 1)) if e.ndim > 1 else ok, e, 0.0)
    z = segment_sum(e, segment_ids, num_segments)
    denom = z[jnp.clip(segment_ids, 0, num_segments - 1)]
    return e / jnp.maximum(denom, 1e-20)
