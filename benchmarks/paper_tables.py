"""Paper-table reproductions (Tables 4-7): QPS, Recall@10, memory, latency
across datasets x systems, on scaled-down stand-in corpora."""
from __future__ import annotations

from typing import List

import numpy as np
import jax

from benchmarks.common import (DATASETS, Decoupled, Monolithic, build_hmgi,
                               load_corpus, make_queries, primary_mod, timeit)
from repro.data.synthetic import ground_truth_topk, recall_at_k


def run(report) -> None:
    k = 10
    for ds in DATASETS:
        corpus = load_corpus(ds)
        mod = primary_mod(ds)
        q = make_queries(corpus, mod)
        truth = ground_truth_topk(corpus.vectors[mod], corpus.node_ids[mod], q, k)

        hmgi = build_hmgi(corpus)
        mono = Monolithic.build(corpus)

        # Table 7 (latency) + Table 4 (QPS): batched vector search
        t_h = timeit(lambda: hmgi.search(q, mod, k=k))
        t_m = timeit(lambda: mono.search(q, k=k))
        report(f"t7_latency_hmgi[{ds}]", t_h / len(q) * 1e6,
               f"qps={len(q)/t_h:.0f}")
        report(f"t7_latency_monolithic[{ds}]", t_m / len(q) * 1e6,
               f"qps={len(q)/t_m:.0f}")

        # Table 5: recall@10
        r_h = recall_at_k(np.asarray(hmgi.search(q, mod, k=k)[1]), truth)
        r_m = recall_at_k(np.asarray(mono.search(q, k=k)[1]), truth)
        report(f"t5_recall_hmgi[{ds}]", r_h * 1000, f"recall@10={r_h:.3f}")
        report(f"t5_recall_monolithic[{ds}]", r_m * 1000, f"recall@10={r_m:.3f}")

        # Table 6: memory (index bytes)
        mem_h = hmgi.memory_usage()["total"]
        mem_m = int(mono.vectors.size * mono.vectors.dtype.itemsize)
        report(f"t6_memory_hmgi[{ds}]", mem_h / 2 ** 20,
               f"MiB={mem_h/2**20:.1f}")
        report(f"t6_memory_monolithic[{ds}]", mem_m / 2 ** 20,
               f"MiB={mem_m/2**20:.1f}")

        # hybrid workload: fused vs decoupled (the paper's 3x QPS claim)
        dec = Decoupled(corpus, hmgi)
        t_fused = timeit(lambda: hmgi.hybrid_search(q, mod, k=k, n_hops=2))
        t_dec = timeit(lambda: dec.hybrid_search(q, mod, k=k, n_hops=2))
        report(f"t4_hybrid_qps_hmgi[{ds}]", t_fused / len(q) * 1e6,
               f"qps={len(q)/t_fused:.0f}")
        report(f"t4_hybrid_qps_decoupled[{ds}]", t_dec / len(q) * 1e6,
               f"qps={len(q)/t_dec:.0f} speedup={t_dec/t_fused:.2f}x")
