"""Parameter construction: arrays + logical sharding axes built together.

Every ``init_*`` function uses a ``Builder`` so the parameter pytree and the
logical-axes pytree (same structure, tuples of logical axis names at leaves)
can never drift apart. ``jax.eval_shape`` over an init function yields the
abstract parameter tree used by the multi-pod dry-run (no allocation).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


class Builder:
    """Collects (params, logical_axes) pairs under split PRNG keys."""

    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self._key = key
        self.dtype = dtype
        self.params: dict = {}
        self.axes: dict = {}

    def key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def dense(self, name: str, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
              fan_in: Optional[int] = None, scale: float = 1.0, dtype=None):
        assert len(shape) == len(axes), (name, shape, axes)
        fi = fan_in if fan_in is not None else shape[0]
        std = scale / math.sqrt(max(fi, 1))
        self.params[name] = (jax.random.normal(self.key(), shape, jnp.float32) * std
                             ).astype(dtype or self.dtype)
        self.axes[name] = axes
        return self

    def zeros(self, name, shape, axes, dtype=None):
        self.params[name] = jnp.zeros(shape, dtype or self.dtype)
        self.axes[name] = axes
        return self

    def ones(self, name, shape, axes, dtype=None):
        self.params[name] = jnp.ones(shape, dtype or self.dtype)
        self.axes[name] = axes
        return self

    def child(self, name: str, sub: "Builder"):
        self.params[name] = sub.params
        self.axes[name] = sub.axes
        return self

    def sub(self) -> "Builder":
        return Builder(self.key(), self.dtype)

    def build(self):
        return self.params, self.axes


def abstract_init(init_fn, *args):
    """Abstract (no-allocation) init: returns (ShapeDtypeStruct tree, axes tree).

    ``init_fn(*args) -> (params, axes)``; the axes tree (strings) is captured
    by side effect since eval_shape can only return JAX types.
    """
    box = {}

    def capture(*a):
        p, ax = init_fn(*a)
        box["axes"] = ax
        return p

    abs_params = jax.eval_shape(capture, *args)
    return abs_params, box["axes"]


def stack_layers(per_layer: list):
    """Stack a list of (params, axes) into scanned (L, ...) params."""
    params = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *[p for p, _ in per_layer])
    axes = jax.tree.map(lambda a: (None,) + tuple(a), per_layer[0][1],
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            e is None or isinstance(e, str) for e in x))
    return params, axes
