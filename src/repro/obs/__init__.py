"""Unified observability layer: metrics registry, trace spans, exporters.

Host-side only — nothing in this package runs inside traced code. See
``metrics`` (counters/gauges/histograms with exact quantiles), ``spans``
(nestable timed spans with optional ``block_until_ready`` fencing and a
``trace()`` tree collector), and ``export`` (Prometheus text exposition,
JSON snapshot).

Typical use::

    from repro import obs

    obs.counter("serving.admitted").inc()
    with obs.span("query.seed_scan") as sp:
        sv, si = run_seed(...)
        sp.fence((sv, si))           # synced only if cfg.obs_sync_spans

    print(obs.render_prometheus())
"""
from .metrics import (COUNT_BUCKETS, DEFAULT_BUCKETS, Counter, Gauge,
                      Histogram, MetricsRegistry, enabled, registry,
                      set_enabled)
from .spans import (Span, SpanNode, Trace, observe_ms, set_sync_spans, span,
                    sync_spans, trace)
from .export import parse_prometheus, render_prometheus


def counter(name: str) -> Counter:
    return registry().counter(name)


def gauge(name: str) -> Gauge:
    return registry().gauge(name)


def histogram(name: str, buckets=None) -> Histogram:
    return registry().histogram(name, buckets)


def snapshot() -> dict:
    """JSON-able snapshot of the global registry (the ``obs`` section of
    ``HMGIIndex.metrics()``)."""
    return registry().to_dict()


def reset() -> None:
    """Drop every metric in the global registry (tests, bench phases)."""
    registry().reset()


__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "DEFAULT_BUCKETS", "COUNT_BUCKETS",
    "registry", "counter", "gauge", "histogram", "snapshot", "reset",
    "enabled", "set_enabled",
    "Span", "SpanNode", "Trace", "span", "trace", "observe_ms",
    "set_sync_spans", "sync_spans",
    "render_prometheus", "parse_prometheus",
]
