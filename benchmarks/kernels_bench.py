"""Kernel micro-benchmarks (interpret-mode wall time is NOT TPU-predictive;
the derived column carries the analytic bytes/flops that the roofline uses —
the comparison of interest on CPU is kernel-vs-oracle agreement + the scan's
arithmetic-intensity accounting).

``ivf_probe_*`` is the exception: it times the two *production* probe paths
of ``ivf.search`` against each other on identical shapes — the fused-kernel
slab scan (int8 end-to-end) vs the legacy fp32 gather-dequant einsum. The
kernel path wins even under interpret mode because it never materialises the
(qb, P, cap, d) fp32 dequant and replaces the full-width top-k with a
chunk-survivor top-k + tiny rescore; on TPU the HBM saving (×4 on traffic)
dominates."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import timeit
from repro.core import ivf as ivf_mod
from repro.core.quantization import quantize
from repro.kernels.ivf_topk.ops import scan_topk_quantized
from repro.kernels.ivf_topk.ref import scan_topk_ref, topk_from_chunks
from repro.kernels.segment_reduce.ops import segment_sum_mm
from repro.kernels.segment_reduce.ref import segment_sum_ref
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


def run(report):
    rng = np.random.default_rng(0)

    # ivf probe path: fused kernel vs fp32-gather einsum on the same shapes
    n, d, nq, n_probe, k = 8192, 128, 64, 8, 10
    v = rng.normal(size=(n, d)).astype(np.float32)
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    idx, _ = ivf_mod.build(jax.random.PRNGKey(0), jnp.asarray(v),
                           jnp.arange(n), n_partitions=32, bits=8)
    q = jnp.asarray(v[:nq] + 0.02 * rng.normal(size=(nq, d)).astype(np.float32))
    t_e = timeit(lambda: ivf_mod.search(idx, q, n_probe=n_probe, k=k,
                                        impl="einsum"), trials=3)
    t_k = timeit(lambda: ivf_mod.search(idx, q, n_probe=n_probe, k=k,
                                        impl="kernel"), trials=3)
    m_rows = n_probe * idx.capacity
    fp32_interm = nq * m_rows * d * 4          # the einsum path's HBM dequant
    report("ivf_probe_einsum", t_e * 1e6,
           f"fp32_dequant_bytes={fp32_interm:.2e}")
    report("ivf_probe_kernel", t_k * 1e6,
           f"speedup={t_e / t_k:.2f}x fp32_dequant_bytes=0 "
           f"int8_scan_bytes={nq * m_rows * d:.2e}")

    # ivf_topk: HBM bytes per query at int8 vs bf16 storage
    n, d, q = 8192, 128, 64
    v = rng.normal(size=(n, d)).astype(np.float32)
    qv = quantize(jnp.asarray(v), 8)
    queries = jnp.asarray(v[:q])
    valid = jnp.ones((n,), bool)
    t_k = timeit(lambda: scan_topk_quantized(queries, qv.data, qv.vmin[:, 0],
                                             qv.scale[:, 0], valid, k=10),
                 trials=3)
    int8_bytes = n * d
    bf16_bytes = n * d * 2
    report("k_ivf_topk_int8", t_k / q * 1e6,
           f"hbm_bytes_per_scan={int8_bytes} vs_bf16={bf16_bytes} (2x saved)")

    # segment_reduce: one-hot-matmul MXU formulation
    e, dd, nn = 8192, 64, 1024
    msg = jnp.asarray(rng.normal(size=(e, dd)).astype(np.float32))
    seg = jnp.asarray(rng.integers(0, nn, e).astype(np.int32))
    t_k = timeit(lambda: segment_sum_mm(msg, seg, nn), trials=3)
    t_r = timeit(lambda: segment_sum_ref(msg, seg, nn), trials=3)
    mxu_flops = 2 * e * nn * dd   # the one-hot matmul the TPU would run
    report("k_segment_reduce", t_k * 1e6,
           f"ref_us={t_r*1e6:.0f} mxu_flops={mxu_flops:.2e}")

    # decode_attention: flash-decode bytes per token
    b, hkv, g, hd, s = 4, 8, 8, 128, 4096
    qa = jnp.asarray(rng.normal(size=(b, hkv * g, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)).astype(np.float32))
    vv = jnp.asarray(rng.normal(size=(b, s, hkv, hd)).astype(np.float32))
    valid = jnp.ones((b, s), bool)
    t_k = timeit(lambda: decode_attention(qa, k, vv, valid), trials=3)
    kv_bytes = 2 * b * s * hkv * hd * 4
    report("k_decode_attention", t_k * 1e6,
           f"kv_bytes={kv_bytes:.2e} tokens={b}")
