"""One-hot-matmul segment-sum Pallas kernel (GNN aggregation / EmbeddingBag).

Scatter-add is the canonical GNN/recsys primitive but maps poorly onto the
TPU's vector memory (serialized random writes). The TPU-native formulation
is a *matmul against an implicit one-hot matrix*:

    out[n, :] = Σ_e 1[seg[e] == n] · msg[e, :]   ==   onehot(seg)ᵀ @ msg

The one-hot block is built in VREGs from an iota compare (never touches HBM)
and the accumulation runs on the MXU. Grid = (node_blocks, edge_blocks); the
output block index map is constant along the edge axis, so each node block
accumulates across the sequential edge-block sweep (TPU grids execute in
order, minor-most last — the standard Pallas accumulation pattern).

VMEM per step (block_n=512, block_e=1024, d≤512 fp32): msg 2 MB, onehot
(1024×512 fp32) 2 MB, out 1 MB — well inside budget; MXU dims are
(512×1024)·(1024×d), lane-aligned.

Unsorted segment ids are fully supported (one-hot handles any order).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(seg_ref, msg_ref, out_ref, *, block_n: int, block_e: int):
    # seg_ref: (block_e, 1) int32; msg_ref: (block_e, d); out_ref: (block_n, d)
    i = pl.program_id(0)          # node-block index
    j = pl.program_id(1)          # edge-block index (accumulation axis)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seg = seg_ref[...][:, 0]                                     # (block_e,)
    node_base = i * block_n
    local = seg - node_base
    onehot = (local[:, None] == jnp.arange(block_n, dtype=jnp.int32)[None, :])
    onehot = onehot.astype(msg_ref.dtype)                        # (block_e, block_n)
    partial = jax.lax.dot_general(
        onehot, msg_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                      # (block_n, d)
    out_ref[...] += partial.astype(out_ref.dtype)


def segment_sum_pallas(messages, seg_ids, n_segments: int, *,
                       block_n: int = 512, block_e: int = 1024,
                       interpret: bool = False):
    """messages (E, d); seg_ids (E,) int32 in [0, n_segments) (or <0 to drop).
    Returns (n_segments, d). E and n_segments must be block-aligned (ops.py
    pads)."""
    e, d = messages.shape
    assert e % block_e == 0 and n_segments % block_n == 0
    grid = (n_segments // block_n, e // block_e)
    seg2 = seg_ids.reshape(e, 1)
    return pl.pallas_call(
        functools.partial(_kernel, block_n=block_n, block_e=block_e),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((block_e, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_segments, d), messages.dtype),
        interpret=interpret,
    )(seg2, messages)
