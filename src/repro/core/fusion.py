"""Hybrid score fusion (paper Eq. 3) with DEG-inspired adaptive weights.

    S = w_v · (1 − d_v) + w_g · (1/h) · Σ_g s_g

``d_v`` is the normalised vector distance (cosine distance for unit-norm
embeddings), the graph term is the mean per-hop traversal mass from
``core/traversal.py``. Adaptive weighting (paper §3.4 "dynamic DEG-inspired
weights") shifts weight toward the vector side when the ANN margin is
confident and toward the graph side when it is ambiguous (polysemy — the
paper's Apple-fruit vs Apple-company case).

Candidate-sparse formulation: fusion only ever needs the union of the ANNS
seeds and the traversal frontier's strongest nodes, so ``fuse_topk_sparse``
operates on an explicit (Q, C) candidate set — C ≪ N — with the graph
normaliser passed in (the global per-query max, free from the frontier
top-k). The dense ``fuse_topk`` is the special case "candidates = all N" and
delegates to it.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class FusionWeights(NamedTuple):
    w_vector: jax.Array   # (Q,) or scalar
    w_graph: jax.Array


def adaptive_weights(vector_scores: jax.Array, *, base_wv: float = 0.6,
                     base_wg: float = 0.4, sensitivity: float = 4.0) -> FusionWeights:
    """vector_scores: (Q, k) descending. Margin = s1 − s2 (top-1 confidence);
    w_v = σ(sensitivity·(margin − m̄)) blended around the configured base."""
    s = vector_scores
    margin = s[:, 0] - jnp.where(s.shape[1] > 1, s[:, min(1, s.shape[1] - 1)], s[:, 0])
    margin = jnp.nan_to_num(margin, nan=0.0, posinf=1.0, neginf=0.0)
    conf = jax.nn.sigmoid(sensitivity * (margin - 0.05))
    wv = base_wv * (0.5 + conf)             # in [0.5·wv, 1.5·wv]
    wg = base_wg * (1.5 - conf)
    tot = wv + wg
    return FusionWeights(w_vector=wv / tot, w_graph=wg / tot)


def fuse(vector_sim: jax.Array, graph_score: jax.Array,
         weights: FusionWeights, *, graph_max: Optional[jax.Array] = None,
         valid: Optional[jax.Array] = None) -> jax.Array:
    """Eq. 3 over per-candidate terms.

    vector_sim: (Q, C) cosine similarity in [-1, 1] (−inf for graph-only
    candidates); graph_score: (Q, C) mean per-hop mass (already (1/h)·Σ s_g).
    graph_max: (Q, 1) normaliser — per-query max over *all* nodes; defaults
    to the max over the given candidates (correct whenever the candidate set
    contains the strongest graph node, and always for the dense case).
    valid: (Q, C) bool — False entries (padding, duplicates) fuse to −inf.
    """
    d_v = 0.5 * (1.0 - vector_sim)                    # cosine distance -> [0,1]
    s_v = 1.0 - d_v
    gmax = (jnp.max(graph_score, axis=-1, keepdims=True)
            if graph_max is None else graph_max)
    g = graph_score / jnp.maximum(gmax, 1e-12)
    wv = jnp.asarray(weights.w_vector).reshape(-1, 1)
    wg = jnp.asarray(weights.w_graph).reshape(-1, 1)
    fused = wv * s_v + wg * g
    fused = jnp.where(jnp.isfinite(vector_sim), fused, wg * g)
    if valid is not None:
        fused = jnp.where(valid, fused, -jnp.inf)
    return fused


def fuse_topk_sparse(cand_sim: jax.Array, cand_graph: jax.Array,
                     weights: FusionWeights, k: int, *,
                     graph_max: Optional[jax.Array] = None,
                     valid: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, jax.Array]:
    """Fused scores over an explicit candidate axis -> top-k.

    Returns (scores (Q, k), positions (Q, k)) — positions index the candidate
    axis; the caller owns the candidate-id mapping. Peak memory is O(Q·C),
    independent of the corpus size."""
    fused = fuse(cand_sim, cand_graph, weights, graph_max=graph_max,
                 valid=valid)
    vals, pos = jax.lax.top_k(fused, k)
    return vals, pos


def fuse_topk(vector_sim_full: jax.Array, graph_score: jax.Array,
              weights: FusionWeights, k: int) -> Tuple[jax.Array, jax.Array]:
    """Dense fusion: candidates = all N nodes (ids are node positions).
    Delegates to the sparse path."""
    return fuse_topk_sparse(vector_sim_full, graph_score, weights, k)


def scatter_sim(n_nodes: int, ids: jax.Array, sims: jax.Array) -> jax.Array:
    """(Q, k) candidate (ids, sims) -> dense (Q, N) similarity, −inf off the
    candidate set. Duplicate ids keep their maximum (matching the sparse
    path's keep-highest dedup). This is the scatter of the *dense* fusion
    representation — the query planner picks it over the candidate-sparse
    path when the fusion frontier would cover every node anyway."""
    qn = ids.shape[0]
    dense = jnp.full((qn, n_nodes), -jnp.inf, sims.dtype)
    rows = jnp.arange(qn)[:, None]
    vals = jnp.where(ids >= 0, sims, -jnp.inf)
    return dense.at[rows, jnp.clip(ids, 0, n_nodes - 1)].max(vals)
