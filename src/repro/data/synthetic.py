"""Synthetic multimodal corpora + knowledge graphs (the GraphGen analogue the
paper uses for its billion-scale KG benchmarks, scaled to this container).

Embeddings are drawn from planted Gaussian clusters so ANN recall has ground
truth structure; the KG is drawn with intra-cluster preferential attachment so
graph neighborhoods correlate with embedding neighborhoods (the regime where
hybrid fusion helps — and what makes the §5.3 ablation meaningful).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class MultimodalCorpus:
    node_ids: Dict[str, np.ndarray]          # modality -> (N_m,) global ids
    vectors: Dict[str, np.ndarray]           # modality -> (N_m, d_m) fp32
    src: np.ndarray                          # KG edges
    dst: np.ndarray
    edge_type: np.ndarray
    cluster_of: np.ndarray                   # (N,) planted cluster per node
    n_nodes: int


def make_corpus(
    n_nodes: int = 2000,
    modality_dims: Optional[Dict[str, int]] = None,
    n_clusters: int = 16,
    intra_p: float = 0.015,
    inter_p: float = 0.0005,
    n_edge_types: int = 4,
    noise: float = 0.25,
    seed: int = 0,
) -> MultimodalCorpus:
    rng = np.random.default_rng(seed)
    modality_dims = modality_dims or {"text": 64, "image": 96}
    mods = list(modality_dims)
    cluster = rng.integers(0, n_clusters, n_nodes)
    modality = rng.integers(0, len(mods), n_nodes)

    node_ids, vectors = {}, {}
    for mi, mod in enumerate(mods):
        d = modality_dims[mod]
        centers = rng.normal(size=(n_clusters, d)).astype(np.float32)
        centers /= np.linalg.norm(centers, axis=1, keepdims=True)
        mask = modality == mi
        ids = np.where(mask)[0].astype(np.int32)
        v = centers[cluster[mask]] + noise * rng.normal(size=(mask.sum(), d)).astype(np.float32)
        node_ids[mod] = ids
        vectors[mod] = v.astype(np.float32)

    # planted-partition KG (preferential within clusters)
    n_intra = int(intra_p * n_nodes * n_nodes / n_clusters)
    n_inter = int(inter_p * n_nodes * n_nodes)
    srcs, dsts = [], []
    for c in range(n_clusters):
        members = np.where(cluster == c)[0]
        if len(members) < 2:
            continue
        e = rng.integers(0, len(members), (max(n_intra // n_clusters, len(members)), 2))
        srcs.append(members[e[:, 0]])
        dsts.append(members[e[:, 1]])
    e = rng.integers(0, n_nodes, (max(n_inter, 1), 2))
    srcs.append(e[:, 0])
    dsts.append(e[:, 1])
    src = np.concatenate(srcs).astype(np.int32)
    dst = np.concatenate(dsts).astype(np.int32)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    et = rng.integers(0, n_edge_types, len(src)).astype(np.int32)
    return MultimodalCorpus(node_ids, vectors, src, dst, et, cluster, n_nodes)


def ground_truth_topk(vectors: np.ndarray, ids: np.ndarray, queries: np.ndarray,
                      k: int) -> np.ndarray:
    """Exact cosine top-k ids (recall oracle)."""
    v = vectors / np.maximum(np.linalg.norm(vectors, axis=1, keepdims=True), 1e-12)
    q = queries / np.maximum(np.linalg.norm(queries, axis=1, keepdims=True), 1e-12)
    s = q @ v.T
    top = np.argsort(-s, axis=1)[:, :k]
    return ids[top]


def recall_at_k(pred_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Mean |pred ∩ true| / k."""
    hits = 0
    for p, t in zip(pred_ids, true_ids):
        hits += len(set(int(x) for x in p if x >= 0) & set(int(x) for x in t))
    return hits / (len(true_ids) * true_ids.shape[1])


def make_lm_batch(rng: np.random.Generator, batch: int, seq: int, vocab: int):
    toks = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int64)
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}
