"""Serving-layer tests: continuous batching over ragged prompts must equal
sequential per-request decoding token for token (the per-slot position
contract), the scheduler's admit/evict/refill lifecycle, and the RAG
submit path's handling of padded retrieval ids.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models import lm
from repro.serving.engine import EngineConfig, RAGEngine
from repro.serving.scheduler import ContinuousBatcher, Request

OPTS = lm.ExecOpts(q_block=0, remat=False)
MAX_SEQ = 48


@pytest.fixture(scope="module")
def lm_setup():
    # float32: batched-vs-single decode must agree to the argmax, and bf16
    # rounding could flip near-ties between the two batch shapes
    cfg = smoke_config("qwen2-72b").replace(dtype="float32")
    params, _ = lm.init_lm(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _sequential(cfg, params, prompt, n):
    """Reference: one request at a time, prefill then single-row decode."""
    clen = lm.cache_len_for(cfg, MAX_SEQ)
    logits, cache = lm.prefill(cfg, params, jnp.asarray(prompt)[None], None,
                               OPTS, margin=clen - len(prompt))
    gen = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    while len(gen) < n:
        l, cache = lm.decode_step(cfg, params, cache, jnp.asarray([gen[-1]]),
                                  jnp.asarray([pos]), None, OPTS)
        gen.append(int(jnp.argmax(l[0])))
        pos += 1
    return gen


class TestPerSlotDecode:
    """lm.decode_step with a (B,) position vector: each row must behave as
    if decoded alone at its own position."""

    @pytest.mark.parametrize("arch", ["qwen2-72b", "deepseek-v2-lite-16b"])
    def test_ragged_batch_matches_single_rows(self, arch):
        cfg = smoke_config(arch).replace(dtype="float32", capacity_factor=16.0)
        params, _ = lm.init_lm(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        la, lb = 5, 9
        pa = rng.integers(0, cfg.vocab_size, la).astype(np.int32)
        pb = rng.integers(0, cfg.vocab_size, lb).astype(np.int32)
        clen = lm.cache_len_for(cfg, 24)
        _, ca = lm.prefill(cfg, params, jnp.asarray(pa)[None], None, OPTS,
                           margin=clen - la)
        _, cb = lm.prefill(cfg, params, jnp.asarray(pb)[None], None, OPTS,
                           margin=clen - lb)
        ta, tb = 7, 11
        ra, _ = lm.decode_step(cfg, params, ca, jnp.asarray([ta]),
                               jnp.asarray([la]), None, OPTS)
        rb, _ = lm.decode_step(cfg, params, cb, jnp.asarray([tb]),
                               jnp.asarray([lb]), None, OPTS)
        # batch the two ragged rows into one step with a position vector
        batched = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=1),
                               ca, cb)
        rab, _ = lm.decode_step(cfg, params, batched, jnp.asarray([ta, tb]),
                                jnp.asarray([la, lb]), None, OPTS)
        np.testing.assert_allclose(np.asarray(rab[0]), np.asarray(ra[0]),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(rab[1]), np.asarray(rb[0]),
                                   rtol=1e-5, atol=1e-5)

    def test_scalar_pos_still_accepted(self, lm_setup):
        cfg, params = lm_setup
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                  cfg.vocab_size)
        _, cache = lm.prefill(cfg, params, toks, None, OPTS, margin=4)
        nxt = jax.random.randint(jax.random.PRNGKey(2), (2,), 0, cfg.vocab_size)
        ls, _ = lm.decode_step(cfg, params, cache, nxt, jnp.asarray(12),
                               None, OPTS)
        lv, _ = lm.decode_step(cfg, params, cache, nxt, jnp.asarray([12, 12]),
                               None, OPTS)
        np.testing.assert_array_equal(np.asarray(ls), np.asarray(lv))


class TestContinuousBatching:
    def test_ragged_prompts_match_sequential(self, lm_setup):
        """More requests than slots, all prompt lengths different: the
        engine's generated streams must equal sequential decoding exactly."""
        cfg, params = lm_setup
        rng = np.random.default_rng(0)
        lens = (3, 11, 7, 5, 9)
        news = (6, 4, 8, 1, 5)
        prompts = [rng.integers(0, cfg.vocab_size, L).astype(np.int32)
                   for L in lens]
        ref = {i: _sequential(cfg, params, p, n)
               for i, (p, n) in enumerate(zip(prompts, news))}
        eng = RAGEngine(cfg, params, None,
                        EngineConfig(n_slots=2, max_seq=MAX_SEQ))
        for i, (p, n) in enumerate(zip(prompts, news)):
            eng.submit(i, p, max_new_tokens=n)
        got = eng.run_to_completion()
        assert got == ref

    def test_zero_token_request_returns_empty(self, lm_setup):
        """max_new_tokens=0 completes at admission: empty generated, no slot
        occupied, and co-scheduled requests are unaffected."""
        cfg, params = lm_setup
        rng = np.random.default_rng(2)
        p0 = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
        p1 = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
        eng = RAGEngine(cfg, params, None,
                        EngineConfig(n_slots=2, max_seq=MAX_SEQ))
        eng.submit(0, p0, max_new_tokens=0)
        eng.submit(1, p1, max_new_tokens=3)
        got = eng.run_to_completion()
        assert got[0] == []
        assert got[1] == _sequential(cfg, params, p1, 3)

    def test_padded_retrieved_ids_dropped(self, lm_setup):
        """hybrid_search pads short candidate sets with -1: those must not
        alias into vocab via the modulo and become phantom context tokens."""
        cfg, params = lm_setup
        eng = RAGEngine(cfg, params, None,
                        EngineConfig(n_slots=2, max_seq=MAX_SEQ))
        prompt = np.arange(5, dtype=np.int32)
        eng.submit(0, prompt, retrieved_ids=np.array([8, -1, 3, -1, -1]),
                   max_new_tokens=1)
        built = eng.batcher.requests[0].prompt
        assert len(built) == len(prompt) + 2           # only the 2 real ids
        assert np.array_equal(built[:2],
                              np.array([8, 3]) % (cfg.vocab_size // 4))

    def test_retrieval_context_changes_prompt(self, lm_setup):
        cfg, params = lm_setup
        eng = RAGEngine(cfg, params, None,
                        EngineConfig(n_slots=1, max_seq=MAX_SEQ))
        prompt = np.arange(4, dtype=np.int32)
        eng.submit(0, prompt, retrieved_ids=np.array([17, 42]),
                   max_new_tokens=2)
        built = eng.batcher.requests[0].prompt
        ref = _sequential(cfg, params, built, 2)
        assert eng.run_to_completion()[0] == ref


class TestScheduler:
    def test_admit_evict_refill(self):
        b = ContinuousBatcher(2)
        for i in range(4):
            b.submit(Request(i, np.arange(3 + i), max_new_tokens=2 + i))
        assert b.admit() == [0, 1]
        assert b.slots[0].pos == 3 and b.slots[1].pos == 4
        assert b.admit() == []                          # both slots busy
        b.record_tokens(np.array([10, 11]))             # remaining 1, 2
        assert all(s.active for s in b.slots)
        b.record_tokens(np.array([12, 13]))             # rid 0 done
        assert not b.slots[0].active and b.slots[1].active
        assert b.requests[0].done and b.requests[0].generated == [10, 12]
        assert b.admit() == [0]                         # refill freed slot
        assert b.slots[0].rid == 2
        assert b.any_active

    def test_pos_advances_per_slot(self):
        b = ContinuousBatcher(2)
        b.submit(Request(0, np.arange(2), max_new_tokens=5))
        b.submit(Request(1, np.arange(9), max_new_tokens=5))
        b.admit()
        b.record_tokens(np.array([1, 1]))
        assert (b.slots[0].pos, b.slots[1].pos) == (3, 10)

    def test_zero_token_never_takes_a_slot(self):
        b = ContinuousBatcher(1)
        b.submit(Request(0, np.arange(3), max_new_tokens=0))
        b.submit(Request(1, np.arange(3), max_new_tokens=2))
        assert b.admit() == [0]
        assert b.slots[0].rid == 1                      # rid 0 skipped
        assert b.requests[0].done and b.requests[0].generated == []

    def test_prefill_token_counts_toward_budget(self):
        b = ContinuousBatcher(1)
        b.submit(Request(0, np.arange(3), max_new_tokens=1))
        (slot,) = b.admit()
        b.record_prefill_token(slot, 7)
        assert b.requests[0].done and b.requests[0].generated == [7]
        assert not b.slots[0].active                    # freed without decode
