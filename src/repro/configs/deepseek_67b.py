"""deepseek-67b [dense] — llama-arch, GQA kv=8.  [arXiv:2401.02954; hf]"""
from repro.configs.base import LMConfig
from repro.configs.lm_shapes import lm_shapes

CONFIG = LMConfig(
    arch_id="deepseek-67b",
    source="arXiv:2401.02954; hf",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    rope_theta=10_000.0,
)

SHAPES = lm_shapes(long_ok=False)
