"""Layer 2b: HMG103 — the compile-count budget gate.

Runs the canonical mixed workload (ingest -> search -> update -> maintain ->
search, the tests/query_ref.py scale) against a fresh HMGIIndex, then reads
the number of distinct compiled signatures per registered jitted entry point
straight off the jit caches (``fn._cache_size()``). The measurement is
compared to ``tools/staticcheck/budgets.json``; any entry that compiled
*more* signatures than budgeted fails. Fewer is fine (and worth re-baseling
with ``--write-budgets``) — the gate bounds respecialisation regressions,
it does not pin exact counts across jax versions.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

from tools.staticcheck import Violation

BUDGETS_PATH = Path(__file__).resolve().parent / "budgets.json"

# canonical workload scale — mirrors tests/query_ref.py suites: two search
# phases with distinct (k, n_probe) plus an update/maintain phase between
# them, so steady-state serving plus one respecialisation per knob is the
# expected signature count
_N, _D, _Q = 512, 32, 8


def load_budgets(path: Optional[Path] = None) -> Dict[str, int]:
    p = Path(path) if path else BUDGETS_PATH
    with open(p) as f:
        data = json.load(f)
    return {k: int(v) for k, v in data["entries"].items()}


def save_budgets(measured: Dict[str, int],
                 path: Optional[Path] = None) -> Path:
    p = Path(path) if path else BUDGETS_PATH
    payload = {
        "_comment": ("HMG103 compile-count budgets: max distinct compiled "
                     "signatures per jitted entry point under the "
                     "canonical mixed workload (python -m tools.staticcheck "
                     "--write-budgets to re-baseline)."),
        "workload": {"n": _N, "d": _D, "q": _Q,
                     "phases": ["ingest", "search", "update", "maintain",
                                "search"]},
        "entries": {k: measured[k] for k in sorted(measured)},
    }
    with open(p, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return p


def run_canonical_workload() -> Dict[str, int]:
    """Execute the mixed workload in-process and return per-entry distinct
    compiled-signature counts."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs.hmgi import HMGIConfig
    from repro.core.index import HMGIIndex

    from tools.staticcheck.registry import budget_functions

    fns = budget_functions()
    jax.clear_caches()
    for fn in fns.values():
        try:
            fn._clear_cache()
        except AttributeError:
            pass

    rng = np.random.default_rng(7)
    cfg = HMGIConfig(n_partitions=8, n_probe=4, top_k=8,
                     delta_capacity=256, maint_auto=False)
    idx = HMGIIndex(cfg, seed=0)
    vecs = rng.normal(size=(_N, _D)).astype(np.float32)
    e = 4 * _N
    edges = (rng.integers(0, _N, e).astype(np.int32),
             rng.integers(0, _N, e).astype(np.int32))

    # ingest
    idx.ingest({"text": (np.arange(_N), vecs)}, n_nodes=_N, edges=edges)
    q = rng.normal(size=(_Q, _D)).astype(np.float32)

    # search (serving steady state: same shapes twice must not recompile)
    idx.search(q, "text", k=8)
    idx.search(q, "text", k=8)
    idx.hybrid_search(q, "text", k=8, n_hops=2)

    # update (insert new + supersede existing + delete)
    idx.insert("text", np.arange(_N, _N + 64),
               rng.normal(size=(64, _D)).astype(np.float32))
    idx.insert("text", np.arange(0, 32),
               rng.normal(size=(32, _D)).astype(np.float32))
    idx.delete("text", np.arange(40, 48))

    # maintain
    idx.maintain("text")

    # search again (post-update shapes; pow2 padding keeps these on the
    # already-compiled signatures wherever possible)
    idx.search(q, "text", k=8)
    idx.search(q, "text", k=8, n_probe=8)

    sizes: Dict[str, int] = {}
    for name, fn in fns.items():
        try:
            sizes[name] = int(fn._cache_size())
        except AttributeError:
            sizes[name] = 0
    return sizes


def check_budgets(measured: Dict[str, int],
                  budgets: Dict[str, int]) -> List[Violation]:
    out: List[Violation] = []
    for name, n in sorted(measured.items()):
        cap = budgets.get(name)
        if cap is None:
            out.append(Violation(
                "HMG103", "tools/staticcheck/budgets.json", 0,
                f"entry '{name}' has no budget — run --write-budgets"))
        elif n > cap:
            out.append(Violation(
                "HMG103", "tools/staticcheck/budgets.json", 0,
                f"entry '{name}' compiled {n} distinct signatures under "
                f"the canonical workload (budget {cap}) — a static shape "
                "arg is respecialising; pad through "
                "pow2_round/pad_to_chunk"))
    return out


def run_budget_rule(write: bool = False,
                    path: Optional[Path] = None) -> List[Violation]:
    measured = run_canonical_workload()
    if write:
        save_budgets(measured, path)
        return []
    try:
        budgets = load_budgets(path)
    except FileNotFoundError:
        return [Violation(
            "HMG103", str(path or BUDGETS_PATH), 0,
            "budgets.json missing — run "
            "'python -m tools.staticcheck --write-budgets'")]
    return check_budgets(measured, budgets)
