"""Relationship-heavy scenario benchmark for the declarative query engine.

Canned plans exercising the query classes HMGI claims to win on (complex,
relationship-heavy hybrid queries): a filtered 2-hop traversal, a typed
traversal, a cross-modal re-score chain, and an intersection of two seed
scans. Reports ms/query end-to-end through ``HMGIIndex.query`` (compile +
execute, the production path) plus the compiled plan choice per scenario,
so future PRs have a latency trajectory for complex queries and can see
planner decisions shift.
"""
from __future__ import annotations

import numpy as np
import jax

from benchmarks.common import build_hmgi, timeit
from repro.data.synthetic import make_corpus
from repro.query import Q

N_NODES = 4096
N_QUERIES = 16
K = 10


def _dual_modality_corpus(seed=0):
    """Every node carries text AND image embeddings (cross-modal re-score
    needs a shared id space), with the synthetic KG's typed edges."""
    rng = np.random.default_rng(seed)
    corpus = make_corpus(n_nodes=N_NODES, modality_dims={"text": 64}, seed=seed)
    ids = np.arange(N_NODES, dtype=np.int32)
    vt = rng.normal(size=(N_NODES, 64)).astype(np.float32)
    vt[corpus.node_ids["text"]] = corpus.vectors["text"]
    vi = rng.normal(size=(N_NODES, 48)).astype(np.float32)
    corpus.node_ids["text"], corpus.vectors["text"] = ids, vt
    corpus.node_ids["image"], corpus.vectors["image"] = ids, vi
    return corpus, rng


def run(report):
    corpus, rng = _dual_modality_corpus()
    idx = build_hmgi(corpus, n_partitions=32, n_probe=8)
    idx.set_attributes({"year": rng.integers(2000, 2030, N_NODES),
                        "cat": rng.integers(0, 8, N_NODES)})

    sel = rng.integers(0, N_NODES, N_QUERIES)
    q = (corpus.vectors["text"][sel]
         + 0.05 * rng.normal(size=(N_QUERIES, 64))).astype(np.float32)
    q2 = (corpus.vectors["text"][rng.integers(0, N_NODES, N_QUERIES)]
          + 0.05 * rng.normal(size=(N_QUERIES, 64))).astype(np.float32)
    qi = (corpus.vectors["image"][sel]
          + 0.05 * rng.normal(size=(N_QUERIES, 48))).astype(np.float32)

    scenarios = [
        ("filtered_2hop",
         Q.vector("text", q).where(("year", ">", 2018)).traverse(2).topk(K)),
        ("typed_2hop",
         Q.vector("text", q).traverse(2, edge_types=(0, 1)).topk(K)),
        ("cross_modal_rescore",
         Q.vector("text", q).traverse(1)
          .cross_modal("image", qi, weight=0.5).topk(K)),
        ("intersect_two_seeds",
         Q.intersect(Q.vector("text", q).topk(4 * K),
                     Q.vector("text", q2).topk(4 * K)).topk(K)),
        ("union_then_traverse",
         Q.union(Q.vector("text", q).topk(2 * K),
                 Q.vector("image", qi).topk(2 * K)).traverse(1).topk(K)),
    ]
    for name, plan in scenarios:
        def call(p=plan):
            return jax.block_until_ready(idx.query(p)[0])
        t = timeit(call, trials=5, warmup=2)
        choice = idx.explain(plan).replace(",", ";")
        report(f"query/{name}", t * 1e6 / N_QUERIES, choice)
