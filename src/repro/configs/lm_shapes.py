"""Shared LM-family shape set (assigned per the task block)."""
from repro.configs.base import ShapeSpec


def lm_shapes(*, long_ok: bool, long_note: str = "") -> list[ShapeSpec]:
    return [
        ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
        ShapeSpec("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
        ShapeSpec("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
        ShapeSpec(
            "long_500k", "decode", {"seq_len": 524288, "global_batch": 1},
            skip=not long_ok,
            skip_reason="" if long_ok else (
                long_note or "pure full-attention arch: no sub-quadratic path at 500k "
                "(skip recorded per docs/DESIGN.md §4)"),
        ),
    ]
