"""Layer-wise neighbor sampler (GraphSAGE-style fanout trees) — the real
sampler the ``minibatch_lg`` cells require: CSR-backed, numpy, per-target
padded trees so the device step is fixed-shape.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass
class SampledBatch:
    """Per-target padded subgraph trees, stacked over the batch.

    nodes:    (B, n_sub) int32  global node ids (row 0 = the target), -1 pad
    feats:    (B, n_sub, F) fp32
    edge_src: (B, n_edge) int32  local (within-sample) indices
    edge_dst: (B, n_edge) int32
    edge_mask:(B, n_edge) bool
    labels:   (B,) int32
    """
    nodes: np.ndarray
    feats: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_mask: np.ndarray
    labels: np.ndarray


def sizes_for_fanout(fanouts: Tuple[int, ...]) -> Tuple[int, int]:
    """(n_sub, n_edge) for a padded fanout tree."""
    n_sub, frontier, n_edge = 1, 1, 0
    for f in fanouts:
        n_edge += frontier * f
        frontier *= f
        n_sub += frontier
    return n_sub, n_edge


class NeighborSampler:
    def __init__(self, n_nodes: int, src: np.ndarray, dst: np.ndarray,
                 feats: np.ndarray, labels: np.ndarray, seed: int = 0):
        order = np.argsort(dst, kind="stable")       # CSR by dst: in-neighbors
        self.nbr = src[order].astype(np.int32)
        counts = np.bincount(dst, minlength=n_nodes)
        self.indptr = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(counts, out=self.indptr[1:])
        self.feats = feats
        self.labels = labels
        self.rng = np.random.default_rng(seed)
        self.n_nodes = n_nodes

    def _sample_neighbors(self, node: int, k: int) -> np.ndarray:
        lo, hi = self.indptr[node], self.indptr[node + 1]
        if hi == lo:
            return np.full(k, -1, np.int32)
        idx = self.rng.integers(lo, hi, k)
        return self.nbr[idx]

    def sample(self, targets: np.ndarray, fanouts: Tuple[int, ...]) -> SampledBatch:
        b = len(targets)
        n_sub, n_edge = sizes_for_fanout(fanouts)
        nodes = np.full((b, n_sub), -1, np.int32)
        esrc = np.zeros((b, n_edge), np.int32)
        edst = np.zeros((b, n_edge), np.int32)
        emask = np.zeros((b, n_edge), bool)
        for i, t in enumerate(targets):
            nodes[i, 0] = t
            frontier = [0]                      # local indices of current layer
            nxt = 1
            e = 0
            for f in fanouts:
                new_frontier = []
                for loc in frontier:
                    g = nodes[i, loc]
                    nb = (self._sample_neighbors(int(g), f) if g >= 0
                          else np.full(f, -1, np.int32))
                    for v in nb:
                        nodes[i, nxt] = v
                        esrc[i, e] = nxt        # message flows child -> parent
                        edst[i, e] = loc
                        emask[i, e] = v >= 0
                        new_frontier.append(nxt)
                        nxt += 1
                        e += 1
                frontier = new_frontier
            assert e == n_edge and nxt == n_sub
        safe = np.clip(nodes, 0, self.n_nodes - 1)
        feats = self.feats[safe] * (nodes >= 0)[..., None]
        labels = self.labels[targets]
        return SampledBatch(nodes, feats.astype(np.float32), esrc, edst, emask,
                            labels.astype(np.int32))
