"""Continuous-batching scheduler: fixed decode slots, admission queue,
per-slot sequence state (the Orca/vLLM iteration-level scheduling model,
sized for a fixed-shape jitted decode step), plus per-tenant token-bucket
admission control shared by the decode and retrieval paths.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro import obs


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (L,) int32
    max_new_tokens: int = 16
    generated: Optional[List[int]] = None
    done: bool = False
    submitted_s: float = 0.0           # perf_counter at submit (queue wait)
    tenant: str = "default"            # admission-control accounting key


# ------------------------------------------------------- per-tenant admission
@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Token-bucket parameters for one tenant: ``rate`` tokens/second
    refill into a bucket capped at ``burst``; each admitted request costs
    one token. ``rate == burst == 0`` is the sanctioned zero-quota spelling
    (always rejected)."""
    rate: float
    burst: float


class AdmissionController:
    """Per-tenant token-bucket admission (one shared instance gates both
    the decode queue and the retrieval path).

    ``try_admit`` is the whole protocol: refill the tenant's bucket by
    elapsed-time x rate (capped at burst), spend one token if available.
    Unknown tenants use ``default_quota``; with no default they are always
    admitted (admission control is opt-in per tenant). Outcomes land in
    the obs registry per tenant (``serving.tenant.<t>.admitted`` /
    ``.rejected``) plus the aggregate ``serving.admission.*`` counters.

    ``now`` is injectable so tests and the racecheck interleaver drive the
    clock deterministically. One lock guards the bucket map (declared in
    the staticcheck GUARDED_BY registry)."""

    def __init__(self, quotas: Optional[Dict[str, TenantQuota]] = None,
                 default_quota: Optional[TenantQuota] = None):
        self.quotas = dict(quotas or {})
        self.default_quota = default_quota
        self._lock = threading.Lock()
        self._buckets: Dict[str, List[float]] = {}  # tenant -> [tokens, ts]

    def _quota(self, tenant: str) -> Optional[TenantQuota]:
        return self.quotas.get(tenant, self.default_quota)

    def try_admit(self, tenant: str = "default", *,
                  now: Optional[float] = None) -> bool:
        quota = self._quota(tenant)
        if quota is None:
            obs.counter(f"serving.tenant.{tenant}.admitted").inc()
            obs.counter("serving.admission.admitted").inc()
            return True
        now = time.monotonic() if now is None else float(now)
        with self._lock:
            bucket = self._buckets.get(tenant)
            if bucket is None:
                bucket = [float(quota.burst), now]
                self._buckets[tenant] = bucket
            tokens, last = bucket
            tokens = min(float(quota.burst),
                         tokens + max(now - last, 0.0) * quota.rate)
            ok = tokens >= 1.0
            bucket[0] = tokens - 1.0 if ok else tokens
            bucket[1] = now
        verdict = "admitted" if ok else "rejected"
        obs.counter(f"serving.tenant.{tenant}.{verdict}").inc()
        obs.counter(f"serving.admission.{verdict}").inc()
        return ok


@dataclasses.dataclass
class Slot:
    active: bool = False
    rid: int = -1
    pos: int = 0                       # next position to decode
    remaining: int = 0


class ContinuousBatcher:
    """Admits requests into free slots; evicts finished ones each step.

    With an ``AdmissionController`` attached, ``submit`` first spends one
    of the request's tenant's tokens; with ``max_queue > 0`` the wait
    queue is bounded and an arrival past the bound is rejected (load
    shedding at the door instead of unbounded queue growth). A rejected
    request is marked done with no generated tokens and counted under
    ``serving.rejected`` (+ the per-tenant counter)."""

    def __init__(self, n_slots: int,
                 admission: Optional[AdmissionController] = None,
                 max_queue: int = 0):
        self.slots = [Slot() for _ in range(n_slots)]
        self.queue: Deque[Request] = deque()
        self.requests: Dict[int, Request] = {}
        self.admission = admission
        self.max_queue = int(max_queue)

    def submit(self, req: Request) -> bool:
        req.generated = []
        req.submitted_s = time.perf_counter()
        if self.max_queue and len(self.queue) >= self.max_queue:
            req.done = True
            obs.counter("serving.rejected").inc()
            obs.counter(f"serving.tenant.{req.tenant}.rejected").inc()
            obs.counter("serving.rejected_queue_full").inc()
            return False
        if self.admission is not None \
                and not self.admission.try_admit(req.tenant):
            req.done = True
            obs.counter("serving.rejected").inc()
            return False
        self.requests[req.rid] = req
        self.queue.append(req)
        obs.counter("serving.submitted").inc()
        obs.gauge("serving.queue_depth").set(len(self.queue))
        return True

    def admit(self) -> List[int]:
        """Fills free slots from the queue; returns newly admitted slot ids.

        Requests with ``max_new_tokens <= 0`` complete at admission (empty
        ``generated``) and never occupy a slot — a slot would still decode
        one token for them (``remaining`` would go 0 -> -1 only after the
        first ``record_tokens``)."""
        newly = []
        for i, s in enumerate(self.slots):
            if s.active:
                continue
            while self.queue and self.queue[0].max_new_tokens <= 0:
                self.queue.popleft().done = True
            if not self.queue:
                break
            req = self.queue.popleft()
            s.active = True
            s.rid = req.rid
            s.pos = len(req.prompt)
            s.remaining = req.max_new_tokens
            newly.append(i)
            obs.counter("serving.admitted").inc()
            wait_s = time.perf_counter() - req.submitted_s
            obs.observe_ms("serving.queue_wait", wait_s)
            obs.observe_ms(f"serving.tenant.{req.tenant}.queue_wait", wait_s)
        if newly:
            obs.gauge("serving.queue_depth").set(len(self.queue))
        return newly

    def record_prefill_token(self, slot: int, token: int):
        """The first generated token comes from the prefill logits, before
        any decode step: record it (and possibly finish the request) so the
        generated stream matches sequential per-request decoding exactly.
        ``pos`` stays at the prompt length — that is where this token's KV
        will be written when it is fed to the next decode step."""
        s = self.slots[slot]
        req = self.requests[s.rid]
        req.generated.append(int(token))
        s.remaining -= 1
        if s.remaining <= 0:
            req.done = True
            s.active = False
            obs.counter("serving.evicted").inc()
            obs.counter("serving.completed").inc()

    def record_tokens(self, tokens: np.ndarray):
        """tokens (n_slots,) — one decoded token per slot this step."""
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            req = self.requests[s.rid]
            req.generated.append(int(tokens[i]))
            s.pos += 1
            s.remaining -= 1
            if s.remaining <= 0:
                req.done = True
                s.active = False
                obs.counter("serving.evicted").inc()
                obs.counter("serving.completed").inc()

    @property
    def any_active(self) -> bool:
        return any(s.active for s in self.slots) or bool(self.queue)

    def active_mask(self) -> np.ndarray:
        return np.array([s.active for s in self.slots])


class MaintenanceDriver:
    """Paces adaptive index maintenance between decode steps.

    Serving interleaves ingest with search: without maintenance the delta
    store fills and every query's scan slows; with synchronous compaction a
    full rebuild stalls an entire decode tick. This driver runs
    ``index.maintain(budget=budget_rows)`` — bounded work by construction —
    every ``interval``-th tick, so the ingest-while-search steady state pays
    a small, constant maintenance tax per tick instead of rare large stalls.
    The engine calls ``tick()`` after each decode step; a no-op maintain
    costs one O(K) planning pass.

    When the index is durable (has a ``snapshot()`` method) and
    ``snapshot_interval > 0``, every ``snapshot_interval``-th tick also
    writes a versioned snapshot — bounding crash-recovery replay at roughly
    one snapshot interval's worth of ops. A no-change snapshot is a no-op
    inside ``DurableHMGIIndex.snapshot`` itself."""

    def __init__(self, index, budget_rows: int = 256, interval: int = 4,
                 snapshot_interval: int = 0):
        self.index = index
        self.budget_rows = budget_rows
        self.interval = max(int(interval), 1)
        self.snapshot_interval = max(int(snapshot_interval), 0)
        self.ticks = 0
        self.runs = 0
        self.snapshots = 0
        self.last_report = None

    def tick(self):
        self.ticks += 1
        if self.index is None:
            return None
        if (self.snapshot_interval
                and self.ticks % self.snapshot_interval == 0
                and hasattr(self.index, "snapshot")):
            if self.index.snapshot() is not None:
                self.snapshots += 1
        if self.ticks % self.interval:
            return None
        # "maintenance.stall" is the decode-tick stall this driver causes:
        # the inline maintain() wall time as seen from the serving loop
        # (index.maintain's own histogram counts every pass, including the
        # mutation-path auto-triggers)
        with obs.span("maintenance.stall"):
            self.last_report = self.index.maintain(budget=self.budget_rows)
        self.runs += 1
        return self.last_report
