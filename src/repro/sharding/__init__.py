from repro.sharding.rules import (
    DEFAULT_RULES, batch_axes, logical_to_spec, rule_overrides, shard_tree,
    with_sharding,
)
