"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.  [arXiv:2401.04088; hf]"""
from repro.configs.base import LMConfig
from repro.configs.lm_shapes import lm_shapes

CONFIG = LMConfig(
    arch_id="mixtral-8x7b",
    source="arXiv:2401.04088; hf",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    moe=True,
    n_experts=8,
    top_k=2,
)

# SWA (W=4096) => decode touches a bounded window + rolling cache: sub-quadratic.
SHAPES = lm_shapes(long_ok=True)
