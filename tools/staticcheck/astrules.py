"""Layer 1: Python-AST lints (HMG001-HMG004).

Checked modules are parsed, never imported — the rules here run in
milliseconds and need no jax. Scope discipline is what keeps the rules
honest: hot-path modules legitimately mix host-side orchestration (numpy,
``int()`` on shapes) with traced code, so HMG001 only fires *inside*
functions that are actually traced — jit-decorated defs, their nested
defs, and local functions handed to ``lax.scan``/``while_loop``/``cond``/
``fori_loop``/``vmap``.
"""
from __future__ import annotations

import ast
from pathlib import PurePosixPath
from typing import Dict, List, Optional, Set, Tuple

from tools.staticcheck import Violation
from tools.staticcheck.registry import (
    FSYNC_CALLS,
    HAZARD_CALLS,
    HOT_PATH_DIRS,
    HOT_PATH_MODULES,
    MVCC_ENTRY_POINTS,
    PERSISTENCE_DIRS,
    RENAME_CALLS,
    SANCTIONED_SHAPE_HELPERS,
    STATIC_INT_PARAMS,
)

_LAX_CALLBACK_OPS = {"scan", "while_loop", "cond", "fori_loop", "vmap",
                     "switch", "checkpoint", "remat"}
_HOST_SYNC_ATTRS = {"item", "block_until_ready", "tolist"}
_NUMPY_ALIASES = {"np", "numpy", "onp"}


def _posix(path: str) -> str:
    return PurePosixPath(path).as_posix()


def is_hot_module(path: str) -> bool:
    p = _posix(path)
    return (any(p.endswith(m) for m in HOT_PATH_MODULES)
            or any(d.rstrip("/") + "/" in p for d in HOT_PATH_DIRS))


def is_persistence_module(path: str) -> bool:
    p = _posix(path)
    return any(d.rstrip("/") + "/" in p for d in PERSISTENCE_DIRS)


def _callee_name(call: ast.Call) -> Tuple[Optional[str], Optional[str]]:
    """(receiver, name) — receiver is the dotted prefix's last segment,
    None for bare names."""
    f = call.func
    if isinstance(f, ast.Name):
        return None, f.id
    if isinstance(f, ast.Attribute):
        recv = f.value
        if isinstance(recv, ast.Name):
            return recv.id, f.attr
        if isinstance(recv, ast.Attribute):
            return recv.attr, f.attr
        return "", f.attr
    return None, None


def _is_jit_decorator(dec: ast.expr) -> bool:
    """jax.jit / jit / functools.partial(jax.jit, ...) / pl.pallas_call."""
    if isinstance(dec, ast.Call):
        recv, name = _callee_name(dec)
        if name == "partial":
            return any(_is_jit_decorator(a) for a in dec.args)
        return name in ("jit", "pallas_call")
    if isinstance(dec, ast.Attribute):
        return dec.attr in ("jit", "pallas_call")
    if isinstance(dec, ast.Name):
        return dec.id == "jit"
    return False


def _collect_traced_functions(tree: ast.Module) -> Set[ast.AST]:
    """Function defs whose bodies execute under trace: jit-decorated defs
    (plus everything nested inside them) and local defs passed by name to
    lax control-flow / vmap combinators."""
    by_name: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(node.name, []).append(node)

    traced: Set[ast.AST] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_is_jit_decorator(d) for d in node.decorator_list):
                traced.add(node)
        elif isinstance(node, ast.Call):
            _, name = _callee_name(node)
            if name in _LAX_CALLBACK_OPS:
                for arg in node.args:
                    if isinstance(arg, ast.Name):
                        traced.update(by_name.get(arg.id, ()))
                    elif isinstance(arg, ast.Lambda):
                        traced.add(arg)

    # nested defs inherit tracedness from their enclosing traced def
    closed: Set[ast.AST] = set(traced)
    for fn in traced:
        for sub in ast.walk(fn):
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                closed.add(sub)
    return closed


# --------------------------------------------------------------------- HMG001
def check_hmg001(path: str, tree: ast.Module) -> List[Violation]:
    if not is_hot_module(path):
        return []
    out: List[Violation] = []
    traced = _collect_traced_functions(tree)

    def scan_fn(fn: ast.AST) -> None:
        own_nested = {sub for sub in ast.walk(fn)
                      if sub is not fn and isinstance(
                          sub, (ast.FunctionDef, ast.AsyncFunctionDef))}
        for node in ast.walk(fn):
            # nested defs are scanned on their own traced pass
            if any(node is s or _contains(s, node) for s in own_nested):
                continue
            if not isinstance(node, ast.Call):
                continue
            recv, name = _callee_name(node)
            if name in _HOST_SYNC_ATTRS and isinstance(node.func,
                                                       ast.Attribute):
                out.append(Violation(
                    "HMG001", path, node.lineno,
                    f".{name}() forces a host sync inside a traced "
                    "function — keep device values on device"))
            elif recv is None and name in ("float", "int") and node.args:
                out.append(Violation(
                    "HMG001", path, node.lineno,
                    f"builtin {name}() on a traced value blocks and "
                    "pulls to host — use jnp casts instead"))
            elif recv in _NUMPY_ALIASES:
                out.append(Violation(
                    "HMG001", path, node.lineno,
                    f"host numpy call {recv}.{name}() inside a traced "
                    "function — use jax.numpy"))
            elif recv == "jax" and name == "device_get":
                out.append(Violation(
                    "HMG001", path, node.lineno,
                    "jax.device_get inside a traced function"))

    seen: Set[int] = set()
    for fn in traced:
        if id(fn) in seen:
            continue
        seen.add(id(fn))
        scan_fn(fn)
    return out


def _contains(outer: ast.AST, node: ast.AST) -> bool:
    return any(node is sub for sub in ast.walk(outer))


# --------------------------------------------------------------------- HMG002
def _expr_has_sanctioner(expr: ast.AST) -> bool:
    for node in ast.walk(expr):
        if isinstance(node, ast.Call):
            _, name = _callee_name(node)
            if name in SANCTIONED_SHAPE_HELPERS:
                return True
    return False


def _expr_has_hazard(expr: ast.AST) -> bool:
    for node in ast.walk(expr):
        if isinstance(node, ast.Call):
            recv, name = _callee_name(node)
            if recv is None and name in HAZARD_CALLS:
                return True
    return False


def _assignments_in_scope(tree: ast.Module) -> Dict[Tuple[int, str],
                                                    List[ast.expr]]:
    """(scope id, name) -> assigned value expressions, per function scope
    (module scope keyed on id(tree))."""
    out: Dict[Tuple[int, str], List[ast.expr]] = {}

    def visit(scope_id: int, body) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                visit(id(stmt), stmt.body)
                continue
            for node in ast.walk(stmt):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    continue
                val = None
                targets: List[ast.expr] = []
                if isinstance(node, ast.Assign):
                    val, targets = node.value, node.targets
                elif isinstance(node, ast.AnnAssign) and node.value:
                    val, targets = node.value, [node.target]
                elif isinstance(node, ast.AugAssign):
                    val, targets = node.value, [node.target]
                if val is None:
                    continue
                for t in targets:
                    if isinstance(t, ast.Name):
                        out.setdefault((scope_id, t.id), []).append(val)

    visit(id(tree), tree.body)
    return out


def check_hmg002(path: str, tree: ast.Module) -> List[Violation]:
    out: List[Violation] = []
    assigns = _assignments_in_scope(tree)

    # map every call back to its enclosing function scope
    scope_of: Dict[int, int] = {}

    def mark(scope_id: int, body) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                mark(id(stmt), stmt.body)
                continue
            for node in ast.walk(stmt):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    mark(id(node), node.body)
                elif isinstance(node, ast.Call):
                    scope_of.setdefault(id(node), scope_id)

    mark(id(tree), tree.body)

    def value_is_sanctioned(expr: ast.AST, scope_id: int) -> bool:
        """Sanctioned directly, or via any one-level Name resolution —
        if any assignment feeding the name routes through a padding
        helper, the call site inherits the sanction (covers doubling
        loops like ``k = min(2*k, k_max)`` whose seed is pow2-rounded)."""
        if _expr_has_sanctioner(expr):
            return True
        for node in ast.walk(expr):
            if isinstance(node, ast.Name):
                for scope in (scope_id, id(tree)):
                    for val in assigns.get((scope, node.id), ()):
                        if _expr_has_sanctioner(val):
                            return True
        return False

    def value_is_hazard(expr: ast.AST, scope_id: int) -> bool:
        if _expr_has_hazard(expr):
            return True
        for node in ast.walk(expr):
            if isinstance(node, ast.Name):
                for scope in (scope_id, id(tree)):
                    vals = assigns.get((scope, node.id), ())
                    if any(_expr_has_hazard(v) and
                           not _expr_has_sanctioner(v) for v in vals):
                        return True
        return False

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        _, name = _callee_name(node)
        params = STATIC_INT_PARAMS.get(name or "")
        if not params:
            continue
        scope_id = scope_of.get(id(node), id(tree))
        exprs: List[Tuple[str, ast.expr]] = []
        for pname, pos in params.items():
            for kw in node.keywords:
                if kw.arg == pname:
                    exprs.append((pname, kw.value))
            if pos is not None and pos < len(node.args):
                exprs.append((pname, node.args[pos]))
        for pname, expr in exprs:
            if value_is_hazard(expr, scope_id) and \
                    not value_is_sanctioned(expr, scope_id):
                out.append(Violation(
                    "HMG002", path, node.lineno,
                    f"data-dependent Python int reaches static arg "
                    f"'{pname}' of jitted entry '{name}' — every distinct "
                    "value compiles a new executable; route through "
                    "pow2_round/pad_to_chunk (repro.common.shapes)"))
    return out


# --------------------------------------------------------------------- HMG003
def check_hmg003(path: str, tree: ast.Module) -> List[Violation]:
    p = _posix(path)
    # the defining modules themselves are exempt (they implement the entry
    # points; internal self-calls are audited by review, not the linter)
    if p.endswith("src/repro/core/delta.py"):
        return []
    out: List[Violation] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        recv, name = _callee_name(node)
        spec = MVCC_ENTRY_POINTS.get(name or "")
        if not spec:
            continue
        receivers, kwargs_ok = spec
        if receivers is not None and recv not in receivers:
            continue
        if p.endswith("src/repro/core/ivf.py") and name in (
                "search", "search_sharded"):
            continue
        spelled = {kw.arg for kw in node.keywords}
        if not spelled.intersection(kwargs_ok):
            out.append(Violation(
                "HMG003", path, node.lineno,
                f"call to scan entry '{name}' does not thread a "
                f"visibility kwarg ({' or '.join(kwargs_ok)}); pass it "
                "explicitly (an explicit =None documents the opt-out) or "
                "pragma with a reason", fixable=True))
    return out


# --------------------------------------------------------------------- HMG004
def _call_names_in(fn: ast.AST):
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            recv, name = _callee_name(node)
            yield node, recv, name


def check_hmg004(path: str, tree: ast.Module) -> List[Violation]:
    if not is_persistence_module(path):
        return []
    out: List[Violation] = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        calls = list(_call_names_in(fn))

        # rename/replace must be dominated by an fsync earlier in the fn
        for node, recv, name in calls:
            if recv == "os" and name in RENAME_CALLS:
                fsync_before = any(
                    n in FSYNC_CALLS and c.lineno <= node.lineno
                    for c, _, n in calls)
                if not fsync_before:
                    out.append(Violation(
                        "HMG004", path, node.lineno,
                        f"os.{name} without a preceding fsync in "
                        f"'{fn.name}' — a crash can publish an "
                        "incompletely-written file"))

        # WAL append-before-apply: a fn that both appends to a log and
        # applies (yield-style context manager, or super() delegation)
        # must append first
        log_appends = [c for c, recv, n in calls
                       if n == "append" and recv in ("_log", "log",
                                                     "oplog", "_oplog")]
        if log_appends:
            append_line = min(c.lineno for c in log_appends)
            yields = [n.lineno for n in ast.walk(fn)
                      if isinstance(n, (ast.Yield, ast.YieldFrom))]
            applies = [c.lineno for c, recv, n in calls
                       if recv == "super" or (n or "").startswith("_apply")
                       or recv == "_apply"]
            # super() shows up as call-of-call: super().insert(...)
            for c, recv, n in calls:
                if isinstance(c.func, ast.Attribute) and \
                        isinstance(c.func.value, ast.Call):
                    r2, n2 = _callee_name(c.func.value)
                    if n2 == "super":
                        applies.append(c.lineno)
            for line in yields + applies:
                if line < append_line:
                    out.append(Violation(
                        "HMG004", path, line,
                        f"state applied before WAL append in "
                        f"'{fn.name}' — log-then-apply is the recovery "
                        "contract"))
                    break
    return out


from tools.staticcheck.concurrency import CONCURRENCY_AST_RULES  # noqa: E402

ALL_AST_RULES = {
    "HMG001": check_hmg001,
    "HMG002": check_hmg002,
    "HMG003": check_hmg003,
    "HMG004": check_hmg004,
    **CONCURRENCY_AST_RULES,
}


def check_source(path: str, source: str,
                 rules: Optional[Set[str]] = None) -> List[Violation]:
    """All AST-layer violations for one file (pragmas NOT yet applied —
    the driver handles suppression so it can also audit the pragmas)."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Violation("HMG000", path, e.lineno or 0,
                          f"file does not parse: {e.msg}")]
    out: List[Violation] = []
    seen: Set[Violation] = set()
    for rule, fn in ALL_AST_RULES.items():
        if rules and rule not in rules:
            continue
        for v in fn(path, tree):
            if v not in seen:       # a lambda traced via two routes would
                seen.add(v)         # otherwise report twice
                out.append(v)
    return out
