"""Host data pipeline: deterministic sharded batches with background
prefetch and restart-safe skipping.

Determinism contract (fault tolerance): batch ``i`` is a pure function of
(seed, i), so a restarted trainer resumes mid-epoch by fast-forwarding the
step counter — no data-state checkpointing needed.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np


class SyntheticLMStream:
    """Deterministic synthetic LM token stream (per-step fresh RNG)."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int, seed: int = 0):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq_len
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.integers(0, self.vocab, (self.batch, self.seq + 1), dtype=np.int64)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class SyntheticRecsysStream:
    def __init__(self, n_fields: int, vocab: int, batch: int, seed: int = 0):
        self.f, self.v, self.b, self.seed = n_fields, vocab, batch, seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        ids = rng.integers(0, self.v, (self.b, self.f), dtype=np.int64)
        # click labelled by a planted sparse rule so accuracy can move
        y = ((ids[:, 0] + ids[:, 1]) % 7 < 3).astype(np.int32)
        return {"ids": ids.astype(np.int32), "labels": y}


class Prefetcher:
    """Background-thread prefetch of ``stream.batch_at(step)``."""

    def __init__(self, stream, start_step: int = 0, depth: int = 2):
        self.stream = stream
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self.q.put((s, self.stream.batch_at(s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
