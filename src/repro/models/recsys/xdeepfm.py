"""xDeepFM (Lian et al., arXiv:1803.05170): linear + CIN + DNN over sparse
field embeddings.

CIN layer k:  X^k_{h} = Σ_{i,j} W^k_{h,i,j} (X^{k-1}_i ∘ X^0_j)   (outer
product over fields, compressed by a learned kernel) — computed as einsums
(MXU-dense, no materialised (H_{k-1}·m·D) tensor beyond one hop).

``retrieval_score`` scores one user against N candidate items as a batched
dot product over joint embeddings — the HMGI retrieval-scoring path
(``retrieval_cand`` shape; no loops).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.params import Builder
from repro.models.recsys.embedding_bag import init_tables, lookup, lookup_sharded


def init(cfg, key):
    b = Builder(key, dtype=jnp.float32)
    tp, ta = init_tables(b.key(), cfg.n_sparse, cfg.vocab_per_field, cfg.embed_dim)
    b.params.update(tp)
    b.axes.update(ta)
    # first-order (linear) weights: one scalar per id
    b.dense("linear_w", (cfg.n_sparse, cfg.vocab_per_field),
            (None, "table"), fan_in=cfg.vocab_per_field, scale=0.1)
    b.zeros("bias", (1,), (None,))
    m = cfg.n_sparse
    prev = m
    for k, h in enumerate(cfg.cin_layers):
        b.dense(f"cin_w{k}", (h, prev, m), (None, None, None), fan_in=prev * m)
        prev = h
    b.dense("cin_out", (sum(cfg.cin_layers), 1), (None, None),
            fan_in=sum(cfg.cin_layers))
    d_in = cfg.n_sparse * cfg.embed_dim
    for k, h in enumerate(cfg.mlp_layers):
        b.dense(f"mlp_w{k}", (d_in, h), (None, "mlp"), fan_in=d_in)
        b.zeros(f"mlp_b{k}", (h,), (None,))
        d_in = h
    b.dense("mlp_out", (d_in, 1), (None, None), fan_in=d_in)
    return b.build()


def cin(params, x0: jax.Array, n_layers: int) -> jax.Array:
    """x0: (B, m, D). Returns (B, Σh) pooled CIN features."""
    xk = x0
    pooled = []
    for k in range(n_layers):
        w = params[f"cin_w{k}"]                       # (H, prev, m)
        # z (B, prev, m, D) contracted against W -> (B, H, D)
        xk = jnp.einsum("bpd,bmd,hpm->bhd", xk, x0, w)
        pooled.append(jnp.sum(xk, axis=-1))           # (B, H)
    return jnp.concatenate(pooled, axis=-1)


def forward(cfg, params, ids: jax.Array, mesh=None) -> jax.Array:
    """ids (B, F) int32 -> logits (B,)."""
    if mesh is not None:
        emb = lookup_sharded(params["tables"], ids, mesh)    # (B, F, D)
    else:
        emb = lookup(params["tables"], ids)
    bsz = ids.shape[0]

    # first order
    lin_rows = jax.vmap(lambda w, i: jnp.take(w, i, mode="clip"),
                        in_axes=(0, 1), out_axes=1)(params["linear_w"], ids)
    first = jnp.sum(lin_rows, axis=-1)                       # (B,)

    cin_feat = cin(params, emb, len(cfg.cin_layers))         # (B, Σh)
    cin_logit = (cin_feat @ params["cin_out"])[:, 0]

    h = emb.reshape(bsz, -1)
    for k in range(len(cfg.mlp_layers)):
        h = jax.nn.relu(h @ params[f"mlp_w{k}"] + params[f"mlp_b{k}"])
    mlp_logit = (h @ params["mlp_out"])[:, 0]

    return first + cin_logit + mlp_logit + params["bias"][0]


def loss_fn(cfg, params, batch, mesh=None):
    logits = forward(cfg, params, batch["ids"], mesh)
    y = batch["labels"].astype(jnp.float32)
    # numerically-stable BCE with logits
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    acc = jnp.mean(((logits > 0) == (y > 0.5)).astype(jnp.float32))
    return loss, {"acc": acc}


def retrieval_score(cfg, params, user_ids: jax.Array, cand_ids: jax.Array,
                    mesh=None) -> jax.Array:
    """One query against N candidates (batched dot, not a loop).

    user_ids (F_u,) — the user's feature ids; cand_ids (N, F_i) — candidate
    item feature ids. Score = <pooled user embedding, pooled item embedding>.
    The candidate axis shards over ("pod","data").

    Distributed path (§Perf iteration 3): *score-then-reduce* — the user
    embedding (F·D floats) broadcasts everywhere; each "model" shard computes
    partial scores from its resident table rows and the psum moves only the
    (B,) score vector instead of (B, F, D) embedding rows (~780x fewer
    collective bytes than gather-then-score).
    """
    if mesh is None:
        u = lookup(params["tables"], user_ids[None, :])[0]   # (F, D)
        c = lookup(params["tables"], cand_ids)               # (N, F, D)
        return c.reshape(c.shape[0], -1) @ u.reshape(-1)

    u = lookup_sharded(params["tables"], user_ids[None, :], mesh)[0]  # (F, D)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    shard_batch = bool(data_axes) and cand_ids.shape[0] % n_data == 0
    bspec = (data_axes if len(data_axes) > 1 else data_axes[0]) if shard_batch else None

    def local(t, cids, u):
        v_loc = t.shape[1]
        rank = jax.lax.axis_index("model")
        rel = cids - rank * v_loc
        ok = jnp.logical_and(rel >= 0, rel < v_loc)
        rows = jax.vmap(lambda tt, ii: jnp.take(tt, ii, axis=0, mode="clip"),
                        in_axes=(0, 1), out_axes=1)(t, jnp.clip(rel, 0, v_loc - 1))
        rows = jnp.where(ok[..., None], rows, 0.0)           # (B_loc, F, D)
        partial = jnp.einsum("bfd,fd->b", rows, u)
        return jax.lax.psum(partial, "model")                # (B_loc,) scores

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(None, "model", None), P(bspec, None), P(None, None)),
        out_specs=P(bspec),
        check_vma=False,
    )
    return fn(params["tables"], cand_ids, u)
