"""GNN execution substrate: flat graphs, a ring-distributed gather engine,
and the generic train/serve steps shared by all four assigned archs.

Execution layouts (docs/DESIGN.md §5):

  * ``FlatGraph`` — one (possibly huge) graph as flat padded arrays. Single
    device: plain segment ops. Distributed: nodes block-sharded over the
    ("pod","data") axes; edges live with their *destination* owner, grouped
    by source-owner round; per layer, node features rotate around the data
    ring (``lax.ppermute``) and each shard gathers the sources it needs that
    round, computes messages, and segment-sums into its local destinations.
    One feature rotation per round — the classic distributed-GNN halo
    exchange expressed as a collective-friendly ring (bytes = N·d per layer),
    with per-destination attention/softmax fully local (all in-edges of an
    owned node are owned).

  * ``(B, n, ...)`` dense per-sample trees/molecules — vmapped message
    passing, pure data parallelism (minibatch_lg, molecule shapes).

Geometric archs on non-geometric graphs (Cora/ogbn-products have no 3D
coordinates) get synthetic unit-sphere positions — the assignment pairs
molecular archs with citation graphs; the arch must still run (docs/DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.sparse import segment as seg


class FlatGraph(NamedTuple):
    """Single-device flat layout. All arrays fixed-shape, -1/-False padded."""
    feats: jax.Array        # (N, F)
    positions: jax.Array    # (N, 3)
    edge_src: jax.Array     # (E,) int32
    edge_dst: jax.Array     # (E,) int32
    edge_mask: jax.Array    # (E,) bool
    node_mask: jax.Array    # (N,) bool
    labels: jax.Array       # (N,) int32

    @property
    def n_nodes(self) -> int:
        return self.feats.shape[0]


class RingGraph(NamedTuple):
    """Distributed flat layout (global arrays; leading dims shard over data).

    Node arrays: (N, ...) block-sharded (owner = id // n_loc).
    Edge arrays: (S, n_rounds, E_cap, ...) — shard s's edges grouped by
    source-owner round r (src owner = (s - r) mod S); dst indices are local.
    """
    feats: jax.Array        # (N, F)
    positions: jax.Array    # (N, 3)
    esrc_local: jax.Array   # (S, R, E_cap) int32 — row in the rotating buffer
    edst_local: jax.Array   # (S, R, E_cap) int32 — local destination row
    edge_mask: jax.Array    # (S, R, E_cap) bool
    node_mask: jax.Array    # (N,) bool
    labels: jax.Array       # (N,) int32


# ---------------------------------------------------------------------------
# host-side conversion
# ---------------------------------------------------------------------------

def to_ring(g: "FlatGraph | dict", n_shards: int,
            e_cap: Optional[int] = None) -> RingGraph:
    """Host-side regrouping of a FlatGraph into the ring layout."""
    feats = np.asarray(g.feats)
    n = feats.shape[0]
    assert n % n_shards == 0, (n, n_shards)
    n_loc = n // n_shards
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.edge_dst)
    mask = np.asarray(g.edge_mask)
    src, dst = src[mask], dst[mask]
    s_own = src // n_loc
    d_own = dst // n_loc
    rounds = (d_own - s_own) % n_shards
    if e_cap is None:
        e_cap = 1
        for s in range(n_shards):
            for r in range(n_shards):
                e_cap = max(e_cap, int(np.sum((d_own == s) & (rounds == r))))
    esrc = np.zeros((n_shards, n_shards, e_cap), np.int32)
    edst = np.zeros((n_shards, n_shards, e_cap), np.int32)
    em = np.zeros((n_shards, n_shards, e_cap), bool)
    for s in range(n_shards):
        for r in range(n_shards):
            sel = (d_own == s) & (rounds == r)
            k = int(np.sum(sel))
            k = min(k, e_cap)
            idx = np.where(sel)[0][:k]
            esrc[s, r, :k] = src[idx] % n_loc
            edst[s, r, :k] = dst[idx] % n_loc
            em[s, r, :k] = True
    return RingGraph(
        feats=jnp.asarray(feats), positions=jnp.asarray(g.positions),
        esrc_local=jnp.asarray(esrc), edst_local=jnp.asarray(edst),
        edge_mask=jnp.asarray(em), node_mask=jnp.asarray(g.node_mask),
        labels=jnp.asarray(g.labels))


# ---------------------------------------------------------------------------
# execution engines — models code against this interface
# ---------------------------------------------------------------------------

class LocalExec:
    """Single-device engine over a FlatGraph."""

    def __init__(self, g: FlatGraph):
        self.g = g
        self.n = g.n_nodes

    def edge_geometry(self):
        rel = self.g.positions[self.g.edge_src] - self.g.positions[self.g.edge_dst]
        dist = jnp.linalg.norm(rel, axis=-1)
        return rel, jnp.where(self.g.edge_mask, dist, 0.0)

    def push(self, node_payload, msg_fn, d_out: int):
        """agg[dst] = Σ_edges msg_fn(payload[src], payload[dst]).

        msg_fn: (src_rows (E, Dp), dst_rows (E, Dp)) -> (E, d_out); payload
        carries whatever the model needs (features ++ positions ++ …).
        """
        srcs = node_payload[self.g.edge_src]
        dsts = node_payload[self.g.edge_dst]
        msgs = msg_fn(srcs, dsts)
        msgs = jnp.where(self.g.edge_mask[:, None], msgs, 0.0)
        return seg.segment_sum(msgs, self.g.edge_dst, self.n)

    def gather_src(self, node_payload):
        """Per-edge source rows (E, Dp) — remote fetch on the ring engine."""
        srcs = node_payload[self.g.edge_src]
        return jnp.where(self.g.edge_mask[:, None], srcs, 0.0)

    def dst_index(self):
        """Flat local destination index + mask (edge order matches gather_src)."""
        return self.g.edge_dst, self.g.edge_mask

    def push_attn(self, node_payload, logit_fn, msg_fn, d_out: int):
        """Softmax-normalised (per destination) attention aggregation."""
        srcs = node_payload[self.g.edge_src]
        dsts = node_payload[self.g.edge_dst]
        logits = logit_fn(srcs, dsts)                           # (E, H)
        logits = jnp.where(self.g.edge_mask[:, None], logits, -jnp.inf)
        w = seg.segment_softmax(logits, self.g.edge_dst, self.n)  # (E, H)
        msgs = msg_fn(srcs, dsts)                               # (E, H, dh)
        msgs = msgs * w[..., None]
        msgs = jnp.where(self.g.edge_mask[:, None, None], msgs, 0.0)
        return seg.segment_sum(msgs.reshape(msgs.shape[0], -1),
                               self.g.edge_dst, self.n)


class RingExec:
    """Per-shard engine inside shard_map (see module docstring).

    Local views: feats (n_loc, F), edge arrays (R, E_cap_loc, ...) — each
    round's edges are additionally split across the "model" axis (16× edge
    parallelism; features replicated over "model"). The payload rotates ``R``
    times over the data ring; per-destination reductions psum over "model".
    """

    def __init__(self, esrc, edst, emask, n_loc: int, data_axes: Tuple[str, ...],
                 model_axis: Optional[str] = None, ring_size: Optional[int] = None):
        self.esrc = esrc          # (R, E_cap_loc)
        self.edst = edst
        self.emask = emask
        self.n = n_loc
        self.axes = data_axes
        self.model_axis = model_axis
        self.rounds = ring_size or esrc.shape[0]

    def _mreduce(self, x, op="sum"):
        if self.model_axis is None:
            return x
        if op == "sum":
            return jax.lax.psum(x, self.model_axis)
        # max via all_gather (pmax has no differentiation rule; the gathered
        # tensor here is the small per-destination logit-max, not features)
        g = jax.lax.all_gather(x, self.model_axis, axis=0)
        return jnp.max(g, axis=0)

    def _rotate(self, x):
        # ring over the flattened data axes: shift by one
        return jax.lax.ppermute(
            x, self.axes,
            [(i, (i + 1) % self.rounds) for i in range(self.rounds)])

    def push(self, node_payload, msg_fn, d_out: int):
        def body(carry, xs):
            buf, acc = carry
            esrc, edst, emask = xs
            msgs = msg_fn(buf[esrc], node_payload[edst])
            msgs = jnp.where(emask[:, None], msgs, 0.0)
            acc = acc + seg.segment_sum(msgs, edst, self.n)
            return (self._rotate(buf), acc), None

        acc0 = jnp.zeros((self.n, d_out), node_payload.dtype)
        (_, acc), _ = jax.lax.scan(body, (node_payload, acc0),
                                   (self.esrc, self.edst, self.emask))
        return self._mreduce(acc)

    def gather_src(self, node_payload):
        """Per-edge source rows: rotate the payload, take per round.

        Returns (R·E_cap, Dp) in (round-major) edge order — matching
        ``dst_index()``."""
        def body(buf, xs):
            esrc, emask = xs
            take = jnp.where(emask[:, None], buf[esrc], 0.0)
            return self._rotate(buf), take

        _, out = jax.lax.scan(body, node_payload, (self.esrc, self.emask))
        return out.reshape(-1, node_payload.shape[-1])

    def dst_index(self):
        return self.edst.reshape(-1), self.emask.reshape(-1)

    def push_attn(self, node_payload, logit_fn, msg_fn, d_out: int):
        # pass 1: logits per edge (small), rotating payload
        def pass1(buf, xs):
            esrc, edst, emask = xs
            logits = logit_fn(buf[esrc], node_payload[edst])
            logits = jnp.where(emask[:, None], logits, -jnp.inf)
            return self._rotate(buf), logits

        _, logits = jax.lax.scan(pass1, node_payload,
                                 (self.esrc, self.edst, self.emask))
        h = logits.shape[-1]
        flat_dst = self.edst.reshape(-1)
        # per-destination softmax across the data-local edges AND the model
        # split (all in-edges of an owned node are data-local by layout).
        # stop_gradient: the max shift is numerics-only (pmax has no VJP)
        m = seg.segment_max(logits.reshape(-1, h), flat_dst, self.n)
        m = jax.lax.stop_gradient(
            self._mreduce(jnp.where(jnp.isfinite(m), m, -3e38), "max"))
        shifted = logits.reshape(-1, h) - m[jnp.clip(flat_dst, 0, self.n - 1)]
        e = jnp.where(jnp.isfinite(shifted), jnp.exp(shifted), 0.0)
        z = self._mreduce(seg.segment_sum(e, flat_dst, self.n))
        w = e / jnp.maximum(z[jnp.clip(flat_dst, 0, self.n - 1)], 1e-20)
        w = w.reshape(logits.shape)

        # pass 2: weighted messages, rotating payload again (flash-style
        # recompute keeps the gathered features out of memory)
        def pass2(carry, xs):
            buf, acc = carry
            esrc, edst, emask, wr = xs
            msgs = msg_fn(buf[esrc], node_payload[edst])         # (E, H, dh)
            msgs = msgs * wr[..., None]
            msgs = jnp.where(emask[:, None, None], msgs, 0.0)
            acc = acc + seg.segment_sum(msgs.reshape(msgs.shape[0], -1), edst, self.n)
            return (self._rotate(buf), acc), None

        acc0 = jnp.zeros((self.n, d_out), node_payload.dtype)
        (_, acc), _ = jax.lax.scan(pass2, (node_payload, acc0),
                                   (self.esrc, self.edst, self.emask, w))
        return self._mreduce(acc)


def run_flat(apply_local, g: "FlatGraph | RingGraph", params, mesh=None):
    """Dispatch: single-device LocalExec, or shard_map ring over the mesh.

    apply_local(params, feats, positions, node_mask, labels, exec) -> loss-like
    pytree of per-shard results (psum-reduced over data axes by caller).
    """
    if mesh is None:
        ex = LocalExec(g)
        return apply_local(params, g.feats, g.positions, g.node_mask, g.labels, ex)

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_shards = 1
    for a in data_axes:
        n_shards *= mesh.shape[a]
    msize = mesh.shape.get("model", 1)
    nspec = P(data_axes if len(data_axes) > 1 else data_axes[0])
    # split each round's edges across the "model" axis
    s, r, e_cap = g.esrc_local.shape
    assert s == n_shards, (
        f"RingGraph built for {s} shards but mesh has {n_shards} data shards")
    pad = (-e_cap) % msize
    def esplit(a, fill):
        if pad:
            a = jnp.pad(a, ((0, 0), (0, 0), (0, pad)), constant_values=fill)
        return a.reshape(s, r, msize, (e_cap + pad) // msize)
    esrc = esplit(g.esrc_local, 0)
    edst = esplit(g.edst_local, 0)
    emask = esplit(g.edge_mask, False)
    espec = P(nspec[0], None, "model", None)

    def shard_fn(params, feats, pos, esrc, edst, emask, nmask, labels):
        ex = RingExec(esrc[0, :, 0], edst[0, :, 0], emask[0, :, 0],
                      feats.shape[0], data_axes,
                      model_axis="model" if msize > 1 else None,
                      ring_size=n_shards)
        out = apply_local(params, feats, pos, nmask, labels, ex)
        # convention: apply_local returns per-shard SUMS -> global psum
        return jax.tree.map(lambda t: jax.lax.psum(t, data_axes), out)

    fn = jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), nspec, nspec, espec, espec, espec, nspec, nspec),
        out_specs=P(),
        check_vma=False,
    )
    return fn(params, g.feats, g.positions, esrc, edst, emask,
              g.node_mask, g.labels)
