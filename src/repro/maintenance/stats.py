"""Per-partition maintenance statistics, tracked incrementally at write time.

The adaptive maintenance loop (docs/DESIGN.md §3.4) decides from four
signals, each cheap enough to maintain on the write path itself:

- **heat** — probe hits per partition. Already tracked by
  ``partitioner.WorkloadStats`` (the executor's seed stage records every
  probe list); the summary reads it, this module does not duplicate it.
- **delta pressure** — the delta store's append watermark vs. capacity
  (O(1) from the store itself) — every query scans the whole delta, so its
  fill is pure per-query cost.
- **tombstone ratio** — ``dead``: stable rows per partition hidden by a
  tombstone or superseded bit. Incremented by the facade on ``delete`` /
  update (one id→partition lookup against a lazily built slab map),
  decremented by the executor when a drain overwrites or a merge purges the
  dead row.
- **centroid drift** — mean assigned-vector distance of *newly written*
  rows vs. the build-time ``baseline`` per partition. ``record_writes``
  accumulates (Σdist, n) at insert time from ``assign_with_distance``;
  ``drift_ratio`` is the relative growth. A recluster/split resets the
  accumulators and re-baselines the partition.

All state is host-side numpy — statistics never enter a jitted computation;
they only parameterise ``cost_model.plan_maintenance``.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import delta as delta_mod
from repro.core.cost_model import MaintenanceSummary
from repro.core.partitioner import assign_with_distance, parked_mask

# drift is only trusted once this many writes have accumulated in a
# partition (a handful of rows says nothing about the centroid)
_MIN_DRIFT_WRITES = 8


def member_distance_stats(vectors, centroids):
    """(mean_dist (K,), counts (K,)) of ``vectors`` under their Eq. 1
    assignment — the build-time baseline the drift signal compares against."""
    a, d2 = assign_with_distance(vectors, centroids)
    a = np.asarray(a)
    dist = np.sqrt(np.asarray(d2, np.float64))
    k = centroids.shape[0]
    counts = np.bincount(a, minlength=k).astype(np.int64)
    sums = np.bincount(a, weights=dist, minlength=k)
    return sums / np.maximum(counts, 1), counts


class PartitionStats:
    """Host-side write-time accumulators for one modality's stable store."""

    def __init__(self, n_partitions: int, max_ids: int):
        self.n_partitions = n_partitions
        self.max_ids = max_ids
        self.baseline = np.zeros(n_partitions)          # mean dist at build
        self.drift_sum = np.zeros(n_partitions)
        self.drift_cnt = np.zeros(n_partitions, np.int64)
        self.dead = np.zeros(n_partitions, np.int64)    # tombstoned/superseded
        self.parked = np.zeros(n_partitions, bool)
        self._part_of: Optional[np.ndarray] = None      # lazy id -> partition

    # ------------------------------------------------------------- lifecycle
    @classmethod
    def from_build(cls, vectors, ids, ivf, max_ids: int) -> "PartitionStats":
        """Fresh stats for a just-built stable store: baseline distances
        from the build's own assignment, everything else zero."""
        st = cls(ivf.n_partitions, max_ids)
        if vectors.shape[0]:
            st.baseline, _ = member_distance_stats(vectors, ivf.centroids)
        st.parked = parked_mask(ivf.centroids)
        return st

    def rebaseline(self, vectors, ivf):
        """Re-anchor after a full rebuild (compaction with refreshed layout):
        current members become the new baseline, accumulators clear."""
        if vectors.shape[0]:
            self.baseline, _ = member_distance_stats(vectors, ivf.centroids)
        self.drift_sum[:] = 0.0
        self.drift_cnt[:] = 0
        self.parked = parked_mask(ivf.centroids)
        self.invalidate_slab()

    def reset_partition(self, p: int, baseline: float, parked: bool = False):
        """One partition re-centered (recluster) or re-filled (split/merge):
        new baseline, cleared accumulators."""
        self.baseline[p] = baseline
        self.drift_sum[p] = 0.0
        self.drift_cnt[p] = 0
        self.dead[p] = 0
        self.parked[p] = parked

    # ------------------------------------------------------------ write path
    def record_writes(self, assignment: np.ndarray, dist2: np.ndarray):
        """Accumulates the drift signal for an insert batch (assignment and
        squared distances from ``partitioner.assign_with_distance``)."""
        a = np.asarray(assignment).reshape(-1)
        d = np.sqrt(np.asarray(dist2, np.float64).reshape(-1))
        np.add.at(self.drift_sum, a, d)
        np.add.at(self.drift_cnt, a, 1)

    def record_dead(self, ids: np.ndarray, ivf):
        """A delete or update just hid stable rows: bump the owning
        partitions' dead counters (ids without a stable row are delta-only
        and cost nothing at probe time)."""
        part = self.partition_of(ids, ivf)
        part = part[part >= 0]
        if part.size:
            np.add.at(self.dead, part, 1)

    def partition_of(self, ids: np.ndarray, ivf) -> np.ndarray:
        """id -> owning partition (-1 when the id has no stable slot), via a
        lazily built slab map. ``invalidate_slab`` drops the map whenever
        slots move."""
        if self._part_of is None:
            slab_ids = np.asarray(ivf.ids).reshape(-1)
            cap = ivf.capacity
            part = (np.arange(slab_ids.size) // cap).astype(np.int32)
            m = np.full(self.max_ids, -1, np.int32)
            ok = slab_ids >= 0
            m[np.clip(slab_ids[ok], 0, self.max_ids - 1)] = part[ok]
            self._part_of = m
        ids = np.asarray(ids).reshape(-1)
        return self._part_of[np.clip(ids, 0, self.max_ids - 1)]

    def invalidate_slab(self):
        self._part_of = None

    # -------------------------------------------------------------- planning
    def drift_ratio(self) -> np.ndarray:
        """(K,) relative growth of the mean assigned distance vs. baseline
        (0 where too few writes accumulated to trust the estimate)."""
        cur = self.drift_sum / np.maximum(self.drift_cnt, 1)
        ok = (self.drift_cnt >= _MIN_DRIFT_WRITES) & (self.baseline > 1e-9)
        return np.where(ok, cur / np.maximum(self.baseline, 1e-9) - 1.0, 0.0)

    def summarize(self, m, heat: Optional[np.ndarray]) -> MaintenanceSummary:
        """Snapshot for ``cost_model.plan_maintenance``. O(K) from the
        incremental counters plus the delta's live-slot scan (O(delta cap))."""
        counts = np.asarray(m.ivf.counts, np.int64)
        dead = np.minimum(self.dead, counts)
        return MaintenanceSummary(
            live=counts - dead,
            free=np.int64(m.ivf.capacity) - counts,
            heat=(np.zeros(self.n_partitions, np.int64) if heat is None
                  else np.asarray(heat, np.int64)),
            dead=dead,
            drift=self.drift_ratio(),
            parked=self.parked.copy(),
            delta_live=int(delta_mod.live_slots(m.delta).size),
            delta_used=int(m.delta.count),
            delta_capacity=int(m.delta.vectors.shape[0]),
            cap=int(m.ivf.capacity),
        )
