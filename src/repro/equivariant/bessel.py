"""Spherical Bessel radial bases (DimeNet) + smooth cutoff envelopes.

j_l via upward recurrence from the closed forms j0 = sin(x)/x,
j1 = sin(x)/x² − cos(x)/x (stable for the x = z_{ln}·r/c > l/2 regime the
basis evaluates — zeros of j_l all exceed l). Zeros found at init by
bisection on the closed forms (numpy, no scipy).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _jl_np(l: int, x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, np.float64)
    safe = np.where(np.abs(x) < 1e-8, 1e-8, x)
    j0 = np.sin(safe) / safe
    if l == 0:
        return j0
    j1 = np.sin(safe) / safe ** 2 - np.cos(safe) / safe
    if l == 1:
        return j1
    jm2, jm1 = j0, j1
    for n in range(2, l + 1):
        jm2, jm1 = jm1, (2 * n - 1) / safe * jm1 - jm2
    return jm1


@functools.lru_cache(maxsize=None)
def bessel_zeros(l_max: int, n_zeros: int) -> np.ndarray:
    """(l_max+1, n_zeros) first zeros of j_l, by bracketed bisection."""
    out = np.zeros((l_max + 1, n_zeros))
    for l in range(l_max + 1):
        found = []
        # zeros of j_l interlace those of j_{l-1}; scan in fine steps
        x0, step = l + 1e-3, 0.1
        x = x0
        prev = _jl_np(l, np.array([x]))[0]
        while len(found) < n_zeros:
            x += step
            cur = _jl_np(l, np.array([x]))[0]
            if prev * cur < 0:
                a, b = x - step, x
                for _ in range(60):
                    mid = 0.5 * (a + b)
                    fm = _jl_np(l, np.array([mid]))[0]
                    if _jl_np(l, np.array([a]))[0] * fm <= 0:
                        b = mid
                    else:
                        a = mid
                found.append(0.5 * (a + b))
            prev = cur
        out[l] = found
    return out


def jl(l: int, x: jax.Array) -> jax.Array:
    """Differentiable spherical Bessel j_l (jnp, recurrence)."""
    safe = jnp.where(jnp.abs(x) < 1e-6, 1e-6, x)
    j0 = jnp.sin(safe) / safe
    if l == 0:
        return j0
    j1 = jnp.sin(safe) / safe ** 2 - jnp.cos(safe) / safe
    if l == 1:
        return j1
    jm2, jm1 = j0, j1
    for n in range(2, l + 1):
        jm2, jm1 = jm1, (2 * n - 1) / safe * jm1 - jm2
    return jm1


def envelope(r: jax.Array, cutoff: float, p: int = 6) -> jax.Array:
    """DimeNet polynomial cutoff envelope u(d), d = r/c (smooth to p-th
    derivative; contains the basis's 1/d factor). d is floored at 0.02 as a
    numerical guard — physical graphs never reach d→0, synthetic ones can."""
    d = jnp.maximum(r / cutoff, 0.02)
    a = -(p + 1) * (p + 2) / 2.0
    b = p * (p + 2)
    c = -p * (p + 1) / 2.0
    env = 1.0 / d + a * d ** (p - 1) + b * d ** p + c * d ** (p + 1)
    return jnp.where(d < 1.0, env, 0.0)


def radial_bessel_basis(r: jax.Array, n_radial: int, cutoff: float) -> jax.Array:
    """DimeNet RBF: u(d)·√(2/c)·sin(nπ d). r (...,) -> (..., n)."""
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    d = jnp.maximum(r / cutoff, 0.02)[..., None]
    basis = math.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d)
    return basis * envelope(r, cutoff)[..., None]


def spherical_bessel_basis(r: jax.Array, n_spherical: int, n_radial: int,
                           cutoff: float) -> jax.Array:
    """DimeNet SBF radial part: j_l(z_{ln} r/c), (..., n_spherical, n_radial)."""
    zeros = jnp.asarray(bessel_zeros(n_spherical - 1, n_radial), jnp.float32)
    rs = (r / cutoff)[..., None]
    outs = []
    for l in range(n_spherical):
        x = zeros[l][None, :] * rs                      # (..., n_radial)
        norm = jnp.asarray(
            [math.sqrt(2.0) / abs(_jl_np(l + 1, np.array([z]))[0]) / cutoff ** 1.5
             for z in np.asarray(bessel_zeros(n_spherical - 1, n_radial))[l]],
            jnp.float32)
        outs.append(jl(l, x) * norm)
    out = jnp.stack(outs, axis=-2)                      # (..., n_sph, n_rad)
    return out * envelope(r, cutoff)[..., None, None]


def angular_basis(angle: jax.Array, n_spherical: int) -> jax.Array:
    """DimeNet CBF angular part: Legendre P_l(cos θ) (..., n_spherical)."""
    c = jnp.cos(angle)
    ps = [jnp.ones_like(c), c]
    for l in range(2, n_spherical):
        ps.append(((2 * l - 1) * c * ps[-1] - (l - 1) * ps[-2]) / l)
    return jnp.stack(ps[:n_spherical], axis=-1)
