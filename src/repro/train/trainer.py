"""Fault-tolerant trainer: microbatched steps, checkpoint/restart, straggler
monitoring, and optional inter-pod gradient compression.

The loop is host-driven; the jitted step is supplied by the model driver
(``make_train_step``). Restart contract: on any step failure the RetryPolicy
restores the latest checkpoint and fast-forwards the deterministic data
stream — training state is exactly (params, opt_state, step).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro import obs
from repro.checkpoint import CheckpointManager
from repro.runtime.fault import HeartbeatMonitor, RetryPolicy


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 2
    log_every: int = 10
    max_retries: int = 3


class Trainer:
    def __init__(self, cfg: TrainerConfig, step_fn: Callable, stream,
                 params, opt_state, to_device: Optional[Callable] = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.stream = stream
        self.params = params
        self.opt_state = opt_state
        self.to_device = to_device or (lambda b: b)
        self.ckpt = CheckpointManager(cfg.checkpoint_dir, cfg.keep_checkpoints)
        self.monitor = HeartbeatMonitor(n_workers=1)
        self.retry = RetryPolicy(max_retries=cfg.max_retries)
        self.step = 0
        self.history: list = []

    # -- restart contract ----------------------------------------------------
    def try_restore(self) -> bool:
        try:
            (self.params, self.opt_state), step, _ = self.ckpt.restore_latest(
                (self.params, self.opt_state))
            self.step = step
            return True
        except FileNotFoundError:
            return False

    def _restore_or_reset(self):
        if not self.try_restore():
            self.step = 0

    # -- main loop -------------------------------------------------------------
    def run(self, fail_injector: Optional[Callable[[int], None]] = None
            ) -> Dict[str, Any]:
        while self.step < self.cfg.total_steps:
            batch = self.to_device(self.stream.batch_at(self.step))

            def one_step():
                if fail_injector is not None:
                    fail_injector(self.step)
                # the loss sync keeps the measured step honest regardless
                # of obs_sync_spans — training always wants real step time
                t0 = time.perf_counter()
                params, opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                obs.observe_ms("train.step", dt)
                return params, opt_state, metrics, dt

            params, opt_state, metrics, dt = self.retry.run(
                one_step, self._restore_or_reset)
            self.params, self.opt_state = params, opt_state
            self.monitor.record(0, dt)
            self.step += 1
            if self.step % self.cfg.log_every == 0 or self.step == 1:
                self.history.append(
                    {"step": self.step, "loss": float(metrics["loss"]),
                     "time_s": dt})
            if self.step % self.cfg.checkpoint_every == 0:
                self.ckpt.save(self.step, (self.params, self.opt_state))
        self.ckpt.save(self.step, (self.params, self.opt_state))
        self.ckpt.wait()
        return {"history": self.history,
                "stragglers": self.monitor.stragglers()}
