"""Training launcher: ``python -m repro.launch.train --arch <id> [--steps N]``.

CPU-scale by default (smoke-config model, synthetic data) — the same driver
binds the production mesh + full config on a real fleet (--full --mesh).
Fault tolerance is on: checkpoint/restart, straggler monitor, deterministic
data skipping (see repro/train/trainer.py).
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.configs.base import GNNConfig, LMConfig, RecsysConfig
from repro.data.pipeline import SyntheticLMStream, SyntheticRecsysStream
from repro.models import lm
from repro.train.optimizer import AdamWConfig, init_adamw
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--full", action="store_true",
                    help="use the full (assigned) config instead of smoke")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else smoke_config(args.arch)
    key = jax.random.PRNGKey(0)

    if isinstance(cfg, LMConfig):
        params, _ = lm.init_lm(cfg, key)
        opt = init_adamw(params)
        opts = lm.ExecOpts(q_block=0, remat=False)
        step = jax.jit(lm.make_train_step(
            cfg, None, opts,
            AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps)))
        stream = SyntheticLMStream(cfg.vocab_size, args.batch, args.seq)
        to_dev = lambda b: {k: jnp.asarray(v) for k, v in b.items()}
    elif isinstance(cfg, RecsysConfig):
        from repro.models.recsys import xdeepfm
        from repro.train.optimizer import adamw_update
        params, _ = xdeepfm.init(cfg, key)
        opt = init_adamw(params)
        ocfg = AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps)

        def _step(params, opt_state, batch):
            (l, aux), g = jax.value_and_grad(
                lambda p: xdeepfm.loss_fn(cfg, p, batch), has_aux=True)(params)
            params, opt_state, om = adamw_update(ocfg, g, opt_state, params)
            return params, opt_state, {"loss": l, **aux, **om}

        step = jax.jit(_step)
        stream = SyntheticRecsysStream(cfg.n_sparse, cfg.vocab_per_field, args.batch)
        to_dev = lambda b: {k: jnp.asarray(v) for k, v in b.items()}
    elif isinstance(cfg, GNNConfig):
        from repro.models.gnn import driver as gd
        from repro.models.gnn.dimenet import build_triplets
        import numpy as np
        g = gd.make_flat_graph(128, 512, 16, seed=0)
        trip = (build_triplets(np.asarray(g.edge_src), np.asarray(g.edge_dst),
                               np.asarray(g.edge_mask))
                if cfg.model == "dimenet" else None)
        params, _ = gd.init_model(cfg, key, 16)
        opt = init_adamw(params)
        step = jax.jit(gd.make_train_step(
            cfg, "full_graph", opt_cfg=AdamWConfig(lr=args.lr)))

        class _GraphStream:
            def batch_at(self, step):
                return {"graph": g, "triplets": trip}
        stream = _GraphStream()
        to_dev = lambda b: b
    else:
        raise SystemExit(f"no trainer for {args.arch}")

    tc = TrainerConfig(total_steps=args.steps, checkpoint_every=max(args.steps // 2, 1),
                       checkpoint_dir=args.ckpt_dir, log_every=max(args.steps // 10, 1))
    trainer = Trainer(tc, step, stream, params, opt, to_dev)
    if trainer.try_restore():
        print(f"restored from step {trainer.step}")
    out = trainer.run()
    for h in out["history"]:
        print(json.dumps(h))
    print(f"final loss: {out['history'][-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
