"""AdamW with global-norm clipping and WSD/cosine schedules.

Optimizer moments are fp32 and inherit each parameter's sharding (ZeRO: the
moments shard exactly like the weights, so optimizer memory scales 1/N_fsdp).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.common.tree import global_norm


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"      # "cosine" | "constant"
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    mu: object
    nu: object


def init_adamw(params) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(f32, params),
                      nu=jax.tree.map(f32, params))


def opt_state_axes(param_axes) -> AdamWState:
    """Logical axes for the optimizer state (moments mirror the params)."""
    return AdamWState(step=(), mu=param_axes, nu=param_axes)


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics). Grads may be bf16; math is fp32."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)) if cfg.clip_norm else 1.0
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        step_ = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), metrics
