"""End-to-end serving driver (the paper's target application): a small LM
encoder + HMGI retrieval + continuous-batched RAG generation.

    PYTHONPATH=src python examples/multimodal_rag.py
"""
import time

import numpy as np
import jax

from repro.configs import get_config, smoke_config
from repro.core import HMGIIndex
from repro.data.synthetic import make_corpus
from repro.models import lm
from repro.serving.engine import EngineConfig, RAGEngine

# 1. knowledge corpus + index
corpus = make_corpus(n_nodes=1500, modality_dims={"text": 48}, seed=0)
cfg = get_config("hmgi").replace(n_partitions=16, n_probe=4, top_k=4,
                                 kmeans_iters=8)
index = HMGIIndex(cfg, seed=0)
index.ingest({"text": (corpus.node_ids["text"], corpus.vectors["text"])},
             n_nodes=corpus.n_nodes,
             edges=(corpus.src, corpus.dst, corpus.edge_type))
print(f"index built: {index.memory_usage()['total']/2**20:.2f} MiB")

# 2. a small LM (reduced phi4-family config) as the generator
lm_cfg = smoke_config("phi4-mini-3.8b")
params, _ = lm.init_lm(lm_cfg, jax.random.PRNGKey(0))
engine = RAGEngine(lm_cfg, params, index,
                   EngineConfig(n_slots=8, max_seq=96, retrieve_k=4, hops=1))

# 3. batched requests: retrieve entity context per query, then generate with
#    continuous batching (slots refill as requests finish)
rng = np.random.default_rng(2)
n_requests = 12
query_vecs = corpus.vectors["text"][rng.integers(0, 700, n_requests)]
retrieved = engine.retrieve(query_vecs)          # hybrid vector+graph
t0 = time.perf_counter()
for rid in range(n_requests):
    prompt = rng.integers(0, lm_cfg.vocab_size, 12)
    engine.submit(rid, prompt, retrieved_ids=retrieved[rid],
                  max_new_tokens=8 + (rid % 3) * 4)   # mixed lengths
outputs = engine.run_to_completion()
dt = time.perf_counter() - t0

done = sum(1 for v in outputs.values() if v)
toks = sum(len(v) for v in outputs.values())
print(f"served {done}/{n_requests} requests, {toks} tokens in {dt:.2f}s "
      f"({toks/dt:.1f} tok/s); engine stats: {engine.stats}")
assert done == n_requests
