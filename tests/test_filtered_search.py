"""Attribute-filtered search (predicate pushdown) vs the
brute-force-with-predicate oracle.

The oracle scores each row in the representation the index actually stores —
dequantized int8 for stable rows, fp32 master rows for delta rows — so at
full probe the filtered search must reproduce its top-k *exactly*, for both
probe implementations (fused kernel / legacy einsum), across selectivities
from "almost nothing passes" to "almost everything passes" (both sides of
the prefilter-vs-oversample planning crossover).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import HMGIIndex
from repro.core import ivf as ivf_mod
from repro.core.cost_model import plan_filtered_scan
from repro.core.graph_store import NodeAttributes
from repro.data.synthetic import make_corpus

N_STABLE = 600
N_DELTA = 16
N_NODES = N_STABLE + N_DELTA
DIM = 32
K = 10
# bucket column ~ Uniform[0, 100): thresholds give the issue's selectivities
SELECTIVITY_THRESHOLDS = (1, 10, 50, 90)


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(5)
    v = rng.normal(size=(N_STABLE, DIM)).astype(np.float32)
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    bucket = rng.integers(0, 100, N_NODES).astype(np.int32)
    cat = rng.integers(0, 8, N_NODES).astype(np.int32)

    cfg = get_config("hmgi").replace(n_partitions=8, n_probe=8, top_k=K,
                                     kmeans_iters=6, delta_capacity=64,
                                     delta_rescore_margin=64)
    corpus = make_corpus(n_nodes=N_NODES, modality_dims={"text": DIM}, seed=2)
    idx = HMGIIndex(cfg, seed=0)
    idx.ingest({"text": (np.arange(N_STABLE, dtype=np.int32), v)},
               n_nodes=N_NODES, edges=(corpus.src, corpus.dst),
               node_attrs={"bucket": bucket, "category": cat})
    # live delta rows on top of the stable index
    dv = rng.normal(size=(N_DELTA, DIM)).astype(np.float32)
    dv /= np.linalg.norm(dv, axis=1, keepdims=True)
    idx.insert("text", np.arange(N_STABLE, N_NODES, dtype=np.int32), dv)
    q = v[:16] + 0.05 * rng.normal(size=(16, DIM)).astype(np.float32)
    return idx, q, bucket, cat


def _as_stored_corpus(idx: HMGIIndex, modality: str):
    """(vectors, ids, valid) of every live row, in the representation the
    index scans: dequantized int8 for stable, fp32 master for delta (latest
    version per id)."""
    m = idx.modalities[modality]
    data, vmin, scale, sids = m.ivf.slab_view()
    stable = ivf_mod._dequant_rows(m.ivf, data, vmin, scale)
    sids = np.asarray(sids)
    dead = np.asarray(m.delta.tombstones) | np.asarray(m.delta.superseded)
    s_ok = (sids >= 0) & ~dead[np.clip(sids, 0, dead.shape[0] - 1)]
    d_ids = np.asarray(m.delta.ids)
    from repro.core.delta import _latest_version_mask
    d_ok = np.asarray(_latest_version_mask(m.delta)) \
        & ~np.asarray(m.delta.tombstones)[np.clip(d_ids, 0, dead.shape[0] - 1)]
    vecs = np.concatenate([np.asarray(stable), np.asarray(m.delta.vectors)])
    ids = np.concatenate([sids, d_ids])
    ok = np.concatenate([s_ok, d_ok])
    return vecs, ids, ok


def _oracle(idx, q, node_pass, k):
    """Brute-force-with-predicate over the stored representation."""
    vecs, ids, ok = _as_stored_corpus(idx, "text")
    ok = ok & node_pass[np.clip(ids, 0, len(node_pass) - 1)]
    qn = np.asarray(idx._norm_queries(q))
    scores = qn @ vecs.T
    scores[:, ~ok] = -np.inf
    order = np.argsort(-scores, axis=1)[:, :k]
    ovals = np.take_along_axis(scores, order, axis=1)
    oids = np.where(np.isfinite(ovals), ids[order], -1)
    return ovals, oids


def _check_exact(sv, si, ovals, oids):
    sv, si = np.asarray(sv), np.asarray(si)
    np.testing.assert_allclose(
        np.where(np.isfinite(sv), sv, 0.0),
        np.where(np.isfinite(ovals), ovals, 0.0), rtol=2e-5, atol=2e-5)
    assert np.all(np.isfinite(sv) == np.isfinite(ovals))
    for a, b, s in zip(si, oids, sv):
        # sets, not sequences: equal scores may legally permute
        assert set(a[np.isfinite(s)].tolist()) == set(
            b[b >= 0].tolist()), (a, b)


class TestFilteredOracle:
    @pytest.mark.parametrize("impl", ["kernel", "einsum"])
    @pytest.mark.parametrize("thresh", SELECTIVITY_THRESHOLDS)
    def test_matches_predicate_oracle(self, setup, impl, thresh):
        idx, q, bucket, _ = setup
        where = ("bucket", "<", thresh)
        node_pass = np.asarray(idx.attributes.node_pass(where))
        sv, si = idx.search(q, "text", k=K, where=where, impl=impl)
        # every hit satisfies the predicate
        for row in np.asarray(si):
            for x in row:
                if x >= 0:
                    assert bucket[x] < thresh
        _check_exact(sv, si, *_oracle(idx, q, node_pass, K))

    def test_planner_crosses_over(self, setup):
        """Low selectivity plans pushdown; high selectivity plans
        oversampling (the cfg crossover is 0.5)."""
        lo = plan_filtered_scan(0.01, K, n_rows=N_NODES)
        hi = plan_filtered_scan(0.9, K, n_rows=N_NODES)
        assert lo.mode == "prefilter"
        assert hi.mode == "oversample" and hi.k_scan > K

    def test_both_plans_agree(self, setup):
        """Forcing prefilter and oversample on the same query must give the
        same answer (planning is a cost decision, not a semantics one)."""
        idx, q, bucket, _ = setup
        where = ("bucket", "<", 50)
        cfg0 = idx.cfg
        try:
            idx.cfg = cfg0.replace(filter_prefilter_max_sel=1.0)
            pv, pi = idx.search(q, "text", k=K, where=where)
            assert idx._metrics["filter_mode"] == "prefilter"
            idx.cfg = cfg0.replace(filter_prefilter_max_sel=0.0)
            ov, oi = idx.search(q, "text", k=K, where=where)
            assert idx._metrics["filter_mode"] == "oversample"
        finally:
            idx.cfg = cfg0
        np.testing.assert_allclose(np.asarray(pv), np.asarray(ov),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_array_equal(np.asarray(pi), np.asarray(oi))

    def test_conjunction_and_in(self, setup):
        idx, q, bucket, cat = setup
        where = [("category", "in", {1, 3, 5}), ("bucket", ">=", 20)]
        node_pass = np.asarray(idx.attributes.node_pass(where))
        assert node_pass.sum() > 0
        sv, si = idx.search(q, "text", k=K, where=where)
        for row in np.asarray(si):
            for x in row:
                if x >= 0:
                    assert cat[x] in (1, 3, 5) and bucket[x] >= 20
        _check_exact(sv, si, *_oracle(idx, q, node_pass, K))

    def test_oversample_k_beyond_corpus_pads(self, setup):
        """k larger than the scannable rows on the oversample path must pad
        with (-inf, -1), exactly like the unfiltered path."""
        idx, q, bucket, _ = setup
        cfg0 = idx.cfg
        try:
            idx.cfg = cfg0.replace(filter_prefilter_max_sel=0.0)  # force it
            sv, si = idx.search(q[:2], "text", k=N_NODES + 50,
                                where=("bucket", "<", 95))
        finally:
            idx.cfg = cfg0
        sv, si = np.asarray(sv), np.asarray(si)
        assert sv.shape == (2, N_NODES + 50)
        assert np.all(np.isneginf(sv[:, -50:])) and np.all(si[:, -50:] == -1)
        for row, s in zip(si, sv):
            live = row[np.isfinite(s)]
            assert np.all(bucket[live] < 95)

    def test_empty_predicate_returns_nothing(self, setup):
        idx, q, bucket, _ = setup
        sv, si = idx.search(q, "text", k=K, where=("bucket", "<", 0))
        assert not np.any(np.isfinite(np.asarray(sv)))
        assert np.all(np.asarray(si) == -1)

    def test_where_without_attributes_raises(self):
        cfg = get_config("hmgi").replace(n_partitions=4, kmeans_iters=2)
        idx = HMGIIndex(cfg, seed=0)
        rng = np.random.default_rng(0)
        v = rng.normal(size=(64, 16)).astype(np.float32)
        idx.ingest({"text": (np.arange(64, dtype=np.int32), v)}, n_nodes=64)
        with pytest.raises(ValueError, match="attributes"):
            idx.search(v[:2], "text", k=3, where=("bucket", "<", 5))


class TestFilteredHybrid:
    def test_hybrid_respects_predicate(self, setup):
        idx, q, bucket, _ = setup
        where = ("bucket", "<", 50)
        hv, hi = idx.hybrid_search(q[:6], "text", k=K, n_hops=2, where=where)
        assert hv.shape == (6, K)
        for row in np.asarray(hi):
            for x in row:
                if x >= 0:
                    assert bucket[x] < 50, row

    def test_traversal_routes_no_mass_through_excluded(self, setup):
        """Graph mass never lands on a predicate-excluded node at any hop."""
        from repro.core import traversal as trav_mod
        idx, q, bucket, _ = setup
        node_pass = idx.attributes.node_pass(("bucket", "<", 30))
        seeds = jnp.zeros((N_NODES,), jnp.float32).at[:8].set(1.0 / 8)
        res = trav_mod.frontier_expand(idx.graph, seeds, n_hops=3,
                                       node_mask=node_pass)
        mass_on_excluded = np.asarray(res.per_hop)[:, ~np.asarray(node_pass)]
        assert np.all(mass_on_excluded == 0.0)


class TestNodeAttributes:
    def test_ops(self):
        attrs = NodeAttributes.from_columns(
            6, {"a": np.array([0, 1, 2, 3, 4, 5]),
                "b": np.array([5, 5, 0, 0, 5, 5])})
        def mask(where):
            return np.asarray(attrs.node_pass(where))
        np.testing.assert_array_equal(mask(("a", "==", 2)),
                                      [0, 0, 1, 0, 0, 0])
        np.testing.assert_array_equal(mask(("a", "!=", 2)),
                                      [1, 1, 0, 1, 1, 1])
        np.testing.assert_array_equal(mask(("a", "<=", 1)),
                                      [1, 1, 0, 0, 0, 0])
        np.testing.assert_array_equal(mask(("a", ">", 4)),
                                      [0, 0, 0, 0, 0, 1])
        np.testing.assert_array_equal(mask(("a", "in", {0, 5})),
                                      [1, 0, 0, 0, 0, 1])
        np.testing.assert_array_equal(
            mask([("a", ">=", 1), ("b", "==", 5)]), [0, 1, 0, 0, 1, 1])

    def test_bad_inputs(self):
        attrs = NodeAttributes.from_columns(3, {"a": np.zeros(3, np.int32)})
        with pytest.raises(ValueError, match="op"):
            attrs.compile_where(("a", "~=", 1))
        with pytest.raises(KeyError):
            attrs.compile_where(("missing", "==", 1))
        with pytest.raises(ValueError, match="shape"):
            NodeAttributes.from_columns(3, {"a": np.zeros(4, np.int32)})
        assert attrs.node_pass(None) is None
