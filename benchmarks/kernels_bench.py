"""Kernel micro-benchmarks (interpret-mode wall time is NOT TPU-predictive;
the derived column carries the analytic bytes/flops that the roofline uses —
the comparison of interest on CPU is kernel-vs-oracle agreement + the scan's
arithmetic-intensity accounting)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import timeit
from repro.core.quantization import quantize
from repro.kernels.ivf_topk.ops import scan_topk_quantized
from repro.kernels.ivf_topk.ref import scan_topk_ref, topk_from_chunks
from repro.kernels.segment_reduce.ops import segment_sum_mm
from repro.kernels.segment_reduce.ref import segment_sum_ref
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


def run(report):
    rng = np.random.default_rng(0)

    # ivf_topk: HBM bytes per query at int8 vs bf16 storage
    n, d, q = 8192, 128, 64
    v = rng.normal(size=(n, d)).astype(np.float32)
    qv = quantize(jnp.asarray(v), 8)
    queries = jnp.asarray(v[:q])
    valid = jnp.ones((n,), bool)
    t_k = timeit(lambda: scan_topk_quantized(queries, qv.data, qv.vmin[:, 0],
                                             qv.scale[:, 0], valid, k=10),
                 trials=3)
    int8_bytes = n * d
    bf16_bytes = n * d * 2
    report("k_ivf_topk_int8", t_k / q * 1e6,
           f"hbm_bytes_per_scan={int8_bytes} vs_bf16={bf16_bytes} (2x saved)")

    # segment_reduce: one-hot-matmul MXU formulation
    e, dd, nn = 8192, 64, 1024
    msg = jnp.asarray(rng.normal(size=(e, dd)).astype(np.float32))
    seg = jnp.asarray(rng.integers(0, nn, e).astype(np.int32))
    t_k = timeit(lambda: segment_sum_mm(msg, seg, nn), trials=3)
    t_r = timeit(lambda: segment_sum_ref(msg, seg, nn), trials=3)
    mxu_flops = 2 * e * nn * dd   # the one-hot matmul the TPU would run
    report("k_segment_reduce", t_k * 1e6,
           f"ref_us={t_r*1e6:.0f} mxu_flops={mxu_flops:.2e}")

    # decode_attention: flash-decode bytes per token
    b, hkv, g, hd, s = 4, 8, 8, 128, 4096
    qa = jnp.asarray(rng.normal(size=(b, hkv * g, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)).astype(np.float32))
    vv = jnp.asarray(rng.normal(size=(b, s, hkv, hd)).astype(np.float32))
    valid = jnp.ones((b, s), bool)
    t_k = timeit(lambda: decode_attention(qa, k, vv, valid), trials=3)
    kv_bytes = 2 * b * s * hkv * hd * 4
    report("k_decode_attention", t_k * 1e6,
           f"kv_bytes={kv_bytes:.2e} tokens={b}")
