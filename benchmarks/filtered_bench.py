"""Attribute-filtered search benchmark: predicate pushdown vs
oversample-then-post-filter across a selectivity sweep.

For each selectivity the same where-clause is executed twice with the
planner pinned to each strategy (the planner is a cost decision only — both
return identical results, see tests/test_filtered_search.py). The expected
shape: at low selectivity the oversampled width k/sel explodes and pushdown
wins decisively; near selectivity 1 the small constant oversample edges out
the per-row mask gather. The ``auto`` row reports what the planner picked.
"""
from __future__ import annotations

import time

import numpy as np
import jax

from benchmarks.common import build_hmgi, load_corpus, make_queries, primary_mod, timeit
from repro.core.cost_model import estimate_selectivity

SELECTIVITIES = (0.01, 0.1, 0.5, 0.9)


def _timeit_interleaved(fns, trials=10, warmup=3):
    """Median wall seconds per fn, the variants interleaved trial-by-trial —
    this container's wall clock drifts up to 2x between runs, so sequential
    per-variant timing regularly inverts close ratios."""
    for fn in fns:
        for _ in range(warmup):
            jax.block_until_ready(fn())
    ts = [[] for _ in fns]
    for _ in range(trials):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts[i].append(time.perf_counter() - t0)
    return [float(np.median(t)) for t in ts]


def run(report):
    name = "sift1b-s"
    corpus = load_corpus(name)
    mod = primary_mod(name)
    idx = build_hmgi(corpus, bits=8, n_partitions=32, n_probe=8)
    rng = np.random.default_rng(9)
    # uniform 0..999 bucket: where ("bucket" < 1000*sel) hits sel exactly-ish
    idx.set_attributes({"bucket": rng.integers(0, 1000, corpus.n_nodes)})
    q = make_queries(corpus, mod, n=32)
    k = 10
    cfg0 = idx.cfg

    def forced(mode_sel, where):
        def fn():
            idx.cfg = cfg0.replace(filter_prefilter_max_sel=mode_sel)
            try:
                return idx.search(q, mod, k=k, where=where)
            finally:
                idx.cfg = cfg0
        return fn

    for sel in SELECTIVITIES:
        where = ("bucket", "<", max(1, int(1000 * sel)))
        sel_true = estimate_selectivity(idx.attributes.node_pass(where))
        t_push, t_over = _timeit_interleaved(
            [forced(1.0, where), forced(0.0, where)])
        idx.search(q, mod, k=k, where=where)
        auto = idx._metrics["filter_mode"]
        report(f"filtered_pushdown_sel{sel}", t_push / len(q) * 1e6,
               f"sel={sel_true:.3f} speedup_vs_postfilter="
               f"{t_over / t_push:.2f}x")
        report(f"filtered_postfilter_sel{sel}", t_over / len(q) * 1e6,
               f"sel={sel_true:.3f} planner_pick={auto}")

    # filtered hybrid query end to end (pushdown + masked traversal + fusion)
    where = ("bucket", "<", 100)
    t_h = timeit(lambda: idx.hybrid_search(q, mod, k=k, n_hops=2, where=where),
                 trials=3)
    report("filtered_hybrid_e2e", t_h / len(q) * 1e6,
           f"sel=0.1 n_nodes={corpus.n_nodes}")
