"""Bounded-work executors for maintenance actions (docs/DESIGN.md §3.4).

Each function applies one ``cost_model.MaintenanceAction`` to a modality's
state (``m`` is the facade's ``ModalityIndex``, duck-typed: ``ivf``,
``delta``, ``vectors``, ``ids``) as in-place slot surgery instead of a
stop-the-world rebuild:

- **compact_chunk** — drains a fixed-size chunk of live delta rows into the
  stable slab, each row placed by its *current* centroid assignment (an
  update whose vector moved must land where future probes will look for
  it; its old slot is cleared, or overwritten in place when the assigned
  partition is full — and the superseded bit clears either way). Rows move
  as their stored int8 bytes (the delta quantizes at insert with the same
  per-row affine scheme the slab uses), so the post-drain scan scores are
  exactly what a full ``delta.compact`` would produce for those rows. Rows
  that fit nowhere stay in the delta for a later step — never dropped.
- **merge_cold** — folds a cold partition's live rows byte-identically into
  the free slots of its nearest sibling (the ``shard_index`` move idiom);
  tombstoned/superseded rows are purged, not moved, and purged tombstones
  stay set (a deleted id must never resurrect). Survivors that don't fit
  the sibling go to the delta (fp32 master rows — the repartition-overflow
  contract). The emptied partition's centroid is parked
  (``partitioner.parked_centroid``), freeing the slot for a future split.
- **split_hot** — K=2 local Lloyd's fit over the hot partition's stored
  (dequantized) members, then a byte-identical redistribution of those rows
  between the hot partition and a parked one (merging the coldest partition
  away first if none is parked). Only the hot partition's rows move.
- **recluster** — re-centers a drifted partition's centroid on the mean of
  its live members. No rows move; only future routing changes.

Every executor returns a result dict (``note`` for the report, plus
counters); ``apply`` dispatches. Invariants these must preserve — at full
probe the visible corpus (stable ∪ delta under MVCC masks) is unchanged
except where an action intentionally changes a row's *representation*
(delta fp32 → stable int8 on drain, stable int8 → delta fp32 on merge
overflow) — are spelled out in docs/DESIGN.md §3.5 and pinned by
tests/test_maintenance.py's oracle checks.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core import delta as delta_mod
from repro.core import ivf as ivf_mod
from repro.core import partitioner
from repro.core.cost_model import MaintenanceAction
from repro.maintenance.stats import PartitionStats


def apply(m, cfg, key, stats: PartitionStats,
          action: MaintenanceAction) -> Dict:
    if action.kind == "compact_chunk":
        # transfers always pad to the configured chunk width, even for a
        # planner-trimmed partial chunk — one compiled executable per config
        return compact_chunk(m, stats, action.rows,
                             pad_to=cfg.maint_chunk)
    if action.kind == "merge_cold":
        return merge_cold(m, stats, action.partition)
    if action.kind == "split_hot":
        return split_hot(m, cfg, key, stats, action.partition)
    if action.kind == "recluster":
        return recluster(m, stats, action.partition)
    raise ValueError(f"unknown maintenance action {action.kind!r}")


def _flat_rows(ivf, p: int, occupied: bool) -> np.ndarray:
    """Flat slab row indices of partition ``p``'s occupied (or free) slots."""
    ids = np.asarray(ivf.ids[p])
    sel = (ids >= 0) if occupied else (ids < 0)
    return p * ivf.capacity + np.where(sel)[0]


def _master_rows(m, gids: np.ndarray) -> np.ndarray:
    """Global id -> row in the fp32 master array (``m.vectors``)."""
    existing = np.asarray(m.ids)
    order = np.argsort(existing, kind="stable")
    pos = np.searchsorted(existing[order], gids)
    pos = np.minimum(pos, existing.size - 1)
    assert np.all(existing[order[pos]] == gids), "stable id missing a master row"
    return order[pos]


# --------------------------------------------------------------------- drain
def compact_chunk(m, stats: PartitionStats, chunk: int,
                  pad_to: int = 0) -> Dict:
    """One incremental compaction step: drain ≤ ``chunk`` live delta rows
    into the stable slab (see module doc for placement rules). ``pad_to``
    widens the padded device transfers beyond ``chunk`` (the facade passes
    ``cfg.maint_chunk`` so partial trailing chunks reuse the same compiled
    executables as full ones)."""
    delta = m.delta
    live = delta_mod.live_slots(delta)
    used_before = int(delta.count)
    if live.size == 0:
        if used_before:
            # only dead weight left (stale versions, tombstone shadows):
            # reclaim the slots, nothing moves to stable
            m.delta = delta_mod.rebuild_keep(delta, np.empty(0, np.int64))
            return {"drained": 0, "reclaimed": used_before,
                    "ivf_changed": False,
                    "note": f"reclaimed {used_before} dead slots"}
        return {"drained": 0, "ivf_changed": False, "note": "empty delta"}

    take = live[:chunk]
    d_ids = np.asarray(delta.ids)[take]
    cap = m.ivf.capacity
    width = max(chunk, pad_to)

    # every drained row is placed by its *current* assignment (an update
    # may have moved the vector far from its old partition — leaving it in
    # place would make probe-limited queries for the new vector miss it).
    # Gathers are padded to the (configured) chunk width so repeated drain
    # steps hit one compiled executable instead of one per distinct size.
    src = np.full(width, take[0], np.int64)
    src[:take.size] = take
    assign = np.asarray(partitioner.assign(
        delta.vectors[jnp.asarray(src)], m.ivf.centroids))[:take.size]

    # an update's old stable slot: the in-place fallback (and, when the row
    # moves partitions, the slot to clear)
    slab_ids = np.asarray(m.ivf.ids).reshape(-1)
    order = np.argsort(slab_ids, kind="stable")
    sorted_ids = slab_ids[order]
    pos = np.minimum(np.searchsorted(sorted_ids, d_ids), sorted_ids.size - 1)
    has_slot = sorted_ids[pos] == d_ids
    old_slot = np.full(d_ids.size, -1, np.int64)
    old_slot[has_slot] = order[pos[has_slot]]

    target = np.full(d_ids.size, -1, np.int64)
    clear_old = np.zeros(d_ids.size, bool)
    free = np.where(slab_ids < 0)[0]
    free_part = free // cap
    for part in np.unique(assign):
        members = np.where(assign == part)[0]
        # already in the right partition: overwrite in place
        in_place = members[old_slot[members] // cap == part]
        in_place = in_place[old_slot[in_place] >= 0]
        target[in_place] = old_slot[in_place]
        rest = members[~np.isin(members, in_place)]
        rows = free[free_part == part]
        n = min(rows.size, rest.size)
        target[rest[:n]] = rows[:n]
        clear_old[rest[:n]] = old_slot[rest[:n]] >= 0
        # no free slot in the assigned partition: fall back to the old
        # slot (placement is recall policy, not correctness —
        # docs/DESIGN.md §3.5); rows with neither stay in the delta for a
        # later step — never dropped
        fb = rest[n:][old_slot[rest[n:]] >= 0]
        target[fb] = old_slot[fb]

    drained = target >= 0
    n_drained = int(drained.sum())
    if n_drained:
        co = old_slot[drained & clear_old]
        if co.size:
            # padded to the chunk width (duplicate clears are idempotent)
            # for the same compiled-executable reuse as the transfer below
            cop = np.full(width, co[0], np.int64)
            cop[:co.size] = co
            m.ivf = ivf_mod.clear_slots(m.ivf, cop)
        # fixed-width transfer: the tail re-writes slot target[0] with
        # its own bytes (idempotent duplicate), keeping shapes stable
        src = np.full(width, take[drained][0], np.int64)
        src[:n_drained] = take[drained]
        tgt = np.full(width, target[drained][0], np.int64)
        tgt[:n_drained] = target[drained]
        sel = jnp.asarray(src)
        if m.ivf.bits == 8:
            # the delta's int8 mirror shares the slab's scheme: move bytes
            data, vmin, scale = (delta.qdata[sel], delta.qvmin[sel],
                                 delta.qscale[sel])
        else:
            # 4/16-bit slabs store a different layout than the delta's int8
            # mirror: re-quantize the fp32 master rows at the slab's width
            # (exactly what a full compact stores for these rows)
            from repro.core.quantization import quantize
            qv = quantize(delta.vectors[sel], m.ivf.bits)
            data, vmin, scale = qv.data, qv.vmin[:, 0], qv.scale[:, 0]
        m.ivf = ivf_mod.set_slots(m.ivf, tgt, data, vmin, scale,
                                  np.asarray(delta.ids)[src])
        # the old slots held the superseded pre-update rows: overwritten or
        # cleared, that dead weight is gone
        part_old = old_slot[drained & has_slot] // cap
        np.subtract.at(stats.dead, part_old, 1)
        np.maximum(stats.dead, 0, out=stats.dead)
        stats.invalidate_slab()
    keep = np.setdiff1d(live, take[drained])
    # count ids whose superseded bit was actually SET (not just those with
    # a stable slot): an updated ingest-overflow row has the bit but no
    # slot, and the facade's NSW refresh keys on this count — an
    # undercount would let the NSW lane serve the pre-update vector
    sup_np = np.asarray(delta.superseded)
    n_cleared = int(sup_np[np.clip(d_ids[drained], 0,
                                   sup_np.shape[0] - 1)].sum())
    m.delta = delta_mod.rebuild_keep(delta, keep,
                                     clear_superseded_ids=d_ids[drained])
    return {"drained": n_drained, "ivf_changed": n_drained > 0,
            "cleared_superseded": n_cleared,
            "left": int(keep.size),
            "note": (f"drained {n_drained} rows "
                     f"(delta {used_before}->{int(m.delta.count)})")}


# --------------------------------------------------------------------- merge
def merge_cold(m, stats: PartitionStats, p: int) -> Dict:
    """Folds partition ``p`` into its nearest live sibling and parks it."""
    ivf = m.ivf
    cents = np.asarray(ivf.centroids)
    parked = partitioner.parked_mask(cents)
    if parked[p]:
        return {"note": f"p={p} already parked", "moved": 0,
                "ivf_changed": False}
    siblings = [q for q in range(ivf.n_partitions) if q != p and not parked[q]]
    if not siblings:
        return {"note": "no live sibling", "moved": 0, "ivf_changed": False}
    d2 = np.sum((cents[siblings] - cents[p]) ** 2, axis=1)
    sib = siblings[int(np.argmin(d2))]

    rows_p = _flat_rows(ivf, p, occupied=True)
    gids = np.asarray(ivf.ids).reshape(-1)[rows_p]
    tomb = np.asarray(m.delta.tombstones)
    sup = np.asarray(m.delta.superseded)
    gc = np.clip(gids, 0, tomb.shape[0] - 1)
    dead = tomb[gc] | sup[gc]
    live_rows = rows_p[~dead]           # dead rows are purged, not moved
    # (purged tombstones stay set: the id must not resurrect; a purged
    # superseded row's latest version lives in the delta and its bit is
    # cleared when that row drains)

    free_sib = _flat_rows(ivf, sib, occupied=False)
    n_fit = min(free_sib.size, live_rows.size)
    if n_fit:
        data, vmin, scale, ids = ivf_mod.gather_slots(ivf, live_rows[:n_fit])
        ivf = ivf_mod.set_slots(ivf, free_sib[:n_fit], data, vmin, scale, ids)
    overflow = live_rows[n_fit:]
    if overflow.size:
        over_ids = np.asarray(m.ivf.ids).reshape(-1)[overflow]
        rows = _master_rows(m, over_ids)
        m.delta = delta_mod.insert_grow(
            m.delta, m.vectors[jnp.asarray(rows)],
            jnp.asarray(over_ids.astype(np.int32)))
    ivf = ivf_mod.clear_slots(ivf, rows_p)
    ivf = ivf._replace(centroids=ivf.centroids.at[p].set(
        jnp.asarray(partitioner.parked_centroid(cents.shape[1]))))
    m.ivf = ivf
    stats.reset_partition(p, 0.0, parked=True)
    stats.invalidate_slab()
    return {"moved": n_fit, "purged": int(dead.sum()), "ivf_changed": True,
            "overflow": int(overflow.size), "sibling": sib,
            "note": (f"p={p} -> p={sib}: moved {n_fit}, purged "
                     f"{int(dead.sum())} dead, {int(overflow.size)} to delta")}


# --------------------------------------------------------------------- split
def split_hot(m, cfg, key, stats: PartitionStats, hot: int) -> Dict:
    """Splits the hot partition's members across (hot, a freed partition)
    via a local K=2 fit. Merges the coldest partition away first when no
    parked slot is available."""
    parked = partitioner.parked_mask(np.asarray(m.ivf.centroids))
    merge_note = ""
    if parked.any():
        target = int(np.where(parked)[0][0])
    else:
        live = np.asarray(m.ivf.counts)
        others = [q for q in range(m.ivf.n_partitions) if q != hot]
        if not others:
            return {"note": "single partition, cannot split", "moved": 0,
                    "ivf_changed": False}
        target = min(others, key=lambda q: int(live[q]))
        res = merge_cold(m, stats, target)
        merge_note = f"; freed via {res['note']}"
        if not partitioner.parked_mask(np.asarray(m.ivf.centroids))[target]:
            return {"note": f"could not free a partition{merge_note}",
                    "moved": 0, "ivf_changed": True}

    ivf = m.ivf
    rows_all = _flat_rows(ivf, hot, occupied=True)
    gids = np.asarray(ivf.ids).reshape(-1)[rows_all]
    tomb = np.asarray(m.delta.tombstones)
    sup = np.asarray(m.delta.superseded)
    gc = np.clip(gids, 0, tomb.shape[0] - 1)
    alive = ~(tomb[gc] | sup[gc])
    rows_h = rows_all[alive]            # dead rows purged with the rewrite
    if rows_h.size < 2:
        return {"note": f"p={hot} has <2 live rows{merge_note}", "moved": 0,
                "ivf_changed": bool(merge_note)}

    data, vmin, scale, ids = ivf_mod.gather_slots(ivf, rows_h)
    members = ivf_mod._dequant_rows(ivf, data, vmin, scale)
    cents2, sub_assign = partitioner.split_two(key, members)
    sub = np.asarray(sub_assign)
    if (sub == 0).all() or (sub == 1).all():
        # degenerate fit (duplicated members): treat as a recluster
        return recluster(m, stats, hot)

    cap = ivf.capacity
    ivf = ivf_mod.clear_slots(ivf, rows_all)
    halves = []
    for g, part in ((np.where(sub == 0)[0], hot),
                    (np.where(sub == 1)[0], target)):
        sel = jnp.asarray(g)
        ivf = ivf_mod.set_slots(
            ivf, part * cap + np.arange(g.size),
            data[sel], vmin[sel], scale[sel], ids[sel])
        halves.append(g.size)
    ivf = ivf._replace(centroids=ivf.centroids.at[hot].set(cents2[0])
                                              .at[target].set(cents2[1]))
    m.ivf = ivf
    for g, part, c in ((np.where(sub == 0)[0], hot, 0),
                       (np.where(sub == 1)[0], target, 1)):
        d = np.asarray(members[jnp.asarray(g)]) - np.asarray(cents2[c])
        stats.reset_partition(part, float(np.mean(
            np.linalg.norm(d, axis=-1))) if g.size else 0.0, parked=False)
    stats.invalidate_slab()
    return {"moved": int(rows_h.size), "halves": tuple(halves),
            "ivf_changed": True,
            "target": target,
            "note": (f"p={hot} split {halves[0]}/{halves[1]} "
                     f"into p={target}{merge_note}")}


# ----------------------------------------------------------------- recluster
def recluster(m, stats: PartitionStats, p: int) -> Dict:
    """Re-centers partition ``p``'s centroid on its live members' mean (no
    row moves — a drifted centroid only mis-routes *future* probes/writes)."""
    ivf = m.ivf
    rows_p = _flat_rows(ivf, p, occupied=True)
    gids = np.asarray(ivf.ids).reshape(-1)[rows_p]
    tomb = np.asarray(m.delta.tombstones)
    sup = np.asarray(m.delta.superseded)
    gc = np.clip(gids, 0, tomb.shape[0] - 1)
    rows_p = rows_p[~(tomb[gc] | sup[gc])]
    if rows_p.size == 0:
        return {"note": f"p={p} has no live rows", "moved": 0,
                "ivf_changed": False}
    data, vmin, scale, _ = ivf_mod.gather_slots(ivf, rows_p)
    members = ivf_mod._dequant_rows(ivf, data, vmin, scale)
    centroid = jnp.mean(members, axis=0)
    m.ivf = ivf._replace(centroids=ivf.centroids.at[p].set(centroid))
    dist = np.linalg.norm(np.asarray(members) - np.asarray(centroid), axis=-1)
    old = stats.baseline[p]
    stats.reset_partition(p, float(np.mean(dist)))
    return {"moved": 0, "members": int(rows_p.size), "ivf_changed": True,
            "note": (f"p={p} re-centered over {int(rows_p.size)} rows "
                     f"(baseline {old:.3f}->{stats.baseline[p]:.3f})")}
