"""Declarative query engine vs the brute-force reference interpreter.

Every plan shape the engine supports — seed scans, Where predicates (both
planner modes), typed multi-hop traversal, cross-modal re-scoring, set ops,
and chains thereof — runs at full probe against ``tests/query_ref.py``'s
exhaustive numpy interpreter (stable + delta rows, boosted edge weights).
The facade wrappers (``search`` / ``hybrid_search``) must stay bit-identical
with the plans they compile to. Also the edge_type_mask test coverage:
masked edge types must route no traversal mass, in every spelling."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import HMGIIndex
from repro.core import traversal as trav_mod
from repro.core.graph_store import edge_type_lut, from_edges as graph_from_edges
from repro.query import Q
from repro.query.planner import compile_plan

from query_ref import assert_matches, reference_execute

N = 260
DT, DI = 24, 16
K = 8
N_TYPES = 3


def _unit(v):
    return v / np.linalg.norm(v, axis=-1, keepdims=True)


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(11)
    vt = _unit(rng.normal(size=(N, DT)).astype(np.float32))
    vi = _unit(rng.normal(size=(N, DI)).astype(np.float32))
    year = rng.integers(2000, 2030, N).astype(np.int32)
    cat = rng.integers(0, 6, N).astype(np.int32)
    e = 2000
    src = rng.integers(0, N, e).astype(np.int32)
    dst = rng.integers(0, N, e).astype(np.int32)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    et = rng.integers(0, N_TYPES, len(src)).astype(np.int32)

    cfg = get_config("hmgi").replace(
        n_partitions=8, n_probe=8, top_k=K, kmeans_iters=6,
        delta_capacity=64, delta_rescore_margin=64)
    idx = HMGIIndex(cfg, seed=0)
    ids = np.arange(N, dtype=np.int32)
    # every node carries embeddings in BOTH modalities (cross-modal re-score
    # needs a shared id space with per-modality vectors)
    idx.ingest({"text": (ids, vt), "image": (ids, vi)}, n_nodes=N,
               edges=(src, dst, et), node_attrs={"year": year, "cat": cat})
    # live delta rows on top of the stable index (MVCC update path)
    upd = _unit(rng.normal(size=(6, DT)).astype(np.float32))
    idx.insert("text", np.arange(6, dtype=np.int32), upd)

    q = vt[40:45] + 0.05 * rng.normal(size=(5, DT)).astype(np.float32)
    qi = vi[40:45] + 0.05 * rng.normal(size=(5, DI)).astype(np.float32)
    return idx, q, qi, year, et


def _check(idx, plan, atol=2e-5):
    phys = compile_plan(idx, plan)
    assert_matches((idx.query(plan)), reference_execute(idx, phys),
                   atol=atol)
    return phys


class TestPlanOracle:
    def test_vector_plan(self, setup):
        idx, q, *_ = setup
        _check(idx, Q.vector("text", q).topk(K))

    @pytest.mark.parametrize("thresh", [2004, 2015, 2027])
    def test_filtered_vector_both_modes(self, setup, thresh):
        """Covers both planner filter modes (pushdown at low selectivity,
        oversample at high) against the predicate oracle."""
        idx, q, *_ = setup
        _check(idx, Q.vector("text", q).where(("year", "<", thresh)).topk(K))

    def test_hybrid_chain(self, setup):
        idx, q, *_ = setup
        _check(idx, Q.vector("text", q).traverse(2).topk(K))

    def test_typed_filtered_hybrid_chain(self, setup):
        """Where + Traverse(edge_types=...): the predicate constrains seeds,
        routing and candidates; masked edge types route no mass."""
        idx, q, *_ = setup
        _check(idx, Q.vector("text", q)
                     .where(("year", "<", 2022))
                     .traverse(2, edge_types=(0, 2)).topk(K))

    def test_cross_modal_chain(self, setup):
        idx, q, qi, *_ = setup
        _check(idx, Q.vector("text", q).traverse(1)
                     .cross_modal("image", qi, weight=0.4).topk(K))

    def test_full_chain(self, setup):
        """The acceptance chain: Where + Traverse + CrossModal, stable+delta,
        full probe."""
        idx, q, qi, *_ = setup
        _check(idx, Q.vector("text", q)
                     .where(("year", ">", 2008), ("cat", "in", {0, 1, 2, 3}))
                     .traverse(2, edge_types=(0, 1))
                     .cross_modal("image", qi, weight=0.3).topk(K))

    def test_union(self, setup):
        idx, q, qi, *_ = setup
        _check(idx, Q.union(Q.vector("text", q).topk(16),
                            Q.vector("image", qi).topk(16)).topk(K))

    def test_intersect(self, setup):
        idx, q, *_ = setup
        q2 = np.roll(np.asarray(q), 1, axis=1).astype(np.float32)
        _check(idx, Q.intersect(Q.vector("text", q).topk(48),
                                Q.vector("text", q2).topk(48)).topk(K))

    def test_union_then_traverse(self, setup):
        idx, q, qi, *_ = setup
        _check(idx, Q.union(Q.vector("text", q).topk(12),
                            Q.vector("image", qi).topk(12))
                     .traverse(1).topk(K))

    def test_union_with_outer_where_post_filters(self, setup):
        idx, q, qi, year, _ = setup
        plan = Q.union(Q.vector("text", q).topk(16),
                       Q.vector("image", qi).topk(16)) \
                .where(("year", "<", 2020)).topk(K)
        _check(idx, plan)
        _, ids = idx.query(plan)
        for row in np.asarray(ids):
            for x in row:
                if x >= 0:
                    assert year[x] < 2020

    def test_hops_zero_equals_search(self, setup):
        idx, q, *_ = setup
        sv, si = idx.query(Q.vector("text", q).traverse(0).topk(K))
        rv, ri = idx.search(q, "text", k=K)
        np.testing.assert_array_equal(np.asarray(si), np.asarray(ri))
        np.testing.assert_allclose(np.asarray(sv), np.asarray(rv),
                                   rtol=0, atol=1e-6)

    def test_dense_fusion_plan(self):
        """Tiny corpus: the planner flips to the dense fusion representation
        (frontier covers every node) — must still match the oracle."""
        rng = np.random.default_rng(3)
        n = 24
        v = _unit(rng.normal(size=(n, 12)).astype(np.float32))
        src = rng.integers(0, n, 120).astype(np.int32)
        dst = (src + 1 + rng.integers(0, n - 1, 120).astype(np.int32)) % n
        cfg = get_config("hmgi").replace(n_partitions=4, n_probe=4, top_k=K,
                                         kmeans_iters=4, delta_capacity=32,
                                         delta_rescore_margin=32)
        idx = HMGIIndex(cfg, seed=0)
        idx.ingest({"text": (np.arange(n, dtype=np.int32), v)}, n_nodes=n,
                   edges=(src, dst))
        plan = Q.vector("text", v[:4]).traverse(1).topk(K)
        phys = _check(idx, plan)
        assert phys.stages[0].repr == "dense"
        assert "fuse=dense" in idx.explain(plan)

    def test_cross_modal_ignores_deleted_embeddings(self):
        """A tombstoned id in the re-scoring modality must read as 'no
        embedding' (sim2 = 0), not contribute its dead vector."""
        rng = np.random.default_rng(9)
        n = 64
        vt = _unit(rng.normal(size=(n, 12)).astype(np.float32))
        vim = _unit(rng.normal(size=(n, 10)).astype(np.float32))
        cfg = get_config("hmgi").replace(n_partitions=4, n_probe=4, top_k=4,
                                         kmeans_iters=4, delta_capacity=32,
                                         delta_rescore_margin=32)
        idx = HMGIIndex(cfg, seed=0)
        ids = np.arange(n, dtype=np.int32)
        idx.ingest({"text": (ids, vt), "image": (ids, vim)}, n_nodes=n)
        q = vt[:2]
        qi = vim[:2]
        _, before = idx.query(Q.vector("text", q)
                               .cross_modal("image", qi, weight=0.5).topk(4))
        victim = int(np.asarray(before)[0, 0])
        idx.delete("image", np.array([victim]))
        plan = Q.vector("text", q).cross_modal("image", qi, weight=0.5).topk(4)
        _check(idx, plan)
        sv, si = idx.query(plan)
        tv, ti = idx.search(q, "text", k=8)
        row = np.asarray(ti)[0].tolist()
        # the victim's rescored value is now (1-w)·text score alone
        if victim in np.asarray(si)[0]:
            pos = np.asarray(si)[0].tolist().index(victim)
            tpos = row.index(victim)
            np.testing.assert_allclose(
                np.asarray(sv)[0, pos],
                0.5 * np.asarray(tv)[0, tpos], rtol=1e-5)

    def test_mvcc_dead_rows_do_not_waste_scan_slots(self):
        """Updates supersede stable rows; at full probe the scan must still
        return the exact visible top-k (visibility pushed into the scan
        validity, gated by the facade's has_dead bit)."""
        rng = np.random.default_rng(10)
        n = 80
        v = _unit(rng.normal(size=(n, 12)).astype(np.float32))
        cfg = get_config("hmgi").replace(n_partitions=4, n_probe=4, top_k=6,
                                         kmeans_iters=4, delta_capacity=32,
                                         delta_rescore_margin=32)
        idx = HMGIIndex(cfg, seed=0)
        idx.ingest({"text": (np.arange(n, dtype=np.int32), v)}, n_nodes=n)
        assert not idx.modalities["text"].has_dead
        # update the 4 nearest rows to the query: their stale stable rows
        # would otherwise fill the scan's top slots and get masked to -inf
        idx.insert("text", np.arange(4, dtype=np.int32),
                   _unit(rng.normal(size=(4, 12)).astype(np.float32)))
        assert idx.modalities["text"].has_dead
        _check(idx, Q.vector("text", v[:3]).topk(6))

    def test_min_recall_resolves_probe_width(self, setup):
        idx, q, *_ = setup
        plan = Q.vector("text", q, min_recall=0.99).traverse(1).topk(K)
        phys = _check(idx, plan)
        assert phys.source.n_probe >= 8   # hybrid_deep-class plan


class TestWrapperEquivalence:
    """search/hybrid_search are thin wrappers over the engine — the compiled
    plan must return bit-identical results."""

    def test_search_is_a_plan(self, setup):
        idx, q, *_ = setup
        sv, si = idx.search(q, "text", k=K)
        pv, pi = idx.query(Q.vector("text", q).topk(K))
        np.testing.assert_array_equal(np.asarray(si), np.asarray(pi))
        np.testing.assert_array_equal(np.asarray(sv), np.asarray(pv))

    def test_filtered_search_is_a_plan(self, setup):
        idx, q, *_ = setup
        where = ("year", "<", 2015)
        sv, si = idx.search(q, "text", k=K, where=where)
        pv, pi = idx.query(Q.vector("text", q).where(where).topk(K))
        np.testing.assert_array_equal(np.asarray(si), np.asarray(pi))
        np.testing.assert_array_equal(np.asarray(sv), np.asarray(pv))

    def test_hybrid_search_is_a_plan(self, setup):
        idx, q, _, _, et = setup
        mask = jnp.asarray(np.array([1.0, 0.0, 1.0], np.float32))
        hv, hi = idx.hybrid_search(q, "text", k=K, n_hops=2,
                                   edge_type_mask=mask,
                                   where=("year", "<", 2026))
        # the wrapper pre-normalises queries before compiling (its historic
        # double-normalisation); mirror that for bitwise equality
        qn = idx._norm_queries(q)
        pv, pi = idx.query(Q.vector("text", qn)
                            .where(("year", "<", 2026))
                            .traverse(2, edge_types=(0, 2)).topk(K))
        np.testing.assert_array_equal(np.asarray(hi), np.asarray(pi))
        np.testing.assert_array_equal(np.asarray(hv), np.asarray(pv))


class TestEdgeTypeMask:
    """Satellite: type-filtered traversal had zero tests."""

    @pytest.fixture()
    def toy(self):
        # 0 -t0-> 1 -t0-> 2 ; 0 -t1-> 3 ; 3 -t0-> 4
        return graph_from_edges(5, np.array([0, 1, 0, 3]),
                                np.array([1, 2, 3, 4]),
                                edge_type=np.array([0, 0, 1, 0]))

    def test_masked_types_route_no_mass(self, toy):
        seeds = jnp.zeros((5,), jnp.float32).at[0].set(1.0)
        res = trav_mod.frontier_expand(
            toy, seeds, n_hops=2, edge_type_mask=jnp.array([1.0, 0.0]))
        mass = np.asarray(res.per_hop)
        # the only path to 3 (and through it to 4) is the masked type-1 edge
        assert np.all(mass[:, 3] == 0.0) and np.all(mass[:, 4] == 0.0)
        assert mass[0, 1] > 0.0 and mass[1, 2] > 0.0

    def test_unmasked_types_reach(self, toy):
        seeds = jnp.zeros((5,), jnp.float32).at[0].set(1.0)
        res = trav_mod.frontier_expand(toy, seeds, n_hops=2)
        assert res.per_hop[0, 3] > 0.0 and res.per_hop[1, 4] > 0.0

    def test_type_id_sequence_equals_mask(self, toy):
        seeds = jnp.zeros((5,), jnp.float32).at[0].set(1.0)
        a = trav_mod.frontier_expand(toy, seeds, n_hops=2,
                                     edge_type_mask=jnp.array([1.0, 0.0]))
        b = trav_mod.frontier_expand(toy, seeds, n_hops=2,
                                     edge_type_mask=(0,))
        np.testing.assert_array_equal(np.asarray(a.per_hop),
                                      np.asarray(b.per_hop))
        # the LUT only spans the requested ids; types beyond it (here
        # type 1) are excluded by the traversal's safe gather
        np.testing.assert_array_equal(np.asarray(edge_type_lut([0])), [1.0])

    def test_multi_hop_batch_typed(self, toy):
        ids = jnp.array([[0]], jnp.int32)
        scores = jnp.array([[1.0]], jnp.float32)
        gs = trav_mod.multi_hop_batch(toy, ids, scores, n_hops=2,
                                      edge_type_mask=(0,))
        gm = trav_mod.multi_hop_batch(toy, ids, scores, n_hops=2,
                                      edge_type_mask=jnp.array([1.0, 0.0]))
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(gm))
        assert np.all(np.asarray(gs)[0, [3, 4]] == 0.0)

    def test_engine_typed_traverse_matches_oracle(self, setup):
        idx, q, *_ = setup
        for types in [(0,), (1, 2)]:
            _check(idx, Q.vector("text", q)
                        .traverse(2, edge_types=types).topk(K))

    def test_edge_type_lut_rejects_bad_input(self):
        with pytest.raises(ValueError, match="empty"):
            edge_type_lut([])
        with pytest.raises(ValueError, match="non-negative"):
            edge_type_lut([-1])
        # a float list is a mask spelled wrong, not a set of type ids —
        # reinterpreting it would silently invert the filter
        with pytest.raises(ValueError, match="mask"):
            edge_type_lut([1.0, 0.0])


class TestExplain:
    def test_filter_mode_reported(self, setup):
        idx, q, *_ = setup
        lo = idx.explain(Q.vector("text", q).where(("year", "<", 2004)).topk(K))
        hi = idx.explain(Q.vector("text", q).where(("year", "<", 2028)).topk(K))
        assert "filter=prefilter" in lo
        assert "filter=oversample" in hi

    def test_stage_order_and_widths(self, setup):
        idx, q, qi, *_ = setup
        s = idx.explain(Q.vector("text", q).traverse(2, edge_types=(0,))
                         .cross_modal("image", qi).topk(K))
        assert s.index("seed[") < s.index("traverse[") < s.index("rescore[")
        assert "typed" in s and "fuse=sparse" in s and f"topk({K})" in s

    def test_explain_is_side_effect_free(self, setup):
        """explain() compiles but must not clobber the execution metrics
        (benchmarks and tests read _metrics after a search)."""
        idx, q, *_ = setup
        idx.search(q, "text", k=K, where=("year", "<", 2004))
        mode = idx._metrics["filter_mode"]
        sel = idx._metrics["filter_selectivity"]
        idx.explain(Q.vector("text", q).where(("year", "<", 2028)).topk(K))
        assert idx._metrics["filter_mode"] == mode
        assert idx._metrics["filter_selectivity"] == sel

    def test_traverse_without_graph_raises(self):
        cfg = get_config("hmgi").replace(n_partitions=4, kmeans_iters=2)
        idx = HMGIIndex(cfg, seed=0)
        rng = np.random.default_rng(0)
        v = rng.normal(size=(32, 8)).astype(np.float32)
        idx.ingest({"text": (np.arange(32, dtype=np.int32), v)}, n_nodes=32)
        with pytest.raises(ValueError, match="graph"):
            idx.query(Q.vector("text", v[:2]).traverse(1).topk(4))
