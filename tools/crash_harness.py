"""Kill-and-recover fault-injection harness for the durable index lifecycle.

For each registered crash point (``repro.persistence.faultpoints.POINTS``)
the harness:

1. runs a child process applying a fixed, deterministic op script to a
   ``DurableHMGIIndex`` with the fault point armed via ``HMGI_FAULTPOINT``
   — the child dies with ``os._exit(137)`` (SIGKILL semantics: no flush,
   no atexit, no finally) at the durability boundary;
2. recovers the data dir in-process and reads the recovered ``last_seq`` D;
3. builds a *golden* index by applying the first D logged ops of the same
   script (plus the interleaved searches that precede them — workload heat
   must match too) to a fresh in-memory ``HMGIIndex``;
4. asserts ``search`` and ``hybrid_search`` results are **bit-identical**
   between recovered and golden.

``recover.*`` points crash the *recovery* instead: the child runs clean,
a second child dies mid-replay, and the harness asserts the next recovery
still matches golden (replay is read-only until the final log truncation,
so a crashed recovery is always re-runnable).

Usage:
    python tools/crash_harness.py --sweep              # every crash point
    python tools/crash_harness.py --point wal.pre_append
    python tools/crash_harness.py --child --data-dir D # (internal)
"""
from __future__ import annotations

import argparse
import os
import shutil
import subprocess
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import HMGIConfig  # noqa: E402


def make_cfg() -> HMGIConfig:
    return HMGIConfig(modalities=("text", "image"), dim=12,
                      n_partitions=4, n_probe=4, kmeans_iters=4,
                      delta_capacity=64, use_nsw_refine=True,
                      snapshot_keep=2)


def queries() -> np.ndarray:
    return np.random.default_rng(99).standard_normal((4, 12)).astype(np.float32)


def scripted_ops():
    """Deterministic op script. ``("op", ...)`` entries are logged (one WAL
    record each, in order); ``search``/``snapshot`` entries are not logged
    but matter — searches move workload heat, snapshots set the recovery
    base. The script covers stable + delta + post-maintenance states and
    leaves a replay tail after the last snapshot."""
    rng = np.random.default_rng(7)
    n, d = 160, 12
    emb = {m: (np.arange(n, dtype=np.int32),
               rng.standard_normal((n, d)).astype(np.float32))
           for m in ("text", "image")}
    edges = (rng.integers(0, n, 400).astype(np.int32),
             rng.integers(0, n, 400).astype(np.int32))
    attrs = {"cat": rng.integers(0, 4, n).astype(np.int32)}
    ins = lambda lo, hi: (np.arange(lo, hi, dtype=np.int32),
                          rng.standard_normal((hi - lo, d)).astype(np.float32))
    return [
        ("ingest", emb, n, edges, attrs),                       # seq 1
        ("search", "text"), ("search", "image"),
        ("insert", "text", *ins(160, 180)),                     # seq 2
        ("search", "text"),
        ("delete", "text", np.arange(3, dtype=np.int32)),       # seq 3
        ("maintain",),                                          # seq 4
        ("snapshot",),
        ("insert", "image", *ins(180, 200)),                    # seq 5
        ("compact", "text"),                                    # seq 6
        ("search", "image"),
        ("insert", "text", *ins(200, 212)),                     # seq 7
        ("snapshot",),
        ("insert", "text", *ins(212, 224)),                     # seq 8
        ("delete", "image", np.arange(8, dtype=np.int32)),      # seq 9
        ("maintain",),                                          # seq 10
    ]


def apply_ops(index, ops, until=None):
    """Applies script entries to ``index`` in order, stopping once ``until``
    logged ops have been applied (searches past that point are skipped too —
    the recovered index's heat is the stamp of the last replayed record)."""
    q = queries()
    done = 0
    for entry in ops:
        kind = entry[0]
        if kind == "search":
            index.search(q, entry[1], k=5)
            continue
        if kind == "snapshot":
            if hasattr(index, "snapshot"):
                index.snapshot()
            continue
        if until is not None and done >= until:
            break
        if kind == "ingest":
            _, emb, n, edges, attrs = entry
            index.ingest(emb, n, edges=edges, build_nsw=True,
                         node_attrs=attrs)
        elif kind == "insert":
            index.insert(entry[1], entry[2], entry[3])
        elif kind == "delete":
            index.delete(entry[1], entry[2])
        elif kind == "maintain":
            index.maintain()
        elif kind == "compact":
            index.compact(entry[1])
        else:
            raise ValueError(kind)
        done += 1
    return done


def total_logged(ops) -> int:
    return sum(e[0] not in ("search", "snapshot") for e in ops)


def golden_index(cfg, d: int):
    """Fresh in-memory index after the first ``d`` logged ops."""
    from repro.core.index import HMGIIndex
    idx = HMGIIndex(cfg, seed=0)
    apply_ops(idx, scripted_ops(), until=d)
    return idx


def assert_bit_identical(recovered, golden, label: str):
    q = queries()
    for mod in ("text", "image"):
        rs, ri = recovered.search(q, mod, k=8)
        gs, gi = golden.search(q, mod, k=8)
        if not (np.array_equal(np.asarray(ri), np.asarray(gi))
                and np.array_equal(np.asarray(rs), np.asarray(gs))):
            raise AssertionError(f"{label}: search({mod}) diverged:\n"
                                 f"  recovered ids {np.asarray(ri)[0]}\n"
                                 f"  golden    ids {np.asarray(gi)[0]}")
        rs, ri = recovered.hybrid_search(q, mod, k=8)
        gs, gi = golden.hybrid_search(q, mod, k=8)
        if not (np.array_equal(np.asarray(ri), np.asarray(gi))
                and np.array_equal(np.asarray(rs), np.asarray(gs))):
            raise AssertionError(f"{label}: hybrid_search({mod}) diverged")


# hits chosen so every point fires after meaningful state exists (e.g.
# wal.pre_rotate hit 1 is the constructor's first segment open; hit 2 is
# the first snapshot's rotation)
DEFAULT_HITS = {
    "wal.pre_append": 5,
    "wal.post_append": 5,
    "wal.pre_rotate": 2,
    "wal.pre_gc": 1,
    "wal.post_gc": 1,
    "snapshot.mid_write": 3,
    "snapshot.pre_rename": 1,
    "snapshot.post_rename": 1,
    "recover.mid_replay": 2,
}


def run_child(data_dir: str, recover_only: bool, env_point: str | None):
    env = dict(os.environ)
    env.pop("HMGI_FAULTPOINT", None)
    if env_point:
        env["HMGI_FAULTPOINT"] = env_point
    cmd = [sys.executable, os.path.abspath(__file__), "--child",
           "--data-dir", data_dir]
    if recover_only:
        cmd.append("--recover-only")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
    return proc


def check_point(point: str, data_dir: str, hits: int | None = None) -> str:
    """One kill-and-recover cycle for ``point``. Returns a summary line;
    raises on any mismatch."""
    from repro.persistence import recover
    hits = DEFAULT_HITS[point] if hits is None else hits
    shutil.rmtree(data_dir, ignore_errors=True)
    cfg = make_cfg()
    if point.startswith("recover."):
        clean = run_child(data_dir, recover_only=False, env_point=None)
        if clean.returncode != 0:
            raise AssertionError(f"clean child failed:\n{clean.stderr[-2000:]}")
        crashed = run_child(data_dir, recover_only=True,
                            env_point=f"{point}:{hits}")
    else:
        crashed = run_child(data_dir, recover_only=False,
                            env_point=f"{point}:{hits}")
    if crashed.returncode != 137:
        raise AssertionError(
            f"{point}: child exited {crashed.returncode}, expected 137 "
            f"(fault never fired?)\n{crashed.stderr[-2000:]}")
    idx = recover(cfg, data_dir, seed=0)
    d = idx.last_seq
    idx.close()
    # recover() is also what a restarted server runs — compare a *fresh*
    # recovery (the one above validated re-runnability after the crash)
    idx = recover(cfg, data_dir, seed=0)
    golden = golden_index(cfg, d)
    assert_bit_identical(idx, golden, point)
    trail = idx.metrics().get("recovery", "")
    idx.close()
    return f"{point}: killed at hit {hits}, recovered {d} ops — OK [{trail}]"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--recover-only", action="store_true")
    ap.add_argument("--data-dir", default="/tmp/hmgi_crash_harness")
    ap.add_argument("--point")
    ap.add_argument("--hits", type=int, default=None)
    ap.add_argument("--sweep", action="store_true")
    args = ap.parse_args()

    if args.child:
        from repro.persistence import DurableHMGIIndex, recover
        cfg = make_cfg()
        if args.recover_only:
            idx = recover(cfg, args.data_dir, seed=0)
        else:
            idx = DurableHMGIIndex(cfg, args.data_dir, seed=0)
            apply_ops(idx, scripted_ops())
        idx.close()
        return

    from repro.persistence.faultpoints import POINTS
    points = list(POINTS) if args.sweep else [args.point]
    if not points[0]:
        ap.error("--point or --sweep required")
    failures = []
    for p in points:
        try:
            print(check_point(p, args.data_dir, args.hits), flush=True)
        except AssertionError as e:
            failures.append(p)
            print(f"FAIL {p}: {e}", flush=True)
    if failures:
        sys.exit(f"{len(failures)} crash point(s) failed: {failures}")
    print(f"all {len(points)} crash point(s): clean recovery, "
          "bit-identical results")


if __name__ == "__main__":
    main()
