"""Exporters: Prometheus text exposition and JSON snapshot.

``render_prometheus`` emits the text format scrapers expect: counters as
``hmgi_<name>_total``, gauges bare, histograms as cumulative
``_bucket{le="..."}`` series plus ``_sum``/``_count``. Metric names are
sanitised (dots and dashes become underscores) and prefixed ``hmgi_``.
``parse_prometheus`` is the inverse over our own output — it exists for
the exposition round-trip test, not as a general parser.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

from .metrics import MetricsRegistry, registry


def _sanitize(name: str) -> str:
    out = []
    for ch in name:
        out.append(ch if (ch.isalnum() or ch == "_") else "_")
    return "hmgi_" + "".join(out)


def _fmt(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))


def render_prometheus(reg: Optional[MetricsRegistry] = None) -> str:
    """Prometheus text exposition of the registry (default: the global
    one). Stable ordering (sorted by name) so output diffs cleanly."""
    reg = reg or registry()
    lines = []
    for name, c in sorted(reg.counters().items()):
        m = _sanitize(name)
        lines.append(f"# TYPE {m}_total counter")
        lines.append(f"{m}_total {_fmt(c.value)}")
    for name, g in sorted(reg.gauges().items()):
        m = _sanitize(name)
        lines.append(f"# TYPE {m} gauge")
        lines.append(f"{m} {_fmt(g.value)}")
    for name, h in sorted(reg.histograms().items()):
        m = _sanitize(name)
        lines.append(f"# TYPE {m} histogram")
        for le, cum in h.cumulative_buckets():
            lines.append(f'{m}_bucket{{le="{_fmt(le)}"}} {cum}')
        lines.append(f"{m}_sum {_fmt(h.total)}")
        lines.append(f"{m}_count {h.count}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> Dict[str, Dict[str, object]]:
    """Parse our own exposition back into
    ``{counters: {m: v}, gauges: {m: v}, histograms: {m: {buckets:
    [(le, cum)], sum, count}}}`` keyed by sanitised metric name. Used by
    the round-trip test."""
    counters: Dict[str, float] = {}
    gauges: Dict[str, float] = {}
    hists: Dict[str, Dict[str, object]] = {}
    types: Dict[str, str] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, metric, kind = line.split()
            types[metric] = kind
            continue
        if line.startswith("#"):
            continue
        key, sval = line.rsplit(" ", 1)
        val = float(sval.replace("+Inf", "inf"))
        if "{" in key:
            base, label = key.split("{", 1)
            assert base.endswith("_bucket"), line
            m = base[: -len("_bucket")]
            le = float(label.split('"')[1].replace("+Inf", "inf"))
            hists.setdefault(m, {"buckets": [], "sum": 0.0, "count": 0})
            hists[m]["buckets"].append((le, int(val)))  # type: ignore[union-attr]
        elif key.endswith("_sum") and types.get(key[: -len("_sum")]) == "histogram":
            hists.setdefault(key[: -4], {"buckets": [], "sum": 0.0, "count": 0})
            hists[key[: -4]]["sum"] = val
        elif key.endswith("_count") and types.get(key[: -len("_count")]) == "histogram":
            hists.setdefault(key[: -6], {"buckets": [], "sum": 0.0, "count": 0})
            hists[key[: -6]]["count"] = int(val)
        elif key.endswith("_total") and types.get(key) == "counter":
            counters[key[: -6]] = val
        else:
            gauges[key] = val
    return {"counters": counters, "gauges": gauges, "histograms": hists}


def buckets_from_histogram(h) -> Tuple[Tuple[float, int], ...]:
    return tuple(h.cumulative_buckets())
