# Declarative hybrid query engine: AST + builder (ast), cost-based
# logical->physical compiler (planner), staged executor over the core's
# jitted primitives (executor). Public surface:
#
#     from repro.query import Q
#     scores, ids = index.query(Q.vector("text", q).traverse(2).topk(10))
from repro.query.ast import (CrossModal, Plan, Q, SetOp, Traverse,
                             VectorSeed, Where)
from repro.query.planner import PhysicalPlan, compile_plan
from repro.query.executor import execute
