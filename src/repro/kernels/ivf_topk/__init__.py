from repro.kernels.ivf_topk.ops import scan_topk_quantized
