"""Layer 2a: jaxpr lints over registry trace entries (HMG101, HMG102).

Each registry entry is traced with ``jax.make_jaxpr`` at its canonical
shapes; the resulting jaxpr is walked recursively (descending into
``pjit``/``scan``/``while``/``cond`` sub-jaxprs) and linted. ``pallas_call``
equations are deliberately NOT descended into: the in-kernel int8 -> f32
register cast is the design — the rule targets dequant that leaks *outside*
the kernel into an HBM-resident slab.
"""
from __future__ import annotations

import math
from typing import Iterator, List, Tuple

from tools.staticcheck import Violation
from tools.staticcheck.registry import TraceEntry, trace_entries

_TRANSFER_PRIMS = {"device_put", "copy_to_host_async", "io_callback",
                   "pure_callback", "host_callback_call"}


def _iter_eqns(jaxpr, in_pallas: bool = False) -> Iterator[Tuple[object,
                                                                 bool]]:
    """Yield (eqn, inside_pallas) over jaxpr and its sub-jaxprs."""
    import jax

    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        yield eqn, in_pallas
        if prim == "pallas_call":
            continue                     # in-kernel casts are the design
        for val in eqn.params.values():
            for sub in _as_jaxprs(val):
                yield from _iter_eqns(sub, in_pallas)


def _as_jaxprs(val):
    import jax

    core = jax.core
    if isinstance(val, core.ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, core.Jaxpr):
        yield val
    elif isinstance(val, (tuple, list)):
        for item in val:
            yield from _as_jaxprs(item)


def lint_jaxpr(entry: TraceEntry, jaxpr) -> List[Violation]:
    out: List[Violation] = []
    for eqn, in_pallas in _iter_eqns(jaxpr):
        prim = eqn.primitive.name
        if prim in _TRANSFER_PRIMS:
            out.append(Violation(
                "HMG102", entry.name, 0,
                f"'{prim}' inside the traced region — host/device "
                "transfers must stay outside jit boundaries"))
        elif (prim == "convert_element_type"
              and entry.max_upcast_elems is not None):
            (invar,) = eqn.invars
            in_dt = getattr(getattr(invar, "aval", None), "dtype", None)
            out_dt = eqn.params.get("new_dtype")
            if in_dt is None or out_dt is None:
                continue
            if str(in_dt) == "int8" and str(out_dt) == "float32":
                shape = getattr(invar.aval, "shape", ())
                n = math.prod(shape) if shape else 1
                if n > entry.max_upcast_elems:
                    out.append(Violation(
                        "HMG101", entry.name, 0,
                        f"slab-scale int8->f32 convert_element_type of "
                        f"shape {tuple(shape)} ({n} elems > budget "
                        f"{entry.max_upcast_elems}) outside the Pallas "
                        "kernel — dequant is leaking into HBM before the "
                        "rescore boundary"))
    return out


def run_trace_rules(names=None) -> List[Violation]:
    """Trace every registry entry and lint its jaxpr."""
    import jax

    out: List[Violation] = []
    for entry in trace_entries():
        if names and entry.name not in names:
            continue
        try:
            fn, args, kwargs = entry.build()
            jaxpr = jax.make_jaxpr(fn)(*args, **kwargs).jaxpr
        except Exception as e:            # a broken entry must fail loudly
            out.append(Violation(
                "HMG101", entry.name, 0,
                f"registry entry failed to trace: {type(e).__name__}: {e}"))
            continue
        out.extend(lint_jaxpr(entry, jaxpr))
    return out
