"""Normalization layers (fp32 statistics, cast back to input dtype)."""
from __future__ import annotations

import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jnp.reciprocal(jnp.sqrt(var + eps))
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jnp.reciprocal(jnp.sqrt(var + eps))
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)
