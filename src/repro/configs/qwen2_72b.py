"""qwen2-72b [dense] — GQA kv=8, QKV bias.  [arXiv:2407.10671; hf]"""
from repro.configs.base import LMConfig
from repro.configs.lm_shapes import lm_shapes

CONFIG = LMConfig(
    arch_id="qwen2-72b",
    source="arXiv:2407.10671; hf",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SHAPES = lm_shapes(long_ok=False)
