"""Continuous-batching scheduler: fixed decode slots, admission queue,
per-slot sequence state (the Orca/vLLM iteration-level scheduling model,
sized for a fixed-shape jitted decode step).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (L,) int32
    max_new_tokens: int = 16
    generated: Optional[List[int]] = None
    done: bool = False


@dataclasses.dataclass
class Slot:
    active: bool = False
    rid: int = -1
    pos: int = 0                       # next position to decode
    remaining: int = 0


class ContinuousBatcher:
    """Admits requests into free slots; evicts finished ones each step."""

    def __init__(self, n_slots: int):
        self.slots = [Slot() for _ in range(n_slots)]
        self.queue: Deque[Request] = deque()
        self.requests: Dict[int, Request] = {}

    def submit(self, req: Request):
        req.generated = []
        self.requests[req.rid] = req
        self.queue.append(req)

    def admit(self) -> List[int]:
        """Fills free slots from the queue; returns newly admitted slot ids."""
        newly = []
        for i, s in enumerate(self.slots):
            if not s.active and self.queue:
                req = self.queue.popleft()
                s.active = True
                s.rid = req.rid
                s.pos = len(req.prompt)
                s.remaining = req.max_new_tokens
                newly.append(i)
        return newly

    def record_tokens(self, tokens: np.ndarray):
        """tokens (n_slots,) — one decoded token per slot this step."""
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            req = self.requests[s.rid]
            req.generated.append(int(tokens[i]))
            s.pos += 1
            s.remaining -= 1
            if s.remaining <= 0:
                req.done = True
                s.active = False

    @property
    def any_active(self) -> bool:
        return any(s.active for s in self.slots) or bool(self.queue)

    def active_mask(self) -> np.ndarray:
        return np.array([s.active for s in self.slots])
