"""Continuous-batching scheduler: fixed decode slots, admission queue,
per-slot sequence state (the Orca/vLLM iteration-level scheduling model,
sized for a fixed-shape jitted decode step).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro import obs


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (L,) int32
    max_new_tokens: int = 16
    generated: Optional[List[int]] = None
    done: bool = False
    submitted_s: float = 0.0           # perf_counter at submit (queue wait)


@dataclasses.dataclass
class Slot:
    active: bool = False
    rid: int = -1
    pos: int = 0                       # next position to decode
    remaining: int = 0


class ContinuousBatcher:
    """Admits requests into free slots; evicts finished ones each step."""

    def __init__(self, n_slots: int):
        self.slots = [Slot() for _ in range(n_slots)]
        self.queue: Deque[Request] = deque()
        self.requests: Dict[int, Request] = {}

    def submit(self, req: Request):
        req.generated = []
        req.submitted_s = time.perf_counter()
        self.requests[req.rid] = req
        self.queue.append(req)
        obs.counter("serving.submitted").inc()
        obs.gauge("serving.queue_depth").set(len(self.queue))

    def admit(self) -> List[int]:
        """Fills free slots from the queue; returns newly admitted slot ids.

        Requests with ``max_new_tokens <= 0`` complete at admission (empty
        ``generated``) and never occupy a slot — a slot would still decode
        one token for them (``remaining`` would go 0 -> -1 only after the
        first ``record_tokens``)."""
        newly = []
        for i, s in enumerate(self.slots):
            if s.active:
                continue
            while self.queue and self.queue[0].max_new_tokens <= 0:
                self.queue.popleft().done = True
            if not self.queue:
                break
            req = self.queue.popleft()
            s.active = True
            s.rid = req.rid
            s.pos = len(req.prompt)
            s.remaining = req.max_new_tokens
            newly.append(i)
            obs.counter("serving.admitted").inc()
            obs.observe_ms("serving.queue_wait",
                           time.perf_counter() - req.submitted_s)
        if newly:
            obs.gauge("serving.queue_depth").set(len(self.queue))
        return newly

    def record_prefill_token(self, slot: int, token: int):
        """The first generated token comes from the prefill logits, before
        any decode step: record it (and possibly finish the request) so the
        generated stream matches sequential per-request decoding exactly.
        ``pos`` stays at the prompt length — that is where this token's KV
        will be written when it is fed to the next decode step."""
        s = self.slots[slot]
        req = self.requests[s.rid]
        req.generated.append(int(token))
        s.remaining -= 1
        if s.remaining <= 0:
            req.done = True
            s.active = False
            obs.counter("serving.evicted").inc()
            obs.counter("serving.completed").inc()

    def record_tokens(self, tokens: np.ndarray):
        """tokens (n_slots,) — one decoded token per slot this step."""
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            req = self.requests[s.rid]
            req.generated.append(int(tokens[i]))
            s.pos += 1
            s.remaining -= 1
            if s.remaining <= 0:
                req.done = True
                s.active = False
                obs.counter("serving.evicted").inc()
                obs.counter("serving.completed").inc()

    @property
    def any_active(self) -> bool:
        return any(s.active for s in self.slots) or bool(self.queue)

    def active_mask(self) -> np.ndarray:
        return np.array([s.active for s in self.slots])


class MaintenanceDriver:
    """Paces adaptive index maintenance between decode steps.

    Serving interleaves ingest with search: without maintenance the delta
    store fills and every query's scan slows; with synchronous compaction a
    full rebuild stalls an entire decode tick. This driver runs
    ``index.maintain(budget=budget_rows)`` — bounded work by construction —
    every ``interval``-th tick, so the ingest-while-search steady state pays
    a small, constant maintenance tax per tick instead of rare large stalls.
    The engine calls ``tick()`` after each decode step; a no-op maintain
    costs one O(K) planning pass.

    When the index is durable (has a ``snapshot()`` method) and
    ``snapshot_interval > 0``, every ``snapshot_interval``-th tick also
    writes a versioned snapshot — bounding crash-recovery replay at roughly
    one snapshot interval's worth of ops. A no-change snapshot is a no-op
    inside ``DurableHMGIIndex.snapshot`` itself."""

    def __init__(self, index, budget_rows: int = 256, interval: int = 4,
                 snapshot_interval: int = 0):
        self.index = index
        self.budget_rows = budget_rows
        self.interval = max(int(interval), 1)
        self.snapshot_interval = max(int(snapshot_interval), 0)
        self.ticks = 0
        self.runs = 0
        self.snapshots = 0
        self.last_report = None

    def tick(self):
        self.ticks += 1
        if self.index is None:
            return None
        if (self.snapshot_interval
                and self.ticks % self.snapshot_interval == 0
                and hasattr(self.index, "snapshot")):
            if self.index.snapshot() is not None:
                self.snapshots += 1
        if self.ticks % self.interval:
            return None
        # "maintenance.stall" is the decode-tick stall this driver causes:
        # the inline maintain() wall time as seen from the serving loop
        # (index.maintain's own histogram counts every pass, including the
        # mutation-path auto-triggers)
        with obs.span("maintenance.stall"):
            self.last_report = self.index.maintain(budget=self.budget_rows)
        self.runs += 1
        return self.last_report
