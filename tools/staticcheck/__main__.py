"""CLI driver: ``python -m tools.staticcheck [paths...]``.

Default run is the AST layer over ``src/repro`` (milliseconds, no jax).
``--trace`` adds the jaxpr rules (HMG101/HMG102), ``--budget`` the
compile-count gate (HMG103), ``--all`` everything; selecting a trace rule
via ``--rule`` implies the layer it lives in. Exit status 0 iff no
violations survive pragma suppression.
"""
from __future__ import annotations

import argparse
import ast
import json
import sys
from pathlib import Path
from typing import List, Optional, Set

from tools.staticcheck import Violation, sort_violations
from tools.staticcheck.astrules import check_source
from tools.staticcheck.pragmas import filter_suppressed, scan_pragmas

REPO_ROOT = Path(__file__).resolve().parents[2]
DEFAULT_PATHS = ("src/repro",)

EXPLAIN = {
    "HMG000": (
        "Pragma discipline. '# staticcheck: disable=RULE (reason)' — the "
        "parenthesised reason is mandatory; a bare disable suppresses "
        "nothing and is itself reported, as is a typo'd rule id. Keeps "
        "the suppression inventory auditable (grep 'staticcheck: "
        "disable')."),
    "HMG001": (
        "No host-sync ops inside traced functions of the hot-path "
        "modules (core/ivf,delta,fusion,traversal, kernels/*, "
        "query/executor). .item(), builtin float()/int() on traced "
        "values, np.* calls and jax.device_get all force a device->host "
        "round trip that serialises the dispatch queue mid-query. Traced "
        "means jit-decorated defs, their nested defs, and local functions "
        "handed to lax.scan/while_loop/cond/fori_loop/vmap; host-side "
        "orchestration in the same files is exempt."),
    "HMG002": (
        "Recompile hazards. Static (shape-like) args of jitted entry "
        "points compile one executable per distinct value; feeding them "
        "data-dependent Python ints (int(...), len(...)) respecialises "
        "per batch. Route the value through pow2_round/pad_to_chunk "
        "(repro.common.shapes) so it takes O(log) distinct values. "
        "Encodes PR 2's pow2-rounded k_scan and PR 5's fixed-(chunk,) "
        "padded drains."),
    "HMG003": (
        "MVCC discipline. Every call into the scan entry points "
        "(ivf.search, search_sharded, search_with_delta[_sharded], "
        "_scan_delta) must spell a visibility kwarg (node_pass= / "
        "mvcc_filter=) explicitly — an explicit =None documents the "
        "opt-out — or carry a reasoned pragma. PRs 2-4 each fixed one "
        "call site that silently returned tombstoned/superseded rows."),
    "HMG004": (
        "Persistence ordering. In persistence/ and checkpoint/: "
        "os.replace/os.rename must be preceded by an fsync in the same "
        "function (publish-after-durable), and WAL appends must precede "
        "the state apply (log-then-apply is the recovery contract). "
        "Encodes PR 6's crash-recovery matrix."),
    "HMG101": (
        "No slab-scale int8->f32 dequant outside the Pallas kernel. The "
        "registry traces each hot entry point at canonical shapes; a "
        "convert_element_type(int8->f32) bigger than the bounded rescore "
        "gather (~2*Q*k*chunk*d elements) means the quantised slab is "
        "being dequantised into HBM before the rescore boundary — the "
        "memory-bandwidth regression the int8 lane exists to avoid. "
        "In-kernel register casts (inside pallas_call) are the design "
        "and are not flagged."),
    "HMG102": (
        "No device_put / host-callback transfer primitives inside traced "
        "regions. Transfers belong at jit boundaries (e.g. the "
        "documented host-level shard gather in search_with_delta_sharded "
        "is fine — it is outside the jit)."),
    "HMG103": (
        "Compile-count budget. The canonical mixed workload (ingest -> "
        "search -> update -> maintain -> search) runs against a fresh "
        "index; distinct compiled signatures per registered entry point "
        "are read off the jit caches and compared to "
        "tools/staticcheck/budgets.json. More signatures than budgeted "
        "fails — the regression gate PRs 2 and 5 needed. Re-baseline "
        "with --write-budgets after intentional changes."),
    "HMG201": (
        "Guarded-by discipline. tools/staticcheck/registry.py GUARDED_BY "
        "declares which shared mutable attributes of concurrent classes "
        "(obs Registry/Histogram, CheckpointManager, Prefetcher, "
        "WorkloadStats, the HMGIIndex modality caches) are protected by "
        "which lock. Any read/write of a registered attribute outside "
        "__init__ must be lexically inside 'with <obj>.<lock>' or a "
        "registered *_locked method (whose call sites must hold the "
        "lock). Double-checked lock-free fast paths carry a reasoned "
        "pragma — grep the pragmas for the complete inventory of "
        "unguarded reads. tools/racecheck.py checks the same contract "
        "dynamically."),
    "HMG202": (
        "No blocking calls under a fine-grained lock: fsync, sleep, "
        "thread/future join/result/wait, block_until_ready, device_get "
        "inside 'with self._lock/_cache_lock' stalls every thread "
        "touching that structure behind the I/O. The coarse "
        "HMGIIndex._write_lock is exempt by design (single-writer: "
        "device work under it IS the serialisation point)."),
    "HMG203": (
        "Lock-order. Nested with-lock blocks plus calls into known "
        "lock-acquiring helpers (LOCK_ACQUIRING_CALLS) form a global "
        "acquisition digraph across all checked files; a cycle is a "
        "potential deadlock and fails the build naming the cycle and "
        "one witness site per edge. Canonical order: "
        "HMGIIndex._write_lock -> HMGIIndex._cache_lock -> leaf locks "
        "(obs, WorkloadStats)."),
    "HMG204": (
        "Publication discipline. A class that spawns worker threads "
        "(Thread/ThreadPoolExecutor/Timer) may not mutate undeclared "
        "self attributes once a thread may be running — in __init__ "
        "after the first start()/submit(), or in any other method. "
        "Declare the attribute (and its lock) in GUARDED_BY so HMG201 "
        "and the dynamic lockset checker cover it."),
}

_AST_RULES = {"HMG000", "HMG001", "HMG002", "HMG003", "HMG004",
              "HMG201", "HMG202", "HMG204"}
_LOCK_ORDER_RULES = {"HMG203"}
_TRACE_RULES = {"HMG101", "HMG102"}
_BUDGET_RULES = {"HMG103"}


def iter_py_files(paths) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        p = Path(p)
        if not p.is_absolute():
            p = REPO_ROOT / p
        if p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            out.append(p)
    return out


def check_files(files: List[Path], rules: Optional[Set[str]],
                fix: bool) -> List[Violation]:
    from tools.staticcheck.fixes import apply_fixes

    out: List[Violation] = []
    trees = []              # (rel, ast.Module) for the cross-file pass
    pragma_index = {}
    for f in files:
        rel = f.relative_to(REPO_ROOT).as_posix() if \
            f.is_relative_to(REPO_ROOT) else f.as_posix()
        source = f.read_text()
        vs = check_source(rel, source, rules)
        if fix:
            fixed, counts = apply_fixes(rel, source, vs)
            if counts:
                f.write_text(fixed)
                print(f"fixed {rel}: " + ", ".join(
                    f"{k} x{n}" for k, n in counts.items()))
                source = fixed
                vs = check_source(rel, source, rules)
        pragmas = scan_pragmas(rel, source)
        pragma_index[rel] = pragmas
        vs = filter_suppressed(vs, pragmas)
        if rules is None or "HMG000" in rules:
            vs = vs + pragmas.violations
        out.extend(vs)
        if rules is None or "HMG203" in rules:
            try:
                trees.append((rel, ast.parse(source, filename=rel)))
            except SyntaxError:
                pass        # already reported as HMG000 by check_source
    if rules is None or "HMG203" in rules:
        from tools.staticcheck.concurrency import check_hmg203
        cyc = check_hmg203(trees)
        out.extend(v for v in cyc
                   if v.path not in pragma_index
                   or not pragma_index[v.path].is_disabled(v.rule, v.line))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.staticcheck",
        description="HMGI repo-invariant static analysis "
                    "(AST lints + jaxpr trace rules + compile budget).")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="files/dirs to lint (default: src/repro)")
    ap.add_argument("--rule", action="append", default=None,
                    metavar="RULE_ID",
                    help="run only these rule ids (repeatable)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit violations as a JSON array")
    ap.add_argument("--explain", metavar="RULE_ID",
                    help="print what a rule enforces and exit")
    ap.add_argument("--fix", action="store_true",
                    help="apply mechanical fixes (pragma normalisation, "
                         "provably-default node_pass=None insertion)")
    ap.add_argument("--trace", action="store_true",
                    help="also run jaxpr trace rules (HMG101/HMG102)")
    ap.add_argument("--budget", action="store_true",
                    help="also run the compile-count budget gate (HMG103)")
    ap.add_argument("--all", action="store_true",
                    help="run every layer (AST + trace + budget)")
    ap.add_argument("--write-budgets", action="store_true",
                    help="measure the canonical workload and rewrite "
                         "budgets.json instead of gating")
    args = ap.parse_args(argv)

    if args.explain:
        rid = args.explain.upper()
        text = EXPLAIN.get(rid)
        if text is None:
            print(f"unknown rule id {rid}; known: "
                  f"{', '.join(sorted(EXPLAIN))}", file=sys.stderr)
            return 2
        print(f"{rid}: {text}")
        return 0

    rules: Optional[Set[str]] = None
    if args.rule:
        rules = {r.strip().upper() for spec in args.rule
                 for r in spec.split(",")}
        unknown = rules - set(EXPLAIN)
        if unknown:
            print(f"unknown rule id(s): {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2

    run_trace = args.trace or args.all or bool(
        rules and rules & _TRACE_RULES)
    run_budget = args.budget or args.all or args.write_budgets or bool(
        rules and rules & _BUDGET_RULES)
    run_ast = not args.write_budgets and (
        rules is None or bool(rules & (_AST_RULES | _LOCK_ORDER_RULES)))

    violations: List[Violation] = []
    if run_ast:
        files = iter_py_files(args.paths)
        violations.extend(check_files(files, rules, args.fix))
    if run_trace:
        from tools.staticcheck.jaxpr_rules import run_trace_rules
        tv = run_trace_rules()
        if rules:
            tv = [v for v in tv if v.rule in rules]
        violations.extend(tv)
    if run_budget:
        from tools.staticcheck.budget import run_budget_rule
        violations.extend(run_budget_rule(write=args.write_budgets))
        if args.write_budgets:
            print("budgets.json rewritten from measured canonical "
                  "workload")

    violations = sort_violations(violations)
    if args.as_json:
        print(json.dumps([v.__dict__ for v in violations], indent=2))
    else:
        for v in violations:
            print(v.format())
        if violations:
            print(f"\n{len(violations)} violation(s). "
                  "Run --explain RULE_ID for the invariant; suppress "
                  "with '# staticcheck: disable=RULE (reason)'.")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
