"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.quantization import quantize
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.ivf_topk.ops import scan_topk_quantized
from repro.kernels.ivf_topk.ref import scan_topk_ref, topk_from_chunks
from repro.kernels.segment_reduce.ops import segment_sum_mm
from repro.kernels.segment_reduce.ref import segment_sum_ref


class TestIvfTopk:
    @pytest.mark.parametrize("n,d,q", [(1024, 64, 8), (2048, 96, 32),
                                       (4096, 128, 16)])
    def test_matches_ref(self, n, d, q, rng):
        v = rng.normal(size=(n, d)).astype(np.float32)
        v /= np.linalg.norm(v, axis=1, keepdims=True)
        qv = quantize(jnp.asarray(v), 8)
        queries = jnp.asarray(v[:q] + 0.01 * rng.normal(size=(q, d)).astype(np.float32))
        cm, ca = scan_topk_ref(queries, qv.data, qv.vmin[:, 0], qv.scale[:, 0])
        rv, ri = topk_from_chunks(cm, ca, 10)
        kv_, ki = scan_topk_quantized(queries, qv.data, qv.vmin[:, 0],
                                      qv.scale[:, 0], jnp.ones((n,), bool), k=10)
        np.testing.assert_allclose(np.asarray(kv_), np.asarray(rv), rtol=2e-5,
                                   atol=1e-5)
        assert np.mean(np.asarray(ki) == np.asarray(ri)) > 0.99

    def test_masking(self, rng):
        n, d = 1024, 64
        v = rng.normal(size=(n, d)).astype(np.float32)
        qv = quantize(jnp.asarray(v), 8)
        valid = jnp.ones((n,), bool).at[jnp.arange(0, n, 7)].set(False)
        kv_, ki = scan_topk_quantized(jnp.asarray(v[:4]), qv.data, qv.vmin[:, 0],
                                      qv.scale[:, 0], valid, k=20)
        dead = np.arange(0, n, 7)
        assert not np.any(np.isin(np.asarray(ki), dead))

    def test_unaligned_n_padding(self, rng):
        n, d = 1900, 64
        v = rng.normal(size=(n, d)).astype(np.float32)
        v /= np.linalg.norm(v, axis=1, keepdims=True)
        qv = quantize(jnp.asarray(v), 8)
        kv_, ki = scan_topk_quantized(jnp.asarray(v[:8]), qv.data, qv.vmin[:, 0],
                                      qv.scale[:, 0], jnp.ones((n,), bool), k=1)
        assert np.array_equal(np.asarray(ki)[:, 0], np.arange(8))


class TestSegmentReduce:
    @pytest.mark.parametrize("e,d,n", [(512, 16, 64), (3000, 48, 300),
                                       (1024, 128, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, e, d, n, dtype, rng):
        msg = jnp.asarray(rng.normal(size=(e, d)).astype(np.float32)).astype(dtype)
        seg = jnp.asarray(rng.integers(-1, n, e).astype(np.int32))
        out_k = segment_sum_mm(msg, seg, n)
        out_r = segment_sum_ref(msg, seg, n)
        tol = 1e-5 if dtype == jnp.float32 else 0.1
        np.testing.assert_allclose(np.asarray(out_k, np.float32),
                                   np.asarray(out_r, np.float32),
                                   rtol=tol, atol=tol)

    def test_unsorted_ids(self, rng):
        msg = jnp.ones((100, 4))
        seg = jnp.asarray(rng.permutation(np.repeat(np.arange(10), 10)).astype(np.int32))
        out = segment_sum_mm(msg, seg, 10)
        np.testing.assert_allclose(np.asarray(out), 10.0)


class TestDecodeAttention:
    @pytest.mark.parametrize("b,hkv,g,hd,s", [(2, 2, 2, 32, 256),
                                              (3, 4, 2, 32, 700),
                                              (1, 8, 8, 64, 1024)])
    def test_matches_ref(self, b, hkv, g, hd, s, rng):
        q = jnp.asarray(rng.normal(size=(b, hkv * g, hd)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)).astype(np.float32))
        valid = jnp.asarray(rng.random((b, s)) > 0.3)
        o_k = decode_attention(q, k, v, valid, block_s=256)
        o_r = decode_attention_ref(q.reshape(b, hkv, g, hd), k, v,
                                   valid).reshape(b, hkv * g, hd)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   rtol=1e-4, atol=1e-5)

    def test_bf16(self, rng):
        b, hkv, g, hd, s = 2, 2, 4, 32, 512
        q = jnp.asarray(rng.normal(size=(b, hkv * g, hd)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)).astype(np.float32))
        valid = jnp.ones((b, s), bool)
        o_k = decode_attention(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                               v.astype(jnp.bfloat16), valid, block_s=128)
        o_r = decode_attention_ref(q.reshape(b, hkv, g, hd), k, v, valid)
        np.testing.assert_allclose(np.asarray(o_k, np.float32),
                                   np.asarray(o_r).reshape(b, hkv * g, hd),
                                   rtol=0.05, atol=0.02)

    def test_fully_masked_rows_are_zero(self, rng):
        b, h, hd, s = 2, 4, 32, 128
        q = jnp.asarray(rng.normal(size=(b, h, hd)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
        valid = jnp.zeros((b, s), bool).at[1].set(True)
        out = decode_attention(q, k, v, valid)
        assert float(jnp.max(jnp.abs(out[0]))) < 1e-6
        assert float(jnp.max(jnp.abs(out[1]))) > 0
