"""Durable index lifecycle: WAL framing + torn tails, snapshot round-trips,
crash recovery bit-identity, graceful degradation, fault-point sweep
(in-process ``mode="raise"``; the subprocess ``kill -9`` sweep lives in
tools/crash_harness.py and runs in the CI durability job)."""
import os
import shutil
import sys
import tempfile

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

import crash_harness as ch  # noqa: E402
from repro.checkpoint import CheckpointError  # noqa: E402
from repro.core.index import HMGIIndex  # noqa: E402
from repro.persistence import (DurableHMGIIndex, OpLog, recover)  # noqa: E402
from repro.persistence import faultpoints  # noqa: E402
from repro.persistence.faultpoints import POINTS, FaultInjected  # noqa: E402
from repro.persistence.snapshot import snapshot_dir, snapshot_steps  # noqa: E402


@pytest.fixture(autouse=True)
def _disarmed():
    faultpoints.disarm()
    yield
    faultpoints.disarm()


@pytest.fixture()
def tmpdir_():
    d = tempfile.mkdtemp(prefix="hmgi_persist_")
    yield d
    shutil.rmtree(d, ignore_errors=True)


class TestOpLog:
    def test_append_scan_roundtrip(self, tmpdir_):
        log = OpLog(tmpdir_)
        a = {"ids": np.arange(5, dtype=np.int32),
             "v": np.random.default_rng(0).standard_normal((5, 3))
                    .astype(np.float32)}
        s1 = log.append("insert", {"modality": "text"}, a)
        s2 = log.append("delete", {"modality": "text"},
                        {"ids": np.arange(2, dtype=np.int64)})
        log.close()
        assert (s1, s2) == (1, 2)
        log2 = OpLog(tmpdir_)
        recs = list(log2.scan())
        assert [r.seq for r in recs] == [1, 2]
        assert recs[0].op == "insert" and recs[0].meta == {"modality": "text"}
        np.testing.assert_array_equal(recs[0].arrays["v"], a["v"])
        assert recs[1].arrays["ids"].dtype == np.int64
        assert not log2.torn_tail

    def test_torn_tail_truncated_on_open(self, tmpdir_):
        log = OpLog(tmpdir_)
        for i in range(3):
            log.append("insert", {"i": i}, {"x": np.arange(i + 1)})
        log.close()
        path = log.segments()[0][1]
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(size - 7)            # tear the last record mid-payload
        log2 = OpLog(tmpdir_)
        recs = list(log2.scan())
        assert [r.meta["i"] for r in recs] == [0, 1] and log2.torn_tail
        log2.open_for_append()
        assert log2.append("insert", {"i": 9}, {}) == 3   # seq continues
        log2.close()
        log3 = OpLog(tmpdir_)
        assert [r.meta["i"] for r in log3.scan()] == [0, 1, 9]
        assert not log3.torn_tail          # the tear was truncated away

    def test_corrupt_mid_record_stops_scan(self, tmpdir_):
        log = OpLog(tmpdir_)
        for i in range(3):
            log.append("insert", {"i": i}, {"x": np.arange(4)})
        log.close()
        path = log.segments()[0][1]
        raw = bytearray(open(path, "rb").read())
        raw[len(raw) // 3] ^= 0xFF          # corrupt the middle record
        with open(path, "wb") as f:
            f.write(raw)
        log2 = OpLog(tmpdir_)
        recs = list(log2.scan())
        assert len(recs) < 3 and log2.torn_tail

    def test_rotate_and_gc(self, tmpdir_):
        log = OpLog(tmpdir_)
        for i in range(4):
            log.append("op", {"i": i}, {})
        log.rotate()                        # wal_5
        for i in range(4, 6):
            log.append("op", {"i": i}, {})
        assert len(log.segments()) == 2
        assert log.gc(4) == 1               # first segment fully ≤ floor
        assert [r.meta["i"] for r in log.scan()] == [4, 5]
        log.close()

    def test_empty_rotated_segment_pins_seq(self, tmpdir_):
        log = OpLog(tmpdir_)
        for _ in range(3):
            log.append("op", {}, {})
        log.rotate()
        log.gc(3)
        log.close()                         # only the empty wal_4 remains
        log2 = OpLog(tmpdir_)
        log2.open_for_append()
        assert log2.append("op", {}, {}) == 4
        log2.close()


class TestFaultPoints:
    def test_unknown_point_rejected(self):
        with pytest.raises(ValueError):
            faultpoints.arm("not.a.point")
        with pytest.raises(ValueError):
            faultpoints.crash_point("not.a.point")

    def test_raise_mode_counts_hits(self, tmpdir_):
        faultpoints.arm("wal.pre_append", hits=2, mode="raise")
        log = OpLog(tmpdir_)
        log.append("op", {}, {})            # hit 1: survives
        with pytest.raises(FaultInjected):
            log.append("op", {}, {})        # hit 2: fires
        log.close()


def _small_cfg():
    return ch.make_cfg()


def _queries():
    return ch.queries()


def _assert_same(a, b):
    ch.assert_bit_identical(a, b, "in-process")


class TestDurableLifecycle:
    def test_fresh_dir_guard(self, tmpdir_):
        cfg = _small_cfg()
        idx = DurableHMGIIndex(cfg, tmpdir_, seed=0)
        ch.apply_ops(idx, ch.scripted_ops(), until=1)
        idx.close()
        with pytest.raises(ValueError, match="recover"):
            DurableHMGIIndex(cfg, tmpdir_, seed=0)

    def test_wal_only_recovery(self, tmpdir_):
        # no snapshot ever written: recovery replays the whole log
        cfg = _small_cfg()
        ops = [e for e in ch.scripted_ops() if e[0] != "snapshot"]
        idx = DurableHMGIIndex(cfg, tmpdir_, seed=0)
        d = ch.apply_ops(idx, ops)
        idx.close()
        rec = recover(cfg, tmpdir_, seed=0)
        assert rec.last_seq == d
        assert "no usable snapshot" in rec.metrics()["recovery"]
        _assert_same(rec, ch.golden_index(cfg, d))
        rec.close()

    def test_snapshot_plus_tail_recovery(self, tmpdir_):
        cfg = _small_cfg()
        idx = DurableHMGIIndex(cfg, tmpdir_, seed=0)
        d = ch.apply_ops(idx, ch.scripted_ops())
        idx.close()
        rec = recover(cfg, tmpdir_, seed=0)
        assert rec.last_seq == d
        assert "snapshot step" in rec.metrics()["recovery"]
        _assert_same(rec, ch.golden_index(cfg, d))
        # recovered index keeps working: mutate + snapshot + recover again
        rec.insert("text", np.arange(300, 310, dtype=np.int32),
                   np.random.default_rng(3).standard_normal((10, 12))
                     .astype(np.float32))
        assert rec.last_seq == d + 1
        rec.snapshot()
        rec.close()
        rec2 = recover(cfg, tmpdir_, seed=0)
        assert rec2.last_seq == d + 1
        _assert_same(rec2, rec)
        rec2.close()

    def test_corrupt_newest_snapshot_degrades_with_warning(self, tmpdir_):
        cfg = _small_cfg()
        idx = DurableHMGIIndex(cfg, tmpdir_, seed=0)
        d = ch.apply_ops(idx, ch.scripted_ops())   # writes 2 snapshots
        idx.close()
        steps = snapshot_steps(tmpdir_)
        assert len(steps) == 2
        leaf = os.path.join(snapshot_dir(tmpdir_), f"step_{steps[-1]:08d}",
                            "leaf_00000.npy")
        raw = bytearray(open(leaf, "rb").read())
        raw[-3] ^= 0xFF
        with open(leaf, "wb") as f:
            f.write(raw)
        rec = recover(cfg, tmpdir_, seed=0)
        trail = rec.metrics()["recovery"]
        assert "WARNING" in trail and f"step {steps[-1]}" in trail
        assert f"snapshot step {steps[0]}" in trail   # fell back to previous
        assert rec.last_seq == d                      # longer replay, same end
        _assert_same(rec, ch.golden_index(cfg, d))
        rec.close()

    def test_config_fingerprint_mismatch_raises(self, tmpdir_):
        cfg = _small_cfg()
        idx = DurableHMGIIndex(cfg, tmpdir_, seed=0)
        ch.apply_ops(idx, ch.scripted_ops())
        idx.close()
        import dataclasses
        other = dataclasses.replace(cfg, quant_bits=4)
        with pytest.raises(CheckpointError, match="fingerprint"):
            recover(other, tmpdir_, seed=0)

    def test_torn_log_tail_recovers_prefix(self, tmpdir_):
        cfg = _small_cfg()
        ops = [e for e in ch.scripted_ops() if e[0] != "snapshot"]
        idx = DurableHMGIIndex(cfg, tmpdir_, seed=0)
        d = ch.apply_ops(idx, ops)
        idx.close()
        seg = sorted(os.listdir(os.path.join(tmpdir_, "wal")))[-1]
        path = os.path.join(tmpdir_, "wal", seg)
        with open(path, "r+b") as f:
            f.truncate(os.path.getsize(path) - 11)
        rec = recover(cfg, tmpdir_, seed=0)
        assert rec.last_seq == d - 1
        assert "truncated" in rec.metrics()["recovery"]
        _assert_same(rec, ch.golden_index(cfg, d - 1))
        rec.close()


class TestFaultSweepInProcess:
    """Every registered crash point, in-process (mode="raise"): the armed
    run dies at the boundary, recovery must be bit-identical to the golden
    prefix. The subprocess kill -9 version of this sweep is
    tools/crash_harness.py (CI durability job)."""

    @pytest.mark.parametrize("point", [p for p in POINTS
                                       if not p.startswith("recover.")])
    def test_crash_then_recover(self, point, tmpdir_):
        cfg = _small_cfg()
        faultpoints.arm(point, hits=ch.DEFAULT_HITS[point], mode="raise")
        idx = DurableHMGIIndex(cfg, tmpdir_, seed=0)
        with pytest.raises(FaultInjected):
            ch.apply_ops(idx, ch.scripted_ops())
        faultpoints.disarm()
        idx.close()
        rec = recover(cfg, tmpdir_, seed=0)
        d = rec.last_seq
        _assert_same(rec, ch.golden_index(cfg, d))
        rec.close()

    def test_crash_mid_replay_then_recover(self, tmpdir_):
        cfg = _small_cfg()
        idx = DurableHMGIIndex(cfg, tmpdir_, seed=0)
        d = ch.apply_ops(idx, ch.scripted_ops())
        idx.close()
        faultpoints.arm("recover.mid_replay", hits=2, mode="raise")
        with pytest.raises(FaultInjected):
            recover(cfg, tmpdir_, seed=0)
        faultpoints.disarm()
        rec = recover(cfg, tmpdir_, seed=0)   # replay is re-runnable
        assert rec.last_seq == d
        _assert_same(rec, ch.golden_index(cfg, d))
        rec.close()


class TestServingIntegration:
    def test_maintenance_driver_snapshot_pacing(self, tmpdir_):
        from repro.serving.scheduler import MaintenanceDriver
        cfg = _small_cfg()
        idx = DurableHMGIIndex(cfg, tmpdir_, seed=0)
        ch.apply_ops(idx, ch.scripted_ops(), until=2)
        # maintenance interval 10 never fires in 6 ticks, so no new ops land
        # between the pacing snapshots: tick 3 writes, tick 6 is a no-op
        drv = MaintenanceDriver(idx, budget_rows=64, interval=10,
                                snapshot_interval=3)
        for _ in range(6):
            drv.tick()
        assert drv.snapshots == 1
        assert snapshot_steps(tmpdir_)
        idx.close()

    def test_plain_index_ignores_snapshot_pacing(self):
        from repro.serving.scheduler import MaintenanceDriver
        cfg = _small_cfg()
        idx = HMGIIndex(cfg, seed=0)
        ch.apply_ops(idx, ch.scripted_ops(), until=1)
        drv = MaintenanceDriver(idx, budget_rows=64, interval=2,
                                snapshot_interval=1)
        for _ in range(4):
            drv.tick()                     # no snapshot() attr: no crash
        assert drv.snapshots == 0
