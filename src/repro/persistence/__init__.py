"""Durable index lifecycle: versioned snapshots + write-ahead op log +
crash recovery (docs/DESIGN.md §7).

- ``faultpoints`` — named crash points at every durability boundary
- ``oplog`` — CRC-framed, segmented, fsync-batched append-only op log
- ``snapshot`` — full-HMGIIndex-state snapshots via the checkpoint substrate
- ``durable`` — ``DurableHMGIIndex`` (log-then-apply facade) + ``recover``

Import hygiene: ``repro.checkpoint`` imports ``faultpoints`` from this
package, and ``durable`` imports ``repro.checkpoint`` — so the package
``__init__`` re-exports lazily (PEP 562) to keep the import graph acyclic.
"""
from repro.persistence import faultpoints  # noqa: F401  (dependency-free)

_LAZY = {
    "DurableHMGIIndex": "repro.persistence.durable",
    "recover": "repro.persistence.durable",
    "replay_op": "repro.persistence.durable",
    "OpLog": "repro.persistence.oplog",
    "config_fingerprint": "repro.persistence.snapshot",
}

__all__ = ["faultpoints", *_LAZY]


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
