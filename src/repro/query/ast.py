"""Logical query AST + the fluent ``Q`` builder — HMGI's declarative hybrid
query surface (the NaviX / TigerVector query class: vector stages, graph
traversals, relational predicates, and set operations composing freely).

A *plan* is a chain: a source (a ``VectorSeed`` scan or a ``SetOp`` over two
sub-plans) followed by stages (``Traverse``, ``CrossModal``), optionally
constrained by ``Where`` predicates and terminated by ``.topk(k)`` (stored
as ``Plan.k``). Nothing here
touches the index — compilation to physical stages (probe widths, predicate
pushdown vs post-filter, sparse vs dense fusion) happens in
``repro/query/planner.py``; execution in ``repro/query/executor.py``.

``Where`` is declarative and position-independent within its chain: all
predicates of a chain conjoin and constrain *every* stage of that chain —
the seed scan (pushdown or planned oversampling), traversal routing
(excluded nodes neither receive nor forward mass) and candidate surfacing —
exactly the semantics of the facade's ``where=``. A chain whose source is a
``SetOp`` applies its own predicates to the merged candidate set as a
post-filter (each branch carries its own ``Where`` scope) and to every later
stage.

    from repro.query import Q
    plan = (Q.vector("text", q)
              .where(("year", ">", 2020))
              .traverse(2, edge_types=(AUTHORED,))
              .topk(10))
    scores, ids = index.query(plan)
    print(index.explain(plan))     # the compiled physical plan
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple, Union


@dataclasses.dataclass(frozen=True, eq=False)
class VectorSeed:
    """ANNS seed scan: top candidates for ``query`` in ``modality``.

    n_probe: partitions probed (None -> planner: cost-model choice via
    ``min_recall`` when given, else the config default)."""
    modality: str
    query: Any                          # (Q, d) array-like
    n_probe: Optional[int] = None
    min_recall: Optional[float] = None
    impl: str = "auto"                  # IVF probe path: kernel | einsum | auto


@dataclasses.dataclass(frozen=True, eq=False)
class Traverse:
    """h-hop typed traversal from the current candidate set, fused back into
    the candidate scores (Eq. 3). ``edge_types`` is an iterable of edge-type
    ids (Cypher's ``[:REL_TYPE]``) or a prebuilt (T,) mask array; None = all
    types. hops=None -> config ``max_hops``."""
    hops: Optional[int] = None
    edge_types: Any = None
    damping: float = 0.85


@dataclasses.dataclass(frozen=True, eq=False)
class Where:
    """Relational predicates, (column, op, value) tuples AND-combined with
    every other Where of the chain (see graph_store.NodeAttributes)."""
    predicates: Tuple[Any, ...]


@dataclasses.dataclass(frozen=True, eq=False)
class CrossModal:
    """Re-score the current candidate set in a second modality's embedding
    space: new = (1-weight)·current + weight·sim(query2, emb_modality[id]).
    Candidates without an embedding in ``modality`` keep only the
    (1-weight)·current term (their cross-modal similarity reads as 0)."""
    modality: str
    query: Any
    weight: float = 0.5


@dataclasses.dataclass(frozen=True, eq=False)
class SetOp:
    """Candidate-set combinator over two sub-plans.

    union:     ids from either side; duplicate ids keep the higher score.
    intersect: ids present on both sides; score = mean of the two."""
    kind: str                 # "union" | "intersect"
    left: "Plan"
    right: "Plan"


Source = Union[VectorSeed, SetOp]


@dataclasses.dataclass(frozen=True, eq=False)
class Plan:
    source: Source
    stages: Tuple[Any, ...] = ()
    k: Optional[int] = None           # terminal TopK (None -> cfg.top_k)


def _norm_predicates(predicates) -> Tuple[Any, ...]:
    """Accepts the facade's ``where`` spellings: one (col, op, value) tuple,
    a sequence of them, or None."""
    if not predicates:
        return ()
    out = []
    for p in predicates:
        if p is None:
            continue
        if isinstance(p, tuple) and len(p) == 3 and isinstance(p[0], str):
            out.append(p)
        else:
            out.extend(q for q in p if q is not None)
    return tuple(out)


class Q:
    """Fluent plan builder. Start with ``Q.vector`` (or combine plans with
    ``Q.union`` / ``Q.intersect``), chain stages, finish with ``.topk(k)``."""

    __slots__ = ("plan",)

    def __init__(self, plan: Plan):
        self.plan = plan

    # ------------------------------------------------------------- sources
    @classmethod
    def vector(cls, modality: str, query, *, n_probe: Optional[int] = None,
               min_recall: Optional[float] = None, impl: str = "auto") -> "Q":
        """ANNS seed source. query: (Q, d_modality) array-like (the planner
        L2-normalises). n_probe: partitions probed (None -> cost model via
        min_recall when given, else cfg default; always clamped to the live
        partition count). impl: IVF probe path ("kernel"/"einsum"/"auto")."""
        return cls(Plan(VectorSeed(modality, query, n_probe, min_recall,
                                   impl)))

    @staticmethod
    def union(a: "Q", b: "Q") -> "Q":
        """Candidate-set union of two plans: ids from either side, duplicate
        ids keep the higher score."""
        return Q(Plan(SetOp("union", a.plan, b.plan)))

    @staticmethod
    def intersect(a: "Q", b: "Q") -> "Q":
        """Candidate-set intersection: ids present on both sides, score =
        mean of the two sides' scores."""
        return Q(Plan(SetOp("intersect", a.plan, b.plan)))

    # -------------------------------------------------------------- stages
    def _append(self, stage) -> "Q":
        return Q(dataclasses.replace(self.plan,
                                     stages=self.plan.stages + (stage,)))

    def traverse(self, hops: Optional[int] = None, *, edge_types=None,
                 damping: float = 0.85) -> "Q":
        """h-hop graph traversal from the current candidates, fused back by
        Eq. 3. hops=None -> cfg.max_hops; edge_types: edge-type ids or a
        prebuilt (T,) mask (None = all types)."""
        return self._append(Traverse(hops, edge_types, damping))

    def where(self, *predicates) -> "Q":
        """Relational constraint: (column, op, value) tuples (or sequences
        thereof), AND-conjoined with every other Where of the chain and
        enforced at every stage. A no-op with no predicates."""
        preds = _norm_predicates(predicates)
        if not preds:
            return self
        return self._append(Where(preds))

    def cross_modal(self, modality: str, query, *, weight: float = 0.5) -> "Q":
        """Width-preserving re-score in a second modality's embedding space:
        new = (1-weight)·current + weight·sim(query, emb[id]); candidates
        without a (live) embedding there read sim = 0."""
        return self._append(CrossModal(modality, query, weight))

    def topk(self, k: int) -> "Q":
        """Terminal width: execution returns (scores (Q, k), ids (Q, k)),
        scores descending, (-inf, -1) on empty slots."""
        return Q(dataclasses.replace(self.plan, k=int(k)))
