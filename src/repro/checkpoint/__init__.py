from repro.checkpoint.checkpoint import (
    CheckpointManager, restore_checkpoint, save_checkpoint,
)
