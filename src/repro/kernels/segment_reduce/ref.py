"""Pure-jnp oracle for segment_reduce."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_ref(messages, seg_ids, n_segments: int):
    ok = jnp.logical_and(seg_ids >= 0, seg_ids < n_segments)
    msg = jnp.where(ok[:, None], messages, 0)
    seg = jnp.where(ok, seg_ids, 0)
    return jax.ops.segment_sum(msg, seg, num_segments=n_segments)
