"""``DurableHMGIIndex`` — the log-then-apply facade — and ``recover``.

Every mutating facade call (``ingest``/``insert``/``delete``/``maintain``/
``compact``/``maybe_repartition``/``set_attributes``) appends one op record
to the write-ahead log *before* applying it, so

    recover(cfg, data_dir)  =  latest valid snapshot + replay of the log
                               tail (seq > snapshot.last_seq)

yields search results **bit-identical** to an uninterrupted run of the
durable op prefix, no matter where the process died (the fault-injection
sweep in tools/crash_harness.py asserts this at every registered crash
point).

Replay determinism (docs/DESIGN.md §7.2):

- All device math is deterministic given identical inputs, and op records
  carry the facade call's inputs byte-exactly.
- PRNG: every key consumer (k-means builds, splits, NSW refreshes) runs
  inside a logged op, so ``self.key`` advances identically on replay and is
  snapshotted as state.
- Workload heat is the one signal written by *searches* (which are not
  logged): each op record stamps every modality's probe-heat counters at
  call time, and replay injects them before applying — the maintenance
  planner sees exactly the statistics it saw live. Search results never
  depend on heat, so recovered searches are bit-identical even though
  post-recovery heat restarts from the last op's stamp.
- Nested triggers (``insert`` auto-running ``maintain``) are *part of* the
  outer op: the reentrancy guard logs only top-level facade calls, so a
  maintenance drain is one atomic log record — replay re-derives the inner
  work, never half of it.

Graceful degradation: a corrupt newest snapshot (bad leaf checksum, torn
manifest) falls back to the previous snapshot plus a longer replay, with a
warning surfaced in ``metrics()["recovery"]``. A config-fingerprint
mismatch raises instead — replaying state under a different config would
silently reinterpret bytes.
"""
from __future__ import annotations

import contextlib
import os
from typing import Dict, Optional

import numpy as np

from repro import obs
from repro.checkpoint.checkpoint import CheckpointError
from repro.core.index import HMGIIndex
from repro.persistence import snapshot as snapshot_mod
from repro.persistence.faultpoints import crash_point
from repro.persistence.oplog import OpLog, OpRecord


def _np32(x, dtype):
    return np.ascontiguousarray(np.asarray(x, dtype))


class DurableHMGIIndex(HMGIIndex):
    """An ``HMGIIndex`` whose every mutation is durable.

    Reads (``search``/``hybrid_search``/``query``/``explain``/``metrics``)
    are inherited untouched — durability costs nothing on the read path.
    ``set_sparse_docs`` is snapshot-only state (not op-logged): re-set it
    after recovery or snapshot after setting it.
    """

    def __init__(self, cfg, data_dir: str, mesh=None, seed: int = 0,
                 _recovering: bool = False):
        super().__init__(cfg, mesh=mesh, seed=seed)
        self.data_dir = data_dir
        self._in_op = False
        os.makedirs(data_dir, exist_ok=True)
        self._log = OpLog(snapshot_mod.wal_dir(data_dir),
                          sync_every=cfg.wal_sync_every)
        self._last_snapshot_seq = -1
        if not _recovering:
            if snapshot_mod.snapshot_steps(data_dir) or self._log.segments():
                raise ValueError(
                    f"{data_dir} already holds durable state — a fresh "
                    "DurableHMGIIndex would fork it; use "
                    "persistence.recover(cfg, data_dir) instead")
            self._log.open_for_append()

    # --------------------------------------------------------- log-then-apply
    @contextlib.contextmanager
    def _logged_op(self, op: str, meta: dict, arrays: Dict[str, np.ndarray]):
        heat = {f"heat/{mod}": np.asarray(m.workload.hits).copy()
                for mod, m in self.modalities.items()
                if m.workload is not None}
        self._log.append(op, meta, {**arrays, **heat})
        self._in_op = True
        try:
            yield
        finally:
            self._in_op = False

    def ingest(self, embeddings, n_nodes, edges=None, build_nsw=False,
               node_attrs=None):
        if self._in_op:
            return super().ingest(embeddings, n_nodes, edges=edges,
                                  build_nsw=build_nsw, node_attrs=node_attrs)
        emb = {mod: (_np32(ids, np.int32), _np32(vecs, np.float32))
               for mod, (ids, vecs) in embeddings.items()}
        arrays: Dict[str, np.ndarray] = {}
        for mod, (ids, vecs) in emb.items():
            arrays[f"emb/{mod}/ids"] = ids
            arrays[f"emb/{mod}/vecs"] = vecs
        meta = {"n_nodes": int(n_nodes), "modality_order": list(emb),
                "build_nsw": bool(build_nsw), "edges": None, "attrs": None}
        if edges is not None:
            arrays["edges/src"] = _np32(edges[0], np.int32)
            arrays["edges/dst"] = _np32(edges[1], np.int32)
            meta["edges"] = {"type": len(edges) > 2, "weight": len(edges) > 3}
            if len(edges) > 2:
                arrays["edges/type"] = _np32(edges[2], np.int32)
            if len(edges) > 3:
                arrays["edges/weight"] = _np32(edges[3], np.float32)
        if node_attrs is not None:
            meta["attrs"] = list(node_attrs)
            for name, col in node_attrs.items():
                arrays[f"attr/{name}"] = _np32(col, np.int32)
        with self._logged_op("ingest", meta, arrays):
            return _apply_ingest(self, meta, arrays)

    def insert(self, modality, ids, vectors):
        if self._in_op:
            return super().insert(modality, ids, vectors)
        ids_np = _np32(ids, np.int32)
        v_np = _np32(vectors, np.float32)
        with self._logged_op("insert", {"modality": modality},
                             {"ids": ids_np, "vectors": v_np}):
            return super().insert(modality, ids_np, v_np)

    def delete(self, modality, ids):
        if self._in_op:
            return super().delete(modality, ids)
        ids_np = _np32(ids, np.int32)
        with self._logged_op("delete", {"modality": modality},
                             {"ids": ids_np}):
            return super().delete(modality, ids_np)

    def maintain(self, modality=None, budget=None, *, need_rows=0):
        if self._in_op:
            return super().maintain(modality, budget, need_rows=need_rows)
        meta = {"modality": modality,
                "budget": None if budget is None else int(budget),
                "need_rows": int(need_rows)}
        with self._logged_op("maintain", meta, {}):
            return super().maintain(modality, budget, need_rows=need_rows)

    def compact(self, modality):
        if self._in_op:
            return super().compact(modality)
        with self._logged_op("compact", {"modality": modality}, {}):
            return super().compact(modality)

    def maybe_repartition(self, modality):
        if self._in_op:
            return super().maybe_repartition(modality)
        with self._logged_op("repartition", {"modality": modality}, {}):
            return super().maybe_repartition(modality)

    def set_attributes(self, node_attrs):
        if self._in_op:
            return super().set_attributes(node_attrs)
        arrays = {f"attr/{name}": _np32(col, np.int32)
                  for name, col in node_attrs.items()}
        with self._logged_op("set_attributes",
                             {"columns": list(node_attrs)}, arrays):
            return super().set_attributes(
                {n: arrays[f"attr/{n}"] for n in node_attrs})

    # -------------------------------------------------------------- snapshots
    @property
    def last_seq(self) -> int:
        return self._log.last_seq

    def snapshot(self) -> Optional[str]:
        """Writes one versioned snapshot of the current state, prunes to
        ``cfg.snapshot_keep``, rotates the log, and unlinks segments no
        retained snapshot needs. No-op (returns None) when nothing changed
        since the last snapshot."""
        self._log.sync()
        seq = self._log.last_seq
        if seq == self._last_snapshot_seq:
            return None
        path = snapshot_mod.write_snapshot(self.data_dir, self, seq)
        self._last_snapshot_seq = seq
        floor = snapshot_mod.prune_snapshots(self.data_dir,
                                             self.cfg.snapshot_keep)
        self._log.rotate(seq + 1)
        if floor is not None:
            self._log.gc(floor)
        return path

    def close(self) -> None:
        self._log.close()


# ---------------------------------------------------------------------------
# replay
# ---------------------------------------------------------------------------

def _apply_ingest(index: HMGIIndex, meta: dict, arrays: dict):
    emb = {mod: (arrays[f"emb/{mod}/ids"], arrays[f"emb/{mod}/vecs"])
           for mod in meta["modality_order"]}
    edges = None
    if meta["edges"] is not None:
        edges = [arrays["edges/src"], arrays["edges/dst"]]
        if meta["edges"]["type"]:
            edges.append(arrays["edges/type"])
        if meta["edges"]["weight"]:
            edges.append(arrays["edges/weight"])
        edges = tuple(edges)
    attrs = ({name: arrays[f"attr/{name}"] for name in meta["attrs"]}
             if meta["attrs"] is not None else None)
    return index.ingest(emb, meta["n_nodes"], edges=edges,
                        build_nsw=meta["build_nsw"], node_attrs=attrs)


def replay_op(index: HMGIIndex, rec: OpRecord) -> None:
    """Applies one logged op to ``index`` — the exact computation the live
    call ran: heat counters are injected first (the op's stamped values),
    and on a durable index the reentrancy guard is held so replay never
    re-logs. Works on a plain ``HMGIIndex`` too (the crash harness's golden
    runs replay the durable prefix into a fresh in-memory index)."""
    for key, arr in rec.arrays.items():
        if key.startswith("heat/"):
            m = index.modalities.get(key[len("heat/"):])
            if m is not None and m.workload is not None:
                m.workload.hits[:] = arr
    guarded = hasattr(index, "_in_op")
    prev = index._in_op if guarded else None
    if guarded:
        index._in_op = True
    try:
        op, meta = rec.op, rec.meta
        if op == "ingest":
            _apply_ingest(index, meta, rec.arrays)
        elif op == "insert":
            index.insert(meta["modality"], rec.arrays["ids"],
                         rec.arrays["vectors"])
        elif op == "delete":
            index.delete(meta["modality"], rec.arrays["ids"])
        elif op == "maintain":
            index.maintain(meta["modality"], meta["budget"],
                           need_rows=meta["need_rows"])
        elif op == "compact":
            index.compact(meta["modality"])
        elif op == "repartition":
            index.maybe_repartition(meta["modality"])
        elif op == "set_attributes":
            index.set_attributes({n: rec.arrays[f"attr/{n}"]
                                  for n in meta["columns"]})
        else:
            raise ValueError(f"unknown op record {op!r} at seq {rec.seq}")
    finally:
        if guarded:
            index._in_op = prev


def recover(cfg, data_dir: str, mesh=None, seed: int = 0) -> DurableHMGIIndex:
    """Restart-and-recover: latest valid snapshot + log-tail replay.

    Snapshots are tried newest-first; one that fails validation (corrupt
    leaf, torn manifest) is skipped with a warning and the previous one
    carries a longer replay — recovery only fails outright when the config
    fingerprint mismatches (wrong-config state must never load silently).
    With no usable snapshot the whole log replays from the initial ingest.
    The recovery trail (snapshot used, ops replayed, warnings) is surfaced
    in ``metrics()["recovery"]``."""
    idx = DurableHMGIIndex(cfg, data_dir, mesh=mesh, seed=seed,
                           _recovering=True)
    warnings = []
    base_seq = 0
    loaded = None
    for step in reversed(snapshot_mod.snapshot_steps(data_dir)):
        try:
            tree, meta, last_seq = snapshot_mod.read_snapshot(
                data_dir, cfg, step)
        except CheckpointError as e:
            if "config fingerprint" in e.reason:
                raise
            warnings.append(f"snapshot step {step} unusable ({e.reason}); "
                            "falling back")
            continue
        idx.restore_state(tree, meta)
        base_seq, loaded = last_seq, step
        break
    replayed = 0
    with obs.span("recovery.replay"):
        for rec in idx._log.scan(min_seq=base_seq):
            crash_point("recover.mid_replay")
            replay_op(idx, rec)
            replayed += 1
    obs.gauge("recovery.replayed_ops").set(replayed)
    if idx._log.torn_tail:
        warnings.append(
            f"op log tail truncated after seq {idx._log.last_seq} "
            "(torn record from an interrupted append)")
    idx._log.open_for_append()
    # the snapshot can be ahead of every surviving log record (the segments
    # it superseded were GC'd; the fresh one is empty) — new appends must
    # continue after it, never reuse sequence numbers
    idx._log.last_seq = max(idx._log.last_seq, base_seq)
    idx._last_snapshot_seq = base_seq if loaded is not None else -1
    trail = (f"recovered from "
             + (f"snapshot step {loaded}" if loaded is not None
                else "empty (no usable snapshot)")
             + f" + {replayed} replayed ops (seq {base_seq} -> "
             + f"{idx._log.last_seq})")
    if warnings:
        trail += "; WARNING: " + "; ".join(warnings)
    idx._metrics["recovery"] = trail
    return idx
