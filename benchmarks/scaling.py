"""Scalability benchmarks (paper §4.5): corpus-size scan (sub-linear IVF
query time vs linear brute force) and update-churn uptime behaviour."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import timeit
from repro.core import ivf as ivf_mod
from repro.data.synthetic import make_corpus


def run(report):
    key = jax.random.PRNGKey(0)
    d = 64
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(32, d)).astype(np.float32))
    for n in (2048, 8192, 32768):
        v = rng.normal(size=(n, d)).astype(np.float32)
        v /= np.linalg.norm(v, axis=1, keepdims=True)
        kparts = int(np.sqrt(n))
        idx, _ = ivf_mod.build(key, jnp.asarray(v), jnp.arange(n),
                               n_partitions=kparts, bits=8)
        t_ivf = timeit(lambda: ivf_mod.search(idx, q, n_probe=8, k=10), trials=3)
        t_bf = timeit(lambda: ivf_mod.brute_force(
            jnp.asarray(v), jnp.ones((n,), bool), jnp.arange(n), q, k=10),
            trials=3)
        report(f"scale_ivf_n{n}", t_ivf / 32 * 1e6,
               f"bruteforce_us={t_bf/32*1e6:.1f} ratio={t_bf/t_ivf:.2f}x "
               f"scanned={8/kparts:.3f}")
