"""Process-global metrics registry: counters, gauges, histograms.

Everything here is host-side Python — metrics are recorded from
orchestration code only (facade methods, schedulers, the WAL), never from
inside traced/jitted functions, so instrumentation can stay always-on
without perturbing compiled programs (HMG001/HMG102 stay clean by
construction: there is nothing jitted in this package to flag).

Design:

- **Counter** — monotone float/int total (``inc``).
- **Gauge** — last-write-wins scalar (``set``).
- **Histogram** — fixed cumulative buckets (Prometheus exposition) *plus* a
  bounded ring of raw samples for exact quantiles: ``percentile(p)`` is
  numpy-exact over the retained window (the newest ``window`` observations;
  all of them while ``count <= window``). Fixed buckets alone would round
  p99 to a bucket edge; raw-sample quantiles alone would not export — the
  pair gives both at O(1) memory.
- **MetricsRegistry** — name -> metric, created on first touch. One
  process-global instance behind ``registry()``; ``reset()`` drops all
  metrics (tests), ``set_enabled(False)`` turns every record call into a
  cheap no-op (the serving load bench's uninstrumented baseline).

Thread-safety: the serving load bench records from N streams concurrently.
Metric creation takes the registry lock; each histogram serialises its
``observe`` on its own lock (counters/gauges ride the GIL for their single
attribute update, with the lock only on read-modify-write paths that need
exactness across threads — ``inc``).
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# log-spaced latency buckets (milliseconds): 50µs .. 10s. Span-fed
# histograms record ms; count-valued histograms (batch sizes, occupancy)
# pass their own buckets.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0,
    250.0, 500.0, 1000.0, 2500.0, 10000.0, float("inf"))

# power-of-two-ish buckets for count-valued histograms (group-commit batch
# sizes, decode batch occupancy, rows per maintenance action)
COUNT_BUCKETS: Tuple[float, ...] = (
    1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 4096, float("inf"))

DEFAULT_WINDOW = 4096


class Counter:
    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        if not _ENABLED:
            return
        with self._lock:
            self.value += n


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        if not _ENABLED:
            return
        self.value = float(v)


class Histogram:
    """Fixed cumulative buckets + exact quantiles over a sample window."""

    __slots__ = ("name", "bounds", "bucket_counts", "count", "total",
                 "vmax", "_window", "_wpos", "_lock")

    def __init__(self, name: str,
                 buckets: Sequence[float] = DEFAULT_BUCKETS,
                 window: int = DEFAULT_WINDOW):
        bounds = tuple(float(b) for b in buckets)
        if bounds != tuple(sorted(bounds)) or bounds[-1] != float("inf"):
            raise ValueError("histogram buckets must ascend and end at +inf")
        self.name = name
        self.bounds = bounds
        self.bucket_counts = [0] * len(bounds)
        self.count = 0
        self.total = 0.0
        self.vmax = float("-inf")
        self._window: List[float] = []
        self._wpos = 0                   # ring write index once saturated
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        if not _ENABLED:
            return
        v = float(v)
        with self._lock:
            self.bucket_counts[bisect.bisect_left(self.bounds, v)] += 1
            self.count += 1
            self.total += v
            if v > self.vmax:
                self.vmax = v
            if len(self._window) < DEFAULT_WINDOW:
                self._window.append(v)
            else:
                self._window[self._wpos] = v
                self._wpos = (self._wpos + 1) % DEFAULT_WINDOW

    # ----------------------------------------------------------------- readout
    def samples(self) -> np.ndarray:
        with self._lock:
            return np.asarray(self._window, dtype=np.float64)

    def percentile(self, p: float) -> float:
        """Exact (numpy linear-interpolation) quantile over the retained
        window — all observations while ``count <= window``, else the
        newest ``window`` of them. NaN with no samples."""
        s = self.samples()
        if s.size == 0:
            return float("nan")
        return float(np.percentile(s, p))

    def cumulative_buckets(self) -> List[Tuple[float, int]]:
        """Prometheus-style ``[(le, cumulative_count)]`` (last le = +inf)."""
        with self._lock:
            counts = list(self.bucket_counts)
        out, running = [], 0
        for le, c in zip(self.bounds, counts):
            running += c
            out.append((le, running))
        return out

    def summary(self) -> Dict[str, float]:
        # one lock acquisition for a coherent snapshot; percentiles are
        # computed outside it (a nested samples() would deadlock on the
        # non-reentrant Lock, and np.percentile needn't stall writers)
        with self._lock:
            count = self.count
            total = self.total
            vmax = self.vmax
            window = np.asarray(self._window, dtype=np.float64)
        pct = (lambda p: float(np.percentile(window, p))) \
            if window.size else (lambda p: float("nan"))
        return {
            "count": count,
            "sum": total,
            "max": vmax if count else float("nan"),
            "p50": pct(50),
            "p90": pct(90),
            "p99": pct(99),
        }


class MetricsRegistry:
    """name -> metric. Metrics are created on first touch and live for the
    process (or until ``reset``); touching an existing name returns the
    same object, so call sites never need to pre-register."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name)
            return g

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(
                    name, buckets or DEFAULT_BUCKETS)
            return h

    # ------------------------------------------------------------------ export
    def counters(self) -> Dict[str, Counter]:
        with self._lock:
            return dict(self._counters)

    def gauges(self) -> Dict[str, Gauge]:
        with self._lock:
            return dict(self._gauges)

    def histograms(self) -> Dict[str, Histogram]:
        with self._lock:
            return dict(self._histograms)

    def to_dict(self) -> Dict[str, Dict[str, object]]:
        """JSON-able snapshot: counter/gauge values, histogram summaries
        (count, sum, max, exact p50/p90/p99). The ``obs`` section of
        ``HMGIIndex.metrics()`` and the ``--metrics-out`` dump."""
        return {
            "counters": {n: c.value for n, c in self.counters().items()},
            "gauges": {n: g.value for n, g in self.gauges().items()},
            "histograms": {n: h.summary()
                           for n, h in self.histograms().items()},
        }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


# ---------------------------------------------------------------------------
# process-global instance + enable switch
# ---------------------------------------------------------------------------

_ENABLED = True
_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    return _REGISTRY


def set_enabled(on: bool) -> None:
    """Global kill switch: with ``on=False`` every ``inc``/``set``/
    ``observe`` returns after one boolean check — the serving load bench's
    uninstrumented baseline mode."""
    global _ENABLED
    _ENABLED = bool(on)


def enabled() -> bool:
    return _ENABLED
