"""Open-loop serving load benchmark: QPS and p50/p99 vs concurrency.

The ROADMAP's serving deliverable: drive the query facade with N
concurrent client streams issuing single-query searches at scheduled
arrival times (open loop — arrivals do not wait for completions, so queue
wait is part of latency, the way a latency SLO sees it), and report
throughput and tail latency **from the obs registry**: each request's
latency is observed into the ``serving.request_ms`` histogram and the
reported p50/p99 are that histogram's exact-quantile readout.

Arrival pacing: the single-stream mean service time is calibrated first;
each stream then offers ``utilization / (t_service * max_streams)`` QPS,
so offered load grows linearly with the stream count and reaches
``utilization`` of single-device capacity at the largest level — low
levels measure un-queued latency, the top level measures queueing near
saturation. JAX releases the GIL during device execution, so
thread-per-stream genuinely overlaps dispatch with device work.

Also prints the instrumentation overhead check: single-stream query p50
with the obs layer enabled (tracing off — the always-on configuration)
vs fully disabled (``obs.set_enabled(False)``), interleaved A/B rounds to
cancel drift. The enabled p50 must stay within ~5% of the disabled one
for "cheap enough to leave always-on" to hold.

    PYTHONPATH=src python benchmarks/serving_load_bench.py \
        --streams 1,8,64 --duration 5
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np
import jax

from repro import obs

try:
    from benchmarks.common import (build_hmgi, load_corpus, make_queries,
                                   primary_mod)
except ImportError:                     # script-style invocation
    from common import build_hmgi, load_corpus, make_queries, primary_mod

REQUEST_HIST = "serving.request_ms"


def _one_query(index, q1, modality, k):
    sv, si = index.search(q1, modality, k=k)
    jax.block_until_ready(sv)
    return sv, si


def calibrate(index, queries, modality, k, warmup=8, trials=32) -> float:
    """Mean single-stream service seconds per request (after compile)."""
    for i in range(warmup):
        _one_query(index, queries[i % len(queries)][None], modality, k)
    t0 = time.perf_counter()
    for i in range(trials):
        _one_query(index, queries[i % len(queries)][None], modality, k)
    return (time.perf_counter() - t0) / trials


def overhead_check(index, queries, modality, k, rounds=6, per_round=24):
    """Interleaved A/B: p50 with obs enabled vs disabled, measured with
    identical host timers. Returns (enabled_p50_ms, disabled_p50_ms)."""
    lat = {True: [], False: []}
    try:
        for r in range(rounds):
            for enabled in (True, False) if r % 2 == 0 else (False, True):
                obs.set_enabled(enabled)
                for i in range(per_round):
                    q1 = queries[(r * per_round + i) % len(queries)][None]
                    t0 = time.perf_counter()
                    _one_query(index, q1, modality, k)
                    lat[enabled].append(time.perf_counter() - t0)
    finally:
        obs.set_enabled(True)
    return (float(np.percentile(lat[True], 50)) * 1e3,
            float(np.percentile(lat[False], 50)) * 1e3)


def run_level(index, queries, modality, k, n_streams, duration_s,
              interval_s, check_ref=None) -> dict:
    """One concurrency level: n_streams open-loop clients for duration_s.
    Latency is measured from each request's *scheduled* arrival time, so a
    request that waited on a busy device is charged its queue time.

    check_ref: optional per-query (scores, ids) precomputed single-thread
    reference — every stream then validates each response bit-exactly, so
    the bench measures correctness under load, not just latency."""
    obs.reset()
    barrier = threading.Barrier(n_streams + 1)
    errors = []

    def stream(sid: int):
        try:
            barrier.wait()
            start = time.perf_counter()
            n = 0
            while True:
                sched = start + n * interval_s
                if sched - start >= duration_s:
                    return
                now = time.perf_counter()
                if sched > now:
                    time.sleep(sched - now)
                qi = (sid + n) % len(queries)
                sv, si = _one_query(index, queries[qi][None], modality, k)
                obs.observe_ms(REQUEST_HIST, time.perf_counter() - sched)
                if check_ref is not None:
                    rv, ri = check_ref[qi]
                    if not (np.array_equal(np.asarray(sv), rv)
                            and np.array_equal(np.asarray(si), ri)):
                        raise RuntimeError(
                            f"response for query {qi} diverged from the "
                            "single-thread reference under concurrency")
                n += 1
        except Exception as e:          # surface, don't hang the join
            errors.append((sid, e))

    threads = [threading.Thread(target=stream, args=(s,), daemon=True)
               for s in range(n_streams)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    elapsed = time.perf_counter() - t0
    if errors:
        # surface EVERY failed stream, not just the first — a race that
        # hits 3 of 64 streams reads very differently from one bad query
        detail = "; ".join(f"stream {sid}: {e!r}" for sid, e in errors)
        raise RuntimeError(
            f"{len(errors)} of {n_streams} stream(s) failed: {detail}"
        ) from errors[0][1]
    h = obs.registry().histogram(REQUEST_HIST)
    return {"streams": n_streams, "requests": h.count,
            "qps": h.count / elapsed,
            "offered_qps": n_streams / interval_s,
            "p50_ms": h.percentile(50), "p99_ms": h.percentile(99)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=str, default="1,8,64",
                    help="comma-separated concurrency levels")
    ap.add_argument("--duration", type=float, default=5.0,
                    help="seconds per concurrency level")
    ap.add_argument("--dataset", type=str, default="dec-10k")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--utilization", type=float, default=0.7,
                    help="offered load at the largest level, as a fraction "
                         "of calibrated single-stream capacity")
    ap.add_argument("--check", action="store_true",
                    help="validate every response bit-exactly against a "
                         "precomputed single-thread reference")
    args = ap.parse_args()
    levels = [int(s) for s in args.streams.split(",")]

    corpus = load_corpus(args.dataset)
    modality = primary_mod(args.dataset)
    index = build_hmgi(corpus)
    queries = make_queries(corpus, modality, n=256)

    t_service = calibrate(index, queries, modality, args.k)
    print(f"# {args.dataset}: service time {t_service*1e3:.3f} ms/req, "
          f"capacity ~{1.0/t_service:.0f} QPS")

    en_p50, dis_p50 = overhead_check(index, queries, modality, args.k)
    delta = (en_p50 - dis_p50) / dis_p50 * 100.0
    verdict = "within 5%" if delta <= 5.0 else "EXCEEDS 5%"
    print(f"# obs overhead: p50 {en_p50:.3f} ms enabled vs {dis_p50:.3f} ms "
          f"uninstrumented ({delta:+.1f}%, {verdict})")

    check_ref = None
    if args.check:
        check_ref = [tuple(np.asarray(x) for x in
                           _one_query(index, q[None], modality, args.k))
                     for q in queries]
        print(f"# check: {len(check_ref)} single-thread reference "
              "responses precomputed; every stream validates bit-exactly")

    # per-stream interval so the top level offers utilization × capacity
    interval_s = t_service * max(levels) / args.utilization
    print("streams,requests,offered_qps,qps,p50_ms,p99_ms")
    for s in levels:
        r = run_level(index, queries, modality, args.k, s, args.duration,
                      interval_s, check_ref=check_ref)
        print(f"{r['streams']},{r['requests']},{r['offered_qps']:.1f},"
              f"{r['qps']:.1f},{r['p50_ms']:.3f},{r['p99_ms']:.3f}")
    if args.check:
        print("# check: PASS (all responses matched the reference)")


if __name__ == "__main__":
    main()
