"""EmbeddingBag — JAX has no native one (docs/DESIGN.md: build it, don't stub).

Lookup = ``jnp.take``; multi-hot reduce = ``segment_sum`` (or the Pallas
one-hot-matmul kernel on TPU). Tables shard their *rows* over the "model"
axis; the distributed lookup masks out-of-range ids per shard, takes
locally, and psums partial rows — one small collective per lookup batch,
no table gather (the tables are the memory; 39 fields × 100k rows × 10
here, 10⁶–10⁹ rows in production).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.params import Builder
from repro.sparse import segment as seg


def init_tables(key, n_fields: int, vocab_per_field: int, dim: int):
    b = Builder(key, dtype=jnp.float32)
    # one stacked table: (F, V, D), rows sharded over "model"
    b.dense("tables", (n_fields, vocab_per_field, dim),
            (None, "table", None), fan_in=dim, scale=0.1)
    return b.build()


def lookup(tables: jax.Array, ids: jax.Array) -> jax.Array:
    """tables (F, V, D); ids (B, F) -> (B, F, D). Single-device / GSPMD path."""
    f = tables.shape[0]
    return jax.vmap(lambda t, i: jnp.take(t, i, axis=0, mode="clip"),
                    in_axes=(0, 1), out_axes=1)(tables, ids)


def lookup_sharded(tables: jax.Array, ids: jax.Array, mesh) -> jax.Array:
    """Row-sharded lookup under shard_map: each "model" shard takes its row
    range and psums the partial rows (exactly one (B,F,D) psum)."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    bspec = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    shard_batch = bool(data_axes) and ids.shape[0] % n_data == 0

    def local(t, i):  # t (F, V_loc, D); i (B_loc, F)
        v_loc = t.shape[1]
        rank = jax.lax.axis_index("model")
        lo = rank * v_loc
        rel = i - lo
        ok = jnp.logical_and(rel >= 0, rel < v_loc)
        rows = jax.vmap(lambda tt, ii: jnp.take(tt, ii, axis=0, mode="clip"),
                        in_axes=(0, 1), out_axes=1)(t, jnp.clip(rel, 0, v_loc - 1))
        rows = jnp.where(ok[..., None], rows, 0.0)
        return jax.lax.psum(rows, "model")

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(None, "model", None), P(bspec if shard_batch else None, None)),
        out_specs=P(bspec if shard_batch else None, None, None),
        check_vma=False,
    )
    return fn(tables, ids)


def embedding_bag(tables: jax.Array, flat_ids: jax.Array, bag_ids: jax.Array,
                  n_bags: int, field: int = 0, mode: str = "sum") -> jax.Array:
    """torch.nn.EmbeddingBag analogue: ragged multi-hot ids reduced per bag.

    flat_ids (L,) rows into tables[field]; bag_ids (L,) in [0, n_bags).
    """
    rows = jnp.take(tables[field], jnp.clip(flat_ids, 0, tables.shape[1] - 1),
                    axis=0)
    rows = jnp.where((flat_ids >= 0)[:, None], rows, 0.0)
    if mode == "sum":
        return seg.segment_sum(rows, bag_ids, n_bags)
    if mode == "mean":
        return seg.segment_mean(rows, bag_ids, n_bags)
    if mode == "max":
        return seg.segment_max(rows, bag_ids, n_bags)
    raise ValueError(mode)
