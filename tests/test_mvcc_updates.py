"""MVCC update-path correctness: latest-version-wins in the delta,
no data loss on repartition or compaction overflow.

These pin the two bugs this PR fixes:
  1. recency — the delta could hold several live versions of one id
     (insert-then-update before compaction) and score-based dedup returned
     whichever scored higher, i.e. possibly the *stale* vector;
  2. data loss — ``maybe_repartition`` discarded the post-split build's
     overflow mask, and ``compact`` silently truncated overflow beyond the
     fresh delta's capacity.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import HMGIIndex
from repro.core import delta as delta_mod
from repro.core import ivf as ivf_mod


def _unit_rows(n, d, rng):
    v = rng.normal(size=(n, d)).astype(np.float32)
    return v / np.linalg.norm(v, axis=1, keepdims=True)


def _axis_vec(d, axis):
    v = np.zeros((1, d), np.float32)
    v[0, axis] = 1.0
    return v


def _build(n=400, d=32, **over):
    rng = np.random.default_rng(11)
    v = _unit_rows(n, d, rng)
    over = dict({"delta_capacity": 64}, **over)
    cfg = get_config("hmgi").replace(n_partitions=8, n_probe=8, top_k=5,
                                     kmeans_iters=4, **over)
    idx = HMGIIndex(cfg, seed=0)
    idx.ingest({"text": (np.arange(n, dtype=np.int32), v)}, n_nodes=n + 64)
    return idx, v


class TestRecency:
    def test_update_never_returns_old_vector(self):
        """insert(id) then insert(id) again: the first (stale) delta version
        must never surface — before or after compaction — even when the
        query is the stale vector itself (where it would score ~1.0)."""
        idx, _ = _build()
        d = 32
        v_old, v_new = _axis_vec(d, 0), _axis_vec(d, 1)
        nid = np.array([450], np.int32)
        idx.insert("text", nid, v_old)
        idx.insert("text", nid, v_new)       # both versions live in the delta

        for stage in ("pre-compaction", "post-compaction"):
            sv, si = idx.search(v_old, "text", k=5)
            for x, s in zip(np.asarray(si)[0], np.asarray(sv)[0]):
                if x == 450:
                    assert s < 0.5, (stage, s)   # stale copy scored ~1.0
            sv, si = idx.search(v_new, "text", k=1)
            assert int(si[0, 0]) == 450 and float(sv[0, 0]) > 0.99, stage
            idx.compact("text")

    def test_update_of_stable_row(self):
        """Updating an ingested row: old stable version superseded, new delta
        version returned, across compaction (the seed's own test, kept here
        with the query aimed at the *old* vector)."""
        idx, v = _build()
        d = 32
        new = _axis_vec(d, 2)
        idx.insert("text", np.array([0], np.int32), new)
        for _ in range(2):
            sv, si = idx.search(v[:1], "text", k=3)   # query = old vector
            for x, s in zip(np.asarray(si)[0], np.asarray(sv)[0]):
                assert x != 0 or s < 0.9, (x, s)
            sv, si = idx.search(new, "text", k=1)
            assert int(si[0, 0]) == 0 and float(sv[0, 0]) > 0.99
            idx.compact("text")

    def test_duplicate_ids_in_one_batch_last_wins(self):
        """One insert batch carrying two versions of an id: the later row
        wins (slot order breaks the version tie)."""
        store = delta_mod.init(16, 8, max_ids=32)
        v = np.zeros((2, 8), np.float32)
        v[0, 0] = 1.0
        v[1, 1] = 1.0
        store = delta_mod.insert(store, jnp.asarray(v), jnp.asarray([3, 3]))
        dv, di = delta_mod._scan_delta(store, jnp.asarray(v), k=4)
        di, dv = np.asarray(di), np.asarray(dv)
        # row 0 (stale) must not be visible: querying it returns the later
        # version's (orthogonal) score, not 1.0
        assert di[0, 0] == 3 and dv[0, 0] < 0.5
        assert di[1, 0] == 3 and dv[1, 0] > 0.99
        # and id 3 appears exactly once per query
        for row in di:
            assert (row == 3).sum() == 1

    def test_nsw_refine_respects_mvcc(self):
        """The NSW refine lane must apply the same visibility rules as the
        stable scan: deleted ids don't resurface and updated ids aren't
        ranked by their stale pre-update score."""
        idx, v = _build(use_nsw_refine=True, nsw_degree=8, nsw_ef=32)
        # delete
        idx.delete("text", np.array([5], np.int32))
        _, si = idx.search(v[5:6], "text", k=10)
        assert not np.any(np.asarray(si) == 5)
        # update: query the OLD vector — id 7 may only appear with the new
        # vector's (low) score, never the stale ~1.0 one. Post-compaction the
        # superseded mask is cleared, so the NSW layer must be refreshed too.
        new = _axis_vec(32, 3)
        idx.insert("text", np.array([7], np.int32), new)
        for stage in ("pre-compaction", "post-compaction"):
            sv, si = idx.search(v[7:8], "text", k=10)
            for x, s in zip(np.asarray(si)[0], np.asarray(sv)[0]):
                if x == 7:
                    assert s < 0.9, (stage, s)
            sv, si = idx.search(new, "text", k=1)
            assert int(si[0, 0]) == 7 and float(sv[0, 0]) > 0.99, stage
            idx.compact("text")

    def test_row_versions_stamped(self):
        store = delta_mod.init(8, 4, max_ids=16)
        store = delta_mod.insert(store, jnp.ones((2, 4)), jnp.asarray([0, 1]))
        store = delta_mod.insert(store, jnp.ones((1, 4)), jnp.asarray([0]))
        rv = np.asarray(store.row_version)
        assert rv[0] == rv[1] == 0 and rv[2] == 1   # batch counter
        assert np.all(rv[3:] == -1)                 # empty slots unstamped
        latest = np.asarray(delta_mod._latest_version_mask(store))
        np.testing.assert_array_equal(latest[:3], [False, True, True])


class TestNoDataLoss:
    def _tight_index(self, n=360, d=24, cap=50, delta_capacity=16):
        """Stable index with per-partition capacity tight enough that
        redistribution overflows."""
        rng = np.random.default_rng(7)
        v = _unit_rows(n, d, rng)
        cfg = get_config("hmgi").replace(n_partitions=8, n_probe=8, top_k=5,
                                         kmeans_iters=4,
                                         delta_capacity=delta_capacity)
        idx = HMGIIndex(cfg, seed=0)
        idx.ingest({"text": (np.arange(n, dtype=np.int32), v)}, n_nodes=n)
        m = idx.modalities["text"]
        # rebuild at tight capacity, routing build overflow to the delta
        # exactly as ingest does
        stable, overflow = ivf_mod.build(
            jax.random.PRNGKey(3), m.vectors, m.ids,
            n_partitions=8, bits=8, capacity=cap,
            centroids=m.ivf.centroids)
        m.ivf = stable
        ov = np.where(np.array(overflow))[0]
        if len(ov):
            m.delta = delta_mod.grow(m.delta, int(m.delta.count) + 2 * len(ov))
            m.delta = delta_mod.insert(m.delta, m.vectors[jnp.asarray(ov)],
                                       m.ids[jnp.asarray(ov)])
        return idx, v

    def _assert_full_corpus_searchable(self, idx, v):
        """Every vector, queried against itself at full probe, returns its
        own id at rank 1 — nothing dropped anywhere."""
        sv, si = idx.search(v, "text", k=1)
        m = idx.modalities["text"]
        np.testing.assert_array_equal(np.asarray(si)[:, 0], np.asarray(m.ids))

    def test_repartition_preserves_corpus(self):
        idx, v = self._tight_index()
        m = idx.modalities["text"]
        m.workload.hits[:] = 0
        m.workload.hits[int(np.argmax(np.asarray(m.ivf.counts)))] = 10_000
        assert idx.maybe_repartition("text")
        # the fix is only exercised if the split actually overflowed
        stable_rows = int(np.sum(np.asarray(m.ivf.ids) >= 0))
        assert stable_rows < v.shape[0], "test setup: no overflow occurred"
        assert int(m.delta.count) >= v.shape[0] - stable_rows
        self._assert_full_corpus_searchable(idx, v)

    def test_compact_grows_delta_instead_of_truncating(self):
        """Compaction overflow larger than the delta's capacity must grow
        the fresh delta, not silently truncate. cap=40 < n/K guarantees
        ≥ 40 overflow rows at build time against a 16-slot delta."""
        idx, v = self._tight_index(cap=40, delta_capacity=16)
        m = idx.modalities["text"]
        overflowed = v.shape[0] - int(np.sum(np.asarray(m.ivf.ids) >= 0))
        assert overflowed > 16, "test setup: overflow must exceed delta cap"
        idx.compact("text")
        m = idx.modalities["text"]
        assert int(m.delta.count) >= overflowed - 16  # nothing truncated
        assert not delta_mod.should_compact(m.delta, idx.cfg.compact_threshold)
        self._assert_full_corpus_searchable(idx, v)

    def test_delete_not_resurrected_by_repartition(self):
        idx, v = self._tight_index()
        m = idx.modalities["text"]
        victim = np.array([5], np.int32)
        idx.delete("text", victim)
        m.workload.hits[:] = 0
        m.workload.hits[int(np.argmax(np.asarray(m.ivf.counts)))] = 10_000
        assert idx.maybe_repartition("text")
        sv, si = idx.search(v[5:6], "text", k=10)
        assert not np.any(np.asarray(si) == 5)

    def test_insert_beyond_delta_capacity_not_dropped(self):
        """A burst of inserts larger than the delta's free space must stay
        searchable (compact-then-grow, never a silent drop)."""
        idx, v = _build(delta_capacity=16)
        rng = np.random.default_rng(13)
        burst = _unit_rows(40, 32, rng)
        ids = np.arange(410, 450, dtype=np.int32)
        idx.insert("text", ids, burst)
        sv, si = idx.search(burst, "text", k=1)
        np.testing.assert_array_equal(np.asarray(si)[:, 0], ids)
