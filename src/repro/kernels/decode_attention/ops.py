"""Public jit'd wrapper for flash-decode attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention_pallas


@functools.lru_cache(maxsize=None)
def _interpret_mode() -> bool:
    """Probed once, lazily (first kernel call): Mosaic needs a TPU; every
    other backend interprets. Deferred past import so app-level JAX setup
    (jax.distributed.initialize, platform selection) runs first."""
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q, k, v, valid, *, block_s: int = 512,
                     interpret: bool | None = None):
    """q (B, H, hd) with H = Hkv·G (GQA); k/v (B, S, Hkv, hd); valid (B, S).

    Returns (B, H, hd)."""
    interp = _interpret_mode() if interpret is None else interpret
    b, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    s = k.shape[1]
    bs = min(block_s, s)
    pad = (-s) % bs
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    qg = q.reshape(b, hkv, g, hd)
    out = decode_attention_pallas(qg, k, v, valid, block_s=bs, interpret=interp)
    return out.reshape(b, h, hd)
