"""Three-term roofline analysis over dry-run records (docs/DESIGN.md §6).

    compute    = HLO_FLOPs / (chips x peak)       [s]
    memory     = HLO_bytes / (chips x HBM_bw)     [s]
    collective = coll_bytes / (chips x link_bw)   [s]

cost_analysis is per-device (calibrated), so terms use per-device numbers
directly. Hardware constants: TPU v5e-class target per the task spec.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (ICI)


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float       # MODEL_FLOPS / HLO_FLOPs (remat/replication waste)
    temp_gib: float
    note: str = ""

    @property
    def bound_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the bound term that is *useful* model compute."""
        if self.bound_time <= 0:
            return 0.0
        chips = 512 if self.mesh == "multipod" else 256
        ideal = self.model_flops / (chips * PEAK_FLOPS)
        return min(ideal / self.bound_time, 1.0)


def analyse_record(rec: Dict) -> Optional[RooflineRow]:
    if rec.get("status") != "ok":
        return None
    chips = 512 if rec["mesh"] == "multipod" else 256
    ext = rec.get("ring_extrapolation") or rec.get("layer_extrapolation")
    if ext:
        flops = ext["true_flops_per_device"]
        hbytes = ext["true_bytes_per_device"]
        coll = ext["true_collective_bytes_per_device"]
        note = (f"ring-extrapolated R={ext['rounds']}" if "rounds" in ext
                else f"layer-extrapolated L={ext['n_scan_layers']}")
    else:
        flops = rec["flops_per_device"]
        hbytes = rec["bytes_per_device"]
        coll = rec["collective_bytes_per_device"].get("total", 0.0)
        note = ""
    compute = flops / PEAK_FLOPS
    memory = hbytes / HBM_BW
    collective = coll / LINK_BW
    dom = max(("compute", compute), ("memory", memory),
              ("collective", collective), key=lambda kv: kv[1])[0]
    model_flops = rec.get("meta", {}).get("model_flops", 0.0)
    total_hlo = flops * chips
    return RooflineRow(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=compute, memory_s=memory, collective_s=collective,
        dominant=dom, model_flops=model_flops, hlo_flops_total=total_hlo,
        useful_ratio=(model_flops / total_hlo) if total_hlo else 0.0,
        temp_gib=rec["memory"]["temp_bytes"] / 2 ** 30, note=note)


def load_all(results_dir: str, mesh: str = "singlepod") -> List[RooflineRow]:
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, mesh, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyse_record(rec)
        if row:
            rows.append(row)
    return rows


def format_table(rows: List[RooflineRow]) -> str:
    hdr = (f"{'arch':22s} {'shape':14s} {'compute(s)':>11s} {'memory(s)':>11s} "
           f"{'collect(s)':>11s} {'bound':>10s} {'useful':>7s} {'roofl%':>7s} "
           f"{'temp GiB':>9s}  note")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:22s} {r.shape:14s} {r.compute_s:11.4e} {r.memory_s:11.4e} "
            f"{r.collective_s:11.4e} {r.dominant:>10s} {r.useful_ratio:7.3f} "
            f"{100*r.roofline_fraction:6.1f}% {r.temp_gib:9.2f}  {r.note}")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"))
    ap.add_argument("--mesh", default="singlepod")
    args = ap.parse_args()
    rows = load_all(args.results, args.mesh)
    print(format_table(rows))


if __name__ == "__main__":
    main()
