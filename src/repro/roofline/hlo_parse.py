"""Collective-byte extraction from compiled HLO text (docs/DESIGN.md §6).

``cost_analysis`` has no collective numbers, so the roofline's third term
comes from parsing ``compiled.as_text()``: sum the result sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
call-graph-aware (a collective inside a called computation counts once per
call site; a collective inside a ``while`` body counts ``trip_count`` times
— the caller supplies known trip counts, e.g. a ring scan's round count,
since XLA's text doesn't expose them reliably).

Per-op link-byte conventions (ring algorithms, per device):
  all-reduce       2 x bytes      (reduce-scatter + all-gather phases)
  all-gather       1 x result bytes
  reduce-scatter   1 x operand bytes (≈ result x group)
  all-to-all       1 x bytes
  collective-permute 1 x bytes
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_CALL_RE = re.compile(
    r"(?:to_apply|body|called_computations=\{)[=\s]*%?([\w\.\-]+)")
_WHILE_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_CALL_TARGET_RE = re.compile(r"(?:call|fusion)\(.*to_apply=%?([\w\.\-]+)")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape literal in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(
    hlo_text: str,
    while_trip_counts: Optional[Dict[str, int]] = None,
    default_trip_count: int = 1,
) -> Dict[str, float]:
    """Returns per-device link bytes by collective kind (+ "total").

    while_trip_counts: substring -> trip count; a while whose body name
    contains the substring multiplies its subtree by that count.
    """
    while_trip_counts = while_trip_counts or {}

    # --- split into computations -------------------------------------------
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.endswith("{") and ("=" not in s.split("{")[0] or s.startswith("ENTRY")):
            m = _COMP_START_RE.match(s)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if s == "}":
            # end of computation body (ignore nested braces in attrs: rare)
            continue
        if cur is not None:
            comps[cur].append(s)

    # --- per-computation direct collective bytes + call edges ---------------
    direct: Dict[str, Dict[str, float]] = {}
    edges: Dict[str, list] = defaultdict(list)   # comp -> [(callee, mult)]
    for name, lines in comps.items():
        acc: Dict[str, float] = defaultdict(float)
        for s in lines:
            eq = s.find("=")
            if eq >= 0:
                rhs = s[eq:]
                for op, factor in _COLLECTIVES.items():
                    # instruction names ("%all-gather.14 = ...") also contain
                    # the op string — only look right of "=" for the call,
                    # and take the shape(s) between "=" and the call site
                    m = re.search(rf"\b{op}(?:-start)?\(", rhs)
                    if m:
                        acc[op] += factor * _shape_bytes(rhs[: m.start()])
                        break
            if " while(" in s or s.startswith("while("):
                m = _WHILE_BODY_RE.search(s)
                if m:
                    body = m.group(1)
                    mult = default_trip_count
                    for key, tc in while_trip_counts.items():
                        if key in body:
                            mult = tc
                            break
                    edges[name].append((body, mult))
            else:
                for m in re.finditer(r"to_apply=%?([\w\.\-]+)", s):
                    edges[name].append((m.group(1), 1))
                m = re.search(r"condition=%?([\w\.\-]+)", s)
                if m:
                    edges[name].append((m.group(1), 1))
        direct[name] = dict(acc)

    # --- roll up through the call graph (memoised DFS) ----------------------
    memo: Dict[str, Dict[str, float]] = {}

    def total_of(comp: str, stack=()) -> Dict[str, float]:
        if comp in memo:
            return memo[comp]
        if comp in stack or comp not in comps:
            return {}
        acc = defaultdict(float, direct.get(comp, {}))
        for callee, mult in edges.get(comp, []):
            sub = total_of(callee, stack + (comp,))
            for k, v in sub.items():
                acc[k] += mult * v
        memo[comp] = dict(acc)
        return memo[comp]

    entry = None
    for name in comps:
        if "main" in name or entry is None:
            entry = name if "main" in name else entry
    if entry is None:
        entry = next(iter(comps), None)
    out = dict(total_of(entry)) if entry else {}
    out["total"] = float(sum(out.values()))
    return out


def count_collective_ops(hlo_text: str) -> Dict[str, int]:
    """Raw occurrence counts (diagnostics)."""
    out = {}
    for op in _COLLECTIVES:
        out[op] = len(re.findall(rf"{op}(?:-start)?\(", hlo_text))
    return out
