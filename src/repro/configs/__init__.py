"""Architecture registry: ``--arch <id>`` resolution for every assigned config."""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.base import (
    ArchConfig, GNNConfig, HMGIConfig, LMConfig, RecsysConfig, ShapeSpec,
)

_MODULES = {
    "deepseek-67b": "repro.configs.deepseek_67b",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite",
    "dimenet": "repro.configs.dimenet",
    "egnn": "repro.configs.egnn",
    "nequip": "repro.configs.nequip",
    "equiformer-v2": "repro.configs.equiformer_v2",
    "xdeepfm": "repro.configs.xdeepfm",
    "hmgi": "repro.configs.hmgi",
}

ASSIGNED_ARCHS: Tuple[str, ...] = tuple(a for a in _MODULES if a != "hmgi")


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def get_shapes(arch_id: str) -> List[ShapeSpec]:
    return importlib.import_module(_MODULES[arch_id]).SHAPES


def all_cells(include_skipped: bool = True):
    """Yield every (arch_id, ShapeSpec) cell of the assignment (40 total)."""
    for arch in ASSIGNED_ARCHS:
        for shape in get_shapes(arch):
            if include_skipped or not shape.skip:
                yield arch, shape


def smoke_config(arch_id: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests (small widths/layers)."""
    cfg = get_config(arch_id)
    if isinstance(cfg, LMConfig):
        kw = dict(
            n_layers=2, d_model=64, n_heads=4, head_dim=16,
            n_kv_heads=min(cfg.n_kv_heads, 2), d_ff=128, vocab_size=512,
            scan_layers=True, remat=False,
        )
        if cfg.moe:
            kw.update(n_experts=min(cfg.n_experts, 4), top_k=min(cfg.top_k, 2),
                      moe_d_ff=64, dense_d_ff=128,
                      n_shared_experts=min(cfg.n_shared_experts, 1))
        if cfg.attention == "mla":
            kw.update(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16)
        if cfg.sliding_window:
            kw.update(sliding_window=32)
        return cfg.replace(**kw)
    if isinstance(cfg, GNNConfig):
        return cfg.replace(n_layers=2, d_hidden=16, n_heads=2,
                           l_max=min(cfg.l_max, 2), m_max=min(cfg.m_max, 1),
                           n_spherical=min(cfg.n_spherical, 4),
                           n_radial=min(cfg.n_radial, 4), n_bilinear=4, n_rbf=4)
    if isinstance(cfg, RecsysConfig):
        return cfg.replace(n_sparse=8, embed_dim=4, vocab_per_field=64,
                           cin_layers=(8, 8), mlp_layers=(16, 16))
    if isinstance(cfg, HMGIConfig):
        return cfg.replace(dim=16, modality_dims={}, n_partitions=4, n_probe=2,
                           kmeans_iters=4, delta_capacity=64, nsw_degree=4, nsw_ef=8)
    raise TypeError(type(cfg))
