"""Append-only write-ahead op log (WAL) for the durable index lifecycle.

Every mutating facade call appends one record *before* applying it
(write-ahead discipline), so after any crash the durable state is exactly:
latest valid snapshot + the log tail — and an op whose append completed is
recovered even if the process died before applying it in memory.

Frame format (little-endian), one per record:

    magic  b"HWAL"   (4)
    seq    uint64    (8)   monotonic, 1-based, global across segments
    len    uint32    (4)   payload byte length
    crc32  uint32    (4)   zlib.crc32(payload)
    payload:
        hlen   uint32                    header byte length
        header json utf-8                {"op", "meta", "arrays": [[key,
                                          dtype, shape], ...]}
        raw array bytes, C-order, concatenated in header order

Torn-tail handling: a crash mid-append leaves a final frame that is short,
has a bad magic, or fails its CRC — ``scan`` stops at the first invalid
frame and ``open_for_append`` truncates the segment back to the last valid
frame boundary before new appends land. A crash can only tear the *tail*
(appends are sequential and earlier bytes were already fsync'd), so one
truncation point suffices; anything invalid *before* the tail is real
corruption and recovery stops there with a warning rather than guessing.

Segmentation: records live in ``wal_<firstseq>.log`` files. A snapshot at
seq S rotates to a fresh segment (``wal_<S+1>.log``) and deletes segments
whose records all precede the *oldest retained* snapshot — the fallback
path (corrupt newest snapshot -> previous snapshot + longer replay) always
finds the records it needs.

fsync policy: ``sync_every`` batches fsyncs (1 = every append is durable at
return; N = up to N-1 trailing ops may be lost to a crash — they are also
not yet applied-and-acknowledged anywhere durable, so recovery still
matches a valid uninterrupted prefix).

Arrays are serialised raw (dtype + shape in the header): exotic dtypes map
through the same integer views the checkpoint substrate uses, and replay
reconstructs bit-identical inputs.
"""
from __future__ import annotations

import json
import os
import re
import struct
import zlib
from typing import Dict, Iterator, List, NamedTuple, Optional, Tuple

import ml_dtypes
import numpy as np

from repro import obs
from repro.checkpoint.checkpoint import fsync_dir, fsync_file
from repro.persistence.faultpoints import crash_point

MAGIC = b"HWAL"
_FRAME = struct.Struct("<4sQII")        # magic, seq, len, crc32

# raw-bytes views for dtypes numpy can't name (mirrors checkpoint._EXOTIC_VIEWS)
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8}


class OpRecord(NamedTuple):
    seq: int
    op: str
    meta: dict
    arrays: Dict[str, np.ndarray]


def encode_payload(op: str, meta: dict, arrays: Dict[str, np.ndarray]) -> bytes:
    specs, blobs = [], []
    for key, arr in arrays.items():
        arr = np.ascontiguousarray(arr)
        name = str(arr.dtype)
        view = arr.view(_EXOTIC[name]) if name in _EXOTIC else arr
        specs.append([key, name, list(arr.shape)])
        blobs.append(view.tobytes())
    header = json.dumps({"op": op, "meta": meta, "arrays": specs}).encode()
    return b"".join([struct.pack("<I", len(header)), header, *blobs])


def decode_payload(payload: bytes) -> Tuple[str, dict, Dict[str, np.ndarray]]:
    (hlen,) = struct.unpack_from("<I", payload, 0)
    header = json.loads(payload[4:4 + hlen].decode())
    arrays: Dict[str, np.ndarray] = {}
    off = 4 + hlen
    for key, name, shape in header["arrays"]:
        if name in _EXOTIC:
            base, final = _EXOTIC[name], getattr(ml_dtypes, name)
        else:
            base = final = np.dtype(name)
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        nbytes = n * np.dtype(base).itemsize
        arr = np.frombuffer(payload[off:off + nbytes], dtype=base)
        arrays[key] = arr.view(final).reshape(shape).copy()
        off += nbytes
    return header["op"], header["meta"], arrays


def _segment_seq(name: str) -> Optional[int]:
    m = re.fullmatch(r"wal_(\d+)\.log", name)
    return int(m.group(1)) if m else None


class OpLog:
    """One writer, segmented WAL under ``directory``."""

    def __init__(self, directory: str, sync_every: int = 1):
        self.directory = directory
        self.sync_every = max(int(sync_every), 1)
        os.makedirs(directory, exist_ok=True)
        self._f = None                   # open append handle (current segment)
        self._unsynced = 0
        self.last_seq = 0                # last *valid* seq on disk
        self.torn_tail = False           # a truncated/invalid tail was seen

    # ----------------------------------------------------------------- layout
    def segments(self) -> List[Tuple[int, str]]:
        """[(first_seq, path)] ascending."""
        out = []
        for name in os.listdir(self.directory):
            s = _segment_seq(name)
            if s is not None:
                out.append((s, os.path.join(self.directory, name)))
        return sorted(out)

    # ------------------------------------------------------------------- read
    def _scan_segment(self, path: str) -> Tuple[List[OpRecord], int, bool]:
        """(records, valid_end_offset, clean) — stops at the first frame that
        is short, mis-magic'd, or CRC-corrupt."""
        records: List[OpRecord] = []
        with open(path, "rb") as f:
            data = f.read()
        off = 0
        while off + _FRAME.size <= len(data):
            magic, seq, plen, crc = _FRAME.unpack_from(data, off)
            end = off + _FRAME.size + plen
            if magic != MAGIC or end > len(data):
                return records, off, False
            payload = data[off + _FRAME.size:end]
            if zlib.crc32(payload) != crc:
                return records, off, False
            op, meta, arrays = decode_payload(payload)
            records.append(OpRecord(seq, op, meta, arrays))
            off = end
        return records, off, off == len(data)

    def scan(self, min_seq: int = 0) -> Iterator[OpRecord]:
        """Valid records with seq > min_seq, in order, across segments.
        Stops (sets ``torn_tail``) at the first invalid frame or sequence
        gap; updates ``last_seq`` to the last record yielded-or-skipped."""
        self.torn_tail = False
        prev = None
        for _, path in self.segments():
            records, _, clean = self._scan_segment(path)
            for rec in records:
                if prev is not None and rec.seq != prev + 1:
                    self.torn_tail = True       # gap: stop, don't guess
                    return
                prev = rec.seq
                self.last_seq = rec.seq
                if rec.seq > min_seq:
                    yield rec
            if not clean:
                self.torn_tail = True
                return

    # ------------------------------------------------------------------ write
    def open_for_append(self) -> None:
        """Positions the writer after the last valid record: scans segments,
        truncates a torn tail of the newest one, opens it for append. A
        fresh directory starts at ``wal_1.log``."""
        segs = self.segments()
        if not segs:
            self.last_seq = 0
            self._open_segment(1)
            return
        # consume the scan to settle last_seq / torn_tail
        for _ in self.scan(min_seq=np.iinfo(np.int64).max):
            pass
        # an empty newest segment (rotated right after a snapshot, no appends
        # yet) still pins the sequence: its name says records start at
        # first_seq, so the last durable seq is at least first_seq - 1
        self.last_seq = max(self.last_seq, segs[-1][0] - 1)
        last_path = segs[-1][1]
        _, valid_end, clean = self._scan_segment(last_path)
        if not clean:
            with open(last_path, "r+b") as f:
                f.truncate(valid_end)
            fsync_file(last_path)
        self._f = open(last_path, "ab")
        self._unsynced = 0

    def _open_segment(self, first_seq: int) -> None:
        crash_point("wal.pre_rotate")
        if self._f is not None:
            self._sync()
            self._f.close()
        path = os.path.join(self.directory, f"wal_{first_seq:016d}.log")
        self._f = open(path, "ab")
        fsync_dir(self.directory)       # the new segment's name is durable
        self._unsynced = 0

    def append(self, op: str, meta: dict,
               arrays: Dict[str, np.ndarray]) -> int:
        """Appends one record; returns its seq. Durable at return whenever
        the fsync batch flushed (always, at sync_every=1)."""
        with obs.span("wal.append"):
            if self._f is None:
                self.open_for_append()
            payload = encode_payload(op, meta, arrays)
            seq = self.last_seq + 1
            crash_point("wal.pre_append")
            self._f.write(_FRAME.pack(MAGIC, seq, len(payload),
                                      zlib.crc32(payload)))
            self._f.write(payload)
            self.last_seq = seq
            self._unsynced += 1
            if self._unsynced >= self.sync_every:
                self._sync()
            crash_point("wal.post_append")
            return seq

    def _sync(self) -> None:
        if self._f is not None and self._unsynced:
            # group-commit accounting: how many appends each fsync covers
            obs.histogram("wal.sync_batch",
                          obs.COUNT_BUCKETS).observe(self._unsynced)
            with obs.span("wal.fsync"):
                self._f.flush()
                os.fsync(self._f.fileno())
            self._unsynced = 0

    def sync(self) -> None:
        self._sync()

    # -------------------------------------------------- snapshot coordination
    def rotate(self, next_seq: Optional[int] = None) -> None:
        """Starts a fresh segment (after a snapshot): future records land in
        ``wal_<next_seq>.log`` so fully-superseded segments become unlinkable
        units."""
        self._open_segment(self.last_seq + 1 if next_seq is None else next_seq)

    def gc(self, floor_seq: int) -> int:
        """Unlinks segments whose records are *all* ≤ ``floor_seq`` (the
        oldest retained snapshot's last applied seq). A segment qualifies
        exactly when the next segment starts at or before floor_seq + 1 —
        the newest segment never qualifies. Returns segments removed."""
        segs = self.segments()
        removed = 0
        crash_point("wal.pre_gc")
        for (first, path), (nxt_first, _) in zip(segs, segs[1:]):
            if nxt_first <= floor_seq + 1:
                os.unlink(path)
                removed += 1
        if removed:
            fsync_dir(self.directory)
        crash_point("wal.post_gc")
        return removed

    def close(self) -> None:
        if self._f is not None:
            self._sync()
            self._f.close()
            self._f = None
