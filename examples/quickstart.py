"""Quickstart: build an HMGI index over a synthetic multimodal corpus,
run vector + hybrid queries, do a live update, compact.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import get_config
from repro.core import HMGIIndex
from repro.data.synthetic import ground_truth_topk, make_corpus, recall_at_k

# 1. corpus: two modalities + a knowledge graph
corpus = make_corpus(n_nodes=2000, modality_dims={"text": 64, "image": 96},
                     seed=0)
print(f"corpus: {corpus.n_nodes} nodes, {len(corpus.src)} edges, "
      f"modalities={list(corpus.vectors)}")

# 2. build the index (modality-aware partitions, int8 flash quantization)
cfg = get_config("hmgi").replace(n_partitions=32, n_probe=8, quant_bits=8)
index = HMGIIndex(cfg, seed=0)
index.ingest({m: (corpus.node_ids[m], corpus.vectors[m])
              for m in corpus.vectors}, n_nodes=corpus.n_nodes,
             edges=(corpus.src, corpus.dst, corpus.edge_type))
print(f"index memory: {index.memory_usage()['total']/2**20:.2f} MiB")

# 3. vector search
rng = np.random.default_rng(1)
sel = rng.integers(0, len(corpus.vectors["text"]), 16)
queries = corpus.vectors["text"][sel] + 0.05 * rng.normal(
    size=(16, 64)).astype(np.float32)
scores, ids = index.search(queries, "text", k=10)
truth = ground_truth_topk(corpus.vectors["text"], corpus.node_ids["text"],
                          queries, 10)
print(f"vector recall@10: {recall_at_k(np.asarray(ids), truth):.3f}")

# 4. hybrid search (Eq. 3 fusion: ANN seeds -> 2-hop traversal -> fused rank)
hscores, hids = index.hybrid_search(queries, "text", k=10, n_hops=2)
print(f"hybrid top-1 ids: {np.asarray(hids)[:4, 0]}")

# 5. dynamic update: insert a new vector, find it, delete it. Writes land
#    in the MVCC delta; adaptive maintenance (auto-triggered, or explicit
#    via maintain(budget=...)) drains it in bounded steps — compact() is
#    the synchronous full-merge fallback shown here.
new_vec = np.zeros((1, 64), np.float32)
new_vec[0, 0] = 1.0
index.insert("text", np.array([1999]), new_vec)
_, found = index.search(new_vec, "text", k=1)
print(f"inserted id found: {int(found[0, 0]) == 1999}")
index.delete("text", np.array([1999]))
report = index.maintain("text", budget=256)   # bounded adaptive pass
print(f"maintenance: {report.describe()}")
index.compact("text")
print("compacted; delta flushed into the stable index")
