"""Learned cost model and plan selection (paper Eq. 5 + §3.6).

    C = α·log N + β·(d·h) + γ·p·log(N/p)

α, β, γ are calibrated by least squares against measured query latencies
(the benchmark harness emits (features, latency) pairs). ``select_plan``
greedily picks the cheapest plan satisfying the recall constraint — the
paper's "greedy plan selection with optimality bounds".
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class CostModel:
    alpha: float = 1.0
    beta: float = 0.01
    gamma: float = 0.1

    def cost(self, n: int, d: int, h: int, p: int) -> float:
        """Eq. 5. n=corpus size, d=dim, h=hops, p=partitions probed."""
        p = max(p, 1)
        return (self.alpha * math.log(max(n, 2))
                + self.beta * (d * h)
                + self.gamma * p * math.log(max(n / p, 2)))

    def features(self, n, d, h, p) -> np.ndarray:
        p = max(p, 1)
        return np.array([math.log(max(n, 2)), d * h, p * math.log(max(n / p, 2))])

    def fit(self, samples: Sequence[Tuple[int, int, int, int]],
            latencies: Sequence[float]) -> "CostModel":
        """Least-squares calibration of (α, β, γ) on measured latencies."""
        X = np.stack([self.features(*s) for s in samples])
        y = np.asarray(latencies, np.float64)
        coef, *_ = np.linalg.lstsq(X, y, rcond=None)
        self.alpha, self.beta, self.gamma = (float(c) for c in coef)
        return self

    def r2(self, samples, latencies) -> float:
        X = np.stack([self.features(*s) for s in samples])
        y = np.asarray(latencies, np.float64)
        pred = X @ np.array([self.alpha, self.beta, self.gamma])
        ss_res = float(np.sum((y - pred) ** 2))
        ss_tot = float(np.sum((y - y.mean()) ** 2)) + 1e-12
        return 1.0 - ss_res / ss_tot


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    name: str
    n_probe: int
    n_hops: int
    use_nsw_refine: bool = False
    use_rerank: bool = False
    expected_recall: float = 0.9


DEFAULT_PLANS: Tuple[QueryPlan, ...] = (
    QueryPlan("vector_fast", n_probe=2, n_hops=0, expected_recall=0.80),
    QueryPlan("vector_std", n_probe=8, n_hops=0, expected_recall=0.95),
    QueryPlan("hybrid_1hop", n_probe=4, n_hops=1, expected_recall=0.93),
    QueryPlan("hybrid_2hop", n_probe=8, n_hops=2, expected_recall=0.97),
    QueryPlan("hybrid_deep", n_probe=16, n_hops=3, use_rerank=True,
              expected_recall=0.99),
)


def select_plan(model: CostModel, *, n: int, d: int, min_recall: float,
                plans: Sequence[QueryPlan] = DEFAULT_PLANS) -> QueryPlan:
    """Greedy: cheapest plan whose expected recall clears the floor."""
    feasible = [p for p in plans if p.expected_recall >= min_recall]
    if not feasible:
        feasible = [max(plans, key=lambda p: p.expected_recall)]
    return min(feasible, key=lambda p: model.cost(n, d, p.n_hops, p.n_probe))


# ---------------------------------------------------------------------------
# attribute-filtered search planning (pre-filter pushdown vs oversample)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FilteredScanPlan:
    """How to serve "top-k WHERE pred": push the predicate into the scan's
    validity mask ("prefilter") or run the unfiltered scan with an inflated
    k and post-filter ("oversample")."""
    mode: str                 # "prefilter" | "oversample"
    k_scan: int               # top-k width handed to the underlying scan
    selectivity: float


def estimate_selectivity(node_pass) -> float:
    """Fraction of rows a predicate admits — one mean over the (N,) mask the
    predicate compiler already produced (exact, not a sketch: attributes are
    resident on device and the mask is reused by every scan stage)."""
    return float(np.mean(np.asarray(node_pass)))


def plan_filtered_scan(selectivity: float, k: int, *, n_rows: int,
                       oversample: float = 3.0,
                       prefilter_max_sel: float = 0.5) -> FilteredScanPlan:
    """Selectivity-aware choice (the NHQ observation, inverted per regime):

    - Low selectivity (few rows pass): post-filtering is hopeless — the
      unfiltered top-k' must be ~k/sel wide before k survivors show up, so
      its top-k sort cost (and exactness risk) blows up as 1/sel. Pushdown
      scans the same rows but spends every top-k slot on qualifying rows.
    - Selectivity near 1: almost everything passes; a small constant
      oversample (k' = oversample·k/sel) already contains the filtered top-k
      with high probability, and skips the per-row mask gather the pushdown
      folds into the scan's valid lane.

    The crossover is where the oversampled width stops being "small":
    k/sel·oversample ≳ the pushdown's masked width ⇒ prefilter below
    ``prefilter_max_sel``, oversample above. k_scan for oversampling is the
    *initial* width — exactness-sensitive callers double it until k
    survivors are found (see HMGIIndex.search)."""
    sel = float(min(max(selectivity, 0.0), 1.0))
    if sel <= 0.0:
        return FilteredScanPlan("prefilter", k, 0.0)
    if sel <= prefilter_max_sel:
        return FilteredScanPlan("prefilter", k, sel)
    k_scan = min(n_rows, max(k + 1, int(math.ceil(k * oversample / sel))))
    return FilteredScanPlan("oversample", k_scan, sel)


# ---------------------------------------------------------------------------
# device layout planning (single-device vs row-sharded stable scan)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeviceLayoutPlan:
    """Where a modality's stable scan runs: "single" (one device holds the
    whole slab) or "sharded" (row-sharded over the mesh's db axes, per-shard
    probes + cross-shard top-k merge — see ivf.shard_index)."""
    layout: str               # "single" | "sharded"
    n_shards: int             # 1 for "single"


def plan_device_layout(n_rows: int, dim: int, *, n_shards: int,
                       budget_bytes: int, bytes_per_elem: int = 1,
                       force: Optional[str] = None) -> DeviceLayoutPlan:
    """Shard the stable scan when one device's slab share would exceed the
    per-device budget (n_rows·dim quantized bytes — the HBM-residency the
    probe path actually touches), single-device otherwise. Sharding below
    that is pure overhead: the probe scan is already one device's flops, and
    the cross-shard all-gather+merge adds a collective per query.

    force: "single"/"sharded" overrides the decision (cfg.shard_layout);
    forcing "sharded" on a 1-shard mesh still degenerates to "single"."""
    if force not in (None, "auto", "single", "sharded"):
        raise ValueError(f"unknown layout {force!r}")
    if n_shards <= 1 or force == "single":
        return DeviceLayoutPlan("single", 1)
    if force == "sharded":
        return DeviceLayoutPlan("sharded", n_shards)
    slab_bytes = n_rows * dim * bytes_per_elem
    if budget_bytes > 0 and slab_bytes > budget_bytes:
        return DeviceLayoutPlan("sharded", n_shards)
    return DeviceLayoutPlan("single", 1)


# ---------------------------------------------------------------------------
# query-engine stage planning (repro/query/planner.py consumes these)
# ---------------------------------------------------------------------------

def plan_seed_width(k: int, downstream: bool) -> int:
    """Scan width for a vector-seed stage: the bare top-k when the seeds are
    the answer; oversampled (fusion/re-score headroom, the facade's historic
    2k ∨ k+8 rule) when later stages re-rank or combine them."""
    return max(2 * k, k + 8) if downstream else k


@dataclasses.dataclass(frozen=True)
class FusionPlan:
    """Shape of a traversal-fusion stage: candidate-sparse (fuse over the
    seeds ∪ frontier union, O(Q·C) memory) vs dense (fuse over all N nodes).

    Sparse wins whenever the frontier is a strict subset of the corpus — its
    peak memory is corpus-size independent and its exactness argument holds
    (frontier = k_fuse + C_in). When ``frontier`` reaches ``n_nodes`` the
    candidate union already spans every node, so the sparse bookkeeping
    (dup masks, concat lanes) buys nothing over one dense scatter."""
    repr: str                 # "sparse" | "dense"
    k_fuse: int               # fused candidates kept (stage output width)
    frontier: int             # traversal nodes admitted to the candidate set


def plan_fusion(n_nodes: int, k: int, c_in: int) -> FusionPlan:
    """c_in = incoming candidate-set width (the seed stage's scan width)."""
    k_fuse = max(k, min(4 * k, n_nodes))
    frontier = int(min(n_nodes, k_fuse + c_in))
    return FusionPlan("dense" if frontier >= n_nodes else "sparse",
                      k_fuse, frontier)
