"""Brute-force numpy reference interpreter for compiled query plans.

``reference_execute`` walks a ``repro.query.planner.PhysicalPlan`` stage by
stage and evaluates each one exhaustively, with no ANN shortcuts:

- seed scans score *every* live row in the representation the index
  actually stores (dequantized int8 for stable rows, fp32 master rows for
  delta rows — so at full probe the engine must reproduce the oracle
  exactly, stable+delta included);
- traversal is the dense h-hop push over the whole edge list (boosted
  weights, edge-type masks, node masks, damping — the same semantics as
  ``traversal.frontier_expand``), fused densely over all N nodes (Eq. 3);
- cross-modal re-scores, set ops, and filters are per-candidate dict math.

Each stage also returns its full candidate *pool* (per-query id -> score
dict). Exactness checks use the pool (``assert_matches``): the engine's
sorted scores must equal the oracle's, and every returned id must carry its
oracle score — tie-robust (equal scores may legally permute ids)."""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import ivf as ivf_mod
from repro.core.delta import _latest_version_mask
from repro.query.planner import (PhysicalPlan, PRescore, PSeed, PSetOp,
                                 PTraverse)

Ref = Tuple[np.ndarray, np.ndarray, List[Dict[int, float]]]


def stored_corpus(idx, modality: str):
    """(vectors, ids, live) of every row, in the representation the index
    scans: dequantized int8 for stable rows, fp32 master for delta rows
    (latest version per id, tombstones out)."""
    m = idx.modalities[modality]
    data, vmin, scale, sids = m.ivf.slab_view()
    stable = ivf_mod._dequant_rows(m.ivf, data, vmin, scale)
    sids = np.asarray(sids)
    dead = np.asarray(m.delta.tombstones) | np.asarray(m.delta.superseded)
    s_ok = (sids >= 0) & ~dead[np.clip(sids, 0, dead.shape[0] - 1)]
    d_ids = np.asarray(m.delta.ids)
    d_ok = np.asarray(_latest_version_mask(m.delta)) \
        & ~np.asarray(m.delta.tombstones)[np.clip(d_ids, 0, dead.shape[0] - 1)]
    vecs = np.concatenate([np.asarray(stable), np.asarray(m.delta.vectors)])
    ids = np.concatenate([sids, d_ids])
    ok = np.concatenate([s_ok, d_ok])
    return vecs.astype(np.float64), ids, ok


def _topk_rows(scores: np.ndarray, ids: np.ndarray, k: int) -> Ref:
    """Per-row exact top-k over a (Q, R) score matrix with row ids (R,);
    -inf entries pad out as (-inf, -1). Pools keep every finite entry."""
    order = np.argsort(-scores, axis=1)[:, :k]
    vals = np.take_along_axis(scores, order, axis=1)
    out_ids = np.where(np.isfinite(vals), ids[order], -1)
    pad = k - vals.shape[1]
    if pad > 0:
        vals = np.concatenate(
            [vals, np.full((vals.shape[0], pad), -np.inf)], axis=1)
        out_ids = np.concatenate(
            [out_ids, np.full((out_ids.shape[0], pad), -1, out_ids.dtype)],
            axis=1)
    pools = [{int(i): float(s) for i, s in zip(ids, row) if np.isfinite(s)}
             for row in scores]
    return vals, out_ids.astype(np.int64), pools


def _pools_of(sv: np.ndarray, si: np.ndarray) -> List[Dict[int, float]]:
    return [{int(i): float(s) for s, i in zip(rs, ri) if np.isfinite(s)}
            for rs, ri in zip(sv, si)]


def _seed(idx, ps: PSeed, node_pass: Optional[np.ndarray]) -> Ref:
    vecs, ids, ok = stored_corpus(idx, ps.modality)
    if node_pass is not None:
        ok = ok & node_pass[np.clip(ids, 0, len(node_pass) - 1)]
    q = np.asarray(ps.query, np.float64)
    scores = q @ vecs.T
    scores = np.where(ok[None, :], scores, -np.inf)
    return _topk_rows(scores, ids, ps.k)


def _seed_mass(n: int, ids: np.ndarray, scores: np.ndarray) -> np.ndarray:
    """numpy twin of traversal.seeds_from_topk."""
    valid = (ids >= 0) & np.isfinite(scores)
    if not valid.any():
        return np.zeros(n)
    smin = float(np.min(scores[valid]))
    smin = smin if np.isfinite(smin) else 0.0
    w = np.where(valid, scores - smin + 1e-6, 0.0)
    w = w / max(w.sum(), 1e-12)
    seed = np.zeros(n)
    np.add.at(seed, np.clip(ids, 0, n - 1), np.where(valid, w, 0.0))
    return seed


def _weights(cfg, sv: np.ndarray):
    """numpy twin of fusion.adaptive_weights / the fixed-weight branch."""
    qn = sv.shape[0]
    if not cfg.adaptive_weights:
        return np.full(qn, cfg.w_vector), np.full(qn, cfg.w_graph)
    s1 = sv[:, 1] if sv.shape[1] > 1 else sv[:, 0]
    with np.errstate(invalid="ignore"):
        margin = sv[:, 0] - s1
    margin = np.nan_to_num(margin, nan=0.0, posinf=1.0, neginf=0.0)
    conf = 1.0 / (1.0 + np.exp(-4.0 * (margin - 0.05)))
    wv = cfg.w_vector * (0.5 + conf)
    wg = cfg.w_graph * (1.5 - conf)
    tot = wv + wg
    return wv / tot, wg / tot


def _traverse(idx, pt: PTraverse, sv, si,
              node_pass: Optional[np.ndarray]) -> Ref:
    if pt.n_hops == 0:
        return sv, si, _pools_of(sv, si)
    g = idx.graph
    n = idx.n_nodes
    ew = np.asarray(idx.boosted_weights if idx.boosted_weights is not None
                    else g.edge_weight, np.float64)
    src = np.asarray(g.src)
    dst = np.asarray(g.indices)
    if pt.edge_type_mask is not None:
        # safe gather, mirroring frontier_expand: edge types beyond the
        # LUT's domain are excluded
        lut = np.asarray(pt.edge_type_mask, np.float64)
        et = np.asarray(g.edge_type)
        ew = ew * np.where(et < len(lut),
                           lut[np.clip(et, 0, len(lut) - 1)], 0.0)
    deg = np.zeros(n)
    np.add.at(deg, src, ew)
    inv = np.where(deg > 0, 1.0 / np.maximum(deg, 1e-12), 0.0)
    nm = None if node_pass is None else node_pass.astype(np.float64)

    qn = sv.shape[0]
    gs = np.zeros((qn, n))
    for qi in range(qn):
        frontier = _seed_mass(n, si[qi], sv[qi])
        if nm is not None:
            frontier = frontier * nm
        acc = np.zeros(n)
        for _ in range(pt.n_hops):
            msg = (frontier * inv)[src] * ew
            nxt = np.zeros(n)
            np.add.at(nxt, dst, msg)
            nxt *= pt.damping
            if nm is not None:
                nxt *= nm
            acc += nxt
            frontier = nxt
        gs[qi] = acc / pt.n_hops

    # dense Eq. 3 fusion over all N nodes (duplicate seed ids keep the max)
    sim = np.full((qn, n), -np.inf)
    for qi in range(qn):
        for i, s in zip(si[qi], sv[qi]):
            if i >= 0 and np.isfinite(s):
                sim[qi, i] = max(sim[qi, i], s)
    wv, wg = _weights(idx.cfg, sv)
    s_v = 1.0 - 0.5 * (1.0 - sim)
    gn = gs / np.maximum(gs.max(axis=1, keepdims=True), 1e-12)
    fused = np.where(np.isfinite(sim),
                     wv[:, None] * s_v + wg[:, None] * gn, wg[:, None] * gn)
    if node_pass is not None:
        fused = np.where(node_pass[None, :], fused, -np.inf)
    return _topk_rows(fused, np.arange(n), pt.k_fuse)


def _rescore(idx, pr: PRescore, sv, si) -> Ref:
    m = idx.modalities[pr.modality]
    rows = np.full(idx.n_nodes, -1, np.int64)
    rows[np.asarray(m.ids)] = np.arange(int(m.ids.shape[0]))
    dead = np.asarray(m.delta.tombstones)
    vecs = np.asarray(m.vectors, np.float64)
    q2 = np.asarray(pr.query, np.float64)
    new = np.full(sv.shape, -np.inf)
    for qi in range(sv.shape[0]):
        for ci in range(sv.shape[1]):
            s, i = sv[qi, ci], si[qi, ci]
            if not np.isfinite(s):
                continue
            # no embedding in this modality — never ingested, or deleted
            # (a tombstoned id must not contribute its dead vector)
            r = rows[i] if 0 <= i < idx.n_nodes \
                and not dead[min(i, len(dead) - 1)] else -1
            sim2 = float(q2[qi] @ vecs[r]) if r >= 0 else 0.0
            new[qi, ci] = (1.0 - pr.weight) * s + pr.weight * sim2
    return _sorted(new, si)


def _sorted(sv, si) -> Ref:
    order = np.argsort(-sv, axis=1)
    vals = np.take_along_axis(sv, order, axis=1)
    ids = np.where(np.isfinite(vals),
                   np.take_along_axis(si, order, axis=1), -1)
    return vals, ids, _pools_of(vals, ids)


def _setop(kind: str, left: Ref, right: Ref) -> Ref:
    la, li, _ = left
    ra, ri, _ = right
    qn = la.shape[0]
    width = la.shape[1] + ra.shape[1] if kind == "union" else la.shape[1]
    sv = np.full((qn, width), -np.inf)
    si = np.full((qn, width), -1, np.int64)
    pools: List[Dict[int, float]] = []
    for qi in range(qn):
        a = {int(i): float(s) for s, i in zip(la[qi], li[qi])
             if np.isfinite(s)}
        b = {int(i): float(s) for s, i in zip(ra[qi], ri[qi])
             if np.isfinite(s)}
        if kind == "union":
            d = dict(b)
            for i, s in a.items():
                d[i] = max(d.get(i, -np.inf), s)
        else:
            d = {i: 0.5 * (s + b[i]) for i, s in a.items() if i in b}
        pools.append(d)
        for ci, (i, s) in enumerate(
                sorted(d.items(), key=lambda kv: -kv[1])[:width]):
            sv[qi, ci], si[qi, ci] = s, i
    return sv, si, pools


def reference_execute(idx, phys: PhysicalPlan, truncate: bool = True) -> Ref:
    node_pass = (None if phys.node_pass is None
                 else np.asarray(phys.node_pass))
    if isinstance(phys.source, PSetOp):
        sv, si, pools = _setop(phys.source.kind,
                               reference_execute(idx, phys.source.left),
                               reference_execute(idx, phys.source.right))
        if node_pass is not None:   # outer Where post-filters the merged set
            keep = (si >= 0) & node_pass[np.clip(si, 0, len(node_pass) - 1)]
            sv, si, pools = _sorted(np.where(keep, sv, -np.inf), si)
    else:
        sv, si, pools = _seed(idx, phys.source, node_pass)
    for st in phys.stages:
        if isinstance(st, PTraverse):
            sv, si, pools = _traverse(idx, st, sv, si, node_pass)
        else:
            sv, si, pools = _rescore(idx, st, sv, si)
    if truncate:
        sv, si = _truncate(sv, si, phys.k)
    return sv, si, pools


def _truncate(sv, si, k) -> Tuple[np.ndarray, np.ndarray]:
    order = np.argsort(-sv, axis=1)[:, :k]
    vals = np.take_along_axis(sv, order, axis=1)
    ids = np.where(np.isfinite(vals),
                   np.take_along_axis(si, order, axis=1), -1)
    pad = k - vals.shape[1]
    if pad > 0:
        vals = np.concatenate(
            [vals, np.full((vals.shape[0], pad), -np.inf)], axis=1)
        ids = np.concatenate(
            [ids, np.full((ids.shape[0], pad), -1, ids.dtype)], axis=1)
    return vals, ids


def assert_matches(engine_out, ref: Ref, atol: float = 2e-5):
    """Tie-robust exactness: sorted scores equal, finiteness patterns equal,
    and every engine id carries exactly its oracle score (ids with equal
    scores may permute)."""
    sv, si = np.asarray(engine_out[0]), np.asarray(engine_out[1])
    rv, ri, pools = ref
    assert sv.shape == rv.shape, (sv.shape, rv.shape)
    fe, fr = np.isfinite(sv), np.isfinite(rv)
    np.testing.assert_array_equal(fe, fr)
    np.testing.assert_allclose(np.where(fe, sv, 0.0), np.where(fr, rv, 0.0),
                               rtol=2e-5, atol=atol)
    for qi in range(sv.shape[0]):
        for s, i in zip(sv[qi], si[qi]):
            if np.isfinite(s):
                assert int(i) in pools[qi], (qi, int(i))
                ref_s = pools[qi][int(i)]
                assert abs(ref_s - s) <= atol + 2e-5 * abs(ref_s), \
                    (qi, int(i), ref_s, float(s))
