"""Sharded, atomic, async-capable checkpointing (fault-tolerance substrate).

Layout: ``<dir>/step_<N>/`` holds one ``.npy`` per pytree leaf (flattened
key paths) + a ``manifest.json`` (treedef, shapes, dtypes, step, config
fingerprint). Writes go to ``step_<N>.tmp`` and are atomically renamed —
a crashed writer never corrupts the latest checkpoint. On multi-host
deployments each host writes its own shard files (``shard_<k>``); here
(single host) arrays are gathered before write, which is also the path the
dry-run exercises.

``CheckpointManager`` adds: retention (keep last k), async background
writes (thread pool), and restore-latest-on-restart (the trainer's
restart-from-step contract).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

# numpy can't serialise ML dtypes natively: store as a same-width integer
# view and restore via the manifest's recorded dtype
_EXOTIC_VIEWS = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _to_savable(arr: np.ndarray):
    name = str(arr.dtype)
    if name in _EXOTIC_VIEWS:
        return arr.view(_EXOTIC_VIEWS[name]), name
    return arr, name


def _from_saved(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC_VIEWS:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Atomic checkpoint write. Returns the final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        savable, dtype_name = _to_savable(arr)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), savable)
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape),
             "dtype": dtype_name})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def restore_checkpoint(directory: str, like: Any, step: Optional[int] = None
                       ) -> Tuple[Any, int, dict]:
    """Restores into the structure of ``like`` (shapes/dtypes validated).
    step=None -> latest. Returns (tree, step, extra)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten_with_paths(like)
    assert len(leaves) == len(manifest["leaves"]), "pytree structure changed"
    restored = []
    for (key, leaf), rec in zip(leaves, manifest["leaves"]):
        assert key == rec["key"], f"leaf order mismatch: {key} vs {rec['key']}"
        arr = _from_saved(np.load(os.path.join(path, rec["file"])), rec["dtype"])
        want = tuple(getattr(leaf, "shape", arr.shape))
        assert tuple(arr.shape) == want, (key, arr.shape, want)
        restored.append(jnp.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, restored)
    return tree, manifest["step"], manifest.get("extra", {})


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


class CheckpointManager:
    """Retention + async writes + restart contract."""

    def __init__(self, directory: str, keep: int = 3, async_writes: bool = True):
        self.directory = directory
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1) if async_writes else None
        self._pending = None
        self._lock = threading.Lock()
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        # materialise on host *now* (snapshot semantics), write in background
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        snap = jax.tree_util.tree_unflatten(treedef, host)

        def work():
            save_checkpoint(self.directory, step, snap, extra)
            self._gc()

        if self._pool is None:
            work()
        else:
            with self._lock:
                if self._pending is not None:
                    self._pending.result()
                self._pending = self._pool.submit(work)

    def wait(self):
        with self._lock:
            if self._pending is not None:
                self._pending.result()
                self._pending = None

    def restore_latest(self, like: Any):
        self.wait()
        return restore_checkpoint(self.directory, like)

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for m in
            (re.fullmatch(r"step_(\d+)", n) for n in os.listdir(self.directory))
            if m)
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
