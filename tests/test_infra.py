"""Infrastructure tests: checkpointing, trainer restart, compression,
fault monitors, data pipeline determinism, serving scheduler, HLO parser,
recsys model, cost model fit, learned forest."""
import os
import shutil
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.core.cost_model import CostModel
from repro.core.learned import ParamPredictor, RandomForestRegressor
from repro.data.pipeline import SyntheticLMStream, SyntheticRecsysStream
from repro.roofline.hlo_parse import count_collective_ops, parse_collective_bytes
from repro.runtime.fault import (HeartbeatMonitor, RetryPolicy, plan_remesh)
from repro.serving.scheduler import ContinuousBatcher, Request
from repro.train.compression import (compress_grads_int8, compress_grads_topk,
                                     init_error_feedback)


class TestCheckpoint:
    def test_roundtrip_bitwise(self):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16),
                      "d": jnp.asarray(3, jnp.int32)}}
        with tempfile.TemporaryDirectory() as tmp:
            save_checkpoint(tmp, 7, tree, extra={"note": "x"})
            got, step, extra = restore_checkpoint(tmp, tree)
            assert step == 7 and extra["note"] == "x"
            for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
                assert a.dtype == b.dtype
                np.testing.assert_array_equal(np.asarray(a, np.float32),
                                              np.asarray(b, np.float32))

    def test_manager_retention_and_latest(self):
        tree = {"w": jnp.zeros((2,))}
        with tempfile.TemporaryDirectory() as tmp:
            mgr = CheckpointManager(tmp, keep=2, async_writes=True)
            for s in (1, 2, 3):
                mgr.save(s, {"w": jnp.full((2,), float(s))})
            mgr.wait()
            got, step, _ = mgr.restore_latest(tree)
            assert step == 3
            np.testing.assert_allclose(np.asarray(got["w"]), 3.0)
            dirs = [d for d in os.listdir(tmp) if d.startswith("step_")]
            assert len(dirs) == 2  # retention


class TestCompression:
    def test_error_feedback_conserves_signal(self):
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                              jnp.float32)}
        ef = init_error_feedback(g)
        steps = 40
        total = jnp.zeros((64,))
        for _ in range(steps):
            comp, ef = compress_grads_topk(g, ef, frac=0.1)
            total = total + comp["w"]
        # error feedback: residual stays bounded, so the compressed running
        # sum converges to the dense sum at rate O(1/steps)
        dense = steps * g["w"]
        rel = float(jnp.linalg.norm(total - dense) / jnp.linalg.norm(dense))
        assert rel < 0.15

    def test_int8_roundtrip_small_error(self):
        g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(128,)),
                              jnp.float32)}
        ef = init_error_feedback(g)
        comp, ef = compress_grads_int8(g, ef)
        rel = float(jnp.linalg.norm(comp["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
        assert rel < 0.02


class TestFault:
    def test_straggler_detection(self):
        m = HeartbeatMonitor(4, ratio=1.5)
        for _ in range(8):
            for w in range(4):
                m.record(w, 1.0 if w != 2 else 3.0)
        rep = m.stragglers()
        assert rep.slow_workers == [2]

    def test_remesh_plan_keeps_global_batch(self):
        plan = plan_remesh(16, failed_workers=3, keep_global_batch=True)
        assert plan.new_data <= 13 and 16 % plan.new_data == 0
        assert plan.grad_accum_factor * plan.new_data == 16

    def test_retry_restores(self):
        calls = {"n": 0, "restores": 0}

        def step():
            calls["n"] += 1
            if calls["n"] < 3:
                raise RuntimeError("boom")
            return "ok"

        def restore():
            calls["restores"] += 1

        out = RetryPolicy(max_retries=3, backoff_s=0.0).run(step, restore)
        assert out == "ok" and calls["restores"] == 2


class TestPipeline:
    def test_determinism(self):
        s = SyntheticLMStream(100, 2, 8, seed=3)
        a = s.batch_at(5)
        b = s.batch_at(5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = s.batch_at(6)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_recsys_stream(self):
        s = SyntheticRecsysStream(8, 100, 16)
        b = s.batch_at(0)
        assert b["ids"].shape == (16, 8) and set(np.unique(b["labels"])) <= {0, 1}


class TestScheduler:
    def test_continuous_batching_slots(self):
        cb = ContinuousBatcher(2)
        for i in range(3):
            cb.submit(Request(i, np.arange(4), max_new_tokens=2))
        admitted = cb.admit()
        assert len(admitted) == 2
        cb.record_tokens(np.array([9, 9]))
        cb.record_tokens(np.array([9, 9]))
        assert cb.requests[0].done and cb.requests[1].done
        admitted = cb.admit()
        assert len(admitted) == 1  # third request enters the freed slot
        assert cb.any_active


class TestHLOParse:
    def test_counts_and_bytes(self):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        # single-device psum via jit over 1-device mesh is a no-op; instead
        # synthesise a tiny HLO text
        txt = """
ENTRY %main (p0: f32[8,4]) -> f32[8,4] {
  %p0 = f32[8,4]{1,0} parameter(0)
  %ar = f32[8,4]{1,0} all-reduce(%p0), replica_groups={}
  ROOT %out = f32[8,4]{1,0} add(%ar, %p0)
}
"""
        ops = count_collective_ops(txt)
        assert ops["all-reduce"] == 1
        by = parse_collective_bytes(txt)
        assert by["all-reduce"] == 2 * 8 * 4 * 4  # 2x factor for all-reduce

    def test_while_trip_multiplier(self):
        txt = """
%body.1 (p: f32[16]) -> f32[16] {
  %p = f32[16]{0} parameter(0)
  ROOT %ag = f32[16]{0} all-gather(%p), dimensions={0}
}
%cond.1 (p: f32[16]) -> pred[] {
  %p = f32[16]{0} parameter(0)
  ROOT %lt = pred[] constant(false)
}
ENTRY %main (p0: f32[16]) -> f32[16] {
  %p0 = f32[16]{0} parameter(0)
  ROOT %w = f32[16]{0} while(%p0), condition=%cond.1, body=%body.1
}
"""
        by1 = parse_collective_bytes(txt, default_trip_count=1)
        by8 = parse_collective_bytes(txt, while_trip_counts={"body": 8})
        assert by8["all-gather"] == 8 * by1["all-gather"]


class TestRecsys:
    def test_xdeepfm_trains(self):
        from repro.configs import smoke_config
        from repro.models.recsys import xdeepfm
        from repro.train.optimizer import AdamWConfig, adamw_update, init_adamw
        cfg = smoke_config("xdeepfm")
        params, _ = xdeepfm.init(cfg, jax.random.PRNGKey(0))
        opt = init_adamw(params)
        stream = SyntheticRecsysStream(cfg.n_sparse, cfg.vocab_per_field, 64)
        ocfg = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=50)

        @jax.jit
        def step(params, opt, batch):
            (l, aux), g = jax.value_and_grad(
                lambda p: xdeepfm.loss_fn(cfg, p, batch), has_aux=True)(params)
            params, opt, _ = adamw_update(ocfg, g, opt, params)
            return params, opt, l

        first = None
        for i in range(25):
            b = {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()}
            params, opt, l = step(params, opt, b)
            if first is None:
                first = float(l)
        assert float(l) < first

    def test_retrieval_ranks_similar_user_higher(self):
        from repro.configs import smoke_config
        from repro.models.recsys import xdeepfm
        cfg = smoke_config("xdeepfm")
        params, _ = xdeepfm.init(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        user = rng.integers(0, cfg.vocab_per_field, cfg.n_sparse).astype(np.int32)
        cands = rng.integers(0, cfg.vocab_per_field,
                             (64, cfg.n_sparse)).astype(np.int32)
        cands[0] = user   # identical item should score max
        s = xdeepfm.retrieval_score(cfg, params, jnp.asarray(user),
                                    jnp.asarray(cands))
        assert int(jnp.argmax(s)) == 0


class TestLearned:
    def test_forest_fits_function(self):
        rng = np.random.default_rng(0)
        x = rng.uniform(-1, 1, (400, 3))
        y = 2 * x[:, 0] + np.sin(3 * x[:, 1]) + 0.1 * rng.normal(size=400)
        f = RandomForestRegressor(n_trees=8, max_depth=6).fit(x[:300], y[:300])
        pred = f.predict(x[300:])
        ss_res = np.sum((y[300:] - pred) ** 2)
        ss_tot = np.sum((y[300:] - y[300:].mean()) ** 2)
        assert 1 - ss_res / ss_tot > 0.6

    def test_cost_model_fit_recovers_coefs(self):
        cm = CostModel(2.0, 0.03, 0.5)
        rng = np.random.default_rng(1)
        samples = [(int(10 ** rng.uniform(3, 7)), int(rng.uniform(32, 512)),
                    int(rng.uniform(0, 4)), int(rng.uniform(1, 32)))
                   for _ in range(200)]
        lat = [cm.cost(*s) + 0.01 * rng.normal() for s in samples]
        fit = CostModel().fit(samples, lat)
        assert fit.r2(samples, lat) > 0.99
        assert abs(fit.alpha - 2.0) < 0.2
