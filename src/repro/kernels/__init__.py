"""Pallas TPU kernels for HMGI's compute hot spots.

Each kernel package has: <name>.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd public wrapper; interpret=True on CPU), ref.py (pure-jnp oracle).

  ivf_topk         — fused int8-dequant scan + per-chunk partial top-1
                     (the paper's ANNS hot loop; ScaNN-on-TPU layout)
  segment_reduce   — one-hot-matmul segment sum (GNN message passing,
                     EmbeddingBag reduce; MXU-friendly scatter replacement)
  decode_attention — GQA single-token flash-decode with online softmax
                     (serving hot loop for the RAG engine)
"""
