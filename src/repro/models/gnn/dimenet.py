"""DimeNet — directional message passing with triplet angular bases
(Klicpera et al., arXiv:2003.03123).

Messages live on *edges*; an interaction block aggregates over triplets
(k→j→i): incoming messages m_kj are modulated by a joint spherical-Bessel ×
Legendre basis of (d_kj, angle_kji) through a bilinear layer — the
triplet-gather kernel regime (not expressible as SpMM).

Triplet lists are host-precomputed and capacity-bounded
(``max_triplets_per_edge``) so device shapes stay fixed; on the ring path the
line graph (edges-as-entities) reuses the same RingExec engine.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.params import Builder
from repro.equivariant.bessel import (angular_basis, radial_bessel_basis,
                                      spherical_bessel_basis)
from repro.sparse import segment as seg


class TripletIndex(NamedTuple):
    t_src: jax.Array    # (T,) int32 — index of edge kj
    t_dst: jax.Array    # (T,) int32 — index of edge ji
    t_mask: jax.Array   # (T,) bool


def build_triplets(edge_src: np.ndarray, edge_dst: np.ndarray,
                   edge_mask: np.ndarray, cap_per_edge: int = 8) -> TripletIndex:
    """Host-side: for each edge ji, up to ``cap_per_edge`` incoming edges kj
    (k≠i) at node j."""
    e = len(edge_src)
    in_edges: dict[int, list[int]] = {}
    for idx in range(e):
        if edge_mask[idx]:
            in_edges.setdefault(int(edge_dst[idx]), []).append(idx)
    t_src, t_dst = [], []
    for ji in range(e):
        if not edge_mask[ji]:
            continue
        j, i = int(edge_src[ji]), int(edge_dst[ji])
        cnt = 0
        for kj in in_edges.get(j, ()):
            if cnt >= cap_per_edge:
                break
            if int(edge_src[kj]) == i:       # exclude backtracking k == i
                continue
            t_src.append(kj)
            t_dst.append(ji)
            cnt += 1
    t = max(len(t_src), 1)
    pad = (-t) % 8 or 0
    ts = np.zeros(t + pad, np.int32)
    td = np.zeros(t + pad, np.int32)
    tm = np.zeros(t + pad, bool)
    ts[: len(t_src)] = t_src
    td[: len(t_dst)] = t_dst
    tm[: len(t_src)] = True
    return TripletIndex(jnp.asarray(ts), jnp.asarray(td), jnp.asarray(tm))


def build_triplet_ring(g, n_shards: int, cap_per_edge: int = 8,
                       t_cap: Optional[int] = None):
    """Host prep for the distributed line-graph ring.

    Edges are laid out per-shard as flat (R·E_cap) slots (the node-ring
    order); triplets (kj -> ji) group by source-edge-owner round. Returns
    (t_src, t_dst, t_mask) shaped (S, S, T_cap) with *local* edge slots.
    """
    import numpy as _np
    from repro.models.gnn.common import to_ring
    ring = to_ring(g, n_shards)
    s_, r_, e_cap = ring.esrc_local.shape
    n = int(_np.asarray(g.feats).shape[0])
    n_loc = n // n_shards

    # reconstruct each edge's (shard, slot) and global (src, dst)
    esrc = _np.asarray(ring.esrc_local)
    edst = _np.asarray(ring.edst_local)
    emask = _np.asarray(ring.edge_mask)
    instances = []    # (gsrc, gdst, shard, slot) per edge instance
    by_dst_node = {}  # global dst node -> [(shard, slot, global_src)]
    for s in range(s_):
        for r in range(r_):
            src_owner = (s - r) % n_shards
            for k in range(e_cap):
                if not emask[s, r, k]:
                    continue
                gsrc = src_owner * n_loc + esrc[s, r, k]
                gdst = s * n_loc + edst[s, r, k]
                slot = r * e_cap + k
                by_dst_node.setdefault(gdst, []).append((s, slot, gsrc))
                instances.append((gsrc, gdst, s, slot))

    tri = [[[] for _ in range(n_shards)] for _ in range(n_shards)]  # [dst_shard][round]
    for (j, i, s_ji, slot_ji) in instances:
        cnt = 0
        for (s_kj, slot_kj, k) in by_dst_node.get(j, ()):
            if k == i or cnt >= cap_per_edge:
                continue
            rnd = (s_ji - s_kj) % n_shards
            tri[s_ji][rnd].append((slot_kj, slot_ji))
            cnt += 1
    cap = t_cap or max(1, max(len(tri[s][r]) for s in range(n_shards)
                              for r in range(n_shards)))
    ts = _np.zeros((n_shards, n_shards, cap), _np.int32)
    td = _np.zeros((n_shards, n_shards, cap), _np.int32)
    tm = _np.zeros((n_shards, n_shards, cap), bool)
    for s in range(n_shards):
        for r in range(n_shards):
            for k, (a, b) in enumerate(tri[s][r][:cap]):
                ts[s, r, k] = a
                td[s, r, k] = b
                tm[s, r, k] = True
    return ring, jnp.asarray(ts), jnp.asarray(td), jnp.asarray(tm)


def ring_loss(cfg, params, ring, t_src, t_dst, t_mask, mesh, ce_sums_fn):
    """Distributed full-graph loss for DimeNet (see node_logits_ring)."""
    from jax.sharding import PartitionSpec as P
    from repro.models.gnn.common import RingExec
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_shards = 1
    for a in data_axes:
        n_shards *= mesh.shape[a]
    msize = mesh.shape.get("model", 1)
    nspec = P(data_axes if len(data_axes) > 1 else data_axes[0])
    # model-split the triplet work
    s_, r_, t_cap = t_src.shape
    pad = (-t_cap) % msize
    def tsplit(a, fill):
        if pad:
            a = jnp.pad(a, ((0, 0), (0, 0), (0, pad)), constant_values=fill)
        return a.reshape(s_, r_, msize, (t_cap + pad) // msize)
    tspec = P(nspec[0], None, "model", None)

    def shard_fn(params, feats, pos, esrc, edst, emask, nmask, labels,
                 tsrc, tdst, tmask):
        n_loc = feats.shape[0]
        e_loc = esrc.shape[1] * esrc.shape[2]
        ex_nodes = RingExec(esrc[0], edst[0], emask[0], n_loc, data_axes,
                            model_axis=None, ring_size=n_shards)
        ex_tri = RingExec(tsrc[0, :, 0], tdst[0, :, 0], tmask[0, :, 0], e_loc,
                          data_axes, model_axis="model" if msize > 1 else None,
                          ring_size=n_shards)
        logits = node_logits_ring(cfg, params, feats, pos, nmask,
                                  ex_nodes, ex_tri)
        out = ce_sums_fn(logits, labels, nmask)
        return jax.tree.map(lambda t: jax.lax.psum(t, data_axes), out)

    fn = jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), nspec, nspec, nspec, nspec, nspec, nspec, nspec,
                  tspec, tspec, tspec),
        out_specs=P(),
        check_vma=False,
    )
    return fn(params, ring.feats, ring.positions, ring.esrc_local,
              ring.edst_local, ring.edge_mask, ring.node_mask, ring.labels,
              tsplit(t_src, 0), tsplit(t_dst, 0), tsplit(t_mask, False))


def _mlp(b: Builder, name: str, dims):
    sub = b.sub()
    for i, (di, do) in enumerate(zip(dims[:-1], dims[1:])):
        sub.dense(f"w{i}", (di, do), (None, "hidden"), fan_in=di)
        sub.zeros(f"b{i}", (do,), (None,))
    b.child(name, sub)


def _apply_mlp(p, x, n, act=jax.nn.silu, final_act=True):
    for i in range(n):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n - 1 or final_act:
            x = act(x)
    return x


def init(cfg, key, d_feat_in: int, n_out: int):
    d = cfg.d_hidden
    nr, ns, nb = cfg.n_radial, cfg.n_spherical, cfg.n_bilinear
    b = Builder(key, dtype=jnp.float32)
    b.dense("enc", (d_feat_in, d), (None, "hidden"), fan_in=d_feat_in)
    b.dense("rbf_lin", (nr, d), (None, "hidden"), fan_in=nr)
    _mlp(b, "edge_embed", (3 * d, d, d))
    blocks = []
    for _ in range(cfg.n_layers):
        lb = b.sub()
        lb.dense("w_msg", (d, d), (None, "hidden"), fan_in=d)
        lb.dense("w_sbf", (ns * nr, nb), (None, None), fan_in=ns * nr)
        lb.dense("w_bilinear", (d, nb, d), (None, None, "hidden"), fan_in=d * nb)
        _mlp(lb, "update", (d, d, d))
        _mlp(lb, "out_node", (d, d, d))
        blocks.append(lb.build())
    b.params["blocks"] = [p for p, _ in blocks]
    b.axes["blocks"] = [a for _, a in blocks]
    b.dense("head", (d, n_out), (None, None), fan_in=d)
    return b.build()


def node_logits(cfg, params, feats, positions, node_mask, ex,
                triplets: Optional[TripletIndex] = None):
    """Single-graph path (LocalExec). Edge messages + triplet interactions."""
    g = ex.g
    d = cfg.d_hidden
    h = feats @ params["enc"]                                   # (N, d)
    rel, dist = ex.edge_geometry()
    rbf = radial_bessel_basis(dist, cfg.n_radial, cfg.cutoff)   # (E, nr)
    rbf_d = rbf @ params["rbf_lin"]                             # (E, d)
    m = _apply_mlp(params["edge_embed"],
                   jnp.concatenate([h[g.edge_src], h[g.edge_dst], rbf_d], -1), 2)
    m = m * g.edge_mask[:, None]                                # (E, d)

    if triplets is not None:
        # joint (distance × angle) basis per triplet
        ts, td, tm = triplets
        v_kj = rel[ts]                                          # k -> j
        v_ji = rel[td]                                          # j -> i
        cos_a = jnp.sum(-v_kj * v_ji, axis=-1) / jnp.maximum(
            jnp.linalg.norm(v_kj, axis=-1) * jnp.linalg.norm(v_ji, axis=-1), 1e-9)
        angle = jnp.arccos(jnp.clip(cos_a, -1 + 1e-7, 1 - 1e-7))
        sbf_r = spherical_bessel_basis(dist[ts], cfg.n_spherical, cfg.n_radial,
                                       cfg.cutoff)              # (T, ns, nr)
        cbf = angular_basis(angle, cfg.n_spherical)             # (T, ns)
        sbf = (sbf_r * cbf[..., None]).reshape(ts.shape[0], -1)  # (T, ns*nr)

    for bp in params["blocks"]:
        if triplets is not None:
            ts, td, tm = triplets
            mk = m[ts] @ bp["w_msg"]                            # (T, d)
            basis = sbf @ bp["w_sbf"]                           # (T, nb)
            contrib = jnp.einsum("td,dbf,tb->tf", mk, bp["w_bilinear"], basis)
            contrib = jnp.where(tm[:, None], contrib, 0.0)
            t_agg = seg.segment_sum(contrib, td, m.shape[0])    # (E, d)
            m = m + _apply_mlp(bp["update"], t_agg, 2)
        # edge -> node
        node_in = seg.segment_sum(m * g.edge_mask[:, None], g.edge_dst,
                                  h.shape[0])
        h = h + _apply_mlp(bp["out_node"], node_in, 2)
        h = h * node_mask[:, None]
    return h @ params["head"]


# ---------------------------------------------------------------------------
# distributed (ring) path: node ring for edge endpoints + line-graph ring for
# triplets (edges are entities; triplet lists grouped by source-edge-owner
# rounds). Edges live with their destination-node owner, so edge->node
# aggregation is local. See docs/DESIGN.md §5.
# ---------------------------------------------------------------------------

def node_logits_ring(cfg, params, feats, positions, node_mask, ex_nodes,
                     ex_tri):
    d = cfg.d_hidden
    n = feats.shape[0]
    h = feats @ params["enc"]

    pos_src = ex_nodes.gather_src(positions)                   # (E_loc, 3)
    edst, emask = ex_nodes.dst_index()
    pos_dst = positions[edst]
    rel = pos_src - pos_dst
    dist = jnp.where(emask, jnp.linalg.norm(rel, axis=-1), 0.0)
    rbf_d = radial_bessel_basis(dist, cfg.n_radial, cfg.cutoff) @ params["rbf_lin"]
    h_src = ex_nodes.gather_src(h)
    m = _apply_mlp(params["edge_embed"],
                   jnp.concatenate([h_src, h[edst], rbf_d], -1), 2)
    m = m * emask[:, None]                                     # (E_loc, d)

    for bp in params["blocks"]:
        payload = jnp.concatenate([m, rel, dist[:, None]], axis=-1)

        def t_msg(srcs, dsts, bp=bp):
            m_kj = srcs[:, :d]
            rel_kj = srcs[:, d:d + 3]
            dist_kj = srcs[:, d + 3]
            rel_ji = dsts[:, d:d + 3]
            cos_a = jnp.sum(-rel_kj * rel_ji, axis=-1) / jnp.maximum(
                jnp.linalg.norm(rel_kj, axis=-1)
                * jnp.linalg.norm(rel_ji, axis=-1), 1e-9)
            angle = jnp.arccos(jnp.clip(cos_a, -1 + 1e-7, 1 - 1e-7))
            sbf_r = spherical_bessel_basis(dist_kj, cfg.n_spherical,
                                           cfg.n_radial, cfg.cutoff)
            cbf = angular_basis(angle, cfg.n_spherical)
            sbf = (sbf_r * cbf[..., None]).reshape(srcs.shape[0], -1)
            mk = m_kj @ bp["w_msg"]
            basis = sbf @ bp["w_sbf"]
            return jnp.einsum("td,dbf,tb->tf", mk, bp["w_bilinear"], basis)

        t_agg = ex_tri.push(payload, t_msg, d)                 # (E_loc, d)
        m = m + _apply_mlp(bp["update"], t_agg, 2) * emask[:, None]
        node_in = seg.segment_sum(m * emask[:, None], edst, n)
        h = h + _apply_mlp(bp["out_node"], node_in, 2)
        h = h * node_mask[:, None]
    return h @ params["head"]
