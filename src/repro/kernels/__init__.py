"""Pallas TPU kernels for HMGI's compute hot spots.

Each kernel package has: <name>.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd public wrapper), ref.py (pure-jnp oracle). Off-TPU the wrappers
run the same kernel bodies under ``interpret=True`` — each package probes the
backend once, lazily on the first kernel call (``_interpret_mode``, cached),
so CPU CI and laptops execute the identical code path the TPU compiles while
app-level JAX setup (``jax.distributed.initialize``) still runs first.

  ivf_topk         — fused int8-dequant scan + per-chunk partial top-1
                     (the paper's ANNS hot loop; ScaNN-on-TPU layout).
                     Two entry points: ``scan_topk_quantized`` scans one
                     corpus slab shared by all queries (delta store,
                     monolithic baseline); ``scan_topk_quantized_batched``
                     scans per-query slabs — the IVF probe path gathers each
                     query's probed partitions as contiguous row blocks of
                     the flattened (K·cap, d) index slab (see
                     ``core/ivf.py:IVFIndex.slab_view``) and rescores the
                     top-k chunk survivors exactly. int8 rows never
                     dequantize to fp32 in HBM on either path.
  segment_reduce   — one-hot-matmul segment sum (GNN message passing,
                     EmbeddingBag reduce; MXU-friendly scatter replacement)
  decode_attention — GQA single-token flash-decode with online softmax
                     (serving hot loop for the RAG engine)

Benchmarks: ``benchmarks/kernels_bench.py`` times the kernel-backed probe
path against the legacy fp32 gather-dequant einsum on identical shapes;
``benchmarks/hybrid_bench.py`` covers the downstream candidate-sparse fusion
stage.
"""
