"""Hypothesis property tests on system invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import delta as delta_mod
from repro.core import ivf as ivf_mod
from repro.core import partitioner
from repro.core.fusion import FusionWeights, fuse
from repro.core.quantization import dequantize, quantize, quantized_scores
from repro.sparse import segment as seg

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

_f32 = st.floats(-10, 10, allow_nan=False, width=32, allow_subnormal=False)


@st.composite
def small_matrix(draw, max_n=24, max_d=16, min_d=2):
    n = draw(st.integers(2, max_n))
    d = draw(st.integers(min_d, max_d))
    data = draw(st.lists(_f32, min_size=n * d, max_size=n * d))
    return np.asarray(data, np.float32).reshape(n, d)


class TestQuantization:
    @given(small_matrix())
    def test_roundtrip_error_bound(self, x):
        """Eq. 2 invariant: |e - deq(q)|inf <= per-vector step size."""
        qv = quantize(jnp.asarray(x), 8)
        err = np.abs(np.asarray(dequantize(qv)) - x)
        step = np.asarray(qv.scale)      # (n, 1)
        assert np.all(err <= step + 1e-5)

    @given(small_matrix())
    def test_4bit_within_bound(self, x):
        qv = quantize(jnp.asarray(x), 4)
        err = np.abs(np.asarray(dequantize(qv)) - x)
        step = np.asarray(qv.scale)
        assert np.all(err <= step + 1e-5)   # step = range/15 per vector

    @given(small_matrix(max_n=12, max_d=12))
    def test_score_identity(self, x):
        """scale*(q . qint) + min*sum(q) == q . dequant(e)."""
        qv = quantize(jnp.asarray(x), 8)
        q = jnp.asarray(x[:2])
        s1 = np.asarray(quantized_scores(q, qv))
        s2 = np.asarray(q @ dequantize(qv).T)
        np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)

    @given(small_matrix())
    def test_memory_halves_per_bit_drop(self, x):
        """The paper's 50% memory saving: 8-bit is half of 16-bit storage;
        4-bit halves again (up to one pad byte per row for odd dims)."""
        n = x.shape[0]
        b16 = quantize(jnp.asarray(x), 16).data.nbytes
        b8 = quantize(jnp.asarray(x), 8).data.nbytes
        b4 = quantize(jnp.asarray(x), 4).data.nbytes
        assert b8 * 2 == b16
        assert b4 <= b8 // 2 + n


class TestKMeans:
    @given(small_matrix(max_n=32))
    def test_assignment_is_argmin(self, x):
        k = min(4, len(x))
        st_ = partitioner.fit(jax.random.PRNGKey(0), jnp.asarray(x), k, 4)
        a = np.asarray(partitioner.assign(jnp.asarray(x), st_.centroids))
        d = ((x[:, None, :] - np.asarray(st_.centroids)[None]) ** 2).sum(-1)
        np.testing.assert_array_equal(a, d.argmin(1))


class TestTopKMerge:
    @given(st.integers(1, 6), st.lists(_f32, min_size=12, max_size=12))
    def test_merge_associative_equals_global(self, k, vals):
        s = np.asarray(vals, np.float32).reshape(1, -1)
        ids = np.arange(12, dtype=np.int32).reshape(1, -1)
        a = (jnp.asarray(s[:, :4]), jnp.asarray(ids[:, :4]))
        b = (jnp.asarray(s[:, 4:8]), jnp.asarray(ids[:, 4:8]))
        c = (jnp.asarray(s[:, 8:]), jnp.asarray(ids[:, 8:]))
        ab_c = ivf_mod.merge_topk(*ivf_mod.merge_topk(*a, *b, k), *c, k)
        a_bc = ivf_mod.merge_topk(*a, *ivf_mod.merge_topk(*b, *c, k), k)
        glob = jax.lax.top_k(jnp.asarray(s), k)[0]
        np.testing.assert_allclose(np.asarray(ab_c[0]), np.asarray(glob))
        np.testing.assert_allclose(np.asarray(a_bc[0]), np.asarray(glob))


class TestDelta:
    @given(small_matrix(max_n=16, min_d=4))
    def test_delta_search_equals_concat_search(self, x):
        """stable+delta search == brute force over the union corpus."""
        x = x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-9)
        n = len(x)
        n_stable = max(n // 2, 1)
        stable, over = ivf_mod.build(jax.random.PRNGKey(0),
                                     jnp.asarray(x[:n_stable]),
                                     jnp.arange(n_stable),
                                     n_partitions=min(2, n_stable), bits=16)
        d = delta_mod.init(16, x.shape[1], max_ids=n)
        if n > n_stable:
            d = delta_mod.insert(d, jnp.asarray(x[n_stable:]),
                                 jnp.arange(n_stable, n))
        sv, si = delta_mod.search_with_delta(stable, d, jnp.asarray(x[:2]),
                                             n_probe=2, k=min(3, n))
        full = x @ x[:2].T
        best = np.argsort(-full[:, 0])[: min(3, n)]
        overflowed = set(np.where(np.asarray(over))[0])
        got = [i for i in np.asarray(si)[0] if i >= 0]
        want = [b for b in best if b not in overflowed]
        # top-1 (excluding capacity-overflow rows) must be found
        if want:
            assert want[0] in got


class TestFusion:
    @given(st.floats(0.05, 0.95, allow_subnormal=False), st.floats(0.0, 1.0, allow_subnormal=False),
           st.floats(0.0, 1.0, allow_subnormal=False))
    def test_graph_term_orders_vector_ties(self, wv, g1, g2):
        """Eq. 3: with equal vector similarity, the candidate with more
        traversal mass must not rank lower (monotone in the graph term)."""
        vs = jnp.asarray([[0.7, 0.7]])
        g = jnp.asarray([[g1, g2]])
        w = FusionWeights(jnp.asarray([wv]), jnp.asarray([1.0 - wv]))
        f = np.asarray(fuse(vs, g, w))[0]
        if g1 > g2:
            assert f[0] >= f[1] - 1e-6
        elif g2 > g1:
            assert f[1] >= f[0] - 1e-6

    @given(st.floats(0.05, 0.95, allow_subnormal=False))
    def test_vector_term_orders_graph_ties(self, wv):
        vs = jnp.asarray([[0.9, 0.2]])
        g = jnp.asarray([[0.5, 0.5]])
        w = FusionWeights(jnp.asarray([wv]), jnp.asarray([1.0 - wv]))
        f = np.asarray(fuse(vs, g, w))[0]
        assert f[0] > f[1]


class TestSegmentOps:
    @given(st.integers(2, 20), st.integers(2, 8))
    def test_segment_sum_vs_numpy(self, e, n):
        rng = np.random.default_rng(e * 31 + n)
        data = rng.normal(size=(e, 3)).astype(np.float32)
        ids = rng.integers(0, n, e).astype(np.int32)
        out = np.asarray(seg.segment_sum(jnp.asarray(data), jnp.asarray(ids), n))
        want = np.zeros((n, 3), np.float32)
        np.add.at(want, ids, data)
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    @given(st.integers(2, 20), st.integers(2, 8))
    def test_segment_softmax_normalised(self, e, n):
        rng = np.random.default_rng(e * 17 + n)
        logits = rng.normal(size=(e, 2)).astype(np.float32)
        ids = rng.integers(0, n, e).astype(np.int32)
        w = np.asarray(seg.segment_softmax(jnp.asarray(logits), jnp.asarray(ids), n))
        sums = np.zeros((n, 2))
        np.add.at(sums, ids, w)
        present = np.zeros(n, bool)
        present[ids] = True
        np.testing.assert_allclose(sums[present], 1.0, rtol=1e-5)
