"""Modality-aware K-means partitioning (paper Eq. 1) + workload-aware repartitioning.

``Cluster Assignment = argmin_c ||e - mu_c||^2``  — fitted per modality, so
each modality gets its own centroid set and per-partition index
(docs/DESIGN.md C2). On TPU the assignment is a single matmul:
argmin_c ||e-mu||² = argmax_c (e·mu - ||mu||²/2), which is how both ``fit``
and ``assign`` are written here.

Parked partitions (docs/DESIGN.md §3.4): a merged-away partition keeps its
slot in the fixed-shape (K, ...) layout but its centroid is replaced with the
``parked_centroid`` sentinel — a vector whose norm is so large that the
assignment score ``e·mu - ||mu||²/2`` is astronomically negative, so neither
``assign`` nor ``assign_topk`` ever routes a vector or a probe there ahead of
a live partition. Parking frees a partition for a later split without
changing any jitted shape.
"""
from __future__ import annotations

import functools
import threading
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class KMeansState(NamedTuple):
    centroids: jax.Array        # (K, d)
    counts: jax.Array           # (K,) assignment counts from the last fit
    inertia: jax.Array          # scalar: mean squared distance


def assign(x: jax.Array, centroids: jax.Array) -> jax.Array:
    """Eq. 1: nearest-centroid ids for x (N, d). One matmul + argmax."""
    half_sq = 0.5 * jnp.sum(centroids * centroids, axis=-1)       # (K,)
    scores = x @ centroids.T - half_sq[None, :]                   # (N, K)
    return jnp.argmax(scores, axis=-1).astype(jnp.int32)


def assign_topk(x: jax.Array, centroids: jax.Array, k: int):
    """Top-k nearest centroids (used for n_probe partition selection)."""
    half_sq = 0.5 * jnp.sum(centroids * centroids, axis=-1)
    scores = x @ centroids.T - half_sq[None, :]
    vals, idx = jax.lax.top_k(scores, k)
    return idx.astype(jnp.int32), vals


@jax.jit
def assign_with_distance(x: jax.Array, centroids: jax.Array):
    """Eq. 1 assignment plus the squared distance to the winning centroid.

    Returns ``(assignment (N,) int32, dist2 (N,) fp32)``. The distance feeds
    the write-time drift statistics (maintenance/stats.py): the mean assigned
    distance of *new* rows vs. the build-time baseline is the centroid-drift
    signal that triggers a local recluster."""
    a = assign(x, centroids)
    d = x - centroids[a]
    return a, jnp.sum(d * d, axis=-1)


# ---------------------------------------------------------------------------
# parked partitions (merge-cold leaves the slot, retires the centroid)
# ---------------------------------------------------------------------------

# any centroid with norm beyond this is a parked sentinel: its assignment
# score e·mu - ||mu||²/2 ≈ -PARKED_NORM²/2 can never beat a live centroid's
# (unit-norm corpora score in [-1, 1])
PARKED_NORM = 32768.0


def parked_centroid(dim: int) -> np.ndarray:
    """The sentinel centroid of a merged-away partition (see module doc)."""
    c = np.zeros((dim,), np.float32)
    c[0] = PARKED_NORM
    return c


def parked_mask(centroids) -> np.ndarray:
    """(K,) bool — which partitions are parked (centroid is the sentinel)."""
    c = np.asarray(centroids)
    return np.sum(c * c, axis=-1) >= (0.5 * PARKED_NORM) ** 2


def live_partitions(centroids) -> int:
    """Number of partitions that can win an assignment / deserve a probe."""
    return int(np.sum(~parked_mask(centroids)))


@functools.partial(jax.jit, static_argnames=("n_clusters", "n_iters"))
def fit(key: jax.Array, x: jax.Array, n_clusters: int, n_iters: int = 16) -> KMeansState:
    """Lloyd's K-means (k-means++-lite seeding: random distinct samples)."""
    n = x.shape[0]
    idx0 = jax.random.choice(key, n, (n_clusters,), replace=n < n_clusters)
    cents = x[idx0]

    def step(cents, _):
        a = assign(x, cents)
        onehot_sum = jax.ops.segment_sum(x, a, num_segments=n_clusters)
        counts = jax.ops.segment_sum(jnp.ones((n,), x.dtype), a, num_segments=n_clusters)
        new = onehot_sum / jnp.maximum(counts[:, None], 1.0)
        # empty clusters keep their previous centroid
        new = jnp.where(counts[:, None] > 0, new, cents)
        return new, counts

    cents, counts = jax.lax.scan(step, cents, None, length=n_iters)
    counts = counts[-1]
    a = assign(x, cents)
    d = x - cents[a]
    inertia = jnp.mean(jnp.sum(d * d, axis=-1))
    return KMeansState(centroids=cents, counts=counts, inertia=inertia)


# ---------------------------------------------------------------------------
# workload-aware repartitioning (paper §3.2: online adjustment on imbalance)
# ---------------------------------------------------------------------------

class WorkloadStats:
    """Host-side probe-frequency tracker driving online repartitioning.

    Search threads bump ``record`` concurrently with writer-side
    ``reset``/``should_repartition``, so every touch of ``hits`` goes
    through ``_lock`` (``np.add.at`` is not atomic under concurrent
    mutation of the same buffer). Guarded-by contract enforced as
    staticcheck HMG201; readers take ``hits_snapshot()``."""

    def __init__(self, n_partitions: int, imbalance_threshold: float = 4.0):
        self.hits = np.zeros(n_partitions, np.int64)
        self.threshold = imbalance_threshold
        self._lock = threading.Lock()

    def record(self, probed_partitions: np.ndarray):
        idx = np.asarray(probed_partitions).reshape(-1)
        with self._lock:
            np.add.at(self.hits, idx, 1)

    def hits_snapshot(self) -> np.ndarray:
        """Coherent copy for readers (state_tree, repartition decisions)."""
        with self._lock:
            return self.hits.copy()

    def load_hits(self, hits: np.ndarray) -> None:
        """Restore path: replace the counters wholesale."""
        with self._lock:
            self.hits = np.asarray(hits, np.int64).copy()

    @property
    def imbalance(self) -> float:
        with self._lock:
            hits = self.hits.copy()
        mean = hits.mean() + 1e-9
        return float(hits.max() / mean)

    def should_repartition(self) -> bool:
        with self._lock:
            hits = self.hits.copy()
        mean = hits.mean() + 1e-9
        return hits.sum() > 0 and float(hits.max() / mean) > self.threshold

    def reset(self):
        with self._lock:
            self.hits[:] = 0


def split_two(key, members: jax.Array, n_iters: int = 8):
    """K=2 Lloyd's fit over one partition's members — the local step behind
    an incremental split (maintenance/executor.py). Returns
    ``(centroids (2, d), assignment (n,))``; only the members move, never the
    rest of the corpus."""
    sub = fit(key, members, 2, n_iters)
    return sub.centroids, assign(members, sub.centroids)


def split_hot_partition(key, x, state: KMeansState, hot: int) -> KMeansState:
    """Legacy stop-the-world split: re-fit K=2 on the hot partition's members
    and overwrite (hot, coldest) centroids; the caller then rebuilds the whole
    slab against the new centroid set. Superseded by the bounded-work split in
    ``repro.maintenance.executor`` (which moves only the hot partition's rows,
    byte-identically) — kept as the reference implementation."""
    a = assign(x, state.centroids)
    # host-side path (numpy): membership gather of the hot partition
    xs = np.asarray(x)
    an = np.asarray(a)
    members = xs[an == hot]
    if len(members) < 2:
        return state
    sub = fit(key, jnp.asarray(members), 2, 8)
    cents = np.asarray(state.centroids).copy()
    cold = int(np.asarray(state.counts).argmin())
    cents[hot] = np.asarray(sub.centroids[0])
    cents[cold] = np.asarray(sub.centroids[1])
    new = KMeansState(jnp.asarray(cents), state.counts, state.inertia)
    return new
