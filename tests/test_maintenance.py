"""Adaptive index maintenance (docs/DESIGN.md §3.4): bounded-work
split/merge/recluster/incremental-compact must preserve the visible corpus
exactly.

Pinned invariants:
- maintain() on an empty delta is a no-op (stable bytes untouched);
- an incremental drain sequence ends in the same searchable state as one
  full ``compact`` (same visible rows, stable-representation scores);
- an all-tombstone partition merges away (parks) without resurrecting a
  single deleted id;
- an interleaved insert/update/delete/search/maintain stream matches the
  ``query_ref`` brute-force oracle at full probe after every step;
- a recluster changes no result at full probe (only future routing);
- maintenance never drops a write, even when every partition is full;
- any state-changing action invalidates the sharded replica.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import HMGIIndex
from repro.core import delta as delta_mod
from repro.core.cost_model import (MaintenanceSummary, plan_maintenance)
from repro.core.partitioner import parked_mask
from repro.query import Q
from repro.query.planner import compile_plan
from repro.serving.scheduler import MaintenanceDriver

from query_ref import assert_matches, reference_execute


def _unit(v):
    return v / np.linalg.norm(v, axis=-1, keepdims=True)


def _build(n=400, d=32, seed=0, **over):
    rng = np.random.default_rng(seed + 11)
    v = _unit(rng.normal(size=(n, d)).astype(np.float32))
    over = dict({"delta_capacity": 64, "delta_rescore_margin": 64}, **over)
    cfg = get_config("hmgi").replace(n_partitions=8, n_probe=8, top_k=5,
                                     kmeans_iters=4, **over)
    idx = HMGIIndex(cfg, seed=0)
    idx.ingest({"text": (np.arange(n, dtype=np.int32), v)}, n_nodes=n + 100)
    return idx, v


def _oracle_check(idx, q, k=5, n_probe=8):
    """Full-probe exactness vs the brute-force reference interpreter."""
    plan = Q.vector("text", q, n_probe=n_probe).topk(k)
    phys = compile_plan(idx, plan)
    assert_matches(idx.query(plan), reference_execute(idx, phys))


class TestNoop:
    def test_empty_delta_maintain_is_noop(self):
        idx, v = _build()
        m = idx.modalities["text"]
        before = (np.asarray(m.ivf.data).copy(), np.asarray(m.ivf.ids).copy(),
                  np.asarray(m.ivf.centroids).copy(), int(m.delta.count))
        report = idx.maintain("text")
        assert report.is_noop and report.describe() == "text: noop"
        np.testing.assert_array_equal(np.asarray(m.ivf.data), before[0])
        np.testing.assert_array_equal(np.asarray(m.ivf.ids), before[1])
        np.testing.assert_array_equal(np.asarray(m.ivf.centroids), before[2])
        assert int(m.delta.count) == before[3]

    def test_plan_maintenance_noop_below_thresholds(self):
        K, cap = 8, 64
        s = MaintenanceSummary(
            live=np.full(K, 40), free=np.full(K, 24),
            heat=np.full(K, 10), dead=np.zeros(K, np.int64),
            drift=np.zeros(K), parked=np.zeros(K, bool),
            delta_live=3, delta_used=3, delta_capacity=64, cap=cap)
        assert plan_maintenance(s, budget_rows=1024, chunk=64) == []


class TestPolicy:
    def _summary(self, **over):
        K, cap = 8, 64
        base = dict(live=np.full(K, 40), free=np.full(K, 24),
                    heat=np.full(K, 10), dead=np.zeros(K, np.int64),
                    drift=np.zeros(K), parked=np.zeros(K, bool),
                    delta_live=3, delta_used=3, delta_capacity=64, cap=cap)
        base.update(over)
        return MaintenanceSummary(**base)

    def test_delta_pressure_emits_chunks_within_budget(self):
        s = self._summary(delta_live=48, delta_used=48)
        acts = plan_maintenance(s, budget_rows=32, chunk=16)
        assert [a.kind for a in acts] == ["compact_chunk", "compact_chunk"]
        assert sum(a.rows for a in acts) <= 32

    def test_need_rows_forces_drain_regardless_of_pressure(self):
        s = self._summary(delta_live=4, delta_used=10)
        acts = plan_maintenance(s, budget_rows=8, chunk=16, need_rows=10)
        assert acts and all(a.kind == "compact_chunk" for a in acts)
        assert sum(a.rows for a in acts) >= 10

    def test_hollow_partition_plans_merge(self):
        live = np.full(8, 40)
        live[3] = 2                       # hollowed out
        dead = np.zeros(8, np.int64)
        dead[3] = 38
        s = self._summary(live=live, dead=dead)
        acts = plan_maintenance(s, budget_rows=1024, chunk=64)
        assert any(a.kind == "merge_cold" and a.partition == 3 for a in acts)

    def test_heat_skew_plans_split_with_enabling_merge(self):
        heat = np.full(8, 2)
        heat[5] = 1000
        live = np.full(8, 60)
        live[2] = 5
        s = self._summary(heat=heat, live=live)
        acts = plan_maintenance(s, budget_rows=1024, chunk=64)
        kinds = [a.kind for a in acts]
        assert "split_hot" in kinds
        # no parked slot: the enabling merge must come before the split
        assert "merge_cold" in kinds
        assert kinds.index("merge_cold") < kinds.index("split_hot")

    def test_drift_plans_recluster(self):
        drift = np.zeros(8)
        drift[1] = 0.8
        s = self._summary(drift=drift)
        acts = plan_maintenance(s, budget_rows=1024, chunk=64)
        assert [(a.kind, a.partition) for a in acts] == [("recluster", 1)]


class TestIncrementalCompact:
    def test_drain_matches_full_compact(self):
        """The same pure-insert stream, drained in chunks vs one full
        compact, must end in the same searchable state: identical
        partition membership, scores equal to within one int8 quantization
        step (the two paths quantize the same vectors under different
        batch shapes, and XLA fusion may flip the last rounding bit — the
        drain moves the delta's stored bytes, the rebuild re-quantizes).
        With interleaved updates/deletes the two paths may additionally
        differ in *placement* — which rows overflow to the fp32 delta —
        and each is then pinned to its own oracle by
        TestInterleavedOracle instead."""
        streams = []
        for _ in range(2):
            idx, v = _build(maint_auto=False, delta_capacity=256)
            rng = np.random.default_rng(3)
            ids = np.arange(450, 510, dtype=np.int32)       # brand-new ids
            vecs = rng.normal(size=(60, 32)).astype(np.float32)
            idx.insert("text", ids, vecs)
            streams.append((idx, v))
        (a, v), (b, _) = streams
        # a: incremental chunks to empty (need_rows forces drains past the
        # pressure threshold, 32 rows of bounded work per call); b: one
        # full compact
        while int(a.modalities["text"].delta.count):
            r = a.maintain("text", budget=32, need_rows=32)
            if all(res.get("drained", 0) == 0 and not res.get("reclaimed", 0)
                   for _, res in r.actions) or r.is_noop:
                break
        b.compact("text")
        assert int(a.modalities["text"].delta.count) == 0
        ma, mb = a.modalities["text"], b.modalities["text"]
        # identical placement: every partition holds the same id set
        ia_slab, ib_slab = np.asarray(ma.ivf.ids), np.asarray(mb.ivf.ids)
        for p in range(ma.ivf.n_partitions):
            assert (set(ia_slab[p][ia_slab[p] >= 0])
                    == set(ib_slab[p][ib_slab[p] >= 0])), p
        q = _unit(np.random.default_rng(5).normal(size=(16, 32))
                  .astype(np.float32))
        sa, ia = a.search(q, "text", k=8)
        sb, ib = b.search(q, "text", k=8)
        # one int8 step of a unit-norm row ≈ 2/255 per element: scores
        # agree to well under that
        np.testing.assert_allclose(np.asarray(sa), np.asarray(sb),
                                   rtol=0, atol=5e-3)

    def test_update_drain_clears_superseded_and_serves_latest(self):
        """An updated id drained incrementally must overwrite its stable
        slot: the pre-update vector never resurfaces, the superseded bit
        clears, and the new version serves from stable."""
        idx, v = _build(maint_auto=False)
        d = 32
        new = np.zeros((1, d), np.float32)
        new[0, 1] = 1.0
        idx.insert("text", np.array([0], np.int32), new)
        m = idx.modalities["text"]
        assert bool(np.asarray(m.delta.superseded)[0])
        idx.maintain("text", budget=4096, need_rows=1)   # force past pressure
        assert int(m.delta.count) == 0
        assert not bool(np.asarray(m.delta.superseded)[0])
        sv, si = idx.search(new, "text", k=1)
        assert int(si[0, 0]) == 0 and float(sv[0, 0]) > 0.99
        sv, si = idx.search(v[:1], "text", k=5)   # query the OLD vector
        for x, s in zip(np.asarray(si)[0], np.asarray(sv)[0]):
            assert x != 0 or s < 0.9, (x, s)

    def test_forced_drain_during_update_insert_keeps_one_version(self):
        """Regression: an insert that forces a mid-call drain (batch larger
        than the delta's free slots) while carrying an update must not end
        with two visible versions. The drain must run BEFORE the batch's
        supersede bookkeeping — draining after it would move the id's old
        delta version into stable and clear its superseded bit, then append
        the new version: both visible, the stale one served from stable."""
        idx, v = _build(delta_capacity=64)          # maint_auto on
        d = 32
        v1, v2 = np.zeros((1, d), np.float32), np.zeros((1, d), np.float32)
        v1[0, 3] = 1.0
        v2[0, 4] = 1.0
        idx.insert("text", np.array([0], np.int32), v1)   # update, in delta
        # batch > free slots forces a drain inside this insert; it carries
        # the next update of the same id
        rng = np.random.default_rng(21)
        big = np.concatenate([v2, rng.normal(size=(70, d)).astype(np.float32)])
        ids = np.concatenate([[0], np.arange(451, 521)]).astype(np.int32)
        idx.insert("text", ids, big)
        sv, si = idx.search(v1, "text", k=5)        # query the OLD vector
        for x, s in zip(np.asarray(si)[0], np.asarray(sv)[0]):
            assert x != 0 or s < 0.9, (x, s)
        sv, si = idx.search(v2, "text", k=1)
        assert int(si[0, 0]) == 0 and float(sv[0, 0]) > 0.99
        _oracle_check(idx, _unit(rng.normal(size=(4, d)).astype(np.float32)))

    @pytest.mark.parametrize("bits", [4, 16])
    def test_drain_requantizes_non_int8_slabs(self, bits):
        """Regression: the delta's int8 mirror only matches an int8 slab's
        layout — draining into a 4/16-bit slab must re-quantize the fp32
        master rows at the slab's width (byte-moving int8 codes would crash
        on the packed layout or corrupt bf16 scores)."""
        idx, v = _build(maint_auto=False, quant_bits=bits)
        assert idx.modalities["text"].ivf.bits == bits
        rng = np.random.default_rng(23)
        burst = _unit(rng.normal(size=(24, 32)).astype(np.float32))
        ids = np.arange(451, 475, dtype=np.int32)
        idx.insert("text", ids, burst)
        idx.maintain("text", budget=4096, need_rows=24)
        assert int(idx.modalities["text"].delta.count) == 0
        sv, si = idx.search(burst, "text", k=1, n_probe=8)
        np.testing.assert_array_equal(np.asarray(si)[:, 0], ids)
        assert float(np.asarray(sv).min()) > 0.9    # sane dequantized scores

    def test_full_partitions_keep_rows_in_delta(self):
        """Rows whose partition has no free slot must survive in the delta
        (searchable), not vanish — the never-drop-a-write invariant under
        bounded drains."""
        idx, v = _build(maint_auto=False, delta_capacity=512)
        m = idx.modalities["text"]
        # burst big enough that some partitions run out of slots
        rng = np.random.default_rng(9)
        burst = _unit(rng.normal(size=(300, 32)).astype(np.float32))
        ids = np.arange(450, 750, dtype=np.int32) % 500    # some updates too
        ids = np.arange(450, 750, dtype=np.int32)
        ids = np.clip(ids, 0, 499)
        idx.insert("text", ids, burst)
        idx.maintain("text", budget=100_000)
        uniq, last = np.unique(ids[::-1], return_index=True)
        sv, si = idx.search(burst[::-1][last], "text", k=1)
        np.testing.assert_array_equal(np.asarray(si)[:, 0], uniq)


    def test_cleared_superseded_counts_slotless_ids(self):
        """Regression: an updated id with no stable slot (it entered via
        the delta) still clears a superseded bit on drain — the count the
        facade's NSW-refresh decision keys on must include it."""
        idx, v = _build(maint_auto=False)
        d = 32
        rng = np.random.default_rng(31)
        nid = np.array([460], np.int32)              # brand-new id
        idx.insert("text", nid, rng.normal(size=(1, d)).astype(np.float32))
        idx.insert("text", nid, rng.normal(size=(1, d)).astype(np.float32))
        m = idx.modalities["text"]
        assert bool(np.asarray(m.delta.superseded)[460])
        report = idx.maintain("text", budget=4096, need_rows=1)
        cleared = sum(r.get("cleared_superseded", 0)
                      for _, r in report.actions)
        assert cleared >= 1
        assert not bool(np.asarray(m.delta.superseded)[460])

    def test_dead_watermark_reclaimed_under_pressure(self):
        """Regression: insert-then-delete-everything leaves a delta full of
        dead weight (live=0, watermark high); an explicit maintain must
        reclaim the slots instead of reporting noop."""
        # pressure below the (synchronous) compact threshold, so the batch
        # itself stays in the delta but still qualifies for maintenance
        idx, v = _build(maint_auto=False, delta_capacity=128,
                        maint_delta_pressure=0.3)
        rng = np.random.default_rng(33)
        ids = np.arange(451, 499, dtype=np.int32)
        idx.insert("text", ids, rng.normal(size=(48, 32)).astype(np.float32))
        idx.delete("text", ids)
        m = idx.modalities["text"]
        assert int(m.delta.count) == 48
        report = idx.maintain("text")
        assert not report.is_noop
        assert int(m.delta.count) == 0
        _, si = idx.search(v[:4], "text", k=10, n_probe=8)
        assert not np.any(np.isin(np.asarray(si), ids))

    def test_budget_zero_is_noop(self):
        """An explicit budget=0 means no optional work — not the default."""
        idx, _ = _build(maint_auto=False, delta_capacity=128,
                        maint_delta_pressure=0.3)
        rng = np.random.default_rng(35)
        idx.insert("text", np.arange(451, 499, dtype=np.int32),
                   rng.normal(size=(48, 32)).astype(np.float32))
        m = idx.modalities["text"]
        before = int(m.delta.count)
        assert before >= 48                  # over pressure, would drain
        assert idx.maintain("text", budget=0).is_noop
        assert int(m.delta.count) == before


class TestMergeCold:
    def test_all_tombstone_partition_merges_away(self):
        idx, v = _build(maint_auto=False)
        m = idx.modalities["text"]
        counts = np.asarray(m.ivf.counts)
        p = int(np.argmin(counts))
        pids = np.asarray(m.ivf.ids[p])
        pids = pids[pids >= 0]
        idx.delete("text", pids)
        report = idx.maintain("text", budget=100_000)
        assert any(a.kind == "merge_cold" and a.partition == p
                   for a, _ in report.actions), report.describe()
        assert parked_mask(np.asarray(m.ivf.centroids))[p]
        assert not np.any(np.asarray(m.ivf.ids[p]) >= 0)
        # deleted ids never resurface — query their own vectors at full probe
        sel = np.isin(np.arange(len(v)), pids)
        _, si = idx.search(v[sel], "text", k=10, n_probe=8)
        assert not np.any(np.isin(np.asarray(si), pids))
        # and the survivors are all still there
        _, si = idx.search(v[~sel], "text", k=1, n_probe=8)
        np.testing.assert_array_equal(np.asarray(si)[:, 0],
                                      np.arange(len(v))[~sel])
        # probe widths clamp to the live partition count
        assert "probe=7" in idx.explain(Q.vector("text", v[:2]).topk(5))

    def test_merge_overflow_routes_to_delta(self):
        """A merge whose sibling lacks room must push survivors to the
        delta, never drop them."""
        idx, v = _build(maint_auto=False)
        m = idx.modalities["text"]
        from repro.maintenance import executor as maint_exec
        counts = np.asarray(m.ivf.counts)
        p = int(np.argmax(counts))          # merging the FULLEST overflows
        before = int(m.delta.count)
        res = maint_exec.merge_cold(m, m.stats, p)
        assert res["ivf_changed"]
        assert res["overflow"] == int(m.delta.count) - before
        _, si = idx.search(v, "text", k=1, n_probe=8)
        np.testing.assert_array_equal(np.asarray(si)[:, 0], np.arange(len(v)))


class TestRecluster:
    def test_results_unchanged_at_full_probe(self):
        idx, v = _build(maint_auto=False)
        m = idx.modalities["text"]
        q = _unit(np.random.default_rng(4).normal(size=(12, 32))
                  .astype(np.float32))
        s0, i0 = idx.search(q, "text", k=8, n_probe=8)
        # inject drift so every live partition re-centers
        m.stats.baseline[:] = 1e-3
        m.stats.drift_sum[:] = 10.0
        m.stats.drift_cnt[:] = 100
        report = idx.maintain("text", budget=100_000)
        assert any(a.kind == "recluster" for a, _ in report.actions)
        s1, i1 = idx.search(q, "text", k=8, n_probe=8)
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        # accumulators re-anchored: no immediate re-trigger
        assert idx.maintain("text").is_noop


class TestInterleavedOracle:
    def test_stream_matches_reference_interpreter(self):
        """The acceptance bar: inserts, updates, deletes, searches and
        maintenance interleaved — after every step the engine matches the
        brute-force oracle at full probe (stable+delta, MVCC-visible)."""
        idx, v = _build(delta_capacity=128, maint_chunk=32,
                        maint_budget_rows=64)
        n, d = len(v), 32
        rng = np.random.default_rng(17)
        q = _unit(rng.normal(size=(6, d)).astype(np.float32))
        for step in range(8):
            ids = rng.integers(0, n + 80, 24).astype(np.int32)  # mix of
            vecs = rng.normal(size=(24, d)).astype(np.float32)  # new+update
            idx.insert("text", ids, vecs)
            idx.delete("text", rng.integers(0, n, 4).astype(np.int32))
            if step % 2:
                idx.maintain("text", budget=48)
            _oracle_check(idx, q)
        # drain everything and check once more
        idx.maintain("text", budget=100_000)
        _oracle_check(idx, q)


class TestWiring:
    def test_maintain_invalidates_sharded_replica(self):
        idx, v = _build(maint_auto=False)
        m = idx.modalities["text"]
        rng = np.random.default_rng(2)
        # sub-threshold batch: stays in the delta until maintain drains it
        idx.insert("text", np.arange(450, 470, dtype=np.int32),
                   rng.normal(size=(20, 32)).astype(np.float32))
        assert int(m.delta.count) == 20
        m.ivf_sharded = "stale-sentinel"
        report = idx.maintain("text", budget=4096, need_rows=1)
        assert not report.is_noop
        assert m.ivf_sharded is None

    def test_auto_trigger_drains_on_insert(self):
        idx, v = _build(delta_capacity=64)       # maint_auto default True
        rng = np.random.default_rng(8)
        for i in range(4):
            idx.insert("text", np.arange(450 + 40 * i, 490 + 40 * i,
                                         dtype=np.int32),
                       rng.normal(size=(40, 32)).astype(np.float32))
        m = idx.modalities["text"]
        # the watermark stays below capacity: drains kept pace with ingest
        assert int(m.delta.count) < 64
        assert "maintenance" in idx.metrics()

    def test_repartition_ignores_parked_partition_heat(self):
        """Regression: a merged-away partition keeps its accumulated probe
        hits (merge never resets heat); maybe_repartition must not let that
        stale heat win the hot-argmax and suppress the real split."""
        idx, v = _build(maint_auto=False)
        m = idx.modalities["text"]
        from repro.maintenance import executor as maint_exec
        p = int(np.argmin(np.asarray(m.ivf.counts)))
        res = maint_exec.merge_cold(m, m.stats, p)
        assert res["ivf_changed"] and m.stats.parked[p]
        m.workload.hits[:] = 0
        m.workload.hits[p] = 50_000          # stale heat on the parked slot
        live_hot = int(np.argmax(np.asarray(m.ivf.counts)))
        m.workload.hits[live_hot] = 10_000
        assert idx.maybe_repartition("text")  # splits the live hot one
        _, si = idx.search(v, "text", k=1, n_probe=8)
        np.testing.assert_array_equal(np.asarray(si)[:, 0], np.arange(len(v)))

    def test_maintenance_driver_paces_runs(self):
        idx, _ = _build()
        drv = MaintenanceDriver(idx, budget_rows=64, interval=3)
        reports = [drv.tick() for _ in range(9)]
        assert drv.runs == 3
        assert sum(r is not None for r in reports) == 3

    def test_maintain_all_modalities_returns_dict(self):
        idx, _ = _build()
        out = idx.maintain()
        assert set(out) == {"text"} and out["text"].is_noop
