"""equiformer-v2 [gnn] — SO(2)-eSCN equivariant graph attention.  [arXiv:2306.12059]"""
from repro.configs.base import GNNConfig
from repro.configs.gnn_shapes import gnn_shapes

CONFIG = GNNConfig(
    arch_id="equiformer-v2",
    source="arXiv:2306.12059; unverified",
    model="equiformer_v2",
    n_layers=12,
    d_hidden=128,
    l_max=6,
    m_max=2,
    n_heads=8,
)

SHAPES = gnn_shapes()
