"""Hybrid-query path benchmark: candidate-sparse fusion vs the dense
(Q, n_nodes) scatter formulation it replaced, plus the end-to-end
``hybrid_search`` wall time.

The fusion-stage comparison runs both formulations over identical stage-1/2
outputs and reports the candidate width C = k_seed + frontier next to
n_nodes — the dense path's peak fusion memory is Q·N·4 bytes, the sparse
path's is Q·C·4 and does not grow with the corpus."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import build_hmgi, load_corpus, make_queries, primary_mod, timeit
from repro.core import traversal as trav_mod
from repro.core.fusion import FusionWeights, adaptive_weights, fuse_topk
from repro.core.index import _fuse_candidates


def run(report):
    name = "sift1b-s"
    corpus = load_corpus(name)
    mod = primary_mod(name)
    idx = build_hmgi(corpus, bits=8, n_partitions=32, n_probe=8)
    q = make_queries(corpus, mod, n=32)
    k = 10

    # end-to-end hybrid query (kernel probe + sparse fusion)
    t_h = timeit(lambda: idx.hybrid_search(q, mod, k=k, n_hops=2), trials=3)
    report("hybrid_e2e", t_h / len(q) * 1e6, f"n_nodes={corpus.n_nodes}")

    # fusion stage in isolation: sparse vs dense over identical inputs
    k_seed = max(2 * k, k + 8)
    qn = idx._norm_queries(q)
    vs, vi = idx.search(qn, mod, k=k_seed)
    g = idx.graph._replace(edge_weight=idx.boosted_weights) \
        if idx.boosted_weights is not None else idx.graph
    gs = trav_mod.multi_hop_batch(g, vi, vs, n_hops=2)
    w = adaptive_weights(vs)
    k_fuse = max(k, min(4 * k, corpus.n_nodes))
    frontier = int(min(corpus.n_nodes, k_fuse + k_seed))

    def dense():
        sim_full = jnp.full((q.shape[0], corpus.n_nodes), -jnp.inf)
        rows = jnp.arange(q.shape[0])[:, None]
        sim_full = sim_full.at[rows, jnp.clip(vi, 0, corpus.n_nodes - 1)].set(
            jnp.where(vi >= 0, vs, -jnp.inf))
        return fuse_topk(sim_full, gs, w, k_fuse)

    def sparse():
        return _fuse_candidates(vs, vi, gs, w.w_vector, w.w_graph,
                                k_fuse=k_fuse, frontier=frontier)

    dv, di = jax.jit(dense)()
    sv, si = sparse()
    agree = float(np.mean(np.asarray(di) == np.asarray(si)))
    t_d = timeit(jax.jit(dense), trials=3)
    t_s = timeit(sparse, trials=3)
    c_width = k_seed + frontier
    dense_bytes = q.shape[0] * corpus.n_nodes * 4
    sparse_bytes = q.shape[0] * c_width * 4
    report("fusion_dense", t_d * 1e6,
           f"peak_fusion_bytes={dense_bytes:.2e} n={corpus.n_nodes}")
    report("fusion_sparse", t_s * 1e6,
           f"speedup={t_d / t_s:.2f}x peak_fusion_bytes={sparse_bytes:.2e} "
           f"C={c_width} id_agreement={agree:.3f}")

    # corpus-scaling of the fusion stage alone (synthetic stage-1/2 outputs):
    # dense fusion walks (Q, N) three times, sparse only pays the frontier
    # top-k — the gap and the memory ratio grow with N
    rng = np.random.default_rng(1)
    qn_, ks_ = 32, k_seed
    for n_big in (65536, 262144):
        gs_ = jnp.asarray(np.abs(rng.normal(size=(qn_, n_big))).astype(np.float32))
        vi_ = jnp.asarray(rng.integers(0, n_big, (qn_, ks_)).astype(np.int32))
        vs_ = jnp.asarray(np.sort(rng.random((qn_, ks_)).astype(np.float32))[:, ::-1])
        w_ = FusionWeights(jnp.full((qn_,), 0.6), jnp.full((qn_,), 0.4))

        def dense_big(vs_, vi_, gs_):
            sim_full = jnp.full((qn_, n_big), -jnp.inf)
            rows = jnp.arange(qn_)[:, None]
            sim_full = sim_full.at[rows, jnp.clip(vi_, 0, n_big - 1)].set(
                jnp.where(vi_ >= 0, vs_, -jnp.inf))
            return fuse_topk(sim_full, gs_, w_, k_fuse)

        t_d = timeit(jax.jit(dense_big), vs_, vi_, gs_, trials=3)
        t_s = timeit(lambda: _fuse_candidates(
            vs_, vi_, gs_, w_.w_vector, w_.w_graph,
            k_fuse=k_fuse, frontier=frontier), trials=3)
        report(f"fusion_sparse_n{n_big}", t_s * 1e6,
               f"speedup={t_d / t_s:.2f}x dense_us={t_d * 1e6:.0f} "
               f"mem_ratio={n_big / c_width:.0f}x")
