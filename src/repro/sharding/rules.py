"""Logical-axis sharding rules (MaxText-style).

Parameters and activations are annotated with tuples of *logical* axis names.
A rule table maps each logical name to a mesh axis (or a tuple of mesh axes,
or None). ``logical_to_spec`` resolves names to a PartitionSpec with two
fallbacks that make one rule table serve every arch/mesh combination:

  * axes not present in the mesh are dropped ("pod" on the single-pod mesh);
  * if the mapped mesh-axis product does not divide the dimension, the
    longest divisible *prefix* of the tuple is used instead (GQA kv_heads=8
    under a 16-way "model" axis falls back to replication; global_batch=256
    under ("pod","data","model")=512 falls back to ("pod","data")=32).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# Logical axis vocabulary used across the framework (see docs/DESIGN.md §5):
DEFAULT_RULES: Dict[Optional[str], MeshAxes] = {
    # activations
    "batch": ("pod", "data"),            # prefix-fallback trims to what divides
    "seq": None,
    "seq_attn": None,                    # context parallelism opt-in (phi4)
    "cache_seq": "model",                # decode KV cache: flash-decode split
    "embed": None,
    "act_mlp": "model",
    "act_heads": "model",
    "vocab_act": "model",
    # params
    "embed_fsdp": "data",                # ZeRO-3 row shard of weight matrices
    "embed_model": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": None,                     # experts replicated; (D,F) carry the shards
    "vocab": "model",
    "kv_lora": None,
    # HMGI index
    "db": ("pod", "data"),
    "partitions": None,
    "dim": None,
    # recsys / gnn
    "table": "model",
    "nodes": ("pod", "data"),
    "edges": ("pod", "data"),
    "feat": None,
    "hidden": "model",
    None: None,
}


_ACTIVE_OVERRIDES: Dict[Optional[str], MeshAxes] = {}


class rule_overrides:
    """Context manager: per-arch logical->mesh overrides active while tracing."""

    def __init__(self, overrides: Optional[Dict] = None):
        self.overrides = dict(overrides or {})

    def __enter__(self):
        global _ACTIVE_OVERRIDES
        self._saved = _ACTIVE_OVERRIDES
        _ACTIVE_OVERRIDES = {**self._saved, **self.overrides}
        return self

    def __exit__(self, *exc):
        global _ACTIVE_OVERRIDES
        _ACTIVE_OVERRIDES = self._saved
        return False


def _axes_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape.get(a, 1)
    return size


def _present(mesh: Mesh, axes: MeshAxes) -> Tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        axes = (axes,)
    return tuple(a for a in axes if a in mesh.shape)


def logical_to_spec(
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Optional[Dict] = None,
    dims: Optional[Sequence[int]] = None,
) -> P:
    """Resolve logical axis names to a PartitionSpec for ``mesh`` (see module doc)."""
    base = {**DEFAULT_RULES, **_ACTIVE_OVERRIDES}
    rules = base if rules is None else {**base, **rules}
    used: set = set()
    out = []
    for i, name in enumerate(logical_axes):
        cand = _present(mesh, rules.get(name))
        cand = tuple(a for a in cand if a not in used)
        # longest divisible prefix
        chosen: Tuple[str, ...] = ()
        if dims is not None and cand:
            size = 1
            for j, a in enumerate(cand):
                size *= mesh.shape[a]
                if dims[i] % size == 0:
                    chosen = cand[: j + 1]
                else:
                    break
        elif cand:
            chosen = cand
        used.update(chosen)
        if not chosen:
            out.append(None)
        elif len(chosen) == 1:
            out.append(chosen[0])
        else:
            out.append(chosen)
    return P(*out)


def shard_tree(axes_tree, shapes_tree, mesh: Mesh, rules=None):
    """Pytree of logical-axes tuples (+ matching abstract shapes) -> NamedShardings."""
    def one(axes, shaped):
        dims = getattr(shaped, "shape", None)
        return NamedSharding(mesh, logical_to_spec(axes, mesh, rules, dims))
    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=_is_axes_leaf)


def _is_axes_leaf(x):
    return isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)


def with_sharding(x, logical_axes, mesh: Optional[Mesh] = None, rules=None):
    """Activation sharding constraint by logical names (identity if no mesh)."""
    if mesh is None:
        return x
    spec = logical_to_spec(logical_axes, mesh, rules, dims=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_axes(mesh: Mesh, n: int) -> Tuple[str, ...]:
    """Mesh axes used for the batch/data dimension of size n (prefix rule)."""
    spec = logical_to_spec(["batch"], mesh, None, [n])[0]
    if spec is None:
        return ()
    return (spec,) if isinstance(spec, str) else tuple(spec)


def db_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes carrying the HMGI stable store's row shards (the "db"
    logical axis — ("pod","data"), trimmed to what the mesh has)."""
    return _present(mesh, DEFAULT_RULES["db"])


def db_shards(mesh: Optional[Mesh]) -> int:
    """Number of row shards the mesh supports for the stable store (1 when
    there is no mesh — the single-device layout)."""
    if mesh is None:
        return 1
    return _axes_size(mesh, db_axes(mesh))
