"""Benchmark harness — one module per paper table/figure.

  paper_tables  — Tables 4-7: QPS / recall@10 / memory / latency,
                  HMGI vs monolithic vs decoupled baselines
  ablations     — §5.1 partitioning, §5.2 updates+quantization, §5.3 fusion
  scaling       — §4.5 sub-linear query scaling
  kernels_bench — Pallas kernel accounting (incl. kernel-vs-einsum probe path)
  hybrid_bench  — hybrid query: sparse vs dense fusion, end-to-end latency
  filtered_bench — attribute-filtered search: pushdown vs post-filter sweep
  query_bench   — declarative query engine: relationship-heavy canned plans
                  (ms/query + compiled plan choice)
  sharded_bench — sharded execution path: 1/2/4/8-shard probe+merge scaling
  maintenance_bench — adaptive maintenance: ingest stall (incremental drain
                  vs full compact) + post-maintenance query latency
  persistence_bench — durability: snapshot write/restore latency, WAL append
                  overhead on ingest, recovery time vs replay length

Prints ``name,us_per_call,derived,n_compiles,p50_ms,p99_ms`` CSV —
``n_compiles`` is the running count of distinct compiled signatures across
the staticcheck (HMG103) registry entries, so jit respecialisation is
visible per row; ``p50_ms``/``p99_ms`` are the obs registry's
``query.execute`` histogram quantiles accumulated since the previous row
(blank for rows that never enter the query executor).
Usage: PYTHONPATH=src python -m benchmarks.run [--only <module>]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["paper_tables", "ablations", "scaling",
                             "kernels_bench", "hybrid_bench",
                             "filtered_bench", "query_bench",
                             "sharded_bench", "maintenance_bench",
                             "persistence_bench"])
    args = ap.parse_args()

    rows = []

    from benchmarks.common import total_compiles
    from repro import obs

    def report(name: str, us_per_call: float, derived: str = ""):
        n_compiles = total_compiles()
        # per-query latency quantiles since the previous row, from the obs
        # registry's "query.execute" histogram (facade-path rows only;
        # rows that never enter the query executor print blanks)
        h = obs.registry().histogram("query.execute")
        p50 = f"{h.percentile(50):.3f}" if h.count else ""
        p99 = f"{h.percentile(99):.3f}" if h.count else ""
        obs.reset()
        rows.append((name, us_per_call, derived, n_compiles))
        print(f"{name},{us_per_call:.3f},{derived},{n_compiles},{p50},{p99}",
              flush=True)

    from benchmarks import (ablations, filtered_bench, hybrid_bench,
                            kernels_bench, maintenance_bench, paper_tables,
                            persistence_bench, query_bench, scaling,
                            sharded_bench)
    mods = {"paper_tables": paper_tables, "ablations": ablations,
            "scaling": scaling, "kernels_bench": kernels_bench,
            "hybrid_bench": hybrid_bench, "filtered_bench": filtered_bench,
            "query_bench": query_bench, "sharded_bench": sharded_bench,
            "maintenance_bench": maintenance_bench,
            "persistence_bench": persistence_bench}
    selected = [mods[args.only]] if args.only else list(mods.values())

    print("name,us_per_call,derived,n_compiles,p50_ms,p99_ms")
    failed = 0
    for mod in selected:
        try:
            mod.run(report)
        except Exception:  # noqa: BLE001
            failed += 1
            traceback.print_exc()
    print(f"# done: {len(rows)} rows, {failed} module failures", flush=True)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
