"""Pure-jnp oracle for decode_attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, valid):
    """q (B, Hkv, G, hd); k/v (B, S, Hkv, hd); valid (B, S) -> (B, Hkv, G, hd)."""
    b, hkv, g, hd = q.shape
    scale = 1.0 / (hd ** 0.5)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, kf) * scale
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(jnp.isfinite(scores), p, 0.0)
    return jnp.einsum("bhgs,bshd->bhgd", p, vf).astype(q.dtype)
