from repro.checkpoint.checkpoint import (
    CheckpointError, CheckpointManager, checkpoint_steps, latest_step,
    restore_checkpoint, save_checkpoint,
)
