"""Sharded execution path: per-shard probe + cross-shard merge scaling.

Rows: the single-device probe scan, then ``search_sharded`` at 1/2/4/8
shards (as many as the process has devices — run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for the full sweep
on CPU). Derived columns report the speedup over the single-device scan and
the merge overhead (sharded end-to-end minus one shard's local scan — the
all-gather + top-k merge the distribution pays per query).

On forced-host-device CPU the "shards" share one socket, so wall-clock
speedup is not the point — the merge overhead and the scaling shape are.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import ivf as ivf_mod
from benchmarks.common import timeit

N, D, K_PARTS, N_PROBE, K, Q = 8192, 64, 32, 8, 10, 32


def run(report):
    rng = np.random.default_rng(0)
    v = rng.normal(size=(N, D)).astype(np.float32)
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    idx, _ = ivf_mod.build(jax.random.PRNGKey(0), jnp.asarray(v),
                           jnp.arange(N), n_partitions=K_PARTS, bits=8)
    q = jnp.asarray(v[:Q] + 0.02 * rng.normal(size=(Q, D)).astype(np.float32))

    t_single = timeit(lambda: ivf_mod.search(idx, q, n_probe=N_PROBE, k=K))
    report("sharded/single_device", t_single * 1e6 / Q, f"n={N} d={D}")

    n_dev = len(jax.devices())
    for s in (1, 2, 4, 8):
        if s > n_dev:
            report(f"sharded/x{s}", 0.0,
                   f"skipped: {n_dev} devices (set XLA_FLAGS="
                   f"--xla_force_host_platform_device_count=8)")
            continue
        mesh = Mesh(np.array(jax.devices()[:s]).reshape(s), ("data",))
        sh = ivf_mod.shard_index(idx, s)
        fn = jax.jit(lambda st, qq, m=mesh: ivf_mod.search_sharded(
            st, qq, m, n_probe=N_PROBE, k=K))
        t_shard = timeit(fn, sh, q)
        # one shard's local scan in isolation: the compute each device does
        loc = ivf_mod.IVFIndex(sh.centroids[0], sh.data[0], sh.vmin[0],
                               sh.scale[0], sh.ids[0], sh.counts[0], sh.bits)
        t_local = timeit(lambda: ivf_mod.search(loc, q, n_probe=N_PROBE, k=K))
        report(f"sharded/x{s}", t_shard * 1e6 / Q,
               f"speedup_vs_single={t_single / t_shard:.2f}x "
               f"merge_overhead_us={(t_shard - t_local) * 1e6 / Q:.1f}")
