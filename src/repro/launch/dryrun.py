import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# (docstring below; the two lines above MUST precede any jax-importing code)
_DOC = """Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

For each cell: jit(step).lower(**input_specs).compile() on the single-pod
(16,16) and multi-pod (2,16,16) production meshes; record
memory_analysis() / cost_analysis() / collective bytes (HLO text parse) to
``results/dryrun/<mesh>/<arch>__<shape>.json``.

Cost-analysis calibration (docs/DESIGN.md §6): LM layer stacks lower with
``unroll=n_layers`` so scan bodies are counted; GNN ring scans stay rolled
(HLO size) and the true cost is extrapolated from two extra small lowerings
(R=1 and R=2-unrolled ring variants): true = f(R1) + (R-1)·(f(R2) - f(R1)).
"""

import argparse
import dataclasses
import json
import math
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.params import abstract_init
from repro.configs import ASSIGNED_ARCHS, get_config, get_shapes
from repro.configs.base import GNNConfig, LMConfig, RecsysConfig, ShapeSpec
from repro.launch.mesh import data_shards, make_production_mesh
from repro.models import lm
from repro.models.gnn import driver as gnn_driver
from repro.models.gnn import dimenet as dimenet_mod
from repro.models.gnn.common import RingGraph
from repro.models.recsys import xdeepfm
from repro.roofline.hlo_parse import count_collective_ops, parse_collective_bytes
from repro.sharding.rules import logical_to_spec, rule_overrides, shard_tree
from repro.train.optimizer import AdamWConfig, init_adamw, opt_state_axes

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def _sh(mesh, logical, dims):
    return NamedSharding(mesh, logical_to_spec(logical, mesh, None, dims))


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def lm_cell(cfg: LMConfig, shape: ShapeSpec, mesh, fast: bool,
            cost_variant: bool = False):
    """fast/main: rolled scans (memory + sharding proof, quick compiles).
    cost_variant: tiny-L unrolled, unblocked attention — the exact-cost probe
    used by the layer-count extrapolation."""
    if cost_variant:
        opts = lm.ExecOpts(q_block=0, unroll_layers=True,
                           unroll_attn_blocks=False, remat=True)
    else:
        opts = lm.ExecOpts(q_block=1024, unroll_layers=not fast,
                           unroll_attn_blocks=not fast, remat=True)
    abs_params, axes = abstract_init(lambda k: lm.init_lm(cfg, k),
                                     jax.random.PRNGKey(0))
    p_sh = shard_tree(axes, abs_params, mesh)
    bsz = shape["global_batch"]
    seq = shape["seq_len"]

    if shape.kind == "train":
        opt_abs = jax.eval_shape(init_adamw, abs_params)
        o_sh = shard_tree(opt_state_axes(axes), opt_abs, mesh)
        # microbatching bounds stored remat activations to ~1 sequence/device;
        # the cost probes run accum=1 (total step FLOPs are accumulation-
        # invariant, and the accum scan body would otherwise count once).
        # batch capacity respects the ACTIVE rule variant (fsdp puts batch on
        # the model axis too)
        from repro.sharding.rules import batch_axes
        baxes = batch_axes(mesh, bsz)
        n_data = 1
        for a in baxes:
            n_data *= mesh.shape[a]
        per_dev = max(bsz // max(n_data, 1), 1)
        accum = 1 if cost_variant else min(per_dev, 8)
        if accum > 1:
            micro = bsz // accum
            tok = _sds((accum, micro, seq), jnp.int32)
            b_sh = _sh(mesh, (None, "batch", None), (accum, micro, seq))
        else:
            tok = _sds((bsz, seq), jnp.int32)
            b_sh = _sh(mesh, ("batch", None), (bsz, seq))
        step = lm.make_train_step(cfg, mesh, opts, AdamWConfig(),
                                  grad_accum=accum)
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, {"tokens": b_sh, "labels": b_sh}),
                     out_shardings=(p_sh, o_sh, None))
        args = (abs_params, opt_abs, {"tokens": tok, "labels": tok})
    elif shape.kind == "prefill":
        tok = _sds((bsz, seq), jnp.int32)
        b_sh = _sh(mesh, ("batch", None), (bsz, seq))
        pf = lambda p, t: lm.prefill(cfg, p, t, mesh, opts)
        fn = jax.jit(pf, in_shardings=(p_sh, b_sh))
        args = (abs_params, tok)
    elif shape.kind == "decode":
        clen = lm.cache_len_for(cfg, seq)
        cache_abs, cache_axes = abstract_init(
            lambda: lm.init_cache(cfg, bsz, clen))
        c_sh = shard_tree(cache_axes, cache_abs, mesh)
        dstep = lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos, mesh, opts)
        fn = jax.jit(dstep, in_shardings=(p_sh, c_sh, None, None),
                     out_shardings=(None, c_sh))
        args = (abs_params, cache_abs, _sds((bsz,), jnp.int32),
                _sds((), jnp.int32))
    else:
        raise ValueError(shape.kind)

    tokens = bsz * (seq if shape.kind != "decode" else 1)
    mult = 3 if shape.kind == "train" else 1          # fwd+bwd ≈ 3x fwd
    model_flops = 2 * cfg.active_param_count() * tokens * mult
    if shape.kind == "decode":
        # decode compute is attention-read dominated; 6ND counts matmuls only
        model_flops = 2 * cfg.active_param_count() * tokens
    meta = {"params": cfg.param_count(), "active_params": cfg.active_param_count(),
            "model_flops": model_flops, "tokens": tokens}
    return fn, args, meta, None


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _ring_specs(mesh, n_nodes, n_edges, d_feat, rounds: Optional[int] = None,
                imbalance: float = 1.15, e_cap: Optional[int] = None):
    s = data_shards(mesh)
    r = rounds or s
    n_pad = int(math.ceil(n_nodes / s) * s)
    # per-(shard, round) capacity — the extrapolation probes override this so
    # the per-round body cost matches the production cell exactly
    e_cap = e_cap or max(int(math.ceil(n_edges / (s * s) * imbalance)), 8)
    g = RingGraph(
        feats=_sds((n_pad, d_feat)),
        positions=_sds((n_pad, 3)),
        esrc_local=_sds((s, r, e_cap), jnp.int32),
        edst_local=_sds((s, r, e_cap), jnp.int32),
        edge_mask=_sds((s, r, e_cap), jnp.bool_),
        node_mask=_sds((n_pad,), jnp.bool_),
        labels=_sds((n_pad,), jnp.int32),
    )
    nspec = _sh(mesh, ("nodes",), (n_pad,))
    nspec2 = _sh(mesh, ("nodes", None), (n_pad, d_feat))
    espec = _sh(mesh, ("edges", None, None), (s, r, e_cap))
    shardings = RingGraph(
        feats=nspec2, positions=nspec2, esrc_local=espec, edst_local=espec,
        edge_mask=espec, node_mask=nspec, labels=nspec)
    return g, shardings, {"n_pad": n_pad, "e_cap": e_cap, "rounds": r, "shards": s}


def gnn_full_graph_cell(cfg: GNNConfig, shape: ShapeSpec, mesh, fast: bool,
                        rounds_override: Optional[int] = None,
                        e_cap_override: Optional[int] = None):
    d_feat = shape.dims.get("d_feat", 16)
    n_nodes, n_edges = shape["n_nodes"], shape["n_edges"]
    abs_params, axes = abstract_init(
        lambda k: gnn_driver.init_model(cfg, k, d_feat), jax.random.PRNGKey(0))
    p_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), abs_params)
    opt_abs = jax.eval_shape(init_adamw, abs_params)
    o_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), opt_abs)

    g, g_sh, ginfo = _ring_specs(mesh, n_nodes, n_edges, d_feat,
                                 rounds=rounds_override, e_cap=e_cap_override)

    if cfg.model == "dimenet":
        s, r = ginfo["shards"], ginfo["rounds"]
        t_cap = max(int(8 * n_edges / (s * s) * 1.15), 8)
        tri = (_sds((s, r, t_cap), jnp.int32), _sds((s, r, t_cap), jnp.int32),
               _sds((s, r, t_cap), jnp.bool_))
        tri_sh = tuple(_sh(mesh, ("edges", None, None), (s, r, t_cap))
                       for _ in range(3))

        def loss_fn(params, g, ts, td, tm):
            sums = dimenet_mod.ring_loss(cfg, params, g, ts, td, tm, mesh,
                                         gnn_driver._ce_sums)
            return sums["loss_sum"] / jnp.maximum(sums["count"], 1.0), sums

        def step(params, opt_state, g, ts, td, tm):
            from repro.train.optimizer import adamw_update
            (l, sums), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, g, ts, td, tm)
            params, opt_state, om = adamw_update(AdamWConfig(lr=1e-3), grads,
                                                 opt_state, params)
            return params, opt_state, {"loss": l, **om}

        fn = jax.jit(step, in_shardings=(p_sh, o_sh, g_sh) + tri_sh,
                     out_shardings=(p_sh, o_sh, None))
        args = (abs_params, opt_abs, g) + tri
    else:
        def loss_fn(params, g):
            sums = gnn_driver.full_graph_loss(cfg, params, g, mesh)
            return sums["loss_sum"] / jnp.maximum(sums["count"], 1.0), sums

        def step(params, opt_state, g):
            from repro.train.optimizer import adamw_update
            (l, sums), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, g)
            params, opt_state, om = adamw_update(AdamWConfig(lr=1e-3), grads,
                                                 opt_state, params)
            return params, opt_state, {"loss": l, **om}

        fn = jax.jit(step, in_shardings=(p_sh, o_sh, g_sh),
                     out_shardings=(p_sh, o_sh, None))
        args = (abs_params, opt_abs, g)

    from repro.common.tree import count_params
    meta = {"params": int(sum(np.prod(l.shape) for l in jax.tree.leaves(abs_params))),
            "model_flops": _gnn_model_flops(cfg, n_edges, d_feat) * 3,
            **ginfo}
    return fn, args, meta, ginfo


def _gnn_model_flops(cfg: GNNConfig, n_edges: int, d_feat: int) -> int:
    """Analytic per-forward FLOPs (message matmuls dominate)."""
    d = cfg.d_hidden
    if cfg.model == "egnn":
        per_edge = 2 * (2 * d + 1) * d + 2 * d * d + 2 * d * 1
    elif cfg.model == "dimenet":
        nb, ns, nr = cfg.n_bilinear, cfg.n_spherical, cfg.n_radial
        per_edge = (2 * 3 * d * d                     # embed MLP
                    + 8 * (2 * d * d + 2 * ns * nr * nb + 2 * d * nb * d))
    elif cfg.model == "nequip":
        dim = (cfg.l_max + 1) ** 2
        n_paths = sum(1 for l1 in range(cfg.l_max + 1)
                      for l2 in range(cfg.l_max + 1)
                      for _ in range(abs(l1 - l2), min(l1 + l2, cfg.l_max) + 1))
        per_edge = n_paths * 2 * d * dim * 3          # CG contractions
    else:  # equiformer_v2
        dim = (cfg.l_max + 1) ** 2
        so2 = sum((2 if m else 1) * 2 * ((cfg.l_max + 1 - m) * d) ** 2
                  for m in range(cfg.m_max + 1))
        rot = 2 * sum((2 * l + 1) ** 2 * d for l in range(cfg.l_max + 1))
        per_edge = 2 * (so2 + 2 * rot)                # two passes (attn)
    return int(per_edge) * int(n_edges) * cfg.n_layers


def gnn_dense_cell(cfg: GNNConfig, shape: ShapeSpec, mesh, fast: bool):
    """molecule / minibatch cells: vmapped per-sample graphs, pure DP."""
    if shape.kind == "molecule":
        bsz, n, e = shape["batch"], shape["n_nodes"], shape["n_edges"]
        d_feat = 4
        kind = "molecule"
    else:
        bsz = shape["batch_nodes"]
        from repro.sparse.sampler import sizes_for_fanout
        n, e = sizes_for_fanout((shape["fanout0"], shape["fanout1"]))
        d_feat = min(shape.dims.get("d_feat", 602), 602)
        kind = "minibatch"
    n_out = 1 if kind == "molecule" else gnn_driver.N_CLASSES
    abs_params, axes = abstract_init(
        lambda k: gnn_driver.init_model(cfg, k, d_feat, n_out),
        jax.random.PRNGKey(0))
    p_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), abs_params)
    opt_abs = jax.eval_shape(init_adamw, abs_params)
    o_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), opt_abs)

    from repro.models.gnn.common import FlatGraph
    g = FlatGraph(
        feats=_sds((bsz, n, d_feat)), positions=_sds((bsz, n, 3)),
        edge_src=_sds((bsz, e), jnp.int32), edge_dst=_sds((bsz, e), jnp.int32),
        edge_mask=_sds((bsz, e), jnp.bool_), node_mask=_sds((bsz, n), jnp.bool_),
        labels=_sds((bsz, n), jnp.int32))
    g_sh = FlatGraph(
        feats=_sh(mesh, ("batch", None, None), (bsz, n, d_feat)),
        positions=_sh(mesh, ("batch", None, None), (bsz, n, 3)),
        edge_src=_sh(mesh, ("batch", None), (bsz, e)),
        edge_dst=_sh(mesh, ("batch", None), (bsz, e)),
        edge_mask=_sh(mesh, ("batch", None), (bsz, e)),
        node_mask=_sh(mesh, ("batch", None), (bsz, n)),
        labels=_sh(mesh, ("batch", None), (bsz, n)))

    batch = {"graph": g}
    b_sh = {"graph": g_sh}
    if kind == "molecule":
        batch["energy"] = _sds((bsz,))
        b_sh["energy"] = _sh(mesh, ("batch",), (bsz,))
        if cfg.model == "dimenet":
            t = 8 * e
            batch["triplets"] = dimenet_mod.TripletIndex(
                _sds((bsz, t), jnp.int32), _sds((bsz, t), jnp.int32),
                _sds((bsz, t), jnp.bool_))
            b_sh["triplets"] = dimenet_mod.TripletIndex(
                *(_sh(mesh, ("batch", None), (bsz, t)) for _ in range(3)))
    else:
        batch["labels"] = _sds((bsz,), jnp.int32)
        b_sh["labels"] = _sh(mesh, ("batch",), (bsz,))
        if cfg.model == "dimenet":
            t = 8 * e
            batch["triplets"] = dimenet_mod.TripletIndex(
                _sds((bsz, t), jnp.int32), _sds((bsz, t), jnp.int32),
                _sds((bsz, t), jnp.bool_))
            b_sh["triplets"] = dimenet_mod.TripletIndex(
                *(_sh(mesh, ("batch", None), (bsz, t)) for _ in range(3)))

    # minibatch dimenet uses per-sample triplets through minibatch_loss? the
    # driver's minibatch/molecule losses pass triplets when present.
    step = gnn_driver.make_train_step(cfg, kind, mesh=None)
    fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                 out_shardings=(p_sh, o_sh, None))
    args = (abs_params, opt_abs, batch)
    meta = {"params": int(sum(np.prod(l.shape) for l in jax.tree.leaves(abs_params))),
            "model_flops": _gnn_model_flops(cfg, bsz * e, d_feat) * 3}
    return fn, args, meta, None


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------

def recsys_cell(cfg: RecsysConfig, shape: ShapeSpec, mesh, fast: bool):
    abs_params, axes = abstract_init(lambda k: xdeepfm.init(cfg, k),
                                     jax.random.PRNGKey(0))
    with rule_overrides(cfg.sharding_overrides):
        p_sh = shard_tree(axes, abs_params, mesh)
    f = cfg.n_sparse
    if shape.kind == "train":
        bsz = shape["batch"]
        opt_abs = jax.eval_shape(init_adamw, abs_params)
        o_sh = shard_tree(opt_state_axes(axes), opt_abs, mesh)

        def step(params, opt_state, batch):
            from repro.train.optimizer import adamw_update
            (l, aux), grads = jax.value_and_grad(
                lambda p: xdeepfm.loss_fn(cfg, p, batch, mesh), has_aux=True)(params)
            params, opt_state, om = adamw_update(AdamWConfig(lr=1e-3), grads,
                                                 opt_state, params)
            return params, opt_state, {"loss": l, **aux, **om}

        batch = {"ids": _sds((bsz, f), jnp.int32), "labels": _sds((bsz,), jnp.int32)}
        b_sh = {"ids": _sh(mesh, ("batch", None), (bsz, f)),
                "labels": _sh(mesh, ("batch",), (bsz,))}
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None))
        args = (abs_params, opt_abs, batch)
        mult = 3
    elif shape.kind == "serve":
        bsz = shape["batch"]
        fwd = lambda p, ids: xdeepfm.forward(cfg, p, ids, mesh)
        fn = jax.jit(fwd, in_shardings=(p_sh, _sh(mesh, ("batch", None), (bsz, f))))
        args = (abs_params, _sds((bsz, f), jnp.int32))
        mult = 1
    else:  # retrieval
        bsz = shape["n_candidates"]
        sc = lambda p, u, c: xdeepfm.retrieval_score(cfg, p, u, c, mesh)
        fn = jax.jit(sc, in_shardings=(p_sh, None,
                                       _sh(mesh, ("batch", None), (bsz, f))))
        args = (abs_params, _sds((f,), jnp.int32), _sds((bsz, f), jnp.int32))
        mult = 1

    # analytic flops: CIN + MLP per example
    m, d = cfg.n_sparse, cfg.embed_dim
    prev = m
    per_ex = 0
    for h in cfg.cin_layers:
        per_ex += 2 * prev * m * d * h
        prev = h
    d_in = m * d
    for h in cfg.mlp_layers:
        per_ex += 2 * d_in * h
        d_in = h
    if shape.kind == "retrieval":
        per_ex = 2 * m * d   # dot-product scoring per candidate
    meta = {"params": cfg.param_count(), "model_flops": per_ex * bsz * mult}
    return fn, args, meta, None


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape: ShapeSpec, mesh, fast: bool, **kw):
    cfg = get_config(arch)
    if isinstance(cfg, LMConfig):
        # main LM compile always rolled (memory/sharding proof); exact cost
        # comes from the layer-count extrapolation probes
        return lm_cell(cfg, shape, mesh, fast=True)
    if isinstance(cfg, GNNConfig):
        if shape.kind == "full_graph":
            return gnn_full_graph_cell(cfg, shape, mesh, fast, **kw)
        return gnn_dense_cell(cfg, shape, mesh, fast)
    if isinstance(cfg, RecsysConfig):
        return recsys_cell(cfg, shape, mesh, fast)
    raise TypeError(type(cfg))


# sharding-rule variants for §Perf hillclimbing (EXPERIMENTS.md):
#   fsdp — pure ZeRO-3 data parallelism for dense LM training: batch over all
#   mesh axes, weights 2-D sharded over ("data","model"), no tensor-parallel
#   activation psums (they dominated the baseline collective term 10:1)
RULE_VARIANTS = {
    "baseline": {},
    "fsdp": {
        "batch": ("pod", "data", "model"),
        "heads": None, "kv_heads": None, "mlp": None, "act_heads": None,
        "embed_fsdp": ("data", "model"),
        "vocab": ("data", "model"),
        "vocab_act": None,
        "embed_model": None,
        "experts": None,
    },
    # serving: weights stay TP-resident (no ZeRO re-gather per token — the
    # baseline decode cells were all-gathering the full parameter set per
    # decoded token, which dominated their collective term)
    "serve": {
        "embed_fsdp": None,
    },
}


def run_cell(arch: str, shape: ShapeSpec, mesh_name: str, fast: bool = False,
             variant: str = "baseline") -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    cfg = get_config(arch)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape.name, "mesh": mesh_name,
                           "kind": shape.kind, "dims": shape.dims,
                           "variant": variant}
    if shape.skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = shape.skip_reason
        return rec
    t0 = time.time()
    overrides = {**getattr(cfg, "sharding_overrides", {}),
                 **RULE_VARIANTS[variant]}
    with rule_overrides(overrides):
        fn, args, meta, ginfo = build_cell(arch, shape, mesh, fast)
        lowered = fn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    coll = parse_collective_bytes(txt)
    ops = count_collective_ops(txt)
    rec.update({
        "status": "ok",
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
        },
        "flops_per_device": ca.get("flops", 0.0),
        "bytes_per_device": ca.get("bytes accessed", 0.0),
        "collective_bytes_per_device": coll,
        "collective_op_counts": ops,
        "hlo_chars": len(txt),
        "meta": meta,
    })

    # GNN ring extrapolation: two small extra lowerings (R=1, R=2)
    if ginfo is not None:
        rec["ring_extrapolation"] = _ring_extrapolate(arch, shape, mesh, ginfo)
    # LM layer-count extrapolation: two tiny-L unrolled cost probes
    if isinstance(cfg, LMConfig):
        rec["layer_extrapolation"] = _lm_extrapolate(cfg, shape, mesh,
                                                     overrides)
    return rec


def _lm_extrapolate(cfg: LMConfig, shape: ShapeSpec, mesh,
                    overrides=None) -> Dict[str, Any]:
    """True per-device cost = f(L_a) + (n_scan-1)·(f(L_b) - f(L_a)) with
    L_a = first_dense+1, L_b = first_dense+2 (exact: the scanned layers are
    homogeneous; outside-the-scan cost cancels in the difference)."""
    fd = cfg.first_dense_layers
    vals = {}
    if overrides is None:
        overrides = getattr(cfg, "sharding_overrides", {})
    for li, lval in (("a", fd + 1), ("b", fd + 2)):
        sub = cfg.replace(n_layers=lval)
        with rule_overrides(overrides):
            fn, args, _, _ = lm_cell(sub, shape, mesh, fast=False,
                                     cost_variant=True)
            comp = fn.lower(*args).compile()
        ca = comp.cost_analysis() or {}
        coll = parse_collective_bytes(comp.as_text())
        vals[li] = {"flops": ca.get("flops", 0.0),
                    "bytes": ca.get("bytes accessed", 0.0),
                    "coll": coll.get("total", 0.0)}
    n_scan = cfg.n_layers - fd
    body = {k: max(vals["b"][k] - vals["a"][k], 0.0)
            for k in ("flops", "bytes", "coll")}
    return {
        "n_scan_layers": n_scan,
        "per_layer": body,
        "true_flops_per_device": vals["a"]["flops"] + (n_scan - 1) * body["flops"],
        "true_bytes_per_device": vals["a"]["bytes"] + (n_scan - 1) * body["bytes"],
        "true_collective_bytes_per_device": (vals["a"]["coll"]
                                             + (n_scan - 1) * body["coll"]),
    }


def _ring_extrapolate(arch, shape, mesh, ginfo) -> Dict[str, Any]:
    cfg = get_config(arch)
    out = {"rounds": ginfo["rounds"]}
    vals = {}
    for r in (1, 2):
        fn, args, _, _ = build_cell(arch, shape, mesh, fast=False,
                                    rounds_override=r,
                                    e_cap_override=ginfo["e_cap"])
        comp = fn.lower(*args).compile()
        ca = comp.cost_analysis() or {}
        coll = parse_collective_bytes(comp.as_text())
        vals[r] = {"flops": ca.get("flops", 0.0),
                   "bytes": ca.get("bytes accessed", 0.0),
                   "coll": coll.get("total", 0.0)}
    R = ginfo["rounds"]
    body = {k: vals[2][k] - vals[1][k] for k in ("flops", "bytes", "coll")}
    out["true_flops_per_device"] = vals[1]["flops"] + (R - 1) * body["flops"]
    out["true_bytes_per_device"] = vals[1]["bytes"] + (R - 1) * body["bytes"]
    out["true_collective_bytes_per_device"] = (vals[1]["coll"]
                                               + (R - 1) * body["coll"])
    out["per_round"] = body
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["singlepod", "multipod", "both"])
    ap.add_argument("--fast", action="store_true",
                    help="rolled scans (quick check; roofline numbers undercount)")
    ap.add_argument("--variant", default="baseline", choices=list(RULE_VARIANTS))
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    if args.variant != "baseline":
        args.out = args.out.rstrip("/") + "_" + args.variant

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    meshes = (["singlepod", "multipod"] if args.mesh == "both" else [args.mesh])

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in get_shapes(arch):
            if args.shape and shape.name != args.shape:
                continue
            for mesh_name in meshes:
                os.makedirs(os.path.join(args.out, mesh_name), exist_ok=True)
                path = os.path.join(args.out, mesh_name,
                                    f"{arch}__{shape.name}.json")
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {mesh_name:9s} {arch:22s} {shape.name}")
                    continue
                try:
                    rec = run_cell(arch, shape, mesh_name, fast=args.fast,
                                   variant=args.variant)
                    status = rec["status"]
                    if status == "ok":
                        n_ok += 1
                        print(f"[ok]     {mesh_name:9s} {arch:22s} {shape.name:14s}"
                              f" compile={rec['compile_s']:.1f}s"
                              f" flops/dev={rec['flops_per_device']:.3e}"
                              f" temp={rec['memory']['temp_bytes']/2**30:.2f}GiB")
                    else:
                        n_skip += 1
                        print(f"[skip]   {mesh_name:9s} {arch:22s} {shape.name:14s}"
                              f" ({rec['skip_reason'][:60]})")
                except Exception as e:  # noqa: BLE001
                    n_fail += 1
                    rec = {"arch": arch, "shape": shape.name, "mesh": mesh_name,
                           "status": "failed", "error": repr(e),
                           "traceback": traceback.format_exc()[-2000:]}
                    print(f"[FAIL]   {mesh_name:9s} {arch:22s} {shape.name:14s} {e!r}")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1, default=float)
    print(f"\ndone: ok={n_ok} skipped={n_skip} failed={n_fail}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
