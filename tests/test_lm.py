"""LM smoke + consistency tests for the five assigned archs (reduced
configs): forward shapes/finiteness, prefill==forward, decode==forward,
training reduces loss, vocab-sharded CE correctness."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, smoke_config
from repro.models import lm
from repro.train.optimizer import AdamWConfig, init_adamw

LM_ARCHS = [a for a in ASSIGNED_ARCHS if get_config(a).family == "lm"]
OPTS = lm.ExecOpts(q_block=0, remat=False)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_smoke(arch):
    cfg = smoke_config(arch)
    params, _ = lm.init_lm(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    logits, aux = lm.forward(cfg, params, toks, None, OPTS)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    if cfg.moe:
        assert float(aux) > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_matches_forward(arch):
    cfg = smoke_config(arch)
    params, _ = lm.init_lm(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    lf, _ = lm.forward(cfg, params, toks, None, OPTS)
    lp, _ = lm.prefill(cfg, params, toks, None, OPTS)
    np.testing.assert_allclose(np.asarray(lf[:, -1], np.float32),
                               np.asarray(lp, np.float32), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_decode_matches_forward(arch):
    cfg = smoke_config(arch).replace(capacity_factor=16.0)  # avoid MoE drops
    params, _ = lm.init_lm(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    _, cache = lm.prefill(cfg, params, toks, None, OPTS, margin=4)
    nxt = jax.random.randint(jax.random.PRNGKey(2), (2,), 0, cfg.vocab_size)
    l13, _ = lm.forward(cfg, params, jnp.concatenate([toks, nxt[:, None]], 1),
                        None, OPTS)
    ld, _ = lm.decode_step(cfg, params, cache, nxt, jnp.asarray(12), None, OPTS)
    # MLA decode uses the absorbed form (different bf16 reduction order)
    tol = 0.08 if cfg.attention == "mla" else 0.02
    np.testing.assert_allclose(np.asarray(l13[:, -1], np.float32),
                               np.asarray(ld, np.float32), rtol=tol, atol=tol)


def test_swa_rolling_cache_decode():
    cfg = smoke_config("mixtral-8x7b").replace(capacity_factor=16.0)
    params, _ = lm.init_lm(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 40), 0, cfg.vocab_size)
    _, cache = lm.prefill(cfg, params, toks, None, OPTS)
    assert cache[0].shape[2] == cfg.sliding_window  # rolled to window
    nxt = jax.random.randint(jax.random.PRNGKey(2), (1,), 0, cfg.vocab_size)
    l41, _ = lm.forward(cfg, params, jnp.concatenate([toks, nxt[:, None]], 1),
                        None, OPTS)
    ld, _ = lm.decode_step(cfg, params, cache, nxt, jnp.asarray(40), None, OPTS)
    np.testing.assert_allclose(np.asarray(l41[:, -1], np.float32),
                               np.asarray(ld, np.float32), rtol=0.02, atol=0.02)


def test_training_reduces_loss():
    cfg = smoke_config("qwen2-72b")
    params, _ = lm.init_lm(cfg, jax.random.PRNGKey(0))
    opt = init_adamw(params)
    step = jax.jit(lm.make_train_step(cfg, None, OPTS,
                                      AdamWConfig(lr=3e-3, warmup_steps=2,
                                                  total_steps=40)))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    first = None
    for i in range(15):
        params, opt, m = step(params, opt, batch)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first


def test_vocab_sharded_xent_matches_dense():
    cfg = smoke_config("deepseek-67b")
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 8, cfg.vocab_size))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    ours = float(lm.xent_loss(cfg, logits, labels))
    lp = jax.nn.log_softmax(logits, axis=-1)
    ref = float(-jnp.mean(jnp.take_along_axis(lp, labels[..., None], -1)))
    assert abs(ours - ref) < 1e-4


def test_param_count_matches_init():
    from repro.common.tree import count_params
    for arch in LM_ARCHS:
        cfg = smoke_config(arch)
        params, _ = lm.init_lm(cfg, jax.random.PRNGKey(0))
        got = count_params(params)
        want = cfg.param_count()
        assert abs(got - want) / want < 0.02, (arch, got, want)
