from repro.sharding.rules import (
    DEFAULT_RULES, batch_axes, db_axes, db_shards, logical_to_spec,
    rule_overrides, shard_tree, with_sharding,
)
