"""Knowledge-graph store: CSR adjacency + typed/weighted edges + node payloads.

This is HMGI's relational side (the paper's Neo4j role): entities are nodes,
relationships are typed weighted edges, and each node carries the id of its
embedding in the vector side of the index. Traversal operators live in
``core/traversal.py`` and run as fixed-hop masked frontier pushes over these
arrays (DESIGN.md §2.3).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class GraphStore(NamedTuple):
    indptr: jax.Array       # (N+1,) int32 CSR row pointers (by src)
    indices: jax.Array      # (E,) int32 dst node per edge
    src: jax.Array          # (E,) int32 src node per edge (COO twin for segment ops)
    edge_type: jax.Array    # (E,) int32
    edge_weight: jax.Array  # (E,) fp32
    node_modality: jax.Array  # (N,) int32 — modality id of each node's embedding

    @property
    def n_nodes(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def n_edges(self) -> int:
        return self.indices.shape[0]

    @property
    def nbytes(self) -> int:
        return sum(int(a.size) * a.dtype.itemsize for a in self)


def from_edges(n_nodes: int, src: np.ndarray, dst: np.ndarray,
               edge_type: Optional[np.ndarray] = None,
               edge_weight: Optional[np.ndarray] = None,
               node_modality: Optional[np.ndarray] = None,
               make_undirected: bool = False) -> GraphStore:
    """Host-side construction: sorts edges by src into CSR."""
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    et = np.zeros_like(src) if edge_type is None else np.asarray(edge_type, np.int32)
    ew = np.ones(len(src), np.float32) if edge_weight is None else np.asarray(edge_weight, np.float32)
    if make_undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        et = np.concatenate([et, et])
        ew = np.concatenate([ew, ew])
    order = np.argsort(src, kind="stable")
    src, dst, et, ew = src[order], dst[order], et[order], ew[order]
    counts = np.bincount(src, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, np.int32)
    np.cumsum(counts, out=indptr[1:])
    nm = (np.zeros(n_nodes, np.int32) if node_modality is None
          else np.asarray(node_modality, np.int32))
    return GraphStore(
        indptr=jnp.asarray(indptr), indices=jnp.asarray(dst), src=jnp.asarray(src),
        edge_type=jnp.asarray(et), edge_weight=jnp.asarray(ew),
        node_modality=jnp.asarray(nm),
    )


def degree(g: GraphStore) -> jax.Array:
    return g.indptr[1:] - g.indptr[:-1]
