"""Config system: dataclasses for architectures, input shapes, meshes, and training.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (an ``ArchConfig`` subclass instance) and ``SHAPES`` (its own
shape set). The registry in ``configs/__init__.py`` resolves ``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    """One (input-shape) cell for an architecture.

    kind:
      lm:     "train" | "prefill" | "decode"   (decode => serve_step w/ KV cache)
      gnn:    "full_graph" | "minibatch" | "molecule"
      recsys: "train" | "serve" | "retrieval"
    """
    name: str
    kind: str
    dims: Dict[str, int] = field(default_factory=dict)
    skip: bool = False           # documented-skip cells (long_500k on full attn)
    skip_reason: str = ""

    def __getitem__(self, k: str) -> int:
        return self.dims[k]


# ---------------------------------------------------------------------------
# Architecture configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchConfig:
    arch_id: str = ""
    family: str = ""             # "lm" | "gnn" | "recsys"
    source: str = ""             # citation from the assignment block
    # per-arch logical->mesh rule overrides (e.g. phi4 context parallelism)
    sharding_overrides: Dict[str, Any] = field(default_factory=dict)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class LMConfig(ArchConfig):
    family: str = "lm"
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0            # 0 => d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    qkv_bias: bool = False       # qwen2
    tie_embeddings: bool = False # phi4-mini
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # attention variant
    attention: str = "gqa"       # "gqa" | "mla"
    sliding_window: int = 0      # >0 => SWA (mixtral)
    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # MoE
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0            # per-expert hidden (dsv2); mixtral uses d_ff
    first_dense_layers: int = 0  # dsv2-lite: first layer is a dense FFN
    dense_d_ff: int = 0          # hidden of those dense layers
    capacity_factor: float = 1.25
    # execution
    dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: bool = True
    remat_policy: str = "nothing"   # "nothing" | "dots" | "full"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def param_count(self) -> int:
        """Analytic parameter count (matches init; used for 6ND roofline)."""
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        if self.attention == "mla":
            # kv down + rope k + kv up (nope k + v per head) + q proj
            attn = (d * self.kv_lora_rank + d * self.qk_rope_head_dim
                    + self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
                    + d * self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                    + self.n_heads * self.v_head_dim * d)
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
            if self.qkv_bias:
                attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        ffn_dense = 3 * d * self.d_ff
        total = 0
        for layer in range(L):
            total += attn + 2 * d  # two rmsnorm scales
            if self.moe and layer >= self.first_dense_layers:
                e_ff = self.moe_d_ff or self.d_ff
                total += self.n_experts * 3 * d * e_ff
                total += self.n_shared_experts * 3 * d * e_ff
                total += d * self.n_experts  # router
            elif self.moe and self.first_dense_layers:
                total += 3 * d * (self.dense_d_ff or self.d_ff)
            else:
                total += ffn_dense
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top_k + shared)."""
        if not self.moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        e_ff = self.moe_d_ff or self.d_ff
        inactive = (L - self.first_dense_layers) * (self.n_experts - self.top_k) * 3 * d * e_ff
        return self.param_count() - inactive


@dataclass(frozen=True)
class GNNConfig(ArchConfig):
    family: str = "gnn"
    model: str = ""              # "dimenet" | "egnn" | "nequip" | "equiformer_v2"
    n_layers: int = 4
    d_hidden: int = 64
    # dimenet
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    # nequip / equiformer
    l_max: int = 2
    m_max: int = 0               # equiformer-v2 eSCN truncation
    n_rbf: int = 8
    cutoff: float = 5.0
    n_heads: int = 8
    d_feat_in: int = 0           # input node-feature dim (0 => atom-type embed)
    n_species: int = 32
    dtype: str = "float32"

    def param_count(self) -> int:  # approximate; exact count read from init
        return 0


@dataclass(frozen=True)
class RecsysConfig(ArchConfig):
    family: str = "recsys"
    n_sparse: int = 39
    n_dense: int = 0
    embed_dim: int = 10
    vocab_per_field: int = 100_000
    cin_layers: Tuple[int, ...] = (200, 200, 200)
    mlp_layers: Tuple[int, ...] = (400, 400)
    dtype: str = "float32"

    def param_count(self) -> int:
        p = self.n_sparse * self.vocab_per_field * self.embed_dim
        m = self.n_sparse
        prev = m
        d_in = self.n_sparse * self.embed_dim + self.n_dense
        for h in self.cin_layers:
            p += h * prev * m
            prev = h
        p += sum(self.cin_layers)  # cin -> logit
        for h in self.mlp_layers:
            p += d_in * h + h
            d_in = h
        p += d_in + 1  # mlp logit + linear part bias
        return p


# ---------------------------------------------------------------------------
# HMGI (the paper's own system) config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HMGIConfig(ArchConfig):
    """Configuration of the Hybrid Multimodal Graph Index itself."""
    arch_id: str = "hmgi"
    family: str = "index"
    dim: int = 384                         # embedding dim (per modality override)
    modalities: Tuple[str, ...] = ("text", "image", "audio", "video")
    modality_dims: Dict[str, int] = field(default_factory=dict)
    n_partitions: int = 64                 # K-means partitions per modality (Eq. 1)
    kmeans_iters: int = 16
    n_probe: int = 8                       # partitions scanned per query
    top_k: int = 10
    # quantization (Eq. 2)
    quant_bits: int = 8                    # 16 | 8 | 4 ; "flash quantization"
    adaptive_quant: bool = True            # memory-pressure driven bit switch
    memory_budget_bytes: int = 0           # 0 = unlimited
    # NSW graph refinement layer
    nsw_degree: int = 16
    nsw_ef: int = 64
    use_nsw_refine: bool = False
    # delta store (MVCC)
    delta_capacity: int = 4096
    compact_threshold: float = 0.5         # compact when delta half full
    delta_rescore_margin: int = 16         # extra int8-scan survivors rescored
                                           # in fp32 (larger = closer to exact
                                           # brute force on a crowded delta)
    # hybrid fusion (Eq. 3)
    w_vector: float = 0.6
    w_graph: float = 0.4
    adaptive_weights: bool = True          # DEG-inspired runtime weighting
    max_hops: int = 2
    # cost model (Eq. 5)
    cost_alpha: float = 1.0
    cost_beta: float = 0.01
    cost_gamma: float = 0.1
    # adaptive maintenance (cost_model.plan_maintenance + repro.maintenance)
    maint_auto: bool = True                # insert/delete auto-trigger maintain()
    maint_budget_rows: int = 1024          # bounded work per maintain() call
    maint_chunk: int = 256                 # delta rows drained per compact step
    maint_delta_pressure: float = 0.5      # drain when delta watermark ≥ this
    maint_heat_imbalance: float = 4.0      # split when hottest ≥ this × mean heat
    maint_split_min_fill: float = 0.75     # ... and the hot partition is this full
    maint_merge_max_fill: float = 0.10     # merge partitions emptier than this
    maint_drift_threshold: float = 0.35    # recluster at +35% mean assigned dist
    # attribute-filtered search (predicate pushdown vs oversampling)
    filter_prefilter_max_sel: float = 0.5  # pushdown when sel <= this
    filter_oversample: float = 3.0         # initial k inflation when not
    # sharded execution path (cost_model.plan_device_layout)
    shard_layout: str = "auto"             # "auto" | "single" | "sharded"
    shard_device_budget_bytes: int = 256 << 20   # shard the stable scan when
                                           # one device's quantized slab share
                                           # would exceed this
    # durability (repro.persistence; docs/DESIGN.md §7)
    wal_sync_every: int = 1                # fsync the op log every N appends
                                           # (1 = durable at return)
    snapshot_keep: int = 2                 # retained snapshots; ≥2 keeps a
                                           # fallback if the newest corrupts
    # observability (repro.obs)
    obs_sync_spans: bool = False           # block_until_ready at span exit so
                                           # async device work is charged to
                                           # the span that launched it (slower;
                                           # profiling only)
    dtype: str = "float32"
