"""Streaming ingestion under MVCC with *adaptive* maintenance: inserts,
updates and deletes with live queries — the delta drains in bounded
incremental steps (no manual compact, no stop-the-world rebuild), cold
partitions merge away, and workload skew splits the hot partition in place.

    PYTHONPATH=src python examples/dynamic_updates.py
"""
import numpy as np

from repro.configs import get_config
from repro.core import HMGIIndex
from repro.data.synthetic import make_corpus

corpus = make_corpus(n_nodes=1000, modality_dims={"text": 48}, seed=0)
cfg = get_config("hmgi").replace(n_partitions=16, n_probe=4, top_k=5,
                                 delta_capacity=128,
                                 maint_chunk=32, maint_budget_rows=64)
index = HMGIIndex(cfg, seed=0)
index.ingest({"text": (corpus.node_ids["text"], corpus.vectors["text"])},
             n_nodes=corpus.n_nodes, edges=(corpus.src, corpus.dst))

# 1. streaming writes: maint_auto (the default) lets insert/delete trigger
#    bounded maintenance — watch the delta watermark stay bounded without a
#    single explicit compact
rng = np.random.default_rng(0)
for step in range(8):
    ids = rng.integers(0, corpus.n_nodes, 40).astype(np.int32)  # some are
    vecs = rng.normal(size=(40, 48)).astype(np.float32)         # updates
    index.insert("text", ids, vecs)
    # live query against the newest version of a just-written id
    _, found = index.search(vecs[:1], "text", k=1)
    fresh = int(found[0, 0]) == int(ids[0])
    delta_rows = int(index.modalities["text"].delta.count)
    print(f"step {step}: delta={delta_rows:4d} "
          f"fresh-read={'OK' if fresh else 'STALE!'}  "
          f"maintenance: {index.metrics().get('maintenance', 'n/a')}")

# 2. an explicit budgeted pass: plan + apply ≤64 rows of work
report = index.maintain("text", budget=64)
print(f"explicit maintain: {report.describe()}")

# 3. hollow out a partition with deletes -> delete's auto-trigger merges it
#    into its nearest sibling and parks the slot (deleted ids never
#    resurrect; the parked slot is reused by the next split)
m = index.modalities["text"]
p = int(np.argmin(np.asarray(m.ivf.counts)))
victims = np.asarray(m.ivf.ids[p])
victims = victims[victims >= 0]
index.delete("text", victims)
print(f"after deleting partition {p}'s rows: "
      f"{index.metrics()['maintenance']}")
print(f"live partitions: {int(np.sum(~m.stats.parked))}/{cfg.n_partitions}")

# 4. workload skew triggers an in-place split of the hot partition (only
#    its rows move, byte-identically — no full rebuild)
m.workload.hits[:] = 0
m.workload.hits[int(np.argmax(np.asarray(m.ivf.counts)))] = 50_000
if index.maybe_repartition("text"):
    print("workload skew detected -> hot partition split (bounded work)")
print(f"final delta size: {int(m.delta.count)}; "
      f"live partitions: {int(np.sum(~m.stats.parked))}/{cfg.n_partitions}")
