"""Shared GNN-family shape set (assigned per the task block)."""
from repro.configs.base import ShapeSpec


def gnn_shapes() -> list[ShapeSpec]:
    return [
        ShapeSpec("full_graph_sm", "full_graph",
                  {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433}),
        ShapeSpec("minibatch_lg", "minibatch",
                  {"n_nodes": 232_965, "n_edges": 114_615_892,
                   "batch_nodes": 1024, "fanout0": 15, "fanout1": 10}),
        ShapeSpec("ogb_products", "full_graph",
                  {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100}),
        ShapeSpec("molecule", "molecule",
                  {"n_nodes": 30, "n_edges": 64, "batch": 128}),
    ]
