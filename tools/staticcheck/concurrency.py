"""Concurrency invariants (HMG201-HMG204): the static layer of PR 9.

The serving path runs the facade from dozens of client threads (the load
bench drives 64), so shared mutable state needs a machine-checked
discipline, not a comment. The registry (``GUARDED_BY`` in
``tools/staticcheck/registry.py``) declares which attributes of which
classes are guarded by which lock; these rules enforce the declaration
*lexically* — stdlib ``ast``, nothing imported — and
``tools/racecheck.py`` enforces it dynamically (Eraser-style locksets +
deterministic interleaving replay).

  HMG201  guarded-by: every read/write of a registered attribute outside
          ``__init__`` must sit inside ``with <recv>.<lock>`` or a
          registered ``*_locked`` method (whose call sites must themselves
          hold the lock). Double-checked fast-path reads carry a reasoned
          pragma — the pragma inventory *is* the list of lock-free reads.
  HMG202  no blocking calls (fsync, sleeps, joins, ``block_until_ready``,
          future ``result``/``wait``) while a fine-grained lock is held:
          every other thread touching that structure stalls behind the
          I/O. The coarse single-writer lock is exempt by design.
  HMG203  lock-order: nested ``with``-lock blocks and calls into known
          lock-acquiring helpers form a global acquisition graph across
          all checked files; a cycle is a potential deadlock and fails
          the build naming the cycle.
  HMG204  publication discipline: a class that starts worker threads may
          not mutate undeclared ``self`` attributes once threads may be
          running — every shared mutable must be in the registry (and
          thereby guarded + dynamically checked), or carry a pragma.

Lexical scope notes: a nested ``def`` does not inherit the enclosing
``with``-lock (its body runs later, possibly on another thread), and only
a class's own ``__init__``/``__post_init__`` is construction-exempt.
"""
from __future__ import annotations

import ast
from pathlib import PurePosixPath
from typing import Dict, Iterable, List, Optional, Set, Tuple

from tools.staticcheck import Violation
from tools.staticcheck.registry import (
    BLOCKING_CALLS,
    GUARDED_BY,
    GUARDED_METHODS,
    GuardSpec,
    HMG202_LOCK_ATTRS,
    LOCK_ACQUIRING_CALLS,
    THREAD_SPAWN_CALLS,
    THREAD_START_CALLS,
)

_INIT_NAMES = ("__init__", "__post_init__")


def _posix(path: str) -> str:
    return PurePosixPath(path).as_posix()


def _specs_for(path: str,
               guards: Iterable[GuardSpec]) -> List[GuardSpec]:
    p = _posix(path)
    return [s for s in guards if any(p.endswith(f) for f in s.files)]


def _call_name(node: ast.Call) -> Tuple[Optional[str], Optional[str]]:
    f = node.func
    if isinstance(f, ast.Name):
        return None, f.id
    if isinstance(f, ast.Attribute):
        recv = f.value.id if isinstance(f.value, ast.Name) else None
        return recv, f.attr
    return None, None


def _lock_attr_of_with_item(item: ast.withitem) -> Optional[Tuple[str, str]]:
    """(receiver, lock attr) when the context manager is ``<recv>.<attr>``
    with a lock-ish attribute name, else None."""
    expr = item.context_expr
    if isinstance(expr, ast.Attribute) and isinstance(expr.value, ast.Name):
        if expr.attr.endswith("lock") or expr.attr.endswith("_lock"):
            return expr.value.id, expr.attr
    return None


def _locked_method_lock(cls: Optional[str], fn: Optional[str],
                        methods: Dict[str, str]) -> Optional[str]:
    """Lock attr a ``*_locked`` method's body holds per the registry, else
    None (an unregistered ``*_locked`` method is its own violation)."""
    if fn is None or not fn.endswith("_locked"):
        return None
    node = methods.get(f"{cls}.{fn}")
    return node.split(".", 1)[1] if node else None


# --------------------------------------------------------------------- HMG201
def check_hmg201(path: str, tree: ast.Module,
                 guards: Optional[Iterable[GuardSpec]] = None,
                 methods: Optional[Dict[str, str]] = None
                 ) -> List[Violation]:
    guards = GUARDED_BY if guards is None else tuple(guards)
    methods = GUARDED_METHODS if methods is None else methods
    specs = _specs_for(path, guards)
    if not specs:
        return []
    by_cls = {s.cls: s for s in specs}
    by_recv = {r: s for s in specs for r in s.receivers}
    out: List[Violation] = []

    def flag(node: ast.AST, spec: GuardSpec, what: str) -> None:
        out.append(Violation(
            "HMG201", path, node.lineno,
            f"{what} of guarded attribute '{node.attr}' "
            f"({spec.cls}, guarded by {spec.lock}) outside 'with "
            f"<obj>.{spec.lock}' — wrap it, or pragma a double-checked "
            "fast path with the reason"))

    def visit(node: ast.AST, cls: Optional[str], fnstack: Tuple[str, ...],
              held: frozenset) -> None:
        if isinstance(node, ast.ClassDef):
            for sub in node.body:
                visit(sub, node.name, (), frozenset())
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fn_held: Set[str] = set()
            lk = _locked_method_lock(cls, node.name, methods)
            if node.name.endswith("_locked"):
                if lk is None:
                    out.append(Violation(
                        "HMG201", path, node.lineno,
                        f"'{node.name}' uses the *_locked convention but "
                        "is not in GUARDED_METHODS — register which lock "
                        "its callers must hold"))
                else:
                    fn_held.add(lk)
            # a nested def does NOT inherit the enclosing with-lock: its
            # body runs later, possibly on another thread
            for sub in node.body:
                visit(sub, cls, fnstack + (node.name,), frozenset(fn_held))
            return
        if isinstance(node, ast.With):
            new = set(held)
            for item in node.items:
                hit = _lock_attr_of_with_item(item)
                if hit:
                    new.add(hit[1])
                visit(item.context_expr, cls, fnstack, held)
            for sub in node.body:
                visit(sub, cls, fnstack, frozenset(new))
            return
        in_init = len(fnstack) == 1 and fnstack[0] in _INIT_NAMES
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name):
            recv = node.value.id
            spec = None
            if recv == "self" and cls in by_cls and \
                    node.attr in by_cls[cls].attrs:
                spec = by_cls[cls]
                if in_init:
                    spec = None          # construction is single-threaded
            elif recv in by_recv and node.attr in by_recv[recv].attrs:
                spec = by_recv[recv]
            if spec is not None and spec.lock not in held:
                kind = "write" if isinstance(node.ctx,
                                             (ast.Store, ast.Del)) \
                    else "read"
                flag(node, spec, kind)
        if isinstance(node, ast.Call):
            _, name = _call_name(node)
            if name and name.endswith("_locked"):
                want = {m.split(".", 1)[1]
                        for k, m in methods.items()
                        if k.split(".", 1)[1] == name}
                if want and not (want & held):
                    out.append(Violation(
                        "HMG201", path, node.lineno,
                        f"call to '{name}' without holding "
                        f"{'/'.join(sorted(want))} — *_locked methods "
                        "require the caller to hold the lock"))
        for sub in ast.iter_child_nodes(node):
            visit(sub, cls, fnstack, held)

    for top in tree.body:
        visit(top, None, (), frozenset())
    return out


# --------------------------------------------------------------------- HMG202
def check_hmg202(path: str, tree: ast.Module,
                 blocking: Tuple[str, ...] = BLOCKING_CALLS,
                 lock_attrs: Tuple[str, ...] = HMG202_LOCK_ATTRS,
                 methods: Optional[Dict[str, str]] = None
                 ) -> List[Violation]:
    methods = GUARDED_METHODS if methods is None else methods
    out: List[Violation] = []

    def scan_body(body, lock_name: str, cls: Optional[str]) -> None:
        # explicit stack so nested def/lambda subtrees are PRUNED (their
        # bodies run later, without the lock) — ast.walk would descend
        stack = list(body)
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue                 # deferred execution
            if isinstance(node, ast.Call):
                _, name = _call_name(node)
                if name in blocking:
                    out.append(Violation(
                        "HMG202", path, node.lineno,
                        f"blocking call '{name}()' while holding "
                        f"{lock_name} — every other thread touching "
                        "that structure stalls behind it; move the "
                        "wait outside the critical section"))
            stack.extend(ast.iter_child_nodes(node))

    def visit(node: ast.AST, cls: Optional[str]) -> None:
        if isinstance(node, ast.ClassDef):
            for sub in node.body:
                visit(sub, node.name)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            lk = _locked_method_lock(cls, node.name, methods)
            if lk is not None and lk in lock_attrs:
                scan_body(node.body, f"{cls}.{lk} (via {node.name})", cls)
        if isinstance(node, ast.With):
            for item in node.items:
                hit = _lock_attr_of_with_item(item)
                if hit and hit[1] in lock_attrs:
                    scan_body(node.body, f"{hit[0]}.{hit[1]}", cls)
                    break
        for sub in ast.iter_child_nodes(node):
            visit(sub, cls)

    visit(tree, None)
    # a with-block nested in a flagged outer with would double-report the
    # same call; dedup on (line, message)
    seen: Set[Tuple[int, str]] = set()
    uniq = []
    for v in out:
        if (v.line, v.message) not in seen:
            seen.add((v.line, v.message))
            uniq.append(v)
    return uniq


# --------------------------------------------------------------------- HMG203
def _lock_node_id(path: str, cls: Optional[str], recv: str, attr: str,
                  guards: Iterable[GuardSpec]) -> str:
    """Canonical cross-module name for a lock: class-qualified when
    resolvable (``self`` inside a class, or a registered receiver),
    file-qualified otherwise."""
    if recv == "self" and cls:
        return f"{cls}.{attr}"
    for s in guards:
        if s.lock == attr and recv in s.receivers:
            return f"{s.cls}.{attr}"
    return f"{_posix(path)}:{recv}.{attr}"


def collect_lock_edges(path: str, tree: ast.Module,
                       guards: Optional[Iterable[GuardSpec]] = None,
                       acquiring: Optional[Dict[str, str]] = None,
                       methods: Optional[Dict[str, str]] = None
                       ) -> List[Tuple[str, str, int]]:
    """All (held_lock, acquired_lock, line) pairs in one file, from nested
    ``with``-lock blocks and calls into known lock-acquiring helpers."""
    guards = GUARDED_BY if guards is None else tuple(guards)
    acquiring = LOCK_ACQUIRING_CALLS if acquiring is None else acquiring
    methods = GUARDED_METHODS if methods is None else methods
    edges: List[Tuple[str, str, int]] = []

    def visit(node: ast.AST, cls: Optional[str],
              held: Tuple[str, ...]) -> None:
        if isinstance(node, ast.ClassDef):
            for sub in node.body:
                visit(sub, node.name, ())
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            base: Tuple[str, ...] = ()
            lock_node = methods.get(f"{cls}.{node.name}")
            if lock_node:
                base = (lock_node,)
            for sub in node.body:
                visit(sub, cls, base)
            return
        if isinstance(node, ast.With):
            new = held
            for item in node.items:
                hit = _lock_attr_of_with_item(item)
                if hit:
                    nid = _lock_node_id(path, cls, hit[0], hit[1], guards)
                    for h in held:
                        if h != nid:
                            edges.append((h, nid, node.lineno))
                    new = new + (nid,)
            for sub in node.body:
                visit(sub, cls, new)
            return
        if isinstance(node, ast.Call) and held:
            _, name = _call_name(node)
            target = acquiring.get(name or "")
            if target:
                for h in held:
                    if h != target:
                        edges.append((h, target, node.lineno))
        for sub in ast.iter_child_nodes(node):
            visit(sub, cls, held)

    for top in tree.body:
        visit(top, None, ())
    return edges


def check_hmg203(files: List[Tuple[str, ast.Module]],
                 guards: Optional[Iterable[GuardSpec]] = None,
                 acquiring: Optional[Dict[str, str]] = None,
                 methods: Optional[Dict[str, str]] = None
                 ) -> List[Violation]:
    """Global pass: build the acquisition digraph over every file and fail
    on cycles. Each edge remembers one witness site for the report."""
    graph: Dict[str, Set[str]] = {}
    witness: Dict[Tuple[str, str], Tuple[str, int]] = {}
    for path, tree in files:
        for a, b, line in collect_lock_edges(path, tree, guards, acquiring,
                                             methods):
            graph.setdefault(a, set()).add(b)
            graph.setdefault(b, set())
            witness.setdefault((a, b), (path, line))

    out: List[Violation] = []
    WHITE, GRAY, BLACK = 0, 1, 2
    color = {n: WHITE for n in graph}
    stack: List[str] = []

    def dfs(n: str) -> Optional[List[str]]:
        color[n] = GRAY
        stack.append(n)
        for nxt in sorted(graph[n]):
            if color[nxt] == GRAY:
                return stack[stack.index(nxt):] + [nxt]
            if color[nxt] == WHITE:
                cyc = dfs(nxt)
                if cyc:
                    return cyc
        stack.pop()
        color[n] = BLACK
        return None

    for n in sorted(graph):
        if color[n] == WHITE:
            cyc = dfs(n)
            if cyc:
                path, line = witness[(cyc[0], cyc[1])]
                sites = "; ".join(
                    f"{a}->{b} at {witness[(a, b)][0]}:{witness[(a, b)][1]}"
                    for a, b in zip(cyc, cyc[1:]))
                out.append(Violation(
                    "HMG203", path, line,
                    "lock acquisition cycle (potential deadlock): "
                    + " -> ".join(cyc) + f" [{sites}]"))
                break                    # one cycle report is actionable
    return out


# --------------------------------------------------------------------- HMG204
def check_hmg204(path: str, tree: ast.Module,
                 guards: Optional[Iterable[GuardSpec]] = None
                 ) -> List[Violation]:
    guards = GUARDED_BY if guards is None else tuple(guards)
    out: List[Violation] = []
    for top in ast.walk(tree):
        if not isinstance(top, ast.ClassDef):
            continue
        spawns = any(
            isinstance(n, ast.Call) and _call_name(n)[1] in
            THREAD_SPAWN_CALLS for n in ast.walk(top))
        if not spawns:
            continue
        declared: Set[str] = set()
        for s in guards:
            if s.cls == top.name:
                declared |= set(s.attrs)
                declared.add(s.lock)

        def self_stores(fn: ast.AST):
            for n in ast.walk(fn):
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and n is not fn:
                    continue
                tgts = []
                if isinstance(n, ast.Assign):
                    tgts = n.targets
                elif isinstance(n, (ast.AnnAssign, ast.AugAssign)):
                    tgts = [n.target]
                for t in tgts:
                    for leaf in ast.walk(t):
                        if isinstance(leaf, ast.Attribute) and \
                                isinstance(leaf.value, ast.Name) and \
                                leaf.value.id == "self":
                            yield leaf

        for fn in top.body:
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if fn.name in _INIT_NAMES:
                # publication starts at the first thread start/submit
                started_at = min(
                    (n.lineno for n in ast.walk(fn)
                     if isinstance(n, ast.Call)
                     and _call_name(n)[1] in THREAD_START_CALLS),
                    default=None)
                if started_at is None:
                    continue
                for leaf in self_stores(fn):
                    if leaf.lineno > started_at and \
                            leaf.attr not in declared:
                        out.append(Violation(
                            "HMG204", path, leaf.lineno,
                            f"'{top.name}.{leaf.attr}' mutated after the "
                            "worker thread started but is not in the "
                            "guarded-by registry — declare it (and its "
                            "lock) in GUARDED_BY"))
            else:
                for leaf in self_stores(fn):
                    if leaf.attr not in declared:
                        out.append(Violation(
                            "HMG204", path, leaf.lineno,
                            f"'{top.name}.{leaf.attr}' mutated while "
                            f"'{top.name}' worker threads may be running "
                            "but is not in the guarded-by registry — "
                            "declare it (and its lock) in GUARDED_BY"))
    return out


CONCURRENCY_AST_RULES = {
    "HMG201": check_hmg201,
    "HMG202": check_hmg202,
    "HMG204": check_hmg204,
}
