"""Public jit'd wrapper for the fused quantized scan.

On CPU (this container) the kernel body runs under ``interpret=True``; on a
real TPU the same pallas_call compiles to Mosaic. The wrapper pads N to the
block size and returns the exact top-k ids/scores over the chunk survivors.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ivf_topk.ivf_topk import scan_topk_pallas
from repro.kernels.ivf_topk.ref import topk_from_chunks


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("k", "chunk", "block_n", "interpret"))
def scan_topk_quantized(queries, data_i8, vmin, scale, valid, *, k: int,
                        chunk: int = 128, block_n: int = 512,
                        interpret: bool | None = None):
    """Top-k over a quantized corpus slab.

    queries (Q, d) fp32; data_i8 (N, d) int8; vmin/scale (N,); valid (N,) bool.
    Returns (scores (Q, k), row_ids (Q, k)) — descending, -inf/-1 padded.
    """
    interp = _on_cpu() if interpret is None else interpret
    n, d = data_i8.shape
    pad = (-n) % block_n
    if pad:
        data_i8 = jnp.pad(data_i8, ((0, pad), (0, 0)))
        vmin = jnp.pad(vmin, (0, pad))
        scale = jnp.pad(scale, (0, pad), constant_values=1.0)
        valid = jnp.pad(valid, (0, pad))
    # invalid rows get a -3e38 additive bias inside the kernel (sign-safe)
    NEG = jnp.float32(-3e38)
    bias = jnp.where(valid, 0.0, NEG)
    cmax, carg = scan_topk_pallas(queries, data_i8, vmin, scale, bias,
                                  chunk=chunk, block_n=block_n, interpret=interp)
    vals, ids = topk_from_chunks(cmax, carg, min(k, cmax.shape[1]))
    dead = vals <= NEG * 0.5
    vals = jnp.where(dead, -jnp.inf, vals)
    ids = jnp.where(dead, -1, ids)
    if k > vals.shape[1]:
        vals = jnp.pad(vals, ((0, 0), (0, k - vals.shape[1])), constant_values=-jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, k - ids.shape[1])), constant_values=-1)
    return vals, ids
