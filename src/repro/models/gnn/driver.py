"""GNN driver: synthetic graph builders per shape kind, model dispatch,
loss/train steps for the three execution layouts (full_graph / minibatch /
molecule)."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.params import Builder
from repro.models.gnn import dimenet as dimenet_mod
from repro.models.gnn import egnn as egnn_mod
from repro.models.gnn import equiformer_v2 as eqv2_mod
from repro.models.gnn import nequip as nequip_mod
from repro.models.gnn.common import FlatGraph, LocalExec, RingGraph, run_flat, to_ring
from repro.train.optimizer import AdamWConfig, adamw_update, init_adamw

N_CLASSES = 16


def make_flat_graph(n_nodes: int, n_edges: int, d_feat: int, seed: int = 0,
                    n_classes: int = N_CLASSES) -> FlatGraph:
    """Synthetic flat graph; unit-sphere positions (geometric archs on
    non-geometric graphs — docs/DESIGN.md §4)."""
    rng = np.random.default_rng(seed)
    feats = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    pos = rng.normal(size=(n_nodes, 3)).astype(np.float32)
    pos /= np.linalg.norm(pos, axis=1, keepdims=True) + 1e-9
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = np.where(dst == src, (dst + 1) % n_nodes, dst)   # no self-loops
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    return FlatGraph(
        feats=jnp.asarray(feats), positions=jnp.asarray(pos),
        edge_src=jnp.asarray(src), edge_dst=jnp.asarray(dst),
        edge_mask=jnp.ones((n_edges,), bool),
        node_mask=jnp.ones((n_nodes,), bool),
        labels=jnp.asarray(labels))


def make_molecule_batch(batch: int, n_nodes: int, n_edges: int, seed: int = 0):
    """Batched small graphs as a leading-B FlatGraph + regression targets."""
    rng = np.random.default_rng(seed)
    gs = [make_flat_graph(n_nodes, n_edges, 4, seed=seed + i) for i in range(batch)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *gs)
    energy = jnp.asarray(rng.normal(size=(batch,)).astype(np.float32))
    return stacked, energy


_MODELS = {
    "egnn": egnn_mod,
    "dimenet": dimenet_mod,
    "nequip": nequip_mod,
    "equiformer_v2": eqv2_mod,
}


def init_model(cfg, key, d_feat_in: int, n_out: int = N_CLASSES):
    return _MODELS[cfg.model].init(cfg, key, d_feat_in, n_out)


def node_logits_local(cfg, params, g: FlatGraph, triplets=None):
    ex = LocalExec(g)
    mod = _MODELS[cfg.model]
    if cfg.model == "dimenet":
        return mod.node_logits(cfg, params, g.feats, g.positions, g.node_mask,
                               ex, triplets=triplets)
    return mod.node_logits(cfg, params, g.feats, g.positions, g.node_mask, ex)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def _ce_sums(logits, labels, mask):
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    ok = mask.astype(jnp.float32)
    correct = (jnp.argmax(logits, -1) == labels).astype(jnp.float32) * ok
    return {"loss_sum": -jnp.sum(ll * ok), "correct": jnp.sum(correct),
            "count": jnp.sum(ok)}


def full_graph_loss(cfg, params, g, mesh=None, triplets=None):
    """CE over labelled nodes. g: FlatGraph (local) or RingGraph (mesh)."""
    if mesh is None:
        logits = node_logits_local(cfg, params, g, triplets)
        return _ce_sums(logits, g.labels, g.node_mask)

    mod = _MODELS[cfg.model]

    def apply_local(params, feats, pos, nmask, labels, ex):
        logits = mod.node_logits(cfg, params, feats, pos, nmask, ex)
        return _ce_sums(logits, labels, nmask)

    return run_flat(apply_local, g, params, mesh)


def molecule_loss(cfg, params, batched_g: FlatGraph, energy, triplets=None):
    """MSE on per-graph energies (masked scalar sum-pool)."""
    def one(g, t):
        logits = node_logits_local(cfg, params, g, t)
        return jnp.sum(logits[:, 0] * g.node_mask)

    pred = (jax.vmap(one)(batched_g, triplets) if triplets is not None
            else jax.vmap(lambda g: one(g, None))(batched_g))
    return {"loss_sum": jnp.sum((pred - energy) ** 2),
            "count": jnp.asarray(float(energy.shape[0]))}


def minibatch_loss(cfg, params, batched_g: FlatGraph, root_labels):
    """CE on each sampled tree's root node (local index 0)."""
    def one(g):
        return node_logits_local(cfg, params, g, None)[0]

    logits = jax.vmap(one)(batched_g)                       # (B, n_classes)
    return _ce_sums(logits, root_labels, jnp.ones_like(root_labels, jnp.float32))


# ---------------------------------------------------------------------------
# train steps
# ---------------------------------------------------------------------------

def make_train_step(cfg, kind: str, mesh=None,
                    opt_cfg: AdamWConfig = AdamWConfig(lr=1e-3)):
    def loss_fn(params, batch):
        if kind == "full_graph":
            sums = full_graph_loss(cfg, params, batch["graph"], mesh,
                                   batch.get("triplets"))
        elif kind == "molecule":
            sums = molecule_loss(cfg, params, batch["graph"], batch["energy"],
                                 batch.get("triplets"))
        elif kind == "minibatch":
            sums = minibatch_loss(cfg, params, batch["graph"], batch["labels"])
        else:
            raise ValueError(kind)
        loss = sums["loss_sum"] / jnp.maximum(sums["count"], 1.0)
        return loss, sums

    def step(params, opt_state, batch):
        (loss, sums), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = adamw_update(opt_cfg, grads, opt_state, params)
        metrics = {"loss": loss, **{k: v for k, v in sums.items()}, **om}
        return params, opt_state, metrics

    return step
