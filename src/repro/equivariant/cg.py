"""Real-basis Clebsch–Gordan (coupling) tensors.

Rather than transcribing Racah's formula + complex→real basis changes (sign
conventions are a classic bug farm), each C^{l1 l2 l3} is solved *numerically
in float64* as the null space of the equivariance constraint

    (D3 ⊗ D1 ⊗ D2)ᵀ vec(C) = vec(C)   for random rotations R

using the same Ivanic–Ruedenberg D matrices the models use at runtime — so
CG ⊗ D consistency is exact by construction. Coupling multiplicities are 1,
so the null space is 1-dimensional; tensors are normalised to ‖C‖=1 and
cached per (l1, l2, l3).
"""
from __future__ import annotations

import functools
import math
from typing import Dict, List, Tuple

import numpy as np

from repro.equivariant import spherical as sph


def _wigner_d_np(R: np.ndarray, l_max: int) -> List[np.ndarray]:
    """Float64 numpy mirror of spherical.wigner_d_from_rotation (setup only)."""
    import jax.numpy as jnp  # reuse the jnp implementation at float32? no —
    # reimplement with numpy for float64 precision:
    batch = R.shape[:-2]
    D0 = np.ones(batch + (1, 1))
    perm = [1, 2, 0]
    D1 = R[..., perm, :][..., :, perm]
    Ds = [D0, D1]

    def d1(i_, j_):
        return D1[..., i_ + 1, j_ + 1]

    for l in range(2, l_max + 1):
        Dl1 = Ds[-1]

        def dl(a_, b_):
            return Dl1[..., a_ + (l - 1), b_ + (l - 1)]

        def p_func(i, a, b):
            if b == l:
                return d1(i, 1) * dl(a, l - 1) - d1(i, -1) * dl(a, -(l - 1))
            if b == -l:
                return d1(i, 1) * dl(a, -(l - 1)) + d1(i, -1) * dl(a, l - 1)
            return d1(i, 0) * dl(a, b)

        rows = []
        for m in range(-l, l + 1):
            row = []
            for n in range(-l, l + 1):
                u, v, w = sph._uvw(l, m, n)
                term = np.zeros(batch)
                if abs(u) > 1e-14:
                    term = term + u * p_func(0, m, n)
                if abs(v) > 1e-14:
                    if m == 0:
                        pv = p_func(1, 1, n) + p_func(-1, -1, n)
                    elif m > 0:
                        dd = 1.0 if m == 1 else 0.0
                        pv = (p_func(1, m - 1, n) * math.sqrt(1 + dd)
                              - p_func(-1, -m + 1, n) * (1 - dd))
                    else:
                        dd = 1.0 if m == -1 else 0.0
                        pv = (p_func(1, m + 1, n) * (1 - dd)
                              + p_func(-1, -m - 1, n) * math.sqrt(1 + dd))
                    term = term + v * pv
                if abs(w) > 1e-14:
                    if m > 0:
                        pw = p_func(1, m + 1, n) + p_func(-1, -m - 1, n)
                    else:
                        pw = p_func(1, m - 1, n) - p_func(-1, -m + 1, n)
                    term = term + w * pw
                row.append(term)
            rows.append(np.stack(row, axis=-1))
        Ds.append(np.stack(rows, axis=-2))
    return Ds[: l_max + 1]


def _rand_rot(rng) -> np.ndarray:
    A = rng.normal(size=(3, 3))
    Q, _ = np.linalg.qr(A)
    if np.linalg.det(Q) < 0:
        Q[:, 0] = -Q[:, 0]
    return Q


@functools.lru_cache(maxsize=None)
def clebsch_gordan(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real coupling tensor C (2l3+1, 2l1+1, 2l2+1), ‖C‖=1; zeros if forbidden."""
    n3, n1, n2 = 2 * l3 + 1, 2 * l1 + 1, 2 * l2 + 1
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return np.zeros((n3, n1, n2))
    rng = np.random.default_rng(hash((l1, l2, l3)) % (2 ** 32))
    lmax = max(l1, l2, l3)
    rows = []
    for _ in range(3):
        R = _rand_rot(rng)
        Ds = _wigner_d_np(R, lmax)
        M = np.kron(np.kron(Ds[l3], Ds[l1]), Ds[l2]).T
        rows.append(M - np.eye(M.shape[0]))
    A = np.concatenate(rows, axis=0)
    _, s, vt = np.linalg.svd(A)
    null_dim = int(np.sum(s < 1e-8))
    assert null_dim == 1, (l1, l2, l3, s[-3:])
    c = vt[-1].reshape(n3, n1, n2)
    # deterministic sign: first nonzero entry positive
    flat = c.reshape(-1)
    nz = flat[np.abs(flat) > 1e-10]
    if len(nz) and nz[0] < 0:
        c = -c
    return c


def paths(l_max_in: int, l_max_sh: int, l_max_out: int) -> List[Tuple[int, int, int]]:
    """All allowed (l_in, l_sh, l_out) coupling paths."""
    out = []
    for l1 in range(l_max_in + 1):
        for l2 in range(l_max_sh + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max_out) + 1):
                out.append((l1, l2, l3))
    return out
