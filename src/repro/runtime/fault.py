"""Fault-tolerance runtime: heartbeats, straggler detection, retry-with-
restore, and elastic re-mesh planning.

On a real cluster these hooks bind to the coordination service (GCS /
Borg / SLURM); here the host-side logic is fully implemented and driven by
injected timings/failures in tests — the policies (quantile straggler
cutoff, checkpoint-restore retry, data-axis shrink plan) are the
deliverable, and the trainer consumes them through this interface.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class StragglerReport:
    slow_workers: List[int]
    p50: float
    p95: float
    cutoff: float


class HeartbeatMonitor:
    """Per-worker step-duration tracker with quantile-based straggler calls.

    A worker is a straggler if its rolling-median step time exceeds
    ``ratio`` x the fleet median over the window (TPU fleets: typically 1.3–2x
    indicates HBM ECC pressure or a failing host NIC).
    """

    def __init__(self, n_workers: int, window: int = 16, ratio: float = 1.5):
        self.n = n_workers
        self.window = window
        self.ratio = ratio
        self.times: List[deque] = [deque(maxlen=window) for _ in range(n_workers)]
        self.last_seen = np.zeros(n_workers)

    def record(self, worker: int, step_time: float, now: Optional[float] = None):
        self.times[worker].append(step_time)
        self.last_seen[worker] = time.monotonic() if now is None else now

    def dead_workers(self, timeout_s: float, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [i for i in range(self.n)
                if self.last_seen[i] and now - self.last_seen[i] > timeout_s]

    def stragglers(self) -> StragglerReport:
        meds = np.array([np.median(t) if t else np.nan for t in self.times])
        fleet = float(np.nanmedian(meds)) if np.any(~np.isnan(meds)) else 0.0
        cutoff = self.ratio * fleet
        slow = [i for i, m in enumerate(meds)
                if not np.isnan(m) and fleet > 0 and m > cutoff]
        p95 = float(np.nanpercentile(meds, 95)) if np.any(~np.isnan(meds)) else 0.0
        return StragglerReport(slow, fleet, p95, cutoff)


@dataclasses.dataclass
class RemeshPlan:
    """Elastic scaling: drop failed hosts by shrinking the data axis.

    The model axis is never resized (TP degree is baked into weight shards);
    capacity changes come out of data parallelism, and the global batch is
    either kept (more grad accumulation) or rescaled.
    """
    old_data: int
    new_data: int
    grad_accum_factor: int
    reshard_from_checkpoint: bool = True


def plan_remesh(data_size: int, failed_workers: int,
                keep_global_batch: bool = True) -> RemeshPlan:
    new = data_size - failed_workers
    # shrink to the largest power-of-two divisor layout we can keep
    while new > 1 and data_size % new != 0:
        new -= 1
    new = max(new, 1)
    accum = (data_size // new) if keep_global_batch else 1
    return RemeshPlan(data_size, new, accum)


class RetryPolicy:
    """Checkpoint-restore retry driver for the training loop."""

    def __init__(self, max_retries: int = 3, backoff_s: float = 1.0):
        self.max_retries = max_retries
        self.backoff_s = backoff_s

    def run(self, step_fn: Callable[[], object],
            restore_fn: Callable[[], None],
            on_failure: Optional[Callable[[int, Exception], None]] = None):
        for attempt in range(self.max_retries + 1):
            try:
                return step_fn()
            except Exception as e:  # noqa: BLE001 — any device/host fault
                if attempt == self.max_retries:
                    raise
                if on_failure:
                    on_failure(attempt, e)
                time.sleep(self.backoff_s * (2 ** attempt))
                restore_fn()
        raise RuntimeError("unreachable")
