"""Community detection for multi-hop reasoning (paper §3.4: "community-based
multi-hop reasoning using Louvain").

Index-build-time (host-side, numpy): one-level Louvain — greedy modularity
moves until convergence — plus a JAX label-propagation fallback for very
large graphs. Communities bias traversal (same-community hops get a weight
boost) which is the paper's 20–30% relational-accuracy mechanism.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.graph_store import GraphStore


def louvain_one_level(n_nodes: int, src: np.ndarray, dst: np.ndarray,
                      weight: np.ndarray, max_sweeps: int = 10,
                      seed: int = 0) -> np.ndarray:
    """Greedy modularity optimisation, one level (no coarsening).

    Returns (N,) community labels. Edges should be directed pairs; the graph
    is treated as undirected (weights summed both ways).
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    w = np.asarray(weight, np.float64)
    # symmetrise
    s2 = np.concatenate([src, dst])
    d2 = np.concatenate([dst, src])
    w2 = np.concatenate([w, w])
    m2 = w2.sum()  # = 2m
    if m2 <= 0:
        return np.zeros(n_nodes, np.int32)

    # CSR for neighbor iteration
    order = np.argsort(s2, kind="stable")
    s2, d2, w2 = s2[order], d2[order], w2[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(np.bincount(s2, minlength=n_nodes), out=indptr[1:])

    k = np.zeros(n_nodes, np.float64)       # weighted degree
    np.add.at(k, s2, w2)
    labels = np.arange(n_nodes, dtype=np.int64)
    sigma_tot = k.copy()                    # community total degree

    rng = np.random.default_rng(seed)
    nodes = np.arange(n_nodes)
    for _ in range(max_sweeps):
        moved = 0
        rng.shuffle(nodes)
        for u in nodes:
            lo, hi = indptr[u], indptr[u + 1]
            if lo == hi:
                continue
            nbr, nw = d2[lo:hi], w2[lo:hi]
            cu = labels[u]
            # weights from u to each neighboring community
            comms, inv = np.unique(labels[nbr], return_inverse=True)
            w_to = np.zeros(len(comms))
            np.add.at(w_to, inv, nw)
            # remove u from its community
            sigma_tot[cu] -= k[u]
            w_cu = w_to[comms == cu].sum() if (comms == cu).any() else 0.0
            # modularity gain of joining community c: w_uc - k_u * sigma_c / m2
            gains = w_to - k[u] * sigma_tot[comms] / m2
            base = w_cu - k[u] * sigma_tot[cu] / m2
            best = int(np.argmax(gains))
            if gains[best] > base + 1e-12 and comms[best] != cu:
                labels[u] = comms[best]
                moved += 1
            sigma_tot[labels[u]] += k[u]
        if moved == 0:
            break
    # relabel densely
    _, dense = np.unique(labels, return_inverse=True)
    return dense.astype(np.int32)


def modularity(n_nodes: int, src, dst, weight, labels) -> float:
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    w = np.asarray(weight, np.float64)
    labels = np.asarray(labels)
    s2 = np.concatenate([src, dst])
    d2 = np.concatenate([dst, src])
    w2 = np.concatenate([w, w])
    m2 = w2.sum()
    if m2 <= 0:
        return 0.0
    k = np.zeros(n_nodes)
    np.add.at(k, s2, w2)
    intra = w2[labels[s2] == labels[d2]].sum() / m2
    sig = np.zeros(labels.max() + 1)
    np.add.at(sig, labels, k)
    return float(intra - np.sum((sig / m2) ** 2))


def label_propagation(g: GraphStore, n_iters: int = 10) -> jax.Array:
    """JAX min-label propagation (connected-component flavored fallback for
    graphs too large for the host sweep): O(E) per iter, fully on device."""
    n = g.n_nodes
    labels = jnp.arange(n, dtype=jnp.int32)

    def step(labels, _):
        neigh_min = jax.ops.segment_min(labels[g.src], g.indices, num_segments=n)
        new = jnp.minimum(labels, neigh_min)
        return new, None

    labels, _ = jax.lax.scan(step, labels, None, length=n_iters)
    return labels


def community_edge_boost(g: GraphStore, labels, boost: float = 1.5) -> jax.Array:
    """Edge weights boosted within communities (traversal bias, §3.4)."""
    lab = jnp.asarray(labels)
    same = lab[g.src] == lab[g.indices]
    return g.edge_weight * jnp.where(same, boost, 1.0)
