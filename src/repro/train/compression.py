"""Gradient compression for slow inter-pod links (distributed-optimization
substrate): top-k sparsification with error feedback, and int8 quantized
all-reduce emulation.

Error feedback (Karimireddy et al. '19): the residual of the compression is
carried into the next step, so compressed SGD/Adam converges at the dense
rate. ``compress -> (all-reduce compressed) -> decompress`` is applied to
the *inter-pod* gradient sync only (the intra-pod psum stays dense) — the
pod axis is the slow link at 1000+ node scale.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class ErrorFeedbackState(NamedTuple):
    residual: object      # pytree like grads (fp32)


def init_error_feedback(grads_like) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def topk_compress(g: jax.Array, frac: float) -> Tuple[jax.Array, jax.Array]:
    """Keep the largest-|g| fraction; returns (values (k,), flat indices (k,))."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(int(frac * flat.shape[0]), 1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_decompress(values, idx, shape) -> jax.Array:
    flat = jnp.zeros((int(jnp.prod(jnp.asarray(shape))),), jnp.float32)
    return flat.at[idx].set(values).reshape(shape)


def compress_grads_topk(grads, ef: ErrorFeedbackState, frac: float = 0.05):
    """Returns (compressed_grads (dense tensors, sparsified), new_ef).

    The compressed gradient is returned dense-but-sparse (zeros elsewhere) so
    the caller's existing all-reduce path applies; on a real deployment the
    (values, indices) pairs are what travel over the pod link — the bytes
    saving is frac·(1 + idx_overhead).
    """
    def one(g, r):
        acc = g.astype(jnp.float32) + r
        vals, idx = topk_compress(acc, frac)
        comp = topk_decompress(vals, idx, acc.shape)
        return comp.astype(g.dtype), acc - comp

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    comp = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    resid = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return comp, ErrorFeedbackState(residual=resid)


def int8_compress(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization (for quantized all-reduce)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_grads_int8(grads, ef: ErrorFeedbackState):
    """Int8 + error feedback (4x inter-pod gradient bytes reduction)."""
    def one(g, r):
        acc = g.astype(jnp.float32) + r
        q, s = int8_compress(acc)
        deq = int8_decompress(q, s)
        return deq.astype(g.dtype), acc - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    comp = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    resid = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return comp, ErrorFeedbackState(residual=resid)
