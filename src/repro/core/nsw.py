"""Navigable-small-world graph index — the paper's HNSW component, re-expressed
for TPU (docs/DESIGN.md §2.2): fixed out-degree adjacency + fixed-width beam search
(`ef` candidates) as batched gathers inside ``lax.while_loop``; vmapped over
queries. Validates the paper's graph-index semantics (recall vs ef) even
though the production hot path is the IVF scan.

Build is IVF-accelerated: each node's M approximate nearest neighbours come
from an IVF search over the corpus (classic NN-descent seeding), which keeps
construction a batch of matmuls rather than pointer insertion.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import ivf as ivf_mod


class NSWGraph(NamedTuple):
    vectors: jax.Array      # (N, d) fp32 (or bf16)
    neighbors: jax.Array    # (N, M) int32, -1 padded
    entry: jax.Array        # () int32 — fixed entry point (medoid-ish)


def build(key, vectors: jax.Array, *, degree: int = 16,
          n_partitions: int = 16, bits: int = 16) -> NSWGraph:
    n, d = vectors.shape
    m = min(degree, n - 1)
    index, _ = ivf_mod.build(key, vectors, jnp.arange(n), n_partitions=min(n_partitions, n),
                             bits=bits, capacity=max(2 * n // min(n_partitions, n) + 1, 8))
    # each node's approx m+1 nearest (self included) via the IVF index
    # staticcheck: disable=HMG003 (build-time scan over a throwaway index just built from `vectors`; no MVCC state exists yet)
    _, ids = ivf_mod.search(index, vectors, n_probe=min(4, n_partitions), k=m + 1)
    # drop self-matches
    self_id = jnp.arange(n)[:, None]
    neigh = jnp.where(ids == self_id, -1, ids)
    # compact: move -1s to the end by sorting on (is_pad, position)
    order = jnp.argsort(jnp.where(neigh < 0, 1, 0), axis=1, stable=True)
    neigh = jnp.take_along_axis(neigh, order, axis=1)[:, :m]
    entry = jnp.argmin(jnp.sum((vectors - vectors.mean(0)) ** 2, axis=1)).astype(jnp.int32)
    return NSWGraph(vectors=vectors.astype(jnp.float32), neighbors=neigh, entry=entry)


@functools.partial(jax.jit, static_argnames=("ef", "k", "max_steps"))
def search(graph: NSWGraph, queries: jax.Array, *, ef: int = 32, k: int = 10,
           max_steps: int = 64) -> Tuple[jax.Array, jax.Array]:
    """Beam search. Returns (scores (Q,k), ids (Q,k)), dot-product similarity."""
    n, d = graph.vectors.shape
    m = graph.neighbors.shape[1]

    def one(q):
        def score(ids):
            v = graph.vectors[jnp.clip(ids, 0, n - 1)]
            s = v @ q
            return jnp.where(ids >= 0, s, -jnp.inf)

        beam_ids = jnp.full((ef,), -1, jnp.int32).at[0].set(graph.entry)
        beam_scores = jnp.full((ef,), -jnp.inf).at[0].set(score(graph.entry[None])[0])
        expanded = jnp.zeros((ef,), bool)
        visited = jnp.zeros((n,), bool).at[graph.entry].set(True)

        def cond(state):
            _, beam_scores, expanded, _, steps = state
            frontier = jnp.logical_and(~expanded, beam_scores > -jnp.inf)
            return jnp.logical_and(jnp.any(frontier), steps < max_steps)

        def body(state):
            beam_ids, beam_scores, expanded, visited, steps = state
            # pick best unexpanded beam entry
            cand = jnp.where(expanded, -jnp.inf, beam_scores)
            pick = jnp.argmax(cand)
            expanded = expanded.at[pick].set(True)
            node = beam_ids[pick]
            neigh = graph.neighbors[jnp.clip(node, 0, n - 1)]          # (M,)
            neigh = jnp.where(node >= 0, neigh, -1)
            fresh = jnp.logical_and(neigh >= 0, ~visited[jnp.clip(neigh, 0, n - 1)])
            neigh = jnp.where(fresh, neigh, -1)
            visited = visited.at[jnp.clip(neigh, 0, n - 1)].set(
                jnp.logical_or(visited[jnp.clip(neigh, 0, n - 1)], neigh >= 0))
            ns = score(neigh)                                           # (M,)
            all_ids = jnp.concatenate([beam_ids, neigh])
            all_scores = jnp.concatenate([beam_scores, ns])
            all_expanded = jnp.concatenate([expanded, jnp.zeros((m,), bool)])
            vals, pos = jax.lax.top_k(all_scores, ef)
            return (all_ids[pos], vals, all_expanded[pos], visited, steps + 1)

        state = (beam_ids, beam_scores, expanded, visited, jnp.zeros((), jnp.int32))
        beam_ids, beam_scores, *_ = jax.lax.while_loop(cond, body, state)
        vals, pos = jax.lax.top_k(beam_scores, min(k, ef))
        out_ids = beam_ids[pos]
        if k > ef:
            out_ids = jnp.pad(out_ids, (0, k - ef), constant_values=-1)
            vals = jnp.pad(vals, (0, k - ef), constant_values=-jnp.inf)
        return vals, out_ids

    return jax.vmap(one)(queries.astype(jnp.float32))
