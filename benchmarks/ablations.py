"""Paper §5 ablations: modality-aware partitioning (§5.1), adaptive updates +
flash quantization (§5.2), hybrid fusion components (§5.3)."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (Decoupled, build_hmgi, load_corpus,
                               make_queries, primary_mod, timeit)
from repro.core import delta as delta_mod
from repro.core import ivf as ivf_mod
from repro.core import partitioner
from repro.data.synthetic import ground_truth_topk, recall_at_k


def _corpus_q(ds="mm-codex-s", n=64):
    corpus = load_corpus(ds)
    mod = primary_mod(ds)
    q = make_queries(corpus, mod, n)
    truth = ground_truth_topk(corpus.vectors[mod], corpus.node_ids[mod], q, 10)
    return corpus, mod, q, truth


def ablation_partitioning(report):
    """§5.1: modality-aware K-means vs monolithic vs random partitions."""
    corpus, mod, q, truth = _corpus_q()
    v = corpus.vectors[mod]
    v = v / np.maximum(np.linalg.norm(v, axis=1, keepdims=True), 1e-9)
    ids = jnp.asarray(corpus.node_ids[mod])
    key = jax.random.PRNGKey(0)
    n, d = v.shape
    kparts = 32

    # modality-aware K-means partitions (ours)
    km, _ = ivf_mod.build(key, jnp.asarray(v), ids, n_partitions=kparts, bits=8)
    # random partitioning (same structure, random centroids)
    rand_cent = jax.random.normal(jax.random.PRNGKey(7), (kparts, d))
    rnd, _ = ivf_mod.build(key, jnp.asarray(v), ids, n_partitions=kparts,
                           bits=8, centroids=rand_cent)

    for name, idx in (("kmeans", km), ("random", rnd)):
        t = timeit(lambda: ivf_mod.search(idx, jnp.asarray(q), n_probe=4, k=10))
        r = recall_at_k(np.asarray(
            ivf_mod.search(idx, jnp.asarray(q), n_probe=4, k=10)[1]), truth)
        # search-space fraction actually scanned
        frac = 4 / kparts
        report(f"a51_partition_{name}", t / len(q) * 1e6,
               f"recall={r:.3f} scanned={frac:.2f}")
    # monolithic: n_probe = all partitions (full scan)
    t = timeit(lambda: ivf_mod.search(km, jnp.asarray(q), n_probe=kparts, k=10))
    r = recall_at_k(np.asarray(
        ivf_mod.search(km, jnp.asarray(q), n_probe=kparts, k=10)[1]), truth)
    report("a51_partition_monolithic", t / len(q) * 1e6,
           f"recall={r:.3f} scanned=1.00")


def ablation_updates(report):
    """§5.2: MVCC delta vs full rebuild on a 10% churn batch; flash-quant
    memory/recall trade."""
    corpus, mod, q, truth = _corpus_q()
    v = corpus.vectors[mod]
    v = v / np.maximum(np.linalg.norm(v, axis=1, keepdims=True), 1e-9)
    n, d = v.shape
    ids = jnp.asarray(corpus.node_ids[mod])
    key = jax.random.PRNGKey(0)
    idx, _ = ivf_mod.build(key, jnp.asarray(v), ids, n_partitions=32, bits=8)
    churn = max(n // 10, 1)
    newv = jnp.asarray(v[:churn] * 0.99)
    new_ids = jnp.arange(churn, dtype=jnp.int32) + corpus.n_nodes

    # delta-store ingestion (ours)
    def with_delta():
        d_ = delta_mod.init(2 * churn, d, max_ids=corpus.n_nodes + 2 * churn)
        d_ = delta_mod.insert(d_, newv, new_ids)
        return delta_mod.search_with_delta(idx, d_, jnp.asarray(q), n_probe=4, k=10)

    t_delta = timeit(with_delta, trials=3)

    # full rebuild baseline
    allv = jnp.concatenate([jnp.asarray(v), newv])
    allids = jnp.concatenate([ids, new_ids])

    def rebuild():
        i2, _ = ivf_mod.build(key, allv, allids, n_partitions=32, bits=8)
        return ivf_mod.search(i2, jnp.asarray(q), n_probe=4, k=10)

    t_rebuild = timeit(rebuild, trials=3)
    report("a52_update_delta", t_delta * 1e3,
           f"rebuild_ms={t_rebuild*1e3:.1f} speedup={t_rebuild/t_delta:.1f}x")

    # flash quantization: memory + recall at 16/8/4 bits
    for bits in (16, 8, 4):
        ib, _ = ivf_mod.build(key, jnp.asarray(v), ids, n_partitions=32,
                              bits=bits)
        r = recall_at_k(np.asarray(
            ivf_mod.search(ib, jnp.asarray(q), n_probe=8, k=10)[1]), truth)
        report(f"a52_quant_{bits}bit", ib.nbytes / 2 ** 20,
               f"recall={r:.3f} MiB={ib.nbytes/2**20:.2f}")


def ablation_fusion(report):
    """§5.3: fused hybrid vs sequential decoupled; adaptive vs fixed weights;
    community boost on/off."""
    corpus, mod, q, truth = _corpus_q()
    hmgi = build_hmgi(corpus)
    dec = Decoupled(corpus, hmgi)

    t_fused = timeit(lambda: hmgi.hybrid_search(q, mod, k=10, n_hops=2))
    t_seq = timeit(lambda: dec.hybrid_search(q, mod, k=10, n_hops=2))
    report("a53_fused", t_fused / len(q) * 1e6, f"qps={len(q)/t_fused:.0f}")
    report("a53_sequential", t_seq / len(q) * 1e6,
           f"qps={len(q)/t_seq:.0f} fused_speedup={t_seq/t_fused:.2f}x")

    # adaptive vs fixed fusion weights: recall of known-item queries
    hmgi_fixed = build_hmgi(corpus, adaptive=False)
    r_adapt = recall_at_k(np.asarray(hmgi.hybrid_search(q, mod, k=10)[1]), truth)
    r_fixed = recall_at_k(np.asarray(hmgi_fixed.hybrid_search(q, mod, k=10)[1]),
                          truth)
    report("a53_adaptive_weights", r_adapt * 1000, f"recall={r_adapt:.3f}")
    report("a53_fixed_weights", r_fixed * 1000, f"recall={r_fixed:.3f}")

    # community-boosted traversal on/off
    boosted = hmgi.boosted_weights
    hmgi.boosted_weights = None
    r_plain = recall_at_k(np.asarray(hmgi.hybrid_search(q, mod, k=10)[1]), truth)
    hmgi.boosted_weights = boosted
    report("a53_no_community_boost", r_plain * 1000, f"recall={r_plain:.3f}")


def run(report):
    ablation_partitioning(report)
    ablation_updates(report)
    ablation_fusion(report)
