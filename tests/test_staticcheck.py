"""staticcheck: per-rule good/bad fixtures, pragma discipline, --fix
rewrites, budget round-trip, and a CLI smoke run over src/.

AST-rule fixtures are inline source snippets checked through
``astrules.check_source`` at hot-path/persistence pseudo-paths — nothing is
imported or executed. Trace-rule fixtures build tiny synthetic jaxprs, so
the detectors are exercised without tracing the real registry entries
(which the CI staticcheck job covers end to end).
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))            # make `tools` importable

from tools.staticcheck import Violation, sort_violations          # noqa: E402
from tools.staticcheck.astrules import check_source               # noqa: E402
from tools.staticcheck.budget import (check_budgets, load_budgets,  # noqa: E402
                                      save_budgets)
from tools.staticcheck.fixes import (insert_mvcc_kwargs,          # noqa: E402
                                     normalize_pragmas)
from tools.staticcheck.pragmas import filter_suppressed, scan_pragmas  # noqa: E402

HOT = "src/repro/core/ivf.py"            # a hot-path pseudo-file
PERSIST = "src/repro/persistence/x.py"   # a persistence pseudo-file


def run_rules(path, src, rule=None):
    src = textwrap.dedent(src)
    vs = check_source(path, src, {rule} if rule else None)
    pragmas = scan_pragmas(path, src)
    return sort_violations(filter_suppressed(vs, pragmas)
                           + pragmas.violations)


def rules_of(vs):
    return [v.rule for v in vs]


# ------------------------------------------------------------------- HMG001
def test_hmg001_bad_host_sync_in_jit():
    vs = run_rules(HOT, """
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            a = float(x.sum())
            b = np.square(x)
            return x.item()
    """, rule="HMG001")
    assert rules_of(vs) == ["HMG001"] * 3
    assert vs[0].line == 7


def test_hmg001_bad_lax_callback():
    vs = run_rules(HOT, """
        import jax

        def outer(xs):
            def body(c, x):
                return c + x.item(), None
            return jax.lax.scan(body, 0.0, xs)
    """, rule="HMG001")
    assert rules_of(vs) == ["HMG001"]


def test_hmg001_good_host_side_code():
    # host orchestration in a hot module is fine — only traced fns count
    vs = run_rules(HOT, """
        import numpy as np

        def build(rows):
            n = int(np.sum(rows))
            return np.zeros(n)
    """, rule="HMG001")
    assert vs == []


def test_hmg001_only_fires_in_hot_modules():
    vs = run_rules("src/repro/serving/batcher.py", """
        import jax

        @jax.jit
        def f(x):
            return x.item()
    """, rule="HMG001")
    assert vs == []


# ------------------------------------------------------------------- HMG002
def test_hmg002_bad_raw_int_to_static_arg():
    vs = run_rules(HOT, """
        def caller(index, q, batch):
            k = int(batch.shape[0])
            return search(index, q, n_probe=4, k=k, node_pass=None)
    """, rule="HMG002")
    assert rules_of(vs) == ["HMG002"]
    assert "'k'" in vs[0].message


def test_hmg002_good_pow2_routed():
    vs = run_rules(HOT, """
        from repro.common.shapes import pow2_round

        def caller(index, q, batch):
            k = pow2_round(len(batch))
            k = min(2 * k, 128)
            return search(index, q, n_probe=4, k=k, node_pass=None)
    """, rule="HMG002")
    assert vs == []


def test_hmg002_good_bit_length_idiom():
    vs = run_rules(HOT, """
        def caller(index, q, batch):
            m = len(batch)
            k = 1 << (m - 1).bit_length()
            return search(index, q, n_probe=4, k=k, node_pass=None)
    """, rule="HMG002")
    assert vs == []


def test_hmg002_positional_static_arg():
    vs = run_rules("src/repro/query/planner.py", """
        def go(index, m, q, probes, width):
            return search_raw(index, m, q, probes, 4, int(width))
    """, rule="HMG002")
    assert rules_of(vs) == ["HMG002"]


# ------------------------------------------------------------------- HMG003
def test_hmg003_bad_missing_visibility_kwarg():
    vs = run_rules("src/repro/core/progressive.py", """
        from repro.core import ivf as ivf_mod

        def go(index, q, k):
            return ivf_mod.search(index, q, n_probe=4, k=k)
    """, rule="HMG003")
    assert rules_of(vs) == ["HMG003"]
    assert vs[0].fixable


def test_hmg003_good_explicit_opt_out_and_threading():
    vs = run_rules("src/repro/core/progressive.py", """
        from repro.core import ivf as ivf_mod

        def go(index, q, k, mask):
            a = ivf_mod.search(index, q, n_probe=4, k=k, node_pass=None)
            b = search_with_delta(index, d, q, n_probe=4, k=k,
                                  mvcc_filter=mask)
            return a, b
    """, rule="HMG003")
    assert vs == []


def test_hmg003_pragma_with_reason_suppresses():
    vs = run_rules("src/repro/core/x.py", """
        def go(index, q, k):
            # staticcheck: disable=HMG003 (fresh build-time index)
            return ivf_mod.search(index, q, n_probe=4, k=k)
    """)
    assert vs == []


def test_hmg003_bare_pragma_suppresses_nothing():
    vs = run_rules("src/repro/core/x.py", """
        def go(index, q, k):
            # staticcheck: disable=HMG003
            return ivf_mod.search(index, q, n_probe=4, k=k)
    """)
    assert rules_of(vs) == ["HMG000", "HMG003"]


def test_unknown_rule_id_in_pragma_is_flagged():
    vs = run_rules("src/repro/core/x.py", """
        x = 1  # staticcheck: disable=HMG999 (whatever)
    """)
    assert rules_of(vs) == ["HMG000"]
    assert "HMG999" in vs[0].message


# ------------------------------------------------------------------- HMG004
def test_hmg004_bad_rename_without_fsync():
    vs = run_rules(PERSIST, """
        import os

        def publish(tmp, final):
            os.replace(tmp, final)
    """, rule="HMG004")
    assert rules_of(vs) == ["HMG004"]


def test_hmg004_good_fsync_then_rename():
    vs = run_rules(PERSIST, """
        import os

        def publish(tmp, final, fd):
            os.fsync(fd)
            os.replace(tmp, final)
    """, rule="HMG004")
    assert vs == []


def test_hmg004_bad_apply_before_wal_append():
    vs = run_rules(PERSIST, """
        class D(Base):
            def insert(self, op):
                r = super().insert(op)
                self._log.append(op)
                return r
    """, rule="HMG004")
    assert rules_of(vs) == ["HMG004"]


def test_hmg004_good_append_then_apply():
    vs = run_rules(PERSIST, """
        class D(Base):
            def insert(self, op):
                self._log.append(op)
                return super().insert(op)
    """, rule="HMG004")
    assert vs == []


def test_hmg004_scoped_to_persistence():
    vs = run_rules("src/repro/data/loader.py", """
        import os

        def swap(a, b):
            os.replace(a, b)
    """, rule="HMG004")
    assert vs == []


# ------------------------------------------------------------- trace layer
jax = pytest.importorskip("jax")


def _lint(fn, args, max_upcast=None):
    from tools.staticcheck.jaxpr_rules import lint_jaxpr
    from tools.staticcheck.registry import TraceEntry
    jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
    return lint_jaxpr(TraceEntry("fixture", None,
                                 max_upcast_elems=max_upcast), jaxpr)


def test_hmg101_bad_slab_scale_dequant():
    import jax.numpy as jnp

    def bad(slab_i8, q):
        return q @ slab_i8.astype(jnp.float32).T

    vs = _lint(bad, (jnp.zeros((4096, 32), jnp.int8),
                     jnp.zeros((4, 32), jnp.float32)), max_upcast=1024)
    assert rules_of(vs) == ["HMG101"]
    assert "(4096, 32)" in vs[0].message


def test_hmg101_good_bounded_rescore_convert():
    import jax.numpy as jnp

    def good(rows_i8, q):
        # k*chunk-sized gather: under the budget, the intended pattern
        return q @ rows_i8.astype(jnp.float32).T

    vs = _lint(good, (jnp.zeros((16, 32), jnp.int8),
                      jnp.zeros((4, 32), jnp.float32)), max_upcast=1024)
    assert vs == []


def test_hmg102_bad_device_put_in_trace():
    import jax.numpy as jnp

    def bad(x):
        return jax.device_put(x) * 2

    vs = _lint(jax.jit(bad), (jnp.zeros((8,), jnp.float32),))
    assert rules_of(vs) == ["HMG102"]


def test_hmg102_good_pure_compute():
    import jax.numpy as jnp

    def good(x):
        return x * 2 + 1

    vs = _lint(jax.jit(good), (jnp.zeros((8,), jnp.float32),))
    assert vs == []


# ------------------------------------------------------------------- HMG103
def test_budget_roundtrip(tmp_path):
    p = tmp_path / "budgets.json"
    measured = {"ivf.search": 4, "delta.insert": 2}
    save_budgets(measured, p)
    assert load_budgets(p) == measured
    data = json.loads(p.read_text())
    assert data["workload"]["phases"][0] == "ingest"


def test_budget_gate_fails_on_respecialisation():
    # the scratch-branch scenario: an unpadded shape arg starts compiling
    # one signature per batch, blowing past the budgeted count
    budgets = {"ivf.search": 4}
    vs = check_budgets({"ivf.search": 9}, budgets)
    assert rules_of(vs) == ["HMG103"]
    assert "9 distinct signatures" in vs[0].message


def test_budget_gate_passes_within_budget():
    assert check_budgets({"ivf.search": 3}, {"ivf.search": 4}) == []


def test_budget_gate_flags_unbudgeted_entry():
    vs = check_budgets({"new.entry": 1}, {})
    assert rules_of(vs) == ["HMG103"]


def test_checked_in_budgets_cover_registry():
    from tools.staticcheck.registry import BUDGET_ENTRIES
    budgets = load_budgets()
    assert set(budgets) == {name for name, _, _ in BUDGET_ENTRIES}


# --------------------------------------------------------------------- --fix
def test_fix_normalizes_pragma_spelling():
    src = "x = 1  #staticcheck:disable = hmg003 , HMG001  (why not)\n"
    out, n = normalize_pragmas(src)
    assert n == 1
    assert out == "x = 1  # staticcheck: disable=HMG001,HMG003 (why not)\n"
    # idempotent
    again, n2 = normalize_pragmas(out)
    assert (again, n2) == (out, 0)


def test_fix_never_invents_a_reason():
    src = "x = 1  # staticcheck: disable=HMG003\n"
    out, n = normalize_pragmas(src)
    assert (out, n) == (src, 0)


def test_fix_inserts_node_pass_kwarg():
    src = textwrap.dedent("""
        def go(index, q, k):
            return ivf_mod.search(index, q,
                                  n_probe=4, k=k)
    """)
    vs = check_source("src/repro/core/x.py", src, {"HMG003"})
    assert rules_of(vs) == ["HMG003"]
    out, n = insert_mvcc_kwargs(src, vs)
    assert n == 1
    assert "k=k, node_pass=None)" in out
    assert check_source("src/repro/core/x.py", out, {"HMG003"}) == []


# ----------------------------------------------------------------- CLI smoke
def test_cli_clean_on_tree():
    r = subprocess.run([sys.executable, "-m", "tools.staticcheck"],
                       cwd=REPO, capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_reports_rule_and_location(tmp_path):
    bad = tmp_path / "src" / "repro" / "persistence"
    bad.mkdir(parents=True)
    f = bad / "bad.py"
    f.write_text("import os\n\ndef pub(a, b):\n    os.replace(a, b)\n")
    r = subprocess.run([sys.executable, "-m", "tools.staticcheck", str(f)],
                       cwd=REPO, capture_output=True, text=True)
    assert r.returncode == 1
    assert "HMG004" in r.stdout and "bad.py:4" in r.stdout


def test_cli_json_and_explain():
    r = subprocess.run([sys.executable, "-m", "tools.staticcheck",
                        "--json", "src/repro/core"],
                       cwd=REPO, capture_output=True, text=True)
    assert r.returncode == 0
    assert json.loads(r.stdout) == []
    r2 = subprocess.run([sys.executable, "-m", "tools.staticcheck",
                         "--explain", "HMG002"],
                        cwd=REPO, capture_output=True, text=True)
    assert r2.returncode == 0 and "Recompile" in r2.stdout


# -------------------------------------------------------- shapes helpers
def test_shapes_helpers():
    from repro.common.shapes import pad_to_chunk, pow2_round
    assert pow2_round(1) == 1
    assert pow2_round(5) == 8
    assert pow2_round(8) == 8
    assert pow2_round(900, hi=512) == 512
    assert pad_to_chunk(0, 16) == 0
    assert pad_to_chunk(1, 16) == 16
    assert pad_to_chunk(16, 16) == 16
    assert pad_to_chunk(17, 16) == 32
    with pytest.raises(ValueError):
        pad_to_chunk(4, 0)
