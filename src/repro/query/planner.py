"""Logical -> physical compiler for the declarative query engine.

``compile_plan`` walks a ``repro.query.ast.Plan`` and emits a
``PhysicalPlan`` with every cost decision resolved against the index and
the cost model (core/cost_model.py):

- **Where placement** — the chain's predicates compile once to one (N,)
  ``node_pass`` mask; ``plan_filtered_scan`` picks *pushdown* (mask folded
  into the scan's validity lanes pre-top-k) vs *oversample-then-post-filter*
  for the seed scan. Traversal routing and candidate surfacing always carry
  the mask — that part is semantic, not a cost choice (a filtered hybrid
  query must not route relevance through an excluded node).
- **Probe widths** — per seed stage: the explicit ``n_probe`` wins, else a
  ``min_recall`` constraint resolves through ``select_plan`` (Eq. 5
  greedy-cheapest-feasible), else the config default. Seed *scan* width is
  ``plan_seed_width``: bare k when the seeds are the answer, oversampled
  when downstream stages re-rank them.
- **Device layout** — per seed stage, ``plan_device_layout`` decides whether
  the stable scan runs single-device or row-sharded over the index's mesh
  (per-shard masked probes + cross-shard top-k merge): sharded when the
  quantized slab exceeds the per-device budget, forced by
  ``cfg.shard_layout`` either way. The two layouts scan the same candidate
  set in the same stored representation, so the choice never changes
  results — only where the flops land.
- **Fusion representation** — per traverse stage, ``plan_fusion`` chooses
  candidate-sparse fusion (seeds ∪ frontier, O(Q·C) memory) vs one dense
  scatter over all N (when the frontier would cover the corpus anyway).
  ``fusion_repr`` forces a choice (the facade's hybrid_search pins "sparse"
  to stay bit-identical with its historic path).
- **Maintenance awareness** — probe widths clamp to the *live* partition
  count: ``plan_maintenance``-driven merges park emptied partitions
  (docs/DESIGN.md §3.4), and a probe spent on a parked slab scans nothing.
  The parked sentinel centroids rank below every live centroid, so the
  clamp never changes which rows are scanned — full probe stays full
  coverage of the live corpus.

Set-op sources compile each branch as an independent physical plan (its own
Where scope, its own widths — a branch without an explicit ``topk`` gets
oversampled parent-k headroom so the combined set can still fill k).

``PhysicalPlan.describe()`` renders the chosen plan (the benchmark
harness's plan-choice reporting and ``HMGIIndex.explain``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple, Union

import jax
import numpy as np

from repro import obs
from repro.core.cost_model import (DeviceLayoutPlan, FilteredScanPlan,
                                   estimate_selectivity, plan_filtered_scan,
                                   plan_fusion, plan_seed_width, select_plan)
from repro.core import traversal as trav_mod
from repro.query.ast import CrossModal, Q, SetOp, Traverse, Where


@dataclasses.dataclass(eq=False)
class PSeed:
    modality: str
    query: jax.Array                       # (Q, d), L2-normalised
    k: int                                 # seed scan width
    n_probe: int
    impl: str
    filter_plan: Optional[FilteredScanPlan]  # None = unfiltered scan
    # where the stable scan runs: single-device or row-sharded over the
    # mesh (cost_model.plan_device_layout against the index's mesh)
    layout: DeviceLayoutPlan = DeviceLayoutPlan("single", 1)


@dataclasses.dataclass(eq=False)
class PTraverse:
    n_hops: int
    damping: float
    edge_type_mask: Optional[jax.Array]    # (T,) fp32, None = all types
    k_fuse: int                            # stage output width
    frontier: int                          # traversal candidates admitted
    repr: str                              # "sparse" | "dense"


@dataclasses.dataclass(eq=False)
class PRescore:
    modality: str
    query: jax.Array                       # (Q, d2), L2-normalised
    weight: float


@dataclasses.dataclass(eq=False)
class PSetOp:
    kind: str                              # "union" | "intersect"
    left: "PhysicalPlan"
    right: "PhysicalPlan"


@dataclasses.dataclass(eq=False)
class PhysicalPlan:
    source: Union[PSeed, PSetOp]
    stages: Tuple[Any, ...]
    k: int
    node_pass: Optional[jax.Array]         # (N,) bool, None = no predicate
    where: Tuple[Any, ...]                 # raw predicates (reporting)

    def describe(self) -> str:
        parts = []
        if isinstance(self.source, PSetOp):
            parts.append(f"{self.source.kind}[{self.source.left.describe()}"
                         f" | {self.source.right.describe()}]")
        else:
            s = self.source
            f = ("" if s.filter_plan is None else
                 f" filter={s.filter_plan.mode}"
                 f"(sel={s.filter_plan.selectivity:.3f})")
            lay = ("" if s.layout.layout == "single" else
                   f" layout=sharded(x{s.layout.n_shards})")
            parts.append(f"seed[{s.modality} k={s.k} probe={s.n_probe}{f}{lay}]")
        for st in self.stages:
            if isinstance(st, PTraverse):
                t = "" if st.edge_type_mask is None else " typed"
                parts.append(f"traverse[h={st.n_hops}{t} fuse={st.repr}"
                             f" k_fuse={st.k_fuse} F={st.frontier}]")
            else:
                parts.append(f"rescore[{st.modality} w={st.weight:g}]")
        parts.append(f"topk({self.k})")
        return " -> ".join(parts)


def compile_plan(index, plan, *, k: Optional[int] = None,
                 node_pass: Optional[jax.Array] = None,
                 fusion_repr: Optional[str] = None) -> PhysicalPlan:
    """index: the HMGIIndex the plan will run against. k: fallback terminal
    width when the plan has no ``topk`` (the plan's own wins). node_pass:
    precompiled predicate mask (skips recompiling the chain's Where).
    fusion_repr: force "sparse"/"dense" fusion (None = cost-based)."""
    # one "query.plan" span per top-level compile; set-op branches recurse
    # through _compile_plan so the histogram counts whole compiles, not
    # every branch
    with obs.span("query.plan"):
        return _compile_plan(index, plan, k=k, node_pass=node_pass,
                             fusion_repr=fusion_repr)


def _compile_plan(index, plan, *, k: Optional[int] = None,
                  node_pass: Optional[jax.Array] = None,
                  fusion_repr: Optional[str] = None) -> PhysicalPlan:
    if isinstance(plan, Q):
        plan = plan.plan
    cfg = index.cfg
    k = int(plan.k or k or cfg.top_k)

    preds = tuple(p for st in plan.stages if isinstance(st, Where)
                  for p in st.predicates)
    if node_pass is None and preds:
        node_pass = index._node_pass(list(preds))
    logical = [st for st in plan.stages if not isinstance(st, Where)]
    downstream = any(isinstance(st, (Traverse, CrossModal)) for st in logical)

    if isinstance(plan.source, SetOp):
        branch_k = plan_seed_width(k, True)
        source: Union[PSeed, PSetOp] = PSetOp(
            plan.source.kind,
            _compile_plan(index, plan.source.left, k=branch_k,
                          fusion_repr=fusion_repr),
            _compile_plan(index, plan.source.right, k=branch_k,
                          fusion_repr=fusion_repr))
        c = (source.left.k + source.right.k if source.kind == "union"
             else source.left.k)
    else:
        vs = plan.source
        m = index.modalities[vs.modality]
        n_probe = vs.n_probe
        if n_probe is None and vs.min_recall is not None:
            n_probe = select_plan(index.cost_model, n=int(m.ids.shape[0]),
                                  d=int(m.vectors.shape[1]),
                                  min_recall=vs.min_recall).n_probe
        # maintenance can park (merge away) partitions: a probe slot spent
        # on a parked, empty slab is pure waste, and the parked sentinel
        # centroids always rank last — clamping to the live count scans
        # exactly the same rows (full probe stays full coverage)
        n_live = (int(np.sum(~m.stats.parked)) if m.stats is not None
                  else m.ivf.n_partitions)
        n_probe = min(int(n_probe or cfg.n_probe), max(n_live, 1))
        k_seed = plan_seed_width(k, downstream)
        fplan = None
        if node_pass is not None:
            # (the filter metrics are recorded at execution time, in
            # executor.run_seed — explain() must stay side-effect free)
            fplan = plan_filtered_scan(
                estimate_selectivity(node_pass), k_seed,
                n_rows=int(m.ids.shape[0]),
                oversample=cfg.filter_oversample,
                prefilter_max_sel=cfg.filter_prefilter_max_sel)
        source = PSeed(vs.modality, index._norm_queries(vs.query), k_seed,
                       int(n_probe or cfg.n_probe), vs.impl, fplan,
                       index.device_layout(vs.modality))
        c = k_seed

    stages = []
    for st in logical:
        if isinstance(st, Traverse):
            if index.graph is None:
                raise ValueError("Traverse needs a graph: ingest(edges=...)")
            hops = cfg.max_hops if st.hops is None else int(st.hops)
            fp = plan_fusion(index.n_nodes, k, c)
            mask = trav_mod.as_edge_mask(st.edge_types)
            stages.append(PTraverse(hops, float(st.damping), mask,
                                    fp.k_fuse, fp.frontier,
                                    fusion_repr or fp.repr))
            if hops > 0:
                c = fp.k_fuse
        else:  # CrossModal (width-preserving re-score)
            if st.modality not in index.modalities:
                raise KeyError(f"unknown modality {st.modality!r}")
            stages.append(PRescore(st.modality,
                                   index._norm_queries(st.query),
                                   float(st.weight)))
    return PhysicalPlan(source, tuple(stages), k, node_pass, preds)
