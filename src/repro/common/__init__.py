from repro.common.tree import count_params, tree_bytes, tree_finite
