"""GNN smoke + invariance tests for the four assigned archs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models.gnn import dimenet as dimenet_mod
from repro.models.gnn import driver
from repro.train.optimizer import init_adamw

GNN_ARCHS = ["egnn", "dimenet", "nequip", "equiformer-v2"]


@pytest.fixture(scope="module")
def graph():
    return driver.make_flat_graph(60, 200, 8, seed=0)


def _trip(g, cfg):
    if cfg.model != "dimenet":
        return None
    return dimenet_mod.build_triplets(np.asarray(g.edge_src),
                                      np.asarray(g.edge_dst),
                                      np.asarray(g.edge_mask))


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_forward_shapes_finite(arch, graph):
    cfg = smoke_config(arch)
    params, _ = driver.init_model(cfg, jax.random.PRNGKey(0), 8)
    logits = driver.node_logits_local(cfg, params, graph, _trip(graph, cfg))
    assert logits.shape == (60, driver.N_CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_rotation_invariance(arch, graph):
    cfg = smoke_config(arch)
    params, _ = driver.init_model(cfg, jax.random.PRNGKey(1), 8)
    rng = np.random.default_rng(5)
    A = rng.normal(size=(3, 3))
    Q, _ = np.linalg.qr(A)
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1
    R = jnp.asarray(Q.astype(np.float32))
    t = _trip(graph, cfg)
    l1 = driver.node_logits_local(cfg, params, graph, t)
    l2 = driver.node_logits_local(
        cfg, params, graph._replace(positions=graph.positions @ R.T), t)
    rel = float(jnp.max(jnp.abs(l1 - l2)) / (jnp.max(jnp.abs(l1)) + 1e-9))
    assert rel < 1e-4, rel


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_train_step_runs(arch, graph):
    cfg = smoke_config(arch)
    params, _ = driver.init_model(cfg, jax.random.PRNGKey(0), 8)
    step = driver.make_train_step(cfg, "full_graph")
    opt = init_adamw(params)
    batch = {"graph": graph, "triplets": _trip(graph, smoke_config(arch))}
    p, o, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))


def test_molecule_batch_loss():
    cfg = smoke_config("egnn")
    params, _ = driver.init_model(cfg, jax.random.PRNGKey(0), 4, n_out=1)
    g, energy = driver.make_molecule_batch(4, 10, 24, seed=0)
    sums = driver.molecule_loss(cfg, params, g, energy)
    assert np.isfinite(float(sums["loss_sum"]))


def test_neighbor_sampler_tree_shapes():
    from repro.sparse.sampler import NeighborSampler, sizes_for_fanout
    rng = np.random.default_rng(0)
    n, e = 200, 2000
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    feats = rng.normal(size=(n, 6)).astype(np.float32)
    labels = rng.integers(0, 4, n)
    s = NeighborSampler(n, src, dst, feats, labels)
    batch = s.sample(np.arange(8), (3, 2))
    n_sub, n_edge = sizes_for_fanout((3, 2))
    assert batch.nodes.shape == (8, n_sub)
    assert batch.edge_src.shape == (8, n_edge)
    # every masked edge's endpoints are valid local indices
    assert batch.edge_src.max() < n_sub and batch.edge_dst.max() < n_sub
    # roots are the targets
    np.testing.assert_array_equal(batch.nodes[:, 0], np.arange(8))


def test_minibatch_loss_runs():
    from repro.sparse.sampler import NeighborSampler
    from repro.models.gnn.common import FlatGraph
    rng = np.random.default_rng(0)
    n, e = 200, 2000
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    feats = rng.normal(size=(n, 6)).astype(np.float32)
    labels = rng.integers(0, driver.N_CLASSES, n)
    s = NeighborSampler(n, src, dst, feats, labels)
    batch = s.sample(np.arange(8), (3, 2))
    cfg = smoke_config("egnn")
    params, _ = driver.init_model(cfg, jax.random.PRNGKey(0), 6)
    b = batch.nodes.shape[0]
    n_sub = batch.nodes.shape[1]
    pos = rng.normal(size=(b, n_sub, 3)).astype(np.float32)
    g = FlatGraph(feats=jnp.asarray(batch.feats), positions=jnp.asarray(pos),
                  edge_src=jnp.asarray(batch.edge_src),
                  edge_dst=jnp.asarray(batch.edge_dst),
                  edge_mask=jnp.asarray(batch.edge_mask),
                  node_mask=jnp.asarray(batch.nodes >= 0),
                  labels=jnp.zeros((b, n_sub), jnp.int32))
    sums = driver.minibatch_loss(cfg, params, g, jnp.asarray(batch.labels))
    assert np.isfinite(float(sums["loss_sum"]))
