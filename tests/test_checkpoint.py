"""Checkpoint substrate hardening: exotic dtypes, retention, tmp-dir GC,
async-failure surfacing, structured validation errors (docs/DESIGN.md §7.1)."""
import json
import os
import shutil
import tempfile
import threading

import numpy as np
import jax.numpy as jnp
import ml_dtypes
import pytest

from repro.checkpoint import (CheckpointError, CheckpointManager,
                              checkpoint_steps, restore_checkpoint,
                              save_checkpoint)


class TestExoticDtypes:
    @pytest.mark.parametrize("dtype", ["bfloat16", "float8_e4m3fn",
                                       "float8_e5m2"])
    def test_roundtrip_bitwise(self, dtype):
        dt = getattr(ml_dtypes, dtype)
        rng = np.random.default_rng(0)
        arr = rng.standard_normal((9, 5)).astype(dt)
        with tempfile.TemporaryDirectory() as tmp:
            save_checkpoint(tmp, 1, {"x": arr})
            got, _, _ = restore_checkpoint(tmp, {"x": arr})
            assert got["x"].dtype == jnp.dtype(dtype)
            # compare raw bits, not values (NaNs etc. must survive too)
            a = np.asarray(got["x"]).view(np.uint8)
            np.testing.assert_array_equal(a, arr.view(np.uint8))

    def test_flat_restore_preserves_host_dtypes(self):
        tree = {"i64": np.arange(4, dtype=np.int64),
                "f64": np.ones(3, np.float64),
                "bf16": np.ones(3).astype(ml_dtypes.bfloat16)}
        with tempfile.TemporaryDirectory() as tmp:
            save_checkpoint(tmp, 2, tree)
            got, step, _ = restore_checkpoint(tmp, like=None)
            assert step == 2
            assert got["i64"].dtype == np.int64      # no silent 32-bit cast
            assert got["f64"].dtype == np.float64
            assert got["bf16"].dtype == ml_dtypes.bfloat16


class TestRetentionAndTmp:
    def test_retention_keeps_exactly_k(self):
        with tempfile.TemporaryDirectory() as tmp:
            mgr = CheckpointManager(tmp, keep=3, async_writes=False)
            for s in range(1, 8):
                mgr.save(s, {"w": jnp.full((2,), float(s))})
            assert checkpoint_steps(tmp) == [5, 6, 7]

    def test_restore_latest_skips_and_gcs_tmp_survivor(self):
        tree = {"w": jnp.zeros((2,))}
        with tempfile.TemporaryDirectory() as tmp:
            mgr = CheckpointManager(tmp, keep=5, async_writes=False)
            mgr.save(1, {"w": jnp.full((2,), 1.0)})
            mgr.save(3, {"w": jnp.full((2,), 3.0)})
            # a crashed writer's leftover: newer step number, but only .tmp
            leftover = os.path.join(tmp, "step_00000009.tmp")
            os.makedirs(leftover)
            with open(os.path.join(leftover, "leaf_00000.npy"), "wb") as f:
                f.write(b"partial")
            got, step, _ = mgr.restore_latest(tree)
            assert step == 3                      # .tmp is never a candidate
            np.testing.assert_allclose(np.asarray(got["w"]), 3.0)
            assert not os.path.exists(leftover)   # and it was GC'd

    def test_concurrent_save_restore_ordering(self):
        # async saves from one thread racing restore_latest from another:
        # restore must always see a *complete* checkpoint (atomic rename),
        # and after the final wait() the latest step is the last save
        with tempfile.TemporaryDirectory() as tmp:
            mgr = CheckpointManager(tmp, keep=10, async_writes=True)
            errors = []

            def reader():
                for _ in range(20):
                    try:
                        got, step, _ = mgr.restore_latest(like=None)
                        np.testing.assert_allclose(
                            np.asarray(got["w"]), float(step))
                    except FileNotFoundError:
                        pass                      # nothing written yet: fine
                    except Exception as e:        # noqa: BLE001
                        errors.append(e)

            t = threading.Thread(target=reader)
            t.start()
            for s in range(1, 9):
                mgr.save(s, {"w": jnp.full((3,), float(s))})
            mgr.wait()
            t.join()
            assert not errors
            _, step, _ = mgr.restore_latest(like=None)
            assert step == 8


class TestAsyncErrorSurfacing:
    def test_background_failure_raises_on_next_call(self):
        tmp = tempfile.mkdtemp()
        try:
            mgr = CheckpointManager(tmp, keep=2, async_writes=True)
            mgr.save(1, {"w": jnp.zeros((2,))})
            mgr.wait()
            # break the directory out from under the background writer
            shutil.rmtree(tmp)
            with open(tmp, "w") as f:
                f.write("not a directory")
            mgr.save(2, {"w": jnp.zeros((2,))})
            with pytest.raises(CheckpointError, match="background"):
                mgr.wait()
            # surfaced exactly once: the next wait is clean
            mgr.wait()
        finally:
            if os.path.isfile(tmp):
                os.unlink(tmp)
            shutil.rmtree(tmp, ignore_errors=True)


class TestValidation:
    def _save_one(self, tmp):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": jnp.ones((4,), jnp.int32)}
        save_checkpoint(tmp, 1, tree)
        return tree

    def test_corrupt_leaf_names_leaf(self):
        with tempfile.TemporaryDirectory() as tmp:
            tree = self._save_one(tmp)
            leaf = os.path.join(tmp, "step_00000001", "leaf_00000.npy")
            raw = bytearray(open(leaf, "rb").read())
            raw[-2] ^= 0xFF
            with open(leaf, "wb") as f:
                f.write(raw)
            with pytest.raises(CheckpointError, match="crc32") as ei:
                restore_checkpoint(tmp, tree)
            assert ei.value.leaf == "a"

    def test_shape_mismatch_names_leaf(self):
        with tempfile.TemporaryDirectory() as tmp:
            tree = self._save_one(tmp)
            bad = dict(tree, b=jnp.ones((5,), jnp.int32))
            with pytest.raises(CheckpointError, match="shape") as ei:
                restore_checkpoint(tmp, bad)
            assert ei.value.leaf == "b"

    def test_dtype_mismatch_names_leaf(self):
        with tempfile.TemporaryDirectory() as tmp:
            tree = self._save_one(tmp)
            bad = dict(tree, b=jnp.ones((4,), jnp.float32))
            with pytest.raises(CheckpointError, match="dtype") as ei:
                restore_checkpoint(tmp, bad)
            assert ei.value.leaf == "b"

    def test_structure_change_is_structured_error(self):
        with tempfile.TemporaryDirectory() as tmp:
            tree = self._save_one(tmp)
            with pytest.raises(CheckpointError, match="structure"):
                restore_checkpoint(tmp, {"a": tree["a"]})

    def test_torn_manifest(self):
        with tempfile.TemporaryDirectory() as tmp:
            self._save_one(tmp)
            mpath = os.path.join(tmp, "step_00000001", "manifest.json")
            blob = open(mpath).read()
            with open(mpath, "w") as f:
                f.write(blob[: len(blob) // 2])   # torn write
            with pytest.raises(CheckpointError, match="manifest"):
                restore_checkpoint(tmp, like=None)

    def test_manifest_json_is_valid(self):
        with tempfile.TemporaryDirectory() as tmp:
            self._save_one(tmp)
            m = json.load(open(os.path.join(tmp, "step_00000001",
                                            "manifest.json")))
            assert {r["key"] for r in m["leaves"]} == {"a", "b"}
            assert all("crc32" in r for r in m["leaves"])
