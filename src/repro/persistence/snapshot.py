"""Versioned full-state snapshots of an ``HMGIIndex``, via the checkpoint
substrate.

A snapshot is one atomically-renamed checkpoint directory
(``<data_dir>/snapshots/step_<seq>/``) holding the index's complete
``state_tree`` — quantized slabs byte-identical, centroids (incl. parked
sentinels), delta + staleness bits, graph CSR, attributes, MVCC
tombstone/superseded bits, partition stats, workload heat, PRNG key — with
per-leaf crc32 checksums and a manifest ``extra`` carrying:

- ``last_seq`` — the op-log sequence number this snapshot reflects; replay
  resumes at ``last_seq + 1``
- ``config_fingerprint`` — sha256 over the sorted config dict; recovery
  refuses to load state under a different config (a changed quantization
  width or partition count would silently reinterpret bytes)
- ``meta`` — the structural metadata ``state_tree`` emitted

Snapshots restore through ``restore_checkpoint(like=None)`` (flat-dict
mode): host-side stat arrays come back with their exact stored dtypes and
``HMGIIndex.restore_state`` re-materialises device state, so a restored
index is bit-identical to the snapshotted one on every search path.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from typing import Optional, Tuple

from repro import obs
from repro.checkpoint.checkpoint import (CheckpointError, checkpoint_steps,
                                         restore_checkpoint, save_checkpoint)

SNAP_SUBDIR = "snapshots"
WAL_SUBDIR = "wal"


def config_fingerprint(cfg) -> str:
    """Stable hash of the full config: any field change (quant bits,
    partition count, delta capacity, ...) changes the fingerprint."""
    d = dataclasses.asdict(cfg)
    blob = json.dumps(d, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()


def snapshot_dir(data_dir: str) -> str:
    return os.path.join(data_dir, SNAP_SUBDIR)


def wal_dir(data_dir: str) -> str:
    return os.path.join(data_dir, WAL_SUBDIR)


def write_snapshot(data_dir: str, index, last_seq: int) -> str:
    """One snapshot at step ``last_seq``. Atomic (tmp + fsync + rename)."""
    with obs.span("snapshot.write"):
        tree, meta = index.state_tree()
        extra = {"last_seq": int(last_seq), "meta": meta,
                 "config_fingerprint": config_fingerprint(index.cfg)}
        return save_checkpoint(snapshot_dir(data_dir), int(last_seq), tree,
                               extra)


def read_snapshot(data_dir: str, cfg, step: int) -> Tuple[dict, dict, int]:
    """Loads snapshot ``step`` -> (tree, meta, last_seq), validating every
    leaf checksum and the config fingerprint. Raises ``CheckpointError``
    naming the offending leaf on any mismatch."""
    sdir = snapshot_dir(data_dir)
    with obs.span("snapshot.read"):
        tree, _, extra = restore_checkpoint(sdir, like=None, step=step)
    want = config_fingerprint(cfg)
    got = extra.get("config_fingerprint")
    if got != want:
        raise CheckpointError(
            os.path.join(sdir, f"step_{step:08d}"), "",
            f"config fingerprint mismatch: snapshot {got!r} vs current "
            f"{want!r} — the stored state was built under a different config")
    return tree, extra["meta"], int(extra["last_seq"])


def snapshot_steps(data_dir: str):
    """Complete snapshot steps, ascending (each step = its last_seq)."""
    return checkpoint_steps(snapshot_dir(data_dir))


def prune_snapshots(data_dir: str, keep: int) -> Optional[int]:
    """Deletes all but the newest ``keep`` snapshots. Returns the oldest
    *retained* step — the log-GC floor: records ≤ it are unreachable from
    every retained snapshot and may be unlinked."""
    steps = snapshot_steps(data_dir)
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(os.path.join(snapshot_dir(data_dir), f"step_{s:08d}"),
                      ignore_errors=True)
    kept = steps[-keep:] if keep else []
    return kept[0] if kept else None
