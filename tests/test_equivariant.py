"""Equivariant-library math tests: SH orthogonality/equivariance, Wigner-D,
Clebsch-Gordan coupling, Bessel bases."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.equivariant.bessel import (_jl_np, angular_basis, bessel_zeros,
                                      radial_bessel_basis,
                                      spherical_bessel_basis)
from repro.equivariant.cg import _rand_rot, _wigner_d_np, clebsch_gordan
from repro.equivariant.spherical import (real_sph_harm, rotation_to_align_z,
                                         sh_dim, wigner_d_from_rotation)


def _rot(seed):
    return _rand_rot(np.random.default_rng(seed))


class TestSphericalHarmonics:
    def test_orthonormality_mc(self, rng):
        v = rng.normal(size=(100_000, 3))
        v /= np.linalg.norm(v, axis=1, keepdims=True)
        y = np.asarray(real_sph_harm(jnp.asarray(v), 3))
        gram = (y.T @ y) / len(v) * 4 * np.pi
        np.testing.assert_allclose(gram, np.eye(sh_dim(3)), atol=0.05)

    @pytest.mark.parametrize("l_max", [1, 2, 4, 6])
    def test_wigner_equivariance(self, l_max, rng):
        R = jnp.asarray(np.stack([_rot(i) for i in range(3)]).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(3, 3)).astype(np.float32))
        y = real_sph_harm(v, l_max)
        yr = real_sph_harm(jnp.einsum("bij,bj->bi", R, v), l_max)
        ds = wigner_d_from_rotation(R, l_max)
        for l in range(l_max + 1):
            sl = slice(l * l, (l + 1) * (l + 1))
            pred = jnp.einsum("bmn,bn->bm", ds[l], y[:, sl])
            np.testing.assert_allclose(np.asarray(pred), np.asarray(yr[:, sl]),
                                       atol=5e-5)

    def test_wigner_orthogonal(self):
        ds = wigner_d_from_rotation(jnp.asarray(_rot(0)[None].astype(np.float32)), 4)
        for d in ds:
            m = np.asarray(d[0])
            np.testing.assert_allclose(m @ m.T, np.eye(len(m)), atol=1e-4)

    def test_align_z(self, rng):
        v = jnp.asarray(rng.normal(size=(16, 3)).astype(np.float32))
        r = rotation_to_align_z(v)
        z = jnp.einsum("bij,bj->bi", r, v / jnp.linalg.norm(v, axis=1, keepdims=True))
        np.testing.assert_allclose(np.asarray(z), [[0, 0, 1.0]] * 16, atol=1e-5)
        np.testing.assert_allclose(np.asarray(jnp.linalg.det(r)), 1.0, atol=1e-5)

    def test_align_z_degenerate_poles(self):
        v = jnp.asarray([[0.0, 0, 1.0], [0.0, 0, -1.0]])
        r = rotation_to_align_z(v)
        z = jnp.einsum("bij,bj->bi", r, v)
        np.testing.assert_allclose(np.asarray(z), [[0, 0, 1.0]] * 2, atol=1e-6)


class TestClebschGordan:
    @pytest.mark.parametrize("l1,l2,l3", [(1, 1, 0), (1, 1, 2), (2, 2, 2),
                                          (3, 2, 1), (6, 2, 6)])
    def test_equivariance(self, l1, l2, l3):
        c = clebsch_gordan(l1, l2, l3)
        r = _rot(42)
        ds = _wigner_d_np(r, max(l1, l2, l3))
        lhs = np.einsum("mn,nab->mab", ds[l3], c)
        rhs = np.einsum("mab,ax,by->mxy", c, ds[l1], ds[l2])
        np.testing.assert_allclose(lhs, rhs, atol=1e-10)

    def test_forbidden_paths_zero(self):
        assert np.allclose(clebsch_gordan(1, 1, 3), 0)
        assert np.allclose(clebsch_gordan(0, 2, 1), 0)

    def test_normalised(self):
        c = clebsch_gordan(2, 1, 2)
        assert abs(np.linalg.norm(c) - 1.0) < 1e-10


class TestBessel:
    def test_j0_zeros_are_n_pi(self):
        z = bessel_zeros(0, 4)
        np.testing.assert_allclose(z[0] / np.pi, [1, 2, 3, 4], rtol=1e-8)

    def test_zeros_are_roots(self):
        z = bessel_zeros(4, 3)
        for l in range(5):
            assert np.max(np.abs(_jl_np(l, z[l]))) < 1e-10

    def test_bases_finite_and_cutoff(self):
        r = jnp.linspace(0.05, 6.0, 32)
        rb = radial_bessel_basis(r, 6, 5.0)
        sb = spherical_bessel_basis(r, 7, 6, 5.0)
        ab = angular_basis(jnp.linspace(0, np.pi, 8), 7)
        for arr in (rb, sb, ab):
            assert bool(jnp.all(jnp.isfinite(arr)))
        # envelope: zero beyond the cutoff
        assert float(jnp.max(jnp.abs(rb[r > 5.0]))) == 0.0
        assert float(jnp.max(jnp.abs(sb[r > 5.0]))) == 0.0

    def test_legendre_recurrence(self):
        a = np.asarray(angular_basis(jnp.asarray([0.3]), 4))[0]
        c = np.cos(0.3)
        want = [1, c, 0.5 * (3 * c ** 2 - 1), 0.5 * (5 * c ** 3 - 3 * c)]
        np.testing.assert_allclose(a, want, rtol=1e-5)
